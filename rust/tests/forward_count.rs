//! Closed-loop complexity contract: the staged pipeline performs O(L)
//! layer forwards where the rescan reference performs O(L²).
//!
//! This lives in its own integration-test binary (single `#[test]`) so
//! the process-global counter in `bench_util` sees no concurrent
//! increments from other tests.

use grail::bench_util::{layer_forwards, layer_forwards_reset};
use grail::compress::Selector;
use grail::data::{SynthText, TextSplit};
use grail::grail::{compress_model, compress_model_rescan, Method, CompressionSpec};
use grail::nn::models::{LmBatch, LmConfig, TinyLm};
use grail::rng::Pcg64;

#[test]
fn closed_loop_layer_forwards_are_linear_in_depth() {
    let layers = 3usize;
    let n_sites = 2 * layers; // one attention + one MLP site per block
    let mut rng = Pcg64::seed(11);
    let lm = TinyLm::init(LmConfig { n_layers: layers, ..Default::default() }, &mut rng);
    let ts = SynthText::new(5).generate(TextSplit::Calib, 2000);
    let calib = LmBatch::from_tokens(&ts, 16, 8);

    // Single shard / single worker so the counter reflects segment
    // executions of the whole batch, independent of sharding.
    let mut cfg = CompressionSpec::uniform(Method::Prune(Selector::Wanda), 0.5, true);
    cfg.shards = 1;
    cfg.workers = 1;

    layer_forwards_reset();
    let mut a = lm.clone();
    let rep = compress_model(&mut a, &calib, &cfg);
    let staged = layer_forwards();
    assert_eq!(rep.sites.len(), n_sites);
    assert!(rep.sites.iter().all(|s| s.units_after < s.units_before));

    layer_forwards_reset();
    let mut b = lm.clone();
    compress_model_rescan(&mut b, &calib, &cfg);
    let rescan = layer_forwards();

    // Staged: one tap per site plus one segment step per site boundary
    // = 2·S − 1. Rescan: site `si` re-runs the whole prefix (si segment
    // steps + 1 tap) = S·(S+1)/2.
    assert_eq!(
        staged,
        (2 * n_sites - 1) as u64,
        "staged layer forwards must be linear in depth"
    );
    assert_eq!(
        rescan,
        (n_sites * (n_sites + 1) / 2) as u64,
        "rescan reference must be quadratic in depth"
    );
    assert!(staged < rescan);

    // And the two strategies still agree on the compressed model.
    assert_eq!(a.forward(&calib), b.forward(&calib));
}
