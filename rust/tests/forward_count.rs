//! Closed-loop complexity contract: the staged pipeline performs O(L)
//! layer forwards where the rescan reference performs O(L²).
//!
//! This lives in its own integration-test binary (single `#[test]`) so
//! the process-global counter in `bench_util` sees no concurrent
//! increments from other tests.

mod common;

use grail::bench_util::{layer_forwards, layer_forwards_reset};
use grail::compress::Selector;
use grail::grail::{
    compress_model, compress_model_rescan, plan_for_model, BudgetMode, CompressionSpec, Method,
    SearchSeed,
};
use grail::serve::digest::digest_bytes;
use grail::serve::provider::{self, StatsContext};
use grail::serve::StatsCache;
use std::sync::Arc;

#[test]
fn closed_loop_layer_forwards_are_linear_in_depth() {
    let layers = 3usize;
    let n_sites = 2 * layers; // one attention + one MLP site per block
    let lm = common::lm_layers(layers, 11);
    let calib = common::lm_calib(5, 2000, 16, 8);

    // Single shard / single worker so the counter reflects segment
    // executions of the whole batch, independent of sharding.
    let mut cfg = CompressionSpec::uniform(Method::Prune(Selector::Wanda), 0.5, true);
    cfg.shards = 1;
    cfg.workers = 1;

    layer_forwards_reset();
    let mut a = lm.clone();
    let rep = compress_model(&mut a, &calib, &cfg);
    let staged = layer_forwards();
    assert_eq!(rep.sites.len(), n_sites);
    assert!(rep.sites.iter().all(|s| s.units_after < s.units_before));

    layer_forwards_reset();
    let mut b = lm.clone();
    compress_model_rescan(&mut b, &calib, &cfg);
    let rescan = layer_forwards();

    // Staged: one tap per site plus one segment step per site boundary
    // = 2·S − 1. Rescan: site `si` re-runs the whole prefix (si segment
    // steps + 1 tap) = S·(S+1)/2.
    assert_eq!(
        staged,
        (2 * n_sites - 1) as u64,
        "staged layer forwards must be linear in depth"
    );
    assert_eq!(
        rescan,
        (n_sites * (n_sites + 1) / 2) as u64,
        "rescan reference must be quadratic in depth"
    );
    assert!(staged < rescan);

    // And the two strategies still agree on the compressed model.
    assert_eq!(a.forward(&calib), b.forward(&calib));

    // Statistics-driven plan resolution is one streamed pass: the
    // gram-sensitivity allocator costs exactly one open-loop pass over
    // the dense model (S taps + S−1 segment steps per shard).
    let mut sens_cfg = cfg.clone();
    sens_cfg.budget = BudgetMode::GramSensitivity { target_ratio: 0.5 };
    layer_forwards_reset();
    let plan = plan_for_model(&lm, &calib, &sens_cfg).unwrap();
    assert_eq!(plan.sites.len(), n_sites);
    assert_eq!(
        layer_forwards(),
        (2 * n_sites - 1) as u64,
        "gram-sensitivity resolution must be one streamed pass"
    );

    // And when the gram-sensitivity allocator composes with the plan
    // search (`budget.seed = "gram-sensitivity"`), the seed
    // sensitivities come from the search's own statistics pass: one
    // pass total, not a sensitivity pass followed by a search pass.
    let mut tune_cfg = cfg.clone();
    tune_cfg.shards = 4; // the held-out split needs ≥ 2 shards
    tune_cfg.workers = 1;
    tune_cfg.budget =
        BudgetMode::Search { target_ratio: 0.5, alpha_grid: vec![1e-4, 5e-3], rounds: 1 };
    tune_cfg.search_seed = SearchSeed::GramSensitivity;
    layer_forwards_reset();
    let plan = plan_for_model(&lm, &calib, &tune_cfg).unwrap();
    assert_eq!(plan.sites.len(), n_sites);
    assert_eq!(
        layer_forwards(),
        4 * (2 * n_sites - 1) as u64,
        "sensitivity-seeded search must reuse its single statistics pass"
    );

    // Warm statistics cache: with a provider installed and the cache
    // populated, plan resolution — both the gram-sensitivity allocator
    // and the full search — performs ZERO calibration layer forwards;
    // every statistic streams off disk, and the plans stay
    // bit-identical to their cold counterparts.
    let cache_root =
        std::env::temp_dir().join(format!("grail_fwd_cache_{}", std::process::id()));
    std::fs::remove_dir_all(&cache_root).ok();
    let ctx = StatsContext::new(
        Arc::new(StatsCache::open(&cache_root).unwrap()),
        digest_bytes(b"lm-layers-3-11"),
        digest_bytes(b"lm-calib-5-2000-16-8"),
    );
    let cold_sens = plan_for_model(&lm, &calib, &sens_cfg).unwrap();
    {
        // Populate: one miss pass per shard geometry.
        let _scope = provider::install(ctx.clone());
        plan_for_model(&lm, &calib, &sens_cfg).unwrap();
        plan_for_model(&lm, &calib, &tune_cfg).unwrap();
    }
    layer_forwards_reset();
    let _scope = provider::install(ctx);
    let warm_sens = plan_for_model(&lm, &calib, &sens_cfg).unwrap();
    let warm_tune = plan_for_model(&lm, &calib, &tune_cfg).unwrap();
    assert_eq!(
        layer_forwards(),
        0,
        "warm-cache plan resolution must skip every calibration layer forward"
    );
    assert_eq!(warm_sens.to_toml(), cold_sens.to_toml());
    assert_eq!(warm_tune.to_toml(), plan.to_toml());
    std::fs::remove_dir_all(&cache_root).ok();
}
