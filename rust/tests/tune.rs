//! `grail tune` acceptance tests: at a matched parameter budget the
//! searched plan beats the uniform spec's held-out reconstruction
//! error on multiple model families, the winning plan is bit-identical
//! at any worker count (the same contract as the blocked solver), and
//! winners survive the TOML round trip.

mod common;

use grail::compress::Selector;
use grail::grail::{
    execute_plan, plan_for_model, score_plan, search_plan, BudgetMode, CompressionPlan,
    CompressionSpec, Method,
};
use grail::nn::models::LmConfig;
use grail::nn::Linear;

/// A search spec sharing every default with the uniform spec, so the
/// seed plan and the uniform plan coincide and their held-out scores
/// are directly comparable.
fn search_spec(ratio: f64) -> CompressionSpec {
    let mut spec = CompressionSpec::uniform(Method::Prune(Selector::Wanda), ratio, true);
    spec.budget = BudgetMode::Search {
        target_ratio: ratio,
        alpha_grid: vec![1e-6, 1e-4, 5e-3, 5e-2],
        rounds: 2,
    };
    spec
}

/// Scale the producer rows `from..` of a layer to ~zero: those units
/// carry almost no activation energy, so a uniform keep allocation
/// wastes budget on them — exactly the situation keep reallocation
/// must exploit.
fn dampen_rows(l: &mut Linear, from: usize) {
    let (out, inn) = (l.w.dim(0), l.w.dim(1));
    for u in from..out {
        for v in &mut l.w.data_mut()[u * inn..(u + 1) * inn] {
            *v *= 1e-3;
        }
        l.b.data_mut()[u] *= 1e-3;
    }
}

#[test]
fn tuned_plan_beats_uniform_on_mlp() {
    let mut m = common::mlp(51);
    // Site 1's producer (fc2) is three-quarters dead; site 0 is full.
    dampen_rows(&mut m.fc2, 8);
    let x = common::vision_calib(52, 96);

    let uniform = CompressionSpec::uniform(Method::Prune(Selector::Wanda), 0.5, true);
    let plan_u = plan_for_model(&m, &x, &uniform).unwrap();
    let out = search_plan(&m, &x, &search_spec(0.5)).unwrap();

    // Matched parameter budget: the winner spends no more weighted
    // units than the uniform plan.
    assert!(
        out.plan.total_keep_weighted() <= plan_u.total_keep_weighted(),
        "tuned {} vs uniform {} weighted units",
        out.plan.total_keep_weighted(),
        plan_u.total_keep_weighted()
    );
    // The search starts from the uniform allocation and only accepts
    // strictly improving moves; with a mostly-dead site to donate from
    // it must find at least one.
    assert!(out.keep_moves >= 1, "no keep reallocation accepted");
    let uniform_score = score_plan(&m, &x, &plan_u);
    assert!(
        out.final_err < uniform_score,
        "tuned {} !< uniform {}",
        out.final_err,
        uniform_score
    );

    // Both plans execute into working models, and the tuned execution
    // honours the searched keep counts.
    let mut a = m.clone();
    execute_plan(&mut a, &x, &plan_u);
    assert!(a.forward(&x).all_finite());
    let mut b = m.clone();
    let rep = execute_plan(&mut b, &x, &out.plan);
    assert!(b.forward(&x).all_finite());
    for (o, ps) in rep.sites.iter().zip(&out.plan.sites) {
        assert_eq!(o.units_after, ps.keep, "{}", o.id);
    }
}

#[test]
fn tuned_plan_beats_uniform_on_tinylm() {
    let mut m = common::lm(LmConfig::default(), 53);
    // block0.mlp's producer is three-quarters dead.
    dampen_rows(&mut m.blocks[0].fc, 48);
    let calib = common::lm_calib(54, 12_000, 16, 32);

    let uniform = CompressionSpec::uniform(Method::Prune(Selector::Wanda), 0.5, true);
    let plan_u = plan_for_model(&m, &calib, &uniform).unwrap();
    let out = search_plan(&m, &calib, &search_spec(0.5)).unwrap();

    assert!(out.plan.total_keep_weighted() <= plan_u.total_keep_weighted());
    assert!(out.keep_moves >= 1, "no keep reallocation accepted");
    let uniform_score = score_plan(&m, &calib, &plan_u);
    assert!(
        out.final_err < uniform_score,
        "tuned {} !< uniform {}",
        out.final_err,
        uniform_score
    );

    let mut b = m.clone();
    let rep = execute_plan(&mut b, &calib, &out.plan);
    assert!(b.forward(&calib).all_finite());
    for (o, ps) in rep.sites.iter().zip(&out.plan.sites) {
        assert_eq!(o.units_after, ps.keep, "{}", o.id);
    }
}

/// The winning plan must be byte-identical at any worker count: every
/// candidate evaluation is a pure function fanned over disjoint result
/// slots, and all accept/reject decisions run serially on the gathered
/// scores. (`workers` itself is an execution knob recorded in the
/// plan, so it is normalized before comparing.)
#[test]
fn worker_count_bit_invariance() {
    let mut m = common::mlp(51);
    dampen_rows(&mut m.fc2, 8);
    let x = common::vision_calib(52, 96);

    let plan_for_workers = |workers: usize| -> (CompressionPlan, f64) {
        let mut spec = search_spec(0.5);
        spec.workers = workers;
        let out = search_plan(&m, &x, &spec).unwrap();
        let mut plan = out.plan;
        plan.workers = 0;
        (plan, out.final_err)
    };
    let (serial, serial_err) = plan_for_workers(1);
    for workers in [2usize, 3, 8] {
        let (par, par_err) = plan_for_workers(workers);
        assert_eq!(par, serial, "workers={workers}");
        assert_eq!(
            par.to_toml().into_bytes(),
            serial.to_toml().into_bytes(),
            "workers={workers}: serialized plans differ"
        );
        assert_eq!(par_err.to_bits(), serial_err.to_bits(), "workers={workers}");
    }
}

/// A searched winner survives the TOML round trip bit-for-bit — the
/// contract behind `grail tune` emitting plan files that `grail run`
/// can execute later.
#[test]
fn tuned_plan_roundtrips_through_toml() {
    let mut m = common::mlp(51);
    dampen_rows(&mut m.fc2, 8);
    let x = common::vision_calib(52, 96);
    let out = search_plan(&m, &x, &search_spec(0.5)).unwrap();
    let back = CompressionPlan::parse(&out.plan.to_toml()).unwrap();
    assert_eq!(back, out.plan);
}
