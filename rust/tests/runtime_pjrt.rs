//! PJRT runtime integration: load the AOT-compiled HLO artifacts and
//! check them against the Rust-native implementations on identical
//! weights — the cross-language parity contract of the three-layer
//! architecture.
//!
//! Requires `make artifacts`; tests skip with a notice otherwise.

use grail::coordinator::{Artifacts, Zoo};
use grail::data::io::{read_images, read_tokens};
use grail::nn::models::LmBatch;
use grail::runtime::Runtime;
use grail::tensor::{ops, Tensor};

fn setup() -> Option<(Artifacts, Zoo, Runtime)> {
    let art = Artifacts::default_root();
    match Zoo::open(art.clone()) {
        Ok(zoo) => match Runtime::cpu(art.clone()) {
            Ok(rt) => Some((art, zoo, rt)),
            Err(e) => {
                eprintln!("skipping: PJRT unavailable: {e}");
                None
            }
        },
        Err(_) => {
            eprintln!("skipping runtime test (run `make artifacts`)");
            None
        }
    }
}

/// The AOT Gram kernel (Pallas, interpret-lowered) matches the Rust
/// SYRK on the same data.
#[test]
fn gram_kernel_matches_rust_syrk() {
    let Some((_, _, mut rt)) = setup() else { return };
    let mut rng = grail::rng::Pcg64::seed(3);
    for h in [64usize, 192] {
        let mut x = Tensor::zeros(&[1024, h]);
        rng.fill_normal(x.data_mut(), 1.0);
        let outs = rt.run_f32(&format!("gram_h{h}_n1024"), &[&x]).unwrap();
        assert_eq!(outs.len(), 1);
        let got = &outs[0];
        assert_eq!(got.shape(), &[h, h]);
        let want = ops::gram(&x);
        let denom = want.frobenius().max(1.0);
        let rel = {
            let mut d = got.clone();
            ops::axpy(&mut d, -1.0, &want);
            d.frobenius() / denom
        };
        assert!(rel < 1e-4, "h={h}: relative gram error {rel}");
    }
}

/// The AOT MLP forward (weights baked) matches the Rust MLP loaded
/// from the same checkpoint.
#[test]
fn mlp_forward_parity() {
    let Some((art, zoo, mut rt)) = setup() else { return };
    let m = zoo.mlp("mlp_seed0").unwrap();
    let imgs = read_images(&art.data("vision_test.imgs")).unwrap().slice(0, 128);
    let outs = rt.run_f32("mlp_seed0_fwd", &[&imgs.x]).unwrap();
    let want = m.forward(&imgs.x);
    assert_eq!(outs[0].shape(), want.shape());
    let diff = outs[0].max_abs_diff(&want);
    assert!(diff < 1e-3, "mlp logits diverge by {diff}");
}

/// The AOT MiniResNet forward matches the Rust conv/BN stack.
#[test]
fn resnet_forward_parity() {
    let Some((art, zoo, mut rt)) = setup() else { return };
    let m = zoo.resnet("resnet_seed0").unwrap();
    let imgs = read_images(&art.data("vision_test.imgs")).unwrap().slice(0, 64);
    // The AOT graph takes NCHW [64, 3, 16, 16].
    let x4 = imgs.x.clone().reshape(&[64, 3, 16, 16]);
    let outs = rt.run_f32("resnet_seed0_fwd", &[&x4]).unwrap();
    let want = m.forward(&imgs.x);
    let diff = outs[0].clone().reshape(&[64, 10]).max_abs_diff(&want);
    assert!(diff < 2e-3, "resnet logits diverge by {diff}");
}

/// The AOT TinyViT forward (Pallas fused linear+GELU inside) matches
/// the Rust implementation.
#[test]
fn vit_forward_parity() {
    let Some((art, zoo, mut rt)) = setup() else { return };
    let m = zoo.vit("vit_seed0").unwrap();
    let imgs = read_images(&art.data("vision_test.imgs")).unwrap().slice(0, 64);
    let x4 = imgs.x.clone().reshape(&[64, 3, 16, 16]);
    let outs = rt.run_f32("vit_seed0_fwd", &[&x4]).unwrap();
    let want = m.forward(&imgs.x);
    let diff = outs[0].clone().reshape(&[64, 10]).max_abs_diff(&want);
    assert!(diff < 2e-3, "vit logits diverge by {diff}");
}

/// The AOT TinyLm forwards (MHA + GQA) match the Rust decoder.
#[test]
fn lm_forward_parity() {
    let Some((art, zoo, mut rt)) = setup() else { return };
    let toks = read_tokens(&art.data("text_calib.tokens")).unwrap();
    let batch = LmBatch::from_tokens(&toks, 32, 8);
    for name in ["tinylm_mha", "tinylm_gqa"] {
        let m = zoo.lm(name).unwrap();
        let outs = rt.run_tokens(&format!("{name}_fwd"), &batch.inputs, 8, 32).unwrap();
        let want = m.forward(&batch);
        let got = outs[0].clone().reshape(&[8 * 32, m.cfg.vocab]);
        let diff = got.max_abs_diff(&want);
        assert!(diff < 2e-3, "{name} logits diverge by {diff}");
    }
}

/// The calibration graph's taps match the Rust taps — the consumer-
/// input activations GRAIL consumes are identical across languages.
#[test]
fn lm_calibration_taps_parity() {
    let Some((art, zoo, mut rt)) = setup() else { return };
    let toks = read_tokens(&art.data("text_calib.tokens")).unwrap();
    let batch = LmBatch::from_tokens(&toks, 32, 8);
    let m = zoo.lm("tinylm_mha").unwrap();
    let outs = rt.run_tokens("tinylm_mha_calib", &batch.inputs, 8, 32).unwrap();
    let (_, taps) = m.forward_with_taps(&batch);
    assert_eq!(outs.len(), 1 + taps.len(), "logits + one tap per site");
    for (i, tap) in taps.iter().enumerate() {
        let got = outs[i + 1].clone().reshape(&[tap.dim(0), tap.dim(1)]);
        let diff = got.max_abs_diff(tap);
        assert!(diff < 2e-3, "tap {i} diverges by {diff}");
    }
}

/// Executables are cached: the second load is a no-op and re-execution
/// is deterministic.
#[test]
fn runtime_caching_and_determinism() {
    let Some((art, _, mut rt)) = setup() else { return };
    let imgs = read_images(&art.data("vision_test.imgs")).unwrap().slice(0, 128);
    let a = rt.run_f32("mlp_seed0_fwd", &[&imgs.x]).unwrap();
    assert_eq!(rt.loaded().len(), 1);
    let b = rt.run_f32("mlp_seed0_fwd", &[&imgs.x]).unwrap();
    assert_eq!(a[0], b[0]);
}
