//! Shared seeded fixtures for the integration-test binaries: model
//! family constructors, calibration batches, random linear-algebra
//! helpers, and report assertions. Each test binary compiles this
//! module independently (`mod common;`), so helpers unused by one
//! binary are expected.
#![allow(dead_code)]

use grail::data::{SynthText, SynthVision, TextSplit, VisionSet};
use grail::grail::Report;
use grail::nn::models::{LmBatch, LmConfig, MiniResNet, MlpNet, TinyLm, TinyViT, VitConfig};
use grail::rng::Pcg64;
use grail::tensor::ops::gram;
use grail::tensor::Tensor;

/// The standard MLP fixture: `MlpNet::init(768, 32, 10)` from a fresh
/// generator seeded with `seed`.
pub fn mlp(seed: u64) -> MlpNet {
    mlp_sized(768, 32, 10, seed)
}

/// An MLP with explicit geometry (wider/narrower sweeps).
pub fn mlp_sized(in_dim: usize, hidden: usize, out: usize, seed: u64) -> MlpNet {
    MlpNet::init(in_dim, hidden, out, &mut Pcg64::seed(seed))
}

/// The standard MiniResNet fixture.
pub fn resnet(seed: u64) -> MiniResNet {
    MiniResNet::init(&mut Pcg64::seed(seed))
}

/// The standard TinyViT fixture (default config).
pub fn vit(seed: u64) -> TinyViT {
    TinyViT::init(VitConfig::default(), &mut Pcg64::seed(seed))
}

/// A TinyLm with the given config from a fresh seeded generator.
pub fn lm(cfg: LmConfig, seed: u64) -> TinyLm {
    TinyLm::init(cfg, &mut Pcg64::seed(seed))
}

/// A TinyLm with `n_layers` layers and otherwise-default (MHA) config.
pub fn lm_layers(n_layers: usize, seed: u64) -> TinyLm {
    lm(LmConfig { n_layers, ..Default::default() }, seed)
}

/// Synthetic vision calibration images `[n, 768]`.
pub fn vision_calib(seed: u64, n: usize) -> Tensor {
    vision_set(seed, n).x
}

/// Synthetic labelled vision set (accuracy / REPAIR fixtures).
pub fn vision_set(seed: u64, n: usize) -> VisionSet {
    SynthVision::new(seed).generate(n)
}

/// Synthetic LM batch from an arbitrary grammar split.
pub fn lm_batch(
    text_seed: u64,
    split: TextSplit,
    tokens: usize,
    seq: usize,
    windows: usize,
) -> LmBatch {
    let ts = SynthText::new(text_seed).generate(split, tokens);
    LmBatch::from_tokens(&ts, seq, windows)
}

/// Synthetic LM calibration batch (the `Calib` split).
pub fn lm_calib(text_seed: u64, tokens: usize, seq: usize, windows: usize) -> LmBatch {
    lm_batch(text_seed, TextSplit::Calib, tokens, seq, windows)
}

/// Standard-normal tensor of the given shape.
pub fn randn(r: &mut Pcg64, shape: &[usize]) -> Tensor {
    let mut t = Tensor::zeros(shape);
    r.fill_normal(t.data_mut(), 1.0);
    t
}

/// Well-conditioned SPD matrix: `XᵀX/rows + I`.
pub fn spd(r: &mut Pcg64, n: usize) -> Tensor {
    let rows = 2 * n + 3;
    let x = randn(r, &[rows, n]);
    let mut g = gram(&x);
    for v in g.data_mut().iter_mut() {
        *v /= rows as f32;
    }
    for i in 0..n {
        let v = g.at2(i, i) + 1.0;
        g.set2(i, i, v);
    }
    g
}

/// Site-by-site bitwise equality of two pipeline reports.
pub fn assert_reports_identical(a: &Report, b: &Report) {
    assert_eq!(a.sites.len(), b.sites.len(), "site counts");
    for (x, y) in a.sites.iter().zip(&b.sites) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.units_before, y.units_before);
        assert_eq!(x.units_after, y.units_after);
        assert_eq!(
            x.recon_err.to_bits(),
            y.recon_err.to_bits(),
            "site {}: recon_err {} vs {}",
            x.id,
            x.recon_err,
            y.recon_err
        );
    }
}
