//! Self-test for `grail check` (rust/src/analysis/).
//!
//! Two halves:
//!   1. The committed tree must come back clean under the committed
//!      allowlist — exactly what CI's `grail check --deny` enforces —
//!      with no stale allowlist entries.
//!   2. A synthetic tree with one injected violation per lint class
//!      must be caught at the exact file:line, and `--deny` must turn
//!      that into a CLI error (process exit 1 via main).
//!
//! The injected violations live inside string literals below, so the
//! real scan over this very file masks them out — the committed-tree
//! half stays clean.

use grail::analysis::{check_cli, run_check, DEFAULT_ALLOWLIST};
use grail::cli::Args;
use std::path::{Path, PathBuf};

#[test]
fn committed_tree_is_clean_under_committed_allowlist() {
    // Cargo runs integration tests with cwd = the package root.
    let report = run_check(Path::new("."), Path::new(DEFAULT_ALLOWLIST)).unwrap();
    let denied: Vec<String> = report
        .denied()
        .map(|f| format!("{} {}:{}  {}", f.lint, f.file, f.line, f.message))
        .collect();
    assert!(
        denied.is_empty(),
        "denied findings on the committed tree:\n{}",
        denied.join("\n")
    );
    assert!(
        report.stale.is_empty(),
        "stale allowlist entries (prune them): {:?}",
        report.stale
    );
    assert!(report.files_scanned > 40, "scanned only {} files", report.files_scanned);
    assert!(report.allowed_count() > 0, "the committed allowlist should be waiving findings");
}

fn write(root: &Path, rel: &str, text: &str) {
    let p = root.join(rel);
    std::fs::create_dir_all(p.parent().unwrap()).unwrap();
    std::fs::write(p, text).unwrap();
}

fn synthetic_tree(tag: &str) -> PathBuf {
    let root = std::env::temp_dir().join(format!("grail-check-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    // One violation per lint class, at a known line.
    let bad_lines = [
        "use std::collections::HashMap;", // 1: forbidden-nondeterminism
        "",
        "pub unsafe fn no_contract() {}", // 3: undocumented-unsafe
        "",
        "pub fn total(xs: &[f32]) -> f32 {",
        "    let mut s = 0.0;",
        "    for x in xs {",
        "        s += *x;", // 8: float-reduction-discipline
        "    }",
        "    s",
        "}",
        "",
        "pub fn lonely_ref() {}", // 13: oracle-pairing (no fast twin, untested)
    ];
    write(&root, "rust/src/bad.rs", &bad_lines.join("\n"));
    // A narrowing `as` cast in a wire-format module path.
    write(
        &root,
        "rust/src/serve/cache.rs",
        "pub fn encode_len(n: usize) -> u32 {\n    n as u32\n}\n", // 2: wire-format-casts
    );
    root
}

#[test]
fn injected_violations_are_reported_at_their_lines() {
    let root = synthetic_tree("lines");
    // Nonexistent allowlist = empty allowlist: everything is denied.
    let report = run_check(&root, Path::new("no-such-allowlist.txt")).unwrap();
    let has = |lint: &str, file: &str, line: usize| {
        report.denied().any(|f| f.lint == lint && f.file == file && f.line == line)
    };
    let table = report.render_table();
    assert!(has("forbidden-nondeterminism", "rust/src/bad.rs", 1), "nondet missed:\n{table}");
    assert!(has("undocumented-unsafe", "rust/src/bad.rs", 3), "unsafe missed:\n{table}");
    assert!(has("float-reduction-discipline", "rust/src/bad.rs", 8), "float missed:\n{table}");
    assert!(has("oracle-pairing", "rust/src/bad.rs", 13), "oracle missed:\n{table}");
    assert!(has("wire-format-casts", "rust/src/serve/cache.rs", 2), "cast missed:\n{table}");
    assert!(report.denied_count() >= 5, "expected >= 5 denied, got:\n{table}");
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn deny_flag_fails_the_cli_and_json_report_is_written() {
    let root = synthetic_tree("cli");
    let json = root.join("lint-report.json");
    let argv = [
        "check".to_string(),
        format!("--root={}", root.display()),
        "--allowlist=no-such-allowlist.txt".to_string(),
        format!("--json={}", json.display()),
        "--deny".to_string(),
    ];
    let args = Args::parse(argv.into_iter()).unwrap();
    let err = check_cli(&args).expect_err("--deny must fail on a dirty tree");
    assert!(err.to_string().contains("denied"), "unexpected error: {err:#}");
    let body = std::fs::read_to_string(&json).expect("json report written before the deny error");
    assert!(body.contains("\"schema\": \"grail-check-v1\""));
    assert!(body.contains("wire-format-casts"));
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn allowlist_ratchet_waives_exactly_n_then_denies() {
    let root = synthetic_tree("ratchet");
    // Waive the nondet finding (unbounded) and nothing else.
    write(
        &root,
        "analysis/allowlist.txt",
        "forbidden-nondeterminism rust/src/bad.rs -- synthetic fixture\n",
    );
    let report = run_check(&root, Path::new("analysis/allowlist.txt")).unwrap();
    assert_eq!(report.allowed_count(), 1, "exactly the nondet finding is waived");
    assert!(report.denied().all(|f| f.lint != "forbidden-nondeterminism"));
    assert!(report.stale.is_empty());
    let _ = std::fs::remove_dir_all(&root);
}
