//! Property suite for the packed GEMM/SYRK engine
//! (`grail::tensor::gemm`): packed kernels vs the scalar `*_ref`
//! oracles across microkernel/panel boundary shapes, NaN/∞ propagation
//! through zero entries, worker-count bit-invariance of the parallel
//! row-panel fan-out, and exact agreement (no data-dependent path) on
//! zero-heavy integer-valued inputs.

mod common;

use grail::rng::Pcg64;
use grail::tensor::gemm::{self, KC, MC, MR, NR};
use grail::tensor::{ops, Tensor};

/// Max |packed − ref| tolerance for random-normal operands of depth
/// `k`: both paths accumulate ascending-k, so the only divergence is
/// FMA contraction in the packed microkernel.
fn tol(k: usize) -> f32 {
    1e-4 * (1.0 + (k as f32).sqrt())
}

fn assert_close(packed: &[f32], reference: &[f32], k: usize, ctx: &str) {
    assert_eq!(packed.len(), reference.len(), "{ctx}");
    let t = tol(k);
    for (i, (p, r)) in packed.iter().zip(reference).enumerate() {
        assert!((p - r).abs() <= t, "{ctx}: element {i}: packed {p} vs ref {r}");
    }
}

#[test]
fn packed_gemm_matches_reference_across_panel_boundaries() {
    let mut rng = Pcg64::seed(1);
    let ms = [1usize, 3, MR, MR + 1, 2 * MR + 1, MC, MC + 3];
    let ns = [1usize, NR - 1, NR, NR + 1, 2 * NR + 5];
    let ks = [1usize, 7, KC, KC + 9];
    for &m in &ms {
        for &n in &ns {
            for &k in &ks {
                let a = common::randn(&mut rng, &[m, k]);
                let b = common::randn(&mut rng, &[k, n]);
                let c0 = common::randn(&mut rng, &[m, n]); // nonzero: tests accumulate
                let mut c_ref = c0.clone();
                let mut c_pack = c0.clone();
                ops::gemm_acc_ref(a.data(), b.data(), c_ref.data_mut(), m, k, n, 0.7);
                gemm::gemm_nn_packed(a.data(), b.data(), c_pack.data_mut(), m, k, n, 0.7, 1);
                assert_close(c_pack.data(), c_ref.data(), k, &format!("nn {m}x{k}x{n}"));

                let bt = common::randn(&mut rng, &[n, k]);
                let mut c_ref = c0.clone();
                let mut c_pack = c0.clone();
                ops::gemm_nt_acc_ref(a.data(), bt.data(), c_ref.data_mut(), m, k, n);
                gemm::gemm_nt_packed(a.data(), bt.data(), c_pack.data_mut(), m, k, n, 1);
                assert_close(c_pack.data(), c_ref.data(), k, &format!("nt {m}x{k}x{n}"));
            }
        }
    }
}

#[test]
fn packed_gemm_k_zero_and_empty_dims_are_noops() {
    let mut c = vec![1.5f32; 6];
    gemm::gemm_nn_packed(&[], &[], &mut c, 2, 0, 3, 1.0, 1);
    gemm::gemm_nt_packed(&[], &[], &mut c, 2, 0, 3, 1);
    assert_eq!(c, vec![1.5f32; 6]);
    let mut empty: Vec<f32> = Vec::new();
    gemm::gemm_nn_packed(&[], &[1.0, 2.0], &mut empty, 0, 1, 2, 1.0, 1);
    gemm::syrk_upper_packed(&[], &mut [], 0, 0, 1);
    let mut g = vec![2.0f32; 4];
    gemm::syrk_upper_packed(&[], &mut g, 0, 2, 1);
    assert_eq!(g, vec![2.0f32; 4]);
}

#[test]
fn packed_syrk_matches_reference_and_writes_upper_only() {
    let mut rng = Pcg64::seed(2);
    for &(rows, h) in &[
        (1usize, 1usize),
        (5, 7),
        (17, NR),
        (64, NR + 3),
        (KC + 5, 2 * NR + 3),
        (33, MC + 9),
    ] {
        let x = common::randn(&mut rng, &[rows, h]);
        // Sentinel-filled G: the packed SYRK must leave the strict
        // lower triangle untouched, like the scalar kernel.
        let g0 = common::randn(&mut rng, &[h, h]);
        let mut g_ref = g0.clone();
        let mut g_pack = g0.clone();
        ops::syrk_upper_acc_ref(&x, &mut g_ref);
        gemm::syrk_upper_packed(x.data(), g_pack.data_mut(), rows, h, 1);
        for i in 0..h {
            for j in 0..h {
                let p = g_pack.at2(i, j);
                if j >= i {
                    let r = g_ref.at2(i, j);
                    assert!(
                        (p - r).abs() <= tol(rows),
                        "({rows},{h}) upper ({i},{j}): {p} vs {r}"
                    );
                } else {
                    assert_eq!(
                        p.to_bits(),
                        g0.at2(i, j).to_bits(),
                        "({rows},{h}) lower ({i},{j}) must be untouched"
                    );
                }
            }
        }
    }
}

#[test]
fn packed_parallel_fanout_is_bit_identical_at_any_worker_count() {
    let mut rng = Pcg64::seed(3);
    let (m, k, n) = (3 * MC + 7, KC + 3, 2 * NR + 5);
    let a = common::randn(&mut rng, &[m, k]);
    let b = common::randn(&mut rng, &[k, n]);
    let mut base = Tensor::zeros(&[m, n]);
    gemm::gemm_nn_packed(a.data(), b.data(), base.data_mut(), m, k, n, 1.0, 1);
    for workers in [2usize, 3, 7, 16] {
        let mut c = Tensor::zeros(&[m, n]);
        gemm::gemm_nn_packed(a.data(), b.data(), c.data_mut(), m, k, n, 1.0, workers);
        for (x, y) in c.data().iter().zip(base.data()) {
            assert_eq!(x.to_bits(), y.to_bits(), "gemm workers={workers}");
        }
    }

    let h = 2 * MC + 5;
    let x = common::randn(&mut rng, &[64, h]);
    let mut gbase = Tensor::zeros(&[h, h]);
    gemm::syrk_upper_packed(x.data(), gbase.data_mut(), 64, h, 1);
    for workers in [2usize, 5, 11] {
        let mut g = Tensor::zeros(&[h, h]);
        gemm::syrk_upper_packed(x.data(), g.data_mut(), 64, h, workers);
        for (p, q) in g.data().iter().zip(gbase.data()) {
            assert_eq!(p.to_bits(), q.to_bits(), "syrk workers={workers}");
        }
    }
}

#[test]
fn nan_and_inf_propagate_through_zero_entries() {
    // A zero coefficient against a non-finite B entry must produce NaN
    // (IEEE 0·NaN = 0·∞ = NaN): the packed path computes every product,
    // so there is no sparse skip to get this wrong.
    let m = MR + 1; // straddle one row-strip boundary
    let k = 3usize;
    let n = NR + 2; // straddle one column-panel boundary
    let mut a = Tensor::zeros(&[m, k]);
    for i in 0..m {
        a.set2(i, 1, 1.0); // row i = [0, 1, 0]
    }
    let mut b = Tensor::full(&[k, n], 2.0);
    b.set2(0, 0, f32::NAN); // hit by a 0 coefficient
    b.set2(2, n - 1, f32::INFINITY); // hit by a 0 coefficient
    let mut c = Tensor::zeros(&[m, n]);
    gemm::gemm_nn_packed(a.data(), b.data(), c.data_mut(), m, k, n, 1.0, 1);
    for i in 0..m {
        assert!(c.at2(i, 0).is_nan(), "0·NaN must be NaN at ({i},0)");
        assert!(c.at2(i, n - 1).is_nan(), "0·∞ must be NaN at ({i},{})", n - 1);
        assert_eq!(c.at2(i, 1), 2.0, "finite columns unaffected");
    }

    // Same for the SYRK cross terms: x = [0, NaN, 1, …].
    let h = NR + 1;
    let mut x = Tensor::zeros(&[1, h]);
    x.data_mut()[1] = f32::NAN;
    x.data_mut()[2] = 1.0;
    let mut g = Tensor::zeros(&[h, h]);
    gemm::syrk_upper_packed(x.data(), g.data_mut(), 1, h, 1);
    assert!(g.at2(0, 1).is_nan(), "0·NaN cross term must be NaN");
    assert!(g.at2(1, 2).is_nan(), "NaN·1 cross term must be NaN");
    assert!(g.at2(1, 1).is_nan());
    assert_eq!(g.at2(0, 0), 0.0);
    assert_eq!(g.at2(2, 2), 1.0);
}

#[test]
fn zero_heavy_inputs_agree_exactly_with_reference() {
    // Regression for the removed finiteness rescan: the old kernels
    // took a data-dependent fast path on zero entries (and re-scanned
    // the whole operand for finiteness to keep it sound). The packed
    // engine has no data-dependent branch at all, so on integer-valued
    // inputs — where every product and partial sum is exact in f32 —
    // zero-heavy operands must agree with the scalar oracle to the bit.
    let mut rng = Pcg64::seed(4);
    let int_tensor = |rng: &mut Pcg64, shape: &[usize]| {
        let mut t = Tensor::zeros(shape);
        for v in t.data_mut().iter_mut() {
            // ~50% exact zeros (post-ReLU shape), rest small ints.
            let r = rng.normal();
            *v = if r < 0.0 { 0.0 } else { (r * 3.0).round().min(3.0) };
        }
        t
    };
    let (m, k, n) = (MC + 3, 64usize, NR + 7);
    let a = int_tensor(&mut rng, &[m, k]);
    let b = int_tensor(&mut rng, &[k, n]);
    let mut c_ref = Tensor::zeros(&[m, n]);
    let mut c_pack = Tensor::zeros(&[m, n]);
    ops::gemm_acc_ref(a.data(), b.data(), c_ref.data_mut(), m, k, n, 1.0);
    gemm::gemm_nn_packed(a.data(), b.data(), c_pack.data_mut(), m, k, n, 1.0, 2);
    for (p, r) in c_pack.data().iter().zip(c_ref.data()) {
        assert_eq!(p.to_bits(), r.to_bits(), "zero-heavy gemm must be exact");
    }

    let x = int_tensor(&mut rng, &[96, MC + 5]);
    let mut g_ref = Tensor::zeros(&[MC + 5, MC + 5]);
    let mut g_pack = Tensor::zeros(&[MC + 5, MC + 5]);
    ops::syrk_upper_acc_ref(&x, &mut g_ref);
    gemm::syrk_upper_packed(x.data(), g_pack.data_mut(), 96, MC + 5, 2);
    for (p, r) in g_pack.data().iter().zip(g_ref.data()) {
        assert_eq!(p.to_bits(), r.to_bits(), "zero-heavy syrk must be exact");
    }
}

#[test]
fn dispatch_entries_and_direct_calls_agree() {
    // Above the flop threshold the `ops` entries route to the packed
    // engine with auto workers; explicit-worker calls must produce the
    // same bits (worker resolution is scheduling only).
    let mut rng = Pcg64::seed(5);
    let (m, k, n) = (2 * MC, 96usize, 48usize);
    let a = common::randn(&mut rng, &[m, k]);
    let b = common::randn(&mut rng, &[k, n]);
    let mut c1 = vec![0.0f32; m * n];
    let mut c2 = vec![0.0f32; m * n];
    ops::gemm_acc(a.data(), b.data(), &mut c1, m, k, n, 1.0);
    gemm::gemm_nn_packed(a.data(), b.data(), &mut c2, m, k, n, 1.0, 3);
    for (x, y) in c1.iter().zip(&c2) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
}

#[test]
fn fused_epilogues_match_unfused_sweeps_bitwise() {
    // The fused epilogue applies act(v + bias) to the finished
    // accumulator tile; the oracle runs the same packed GEMM with no
    // epilogue, then separate bias/activation sweeps. Same scalar ops
    // in the same order => bitwise equality, across tile boundaries
    // and for every epilogue kind.
    let mut rng = Pcg64::seed(6);
    for &(m, k, n) in &[
        (1usize, 64usize, 48usize),
        (MR + 1, KC + 9, NR + 1),
        (MC + 3, 96, 2 * NR + 5),
    ] {
        let a = common::randn(&mut rng, &[m, k]);
        let bt = common::randn(&mut rng, &[n, k]);
        let mut bias = vec![0.0f32; n];
        rng.fill_normal(&mut bias, 1.0);
        for kind in 0..3usize {
            let ep = match kind {
                0 => gemm::Epilogue::Bias(&bias),
                1 => gemm::Epilogue::BiasRelu(&bias),
                _ => gemm::Epilogue::BiasGelu(&bias),
            };
            let mut fused = vec![0.0f32; m * n];
            gemm::gemm_nt_packed_ep(a.data(), bt.data(), &mut fused, m, k, n, ep, 2);
            let mut want = vec![0.0f32; m * n];
            gemm::gemm_nt_packed(a.data(), bt.data(), &mut want, m, k, n, 2);
            for row in want.chunks_mut(n) {
                for (v, &bj) in row.iter_mut().zip(&bias) {
                    match kind {
                        0 => *v += bj,
                        1 => *v = (*v + bj).max(0.0),
                        _ => *v = grail::nn::gelu_scalar(*v + bj),
                    }
                }
            }
            for (f, w) in fused.iter().zip(&want) {
                assert_eq!(f.to_bits(), w.to_bits(), "epilogue {kind} {m}x{k}x{n}");
            }
        }
    }
}

#[test]
fn prepacked_matches_per_call_packing_bitwise() {
    // PackedB::pack_nt shares the packing routine with the per-call
    // path and gemm_nt_prepacked shares the compute body, so the two
    // entries must agree exactly — at any worker count.
    let mut rng = Pcg64::seed(7);
    for &(m, k, n) in &[(1usize, KC + 9, 2 * NR + 5), (MC + 7, 96, 48)] {
        let a = common::randn(&mut rng, &[m, k]);
        let bt = common::randn(&mut rng, &[n, k]);
        let mut bias = vec![0.0f32; n];
        rng.fill_normal(&mut bias, 1.0);
        let pb = gemm::PackedB::pack_nt(bt.data(), k, n);
        assert_eq!(pb.k(), k);
        assert_eq!(pb.n(), n);
        for workers in [1usize, 2, 5] {
            let mut pre = vec![0.0f32; m * n];
            gemm::gemm_nt_prepacked(a.data(), &pb, &mut pre, m, gemm::Epilogue::Bias(&bias), workers);
            let mut percall = vec![0.0f32; m * n];
            gemm::gemm_nt_packed_ep(
                a.data(),
                bt.data(),
                &mut percall,
                m,
                k,
                n,
                gemm::Epilogue::Bias(&bias),
                workers,
            );
            for (p, q) in pre.iter().zip(&percall) {
                assert_eq!(p.to_bits(), q.to_bits(), "{m}x{k}x{n} workers={workers}");
            }
        }
    }
}

#[test]
fn serve_entries_are_row_count_invariant() {
    // The serving dispatch (use_packed_cols) ignores m, and both the
    // packed engine and the scalar refs compute each output row from
    // row-local state — so an m-row serve call must equal m separate
    // 1-row calls, bitwise. This is the property that lets KV-cache
    // decode (m=1) reproduce the full forward (m=t) exactly.
    let mut rng = Pcg64::seed(8);
    // One shape on the packed side of the col threshold, one scalar.
    for &(m, k, n) in &[(MC + 7, 64usize, 64usize), (9, 8, 40)] {
        let a = common::randn(&mut rng, &[m, k]);
        let bt = common::randn(&mut rng, &[n, k]);
        let mut bias = vec![0.0f32; n];
        rng.fill_normal(&mut bias, 1.0);
        let mut full = vec![0.0f32; m * n];
        ops::gemm_nt_serve(a.data(), bt.data(), &mut full, m, k, n, gemm::Epilogue::BiasRelu(&bias));
        for r in 0..m {
            let mut one = vec![0.0f32; n];
            ops::gemm_nt_serve(
                &a.data()[r * k..(r + 1) * k],
                bt.data(),
                &mut one,
                1,
                k,
                n,
                gemm::Epilogue::BiasRelu(&bias),
            );
            for (x, y) in one.iter().zip(&full[r * n..(r + 1) * n]) {
                assert_eq!(x.to_bits(), y.to_bits(), "nt row {r} of {m}x{k}x{n}");
            }
        }

        let b = common::randn(&mut rng, &[k, n]);
        let mut full = vec![0.0f32; m * n];
        ops::gemm_nn_serve(a.data(), b.data(), &mut full, m, k, n);
        for r in 0..m {
            let mut one = vec![0.0f32; n];
            ops::gemm_nn_serve(&a.data()[r * k..(r + 1) * k], b.data(), &mut one, 1, k, n);
            for (x, y) in one.iter().zip(&full[r * n..(r + 1) * n]) {
                assert_eq!(x.to_bits(), y.to_bits(), "nn row {r} of {m}x{k}x{n}");
            }
        }
    }
}
