//! Equivalence tests for the blocked SPD solve engine: the production
//! [`grail::linalg::BlockedCholesky`] path must agree with the scalar
//! reference (`solve_spd_multi_ref`) on every size/shape regime — below,
//! at, and above the panel widths, single-column systems, the
//! jitter-rescue path — and its parallel RHS fan-out must be
//! bit-invariant to the worker count.

mod common;

use common::{randn, spd};
use grail::linalg::{solve_spd_multi, solve_spd_multi_ref, BlockedCholesky};
use grail::linalg::{FACTOR_BLOCK, RHS_PANEL};
use grail::rng::Pcg64;
use grail::tensor::ops::{gram, matmul};
use grail::testing::{check, Config};

/// Property: blocked and scalar solves agree within f32 round-off for
/// random sizes straddling the factor-panel and RHS-panel boundaries,
/// including the K=1 single-RHS edge.
#[test]
fn prop_blocked_matches_scalar_reference() {
    check(Config { cases: 24, seed: 0xB10C }, |rng, size| {
        // Bias sizes toward the block boundaries where the panel
        // arithmetic has edge cases.
        let n = match rng.below(4) {
            0 => 1 + rng.below(size.scale(8, 2)),
            1 => FACTOR_BLOCK - 1 + rng.below(3),
            2 => 2 * FACTOR_BLOCK - 1 + rng.below(3),
            _ => 1 + rng.below(size.scale(120, 8)),
        };
        let m = match rng.below(3) {
            0 => 1, // K=1 edge
            1 => RHS_PANEL - 1 + rng.below(3),
            _ => 1 + rng.below(size.scale(80, 4)),
        };
        let mut r = Pcg64::seed(rng.next_u64());
        let a = spd(&mut r, n);
        let b = randn(&mut r, &[n, m]);
        let fast = solve_spd_multi(&a, &b);
        let slow = solve_spd_multi_ref(&a, &b);
        let scale = 1.0 + slow.frobenius() / ((n * m) as f32).sqrt();
        let diff = fast.max_abs_diff(&slow);
        if diff > 1e-3 * scale {
            return Err(format!("n={n} m={m}: blocked vs ref diff {diff} (scale {scale})"));
        }
        // And the blocked solution actually solves the system.
        let ax = matmul(&a, &fast);
        let res = ax.max_abs_diff(&b);
        if res > 1e-2 * (1.0 + b.frobenius() / ((n * m) as f32).sqrt()) {
            return Err(format!("n={n} m={m}: residual {res}"));
        }
        Ok(())
    });
}

/// The jitter-rescue path (rank-deficient Gram, N < H) succeeds in both
/// engines and produces usable (finite, small-residual-after-ridge)
/// solutions.
#[test]
fn prop_jitter_rescue_path() {
    check(Config { cases: 12, seed: 0x1177 }, |rng, size| {
        let h = 6 + rng.below(size.scale(40, 4));
        let rows = 1 + rng.below(h.saturating_sub(1).max(1)); // rows < h
        let mut r = Pcg64::seed(rng.next_u64());
        let x = randn(&mut r, &[rows, h]);
        let g = gram(&x); // rank-deficient in exact arithmetic
        if BlockedCholesky::factor(&g).is_ok() {
            // Round-off occasionally leaves all pivots barely positive;
            // there is nothing to rescue in that case.
            return Ok(());
        }
        let chol = match BlockedCholesky::factor_jittered(&g) {
            Ok(c) => c,
            Err(e) => return Err(format!("h={h} rows={rows}: jitter failed: {e}")),
        };
        let b = randn(&mut r, &[h, 3]);
        let fast = chol.solve_multi(&b);
        let slow = solve_spd_multi_ref(&g, &b);
        if !fast.all_finite() || !slow.all_finite() {
            return Err(format!("h={h} rows={rows}: non-finite rescue solve"));
        }
        Ok(())
    });
}

/// Parallel RHS panels must be bit-identical at every worker count —
/// panels are computed independently and written to disjoint columns,
/// so thread scheduling can never reorder a float sum.
#[test]
fn worker_count_invariance() {
    let mut r = Pcg64::seed(77);
    for &(n, m) in &[(33usize, 70usize), (96, 200), (130, 513)] {
        let a = spd(&mut r, n);
        let b = randn(&mut r, &[n, m]);
        let chol = BlockedCholesky::factor(&a).unwrap();
        let serial = chol.solve_multi_with(&b, 1);
        for workers in [2usize, 3, 5, 16] {
            let par = chol.solve_multi_with(&b, workers);
            assert_eq!(serial, par, "n={n} m={m} workers={workers}");
        }
        // The auto-threaded entry point takes one of those paths.
        assert_eq!(serial, chol.solve_multi(&b), "n={n} m={m} auto");
    }
}

/// The transposed solve used by the ridge reconstruction is exactly the
/// transpose of the plain solve, for panel-straddling shapes.
#[test]
fn transposed_solve_matches() {
    let mut r = Pcg64::seed(78);
    for &(n, m) in &[(20usize, 1usize), (50, RHS_PANEL), (90, 100)] {
        let a = spd(&mut r, n);
        let b = randn(&mut r, &[n, m]);
        let chol = BlockedCholesky::factor(&a).unwrap();
        let x = chol.solve_multi(&b);
        let xt = chol.solve_multi_t(&b);
        assert_eq!(xt.shape(), &[m, n]);
        for i in 0..n {
            for j in 0..m {
                assert_eq!(x.at2(i, j).to_bits(), xt.at2(j, i).to_bits(), "({i},{j})");
            }
        }
    }
}
