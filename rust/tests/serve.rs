//! Serve subsystem acceptance tests: the content-addressed ActStats
//! cache must be *bit-identical* to the cold path (the cached bytes
//! are the verbatim un-finalized accumulators, so identity holds by
//! construction — these tests prove the plumbing preserves it), the
//! entry round trip must be byte-exact at every shard count and
//! reject corruption, and the `grail serve` daemon must produce the
//! same plan a direct `grail plan` resolves, survive a failing job
//! with bounded observable retries, and account cache hits on
//! resubmission.

mod common;

use std::path::PathBuf;
use std::sync::Arc;

use grail::compress::{Compressible, Selector};
use grail::coordinator::{write_dev_checkpoints, Artifacts};
use grail::data::SynthVision;
use grail::exp::runner::{resolve_job_plan, Family, SpecJob};
use grail::exp::ExpOptions;
use grail::grail::{
    compress_model, search_plan, ActStats, BudgetMode, CompressionPlan, CompressionSpec, Method,
};
use grail::rng::Pcg64;
use grail::serve::daemon::{self, ServeConfig, ServeRoot};
use grail::serve::digest::digest_bytes;
use grail::serve::job::{JobRecord, JobState, JobVerb};
use grail::serve::provider::{self, StatsContext};
use grail::serve::StatsCache;
use grail::tensor::Tensor;

fn tmp_dir(name: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!("grail_serve_{name}_{}", std::process::id()));
    std::fs::remove_dir_all(&p).ok();
    std::fs::create_dir_all(&p).unwrap();
    p
}

fn search_spec(ratio: f64) -> CompressionSpec {
    let mut spec = CompressionSpec::uniform(Method::Prune(Selector::Wanda), ratio, true);
    spec.budget =
        BudgetMode::Search { target_ratio: ratio, alpha_grid: vec![1e-4, 5e-3], rounds: 1 };
    spec.shards = 4;
    spec.workers = 1;
    spec
}

fn ctx(cache: &Arc<StatsCache>, model: &[u8], corpus: &[u8]) -> StatsContext {
    StatsContext::new(cache.clone(), digest_bytes(model), digest_bytes(corpus))
}

/// The tune winner from cached statistics is byte-equal to the
/// fresh-pass winner — cold (no provider), miss (provider, empty
/// cache), and warm (provider, populated cache) all serialize to
/// identical plan TOML — and the warm path preserves the worker-count
/// bit-invariance the search already guarantees cold.
#[test]
fn warm_tune_winner_is_bit_identical_to_cold() {
    let m = common::mlp(51);
    let x = common::vision_calib(52, 96);
    let spec = search_spec(0.5);

    // Cold: no provider installed anywhere on this thread.
    let cold = search_plan(&m, &x, &spec).unwrap();

    let root = tmp_dir("warm_tune");
    let cache = Arc::new(StatsCache::open(root.join("cache")).unwrap());

    // First provider pass misses, computes, and stores.
    let miss = {
        let _scope = provider::install(ctx(&cache, b"mlp-51", b"vision-52"));
        search_plan(&m, &x, &spec).unwrap()
    };
    assert!(cache.misses() > 0 && cache.hits() == 0, "first pass must miss");

    // Second provider pass serves every site from disk.
    let warm = {
        let _scope = provider::install(ctx(&cache, b"mlp-51", b"vision-52"));
        search_plan(&m, &x, &spec).unwrap()
    };
    assert!(cache.hits() > 0, "second pass must hit");

    assert_eq!(
        miss.plan.to_toml().into_bytes(),
        cold.plan.to_toml().into_bytes(),
        "store-through pass diverged from cold"
    );
    assert_eq!(
        warm.plan.to_toml().into_bytes(),
        cold.plan.to_toml().into_bytes(),
        "cache-served pass diverged from cold"
    );
    assert_eq!(warm.final_err.to_bits(), cold.final_err.to_bits());
    assert_eq!(warm.initial_err.to_bits(), cold.initial_err.to_bits());

    // Worker-count bit-invariance holds on the warm path too.
    let warm_workers = |workers: usize| -> CompressionPlan {
        let mut spec = search_spec(0.5);
        spec.workers = workers;
        let _scope = provider::install(ctx(&cache, b"mlp-51", b"vision-52"));
        let mut plan = search_plan(&m, &x, &spec).unwrap().plan;
        plan.workers = 0;
        plan
    };
    let serial = warm_workers(1);
    for workers in [2usize, 4] {
        assert_eq!(warm_workers(workers), serial, "warm workers={workers}");
    }
    std::fs::remove_dir_all(&root).ok();
}

/// Open-loop execution (the other consumer of the statistics choke
/// point) is bit-identical warm vs cold, and the pipeline `Report`
/// carries the per-run hit/miss counters.
#[test]
fn warm_open_loop_is_bit_identical_and_counted() {
    let m = common::mlp(61);
    let x = common::vision_calib(62, 64);
    let mut spec = CompressionSpec::uniform(Method::Prune(Selector::Wanda), 0.5, true);
    spec.closed_loop = false;
    spec.shards = 4;
    spec.workers = 1;
    let n_sites = m.sites().len() as u64;

    let mut cold_m = m.clone();
    let cold_rep = compress_model(&mut cold_m, &x, &spec);
    assert_eq!((cold_rep.cache_hits, cold_rep.cache_misses), (0, 0), "no provider, no traffic");

    let root = tmp_dir("warm_open");
    let cache = Arc::new(StatsCache::open(root.join("cache")).unwrap());

    let mut miss_m = m.clone();
    let miss_rep = {
        let _scope = provider::install(ctx(&cache, b"mlp-61", b"vision-62"));
        compress_model(&mut miss_m, &x, &spec)
    };
    assert_eq!((miss_rep.cache_hits, miss_rep.cache_misses), (0, n_sites));

    let mut warm_m = m.clone();
    let warm_rep = {
        let _scope = provider::install(ctx(&cache, b"mlp-61", b"vision-62"));
        compress_model(&mut warm_m, &x, &spec)
    };
    assert_eq!((warm_rep.cache_hits, warm_rep.cache_misses), (n_sites, 0));

    common::assert_reports_identical(&cold_rep, &miss_rep);
    common::assert_reports_identical(&cold_rep, &warm_rep);
    // The compressed models themselves are bit-identical.
    let (a, b) = (cold_m.forward(&x), warm_m.forward(&x));
    assert_eq!(a.shape(), b.shape());
    for (p, q) in a.data().iter().zip(b.data()) {
        assert_eq!(p.to_bits(), q.to_bits(), "warm compressed model diverged");
    }
    std::fs::remove_dir_all(&root).ok();
}

/// Fuzz the entry round trip: random widths/rows at every shard count
/// the pipeline uses come back byte-identical; a flipped byte is
/// evicted as a miss; truncated prefixes are rejected.
#[test]
fn actstats_entries_roundtrip_byte_exact_across_shard_counts() {
    let root = tmp_dir("fuzz");
    let cache = StatsCache::open(root.join("cache")).unwrap();
    let mut rng = Pcg64::seed(0xF022);
    for (case, &n_shards) in [1usize, 2, 3, 16].iter().enumerate() {
        for rep in 0..4 {
            let h = 2 + rng.below(9);
            let shards: Vec<ActStats> = (0..n_shards)
                .map(|_| {
                    let rows = 1 + rng.below(12);
                    let mut acts = Tensor::zeros(&[rows, h]);
                    rng.fill_normal(acts.data_mut(), 1.0);
                    let mut s = ActStats::new(h);
                    s.update(&acts);
                    s
                })
                .collect();
            let key = digest_bytes(format!("fuzz-{case}-{rep}").as_bytes());
            cache.store(&key, &shards).unwrap();
            let back = cache.load(&key).expect("entry just stored");
            assert_eq!(back.len(), n_shards);
            for (a, b) in shards.iter().zip(&back) {
                assert_eq!(a.rows, b.rows);
                assert_eq!(a.width(), b.width());
                for (x, y) in a.mean.iter().zip(&b.mean) {
                    assert_eq!(x.to_bits(), y.to_bits(), "mean bytes");
                }
                for (x, y) in a.gram.data().iter().zip(b.gram.data()) {
                    assert_eq!(x.to_bits(), y.to_bits(), "gram bytes");
                }
            }

            // Flip one random byte: the checksum fails and the entry
            // is evicted from disk.
            let path = cache.entry_path(&key);
            let mut bytes = std::fs::read(&path).unwrap();
            let evictions_before = cache.evictions();
            let at = rng.below(bytes.len());
            bytes[at] ^= 0x20;
            std::fs::write(&path, &bytes).unwrap();
            assert!(cache.load(&key).is_none(), "corrupt entry served (byte {at})");
            assert_eq!(cache.evictions(), evictions_before + 1);
            assert!(!path.exists(), "corrupt entry not deleted");

            // Truncations of the intact bytes are rejected too.
            bytes[at] ^= 0x20;
            let cut = rng.below(bytes.len());
            std::fs::write(&path, &bytes[..cut]).unwrap();
            assert!(cache.load(&key).is_none(), "truncated at {cut} was served");
        }
    }
    std::fs::remove_dir_all(&root).ok();
}

/// Stand up a throwaway artifacts tree the daemon can serve from: dev
/// checkpoints (untrained, seeded) plus the vision calibration file
/// the mlp family reads.
fn fake_artifacts(tmp: &std::path::Path) -> Artifacts {
    let art = Artifacts::at(tmp.join("artifacts").to_str().unwrap());
    let mut msgs: Vec<String> = Vec::new();
    write_dev_checkpoints(&art, &mut |m| msgs.push(m.to_string())).unwrap();
    assert!(msgs.iter().any(|m| m.contains("tinylm_mha")), "ensure_ready marker written");
    std::fs::create_dir_all(art.data_dir()).unwrap();
    let calib = SynthVision::new(42).generate_split(128, 2);
    grail::data::io::write_images(&art.data("vision_calib.imgs"), &calib).unwrap();
    art
}

/// End-to-end daemon contract: a submitted plan job produces exactly
/// the plan a direct resolve produces; resubmitting it re-queues and
/// serves the statistics from the cache; a job against a missing
/// checkpoint retries the configured number of times, lands `failed`
/// with the error captured, and never stalls the queue.
#[test]
fn daemon_plan_matches_direct_retries_bounded_and_caches() {
    let tmp = tmp_dir("daemon");
    let art = fake_artifacts(&tmp);
    let opts = ExpOptions {
        out_dir: tmp.join("out").to_string_lossy().into_owned(),
        artifacts: art,
        quick: true,
        seed: 0,
        cache: None,
    };

    // A statistics-hungry spec: the gram-sensitivity allocator runs a
    // full calibration pass, so the cache has real traffic to account
    // (a per-site budget would resolve with no statistics at all).
    let spec_path = tmp.join("job.spec.toml");
    std::fs::write(
        &spec_path,
        "[model]\nfamily = \"mlp\"\nckpt = \"mlp_dev\"\n\n\
         [pipeline]\nmethod = \"mag-l2\"\nratio = 0.5\nshards = 4\nworkers = 1\n\n\
         [budget]\nmode = \"gram-sensitivity\"\ntarget_ratio = 0.5\n",
    )
    .unwrap();
    let spec_str = spec_path.to_str().unwrap();

    // Direct, cold resolution — the reference output.
    let sj = SpecJob::load(spec_str).unwrap();
    assert_eq!(sj.family, Family::Mlp);
    let direct = resolve_job_plan(&opts, sj.family, "mlp_dev", &sj.spec).unwrap();

    let root = ServeRoot::at(tmp.join("serve"));
    let cfg = ServeConfig { jobs: 1, once: true, poll_ms: 10 };

    let (id, re) = daemon::submit_file(&root, spec_str, JobVerb::Plan, 1, "", "").unwrap();
    assert!(!re);
    daemon::serve(&opts, &root, &cfg).unwrap();

    let rec = JobRecord::load(&root.job_dir(&id)).unwrap();
    assert_eq!(rec.state, JobState::Done, "error: {}", rec.error);
    assert_eq!(rec.attempts, 1);
    assert_eq!(rec.result, format!("results/{id}/plan.toml"));
    let daemon_plan = std::fs::read(root.root.join(&rec.result)).unwrap();
    assert_eq!(
        daemon_plan,
        direct.to_toml().into_bytes(),
        "daemon plan diverged from direct `grail plan`"
    );
    assert!(rec.cache_misses > 0, "sensitivity pass must populate the cache");
    assert_eq!(rec.cache_hits, 0);

    // Resubmit the finished job: re-queued, served warm.
    let (id2, re2) = daemon::submit_file(&root, spec_str, JobVerb::Plan, 1, "", "").unwrap();
    assert_eq!(id2, id);
    assert!(re2, "terminal job must re-queue");
    daemon::serve(&opts, &root, &cfg).unwrap();
    let rec = JobRecord::load(&root.job_dir(&id)).unwrap();
    assert_eq!(rec.state, JobState::Done, "error: {}", rec.error);
    assert!(rec.cache_hits > 0, "warm re-run must hit the statistics cache");
    let warm_plan = std::fs::read(root.root.join(&rec.result)).unwrap();
    assert_eq!(warm_plan, direct.to_toml().into_bytes(), "warm daemon plan diverged");

    // A poisoned job (missing checkpoint) fails after `1 + retries`
    // observable attempts while a healthy job in the same drain cycle
    // completes.
    let (bad, _) =
        daemon::submit_file(&root, spec_str, JobVerb::Plan, 1, "", "no_such_ckpt").unwrap();
    assert_ne!(bad, id, "ckpt override participates in the job id");
    let (good, good_re) = daemon::submit_file(&root, spec_str, JobVerb::Plan, 1, "", "").unwrap();
    assert_eq!(good, id);
    assert!(good_re);
    daemon::serve(&opts, &root, &cfg).unwrap();

    let bad_rec = JobRecord::load(&root.job_dir(&bad)).unwrap();
    assert_eq!(bad_rec.state, JobState::Failed);
    assert_eq!(bad_rec.attempts, 2, "retries = 1 means two attempts");
    assert!(!bad_rec.error.is_empty(), "failure must capture the error");
    let bad_log = std::fs::read_to_string(root.job_dir(&bad).join("log.txt")).unwrap();
    assert_eq!(
        bad_log.matches("state=running").count(),
        2,
        "both attempts must be observable in the job log:\n{bad_log}"
    );
    assert!(bad_log.contains("state=failed"), "terminal state logged:\n{bad_log}");

    let good_rec = JobRecord::load(&root.job_dir(&good)).unwrap();
    assert_eq!(good_rec.state, JobState::Done, "queue must drain around the failure");

    std::fs::remove_dir_all(&tmp).ok();
}
