//! Property tests over the `Spec → Plan` surface: every `BudgetMode`
//! allocator must conserve its budget, respect per-site keep floors
//! and group divisibility, ramp monotonically where it promises to,
//! and resolve deterministically — over seeded random site lists and
//! specs. Plus the serializer fuzz: `CompressionPlan → TOML → parse`
//! must reconstruct an identical plan for arbitrary (nasty) site ids
//! and full-precision policies.

mod common;

use grail::compress::{SiteInfo, SiteKind};
use grail::grail::pipeline::uniform_keep;
use grail::grail::{
    BudgetMode, CompressionPlan, CompressionSpec, Method, PlannedSite, PolicyOverrides,
    PolicyRule, SiteMatcher, SitePolicy,
};
use grail::rng::Pcg64;
use grail::testing::{check, Config, Size};

const KINDS: [SiteKind; 4] =
    [SiteKind::Dense, SiteKind::Conv, SiteKind::MlpPair, SiteKind::AttnHeads];

/// Smallest admissible keep / smallest keep step of a site (mirrors
/// the resolver's group constraints: divisible grouped sites move in
/// whole groups, everything else unit by unit).
fn floor_and_step(units: usize, groups: usize) -> (usize, usize) {
    if groups > 1 && units % groups == 0 {
        (groups, groups)
    } else {
        (1, 1)
    }
}

fn random_sites(rng: &mut Pcg64, size: Size) -> Vec<SiteInfo> {
    let n = 1 + rng.below(size.scale(10, 2));
    (0..n)
        .map(|i| {
            let groups = 1 + rng.below(4);
            let units = if rng.below(2) == 0 {
                groups * (1 + rng.below(16)) // group-divisible
            } else {
                1 + rng.below(64) // arbitrary (often non-divisible)
            };
            SiteInfo {
                id: format!("s{i}"),
                units,
                unit_dim: 1 + rng.below(4),
                groups,
                kind: KINDS[rng.below(4)],
            }
        })
        .collect()
}

/// Per-site structural floor: keep within `[1, units]`, whole groups
/// on divisible grouped sites.
fn assert_site_keeps_valid(plan: &CompressionPlan) {
    for ps in &plan.sites {
        assert!(ps.keep >= 1 && ps.keep <= ps.units, "{}: keep {}", ps.id, ps.keep);
        let (floor, _) = floor_and_step(ps.units, ps.groups);
        assert!(ps.keep >= floor, "{}: keep {} under floor {floor}", ps.id, ps.keep);
        if ps.groups > 1 && ps.units % ps.groups == 0 {
            assert_eq!(ps.keep % ps.groups, 0, "{}: keep {} not whole groups", ps.id, ps.keep);
        }
    }
}

/// Budget conservation for the global allocators: the total keep over
/// the non-pinned sites lands on the clamped unit target, within one
/// group step of it.
fn assert_budget_conserved(plan: &CompressionPlan, free: &[usize], target_ratio: f64) {
    let total_units: usize = free.iter().map(|&i| plan.sites[i].units).sum();
    let min_total: usize = free
        .iter()
        .map(|&i| floor_and_step(plan.sites[i].units, plan.sites[i].groups).0)
        .sum();
    let target = (((total_units as f64) * (1.0 - target_ratio)).round() as usize)
        .clamp(min_total, total_units);
    let kept: usize = free.iter().map(|&i| plan.sites[i].keep).sum();
    let max_step = free
        .iter()
        .map(|&i| floor_and_step(plan.sites[i].units, plan.sites[i].groups).1)
        .max()
        .unwrap_or(1);
    assert!(
        kept <= target + max_step && kept + max_step >= target,
        "kept {kept} vs target {target} (max step {max_step})"
    );
}

#[test]
fn prop_per_site_matches_uniform_keep() {
    check(Config { cases: 48, seed: 0x9AAA }, |rng, size| {
        let sites = random_sites(rng, size);
        let ratio = 0.05 + 0.9 * rng.next_f64();
        let spec = CompressionSpec::uniform(Method::Fold, ratio, true);
        let plan = spec.resolve(&sites, None).map_err(|e| e.to_string())?;
        assert_site_keeps_valid(&plan);
        for (ps, s) in plan.sites.iter().zip(&sites) {
            if ps.keep != uniform_keep(s.units, s.groups, ratio) {
                return Err(format!("{}: keep {} != uniform", ps.id, ps.keep));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_global_allocators_conserve_budget() {
    check(Config { cases: 48, seed: 0x9BBB }, |rng, size| {
        let sites = random_sites(rng, size);
        let target = 0.05 + 0.9 * rng.next_f64();
        // Half the cases pin site 0 by rule: allocators must leave it
        // alone and conserve over the rest.
        let pin = rng.below(2) == 0;
        let pin_ratio = 0.1 + 0.5 * rng.next_f64();
        let budgets = [
            BudgetMode::GramSensitivity { target_ratio: target },
            BudgetMode::Search {
                target_ratio: target,
                alpha_grid: vec![1e-4, 5e-3],
                rounds: 1,
            },
        ];
        for budget in budgets {
            let mut spec = CompressionSpec::uniform(Method::Fold, 0.5, true);
            spec.budget = budget;
            if pin {
                spec.rules = vec![PolicyRule {
                    matcher: SiteMatcher { id_glob: Some("s0".into()), ..Default::default() },
                    set: PolicyOverrides { ratio: Some(pin_ratio), ..Default::default() },
                }];
            }
            let sens: Vec<f64> = sites.iter().map(|_| rng.next_f64() * 4.0).collect();
            let plan = spec.resolve(&sites, Some(&sens)).map_err(|e| e.to_string())?;
            assert_site_keeps_valid(&plan);
            let free: Vec<usize> = (0..sites.len()).skip(usize::from(pin)).collect();
            assert_budget_conserved(&plan, &free, target);
            if pin {
                let s0 = &plan.sites[0];
                if s0.keep != uniform_keep(s0.units, s0.groups, pin_ratio) {
                    return Err(format!("pinned site moved: keep {}", s0.keep));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_depth_ramp_monotone_in_depth_and_gamma() {
    check(Config { cases: 48, seed: 0x9CCC }, |rng, size| {
        let sites = random_sites(rng, size);
        let target = 0.1 + 0.6 * rng.next_f64();
        let g1 = 1.5 * rng.next_f64();
        let g2 = g1 + rng.next_f64();
        let resolve = |gamma: f64| {
            let mut spec = CompressionSpec::uniform(Method::Fold, 0.5, true);
            spec.budget = BudgetMode::DepthRamp { target_ratio: target, gamma };
            spec.resolve(&sites, None).unwrap()
        };
        let (a, b) = (resolve(g1), resolve(g2));
        assert_site_keeps_valid(&a);
        assert_site_keeps_valid(&b);
        let n = sites.len();
        for i in 0..n {
            // Within one plan: ratios non-decreasing in depth.
            if i + 1 < n && a.sites[i + 1].policy.ratio < a.sites[i].policy.ratio {
                return Err(format!("gamma {g1}: ratio dips at {i}"));
            }
            // Across gammas: larger gamma prunes the deep half at
            // least as hard and the shallow half at most as hard.
            let pos = if n <= 1 { 0.5 } else { i as f64 / (n - 1) as f64 };
            let (ra, rb) = (a.sites[i].policy.ratio, b.sites[i].policy.ratio);
            if 2.0 * pos - 1.0 >= 0.0 {
                if rb < ra {
                    return Err(format!("site {i}: deep ratio fell {ra} -> {rb}"));
                }
            } else if rb > ra {
                return Err(format!("site {i}: shallow ratio rose {ra} -> {rb}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_resolve_is_deterministic() {
    check(Config { cases: 32, seed: 0x9DDD }, |rng, size| {
        let sites = random_sites(rng, size);
        let target = 0.05 + 0.9 * rng.next_f64();
        let budgets = [
            BudgetMode::PerSite,
            BudgetMode::DepthRamp { target_ratio: target, gamma: 0.7 },
            BudgetMode::GramSensitivity { target_ratio: target },
            BudgetMode::Search { target_ratio: target, alpha_grid: vec![1e-4], rounds: 2 },
        ];
        let sens: Vec<f64> = sites.iter().map(|_| rng.next_f64()).collect();
        for budget in budgets {
            let mut spec = CompressionSpec::uniform(Method::Fold, target, true);
            spec.budget = budget;
            let a = spec.resolve(&sites, Some(&sens)).map_err(|e| e.to_string())?;
            let b = spec.resolve(&sites, Some(&sens)).map_err(|e| e.to_string())?;
            if a != b {
                return Err(format!("{}: resolve not deterministic", spec.budget.name()));
            }
            if a.to_toml() != b.to_toml() {
                return Err(format!("{}: serialization not deterministic", spec.budget.name()));
            }
        }
        Ok(())
    });
}

/// Serializer fuzz: arbitrary plans — nasty ids with globs, quotes,
/// escapes, whitespace; full-precision float policies — must round-trip
/// through `to_toml` + `parse` bit-for-bit.
#[test]
fn prop_plan_toml_roundtrip() {
    const POOL: &[char] = &[
        'a', 'b', 'z', 'A', '0', '7', '.', '-', '_', '>', '*', '?', '"', '\\', '#', ' ', '\n',
        '\t',
    ];
    check(Config { cases: 64, seed: 0x9EEE }, |rng, size| {
        let n = 1 + rng.below(size.scale(6, 2));
        let methods = Method::all();
        let sites: Vec<PlannedSite> = (0..n)
            .map(|i| {
                let units = 1 + rng.below(64);
                let keep = 1 + rng.below(units);
                let id: String =
                    (0..rng.below(14)).map(|_| POOL[rng.below(POOL.len())]).collect();
                PlannedSite {
                    id,
                    index: i,
                    units,
                    unit_dim: 1 + rng.below(8),
                    groups: 1 + rng.below(8),
                    kind: KINDS[rng.below(4)],
                    keep,
                    policy: SitePolicy {
                        method: methods[rng.below(methods.len())],
                        ratio: rng.next_f64(),
                        grail: rng.below(2) == 0,
                        alpha: (rng.next_f32() + 1e-6)
                            * 10f32.powi(-(rng.below(7) as i32)),
                    },
                    rules_applied: (0..rng.below(4)).map(|_| rng.below(40)).collect(),
                }
            })
            .collect();
        let plan = CompressionPlan {
            sites,
            seed: rng.next_u64() >> 16,
            closed_loop: rng.below(2) == 0,
            shards: rng.below(32),
            workers: rng.below(16),
        };
        let text = plan.to_toml();
        let back = CompressionPlan::parse(&text)
            .map_err(|e| format!("parse failed: {e:#}\n--- toml ---\n{text}"))?;
        if back != plan {
            return Err(format!("round trip changed the plan\n--- toml ---\n{text}"));
        }
        Ok(())
    });
}
