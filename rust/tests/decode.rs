//! KV-cache decode conformance (`TinyLm::prefill` / `decode_step` /
//! `generate`).
//!
//! The serving contract is *bitwise*: prefill must reproduce the full
//! forward exactly, and every incremental decode step must reproduce
//! the last row of the full forward over the sequence so far — for
//! dense, GQA, pruned, and folded models, at any worker count. The
//! chain that makes this hold (row-count-invariant GEMM dispatch,
//! prepacked weights sharing the per-call compute body, shared
//! `attend_cached`/fused-softmax kernels) is documented on
//! `TinyLm::decode_append`; these tests are the enforcement. The lazy
//! prefill `lm_head` (last-row-only logits) is checked against the
//! `prefill_full`/`paged_prefill_full` full-logits oracles, and
//! chunked prefill (`TinyLm::batch_step` spans, scheduler
//! `with_prefill_chunk`) is checked bit-identical to one-shot prefill
//! at every chunk-boundary shape.

mod common;

use grail::compress::{Compressible, ReductionPlan, Reducer};
use grail::coordinator::scheduler::run_grid;
use grail::nn::models::{BatchScratch, LmBatch, LmConfig, PagedKv, RowSpan, TinyLm};
use grail::serve::{BatchScheduler, KvPagePool};
use grail::tensor::Tensor;

/// Single-sequence batch (targets unused by `forward`).
fn batch_of(tokens: &[u16]) -> LmBatch {
    LmBatch { inputs: tokens.to_vec(), targets: vec![0; tokens.len()], b: 1, t: tokens.len() }
}

/// Deterministic in-vocab prompt.
fn prompt(len: usize) -> Vec<u16> {
    (0..len).map(|i| ((i * 5 + 2) % 64) as u16).collect()
}

fn assert_rows_bits_eq(a: &Tensor, ar: usize, b: &Tensor, br: usize, what: &str) {
    assert_eq!(a.dim(1), b.dim(1), "{what}: width");
    for (x, y) in a.row(ar).iter().zip(b.row(br)) {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: bits diverged");
    }
}

/// The four serving configurations the decode path must cover: plain
/// MHA, true GQA, head/MLP-pruned, and head/MLP-folded models (the
/// reductions change head counts, cache widths, and every GEMM shape).
fn variants() -> Vec<(&'static str, TinyLm)> {
    let dense = common::lm(LmConfig::default(), 31);
    let gqa = common::lm(LmConfig::gqa(), 32);
    let mut pruned = dense.clone();
    pruned.apply(0, &ReductionPlan::bare(Reducer::Select(vec![0, 2, 5, 7])));
    pruned.apply(3, &ReductionPlan::bare(Reducer::Select((0..96).collect())));
    let mut folded = dense.clone();
    folded.apply(
        2,
        &ReductionPlan::bare(Reducer::Fold { assign: vec![0, 0, 1, 1, 2, 2, 3, 3], k: 4 }),
    );
    folded.apply(
        5,
        &ReductionPlan::bare(Reducer::Fold { assign: (0..192).map(|i| i / 2).collect(), k: 96 }),
    );
    vec![("dense", dense), ("gqa", gqa), ("pruned", pruned), ("folded", folded)]
}

#[test]
fn prefill_matches_full_forward_bitwise() {
    for (name, m) in variants() {
        let toks = prompt(12);
        let full = m.forward(&batch_of(&toks));
        let mut state = m.decode_state();
        let pre = m.prefill_full(&mut state, &toks);
        assert_eq!(state.len(), toks.len(), "{name}: cached length");
        assert_eq!(pre.shape(), full.shape(), "{name}: logits shape");
        for r in 0..toks.len() {
            assert_rows_bits_eq(&pre, r, &full, r, &format!("{name}: prefill row {r}"));
        }
        // The serving entry projects only the last row — bitwise the
        // same row, one vocab-GEMM row instead of prompt_len.
        let mut lazy_state = m.decode_state();
        let lazy = m.prefill(&mut lazy_state, &toks);
        assert_eq!(lazy.shape(), &[1, m.cfg.vocab], "{name}: lazy prefill shape");
        assert_rows_bits_eq(&lazy, 0, &full, toks.len() - 1, &format!("{name}: lazy last row"));
        assert_eq!(lazy_state.len(), toks.len(), "{name}: lazy cached length");
    }
}

#[test]
fn incremental_decode_matches_prefix_forwards_bitwise() {
    for (name, m) in variants() {
        let toks = prompt(10);
        let mut state = m.decode_state();
        let mut logits = m.prefill(&mut state, &toks[..1]);
        for p in 0..toks.len() {
            if p > 0 {
                logits = m.decode_step(&mut state, toks[p]);
            }
            let full = m.forward(&batch_of(&toks[..p + 1]));
            assert_rows_bits_eq(
                &logits,
                logits.dim(0) - 1,
                &full,
                p,
                &format!("{name}: decode step at position {p}"),
            );
            assert_eq!(state.len(), p + 1, "{name}: cache length after step {p}");
        }
    }
}

#[test]
fn generate_matches_rescan_at_any_worker_count() {
    for (name, m) in variants() {
        let p = prompt(6);
        let want = m.generate_rescan(&p, 10);
        assert_eq!(m.generate(&p, 10), want, "{name}: decode vs rescan generation");
        // Nested fan-outs hand workers different thread-budget shares;
        // the generated tokens must not notice.
        for workers in [2usize, 4, 8] {
            let outs = run_grid(vec![(); workers], workers, |_, _| m.generate(&p, 10));
            for out in outs {
                assert_eq!(out, want, "{name}: generation drifted at {workers} workers");
            }
        }
    }
}

#[test]
fn decode_state_reports_capacity() {
    let m = common::lm(LmConfig::default(), 33);
    let state = m.decode_state();
    assert!(state.is_empty());
    assert_eq!(state.len(), 0);
    assert_eq!(state.capacity(), m.cfg.max_seq);
}

#[test]
#[should_panic(expected = "decode past cache capacity")]
fn decode_past_capacity_panics() {
    let m = common::lm(LmConfig::default(), 34);
    let mut state = m.decode_state();
    let toks = prompt(m.cfg.max_seq);
    m.prefill(&mut state, &toks);
    m.decode_step(&mut state, 0);
}

#[test]
#[should_panic(expected = "prefill on a used DecodeState")]
fn prefill_twice_panics() {
    let m = common::lm(LmConfig::default(), 35);
    let mut state = m.decode_state();
    m.prefill(&mut state, &prompt(4));
    m.prefill(&mut state, &prompt(4));
}

// ---------------------------------------------------------------------
// Paged KV + continuous batching (`serve::batch`). The contract is the
// same bitwise one as above, extended across requests: paged storage
// must reproduce the slab path exactly, and an m-row coalesced batch
// step must reproduce m solo steps exactly — at any batch composition,
// admission order, and worker count.
// ---------------------------------------------------------------------

#[test]
fn paged_decode_matches_slab_decode_bitwise() {
    // A page size that divides nothing (5) exercises partial tail
    // pages on every variant's stream layout.
    for (name, m) in variants() {
        let pack = m.serve_pack();
        let mut pool = KvPagePool::new(5, pack.d_head(), 4096);
        let mut kv = PagedKv::new(&pack, m.cfg.max_seq);
        let mut slab = m.decode_state();
        let toks = prompt(7);
        let paged = m.paged_prefill_full(&pack, &mut pool, &mut kv, &toks);
        let flat = m.prefill_full(&mut slab, &toks);
        for r in 0..toks.len() {
            assert_rows_bits_eq(&paged, r, &flat, r, &format!("{name}: paged prefill row {r}"));
        }
        // The lazy paged entry matches the oracle's last row bitwise.
        let mut pool_l = KvPagePool::new(5, pack.d_head(), 4096);
        let mut kv_l = PagedKv::new(&pack, m.cfg.max_seq);
        let lazy = m.paged_prefill(&pack, &mut pool_l, &mut kv_l, &toks);
        assert_eq!(lazy.shape(), &[1, m.cfg.vocab], "{name}: lazy paged prefill shape");
        assert_rows_bits_eq(&lazy, 0, &paged, toks.len() - 1, &format!("{name}: lazy paged row"));
        assert_eq!(
            kv.pages_held(),
            pack.pages_needed(toks.len(), pool.page_positions()),
            "{name}: page accounting after prefill"
        );
        for (step, &tok) in prompt(12).iter().enumerate().take(9) {
            let p = m.paged_decode_step(&pack, &mut pool, &mut kv, tok);
            let s = m.decode_step(&mut slab, tok);
            assert_rows_bits_eq(&p, 0, &s, 0, &format!("{name}: paged decode step {step}"));
            assert_eq!(kv.len(), slab.len(), "{name}: cache lengths agree");
        }
    }
}

#[test]
fn one_request_batch_is_bitwise_equal_to_solo_decode_step() {
    for (name, m) in variants() {
        let pack = m.serve_pack();
        let mut pool_a = KvPagePool::new(8, pack.d_head(), 2048);
        let mut pool_b = KvPagePool::new(8, pack.d_head(), 2048);
        let mut kv_batch = PagedKv::new(&pack, m.cfg.max_seq);
        let mut kv_solo = PagedKv::new(&pack, m.cfg.max_seq);
        let toks = prompt(6);
        m.paged_prefill(&pack, &mut pool_a, &mut kv_batch, &toks);
        m.paged_prefill(&pack, &mut pool_b, &mut kv_solo, &toks);
        let mut tok = 3u16;
        for step in 0..5 {
            let batched = m.decode_batch_step(
                &pack,
                &mut pool_a,
                std::slice::from_mut(&mut kv_batch),
                &[tok],
            );
            let solo = m.paged_decode_step(&pack, &mut pool_b, &mut kv_solo, tok);
            assert_rows_bits_eq(
                &batched,
                0,
                &solo,
                0,
                &format!("{name}: 1-request batch step {step}"),
            );
            tok = (tok + 7) % 60;
        }
    }
}

#[test]
fn batched_decode_matches_solo_streams_at_any_worker_count() {
    // Three requests at different positions coalesced into one batch:
    // every row must be bitwise equal to the request's solo paged
    // stream, and worker count must not matter (the per-(request,
    // head) fan-out writes disjoint panels).
    let m = common::lm(LmConfig::default(), 36);
    let pack = m.serve_pack();
    let prompts: [Vec<u16>; 3] =
        [prompt(3), (0..5).map(|i| ((i * 11 + 1) % 64) as u16).collect(), prompt(8)];
    let run = || {
        let mut pool_b = KvPagePool::new(4, pack.d_head(), 4096);
        let mut pool_s = KvPagePool::new(4, pack.d_head(), 4096);
        let mut batch: Vec<PagedKv> =
            prompts.iter().map(|_| PagedKv::new(&pack, m.cfg.max_seq)).collect();
        let mut solo: Vec<PagedKv> =
            prompts.iter().map(|_| PagedKv::new(&pack, m.cfg.max_seq)).collect();
        let mut toks: Vec<u16> = Vec::new();
        for (i, p) in prompts.iter().enumerate() {
            let lb = m.paged_prefill(&pack, &mut pool_b, &mut batch[i], p);
            m.paged_prefill(&pack, &mut pool_s, &mut solo[i], p);
            toks.push(grail::nn::argmax_rows(&lb)[lb.dim(0) - 1] as u16);
        }
        let mut stream: Vec<Vec<u16>> = toks.iter().map(|&t| vec![t]).collect();
        for step in 0..6 {
            let bl = m.decode_batch_step(&pack, &mut pool_b, &mut batch, &toks);
            // Every coalesced row == its request's solo paged step.
            for (r, kv) in solo.iter_mut().enumerate() {
                let sl = m.paged_decode_step(&pack, &mut pool_s, kv, toks[r]);
                assert_rows_bits_eq(&bl, r, &sl, 0, &format!("row {r} step {step}"));
            }
            let picks = grail::nn::argmax_rows(&bl);
            for (r, &p) in picks.iter().enumerate() {
                toks[r] = p as u16;
                stream[r].push(toks[r]);
            }
        }
        stream
    };
    let baseline = run();
    // Re-run under fanned-out workers: each worker thread carries a
    // different nested thread-budget share, and the batch step's
    // per-(request, head) fan-out must not let that reach the bits.
    for workers in [2usize, 4, 8] {
        for stream in run_grid(vec![(); workers], workers, |_, _| run()) {
            assert_eq!(stream, baseline, "token streams drifted at {workers} workers");
        }
    }
}

#[test]
fn scheduler_admission_and_eviction_keep_survivors_bit_identical() {
    // max_batch 2 over 5 requests with staggered lengths forces
    // mid-flight admission and eviction; every completed stream must
    // still equal its solo `generate` run, and submission order must
    // not change any request's tokens.
    let m = common::lm(LmConfig::default(), 37);
    let reqs: Vec<(Vec<u16>, usize)> = (0..5)
        .map(|i| {
            let p: Vec<u16> = (0..3 + (i % 3)).map(|j| ((i * 13 + j * 5 + 1) % 64) as u16).collect();
            (p, 2 + (i * 3) % 7)
        })
        .collect();
    let solo: Vec<Vec<u16>> = reqs.iter().map(|(p, n)| m.generate(p, *n)).collect();
    for order in [[0usize, 1, 2, 3, 4], [4, 2, 0, 3, 1]] {
        let mut sched = BatchScheduler::new(&m, 8, 4096, 2);
        let ids: Vec<(usize, usize)> =
            order.iter().map(|&i| (sched.submit(&reqs[i].0, reqs[i].1), i)).collect();
        let done = sched.run_to_completion();
        assert_eq!(done.len(), reqs.len());
        for (id, i) in ids {
            let c = done.iter().find(|c| c.id == id).unwrap();
            assert_eq!(c.tokens, solo[i], "request {i} (order {order:?})");
        }
        let st = sched.stats();
        assert_eq!(st.completed, reqs.len());
        assert!(st.peak_active <= 2, "max_batch respected: {st:?}");
        // Each request takes its first token from prefill and exactly
        // one coalesced decode row per token after that, no matter how
        // the schedule staggered it.
        let decode_rows: usize = reqs.iter().map(|(_, n)| n - 1).sum();
        assert_eq!(st.coalesced_rows, decode_rows, "{st:?}");
        // 21 decode rows at <= 2 per step means the batch turned over
        // several generations of requests.
        assert!(st.decode_steps >= decode_rows / 2, "{st:?}");
        assert_eq!(sched.pool().pages_in_use(), 0, "evicted requests returned every page");
    }
}

#[test]
#[should_panic(expected = "KV page pool exhausted")]
fn page_pool_exhaustion_panics_loudly() {
    // Driving the paged path directly (bypassing scheduler admission)
    // past the page budget must die with a clear message — silent
    // truncation would corrupt every later token.
    let m = common::lm(LmConfig::default(), 38);
    let pack = m.serve_pack();
    // A 7-token prompt at ps=4 needs 2 pages per stream = 128 total.
    let mut pool = KvPagePool::new(4, pack.d_head(), 32);
    let mut kv = PagedKv::new(&pack, m.cfg.max_seq);
    m.paged_prefill(&pack, &mut pool, &mut kv, &prompt(7));
}

#[test]
fn paged_pool_holds_4x_more_concurrent_requests_than_slabs() {
    // Same memory budget, measured in cache floats: two per-request
    // max_seq slabs' worth of pool pages. Short requests (8 live
    // positions of max_seq = 64) pack 8× more streams into it — the
    // scheduler must actually hold ≥ 4× the slab count in flight at
    // once, and still produce solo-identical tokens.
    let m = common::lm(LmConfig::default(), 39);
    let pack = m.serve_pack();
    let ps = 8usize;
    let slab_requests = 2usize;
    let budget_elems = slab_requests * pack.slab_elems(m.cfg.max_seq);
    let pool_pages = budget_elems / (ps * pack.d_head());
    let n_req = 16usize;
    let mut sched = BatchScheduler::new(&m, ps, pool_pages, n_req);
    let reqs: Vec<Vec<u16>> = (0..n_req)
        .map(|i| (0..4).map(|j| ((i * 7 + j * 3 + 2) % 64) as u16).collect())
        .collect();
    let ids: Vec<usize> = reqs.iter().map(|p| sched.submit(p, 4)).collect();
    let done = sched.run_to_completion();
    let st = sched.stats();
    assert!(
        st.peak_active >= 4 * slab_requests,
        "paged pool must hold >= 4x the slab-equivalent request count, got {st:?}"
    );
    assert_eq!(st.peak_active, n_req, "every short request fits the pool at once");
    for (i, id) in ids.iter().enumerate() {
        let c = done.iter().find(|c| c.id == *id).unwrap();
        assert_eq!(c.tokens, m.generate(&reqs[i], 4), "request {i}");
    }
}

#[test]
fn scheduler_tokens_invariant_under_thread_env() {
    // GRAIL_THREADS caps the machine-level budget that the batch
    // step's per-(request, head) fan-out divides up; the token streams
    // must be bit-identical at every setting — with chunked prefill
    // active (chunk 3 splits the length-20 prompt across many mixed
    // steps, so prefill-span attention jobs fan out too).
    let m = common::lm(LmConfig::default(), 40);
    let mut reqs: Vec<(Vec<u16>, usize)> =
        (0..3).map(|i| (prompt(4 + i), 3 + i)).collect();
    reqs.push((prompt(20), 4));
    let run = || {
        let mut sched = BatchScheduler::new(&m, 8, 2048, 4).with_prefill_chunk(3);
        let ids: Vec<usize> = reqs.iter().map(|(p, n)| sched.submit(p, *n)).collect();
        let done = sched.run_to_completion();
        ids.iter()
            .map(|id| done.iter().find(|c| c.id == *id).unwrap().tokens.clone())
            .collect::<Vec<_>>()
    };
    let baseline = run();
    for (i, (p, n)) in reqs.iter().enumerate() {
        assert_eq!(baseline[i], m.generate(p, *n), "baseline request {i} vs solo generate");
    }
    for threads in ["1", "2", "4", "8"] {
        std::env::set_var("GRAIL_THREADS", threads);
        let got = run();
        std::env::remove_var("GRAIL_THREADS");
        assert_eq!(got, baseline, "token streams drifted at GRAIL_THREADS={threads}");
    }
}

// ---------------------------------------------------------------------
// Chunked prefill (`TinyLm::batch_step` multi-row spans + the
// scheduler's `with_prefill_chunk`). The contract: ANY chunking of a
// prompt writes the same K/V content and final logits as the one-shot
// prefill, and mixed prefill+decode scheduling never reaches any
// request's tokens.
// ---------------------------------------------------------------------

/// Prefill `toks` into `kv` through `batch_step` in chunks of at most
/// `chunk` rows, returning the final chunk's logits. Interior chunks
/// must return zero-row logits (their vocab projection is skipped).
fn chunked_prefill(
    m: &TinyLm,
    pack: &grail::nn::models::LmServePack,
    pool: &mut KvPagePool,
    kvs: &mut [PagedKv],
    toks: &[u16],
    chunk: usize,
) -> Tensor {
    let mut scratch = BatchScratch::new();
    let mut filled = 0usize;
    let mut logits = Tensor::zeros(&[0, m.cfg.vocab]);
    while filled < toks.len() {
        let rows = chunk.min(toks.len() - filled);
        let last = filled + rows == toks.len();
        let spans = [RowSpan { slot: 0, rows, want_logits: last }];
        let out = m.batch_step(pack, pool, kvs, &spans, &toks[filled..filled + rows], &mut scratch);
        if last {
            logits = out;
        } else {
            assert_eq!(out.shape(), &[0, m.cfg.vocab], "interior chunk must skip lm_head");
        }
        filled += rows;
    }
    logits
}

#[test]
fn chunked_prefill_matches_one_shot_bitwise() {
    // Page size 8; prompt lengths straddle the page boundary (7, 8, 9)
    // plus a multi-page length; chunk sizes hit every boundary shape:
    // 1, ps-1, ps, the whole prompt, and past the prompt.
    let ps = 8usize;
    for (name, m) in variants() {
        let pack = m.serve_pack();
        for plen in [7usize, 8, 9, 19] {
            let toks = prompt(plen);
            let mut pool_os = KvPagePool::new(ps, pack.d_head(), 4096);
            let mut kv_os = PagedKv::new(&pack, m.cfg.max_seq);
            let one_shot = m.paged_prefill(&pack, &mut pool_os, &mut kv_os, &toks);
            for chunk in [1usize, ps - 1, ps, plen, plen + 5] {
                let tag = format!("{name} plen={plen} chunk={chunk}");
                let mut pool = KvPagePool::new(ps, pack.d_head(), 4096);
                let mut kv = vec![PagedKv::new(&pack, m.cfg.max_seq)];
                let logits = chunked_prefill(&m, &pack, &mut pool, &mut kv, &toks, chunk);
                assert_eq!(logits.shape(), &[1, m.cfg.vocab], "{tag}: final logits shape");
                assert_rows_bits_eq(&logits, 0, &one_shot, 0, &tag);
                // Page *ids* legitimately differ between chunkings
                // (allocation order interleaves); the content at every
                // (stream, position) must not.
                assert_eq!(kv[0].len(), kv_os.len(), "{tag}: cached length");
                for s in 0..pack.total_kv_streams() {
                    let (kc, ko) = (
                        kv[0].gather_k(&pool, s, pack.d_head()),
                        kv_os.gather_k(&pool_os, s, pack.d_head()),
                    );
                    let (vc, vo) = (
                        kv[0].gather_v(&pool, s, pack.d_head()),
                        kv_os.gather_v(&pool_os, s, pack.d_head()),
                    );
                    for (a, b) in kc.iter().zip(&ko) {
                        assert_eq!(a.to_bits(), b.to_bits(), "{tag}: K stream {s}");
                    }
                    for (a, b) in vc.iter().zip(&vo) {
                        assert_eq!(a.to_bits(), b.to_bits(), "{tag}: V stream {s}");
                    }
                }
                // Decode continuations from the chunked cache stay on
                // the one-shot stream.
                let mut tok = grail::nn::argmax_rows(&logits)[0] as u16;
                let mut tok_os = tok;
                for step in 0..3 {
                    let dc = m.paged_decode_step(&pack, &mut pool, &mut kv[0], tok);
                    let dos = m.paged_decode_step(&pack, &mut pool_os, &mut kv_os, tok_os);
                    assert_rows_bits_eq(&dc, 0, &dos, 0, &format!("{tag}: decode step {step}"));
                    tok = grail::nn::argmax_rows(&dc)[0] as u16;
                    tok_os = grail::nn::argmax_rows(&dos)[0] as u16;
                }
            }
        }
    }
}

#[test]
fn chunked_scheduler_streams_match_solo_and_unchunked_any_order() {
    // Mixed prefill+decode survivor bit-identity: four requests
    // (including a 20-token and a 13-token prompt that must chunk),
    // two admission orders, three chunk budgets including the
    // one-shot `usize::MAX` schedule. Every completed stream equals
    // its solo `generate` run in every configuration.
    let m = common::lm(LmConfig::default(), 41);
    let reqs: Vec<(Vec<u16>, usize)> = vec![
        (prompt(20), 5),
        ((0..4).map(|j| ((j * 9 + 3) % 64) as u16).collect(), 7),
        (prompt(13), 3),
        ((0..6).map(|j| ((j * 17 + 1) % 64) as u16).collect(), 6),
    ];
    let solo: Vec<Vec<u16>> = reqs.iter().map(|(p, n)| m.generate(p, *n)).collect();
    for chunk in [3usize, 8, usize::MAX] {
        for order in [[0usize, 1, 2, 3], [3, 1, 0, 2]] {
            let mut sched = BatchScheduler::new(&m, 8, 4096, 3).with_prefill_chunk(chunk);
            let ids: Vec<(usize, usize)> =
                order.iter().map(|&i| (sched.submit(&reqs[i].0, reqs[i].1), i)).collect();
            let done = sched.run_to_completion();
            assert_eq!(done.len(), reqs.len());
            for (id, i) in ids {
                let c = done.iter().find(|c| c.id == id).unwrap();
                assert_eq!(c.tokens, solo[i], "request {i} chunk={chunk} order={order:?}");
            }
            let st = sched.stats();
            // First token always comes from the prefill-final pass, so
            // decode rows are exactly n_new - 1 per request at ANY
            // chunk size — and the lazy lm_head skips exactly the
            // interior prompt rows.
            let decode_rows: usize = reqs.iter().map(|(_, n)| n - 1).sum();
            assert_eq!(st.coalesced_rows, decode_rows, "chunk={chunk} {st:?}");
            assert_eq!(st.prefill_rows, reqs.iter().map(|(p, _)| p.len()).sum::<usize>());
            assert_eq!(
                st.lm_head_rows_saved,
                reqs.iter().map(|(p, _)| p.len() - 1).sum::<usize>(),
                "chunk={chunk} {st:?}"
            );
            if chunk == 3 {
                assert!(st.mixed_steps > 0, "small chunks must overlap decode: {st:?}");
            }
            assert_eq!(sched.pool().pages_in_use(), 0, "all pages returned");
        }
    }
}
