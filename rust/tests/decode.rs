//! KV-cache decode conformance (`TinyLm::prefill` / `decode_step` /
//! `generate`).
//!
//! The serving contract is *bitwise*: prefill must reproduce the full
//! forward exactly, and every incremental decode step must reproduce
//! the last row of the full forward over the sequence so far — for
//! dense, GQA, pruned, and folded models, at any worker count. The
//! chain that makes this hold (row-count-invariant GEMM dispatch,
//! prepacked weights sharing the per-call compute body, shared
//! `attend_cached`/fused-softmax kernels) is documented on
//! `TinyLm::decode_append`; these tests are the enforcement.

mod common;

use grail::compress::{Compressible, ReductionPlan, Reducer};
use grail::coordinator::scheduler::run_grid;
use grail::nn::models::{LmBatch, LmConfig, TinyLm};
use grail::tensor::Tensor;

/// Single-sequence batch (targets unused by `forward`).
fn batch_of(tokens: &[u16]) -> LmBatch {
    LmBatch { inputs: tokens.to_vec(), targets: vec![0; tokens.len()], b: 1, t: tokens.len() }
}

/// Deterministic in-vocab prompt.
fn prompt(len: usize) -> Vec<u16> {
    (0..len).map(|i| ((i * 5 + 2) % 64) as u16).collect()
}

fn assert_rows_bits_eq(a: &Tensor, ar: usize, b: &Tensor, br: usize, what: &str) {
    assert_eq!(a.dim(1), b.dim(1), "{what}: width");
    for (x, y) in a.row(ar).iter().zip(b.row(br)) {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: bits diverged");
    }
}

/// The four serving configurations the decode path must cover: plain
/// MHA, true GQA, head/MLP-pruned, and head/MLP-folded models (the
/// reductions change head counts, cache widths, and every GEMM shape).
fn variants() -> Vec<(&'static str, TinyLm)> {
    let dense = common::lm(LmConfig::default(), 31);
    let gqa = common::lm(LmConfig::gqa(), 32);
    let mut pruned = dense.clone();
    pruned.apply(0, &ReductionPlan::bare(Reducer::Select(vec![0, 2, 5, 7])));
    pruned.apply(3, &ReductionPlan::bare(Reducer::Select((0..96).collect())));
    let mut folded = dense.clone();
    folded.apply(
        2,
        &ReductionPlan::bare(Reducer::Fold { assign: vec![0, 0, 1, 1, 2, 2, 3, 3], k: 4 }),
    );
    folded.apply(
        5,
        &ReductionPlan::bare(Reducer::Fold { assign: (0..192).map(|i| i / 2).collect(), k: 96 }),
    );
    vec![("dense", dense), ("gqa", gqa), ("pruned", pruned), ("folded", folded)]
}

#[test]
fn prefill_matches_full_forward_bitwise() {
    for (name, m) in variants() {
        let toks = prompt(12);
        let full = m.forward(&batch_of(&toks));
        let mut state = m.decode_state();
        let pre = m.prefill(&mut state, &toks);
        assert_eq!(state.len(), toks.len(), "{name}: cached length");
        assert_eq!(pre.shape(), full.shape(), "{name}: logits shape");
        for r in 0..toks.len() {
            assert_rows_bits_eq(&pre, r, &full, r, &format!("{name}: prefill row {r}"));
        }
    }
}

#[test]
fn incremental_decode_matches_prefix_forwards_bitwise() {
    for (name, m) in variants() {
        let toks = prompt(10);
        let mut state = m.decode_state();
        let mut logits = m.prefill(&mut state, &toks[..1]);
        for p in 0..toks.len() {
            if p > 0 {
                logits = m.decode_step(&mut state, toks[p]);
            }
            let full = m.forward(&batch_of(&toks[..p + 1]));
            assert_rows_bits_eq(
                &logits,
                logits.dim(0) - 1,
                &full,
                p,
                &format!("{name}: decode step at position {p}"),
            );
            assert_eq!(state.len(), p + 1, "{name}: cache length after step {p}");
        }
    }
}

#[test]
fn generate_matches_rescan_at_any_worker_count() {
    for (name, m) in variants() {
        let p = prompt(6);
        let want = m.generate_rescan(&p, 10);
        assert_eq!(m.generate(&p, 10), want, "{name}: decode vs rescan generation");
        // Nested fan-outs hand workers different thread-budget shares;
        // the generated tokens must not notice.
        for workers in [2usize, 4, 8] {
            let outs = run_grid(vec![(); workers], workers, |_, _| m.generate(&p, 10));
            for out in outs {
                assert_eq!(out, want, "{name}: generation drifted at {workers} workers");
            }
        }
    }
}

#[test]
fn decode_state_reports_capacity() {
    let m = common::lm(LmConfig::default(), 33);
    let state = m.decode_state();
    assert!(state.is_empty());
    assert_eq!(state.len(), 0);
    assert_eq!(state.capacity(), m.cfg.max_seq);
}

#[test]
#[should_panic(expected = "decode past cache capacity")]
fn decode_past_capacity_panics() {
    let m = common::lm(LmConfig::default(), 34);
    let mut state = m.decode_state();
    let toks = prompt(m.cfg.max_seq);
    m.prefill(&mut state, &toks);
    m.decode_step(&mut state, 0);
}

#[test]
#[should_panic(expected = "prefill on a used DecodeState")]
fn prefill_twice_panics() {
    let m = common::lm(LmConfig::default(), 35);
    let mut state = m.decode_state();
    m.prefill(&mut state, &prompt(4));
    m.prefill(&mut state, &prompt(4));
}
