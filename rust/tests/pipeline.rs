//! Artifact-free integration tests: the full compression pipeline on
//! in-memory models across every architecture, method, and recovery
//! combination, plus property-based invariants via the in-tree
//! framework (no proptest offline).

mod common;

use grail::compress::baselines::Baseline;
use grail::compress::Selector;
use grail::data::{SynthText, TextSplit};
use grail::eval::{lm_perplexity, vision_accuracy};
use grail::grail::{compress_model, CompressionSpec, Method};
use grail::nn::models::{LmConfig, MlpNet};
use grail::rng::Pcg64;
use grail::testing::{check, Config};

/// `Compressible::param_count` must agree with the serialized
/// checkpoint size for every family (guards drift between the
/// hand-summed counts and `to_bundle`).
#[test]
fn param_count_matches_bundle_for_all_families() {
    use grail::compress::Compressible;
    let mlp = common::mlp(99);
    assert_eq!(mlp.param_count(), mlp.to_bundle().num_params());
    let resnet = common::resnet(99);
    assert_eq!(resnet.param_count(), resnet.to_bundle().num_params());
    let vit = common::vit(99);
    assert_eq!(vit.param_count(), vit.to_bundle().num_params());
    for cfg in [LmConfig::default(), LmConfig::gqa()] {
        let lm = common::lm(cfg, 99);
        assert_eq!(lm.param_count(), lm.to_bundle().num_params());
    }
}

/// Every (method, grail) combination leaves every model functional.
#[test]
fn all_methods_all_models_stay_finite() {
    let methods = [
        Method::Prune(Selector::MagnitudeL1),
        Method::Prune(Selector::MagnitudeL2),
        Method::Prune(Selector::Wanda),
        Method::Prune(Selector::GramDiag),
        Method::Prune(Selector::Random),
        Method::Fold,
        Method::RandomFold,
        Method::Baseline(Baseline::Wanda),
        Method::Baseline(Baseline::WandaPP),
        Method::Baseline(Baseline::SlimGPT),
        Method::Baseline(Baseline::ZipLM),
        Method::Baseline(Baseline::Flap),
    ];
    let x = common::vision_calib(9, 64);
    let mlp = common::mlp(1);
    let resnet = common::resnet(1);
    let vit = common::vit(1);
    for method in methods {
        for grail_on in [false, true] {
            let cfg = CompressionSpec::uniform(method, 0.5, grail_on);
            let mut m = mlp.clone();
            compress_model(&mut m, &x, &cfg);
            assert!(m.forward(&x).all_finite(), "mlp {method:?} grail={grail_on}");
            let mut r = resnet.clone();
            compress_model(&mut r, &x, &cfg);
            assert!(r.forward(&x).all_finite(), "resnet {method:?} grail={grail_on}");
            let mut v = vit.clone();
            compress_model(&mut v, &x, &cfg);
            assert!(v.forward(&x).all_finite(), "vit {method:?} grail={grail_on}");
        }
    }
}

/// The LM pipeline handles head sites (MHA and GQA) for every method.
#[test]
fn lm_pipeline_mha_and_gqa() {
    let calib = common::lm_batch(3, TextSplit::Train, 4000, 16, 16);
    for cfg_lm in [LmConfig::default(), LmConfig::gqa()] {
        let lm = common::lm(cfg_lm, 2);
        for method in [
            Method::Prune(Selector::Wanda),
            Method::Fold,
            Method::Baseline(Baseline::Flap),
            Method::Baseline(Baseline::ZipLM),
        ] {
            for grail_on in [false, true] {
                let mut m = lm.clone();
                let cfg = CompressionSpec::uniform(method, 0.5, grail_on);
                let rep = compress_model(&mut m, &calib, &cfg);
                assert_eq!(rep.sites.len(), 8);
                assert!(m.forward(&calib).all_finite(), "{method:?} grail={grail_on}");
                // Heads halved on every attention site.
                for blk in &m.blocks {
                    assert_eq!(blk.attn.n_heads, 4);
                }
            }
        }
    }
}

/// GRAIL's defining guarantee: for a *trained-ish* model with
/// correlated activations, compensation beats data-free updates on
/// output fidelity — across selectors and architectures.
#[test]
fn grail_beats_bare_on_output_fidelity() {
    let model = common::mlp_sized(768, 64, 10, 4);
    let x = common::vision_calib(5, 96);
    let y_ref = model.forward(&x);
    for method in [
        Method::Prune(Selector::MagnitudeL2),
        Method::Prune(Selector::Random),
        Method::Fold,
    ] {
        let mut dist = [0.0f32; 2];
        for (i, grail_on) in [false, true].into_iter().enumerate() {
            let mut m = model.clone();
            compress_model(&mut m, &x, &CompressionSpec::uniform(method, 0.6, grail_on));
            let mut d = m.forward(&x);
            grail::tensor::ops::axpy(&mut d, -1.0, &y_ref);
            dist[i] = d.frobenius();
        }
        assert!(
            dist[1] < dist[0],
            "{method:?}: grail {} !< bare {}",
            dist[1],
            dist[0]
        );
    }
}

/// Property: for any ratio and seed, pruning+GRAIL keeps logits finite
/// and the requested widths (shrink-lite sweeps smaller shapes too).
#[test]
fn prop_pipeline_widths_and_finiteness() {
    check(Config { cases: 24, seed: 77 }, |rng, size| {
        let hidden = 8 + rng.below(size.scale(48, 8));
        let mut init_rng = Pcg64::seed(rng.next_u64());
        let model = MlpNet::init(48, hidden, 5, &mut init_rng);
        let mut x = grail::tensor::Tensor::zeros(&[32, 48]);
        init_rng.fill_normal(x.data_mut(), 1.0);
        let ratio = 0.1 + 0.8 * rng.next_f64();
        let mut cfg = CompressionSpec::uniform(Method::Prune(Selector::Wanda), ratio, true);
        cfg.seed = rng.next_u64();
        let mut m = model;
        let rep = compress_model(&mut m, &x, &cfg);
        let want = grail::grail::pipeline::uniform_keep(hidden, 1, ratio);
        if m.fc1.out_dim() != want {
            return Err(format!("fc1 width {} != {}", m.fc1.out_dim(), want));
        }
        if !m.forward(&x).all_finite() {
            return Err("non-finite logits".into());
        }
        if rep.sites.len() != 2 {
            return Err("wrong site count".into());
        }
        Ok(())
    });
}

/// Property: the reconstruction map at α→0 on an identity Gram is the
/// selection matrix for arbitrary widths/selections.
#[test]
fn prop_identity_gram_recovers_selection() {
    check(Config { cases: 40, seed: 78 }, |rng, size| {
        let h = 4 + rng.below(size.scale(60, 4));
        let k = 1 + rng.below(h);
        let keep = rng.choose_k(h, k);
        let mut keep = keep;
        keep.sort_unstable();
        let g = grail::tensor::Tensor::eye(h);
        let r = grail::compress::Reducer::Select(keep.clone());
        let b = grail::grail::reconstruction(&g, &r, 1, 0.0);
        let m = r.matrix(h);
        if b.max_abs_diff(&m) > 1e-4 {
            return Err(format!("h={h} k={k}: B differs from M"));
        }
        Ok(())
    });
}

/// End-to-end sanity on real (in-test trained-free) statistics: a
/// MiniResNet compressed at a mild ratio with GRAIL + REPAIR retains
/// more accuracy than plain pruning. Uses an untrained net, so we
/// check relative output distortion rather than accuracy.
#[test]
fn resnet_grail_repair_reduces_distortion() {
    let model = common::resnet(6);
    let calib_set = common::vision_set(7, 48);
    let y_ref = model.forward(&calib_set.x);
    let run = |grail_on: bool, repair: bool| {
        let mut m = model.clone();
        let cfg = CompressionSpec::uniform(Method::Prune(Selector::MagnitudeL2), 0.5, grail_on);
        compress_model(&mut m, &calib_set.x, &cfg);
        if repair {
            m.repair(&calib_set);
        }
        let mut d = m.forward(&calib_set.x);
        grail::tensor::ops::axpy(&mut d, -1.0, &y_ref);
        d.frobenius()
    };
    let bare = run(false, false);
    let grail_only = run(true, false);
    assert!(grail_only < bare, "grail {grail_only} !< bare {bare}");
}

/// Perplexity direction on a *trained* tiny chain: a 1-layer LM fitted
/// briefly in-test (closed-form-ish via many SGD steps is too slow
/// here, so we instead verify the weaker invariant that GRAIL never
/// makes an untrained model's perplexity dramatically worse).
#[test]
fn lm_grail_does_not_explode_perplexity() {
    let lm = common::lm_layers(2, 8);
    let text = SynthText::new(10);
    let calib = common::lm_batch(10, TextSplit::Calib, 3000, 16, 16);
    let eval = text.generate(TextSplit::Wt2s, 2000);
    let base = lm_perplexity(&lm, &eval, 16, 16, 8);
    let mut m = lm.clone();
    compress_model(
        &mut m,
        &calib,
        &CompressionSpec::uniform(Method::Prune(Selector::Wanda), 0.3, true),
    );
    let after = lm_perplexity(&m, &eval, 16, 16, 8);
    assert!(after.is_finite());
    assert!(after < base * 3.0, "ppl {base} -> {after}");
}

/// Accuracy metric plumbed through the sweep path agrees with direct
/// evaluation (guards the experiment engine's batching).
#[test]
fn sweep_eval_matches_direct() {
    let m = common::mlp_sized(768, 24, 10, 11);
    let set = common::vision_set(12, 100);
    let direct = {
        let logits = m.forward(&set.x);
        grail::eval::accuracy_from_logits(&logits, &set.y)
    };
    let batched = vision_accuracy(|x| m.forward(x), &set, 13);
    assert!((direct - batched).abs() < 1e-12);
}

/// Extreme-ratio edge cases: the pipeline clamps to ≥1 unit (or one
/// head per KV group) and still produces a working model.
#[test]
fn extreme_ratios_clamp_safely() {
    let x = common::vision_calib(9, 64);
    for ratio in [0.95, 0.99] {
        let mut m = common::mlp_sized(768, 16, 10, 20);
        compress_model(
            &mut m,
            &x,
            &CompressionSpec::uniform(Method::Prune(Selector::Wanda), ratio, true),
        );
        assert!(m.fc1.out_dim() >= 1);
        assert!(m.forward(&x).all_finite());
    }
    // GQA: never below one query head per group.
    let calib = common::lm_batch(21, TextSplit::Train, 2000, 16, 8);
    let mut lm = common::lm(LmConfig::gqa(), 20);
    compress_model(
        &mut lm,
        &calib,
        &CompressionSpec::uniform(Method::Prune(Selector::Wanda), 0.99, true),
    );
    for blk in &lm.blocks {
        assert_eq!(blk.attn.n_heads, 4); // 4 groups × 1 head floor
        assert_eq!(blk.attn.n_kv, 4);
    }
    assert!(lm.forward(&calib).all_finite());
}

/// Open-loop ablation plumbing: both modes run; closed loop is at
/// least as good on deep-model output fidelity.
#[test]
fn closed_loop_no_worse_than_open() {
    let model = common::mlp_sized(768, 64, 10, 22);
    let x = common::vision_calib(23, 96);
    let y_ref = model.forward(&x);
    let run = |closed: bool| {
        let mut m = model.clone();
        let mut cfg = CompressionSpec::uniform(Method::Prune(Selector::MagnitudeL2), 0.6, true);
        cfg.closed_loop = closed;
        compress_model(&mut m, &x, &cfg);
        let mut d = m.forward(&x);
        grail::tensor::ops::axpy(&mut d, -1.0, &y_ref);
        d.frobenius()
    };
    let closed = run(true);
    let open = run(false);
    assert!(closed.is_finite() && open.is_finite());
    assert!(closed <= open * 1.05, "closed {closed} vs open {open}");
}

/// Determinism across the whole pipeline: same seed, same compressed
/// weights, bit-for-bit — the reproducibility contract every
/// experiment relies on.
#[test]
fn full_pipeline_bitwise_deterministic() {
    let run = || {
        let mut m = common::lm(LmConfig::default(), 30);
        let calib = common::lm_calib(31, 2000, 16, 8);
        let mut cfg = CompressionSpec::uniform(Method::Baseline(Baseline::Flap), 0.5, true);
        cfg.seed = 99;
        compress_model(&mut m, &calib, &cfg);
        m.forward(&calib)
    };
    assert_eq!(run(), run());
}
