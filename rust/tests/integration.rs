//! Artifact-dependent integration tests: checkpoint zoo, trained-model
//! compression quality, and experiment harness smoke runs.
//!
//! These require `make artifacts`; each test skips (with a notice)
//! when artifacts are absent so `cargo test` is green on a fresh
//! clone.

use grail::compress::baselines::Baseline;
use grail::compress::Selector;
use grail::coordinator::{Artifacts, Zoo};
use grail::data::io::{read_images, read_tokens};
use grail::eval::{lm_perplexity, vision_accuracy};
use grail::grail::{compress_model, Method, CompressionSpec};
use grail::nn::models::LmBatch;

fn zoo() -> Option<(Artifacts, Zoo)> {
    let art = Artifacts::default_root();
    match Zoo::open(art.clone()) {
        Ok(z) => Some((art, z)),
        Err(_) => {
            eprintln!("skipping artifact test (run `make artifacts`)");
            None
        }
    }
}

#[test]
fn zoo_lists_all_families() {
    let Some((_, zoo)) = zoo() else { return };
    assert!(!zoo.list("mlp").is_empty());
    assert!(!zoo.list("resnet").is_empty());
    assert!(!zoo.list("vit").is_empty());
    assert!(zoo.list("tinylm").contains(&"tinylm_mha".to_string()));
    assert!(zoo.list("tinylm").contains(&"tinylm_gqa".to_string()));
}

#[test]
fn trained_checkpoints_beat_chance() {
    let Some((art, zoo)) = zoo() else { return };
    let test = read_images(&art.data("vision_test.imgs")).unwrap().slice(0, 512);
    for name in zoo.list("mlp") {
        let m = zoo.mlp(&name).unwrap();
        let acc = vision_accuracy(|x| m.forward(x), &test, 128);
        assert!(acc > 0.6, "{name}: acc {acc}");
    }
    for name in zoo.list("resnet") {
        let m = zoo.resnet(&name).unwrap();
        let acc = vision_accuracy(|x| m.forward(x), &test, 128);
        assert!(acc > 0.7, "{name}: acc {acc}");
    }
    for name in zoo.list("vit") {
        let m = zoo.vit(&name).unwrap();
        let acc = vision_accuracy(|x| m.forward(x), &test, 128);
        assert!(acc > 0.6, "{name}: acc {acc}");
    }
}

#[test]
fn trained_lm_learns_the_grammar() {
    let Some((art, zoo)) = zoo() else { return };
    let eval = read_tokens(&art.data("text_c4s.tokens")).unwrap();
    for name in ["tinylm_mha", "tinylm_gqa"] {
        let m = zoo.lm(name).unwrap();
        let ppl = lm_perplexity(&m, &eval, 32, 64, 16);
        // Uniform = 64; the grammar's oracle is far lower. Trained
        // model must be well under uniform.
        assert!(ppl < 30.0, "{name}: ppl {ppl}");
    }
}

/// The paper's headline claim on a *trained* network: at moderate
/// sparsity GRAIL recovers most of the accuracy that pruning destroys.
#[test]
fn grail_recovers_trained_resnet_accuracy() {
    let Some((art, zoo)) = zoo() else { return };
    let calib = read_images(&art.data("vision_calib.imgs")).unwrap().slice(0, 128);
    let test = read_images(&art.data("vision_test.imgs")).unwrap().slice(0, 512);
    let base = zoo.resnet("resnet_seed0").unwrap();
    let dense = vision_accuracy(|x| base.forward(x), &test, 128);

    let run = |grail_on: bool| {
        let mut m = base.clone();
        let cfg =
            CompressionSpec::uniform(Method::Prune(Selector::MagnitudeL1), 0.6, grail_on);
        compress_model(&mut m, &calib.x, &cfg);
        vision_accuracy(|x| m.forward(x), &test, 128)
    };
    let bare = run(false);
    let grail_acc = run(true);
    assert!(
        grail_acc > bare + 0.02,
        "GRAIL must recover accuracy: dense {dense:.3}, bare {bare:.3}, grail {grail_acc:.3}"
    );
    assert!(grail_acc > 0.5 * dense, "grail {grail_acc:.3} vs dense {dense:.3}");
}

/// Table-1 direction on the trained LM: wanda+GRAIL ≤ wanda at 40%.
#[test]
fn grail_improves_trained_lm_perplexity() {
    let Some((art, zoo)) = zoo() else { return };
    let calib_toks = read_tokens(&art.data("text_calib.tokens")).unwrap();
    let calib = LmBatch::from_tokens(&calib_toks, 32, 128);
    let eval = read_tokens(&art.data("text_wt2s.tokens")).unwrap();
    let base = zoo.lm("tinylm_mha").unwrap();
    let run = |grail_on: bool| {
        let mut m = base.clone();
        let cfg = CompressionSpec::uniform(Method::Baseline(Baseline::Wanda), 0.4, grail_on);
        compress_model(&mut m, &calib, &cfg);
        lm_perplexity(&m, &eval, 32, 64, 16)
    };
    let bare = run(false);
    let grail_ppl = run(true);
    assert!(
        grail_ppl < bare,
        "wanda+GRAIL {grail_ppl:.2} must beat wanda {bare:.2}"
    );
}

/// The probe-task suite produces sane accuracies on the trained LM.
#[test]
fn probes_above_chance_on_trained_lm() {
    let Some((_, zoo)) = zoo() else { return };
    let m = zoo.lm("tinylm_mha").unwrap();
    let text = grail::data::SynthText::new(grail::coordinator::datagen::TASK_SEED);
    use grail::eval::probes::{probe_accuracy, probe_items, ProbeTask};
    // Cloze is the most direct grammar probe: trained model must beat
    // 4-way chance clearly.
    let items = probe_items(ProbeTask::Cloze, &text, 48, 1);
    let acc = probe_accuracy(&m, &items);
    assert!(acc > 0.4, "cloze acc {acc} (chance 0.25)");
}

/// `grail run --spec` end-to-end: a heterogeneous spec file (rules +
/// depth-ramp budget) resolves, executes on a zoo checkpoint, and
/// reports per-site provenance plus the parameter summary.
#[test]
fn run_spec_file_end_to_end() {
    use grail::exp::runner::{execute_job, resolve_job_plan, SpecJob};
    let Some((art, _)) = zoo() else { return };
    let dir = std::env::temp_dir().join("grail_spec_e2e");
    std::fs::create_dir_all(&dir).unwrap();
    let spec_path = dir.join("het.spec.toml");
    std::fs::write(
        &spec_path,
        r#"
[model]
family = "lm"
ckpt = "tinylm_mha"

[pipeline]
method = "prune-wanda"
ratio = 0.4
grail = true

[budget]
mode = "depth-ramp"
target_ratio = 0.4
gamma = 0.5

[rule.0]
match_kind = "attn-heads"
method = "fold"
"#,
    )
    .unwrap();
    let job = SpecJob::load(spec_path.to_str().unwrap()).unwrap();
    let opts = grail::exp::ExpOptions {
        out_dir: dir.to_string_lossy().into_owned(),
        artifacts: art,
        quick: true,
        seed: 0,
        cache: None,
    };
    // Plan resolution is side-effect free and heterogeneous.
    let plan = resolve_job_plan(&opts, job.family, &job.ckpt_or_default(), &job.spec).unwrap();
    let ratios: Vec<f64> = plan.sites.iter().map(|s| s.policy.ratio).collect();
    assert!(ratios.first().unwrap() < ratios.last().unwrap(), "{ratios:?}");
    assert!(plan.render().contains("fold"));
    // Execution matches the plan and evaluates before/after.
    let out = execute_job(&opts, job.family, &job.ckpt_or_default(), &job.spec, "het").unwrap();
    assert_eq!(out.metric, "ppl");
    assert!(out.before.is_finite() && out.after.is_finite());
    assert!(out.report.params_after < out.report.params_before);
    for (o, p) in out.report.sites.iter().zip(&plan.sites) {
        assert_eq!(o.units_after, p.keep, "{}", o.id);
        assert_eq!(o.method, p.policy.method.name());
    }
}

/// Experiment harness smoke: table3 (cheapest) runs end-to-end and
/// writes CSV.
#[test]
fn exp_table3_smoke() {
    let Some((art, _)) = zoo() else { return };
    let out = std::env::temp_dir().join("grail_exp_smoke");
    let opts = grail::exp::ExpOptions {
        out_dir: out.to_string_lossy().into_owned(),
        artifacts: art,
        quick: true,
        seed: 0,
        cache: None,
    };
    grail::exp::table3::run(&opts).unwrap();
    assert!(out.join("table3.csv").exists());
}
