//! Artifact-dependent integration tests: checkpoint zoo, trained-model
//! compression quality, and experiment harness smoke runs.
//!
//! These require `make artifacts`; each test skips (with a notice)
//! when artifacts are absent so `cargo test` is green on a fresh
//! clone.

use grail::compress::baselines::Baseline;
use grail::compress::Selector;
use grail::coordinator::{Artifacts, Zoo};
use grail::data::io::{read_images, read_tokens};
use grail::eval::{lm_perplexity, vision_accuracy};
use grail::grail::{compress_model, Method, PipelineConfig};
use grail::nn::models::LmBatch;

fn zoo() -> Option<(Artifacts, Zoo)> {
    let art = Artifacts::default_root();
    match Zoo::open(art.clone()) {
        Ok(z) => Some((art, z)),
        Err(_) => {
            eprintln!("skipping artifact test (run `make artifacts`)");
            None
        }
    }
}

#[test]
fn zoo_lists_all_families() {
    let Some((_, zoo)) = zoo() else { return };
    assert!(!zoo.list("mlp").is_empty());
    assert!(!zoo.list("resnet").is_empty());
    assert!(!zoo.list("vit").is_empty());
    assert!(zoo.list("tinylm").contains(&"tinylm_mha".to_string()));
    assert!(zoo.list("tinylm").contains(&"tinylm_gqa".to_string()));
}

#[test]
fn trained_checkpoints_beat_chance() {
    let Some((art, zoo)) = zoo() else { return };
    let test = read_images(&art.data("vision_test.imgs")).unwrap().slice(0, 512);
    for name in zoo.list("mlp") {
        let m = zoo.mlp(&name).unwrap();
        let acc = vision_accuracy(|x| m.forward(x), &test, 128);
        assert!(acc > 0.6, "{name}: acc {acc}");
    }
    for name in zoo.list("resnet") {
        let m = zoo.resnet(&name).unwrap();
        let acc = vision_accuracy(|x| m.forward(x), &test, 128);
        assert!(acc > 0.7, "{name}: acc {acc}");
    }
    for name in zoo.list("vit") {
        let m = zoo.vit(&name).unwrap();
        let acc = vision_accuracy(|x| m.forward(x), &test, 128);
        assert!(acc > 0.6, "{name}: acc {acc}");
    }
}

#[test]
fn trained_lm_learns_the_grammar() {
    let Some((art, zoo)) = zoo() else { return };
    let eval = read_tokens(&art.data("text_c4s.tokens")).unwrap();
    for name in ["tinylm_mha", "tinylm_gqa"] {
        let m = zoo.lm(name).unwrap();
        let ppl = lm_perplexity(&m, &eval, 32, 64, 16);
        // Uniform = 64; the grammar's oracle is far lower. Trained
        // model must be well under uniform.
        assert!(ppl < 30.0, "{name}: ppl {ppl}");
    }
}

/// The paper's headline claim on a *trained* network: at moderate
/// sparsity GRAIL recovers most of the accuracy that pruning destroys.
#[test]
fn grail_recovers_trained_resnet_accuracy() {
    let Some((art, zoo)) = zoo() else { return };
    let calib = read_images(&art.data("vision_calib.imgs")).unwrap().slice(0, 128);
    let test = read_images(&art.data("vision_test.imgs")).unwrap().slice(0, 512);
    let base = zoo.resnet("resnet_seed0").unwrap();
    let dense = vision_accuracy(|x| base.forward(x), &test, 128);

    let run = |grail_on: bool| {
        let mut m = base.clone();
        let cfg =
            PipelineConfig::new(Method::Prune(Selector::MagnitudeL1), 0.6, grail_on);
        compress_model(&mut m, &calib.x, &cfg);
        vision_accuracy(|x| m.forward(x), &test, 128)
    };
    let bare = run(false);
    let grail_acc = run(true);
    assert!(
        grail_acc > bare + 0.02,
        "GRAIL must recover accuracy: dense {dense:.3}, bare {bare:.3}, grail {grail_acc:.3}"
    );
    assert!(grail_acc > 0.5 * dense, "grail {grail_acc:.3} vs dense {dense:.3}");
}

/// Table-1 direction on the trained LM: wanda+GRAIL ≤ wanda at 40%.
#[test]
fn grail_improves_trained_lm_perplexity() {
    let Some((art, zoo)) = zoo() else { return };
    let calib_toks = read_tokens(&art.data("text_calib.tokens")).unwrap();
    let calib = LmBatch::from_tokens(&calib_toks, 32, 128);
    let eval = read_tokens(&art.data("text_wt2s.tokens")).unwrap();
    let base = zoo.lm("tinylm_mha").unwrap();
    let run = |grail_on: bool| {
        let mut m = base.clone();
        let cfg = PipelineConfig::new(Method::Baseline(Baseline::Wanda), 0.4, grail_on);
        compress_model(&mut m, &calib, &cfg);
        lm_perplexity(&m, &eval, 32, 64, 16)
    };
    let bare = run(false);
    let grail_ppl = run(true);
    assert!(
        grail_ppl < bare,
        "wanda+GRAIL {grail_ppl:.2} must beat wanda {bare:.2}"
    );
}

/// The probe-task suite produces sane accuracies on the trained LM.
#[test]
fn probes_above_chance_on_trained_lm() {
    let Some((_, zoo)) = zoo() else { return };
    let m = zoo.lm("tinylm_mha").unwrap();
    let text = grail::data::SynthText::new(grail::coordinator::datagen::TASK_SEED);
    use grail::eval::probes::{probe_accuracy, probe_items, ProbeTask};
    // Cloze is the most direct grammar probe: trained model must beat
    // 4-way chance clearly.
    let items = probe_items(ProbeTask::Cloze, &text, 48, 1);
    let acc = probe_accuracy(&m, &items);
    assert!(acc > 0.4, "cloze acc {acc} (chance 0.25)");
}

/// Experiment harness smoke: table3 (cheapest) runs end-to-end and
/// writes CSV.
#[test]
fn exp_table3_smoke() {
    let Some((art, _)) = zoo() else { return };
    let out = std::env::temp_dir().join("grail_exp_smoke");
    let opts = grail::exp::ExpOptions {
        out_dir: out.to_string_lossy().into_owned(),
        artifacts: art,
        quick: true,
        seed: 0,
    };
    grail::exp::table3::run(&opts).unwrap();
    assert!(out.join("table3.csv").exists());
}
