//! Segment-executor equivalence tests: the staged O(L) closed loop
//! must be *bit-identical* to the from-scratch reference on every model
//! family, in both closed- and open-loop modes — same selections, same
//! reconstruction errors, same compressed weights.

mod common;

use common::assert_reports_identical;
use grail::compress::{Compressible, Selector, SiteKind};
use grail::grail::{
    compress_model, compress_model_rescan, plan_for_model, BudgetMode, CompressionSpec, Method,
    PolicyOverrides, PolicyRule, SiteMatcher,
};
use grail::nn::models::{LmConfig, MlpNet};
use grail::rng::Pcg64;
use grail::testing::{check, Config};

fn configs() -> Vec<CompressionSpec> {
    let mut out = Vec::new();
    for closed in [true, false] {
        for method in [Method::Prune(Selector::Wanda), Method::Fold] {
            let mut cfg = CompressionSpec::uniform(method, 0.5, true);
            cfg.closed_loop = closed;
            out.push(cfg);
        }
    }
    out
}

#[test]
fn staged_matches_rescan_mlp() {
    let m0 = common::mlp(1);
    let x = common::vision_calib(9, 48);
    for cfg in configs() {
        let mut a = m0.clone();
        let ra = compress_model(&mut a, &x, &cfg);
        let mut b = m0.clone();
        let rb = compress_model_rescan(&mut b, &x, &cfg);
        assert_reports_identical(&ra, &rb);
        assert_eq!(a.forward(&x), b.forward(&x), "cfg {cfg:?}");
    }
}

#[test]
fn staged_matches_rescan_resnet() {
    let m0 = common::resnet(2);
    let x = common::vision_calib(9, 12);
    for cfg in configs() {
        let mut a = m0.clone();
        let ra = compress_model(&mut a, &x, &cfg);
        let mut b = m0.clone();
        let rb = compress_model_rescan(&mut b, &x, &cfg);
        assert_reports_identical(&ra, &rb);
        assert_eq!(a.forward(&x), b.forward(&x), "cfg {cfg:?}");
    }
}

#[test]
fn staged_matches_rescan_vit() {
    let m0 = common::vit(3);
    let x = common::vision_calib(9, 16);
    for cfg in configs() {
        let mut a = m0.clone();
        let ra = compress_model(&mut a, &x, &cfg);
        let mut b = m0.clone();
        let rb = compress_model_rescan(&mut b, &x, &cfg);
        assert_reports_identical(&ra, &rb);
        assert_eq!(a.forward(&x), b.forward(&x), "cfg {cfg:?}");
    }
}

#[test]
fn staged_matches_rescan_lm_mha_and_gqa() {
    let calib = common::lm_calib(5, 3000, 16, 12);
    for lm_cfg in [LmConfig::default(), LmConfig::gqa()] {
        let m0 = common::lm(lm_cfg, 4);
        for cfg in configs() {
            let mut a = m0.clone();
            let ra = compress_model(&mut a, &calib, &cfg);
            let mut b = m0.clone();
            let rb = compress_model_rescan(&mut b, &calib, &cfg);
            assert_reports_identical(&ra, &rb);
            assert_eq!(a.forward(&calib), b.forward(&calib), "cfg {cfg:?}");
        }
    }
}

/// After a full compression pass, staged prefix execution on the
/// *compressed* model must still bit-match the one-shot tap oracle for
/// every family — the invariant the next closed-loop run relies on.
#[test]
fn staged_prefix_matches_taps_after_compression_all_families() {
    let cfg = CompressionSpec::uniform(Method::Prune(Selector::Wanda), 0.5, true);
    let x = common::vision_calib(9, 10);

    let mut mlp = common::mlp(6);
    compress_model(&mut mlp, &x, &cfg);
    let (_, taps) = mlp.forward_with_taps(&x);
    for (site, tap) in taps.iter().enumerate() {
        assert_eq!(&mlp.site_activations(&x, site), tap, "mlp site {site}");
    }

    let mut resnet = common::resnet(6);
    compress_model(&mut resnet, &x, &cfg);
    let (_, taps) = resnet.forward_with_taps(&x);
    for (site, tap) in taps.iter().enumerate() {
        assert_eq!(&resnet.site_activations(&x, site), tap, "resnet site {site}");
    }

    let mut vit = common::vit(6);
    compress_model(&mut vit, &x, &cfg);
    let (_, taps) = vit.forward_with_taps(&x);
    for (site, tap) in taps.iter().enumerate() {
        assert_eq!(&vit.site_activations(&x, site), tap, "vit site {site}");
    }

    let calib = common::lm_calib(5, 2000, 16, 8);
    let mut lm = common::lm(LmConfig::default(), 6);
    compress_model(&mut lm, &calib, &cfg);
    let (_, taps) = lm.forward_with_taps(&calib);
    for (site, tap) in taps.iter().enumerate() {
        assert_eq!(&lm.site_activations(&calib, site), tap, "lm site {site}");
    }
}

/// Property: for random widths, ratios, and seeds, incremental staged
/// execution (tap, advance, tap, …) bit-matches the one-shot forward
/// taps on the compressed model.
#[test]
fn prop_incremental_states_match_one_shot_taps() {
    check(Config { cases: 12, seed: 0xA11 }, |rng, size| {
        let hidden = 8 + rng.below(size.scale(40, 8));
        let mut init_rng = Pcg64::seed(rng.next_u64());
        let model0 = MlpNet::init(48, hidden, 5, &mut init_rng);
        let mut x = grail::tensor::Tensor::zeros(&[16, 48]);
        init_rng.fill_normal(x.data_mut(), 1.0);
        let ratio = 0.1 + 0.8 * rng.next_f64();
        let mut cfg = CompressionSpec::uniform(Method::Prune(Selector::Wanda), ratio, true);
        cfg.seed = rng.next_u64();
        let mut m = model0;
        compress_model(&mut m, &x, &cfg);

        let (_, taps) = m.forward_with_taps(&x);
        let mut st = m.calib_begin(&x);
        for site in 0..taps.len() {
            let tap = m.site_tap(&mut st, site);
            if tap != taps[site] {
                return Err(format!("hidden={hidden} ratio={ratio:.2}: site {site} mismatch"));
            }
            if site + 1 < taps.len() {
                m.forward_segment(&mut st, site, site + 1);
            }
        }
        Ok(())
    });
}

/// A spec that reaches the same uniform per-site policy through the
/// rule machinery instead of the defaults: the defaults are set to a
/// deliberately wrong policy and a match-everything rule overrides
/// every field back to the target. Resolving it must produce the same
/// plan — and executing it bit-identical outcomes — as the plain
/// uniform spec (the legacy `PipelineConfig` semantics).
fn rule_built_uniform(target: &CompressionSpec) -> CompressionSpec {
    let mut spec = CompressionSpec::uniform(Method::Prune(Selector::Random), 0.9, false);
    spec.defaults.alpha = 123.0;
    spec.rules = vec![PolicyRule {
        matcher: SiteMatcher::default(),
        set: PolicyOverrides {
            method: Some(target.defaults.method),
            ratio: Some(target.defaults.ratio),
            grail: Some(target.defaults.grail),
            alpha: Some(target.defaults.alpha),
        },
    }];
    spec.seed = target.seed;
    spec.closed_loop = target.closed_loop;
    spec.shards = target.shards;
    spec.workers = target.workers;
    spec
}

/// Golden equivalence: a uniform `CompressionSpec` (the legacy
/// `PipelineConfig` path, now `CompressionSpec::uniform`) and the same
/// policy reached through matcher rules produce bit-identical
/// `Report.sites` and compressed weights — on every model family, in
/// both engines, closed- and open-loop.
#[test]
fn uniform_spec_equivalence_all_families() {
    let x = common::vision_calib(9, 16);
    let lm_calib = common::lm_calib(5, 2000, 16, 8);

    macro_rules! check_family {
        ($m0:expr, $calib:expr) => {
            for cfg in configs() {
                let ruled = rule_built_uniform(&cfg);
                // Staged engine.
                let mut a = $m0.clone();
                let ra = compress_model(&mut a, $calib, &cfg);
                let mut b = $m0.clone();
                let rb = compress_model(&mut b, $calib, &ruled);
                assert_reports_identical(&ra, &rb);
                assert_eq!(a.forward($calib), b.forward($calib), "staged cfg {cfg:?}");
                // Rescan engine.
                let mut c = $m0.clone();
                let rc = compress_model_rescan(&mut c, $calib, &ruled);
                assert_reports_identical(&ra, &rc);
                assert_eq!(a.forward($calib), c.forward($calib), "rescan cfg {cfg:?}");
            }
        };
    }

    let mlp = common::mlp(41);
    check_family!(mlp, &x);
    let resnet = common::resnet(41);
    check_family!(resnet, &x);
    let vit = common::vit(41);
    check_family!(vit, &x);
    let lm = common::lm(LmConfig::default(), 41);
    check_family!(lm, &lm_calib);
}

/// A heterogeneous spec — depth-ramped ratios with mixed prune+fold
/// methods via matcher rules — resolves to the expected per-site plan
/// and runs end-to-end on TinyLm through both engines.
#[test]
fn heterogeneous_spec_on_tinylm() {
    let calib = common::lm_calib(5, 3000, 16, 12);
    let m0 = common::lm_layers(3, 42);

    let mut spec = CompressionSpec::uniform(Method::Prune(Selector::Wanda), 0.5, true);
    // Attention sites fold instead of prune; the deepest block is
    // pinned gentle by a glob rule.
    spec.rules = vec![
        PolicyRule {
            matcher: SiteMatcher { kind: Some(SiteKind::AttnHeads), ..Default::default() },
            set: PolicyOverrides { method: Some(Method::Fold), ..Default::default() },
        },
        PolicyRule {
            matcher: SiteMatcher { id_glob: Some("block2.*".into()), ..Default::default() },
            set: PolicyOverrides { ratio: Some(0.25), ..Default::default() },
        },
    ];
    // Depth-ramp allocator over the non-pinned sites.
    spec.budget = BudgetMode::DepthRamp { target_ratio: 0.5, gamma: 0.5 };

    let plan = plan_for_model(&m0, &calib, &spec).unwrap();
    assert_eq!(plan.sites.len(), 6);
    // Attention sites got the fold override, MLP sites kept wanda.
    for ps in &plan.sites {
        if ps.id.ends_with(".attn") {
            assert_eq!(ps.policy.method, Method::Fold, "{}", ps.id);
        } else {
            assert_eq!(ps.policy.method, Method::Prune(Selector::Wanda), "{}", ps.id);
        }
    }
    // Ramped ratios increase with depth on the non-pinned prefix …
    let r: Vec<f64> = plan.sites.iter().map(|s| s.policy.ratio).collect();
    assert!(r[0] < r[1] && r[1] < r[2] && r[2] < r[3], "{r:?}");
    // … while block2 sites (indices 4, 5) are rule-pinned at 0.25.
    assert_eq!(r[4], 0.25);
    assert_eq!(r[5], 0.25);

    // Executes end-to-end, matches the plan, and both engines agree.
    let mut a = m0.clone();
    let ra = compress_model(&mut a, &calib, &spec);
    assert!(a.forward(&calib).all_finite());
    for (out, ps) in ra.sites.iter().zip(&plan.sites) {
        assert_eq!(out.id, ps.id);
        assert_eq!(out.units_after, ps.keep);
        assert_eq!(out.method, ps.policy.method.name());
        assert_eq!(out.ratio, ps.policy.ratio);
    }
    assert!(ra.params_after < ra.params_before);
    let mut b = m0.clone();
    let rb = compress_model_rescan(&mut b, &calib, &spec);
    assert_reports_identical(&ra, &rb);
    assert_eq!(a.forward(&calib), b.forward(&calib));
}

/// The Gram-sensitivity budget allocator runs end-to-end: keep counts
/// track the global budget and the compressed model still works.
#[test]
fn gram_sensitivity_budget_on_tinylm() {
    let calib = common::lm_calib(5, 3000, 16, 12);
    let m0 = common::lm(LmConfig::default(), 43);

    let mut spec = CompressionSpec::uniform(Method::Prune(Selector::Wanda), 0.5, true);
    spec.budget = BudgetMode::GramSensitivity { target_ratio: 0.5 };
    let plan = plan_for_model(&m0, &calib, &spec).unwrap();
    let total: usize = plan.sites.iter().map(|s| s.units).sum();
    let kept: usize = plan.sites.iter().map(|s| s.keep).sum();
    // Within one group step of the 50% unit budget.
    assert!(
        (kept as i64 - (total / 2) as i64).unsigned_abs() as usize <= 8,
        "kept {kept} of {total}"
    );
    let mut m = m0.clone();
    let rep = compress_model(&mut m, &calib, &spec);
    assert!(m.forward(&calib).all_finite());
    for (out, ps) in rep.sites.iter().zip(&plan.sites) {
        assert_eq!(out.units_after, ps.keep, "{}", out.id);
    }
}

/// Sharded, multi-threaded calibration keeps the structural outcome
/// (selected widths) and produces working models at every shard count.
#[test]
fn shard_counts_agree_on_selections() {
    let calib = common::lm_calib(5, 3000, 16, 12);
    let m0 = common::lm(LmConfig::default(), 7);
    let mut widths: Vec<Vec<usize>> = Vec::new();
    for (shards, workers) in [(1usize, 1usize), (4, 2), (12, 4)] {
        let mut cfg = CompressionSpec::uniform(Method::Prune(Selector::Wanda), 0.5, true);
        cfg.shards = shards;
        cfg.workers = workers;
        let mut m = m0.clone();
        let rep = compress_model(&mut m, &calib, &cfg);
        assert!(m.forward(&calib).all_finite(), "shards={shards}");
        widths.push(rep.sites.iter().map(|s| s.units_after).collect());
    }
    assert_eq!(widths[0], widths[1]);
    assert_eq!(widths[0], widths[2]);
}
