//! Segment-executor equivalence tests: the staged O(L) closed loop
//! must be *bit-identical* to the from-scratch reference on every model
//! family, in both closed- and open-loop modes — same selections, same
//! reconstruction errors, same compressed weights.

use grail::compress::{Compressible, Selector};
use grail::data::{SynthText, SynthVision, TextSplit};
use grail::grail::{compress_model, compress_model_rescan, Method, PipelineConfig, Report};
use grail::nn::models::{LmBatch, LmConfig, MiniResNet, MlpNet, TinyLm, TinyViT, VitConfig};
use grail::rng::Pcg64;
use grail::testing::{check, Config};

fn assert_reports_identical(a: &Report, b: &Report) {
    assert_eq!(a.sites.len(), b.sites.len(), "site counts");
    for (x, y) in a.sites.iter().zip(&b.sites) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.units_before, y.units_before);
        assert_eq!(x.units_after, y.units_after);
        assert_eq!(
            x.recon_err.to_bits(),
            y.recon_err.to_bits(),
            "site {}: recon_err {} vs {}",
            x.id,
            x.recon_err,
            y.recon_err
        );
    }
}

fn configs() -> Vec<PipelineConfig> {
    let mut out = Vec::new();
    for closed in [true, false] {
        for method in [Method::Prune(Selector::Wanda), Method::Fold] {
            let mut cfg = PipelineConfig::new(method, 0.5, true);
            cfg.closed_loop = closed;
            out.push(cfg);
        }
    }
    out
}

#[test]
fn staged_matches_rescan_mlp() {
    let mut rng = Pcg64::seed(1);
    let m0 = MlpNet::init(768, 32, 10, &mut rng);
    let x = SynthVision::new(9).generate(48).x;
    for cfg in configs() {
        let mut a = m0.clone();
        let ra = compress_model(&mut a, &x, &cfg);
        let mut b = m0.clone();
        let rb = compress_model_rescan(&mut b, &x, &cfg);
        assert_reports_identical(&ra, &rb);
        assert_eq!(a.forward(&x), b.forward(&x), "cfg {cfg:?}");
    }
}

#[test]
fn staged_matches_rescan_resnet() {
    let mut rng = Pcg64::seed(2);
    let m0 = MiniResNet::init(&mut rng);
    let x = SynthVision::new(9).generate(12).x;
    for cfg in configs() {
        let mut a = m0.clone();
        let ra = compress_model(&mut a, &x, &cfg);
        let mut b = m0.clone();
        let rb = compress_model_rescan(&mut b, &x, &cfg);
        assert_reports_identical(&ra, &rb);
        assert_eq!(a.forward(&x), b.forward(&x), "cfg {cfg:?}");
    }
}

#[test]
fn staged_matches_rescan_vit() {
    let mut rng = Pcg64::seed(3);
    let m0 = TinyViT::init(VitConfig::default(), &mut rng);
    let x = SynthVision::new(9).generate(16).x;
    for cfg in configs() {
        let mut a = m0.clone();
        let ra = compress_model(&mut a, &x, &cfg);
        let mut b = m0.clone();
        let rb = compress_model_rescan(&mut b, &x, &cfg);
        assert_reports_identical(&ra, &rb);
        assert_eq!(a.forward(&x), b.forward(&x), "cfg {cfg:?}");
    }
}

#[test]
fn staged_matches_rescan_lm_mha_and_gqa() {
    let mut rng = Pcg64::seed(4);
    let ts = SynthText::new(5).generate(TextSplit::Calib, 3000);
    let calib = LmBatch::from_tokens(&ts, 16, 12);
    for lm_cfg in [LmConfig::default(), LmConfig::gqa()] {
        let m0 = TinyLm::init(lm_cfg, &mut rng);
        for cfg in configs() {
            let mut a = m0.clone();
            let ra = compress_model(&mut a, &calib, &cfg);
            let mut b = m0.clone();
            let rb = compress_model_rescan(&mut b, &calib, &cfg);
            assert_reports_identical(&ra, &rb);
            assert_eq!(a.forward(&calib), b.forward(&calib), "cfg {cfg:?}");
        }
    }
}

/// After a full compression pass, staged prefix execution on the
/// *compressed* model must still bit-match the one-shot tap oracle for
/// every family — the invariant the next closed-loop run relies on.
#[test]
fn staged_prefix_matches_taps_after_compression_all_families() {
    let mut rng = Pcg64::seed(6);
    let cfg = PipelineConfig::new(Method::Prune(Selector::Wanda), 0.5, true);
    let x = SynthVision::new(9).generate(10).x;

    let mut mlp = MlpNet::init(768, 32, 10, &mut rng);
    compress_model(&mut mlp, &x, &cfg);
    let (_, taps) = mlp.forward_with_taps(&x);
    for (site, tap) in taps.iter().enumerate() {
        assert_eq!(&mlp.site_activations(&x, site), tap, "mlp site {site}");
    }

    let mut resnet = MiniResNet::init(&mut rng);
    compress_model(&mut resnet, &x, &cfg);
    let (_, taps) = resnet.forward_with_taps(&x);
    for (site, tap) in taps.iter().enumerate() {
        assert_eq!(&resnet.site_activations(&x, site), tap, "resnet site {site}");
    }

    let mut vit = TinyViT::init(VitConfig::default(), &mut rng);
    compress_model(&mut vit, &x, &cfg);
    let (_, taps) = vit.forward_with_taps(&x);
    for (site, tap) in taps.iter().enumerate() {
        assert_eq!(&vit.site_activations(&x, site), tap, "vit site {site}");
    }

    let ts = SynthText::new(5).generate(TextSplit::Calib, 2000);
    let calib = LmBatch::from_tokens(&ts, 16, 8);
    let mut lm = TinyLm::init(LmConfig::default(), &mut rng);
    compress_model(&mut lm, &calib, &cfg);
    let (_, taps) = lm.forward_with_taps(&calib);
    for (site, tap) in taps.iter().enumerate() {
        assert_eq!(&lm.site_activations(&calib, site), tap, "lm site {site}");
    }
}

/// Property: for random widths, ratios, and seeds, incremental staged
/// execution (tap, advance, tap, …) bit-matches the one-shot forward
/// taps on the compressed model.
#[test]
fn prop_incremental_states_match_one_shot_taps() {
    check(Config { cases: 12, seed: 0xA11 }, |rng, size| {
        let hidden = 8 + rng.below(size.scale(40, 8));
        let mut init_rng = Pcg64::seed(rng.next_u64());
        let model0 = MlpNet::init(48, hidden, 5, &mut init_rng);
        let mut x = grail::tensor::Tensor::zeros(&[16, 48]);
        init_rng.fill_normal(x.data_mut(), 1.0);
        let ratio = 0.1 + 0.8 * rng.next_f64();
        let mut cfg = PipelineConfig::new(Method::Prune(Selector::Wanda), ratio, true);
        cfg.seed = rng.next_u64();
        let mut m = model0;
        compress_model(&mut m, &x, &cfg);

        let (_, taps) = m.forward_with_taps(&x);
        let mut st = m.calib_begin(&x);
        for site in 0..taps.len() {
            let tap = m.site_tap(&mut st, site);
            if tap != taps[site] {
                return Err(format!("hidden={hidden} ratio={ratio:.2}: site {site} mismatch"));
            }
            if site + 1 < taps.len() {
                m.forward_segment(&mut st, site, site + 1);
            }
        }
        Ok(())
    });
}

/// Sharded, multi-threaded calibration keeps the structural outcome
/// (selected widths) and produces working models at every shard count.
#[test]
fn shard_counts_agree_on_selections() {
    let mut rng = Pcg64::seed(7);
    let ts = SynthText::new(5).generate(TextSplit::Calib, 3000);
    let calib = LmBatch::from_tokens(&ts, 16, 12);
    let m0 = TinyLm::init(LmConfig::default(), &mut rng);
    let mut widths: Vec<Vec<usize>> = Vec::new();
    for (shards, workers) in [(1usize, 1usize), (4, 2), (12, 4)] {
        let mut cfg = PipelineConfig::new(Method::Prune(Selector::Wanda), 0.5, true);
        cfg.shards = shards;
        cfg.workers = workers;
        let mut m = m0.clone();
        let rep = compress_model(&mut m, &calib, &cfg);
        assert!(m.forward(&calib).all_finite(), "shards={shards}");
        widths.push(rep.sites.iter().map(|s| s.units_after).collect());
    }
    assert_eq!(widths[0], widths[1]);
    assert_eq!(widths[0], widths[2]);
}
