//! Zero-shot probe tasks — the Table 2 substitute (DESIGN.md §2).
//!
//! The paper evaluates pruned LLaMA-2 on six likelihood-ranked
//! multiple-choice suites (ARC-C/E, HellaSwag, PIQA, BoolQ,
//! Winogrande). Those corpora don't exist for the synthetic grammar,
//! so we build six probe tasks with the same *evaluation shape* —
//! multiple-choice, scored by the model's conditional log-likelihood —
//! each stressing a different capability of the trained TinyLm.

use crate::data::{SynthText, TextSplit};
use crate::nn::models::{LmBatch, TinyLm};
use crate::nn::log_softmax_rows;
use crate::rng::Pcg64;

/// The six probe tasks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProbeTask {
    /// 4-way next-token cloze (ARC-E analogue).
    Cloze,
    /// Real vs token-shuffled sequence (BoolQ-ish acceptability).
    Accept,
    /// Real vs resampled 8-token continuation (HellaSwag analogue).
    Rank,
    /// Repeated-segment induction: continue the copy (Winogrande-ish).
    Copy,
    /// Long-range needle retrieval (PIQA-slot analogue).
    Retrieve,
    /// Likely vs unlikely bigram tail (ARC-C analogue).
    Bigram,
}

impl ProbeTask {
    /// All tasks, in Table-2 column order.
    pub const ALL: [ProbeTask; 6] = [
        ProbeTask::Cloze,
        ProbeTask::Accept,
        ProbeTask::Rank,
        ProbeTask::Copy,
        ProbeTask::Retrieve,
        ProbeTask::Bigram,
    ];

    /// Stable display name.
    pub fn name(&self) -> &'static str {
        match self {
            ProbeTask::Cloze => "cloze",
            ProbeTask::Accept => "accept",
            ProbeTask::Rank => "rank",
            ProbeTask::Copy => "copy",
            ProbeTask::Retrieve => "retrieve",
            ProbeTask::Bigram => "bigram",
        }
    }
}

/// One multiple-choice item: pick the candidate continuation with the
/// highest conditional log-likelihood after `context`.
#[derive(Clone, Debug)]
pub struct ProbeItem {
    pub context: Vec<u16>,
    pub candidates: Vec<Vec<u16>>,
    pub answer: usize,
}

/// Generate `n` items of a task from the grammar (deterministic in
/// `seed`).
pub fn probe_items(task: ProbeTask, text: &SynthText, n: usize, seed: u64) -> Vec<ProbeItem> {
    let mut rng = Pcg64::seed_stream(seed, 0x9B0B + task as u64);
    let vocab = crate::data::text::VOCAB;
    let stream = text.generate(TextSplit::C4s, n * 96 + 256).tokens;
    let probs = text.transition(TextSplit::C4s);
    let mut items = Vec::with_capacity(n);
    for i in 0..n {
        let base = i * 96;
        let ctx_len = 24;
        let context: Vec<u16> = stream[base..base + ctx_len].to_vec();
        let next = stream[base + ctx_len];
        let item = match task {
            ProbeTask::Cloze => {
                // 3 distractors drawn from the *unlikely* successors.
                let prev = context[ctx_len - 1] as usize;
                let mut cands = vec![vec![next]];
                while cands.len() < 4 {
                    let d = rng.below(vocab) as u16;
                    if d != next && probs[prev * vocab + d as usize] < 0.02 {
                        cands.push(vec![d]);
                    }
                }
                shuffle_item(cands, &mut rng)
            }
            ProbeTask::Accept => {
                let real: Vec<u16> = stream[base + ctx_len..base + ctx_len + 8].to_vec();
                let mut fake = real.clone();
                rng.shuffle(&mut fake);
                if fake == real {
                    fake.rotate_left(1);
                }
                shuffle_item(vec![real, fake], &mut rng)
            }
            ProbeTask::Rank => {
                let real: Vec<u16> = stream[base + ctx_len..base + ctx_len + 8].to_vec();
                // Foil: a real-looking continuation of a *different*
                // context further along the stream.
                let foil: Vec<u16> = stream[base + 60..base + 68].to_vec();
                shuffle_item(vec![real, foil], &mut rng)
            }
            ProbeTask::Copy => {
                // context = [seg, seg[..m]] — the answer continues the
                // copy; the foil is a grammar-plausible token instead.
                let seg: Vec<u16> = stream[base..base + 12].to_vec();
                let m = 6;
                let mut context: Vec<u16> = seg.clone();
                context.extend_from_slice(&seg[..m]);
                let answer_tok = seg[m];
                let prev = context[context.len() - 1] as usize;
                let mut foil = answer_tok;
                for cand in 0..vocab as u16 {
                    if cand != answer_tok && probs[prev * vocab + cand as usize] > 0.05 {
                        foil = cand;
                        break;
                    }
                }
                if foil == answer_tok {
                    foil = (answer_tok + 1) % vocab as u16;
                }
                let cands = shuffle_item(vec![vec![answer_tok], vec![foil]], &mut rng);
                items.push(ProbeItem {
                    context,
                    candidates: cands.0,
                    answer: cands.1,
                });
                continue;
            }
            ProbeTask::Retrieve => {
                // Needle token early in a long context; candidates are
                // the needle vs a token never seen in context.
                let needle = stream[base + 1];
                let context: Vec<u16> = stream[base..base + 40].to_vec();
                let mut foil = 0u16;
                for cand in 0..vocab as u16 {
                    if !context.contains(&cand) {
                        foil = cand;
                        break;
                    }
                }
                let cands = shuffle_item(vec![vec![needle], vec![foil]], &mut rng);
                items.push(ProbeItem { context, candidates: cands.0, answer: cands.1 });
                continue;
            }
            ProbeTask::Bigram => {
                // Likely bigram tail vs unlikely bigram tail.
                let prev = context[ctx_len - 1] as usize;
                let (mut hi, mut hi_p) = (0usize, -1.0f32);
                let (mut lo, mut lo_p) = (0usize, 2.0f32);
                for candidate in 0..vocab {
                    let p = probs[prev * vocab + candidate];
                    if p > hi_p {
                        hi_p = p;
                        hi = candidate;
                    }
                    if p < lo_p {
                        lo_p = p;
                        lo = candidate;
                    }
                }
                let hi2 = likely_next(&probs, hi, vocab);
                let lo2 = likely_next(&probs, lo, vocab);
                shuffle_item(
                    vec![vec![hi as u16, hi2 as u16], vec![lo as u16, lo2 as u16]],
                    &mut rng,
                )
            }
        };
        items.push(ProbeItem { context, candidates: item.0, answer: item.1 });
    }
    items
}

/// All six probe tasks' items concatenated in [`ProbeTask::ALL`] order
/// — the aggregate suite whose accuracy `grail tune --eval` reports
/// before/after executing a searched plan. Deterministic in `seed`.
pub fn probe_suite(text: &SynthText, per_task: usize, seed: u64) -> Vec<ProbeItem> {
    ProbeTask::ALL
        .iter()
        .flat_map(|&t| probe_items(t, text, per_task, seed))
        .collect()
}

fn likely_next(probs: &[f32], tok: usize, vocab: usize) -> usize {
    (0..vocab)
        .max_by(|&a, &b| probs[tok * vocab + a].total_cmp(&probs[tok * vocab + b]))
        .unwrap_or(0)
}

/// Shuffle candidates, returning `(candidates, index_of_true_answer)`
/// (the true answer enters at index 0).
fn shuffle_item(mut cands: Vec<Vec<u16>>, rng: &mut Pcg64) -> (Vec<Vec<u16>>, usize) {
    let mut order: Vec<usize> = (0..cands.len()).collect();
    rng.shuffle(&mut order);
    let answer = order.iter().position(|&o| o == 0).unwrap();
    let mut out = Vec::with_capacity(cands.len());
    for &o in &order {
        out.push(std::mem::take(&mut cands[o]));
    }
    (out, answer)
}

/// Conditional log-likelihood of `continuation` after `context`.
pub fn continuation_logprob(model: &TinyLm, context: &[u16], continuation: &[u16]) -> f64 {
    let mut seq: Vec<u16> = context.to_vec();
    seq.extend_from_slice(continuation);
    assert!(seq.len() <= model.cfg.max_seq, "probe sequence too long");
    let t = seq.len() - 1;
    let batch = LmBatch {
        inputs: seq[..t].to_vec(),
        targets: seq[1..].to_vec(),
        b: 1,
        t,
    };
    let mut logits = model.forward(&batch);
    log_softmax_rows(&mut logits);
    // Sum log p of the continuation tokens only.
    let start = context.len() - 1; // row predicting continuation[0]
    let mut total = 0.0f64;
    for (j, &tok) in continuation.iter().enumerate() {
        total += logits.at2(start + j, tok as usize) as f64;
    }
    total
}

/// Accuracy of a model on a set of probe items. Items are independent
/// single-sequence forwards, so they fan out over the scheduler's
/// worker threads; the correct-count is order-insensitive, keeping the
/// result bit-identical to a sequential evaluation.
pub fn probe_accuracy(model: &TinyLm, items: &[ProbeItem]) -> f64 {
    let threads = crate::coordinator::scheduler::default_threads();
    let jobs: Vec<usize> = (0..items.len()).collect();
    let hits = crate::coordinator::scheduler::run_grid(jobs, threads, |_, &idx| {
        let item = &items[idx];
        let scores: Vec<f64> = item
            .candidates
            .iter()
            .map(|c| continuation_logprob(model, &item.context, c))
            .collect();
        let best = scores
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap_or(0);
        best == item.answer
    });
    let correct = hits.iter().filter(|&&h| h).count();
    correct as f64 / items.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::models::{LmConfig, TinyLm};

    #[test]
    fn items_are_wellformed_and_deterministic() {
        let text = SynthText::new(4);
        for task in ProbeTask::ALL {
            let a = probe_items(task, &text, 8, 1);
            let b = probe_items(task, &text, 8, 1);
            assert_eq!(a.len(), 8, "{task:?}");
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.context, y.context);
                assert_eq!(x.answer, y.answer);
                assert!(x.answer < x.candidates.len());
                assert!(!x.candidates.is_empty());
                // All sequences fit the default model context.
                assert!(x.context.len() + x.candidates[0].len() <= 64);
            }
        }
    }

    #[test]
    fn answers_are_shuffled() {
        let text = SynthText::new(4);
        let items = probe_items(ProbeTask::Cloze, &text, 32, 2);
        let first_answers: Vec<usize> = items.iter().map(|i| i.answer).collect();
        assert!(first_answers.iter().any(|&a| a != first_answers[0]));
    }

    #[test]
    fn continuation_logprob_is_additive() {
        let mut rng = Pcg64::seed(1);
        let m = TinyLm::init(LmConfig { n_layers: 1, ..Default::default() }, &mut rng);
        let ctx = vec![1u16, 2, 3, 4];
        // log p(a,b|ctx) = log p(a|ctx) + log p(b|ctx,a)
        let ab = continuation_logprob(&m, &ctx, &[7, 9]);
        let a = continuation_logprob(&m, &ctx, &[7]);
        let mut ctx_a = ctx.clone();
        ctx_a.push(7);
        let b = continuation_logprob(&m, &ctx_a, &[9]);
        assert!((ab - (a + b)).abs() < 1e-4, "{ab} vs {}", a + b);
    }

    #[test]
    fn untrained_model_near_chance() {
        let mut rng = Pcg64::seed(2);
        let m = TinyLm::init(LmConfig { n_layers: 1, ..Default::default() }, &mut rng);
        let text = SynthText::new(4);
        let items = probe_items(ProbeTask::Cloze, &text, 24, 3);
        let acc = probe_accuracy(&m, &items);
        assert!(acc < 0.8, "untrained acc={acc} suspiciously high");
    }

    #[test]
    fn suite_concatenates_all_tasks_deterministically() {
        let text = SynthText::new(4);
        let a = probe_suite(&text, 4, 9);
        let b = probe_suite(&text, 4, 9);
        assert_eq!(a.len(), 6 * 4);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.context, y.context);
            assert_eq!(x.candidates, y.candidates);
            assert_eq!(x.answer, y.answer);
        }
        // The per-task prefix matches the standalone generator.
        let cloze = probe_items(ProbeTask::Cloze, &text, 4, 9);
        assert_eq!(a[0].context, cloze[0].context);
    }

    #[test]
    fn task_names_unique() {
        let names: std::collections::HashSet<_> =
            ProbeTask::ALL.iter().map(|t| t.name()).collect();
        assert_eq!(names.len(), 6);
    }
}
