//! Evaluation: classification accuracy, LM perplexity, and the
//! zero-shot probe-task suite (the Table 2 substitute).

pub mod metrics;
pub mod probes;

pub use metrics::{accuracy_from_logits, lm_perplexity, nll_from_logits, vision_accuracy};
