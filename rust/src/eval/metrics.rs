//! Core evaluation metrics: classification accuracy and language-model
//! perplexity.

use crate::data::{TokenSet, VisionSet};
use crate::nn::models::{LmBatch, TinyLm};
use crate::nn::{argmax_rows, log_softmax_rows};
use crate::tensor::Tensor;

/// Top-1 accuracy of logits against labels.
pub fn accuracy_from_logits(logits: &Tensor, labels: &[u16]) -> f64 {
    assert_eq!(logits.dim(0), labels.len(), "one row per label");
    if labels.is_empty() {
        return 0.0;
    }
    let pred = argmax_rows(logits);
    let correct = pred.iter().zip(labels).filter(|(p, y)| **p == **y as usize).count();
    correct as f64 / labels.len() as f64
}

/// Accuracy of a vision model (anything exposing `forward`) on a set,
/// evaluated in mini-batches to bound memory.
pub fn vision_accuracy<F>(forward: F, set: &VisionSet, batch: usize) -> f64
where
    F: Fn(&Tensor) -> Tensor,
{
    let mut correct = 0usize;
    let mut total = 0usize;
    let mut start = 0;
    while start < set.len() {
        let chunk = set.slice(start, batch);
        let logits = forward(&chunk.x);
        let pred = argmax_rows(&logits);
        correct += pred.iter().zip(&chunk.y).filter(|(p, y)| **p == **y as usize).count();
        total += chunk.len();
        start += batch;
    }
    correct as f64 / total.max(1) as f64
}

/// Mean negative log-likelihood (nats/token) of a logits matrix
/// against target ids.
pub fn nll_from_logits(logits: &Tensor, targets: &[u16]) -> f64 {
    assert_eq!(logits.dim(0), targets.len());
    let mut ls = logits.clone();
    log_softmax_rows(&mut ls);
    let mut total = 0.0f64;
    for (i, &t) in targets.iter().enumerate() {
        total -= ls.at2(i, t as usize) as f64;
    }
    total / targets.len().max(1) as f64
}

/// Perplexity of a TinyLm on a token stream, windowed at `seq_len`,
/// processed `batch_windows` windows at a time.
pub fn lm_perplexity(
    model: &TinyLm,
    tokens: &TokenSet,
    seq_len: usize,
    max_windows: usize,
    batch_windows: usize,
) -> f64 {
    let windows = tokens.windows(seq_len, max_windows);
    assert!(!windows.is_empty(), "token stream too short for seq_len {seq_len}");
    let mut total_nll = 0.0f64;
    let mut total_tok = 0usize;
    for chunk in windows.chunks(batch_windows) {
        let batch = LmBatch::from_windows(chunk);
        let logits = model.forward(&batch);
        total_nll += nll_from_logits(&logits, &batch.targets) * batch.targets.len() as f64;
        total_tok += batch.targets.len();
    }
    (total_nll / total_tok.max(1) as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{SynthText, TextSplit};
    use crate::nn::models::LmConfig;
    use crate::rng::Pcg64;

    #[test]
    fn accuracy_counts() {
        let logits = Tensor::from_vec(&[3, 2], vec![1., 0., 0., 1., 1., 0.]);
        let acc = accuracy_from_logits(&logits, &[0, 1, 1]);
        assert!((acc - 2.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn batched_accuracy_matches_full() {
        let mut rng = Pcg64::seed(1);
        let set = crate::data::SynthVision::new(1).generate(30);
        let m = crate::nn::models::MlpNet::init(768, 16, 10, &mut rng);
        let full = accuracy_from_logits(&m.forward(&set.x), &set.y);
        let batched = vision_accuracy(|x| m.forward(x), &set, 7);
        assert!((full - batched).abs() < 1e-9);
    }

    #[test]
    fn nll_of_uniform_logits_is_log_v() {
        let logits = Tensor::zeros(&[5, 8]);
        let nll = nll_from_logits(&logits, &[0, 1, 2, 3, 4]);
        assert!((nll - (8.0f64).ln()).abs() < 1e-5);
    }

    #[test]
    fn perplexity_of_untrained_lm_near_vocab() {
        // An untrained model's ppl should be within a small factor of
        // the vocab size (uniform ≈ 64).
        let mut rng = Pcg64::seed(2);
        let m = TinyLm::init(LmConfig { n_layers: 1, ..Default::default() }, &mut rng);
        let ts = SynthText::new(1).generate(TextSplit::C4s, 600);
        let ppl = lm_perplexity(&m, &ts, 16, 8, 4);
        assert!(ppl > 20.0 && ppl < 220.0, "ppl={ppl}");
    }

    #[test]
    fn perplexity_batching_invariant() {
        let mut rng = Pcg64::seed(3);
        let m = TinyLm::init(LmConfig { n_layers: 1, ..Default::default() }, &mut rng);
        let ts = SynthText::new(2).generate(TextSplit::Wt2s, 600);
        let a = lm_perplexity(&m, &ts, 16, 8, 1);
        let b = lm_perplexity(&m, &ts, 16, 8, 8);
        assert!((a - b).abs() / a < 1e-5, "{a} vs {b}");
    }
}
