//! Minimal benchmarking harness (no `criterion` offline).
//!
//! `cargo bench` runs the `[[bench]]` targets with `harness = false`;
//! they call [`bench`] which warms up, runs timed iterations, and
//! prints a stable `name  median  p10  p90  iters` line (plus optional
//! throughput).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Global layer-forward counter. Every block-level segment execution in
/// the model families increments it (see `Compressible::site_tap` /
/// `forward_segment` impls), which is how tests and benches verify the
/// closed-loop pipeline performs O(L) — not O(L²) — layer forwards.
static LAYER_FORWARDS: AtomicU64 = AtomicU64::new(0);

/// Record one block-level forward execution.
#[inline]
pub fn count_layer_forward() {
    LAYER_FORWARDS.fetch_add(1, Ordering::Relaxed);
}

/// Reset the global layer-forward counter to zero.
pub fn layer_forwards_reset() {
    LAYER_FORWARDS.store(0, Ordering::Relaxed);
}

/// Current value of the global layer-forward counter.
pub fn layer_forwards() -> u64 {
    LAYER_FORWARDS.load(Ordering::Relaxed)
}

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
    pub iters: usize,
}

impl BenchResult {
    /// Throughput in operations/sec given work per iteration.
    pub fn per_second(&self, work_per_iter: f64) -> f64 {
        work_per_iter / (self.median_ns / 1e9)
    }
}

/// Percentile `p ∈ [0, 1]` of an ascending-sorted sample set, with
/// linear interpolation between adjacent order statistics (the
/// "type 7" estimator). This is the *single* percentile definition for
/// every bench target: the previous state of the world had two — a
/// truncating index here and a rounding index in `benches/serve.rs` —
/// which disagreed on the same data and biased p99 low on small
/// samples (on 10 samples, truncation turned "p99" into p0 of the top
/// decile).
///
/// Panics on an empty slice, `p` outside `[0, 1]`, or unsorted input —
/// a silent garbage percentile must not make it into a trajectory
/// file.
pub fn pct(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty(), "pct of an empty sample set");
    assert!((0.0..=1.0).contains(&p), "percentile {p} outside [0, 1]");
    debug_assert!(
        sorted.windows(2).all(|w| w[0] <= w[1]),
        "pct input must be ascending-sorted"
    );
    let idx = (sorted.len() - 1) as f64 * p;
    let lo = idx.floor() as usize;
    let frac = idx - lo as f64;
    if frac == 0.0 {
        sorted[lo]
    } else {
        sorted[lo] + frac * (sorted[lo + 1] - sorted[lo])
    }
}

/// Time `f` adaptively: warm up, then run enough iterations to spend
/// ~`budget_ms`, reporting percentile stats over per-iteration times.
pub fn bench<T>(name: &str, budget_ms: u64, mut f: impl FnMut() -> T) -> BenchResult {
    // Warmup + calibration.
    let t0 = Instant::now();
    std::hint::black_box(f());
    let once = t0.elapsed().as_nanos().max(1) as f64;
    let target_iters = ((budget_ms as f64 * 1e6) / once).clamp(3.0, 10_000.0) as usize;

    let mut samples = Vec::with_capacity(target_iters);
    for _ in 0..target_iters {
        let t = Instant::now();
        std::hint::black_box(f());
        samples.push(t.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.total_cmp(b));
    let r = BenchResult {
        name: name.to_string(),
        median_ns: pct(&samples, 0.5),
        p10_ns: pct(&samples, 0.1),
        p90_ns: pct(&samples, 0.9),
        iters: samples.len(),
    };
    println!(
        "{:<44} median {:>12}  p10 {:>12}  p90 {:>12}  ({} iters)",
        r.name,
        fmt_ns(r.median_ns),
        fmt_ns(r.p10_ns),
        fmt_ns(r.p90_ns),
        r.iters
    );
    r
}

/// Collects measurements and derived metrics for a machine-readable
/// `BENCH_*.json` trajectory file. Shared by every `[[bench]]` target
/// so they all ship the same schema shape:
/// `{"schema": ..., "benches": [...], "metrics": [...]}`.
///
/// Fails loudly: [`Recorder::write_json`] panics if nothing was
/// recorded or the file cannot be written, so a bench that silently
/// skipped its measurements (the way `BENCH_hotpath.json` once shipped
/// empty arrays) fails CI instead of committing an empty trajectory.
#[derive(Default)]
pub struct Recorder {
    benches: Vec<BenchResult>,
    metrics: Vec<(String, f64)>,
}

impl Recorder {
    /// Record one measurement.
    pub fn push(&mut self, r: &BenchResult) {
        self.benches.push(r.clone());
    }

    /// Record one derived metric (a speedup, a ratio, a throughput).
    pub fn metric(&mut self, name: &str, value: f64) {
        assert!(value.is_finite(), "metric {name} is not finite: {value}");
        self.metrics.push((name.to_string(), value));
    }

    /// Write the trajectory file. Panics if the recorder is empty
    /// (benches *and* metrics) or the write fails — an empty or
    /// missing trajectory must never look like success.
    pub fn write_json(&self, path: &str, schema: &str) {
        assert!(
            !self.benches.is_empty() && !self.metrics.is_empty(),
            "Recorder for {path} has {} benches and {} metrics — a bench target must \
             record measurements and derived metrics before writing its trajectory",
            self.benches.len(),
            self.metrics.len()
        );
        let mut s = format!("{{\n  \"schema\": \"{schema}\",\n  \"benches\": [\n");
        for (i, b) in self.benches.iter().enumerate() {
            let sep = if i + 1 < self.benches.len() { "," } else { "" };
            s.push_str(&format!(
                "    {{\"name\": \"{}\", \"median_ns\": {:.1}, \"p10_ns\": {:.1}, \
                 \"p90_ns\": {:.1}, \"iters\": {}}}{sep}\n",
                b.name, b.median_ns, b.p10_ns, b.p90_ns, b.iters
            ));
        }
        s.push_str("  ],\n  \"metrics\": [\n");
        for (i, (name, value)) in self.metrics.iter().enumerate() {
            let sep = if i + 1 < self.metrics.len() { "," } else { "" };
            s.push_str(&format!("    {{\"name\": \"{name}\", \"value\": {value}}}{sep}\n"));
        }
        s.push_str("  ]\n}\n");
        if let Err(e) = std::fs::write(path, &s) {
            panic!("could not write {path}: {e}");
        }
        println!("\nwrote {path}");
    }
}

/// Human-readable nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Print a GFLOP/s line for a measured kernel.
pub fn report_gflops(r: &BenchResult, flops_per_iter: f64) {
    println!(
        "{:<44} {:.2} GFLOP/s",
        format!("{} throughput", r.name),
        r.per_second(flops_per_iter) / 1e9
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop-ish", 5, || {
            let mut s = 0u64;
            for i in 0..100u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert!(r.median_ns > 0.0);
        assert!(r.p10_ns <= r.median_ns && r.median_ns <= r.p90_ns);
        assert!(r.iters >= 3);
    }

    #[test]
    fn pct_interpolates_between_order_statistics() {
        let s = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(pct(&s, 0.0), 10.0);
        assert_eq!(pct(&s, 1.0), 40.0);
        assert_eq!(pct(&s, 0.5), 25.0);
        // idx = 3 * 0.25 = 0.75 → 10 + 0.75 * 10.
        assert!((pct(&s, 0.25) - 17.5).abs() < 1e-12);
        // Single sample: every percentile is that sample.
        assert_eq!(pct(&[7.0], 0.0), 7.0);
        assert_eq!(pct(&[7.0], 0.99), 7.0);
        assert_eq!(pct(&[7.0], 1.0), 7.0);
    }

    #[test]
    fn pct_p99_not_biased_low_on_small_samples() {
        // 10 samples 0..90: the old truncating definition returned
        // s[(9 * 0.99) as usize] = s[8] = 80 — p99 reported as p89.
        // The interpolating estimator lands between s[8] and s[9].
        let s: Vec<f64> = (0..10).map(|i| (i * 10) as f64).collect();
        let p99 = pct(&s, 0.99);
        assert!((p99 - 89.1).abs() < 1e-9, "{p99}");
        // And the rounding definition from the old serve bench
        // (s[round(idx)] = s[9] = 90) disagreed with it; both now
        // route through this one function.
        assert!(p99 > 80.0 && p99 < 90.0);
    }

    #[test]
    #[should_panic(expected = "empty sample set")]
    fn pct_rejects_empty() {
        pct(&[], 0.5);
    }

    #[test]
    #[should_panic(expected = "outside [0, 1]")]
    fn pct_rejects_out_of_range() {
        pct(&[1.0], 1.5);
    }

    #[test]
    fn forward_counter_counts() {
        layer_forwards_reset();
        let before = layer_forwards();
        count_layer_forward();
        count_layer_forward();
        assert!(layer_forwards() >= before + 2);
    }

    #[test]
    #[should_panic(expected = "must record measurements")]
    fn empty_recorder_refuses_to_write() {
        Recorder::default().write_json("/tmp/grail_recorder_empty_test.json", "test-v0");
    }

    #[test]
    #[should_panic(expected = "must record measurements")]
    fn recorder_without_metrics_refuses_to_write() {
        let mut rec = Recorder::default();
        rec.push(&BenchResult {
            name: "x".into(),
            median_ns: 1.0,
            p10_ns: 1.0,
            p90_ns: 1.0,
            iters: 3,
        });
        rec.write_json("/tmp/grail_recorder_nometrics_test.json", "test-v0");
    }

    #[test]
    fn recorder_writes_schema_and_entries() {
        let mut rec = Recorder::default();
        rec.push(&BenchResult {
            name: "k".into(),
            median_ns: 2.5,
            p10_ns: 2.0,
            p90_ns: 3.0,
            iters: 7,
        });
        rec.metric("speedup", 2.0);
        let path = "/tmp/grail_recorder_roundtrip_test.json";
        rec.write_json(path, "test-v1");
        let s = std::fs::read_to_string(path).unwrap();
        assert!(s.contains("\"schema\": \"test-v1\""));
        assert!(s.contains("\"name\": \"k\""));
        assert!(s.contains("\"name\": \"speedup\""));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn fmt_ns_ranges() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert!(fmt_ns(5_000.0).contains("µs"));
        assert!(fmt_ns(5_000_000.0).contains("ms"));
        assert!(fmt_ns(5e9).contains(" s"));
    }
}
