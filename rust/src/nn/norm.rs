//! Normalization layers: LayerNorm (transformers) and BatchNorm2d
//! (CNNs; evaluation mode uses running statistics, and REPAIR resets
//! them from calibration data).

use super::{Tensor, NORM_EPS};

/// Layer normalization over the last dimension.
#[derive(Clone, Debug)]
pub struct LayerNorm {
    pub gamma: Tensor,
    pub beta: Tensor,
}

impl LayerNorm {
    /// Unit-gain layer norm of width `d`.
    pub fn new(d: usize) -> Self {
        LayerNorm { gamma: Tensor::full(&[d], 1.0), beta: Tensor::zeros(&[d]) }
    }

    /// Scalar parameter count (gamma + beta).
    pub fn param_count(&self) -> usize {
        self.gamma.len() + self.beta.len()
    }

    /// Normalized width.
    pub fn dim(&self) -> usize {
        self.gamma.dim(0)
    }

    /// Forward over `[n, d]`, in place.
    pub fn forward_inplace(&self, x: &mut Tensor) {
        let (n, d) = (x.dim(0), x.dim(1));
        assert_eq!(d, self.dim(), "layernorm width");
        let g = self.gamma.data();
        let b = self.beta.data();
        for i in 0..n {
            let row = &mut x.data_mut()[i * d..(i + 1) * d];
            let mean: f32 = row.iter().sum::<f32>() / d as f32;
            let var: f32 = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / d as f32;
            let inv = 1.0 / (var + NORM_EPS).sqrt();
            for (j, v) in row.iter_mut().enumerate() {
                *v = (*v - mean) * inv * g[j] + b[j];
            }
        }
    }

    /// Forward returning a new tensor.
    pub fn forward(&self, x: &Tensor) -> Tensor {
        let mut y = x.clone();
        self.forward_inplace(&mut y);
        y
    }
}

/// BatchNorm over channels of `[n, c, h, w]` activations, evaluation
/// mode (running statistics). Channel-indexable for structured pruning
/// and recomputable for REPAIR.
#[derive(Clone, Debug)]
pub struct BatchNorm2d {
    pub gamma: Tensor,
    pub beta: Tensor,
    pub running_mean: Tensor,
    pub running_var: Tensor,
}

impl BatchNorm2d {
    /// Identity-initialized batch norm over `c` channels.
    pub fn new(c: usize) -> Self {
        BatchNorm2d {
            gamma: Tensor::full(&[c], 1.0),
            beta: Tensor::zeros(&[c]),
            running_mean: Tensor::zeros(&[c]),
            running_var: Tensor::full(&[c], 1.0),
        }
    }

    /// Scalar parameter count (gamma + beta + running stats — the
    /// tensors a checkpoint carries).
    pub fn param_count(&self) -> usize {
        self.gamma.len() + self.beta.len() + self.running_mean.len() + self.running_var.len()
    }

    /// Number of channels.
    pub fn channels(&self) -> usize {
        self.gamma.dim(0)
    }

    /// Forward in place on `[n, c*h*w]` data laid out CHW with `hw`
    /// spatial elements per channel.
    pub fn forward_inplace(&self, x: &mut Tensor, hw: usize) {
        let c = self.channels();
        let (n, d) = (x.dim(0), x.dim(1));
        assert_eq!(d, c * hw, "batchnorm channel layout");
        let g = self.gamma.data();
        let b = self.beta.data();
        let mu = self.running_mean.data();
        let var = self.running_var.data();
        let scale: Vec<f32> =
            (0..c).map(|j| g[j] / (var[j] + NORM_EPS).sqrt()).collect();
        let shift: Vec<f32> = (0..c).map(|j| b[j] - mu[j] * scale[j]).collect();
        for i in 0..n {
            let row = &mut x.data_mut()[i * d..(i + 1) * d];
            for j in 0..c {
                let (s, t) = (scale[j], shift[j]);
                for v in &mut row[j * hw..(j + 1) * hw] {
                    *v = *v * s + t;
                }
            }
        }
    }

    /// Keep only channels `idx`.
    pub fn select_channels(&mut self, idx: &[usize]) {
        let pick = |t: &Tensor| {
            let v: Vec<f32> = idx.iter().map(|&i| t.data()[i]).collect();
            Tensor::from_vec(&[idx.len()], v)
        };
        self.gamma = pick(&self.gamma);
        self.beta = pick(&self.beta);
        self.running_mean = pick(&self.running_mean);
        self.running_var = pick(&self.running_var);
    }

    /// Fold channels by cluster averaging.
    pub fn fold_channels(&mut self, assign: &[usize], k_total: usize) {
        let fold = |t: &Tensor| {
            let mut out = vec![0.0f32; k_total];
            let mut counts = vec![0usize; k_total];
            for (h, &k) in assign.iter().enumerate() {
                out[k] += t.data()[h];
                counts[k] += 1;
            }
            for k in 0..k_total {
                out[k] /= counts[k].max(1) as f32;
            }
            Tensor::from_vec(&[k_total], out)
        };
        self.gamma = fold(&self.gamma);
        self.beta = fold(&self.beta);
        self.running_mean = fold(&self.running_mean);
        self.running_var = fold(&self.running_var);
    }

    /// REPAIR: overwrite running statistics with the empirical mean /
    /// variance of pre-norm activations `x: [n, c*hw]` (CHW layout).
    pub fn recompute_stats(&mut self, x: &Tensor, hw: usize) {
        let c = self.channels();
        let (n, d) = (x.dim(0), x.dim(1));
        assert_eq!(d, c * hw);
        let count = (n * hw) as f64;
        for j in 0..c {
            let mut s = 0.0f64;
            let mut s2 = 0.0f64;
            for i in 0..n {
                for &v in &x.data()[i * d + j * hw..i * d + (j + 1) * hw] {
                    s += v as f64;
                    s2 += (v as f64) * (v as f64);
                }
            }
            let mean = s / count;
            let var = (s2 / count - mean * mean).max(0.0);
            self.running_mean.data_mut()[j] = mean as f32;
            self.running_var.data_mut()[j] = var as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn layernorm_zero_mean_unit_var() {
        let mut rng = Pcg64::seed(1);
        let mut x = Tensor::zeros(&[4, 16]);
        rng.fill_normal(x.data_mut(), 3.0);
        let ln = LayerNorm::new(16);
        ln.forward_inplace(&mut x);
        for i in 0..4 {
            let row = x.row(i);
            let mean: f32 = row.iter().sum::<f32>() / 16.0;
            let var: f32 = row.iter().map(|v| (v - mean).powi(2)).sum::<f32>() / 16.0;
            assert!(mean.abs() < 1e-4);
            assert!((var - 1.0).abs() < 1e-2);
        }
    }

    #[test]
    fn layernorm_gain_bias() {
        let mut ln = LayerNorm::new(2);
        ln.gamma = Tensor::from_vec(&[2], vec![2.0, 2.0]);
        ln.beta = Tensor::from_vec(&[2], vec![1.0, 1.0]);
        let x = Tensor::from_vec(&[1, 2], vec![-1.0, 1.0]);
        let y = ln.forward(&x);
        // normalized = [-1, 1] (up to eps), scaled+shifted = [-1, 3].
        assert!((y.at2(0, 0) + 1.0).abs() < 1e-2);
        assert!((y.at2(0, 1) - 3.0).abs() < 1e-2);
    }

    #[test]
    fn batchnorm_identity_with_matching_stats() {
        let mut bn = BatchNorm2d::new(2);
        bn.running_mean = Tensor::from_vec(&[2], vec![1.0, -1.0]);
        bn.running_var = Tensor::from_vec(&[2], vec![4.0, 0.25]);
        // x with those exact stats per channel maps to ~N(0,1).
        let x = Tensor::from_vec(&[1, 4], vec![3.0, -1.0, -0.5, -1.5]); // hw=2
        let mut y = x.clone();
        bn.forward_inplace(&mut y, 2);
        assert!((y.at2(0, 0) - 1.0).abs() < 1e-3); // (3-1)/2
        assert!((y.at2(0, 1) + 1.0).abs() < 1e-3);
        assert!((y.at2(0, 2) - 1.0).abs() < 1e-3); // (-0.5+1)/0.5
        assert!((y.at2(0, 3) + 1.0).abs() < 1e-3);
    }

    #[test]
    fn recompute_stats_then_normalizes() {
        let mut rng = Pcg64::seed(5);
        let mut x = Tensor::zeros(&[32, 3 * 8]);
        rng.fill_normal(x.data_mut(), 2.0);
        for v in x.data_mut().iter_mut() {
            *v += 5.0;
        }
        let mut bn = BatchNorm2d::new(3);
        bn.recompute_stats(&x, 8);
        let mut y = x.clone();
        bn.forward_inplace(&mut y, 8);
        let mean: f32 = y.data().iter().sum::<f32>() / y.len() as f32;
        assert!(mean.abs() < 1e-3, "mean={mean}");
    }

    #[test]
    fn select_and_fold_channels() {
        let mut bn = BatchNorm2d::new(3);
        bn.running_mean = Tensor::from_vec(&[3], vec![1., 2., 3.]);
        let mut sel = bn.clone();
        sel.select_channels(&[2, 0]);
        assert_eq!(sel.running_mean.data(), &[3., 1.]);
        bn.fold_channels(&[0, 0, 1], 2);
        assert_eq!(bn.running_mean.data(), &[1.5, 3.0]);
    }
}
