//! Neural-network layers and models (Rust-side inference).
//!
//! The Rust forward passes are the variable-shape engine: compressed
//! models have data-dependent widths, so they cannot run through the
//! fixed-shape PJRT artifacts. Layer math mirrors the JAX definitions
//! in `python/compile/model.py` exactly (same GELU approximation, same
//! layer-norm epsilon) — `rust/tests/runtime_pjrt.rs` asserts the two
//! implementations agree on identical weights.

pub mod attention;
pub mod conv;
pub mod linear;
pub mod models;
pub mod norm;
pub mod weights;

pub use attention::MultiHeadAttention;
pub use conv::{BatchNorm2d, Conv2d};
pub use linear::{Activation, Linear};
pub use norm::LayerNorm;

use crate::tensor::Tensor;

/// Shared layer-norm / batch-norm epsilon (matches the Python side).
pub const NORM_EPS: f32 = 1e-5;

/// ReLU, elementwise.
pub fn relu(x: &mut Tensor) {
    x.map_inplace(|v| v.max(0.0));
}

/// GELU with the tanh approximation (matches `jax.nn.gelu`'s default
/// `approximate=True`).
pub fn gelu(x: &mut Tensor) {
    x.map_inplace(gelu_scalar);
}

/// Scalar tanh-approximate GELU.
#[inline]
pub fn gelu_scalar(v: f32) -> f32 {
    const C: f32 = 0.7978845608028654; // sqrt(2/pi)
    0.5 * v * (1.0 + (C * (v + 0.044715 * v * v * v)).tanh())
}

/// Row-wise softmax in place.
pub fn softmax_rows(x: &mut Tensor) {
    let (m, n) = (x.dim(0), x.dim(1));
    for i in 0..m {
        let row = &mut x.data_mut()[i * n..(i + 1) * n];
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut z = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - mx).exp();
            z += *v;
        }
        let inv = 1.0 / z;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

/// Row-wise log-softmax in place (numerically stable; used for NLL /
/// perplexity).
pub fn log_softmax_rows(x: &mut Tensor) {
    let (m, n) = (x.dim(0), x.dim(1));
    for i in 0..m {
        let row = &mut x.data_mut()[i * n..(i + 1) * n];
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let z: f32 = row.iter().map(|v| (v - mx).exp()).sum();
        let lz = mx + z.ln();
        for v in row.iter_mut() {
            *v -= lz;
        }
    }
}

/// Row-wise argmax.
pub fn argmax_rows(x: &Tensor) -> Vec<usize> {
    (0..x.dim(0))
        .map(|i| {
            x.row(i)
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(j, _)| j)
                .unwrap_or(0)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relu_clamps() {
        let mut t = Tensor::from_vec(&[4], vec![-1., 0., 2., -3.]);
        relu(&mut t);
        assert_eq!(t.data(), &[0., 0., 2., 0.]);
    }

    #[test]
    fn gelu_known_values() {
        // gelu(0) = 0; gelu(large) ≈ identity; gelu(-large) ≈ 0.
        assert_eq!(gelu_scalar(0.0), 0.0);
        assert!((gelu_scalar(10.0) - 10.0).abs() < 1e-3);
        assert!(gelu_scalar(-10.0).abs() < 1e-3);
        // Reference value from jax.nn.gelu(1.0) ≈ 0.841192.
        assert!((gelu_scalar(1.0) - 0.841192).abs() < 1e-4);
    }

    #[test]
    fn softmax_rows_normalize() {
        let mut t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 1000., 1000., 1000.]);
        softmax_rows(&mut t);
        for i in 0..2 {
            let s: f32 = t.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
        // Large-but-equal logits -> uniform, no NaN.
        assert!((t.at2(1, 0) - 1.0 / 3.0).abs() < 1e-5);
    }

    #[test]
    fn log_softmax_matches_softmax() {
        let mut a = Tensor::from_vec(&[1, 4], vec![0.3, -1.0, 2.0, 0.0]);
        let mut b = a.clone();
        softmax_rows(&mut a);
        log_softmax_rows(&mut b);
        for j in 0..4 {
            assert!((a.at2(0, j).ln() - b.at2(0, j)).abs() < 1e-5);
        }
    }

    #[test]
    fn argmax_picks_max() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 5., 2., 9., 0., 3.]);
        assert_eq!(argmax_rows(&t), vec![1, 0]);
    }
}
