//! Fully connected layer.

use super::Tensor;
use crate::rng::Pcg64;
use crate::tensor::gemm::{self, Epilogue, PackedB};
use crate::tensor::ops;

/// An activation fused into [`Linear::forward_act`]'s GEMM epilogue.
///
/// The fused forward is bit-identical to the unfused GEMM + `add_bias`
/// + activation-sweep sequence (see [`Epilogue`]), so model forwards
/// can adopt it without perturbing any calibration or conformance
/// result.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Activation {
    /// No activation — plain `x Wᵀ + b`.
    Identity,
    /// `max(·, 0)`, matching [`crate::nn::relu`].
    Relu,
    /// The tanh-approximation GELU, matching [`crate::nn::gelu`].
    Gelu,
}

/// `y = x Wᵀ + b` with `W: [out, in]`, `b: [out]`.
///
/// Weights are stored `[out, in]` so that each *row* is one output
/// unit: structured pruning of the layer's outputs is a row selection,
/// matching the paper's `W'_{i-1} = W_{i-1}[P, :]`.
#[derive(Clone, Debug)]
pub struct Linear {
    pub w: Tensor,
    pub b: Tensor,
}

impl Linear {
    /// He-initialized layer (used by pure-Rust tests; real checkpoints
    /// come from the Python training step).
    pub fn init(out_dim: usize, in_dim: usize, rng: &mut Pcg64) -> Self {
        let std = (2.0 / in_dim as f32).sqrt();
        let mut w = Tensor::zeros(&[out_dim, in_dim]);
        rng.fill_normal(w.data_mut(), std);
        Linear { w, b: Tensor::zeros(&[out_dim]) }
    }

    /// Output width.
    pub fn out_dim(&self) -> usize {
        self.w.dim(0)
    }

    /// Scalar parameter count (weights + bias).
    pub fn param_count(&self) -> usize {
        self.w.len() + self.b.len()
    }

    /// Input width.
    pub fn in_dim(&self) -> usize {
        self.w.dim(1)
    }

    /// Forward over a batch `[n, in] -> [n, out]`: one fused pass (the
    /// bias rides the GEMM epilogue).
    pub fn forward(&self, x: &Tensor) -> Tensor {
        self.forward_act(x, Activation::Identity)
    }

    /// Forward with the following activation fused into the GEMM
    /// epilogue: one pass over the output instead of GEMM + `add_bias`
    /// + an activation sweep. Dispatches on the row-count-free serving
    /// rule ([`gemm::use_packed_cols`]), so a 1-row decode call takes
    /// the same kernel — and produces the same bits — as a multi-row
    /// forward through the same layer.
    pub fn forward_act(&self, x: &Tensor, act: Activation) -> Tensor {
        assert_eq!(x.dim(1), self.in_dim(), "linear input width");
        let (m, k, n) = (x.dim(0), self.in_dim(), self.out_dim());
        let mut y = Tensor::zeros(&[m, n]);
        ops::gemm_nt_serve(x.data(), self.w.data(), y.data_mut(), m, k, n, self.epilogue(act));
        y
    }

    fn epilogue(&self, act: Activation) -> Epilogue<'_> {
        match act {
            Activation::Identity => Epilogue::Bias(self.b.data()),
            Activation::Relu => Epilogue::BiasRelu(self.b.data()),
            Activation::Gelu => Epilogue::BiasGelu(self.b.data()),
        }
    }

    /// Prepack the weight operand for repeated serving calls. Returns
    /// `Some` exactly when the serving dispatch takes the packed path
    /// for this layer's `(in, out)` shape, so
    /// [`Self::forward_prepacked`] stays bit-identical to
    /// [`Self::forward_act`] on either side of the threshold.
    pub fn prepack(&self) -> Option<PackedB> {
        if gemm::use_packed_cols(self.in_dim(), self.out_dim()) {
            Some(PackedB::pack_nt(self.w.data(), self.in_dim(), self.out_dim()))
        } else {
            None
        }
    }

    /// [`Self::forward_act`] against a weight operand prepacked by
    /// [`Self::prepack`] on this same layer — skips the per-call B
    /// packing that dominates single-row decode GEMMs.
    pub fn forward_prepacked(&self, pb: Option<&PackedB>, x: &Tensor, act: Activation) -> Tensor {
        let Some(pb) = pb else {
            return self.forward_act(x, act);
        };
        assert_eq!(x.dim(1), self.in_dim(), "linear input width");
        assert_eq!(pb.k(), self.in_dim(), "prepacked weight is stale");
        assert_eq!(pb.n(), self.out_dim(), "prepacked weight is stale");
        let m = x.dim(0);
        let mut y = Tensor::zeros(&[m, self.out_dim()]);
        gemm::gemm_nt_prepacked(x.data(), pb, y.data_mut(), m, self.epilogue(act), 0);
        y
    }

    /// Keep only output rows `idx` (structured output pruning).
    pub fn select_outputs(&mut self, idx: &[usize]) {
        self.w = ops::gather_rows(&self.w, idx);
        let b: Vec<f32> = idx.iter().map(|&i| self.b.data()[i]).collect();
        self.b = Tensor::from_vec(&[idx.len()], b);
    }

    /// Fold output rows by cluster averaging: `assign[h] = k` maps each
    /// output unit to one of `k_total` centroids.
    pub fn fold_outputs(&mut self, assign: &[usize], k_total: usize) {
        assert_eq!(assign.len(), self.out_dim());
        let in_dim = self.in_dim();
        let mut w = Tensor::zeros(&[k_total, in_dim]);
        let mut b = vec![0.0f32; k_total];
        let mut counts = vec![0usize; k_total];
        for (h, &k) in assign.iter().enumerate() {
            assert!(k < k_total);
            counts[k] += 1;
            for (dst, &src) in w.row_mut(k).iter_mut().zip(self.w.row(h)) {
                *dst += src;
            }
            b[k] += self.b.data()[h];
        }
        for k in 0..k_total {
            let c = counts[k].max(1) as f32;
            for v in w.row_mut(k) {
                *v /= c;
            }
            b[k] /= c;
        }
        self.w = w;
        self.b = Tensor::from_vec(&[k_total], b);
    }

    /// Replace the input side with `W·B` (absorb a reconstruction map
    /// `B: [in, k]` — the GRAIL consumer merge `W'_i = W_i B`).
    pub fn merge_input_map(&mut self, b_map: &Tensor) {
        assert_eq!(b_map.dim(0), self.in_dim(), "B rows must match consumer input width");
        self.w = ops::matmul(&self.w, b_map);
    }

    /// Keep only input columns `idx` (the uncompensated consumer update
    /// that classic pruning applies).
    pub fn select_inputs(&mut self, idx: &[usize]) {
        self.w = ops::gather_cols(&self.w, idx);
    }

    /// Per-input-column L2 norms (selector scoring).
    pub fn input_col_norms(&self) -> Vec<f32> {
        ops::col_l2(&self.w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layer() -> Linear {
        // 3 outputs, 2 inputs.
        Linear {
            w: Tensor::from_vec(&[3, 2], vec![1., 0., 0., 1., 1., 1.]),
            b: Tensor::from_vec(&[3], vec![0.5, -0.5, 0.0]),
        }
    }

    #[test]
    fn forward_math() {
        let l = layer();
        let x = Tensor::from_vec(&[1, 2], vec![2., 3.]);
        let y = l.forward(&x);
        assert_eq!(y.data(), &[2.5, 2.5, 5.0]);
    }

    #[test]
    fn fused_activation_matches_unfused_sweeps_bitwise() {
        let mut rng = Pcg64::seed(7);
        // One shape on each side of the serving threshold
        // (8·16 = 128 < PACKED_MIN_COLS ≤ 64·64).
        for &(m, ind, out) in &[(5usize, 8usize, 16usize), (9, 64, 64)] {
            let l = Linear::init(out, ind, &mut rng);
            let mut x = Tensor::zeros(&[m, ind]);
            rng.fill_normal(x.data_mut(), 1.0);
            for act in [Activation::Identity, Activation::Relu, Activation::Gelu] {
                let fused = l.forward_act(&x, act);
                // Unfused oracle: the same serve GEMM, then separate
                // bias and activation sweeps.
                let mut y = Tensor::zeros(&[m, out]);
                ops::gemm_nt_serve(
                    x.data(),
                    l.w.data(),
                    y.data_mut(),
                    m,
                    ind,
                    out,
                    Epilogue::None,
                );
                ops::add_bias(&mut y, l.b.data());
                match act {
                    Activation::Identity => {}
                    Activation::Relu => crate::nn::relu(&mut y),
                    Activation::Gelu => crate::nn::gelu(&mut y),
                }
                for (f, u) in fused.data().iter().zip(y.data()) {
                    assert_eq!(f.to_bits(), u.to_bits(), "{act:?} ({m},{ind},{out})");
                }
                // Prepacked forward must match too, on both sides of
                // the threshold (prepack is None below it).
                let pre = l.forward_prepacked(l.prepack().as_ref(), &x, act);
                for (p, f) in pre.data().iter().zip(fused.data()) {
                    assert_eq!(p.to_bits(), f.to_bits(), "prepacked {act:?}");
                }
            }
        }
    }

    #[test]
    fn select_outputs_keeps_rows() {
        let mut l = layer();
        l.select_outputs(&[2, 0]);
        assert_eq!(l.out_dim(), 2);
        assert_eq!(l.w.row(0), &[1., 1.]);
        assert_eq!(l.b.data(), &[0.0, 0.5]);
    }

    #[test]
    fn fold_outputs_averages() {
        let mut l = layer();
        l.fold_outputs(&[0, 0, 1], 2);
        assert_eq!(l.out_dim(), 2);
        assert_eq!(l.w.row(0), &[0.5, 0.5]); // mean of rows 0,1
        assert_eq!(l.w.row(1), &[1., 1.]);
        assert_eq!(l.b.data(), &[0.0, 0.0]);
    }

    #[test]
    fn merge_input_map_shrinks_inputs() {
        let mut l = layer();
        // B maps a single reduced input back to the two originals.
        let b = Tensor::from_vec(&[2, 1], vec![1.0, 2.0]);
        l.merge_input_map(&b);
        assert_eq!(l.in_dim(), 1);
        assert_eq!(l.w.data(), &[1., 2., 3.]);
    }

    #[test]
    fn select_inputs_matches_identity_merge() {
        let mut a = layer();
        let mut b = layer();
        a.select_inputs(&[1]);
        let m = Tensor::from_vec(&[2, 1], vec![0.0, 1.0]);
        b.merge_input_map(&m);
        assert_eq!(a.w, b.w);
    }
}
