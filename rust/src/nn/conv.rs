//! 2-D convolution via im2col + GEMM (re-exports [`BatchNorm2d`] from
//! the norm module for CNN call sites).

pub use super::norm::BatchNorm2d;
use super::Tensor;
use crate::rng::Pcg64;
use crate::tensor::ops;

/// Convolution layer. Weights `[o, c, kh, kw]`, activations `[n, c*h*w]`
/// flattened CHW. Same-padding is explicit via `pad`.
#[derive(Clone, Debug)]
pub struct Conv2d {
    /// Kernel, stored as a 4-D tensor `[o, c, kh, kw]`.
    pub w: Tensor,
    /// Bias per output channel.
    pub b: Tensor,
    pub stride: usize,
    pub pad: usize,
}

impl Conv2d {
    /// He-initialized conv (Rust-side tests; checkpoints come from
    /// Python).
    pub fn init(o: usize, c: usize, k: usize, stride: usize, pad: usize, rng: &mut Pcg64) -> Self {
        let std = (2.0 / (c * k * k) as f32).sqrt();
        let mut w = Tensor::zeros(&[o, c, k, k]);
        rng.fill_normal(w.data_mut(), std);
        Conv2d { w, b: Tensor::zeros(&[o]), stride, pad }
    }

    pub fn out_channels(&self) -> usize {
        self.w.dim(0)
    }

    /// Scalar parameter count (kernel + bias).
    pub fn param_count(&self) -> usize {
        self.w.len() + self.b.len()
    }

    pub fn in_channels(&self) -> usize {
        self.w.dim(1)
    }

    pub fn kernel(&self) -> (usize, usize) {
        (self.w.dim(2), self.w.dim(3))
    }

    /// Spatial output size for an input of `h×w`.
    pub fn out_hw(&self, h: usize, w: usize) -> (usize, usize) {
        let (kh, kw) = self.kernel();
        (
            (h + 2 * self.pad - kh) / self.stride + 1,
            (w + 2 * self.pad - kw) / self.stride + 1,
        )
    }

    /// im2col: expand `[n, c*h*w]` into patch rows
    /// `[n*oh*ow, c*kh*kw]`.
    pub fn im2col(&self, x: &Tensor, h: usize, w: usize) -> Tensor {
        let c = self.in_channels();
        let (kh, kw) = self.kernel();
        let (oh, ow) = self.out_hw(h, w);
        let n = x.dim(0);
        assert_eq!(x.dim(1), c * h * w, "conv input layout");
        let mut cols = Tensor::zeros(&[n * oh * ow, c * kh * kw]);
        let xd = x.data();
        let pad = self.pad as isize;
        let stride = self.stride;
        for i in 0..n {
            let base = i * c * h * w;
            for oy in 0..oh {
                for ox in 0..ow {
                    let row_idx = (i * oh + oy) * ow + ox;
                    let dst = cols.row_mut(row_idx);
                    for cc in 0..c {
                        for ky in 0..kh {
                            let sy = oy as isize * stride as isize + ky as isize - pad;
                            for kx in 0..kw {
                                let sx = ox as isize * stride as isize + kx as isize - pad;
                                let v = if sy >= 0 && sy < h as isize && sx >= 0 && sx < w as isize
                                {
                                    xd[base + cc * h * w + sy as usize * w + sx as usize]
                                } else {
                                    0.0
                                };
                                dst[(cc * kh + ky) * kw + kx] = v;
                            }
                        }
                    }
                }
            }
        }
        cols
    }

    /// Forward: `[n, c*h*w] -> [n, o*oh*ow]` (CHW layout). The im2col
    /// GEMM (`ops::matmul_nt`) dispatches to the packed engine
    /// ([`crate::tensor::gemm`]) at conv-block shapes.
    ///
    /// Perf pass note (EXPERIMENTS.md §Perf): an image-chunked im2col
    /// variant was tried and reverted — the monolithic buffer stays
    /// within LLC at these geometries and chunking only added copy +
    /// dispatch overhead.
    pub fn forward(&self, x: &Tensor, h: usize, w: usize) -> Tensor {
        let n = x.dim(0);
        let o = self.out_channels();
        let (oh, ow) = self.out_hw(h, w);
        let cols = self.im2col(x, h, w); // [n*oh*ow, c*kh*kw]
        let wmat = self.weight_matrix(); // [o, c*kh*kw]
        let y = ops::matmul_nt(&cols, &wmat); // [n*oh*ow, o]
        // Rearrange to [n, o, oh, ow] and add bias: channel-outer loop
        // gives contiguous writes (strided reads hit the LLC line
        // already brought in by the GEMM).
        let mut out = Tensor::zeros(&[n, o * oh * ow]);
        let yd = y.data();
        let bd = self.b.data();
        let hw = oh * ow;
        for i in 0..n {
            let dst = out.row_mut(i);
            for ch in 0..o {
                let b = bd[ch];
                let drow = &mut dst[ch * hw..(ch + 1) * hw];
                for (s, dv) in drow.iter_mut().enumerate() {
                    *dv = yd[(i * hw + s) * o + ch] + b;
                }
            }
        }
        out
    }

    /// The kernel viewed as a 2-D matrix `[o, c*kh*kw]`.
    pub fn weight_matrix(&self) -> Tensor {
        let (o, c) = (self.out_channels(), self.in_channels());
        let (kh, kw) = self.kernel();
        Tensor::from_vec(&[o, c * kh * kw], self.w.data().to_vec())
    }

    /// Keep output channels `idx` (producer narrowing).
    pub fn select_outputs(&mut self, idx: &[usize]) {
        let (c, kh, kw) = (self.in_channels(), self.kernel().0, self.kernel().1);
        let sz = c * kh * kw;
        let mut w = Tensor::zeros(&[idx.len(), c, kh, kw]);
        for (dst, &src) in idx.iter().enumerate() {
            assert!(src < self.out_channels());
            w.data_mut()[dst * sz..(dst + 1) * sz]
                .copy_from_slice(&self.w.data()[src * sz..(src + 1) * sz]);
        }
        self.w = w;
        let b: Vec<f32> = idx.iter().map(|&i| self.b.data()[i]).collect();
        self.b = Tensor::from_vec(&[idx.len()], b);
    }

    /// Fold output channels by cluster averaging.
    pub fn fold_outputs(&mut self, assign: &[usize], k_total: usize) {
        let (c, kh, kw) = (self.in_channels(), self.kernel().0, self.kernel().1);
        let sz = c * kh * kw;
        assert_eq!(assign.len(), self.out_channels());
        let mut w = Tensor::zeros(&[k_total, c, kh, kw]);
        let mut b = vec![0.0f32; k_total];
        let mut counts = vec![0usize; k_total];
        for (h, &k) in assign.iter().enumerate() {
            counts[k] += 1;
            for (dv, &sv) in w.data_mut()[k * sz..(k + 1) * sz]
                .iter_mut()
                .zip(&self.w.data()[h * sz..(h + 1) * sz])
            {
                *dv += sv;
            }
            b[k] += self.b.data()[h];
        }
        for k in 0..k_total {
            let cnt = counts[k].max(1) as f32;
            for v in &mut w.data_mut()[k * sz..(k + 1) * sz] {
                *v /= cnt;
            }
            b[k] /= cnt;
        }
        self.w = w;
        self.b = Tensor::from_vec(&[k_total], b);
    }

    /// GRAIL conv merge: apply the reconstruction map `B: [c, k]` along
    /// the *input channel* axis —
    /// `W'(o,k,:,:) = Σ_c W(o,c,:,:) B(c,k)` (paper §3.1).
    pub fn merge_input_map(&mut self, b_map: &Tensor) {
        let (o, c) = (self.out_channels(), self.in_channels());
        let (kh, kw) = self.kernel();
        assert_eq!(b_map.dim(0), c, "B rows must match conv in-channels");
        let k = b_map.dim(1);
        let mut w = Tensor::zeros(&[o, k, kh, kw]);
        let src = self.w.data();
        let dst = w.data_mut();
        for oo in 0..o {
            for cc in 0..c {
                let s_base = (oo * c + cc) * kh * kw;
                for kk in 0..k {
                    let scale = b_map.at2(cc, kk);
                    if scale == 0.0 {
                        continue;
                    }
                    let d_base = (oo * k + kk) * kh * kw;
                    for t in 0..kh * kw {
                        dst[d_base + t] += scale * src[s_base + t];
                    }
                }
            }
        }
        self.w = w;
    }

    /// Keep input channels `idx` (uncompensated consumer update).
    pub fn select_inputs(&mut self, idx: &[usize]) {
        let (o, c) = (self.out_channels(), self.in_channels());
        let (kh, kw) = self.kernel();
        let mut w = Tensor::zeros(&[o, idx.len(), kh, kw]);
        for oo in 0..o {
            for (dst_c, &src_c) in idx.iter().enumerate() {
                assert!(src_c < c);
                let s = (oo * c + src_c) * kh * kw;
                let d = (oo * idx.len() + dst_c) * kh * kw;
                w.data_mut()[d..d + kh * kw].copy_from_slice(&self.w.data()[s..s + kh * kw]);
            }
        }
        self.w = w;
    }

    /// Per-input-channel L2 norm over `(o, kh, kw)` (selector scoring).
    pub fn input_col_norms(&self) -> Vec<f32> {
        let (o, c) = (self.out_channels(), self.in_channels());
        let (kh, kw) = self.kernel();
        let mut acc = vec![0.0f64; c];
        for oo in 0..o {
            for cc in 0..c {
                for &v in &self.w.data()[(oo * c + cc) * kh * kw..(oo * c + cc + 1) * kh * kw] {
                    acc[cc] += (v as f64) * (v as f64);
                }
            }
        }
        acc.iter().map(|v| v.sqrt() as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Direct (naive) convolution for cross-checking.
    fn conv_ref(conv: &Conv2d, x: &Tensor, h: usize, w: usize) -> Tensor {
        let n = x.dim(0);
        let (o, c) = (conv.out_channels(), conv.in_channels());
        let (kh, kw) = conv.kernel();
        let (oh, ow) = conv.out_hw(h, w);
        let mut out = Tensor::zeros(&[n, o * oh * ow]);
        for i in 0..n {
            for ch in 0..o {
                for oy in 0..oh {
                    for ox in 0..ow {
                        let mut s = conv.b.data()[ch];
                        for cc in 0..c {
                            for ky in 0..kh {
                                for kx in 0..kw {
                                    let sy = (oy * conv.stride + ky) as isize - conv.pad as isize;
                                    let sx = (ox * conv.stride + kx) as isize - conv.pad as isize;
                                    if sy >= 0 && sy < h as isize && sx >= 0 && sx < w as isize {
                                        let xv = x.data()
                                            [i * c * h * w + cc * h * w + sy as usize * w + sx as usize];
                                        let wv = conv.w.data()
                                            [((ch * c + cc) * kh + ky) * kw + kx];
                                        s += xv * wv;
                                    }
                                }
                            }
                        }
                        out.data_mut()[i * o * oh * ow + ch * oh * ow + oy * ow + ox] = s;
                    }
                }
            }
        }
        out
    }

    #[test]
    fn forward_matches_reference() {
        let mut rng = crate::rng::Pcg64::seed(1);
        for &(stride, pad) in &[(1usize, 1usize), (2, 1), (1, 0)] {
            let conv = Conv2d::init(4, 3, 3, stride, pad, &mut rng);
            let mut x = Tensor::zeros(&[2, 3 * 8 * 8]);
            rng.fill_normal(x.data_mut(), 1.0);
            let y = conv.forward(&x, 8, 8);
            let yr = conv_ref(&conv, &x, 8, 8);
            assert!(y.max_abs_diff(&yr) < 1e-4, "stride={stride} pad={pad}");
        }
    }

    #[test]
    fn identity_kernel_passthrough() {
        // 1x1 conv with identity weights returns the input.
        let mut conv = Conv2d { w: Tensor::zeros(&[2, 2, 1, 1]), b: Tensor::zeros(&[2]), stride: 1, pad: 0 };
        conv.w.data_mut()[0] = 1.0; // (0,0)
        conv.w.data_mut()[3] = 1.0; // (1,1)
        let x = Tensor::from_vec(&[1, 2 * 2 * 2], vec![1., 2., 3., 4., 5., 6., 7., 8.]);
        let y = conv.forward(&x, 2, 2);
        assert_eq!(y.data(), x.data());
    }

    #[test]
    fn select_outputs_drops_channels() {
        let mut rng = crate::rng::Pcg64::seed(2);
        let mut conv = Conv2d::init(4, 2, 3, 1, 1, &mut rng);
        let x = {
            let mut t = Tensor::zeros(&[1, 2 * 6 * 6]);
            rng.fill_normal(t.data_mut(), 1.0);
            t
        };
        let full = conv.forward(&x, 6, 6);
        conv.select_outputs(&[3, 1]);
        let sel = conv.forward(&x, 6, 6);
        // Channel 0 of sel equals channel 3 of full.
        let hw = 36;
        assert_eq!(&sel.data()[0..hw], &full.data()[3 * hw..4 * hw]);
        assert_eq!(&sel.data()[hw..2 * hw], &full.data()[hw..2 * hw]);
    }

    #[test]
    fn merge_identity_is_noop() {
        let mut rng = crate::rng::Pcg64::seed(3);
        let mut conv = Conv2d::init(3, 4, 3, 1, 1, &mut rng);
        let orig = conv.w.clone();
        conv.merge_input_map(&Tensor::eye(4));
        assert!(conv.w.max_abs_diff(&orig) < 1e-6);
    }

    #[test]
    fn merge_selection_matches_select_inputs() {
        let mut rng = crate::rng::Pcg64::seed(4);
        let conv = Conv2d::init(3, 5, 3, 1, 1, &mut rng);
        let idx = [4usize, 0, 2];
        let mut a = conv.clone();
        a.select_inputs(&idx);
        let mut m = Tensor::zeros(&[5, 3]);
        for (k, &i) in idx.iter().enumerate() {
            m.set2(i, k, 1.0);
        }
        let mut b = conv.clone();
        b.merge_input_map(&m);
        assert!(a.w.max_abs_diff(&b.w) < 1e-6);
    }

    #[test]
    fn fold_outputs_centroid() {
        let mut rng = crate::rng::Pcg64::seed(5);
        let mut conv = Conv2d::init(4, 2, 1, 1, 0, &mut rng);
        let w0 = conv.w.data()[0 * 2..1 * 2].to_vec();
        let w2 = conv.w.data()[2 * 2..3 * 2].to_vec();
        conv.fold_outputs(&[0, 1, 0, 1], 2);
        assert_eq!(conv.out_channels(), 2);
        // First centroid is the mean of original channels 0 and 2.
        for j in 0..2 {
            let want = (w0[j] + w2[j]) / 2.0;
            assert!((conv.w.data()[j] - want).abs() < 1e-6);
        }
    }

    #[test]
    fn out_hw_arithmetic() {
        let conv = Conv2d { w: Tensor::zeros(&[1, 1, 3, 3]), b: Tensor::zeros(&[1]), stride: 2, pad: 1 };
        assert_eq!(conv.out_hw(16, 16), (8, 8));
        let c2 = Conv2d { w: Tensor::zeros(&[1, 1, 3, 3]), b: Tensor::zeros(&[1]), stride: 1, pad: 1 };
        assert_eq!(c2.out_hw(16, 16), (16, 16));
    }
}
