//! Three-layer MLP classifier — the simplest compression target and
//! the unit-test workhorse for the dense-block math of paper §3.1.

use crate::compress::{Compressible, ReductionPlan, Reducer, SiteInfo, SiteKind};
use crate::nn::weights::WeightBundle;
use crate::nn::{Activation, Linear};
use crate::rng::Pcg64;
use crate::tensor::Tensor;
use anyhow::Result;

/// `x -> relu(fc1) -> relu(fc2) -> head` classifier.
#[derive(Clone, Debug)]
pub struct MlpNet {
    pub fc1: Linear,
    pub fc2: Linear,
    pub head: Linear,
}

impl MlpNet {
    /// Random-initialized network.
    pub fn init(in_dim: usize, hidden: usize, classes: usize, rng: &mut Pcg64) -> Self {
        MlpNet {
            fc1: Linear::init(hidden, in_dim, rng),
            fc2: Linear::init(hidden, hidden, rng),
            head: Linear::init(classes, hidden, rng),
        }
    }

    /// Logits for a batch `[n, in_dim]`.
    pub fn forward(&self, x: &Tensor) -> Tensor {
        self.forward_with_taps(x).0
    }

    /// Logits plus consumer-input activations per site:
    /// `taps[0]` = input of `fc2`, `taps[1]` = input of `head`.
    pub fn forward_with_taps(&self, x: &Tensor) -> (Tensor, Vec<Tensor>) {
        let h1 = self.fc1.forward_act(x, Activation::Relu);
        let h2 = self.fc2.forward_act(&h1, Activation::Relu);
        let y = self.head.forward(&h2);
        (y, vec![h1, h2])
    }

    /// Serialize all parameters.
    pub fn to_bundle(&self) -> WeightBundle {
        let mut b = WeightBundle::new();
        for (name, l) in [("fc1", &self.fc1), ("fc2", &self.fc2), ("head", &self.head)] {
            b.insert(&format!("{name}.w"), l.w.clone());
            b.insert(&format!("{name}.b"), l.b.clone());
        }
        b
    }

    /// Load from a bundle (shapes inferred from the stored tensors).
    pub fn from_bundle(b: &WeightBundle) -> Result<Self> {
        let lin = |name: &str| -> Result<Linear> {
            let w = b.get(&format!("{name}.w"))?.clone();
            let bias = b.get(&format!("{name}.b"))?.clone();
            anyhow::ensure!(w.ndim() == 2 && bias.ndim() == 1, "{name}: bad ranks");
            anyhow::ensure!(w.dim(0) == bias.dim(0), "{name}: w/b mismatch");
            Ok(Linear { w, b: bias })
        };
        Ok(MlpNet { fc1: lin("fc1")?, fc2: lin("fc2")?, head: lin("head")? })
    }
}

/// Segment-executor state: the input of the current site's producer
/// (`x` for site 0, `relu(fc1·x)` for site 1).
#[derive(Clone, Debug)]
pub struct MlpCalibState {
    cur: Tensor,
}

impl Compressible for MlpNet {
    type Input = Tensor;
    type CalibState = MlpCalibState;

    fn calib_begin(&self, input: &Tensor) -> MlpCalibState {
        MlpCalibState { cur: input.clone() }
    }

    fn site_tap(&self, state: &mut MlpCalibState, site: usize) -> Tensor {
        crate::bench_util::count_layer_forward();
        let p = if site == 0 { &self.fc1 } else { &self.fc2 };
        p.forward_act(&state.cur, Activation::Relu)
    }

    fn forward_segment(&self, state: &mut MlpCalibState, from_site: usize, to_site: usize) {
        for s in from_site..to_site {
            crate::bench_util::count_layer_forward();
            let p = if s == 0 { &self.fc1 } else { &self.fc2 };
            state.cur = p.forward_act(&state.cur, Activation::Relu);
        }
    }

    fn split_input(&self, input: &Tensor, max_shards: usize) -> Vec<Tensor> {
        crate::tensor::ops::split_rows(input, max_shards)
    }

    fn param_count(&self) -> usize {
        self.fc1.param_count() + self.fc2.param_count() + self.head.param_count()
    }

    fn sites(&self) -> Vec<SiteInfo> {
        vec![
            SiteInfo {
                id: "fc1>fc2".into(),
                units: self.fc1.out_dim(),
                unit_dim: 1,
                groups: 1,
                kind: SiteKind::Dense,
            },
            SiteInfo {
                id: "fc2>head".into(),
                units: self.fc2.out_dim(),
                unit_dim: 1,
                groups: 1,
                kind: SiteKind::Dense,
            },
        ]
    }

    fn producer_row_norm(&self, site: usize, ord: u8) -> Vec<f32> {
        let p = if site == 0 { &self.fc1 } else { &self.fc2 };
        row_norms(&p.w, ord)
    }

    fn producer_features(&self, site: usize) -> Tensor {
        let p = if site == 0 { &self.fc1 } else { &self.fc2 };
        p.w.clone()
    }

    fn consumer_col_norms(&self, site: usize) -> Vec<f32> {
        let c = if site == 0 { &self.fc2 } else { &self.head };
        c.input_col_norms()
    }

    fn consumer_matrix(&self, site: usize) -> Tensor {
        let c = if site == 0 { &self.fc2 } else { &self.head };
        c.w.clone()
    }

    fn apply(&mut self, site: usize, plan: &ReductionPlan) {
        let (producer, consumer) = if site == 0 {
            (&mut self.fc1, &mut self.fc2)
        } else {
            (&mut self.fc2, &mut self.head)
        };
        apply_dense_pair(producer, consumer, plan);
    }
}

/// Per-row L1/L2 norms of a weight matrix.
pub(crate) fn row_norms(w: &Tensor, ord: u8) -> Vec<f32> {
    (0..w.dim(0))
        .map(|i| {
            let row = w.row(i);
            match ord {
                1 => row.iter().map(|v| v.abs()).sum(),
                2 => row.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>().sqrt() as f32,
                _ => panic!("row_norms: ord must be 1 or 2"),
            }
        })
        .collect()
}

/// Shared producer/consumer update for dense pairs (also used by the
/// ViT/LM MLP sites).
pub(crate) fn apply_dense_pair(producer: &mut Linear, consumer: &mut Linear, plan: &ReductionPlan) {
    let h = producer.out_dim();
    // 1. Narrow the producer.
    match &plan.reducer {
        Reducer::Select(idx) => producer.select_outputs(idx),
        Reducer::Fold { assign, k } => producer.fold_outputs(assign, *k),
    }
    // 2. Update the consumer: override ≻ compensation ≻ data-free.
    if let Some(w) = &plan.consumer_override {
        assert_eq!(w.dim(0), consumer.out_dim(), "override rows");
        assert_eq!(w.dim(1), plan.reducer.k(), "override cols");
        consumer.w = w.clone();
    } else if let Some(b_map) = &plan.compensation {
        consumer.merge_input_map(b_map);
    } else {
        consumer.merge_input_map(&plan.reducer.consumer_matrix(h));
    }
    // 3. Optional bias correction.
    if let Some(delta) = &plan.bias_delta {
        assert_eq!(delta.len(), consumer.out_dim(), "bias delta length");
        for (b, d) in consumer.b.data_mut().iter_mut().zip(delta) {
            *b += d;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Reducer;

    fn net() -> MlpNet {
        let mut rng = Pcg64::seed(11);
        MlpNet::init(12, 16, 4, &mut rng)
    }

    fn batch(n: usize) -> Tensor {
        let mut rng = Pcg64::seed(99);
        let mut x = Tensor::zeros(&[n, 12]);
        rng.fill_normal(x.data_mut(), 1.0);
        x
    }

    #[test]
    fn forward_shapes_and_taps() {
        let m = net();
        let x = batch(5);
        let (y, taps) = m.forward_with_taps(&x);
        assert_eq!(y.shape(), &[5, 4]);
        assert_eq!(taps[0].shape(), &[5, 16]);
        assert_eq!(taps[1].shape(), &[5, 16]);
        // Taps are post-ReLU: non-negative.
        assert!(taps[0].data().iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn bundle_roundtrip() {
        let m = net();
        let b = m.to_bundle();
        let r = MlpNet::from_bundle(&b).unwrap();
        let x = batch(3);
        assert!(m.forward(&x).max_abs_diff(&r.forward(&x)) < 1e-7);
    }

    #[test]
    fn prune_site0_changes_width() {
        let mut m = net();
        let keep: Vec<usize> = (0..8).collect();
        m.apply(0, &ReductionPlan::bare(Reducer::Select(keep)));
        assert_eq!(m.fc1.out_dim(), 8);
        assert_eq!(m.fc2.in_dim(), 8);
        let y = m.forward(&batch(2));
        assert_eq!(y.shape(), &[2, 4]);
        assert!(y.all_finite());
    }

    #[test]
    fn identity_fold_preserves_function() {
        // Folding H units into H singleton clusters is a no-op.
        let mut m = net();
        let x = batch(4);
        let y0 = m.forward(&x);
        m.apply(1, &ReductionPlan::bare(Reducer::Fold { assign: (0..16).collect(), k: 16 }));
        let y1 = m.forward(&x);
        assert!(y0.max_abs_diff(&y1) < 1e-5);
    }

    #[test]
    fn full_selection_preserves_function() {
        let mut m = net();
        let x = batch(4);
        let y0 = m.forward(&x);
        m.apply(0, &ReductionPlan::bare(Reducer::Select((0..16).collect())));
        assert!(y0.max_abs_diff(&m.forward(&x)) < 1e-6);
    }

    #[test]
    fn duplicate_units_fold_losslessly() {
        // Make units 0 and 1 of fc1 identical; folding them together
        // with the data-free consumer update is exactly lossless.
        let mut m = net();
        let r0 = m.fc1.w.row(0).to_vec();
        m.fc1.w.row_mut(1).copy_from_slice(&r0);
        let b0 = m.fc1.b.data()[0];
        m.fc1.b.data_mut()[1] = b0;
        let x = batch(6);
        let y0 = m.forward(&x);
        // Units 0,1 -> cluster 0; unit h>=2 -> cluster h-1.
        let assign: Vec<usize> = (0..16usize).map(|h| h.saturating_sub(1)).collect();
        m.apply(0, &ReductionPlan::bare(Reducer::Fold { assign, k: 15 }));
        let y1 = m.forward(&x);
        assert!(y0.max_abs_diff(&y1) < 1e-4);
        assert_eq!(m.fc1.out_dim(), 15);
    }

    #[test]
    fn bias_delta_applied() {
        let mut m = net();
        let before = m.head.b.data().to_vec();
        let plan = ReductionPlan {
            reducer: Reducer::Select((0..16).collect()),
            compensation: None,
            bias_delta: Some(vec![1.0; 4]),
            consumer_override: None,
        };
        m.apply(1, &plan);
        for (a, b) in m.head.b.data().iter().zip(&before) {
            assert!((a - b - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn staged_taps_match_forward_with_taps() {
        let m = net();
        let x = batch(6);
        let (_, taps) = m.forward_with_taps(&x);
        for site in 0..2 {
            let staged = m.site_activations(&x, site);
            assert_eq!(staged, taps[site], "site {site}");
        }
    }

    #[test]
    fn split_input_rejoins() {
        let m = net();
        let x = batch(7);
        let shards = m.split_input(&x, 3);
        assert_eq!(shards.len(), 3);
        let total: usize = shards.iter().map(|s| s.dim(0)).sum();
        assert_eq!(total, 7);
    }

    #[test]
    fn consumer_override_wins() {
        let mut m = net();
        let w = Tensor::full(&[4, 3], 0.25);
        let plan = ReductionPlan {
            reducer: Reducer::Select(vec![0, 5, 9]),
            compensation: Some(Tensor::eye(16)), // would be wrong; must be ignored
            bias_delta: None,
            consumer_override: Some(w.clone()),
        };
        m.apply(1, &plan);
        assert_eq!(m.head.w, w);
        assert_eq!(m.fc2.out_dim(), 3);
    }
}
