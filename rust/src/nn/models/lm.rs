//! TinyLm — the repro stand-in for LLaMA-2-7B (decoder-only, pre-LN).
//!
//! Exercises everything paper §3.2 needs: head-structured attention
//! reduction (plain MHA and GQA with the block-diagonal constraint),
//! MLP fc/proj pairs, consumer-input Gram sampling at the `w_o` and
//! `w_proj` inputs, and sequential closed-loop compensation over depth.

use crate::compress::{Compressible, ReductionPlan, Reducer, SiteInfo, SiteKind};
use crate::coordinator::scheduler::{audit::WriteSet, default_threads, run_grid_mut};
use crate::data::TokenSet;
use crate::nn::attention::{attend_cached, attend_paged, gather_block, scatter_block};
use crate::nn::weights::WeightBundle;
use crate::nn::{argmax_rows, Activation, LayerNorm, Linear, MultiHeadAttention};
use crate::rng::Pcg64;
use crate::serve::batch::KvPagePool;
use crate::tensor::gemm::PackedB;
use crate::tensor::{ops, Tensor};
use anyhow::Result;

use super::vit::{pull_attn, pull_lin, pull_ln, push_attn, push_lin, push_ln};

/// Architecture hyper-parameters.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LmConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    /// KV heads; `== n_heads` for plain MHA, a divisor for GQA.
    pub n_kv: usize,
    pub d_ff: usize,
    pub n_layers: usize,
    pub max_seq: usize,
}

impl Default for LmConfig {
    fn default() -> Self {
        LmConfig {
            vocab: crate::data::text::VOCAB,
            d_model: 64,
            n_heads: 8,
            n_kv: 8,
            d_ff: 192,
            n_layers: 4,
            max_seq: 64,
        }
    }
}

impl LmConfig {
    /// The GQA variant (8 query heads in 4 KV groups).
    pub fn gqa() -> Self {
        LmConfig { n_kv: 4, ..Default::default() }
    }

    /// Per-head width.
    pub fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }
}

/// A batch of next-token-prediction windows.
#[derive(Clone, Debug)]
pub struct LmBatch {
    /// Input token ids, `b*t` row-major.
    pub inputs: Vec<u16>,
    /// Target ids (inputs shifted by one).
    pub targets: Vec<u16>,
    pub b: usize,
    pub t: usize,
}

impl LmBatch {
    /// Build from `[t+1]`-length windows (see [`TokenSet::windows`]).
    pub fn from_windows(windows: &[Vec<u16>]) -> LmBatch {
        assert!(!windows.is_empty(), "empty batch");
        let t = windows[0].len() - 1;
        let mut inputs = Vec::with_capacity(windows.len() * t);
        let mut targets = Vec::with_capacity(windows.len() * t);
        for w in windows {
            assert_eq!(w.len(), t + 1, "ragged windows");
            inputs.extend_from_slice(&w[..t]);
            targets.extend_from_slice(&w[1..]);
        }
        LmBatch { inputs, targets, b: windows.len(), t }
    }

    /// Build the standard calibration/eval batch from a token stream.
    pub fn from_tokens(ts: &TokenSet, seq_len: usize, max_windows: usize) -> LmBatch {
        LmBatch::from_windows(&ts.windows(seq_len, max_windows))
    }
}

/// One pre-LN decoder block.
#[derive(Clone, Debug)]
pub struct LmBlock {
    pub ln1: LayerNorm,
    pub attn: MultiHeadAttention,
    pub ln2: LayerNorm,
    pub fc: Linear,
    pub proj: Linear,
}

/// The decoder-only language model.
#[derive(Clone, Debug)]
pub struct TinyLm {
    pub cfg: LmConfig,
    pub embed: Tensor, // [vocab, d_model]
    pub pos: Tensor,   // [max_seq, d_model]
    pub blocks: Vec<LmBlock>,
    pub ln_f: LayerNorm,
    pub lm_head: Linear,
}

impl TinyLm {
    /// Random-initialized model.
    pub fn init(cfg: LmConfig, rng: &mut Pcg64) -> Self {
        let d = cfg.d_model;
        let dh = cfg.d_head();
        let blocks = (0..cfg.n_layers)
            .map(|_| LmBlock {
                ln1: LayerNorm::new(d),
                attn: MultiHeadAttention::init(d, cfg.n_heads, cfg.n_kv, dh, true, rng),
                ln2: LayerNorm::new(d),
                fc: Linear::init(cfg.d_ff, d, rng),
                proj: Linear::init(d, cfg.d_ff, rng),
            })
            .collect();
        let mut embed = Tensor::zeros(&[cfg.vocab, d]);
        rng.fill_normal(embed.data_mut(), 0.05);
        let mut pos = Tensor::zeros(&[cfg.max_seq, d]);
        rng.fill_normal(pos.data_mut(), 0.02);
        TinyLm {
            cfg,
            embed,
            pos,
            blocks,
            ln_f: LayerNorm::new(d),
            lm_head: Linear::init(cfg.vocab, d, rng),
        }
    }

    /// Logits `[b*t, vocab]`.
    pub fn forward(&self, batch: &LmBatch) -> Tensor {
        self.forward_with_taps(batch).0
    }

    /// Token + positional embedding: batch ids to the `[b*t, d_model]`
    /// residual stream entering block 0.
    pub fn embed_batch(&self, batch: &LmBatch) -> Tensor {
        let (b, t) = (batch.b, batch.t);
        assert!(t <= self.cfg.max_seq, "sequence too long");
        let d = self.cfg.d_model;
        let rows = b * t;
        let mut cur = Tensor::zeros(&[rows, d]);
        for r in 0..rows {
            let tok = batch.inputs[r] as usize;
            assert!(tok < self.embed.dim(0), "token out of vocab");
            let dst = cur.row_mut(r);
            let e = self.embed.row(tok);
            let p = self.pos.row(r % t);
            for j in 0..d {
                dst[j] = e[j] + p[j];
            }
        }
        cur
    }

    /// Logits plus consumer-input taps in site order: for each block,
    /// the pre-`w_o` concatenated head features, then the post-GELU
    /// MLP hidden (`2·n_layers` taps total).
    pub fn forward_with_taps(&self, batch: &LmBatch) -> (Tensor, Vec<Tensor>) {
        let (b, t) = (batch.b, batch.t);
        let mut cur = self.embed_batch(batch);
        let mut taps = Vec::with_capacity(2 * self.blocks.len());
        for blk in &self.blocks {
            let normed = blk.ln1.forward(&cur);
            let (attn_out, attn_tap) = blk.attn.forward(&normed, b, t);
            taps.push(attn_tap);
            ops::axpy(&mut cur, 1.0, &attn_out);
            let normed = blk.ln2.forward(&cur);
            let hid = blk.fc.forward_act(&normed, Activation::Gelu);
            taps.push(hid.clone());
            let mlp_out = blk.proj.forward(&hid);
            ops::axpy(&mut cur, 1.0, &mlp_out);
        }
        let normed = self.ln_f.forward(&cur);
        (self.lm_head.forward(&normed), taps)
    }

    /// Serialize all parameters.
    pub fn to_bundle(&self) -> WeightBundle {
        let mut b = WeightBundle::new();
        b.insert("embed", self.embed.clone());
        b.insert("pos", self.pos.clone());
        for (i, blk) in self.blocks.iter().enumerate() {
            push_ln(&mut b, &format!("block{i}.ln1"), &blk.ln1);
            push_attn(&mut b, &format!("block{i}.attn"), &blk.attn);
            push_ln(&mut b, &format!("block{i}.ln2"), &blk.ln2);
            push_lin(&mut b, &format!("block{i}.fc"), &blk.fc);
            push_lin(&mut b, &format!("block{i}.proj"), &blk.proj);
        }
        push_ln(&mut b, "ln_f", &self.ln_f);
        push_lin(&mut b, "lm_head", &self.lm_head);
        b
    }

    /// Load from a bundle.
    pub fn from_bundle(b: &WeightBundle, cfg: LmConfig) -> Result<Self> {
        let dh = cfg.d_head();
        let mut blocks = Vec::new();
        for i in 0..cfg.n_layers {
            blocks.push(LmBlock {
                ln1: pull_ln(b, &format!("block{i}.ln1"))?,
                attn: pull_attn(b, &format!("block{i}.attn"), cfg.n_heads, cfg.n_kv, dh, true)?,
                ln2: pull_ln(b, &format!("block{i}.ln2"))?,
                fc: pull_lin(b, &format!("block{i}.fc"))?,
                proj: pull_lin(b, &format!("block{i}.proj"))?,
            });
        }
        Ok(TinyLm {
            cfg,
            embed: b.get("embed")?.clone(),
            pos: b.get("pos")?.clone(),
            blocks,
            ln_f: pull_ln(b, "ln_f")?,
            lm_head: pull_lin(b, "lm_head")?,
        })
    }

    /// Fresh incremental-decoding state for one sequence: per-block
    /// K/V caches sized by each block's *current* (possibly
    /// compressed) head layout, plus the model's linear weights
    /// prepacked once for the whole sequence.
    pub fn decode_state(&self) -> DecodeState {
        let cap = self.cfg.max_seq;
        let mut k_cache = Vec::with_capacity(self.blocks.len());
        let mut v_cache = Vec::with_capacity(self.blocks.len());
        let mut packs = Vec::with_capacity(self.blocks.len());
        for blk in &self.blocks {
            // Pruned/folded heads shrink the cache with the model —
            // the memory saving the paper's serving pitch is about.
            let sz = blk.attn.n_kv * cap * blk.attn.d_head;
            k_cache.push(vec![0.0f32; sz]);
            v_cache.push(vec![0.0f32; sz]);
            packs.push(BlockPack {
                wq: blk.attn.wq.prepack(),
                wk: blk.attn.wk.prepack(),
                wv: blk.attn.wv.prepack(),
                wo: blk.attn.wo.prepack(),
                fc: blk.fc.prepack(),
                proj: blk.proj.prepack(),
            });
        }
        DecodeState {
            len: 0,
            cap,
            k_cache,
            v_cache,
            packs,
            head_pack: self.lm_head.prepack(),
        }
    }

    /// Run the prompt through the model once, filling the K/V caches.
    /// Returns the **last row's** logits `[1, vocab]`, bit-identical
    /// to the last row of [`Self::forward`] over the same tokens. The
    /// interior prompt rows' logits are dead in every serving consumer
    /// (only the last row's argmax seeds generation), so the
    /// vocab-sized `lm_head` GEMM runs over one row instead of
    /// `prompt.len()` — [`Self::prefill_full`] keeps the full-logits
    /// contract for tests and oracles.
    pub fn prefill(&self, state: &mut DecodeState, prompt: &[u16]) -> Tensor {
        assert!(state.is_empty(), "prefill on a used DecodeState");
        self.decode_append(state, prompt, true)
    }

    /// [`Self::prefill`] with logits for **every** prompt row
    /// (`[prompt.len(), vocab]`), bit-identical to [`Self::forward`].
    /// The serving paths never consume interior rows; this entry is
    /// the oracle the lazy last-row path is tested against
    /// (`rust/tests/decode.rs`).
    pub fn prefill_full(&self, state: &mut DecodeState, prompt: &[u16]) -> Tensor {
        assert!(state.is_empty(), "prefill on a used DecodeState");
        self.decode_append(state, prompt, false)
    }

    /// Append one token and return its logits `[1, vocab]` — bit-
    /// identical to the last row of [`Self::forward`] over the whole
    /// sequence so far. Costs one 1-row pass over the layers plus one
    /// attention row per cached position, instead of a full `t`-row
    /// forward.
    pub fn decode_step(&self, state: &mut DecodeState, token: u16) -> Tensor {
        self.decode_append(state, &[token], true)
    }

    /// The shared prefill/decode body: embed `tokens` at absolute
    /// positions `state.len()..`, append their K/V rows to the caches,
    /// and attend against the cache prefixes via the same
    /// [`attend_cached`] the batch forward uses.
    ///
    /// Every step here is row-count-invariant — embedding, LayerNorm,
    /// the serving GEMMs (row-count-free dispatch, prepacked weights
    /// sharing the per-call compute body), [`attend_cached`] at
    /// matching `(k, n)` shapes, and the elementwise residual adds —
    /// which is what makes incremental decode reproduce the full
    /// forward's bits exactly (`rust/tests/decode.rs` asserts it for
    /// dense, pruned, folded, and GQA models).
    ///
    /// With `last_only`, only the final row goes through `ln_f` +
    /// `lm_head` (LayerNorm is row-local and the head GEMM is
    /// row-count-invariant, so the one projected row is bitwise the
    /// last row of the full projection).
    fn decode_append(&self, state: &mut DecodeState, tokens: &[u16], last_only: bool) -> Tensor {
        let t = tokens.len();
        assert!(t > 0, "decode_append needs at least one token");
        let p0 = state.len;
        let len = p0 + t;
        assert!(len <= state.cap, "decode past cache capacity {}", state.cap);
        assert_eq!(state.packs.len(), self.blocks.len(), "DecodeState from another model");
        let d = self.cfg.d_model;
        let cap = state.cap;
        // Embed at absolute positions p0..p0+t — for b = 1 this is
        // exactly what `embed_batch` computes.
        let mut cur = Tensor::zeros(&[t, d]);
        for (r, &tok) in tokens.iter().enumerate() {
            let tok = tok as usize;
            assert!(tok < self.embed.dim(0), "token out of vocab");
            let dst = cur.row_mut(r);
            let e = self.embed.row(tok);
            let p = self.pos.row(p0 + r);
            for j in 0..d {
                dst[j] = e[j] + p[j];
            }
        }
        for (bi, blk) in self.blocks.iter().enumerate() {
            let pack = &state.packs[bi];
            let (nh, nkv, dh) = (blk.attn.n_heads, blk.attn.n_kv, blk.attn.d_head);
            let gs = blk.attn.group_size();
            let normed = blk.ln1.forward(&cur);
            let q = blk.attn.wq.forward_prepacked(pack.wq.as_ref(), &normed, Activation::Identity);
            let k = blk.attn.wk.forward_prepacked(pack.wk.as_ref(), &normed, Activation::Identity);
            let v = blk.attn.wv.forward_prepacked(pack.wv.as_ref(), &normed, Activation::Identity);
            // Append the new K/V rows into each head's cache panel
            // (head-major `[n_kv][cap][d_head]`, so a head's live
            // prefix is one contiguous `[len, d_head]` slice).
            for h in 0..nkv {
                let kc = &mut state.k_cache[bi][(h * cap + p0) * dh..(h * cap + len) * dh];
                gather_block(k.data(), nkv * dh, 0, h * dh, t, dh, kc);
                let vc = &mut state.v_cache[bi][(h * cap + p0) * dh..(h * cap + len) * dh];
                gather_block(v.data(), nkv * dh, 0, h * dh, t, dh, vc);
            }
            // Attend each query head over its KV group's cache prefix.
            let mut tap = Tensor::zeros(&[t, nh * dh]);
            let mut qp = vec![0.0f32; t * dh];
            let mut ctx = vec![0.0f32; t * dh];
            for h in 0..nh {
                gather_block(q.data(), nh * dh, 0, h * dh, t, dh, &mut qp);
                let kvh = h / gs;
                let kc = &state.k_cache[bi][kvh * cap * dh..kvh * cap * dh + len * dh];
                let vc = &state.v_cache[bi][kvh * cap * dh..kvh * cap * dh + len * dh];
                ctx.fill(0.0);
                attend_cached(&qp, kc, vc, t, len, dh, p0, blk.attn.causal, &mut ctx);
                scatter_block(&ctx, tap.data_mut(), nh * dh, 0, h * dh, t, dh);
            }
            let attn_out =
                blk.attn.wo.forward_prepacked(pack.wo.as_ref(), &tap, Activation::Identity);
            ops::axpy(&mut cur, 1.0, &attn_out);
            let normed = blk.ln2.forward(&cur);
            let hid = blk.fc.forward_prepacked(pack.fc.as_ref(), &normed, Activation::Gelu);
            let mlp_out = blk.proj.forward_prepacked(pack.proj.as_ref(), &hid, Activation::Identity);
            ops::axpy(&mut cur, 1.0, &mlp_out);
        }
        state.len = len;
        let cur = if last_only && t > 1 { last_row(&cur) } else { cur };
        let normed = self.ln_f.forward(&cur);
        self.lm_head.forward_prepacked(state.head_pack.as_ref(), &normed, Activation::Identity)
    }

    /// Greedy generation through the KV-cache decode path: one prefill
    /// over the prompt, then one [`Self::decode_step`] per new token.
    /// Produces exactly the tokens [`Self::generate_rescan`] produces
    /// (asserted by `benches/serve.rs` and `rust/tests/decode.rs`),
    /// at a fraction of the cost.
    pub fn generate(&self, prompt: &[u16], n_new: usize) -> Vec<u16> {
        assert!(!prompt.is_empty(), "empty prompt");
        assert!(prompt.len() + n_new <= self.cfg.max_seq, "generation would exceed max_seq");
        let mut state = self.decode_state();
        let mut out = Vec::with_capacity(prompt.len() + n_new);
        out.extend_from_slice(prompt);
        let mut logits = self.prefill(&mut state, prompt);
        for step in 0..n_new {
            let next = argmax_last(&logits);
            out.push(next);
            if step + 1 < n_new {
                logits = self.decode_step(&mut state, next);
            }
        }
        out
    }

    /// Greedy generation the pre-decode way: re-run the full forward
    /// over the whole sequence for every new token. Kept as the
    /// decode path's correctness oracle and the baseline the serve
    /// bench measures the KV-cache speedup against.
    pub fn generate_rescan(&self, prompt: &[u16], n_new: usize) -> Vec<u16> {
        assert!(!prompt.is_empty(), "empty prompt");
        assert!(prompt.len() + n_new <= self.cfg.max_seq, "generation would exceed max_seq");
        let mut out = prompt.to_vec();
        for _ in 0..n_new {
            let t = out.len();
            let batch = LmBatch { inputs: out.clone(), targets: vec![0; t], b: 1, t };
            out.push(argmax_last(&self.forward(&batch)));
        }
        out
    }

    /// Prepack the model's serving weights **once** for all requests.
    ///
    /// [`Self::decode_state`] prepacks per request — fine for one
    /// stream, wasteful for a fleet. The pack also records the KV
    /// layout (per-block KV head counts as flat stream offsets, the
    /// uniform head width) that [`PagedKv`] page tables and the page
    /// budget arithmetic are indexed by.
    pub fn serve_pack(&self) -> LmServePack {
        let dh = self.cfg.d_head();
        let mut kv_off = Vec::with_capacity(self.blocks.len() + 1);
        kv_off.push(0usize);
        let mut packs = Vec::with_capacity(self.blocks.len());
        for blk in &self.blocks {
            assert_eq!(
                blk.attn.d_head, dh,
                "paged KV assumes the uniform head width compression preserves"
            );
            kv_off.push(kv_off.last().unwrap() + blk.attn.n_kv);
            packs.push(BlockPack {
                wq: blk.attn.wq.prepack(),
                wk: blk.attn.wk.prepack(),
                wv: blk.attn.wv.prepack(),
                wo: blk.attn.wo.prepack(),
                fc: blk.fc.prepack(),
                proj: blk.proj.prepack(),
            });
        }
        LmServePack { packs, head_pack: self.lm_head.prepack(), kv_off, dh }
    }

    /// Run the prompt through the model once, appending its K/V rows
    /// to pool pages. Paged twin of [`Self::prefill`]: last-row logits
    /// `[1, vocab]`, bit-identical to it (and to the last row of
    /// [`Self::forward`]) over the same tokens.
    pub fn paged_prefill(
        &self,
        pack: &LmServePack,
        pool: &mut KvPagePool,
        kv: &mut PagedKv,
        prompt: &[u16],
    ) -> Tensor {
        assert!(kv.is_empty(), "prefill on a used PagedKv");
        self.paged_append(pack, pool, kv, prompt, true)
    }

    /// [`Self::paged_prefill`] with logits for every prompt row —
    /// the paged twin of [`Self::prefill_full`], kept as the
    /// full-logits oracle for the lazy serving path.
    pub fn paged_prefill_full(
        &self,
        pack: &LmServePack,
        pool: &mut KvPagePool,
        kv: &mut PagedKv,
        prompt: &[u16],
    ) -> Tensor {
        assert!(kv.is_empty(), "prefill on a used PagedKv");
        self.paged_append(pack, pool, kv, prompt, false)
    }

    /// Append one token against paged K/V storage. Paged twin of
    /// [`Self::decode_step`], bit-identical to it.
    pub fn paged_decode_step(
        &self,
        pack: &LmServePack,
        pool: &mut KvPagePool,
        kv: &mut PagedKv,
        token: u16,
    ) -> Tensor {
        self.paged_append(pack, pool, kv, &[token], true)
    }

    /// [`Self::decode_append`] with the K/V caches living in fixed-size
    /// pool pages instead of a per-request `max_seq` slab: identical
    /// embed/GEMM/residual structure, K/V rows appended through the
    /// request's page tables, attention gathering each paged prefix
    /// into a contiguous panel before the shared
    /// [`attend_cached`] math
    /// ([`attend_paged`](crate::nn::attention)). Bitwise equality with
    /// the slab path is by construction and asserted across model
    /// variants in `rust/tests/decode.rs`.
    fn paged_append(
        &self,
        pack: &LmServePack,
        pool: &mut KvPagePool,
        kv: &mut PagedKv,
        tokens: &[u16],
        last_only: bool,
    ) -> Tensor {
        let t = tokens.len();
        assert!(t > 0, "paged_append needs at least one token");
        let p0 = kv.len();
        let len = p0 + t;
        assert!(len <= kv.capacity(), "decode past cache capacity {}", kv.capacity());
        assert_eq!(pack.packs.len(), self.blocks.len(), "LmServePack from another model");
        let d = self.cfg.d_model;
        let ps = pool.page_positions();
        let mut cur = Tensor::zeros(&[t, d]);
        for (r, &tok) in tokens.iter().enumerate() {
            let tok = tok as usize;
            assert!(tok < self.embed.dim(0), "token out of vocab");
            let dst = cur.row_mut(r);
            let e = self.embed.row(tok);
            let p = self.pos.row(p0 + r);
            for j in 0..d {
                dst[j] = e[j] + p[j];
            }
        }
        for (bi, blk) in self.blocks.iter().enumerate() {
            let bp = &pack.packs[bi];
            let (nh, nkv, dh) = (blk.attn.n_heads, blk.attn.n_kv, blk.attn.d_head);
            let gs = blk.attn.group_size();
            let off = pack.kv_off[bi];
            let normed = blk.ln1.forward(&cur);
            let q = blk.attn.wq.forward_prepacked(bp.wq.as_ref(), &normed, Activation::Identity);
            let k = blk.attn.wk.forward_prepacked(bp.wk.as_ref(), &normed, Activation::Identity);
            let v = blk.attn.wv.forward_prepacked(bp.wv.as_ref(), &normed, Activation::Identity);
            for r in 0..t {
                let krow = &k.data()[r * nkv * dh..(r + 1) * nkv * dh];
                let vrow = &v.data()[r * nkv * dh..(r + 1) * nkv * dh];
                kv.append_block_row(pool, off, nkv, dh, p0 + r, krow, vrow);
            }
            let mut tap = Tensor::zeros(&[t, nh * dh]);
            let mut qp = vec![0.0f32; t * dh];
            let mut ctx = vec![0.0f32; t * dh];
            let (mut kbuf, mut vbuf) = (Vec::new(), Vec::new());
            for h in 0..nh {
                gather_block(q.data(), nh * dh, 0, h * dh, t, dh, &mut qp);
                let flat = off + h / gs;
                ctx.fill(0.0);
                attend_paged(
                    &qp,
                    |i| pool.page(kv.k_page(flat, i)),
                    |i| pool.page(kv.v_page(flat, i)),
                    ps,
                    t,
                    len,
                    dh,
                    p0,
                    blk.attn.causal,
                    &mut kbuf,
                    &mut vbuf,
                    &mut ctx,
                );
                scatter_block(&ctx, tap.data_mut(), nh * dh, 0, h * dh, t, dh);
            }
            let attn_out = blk.attn.wo.forward_prepacked(bp.wo.as_ref(), &tap, Activation::Identity);
            ops::axpy(&mut cur, 1.0, &attn_out);
            let normed = blk.ln2.forward(&cur);
            let hid = blk.fc.forward_prepacked(bp.fc.as_ref(), &normed, Activation::Gelu);
            let mlp_out = blk.proj.forward_prepacked(bp.proj.as_ref(), &hid, Activation::Identity);
            ops::axpy(&mut cur, 1.0, &mlp_out);
        }
        kv.advance(t);
        let cur = if last_only && t > 1 { last_row(&cur) } else { cur };
        let normed = self.ln_f.forward(&cur);
        self.lm_head.forward_prepacked(pack.head_pack.as_ref(), &normed, Activation::Identity)
    }

    /// One **coalesced** decode step for `m` in-flight requests: each
    /// contributes its 1-token row to a single multi-row pass through
    /// the layers. Returns logits `[m, vocab]`, row `r` bit-identical
    /// to a solo [`Self::paged_decode_step`] (and hence to the slab
    /// [`Self::decode_step`]) for request `r` — at any batch
    /// composition and any worker count. Thin wrapper over
    /// [`Self::batch_step`] with one 1-row span per request.
    pub fn decode_batch_step(
        &self,
        pack: &LmServePack,
        pool: &mut KvPagePool,
        kvs: &mut [PagedKv],
        tokens: &[u16],
    ) -> Tensor {
        let m = kvs.len();
        assert!(m > 0, "decode_batch_step needs at least one request");
        assert_eq!(tokens.len(), m, "one token per in-flight request");
        for s in kvs.iter() {
            assert!(!s.is_empty(), "batch decode needs prefilled states");
        }
        let spans: Vec<RowSpan> =
            (0..m).map(|slot| RowSpan { slot, rows: 1, want_logits: true }).collect();
        let mut scratch = BatchScratch::new();
        self.batch_step(pack, pool, kvs, &spans, tokens, &mut scratch)
    }

    /// One **mixed** coalesced pass: every [`RowSpan`] appends `rows`
    /// new tokens to its request's [`PagedKv`] (decode steps are 1-row
    /// spans, prefill chunks are multi-row spans), all executed as a
    /// single GEMM per layer stage. Chunk rows attend causally at
    /// their absolute positions `p0..p0+rows` against the span's own
    /// paged prefix. Returns logits for the **last row of each span
    /// with `want_logits`** (`[n_want, vocab]`, span order) — interior
    /// prefill chunks skip the vocab projection entirely.
    ///
    /// Why the bits never depend on the batch composition or the
    /// chunking: every stage is row-local and row-count invariant.
    /// Embedding and the residual adds are elementwise per row;
    /// LayerNorm normalizes each row from its own mean/variance; the
    /// serving GEMMs dispatch on `(k, n)` only
    /// ([`use_packed_cols`](crate::tensor::gemm::use_packed_cols) has
    /// no `m` argument) and compute each output row from row-local
    /// accumulator state in the same `k` order; and attention runs per
    /// `(span, head)` against that span's own paged prefix via the
    /// exact solo-path math. A chunk's attention sees `len = p0 +
    /// rows` keys where the one-shot prefill sees the full prompt, but
    /// the extra keys are causally masked for every chunk row: their
    /// softmax weights are exactly `0.0`, and the trailing `+= 0.0·v`
    /// terms of the scalar context dot cannot change finite sums (see
    /// the dispatch-threshold note on `use_packed_cols` for the one
    /// shape caveat). `rust/tests/decode.rs` asserts chunk-size,
    /// admission-order, and worker-count invariance bitwise.
    ///
    /// Appends happen serially (the page pool hands out pages under
    /// `&mut`), then the per-`(span, head)` attention jobs fan out
    /// over disjoint chunk-row context panels, each claimed in the
    /// [`WriteSet`] audit. `scratch` hosts the reusable buffers so a
    /// warmed scheduler loop allocates nothing here beyond the
    /// per-layer activation tensors.
    pub fn batch_step(
        &self,
        pack: &LmServePack,
        pool: &mut KvPagePool,
        kvs: &mut [PagedKv],
        spans: &[RowSpan],
        tokens: &[u16],
        scratch: &mut BatchScratch,
    ) -> Tensor {
        assert!(!spans.is_empty(), "batch_step needs at least one row span");
        assert_eq!(pack.packs.len(), self.blocks.len(), "LmServePack from another model");
        let rt: usize = spans.iter().map(|s| s.rows).sum();
        assert_eq!(tokens.len(), rt, "one token per coalesced row");
        let d = self.cfg.d_model;
        let ps = pool.page_positions();
        // Per-span geometry: starting row in the coalesced pass and
        // starting position in the span's own cache.
        scratch.row0.clear();
        scratch.p0s.clear();
        {
            let mut acc = 0usize;
            for sp in spans {
                assert!(sp.rows > 0, "empty row span");
                let kv = &kvs[sp.slot];
                assert!(
                    kv.len() + sp.rows <= kv.capacity(),
                    "decode past cache capacity {}",
                    kv.capacity()
                );
                scratch.row0.push(acc);
                scratch.p0s.push(kv.len());
                acc += sp.rows;
            }
        }
        // Two spans growing one cache in a single pass would
        // interleave their appended positions.
        debug_assert!(
            {
                let mut slots: Vec<usize> = spans.iter().map(|s| s.slot).collect();
                slots.sort_unstable();
                slots.windows(2).all(|w| w[0] != w[1])
            },
            "duplicate kv slot across spans of one batch_step"
        );
        let mut buf = std::mem::take(&mut scratch.cur);
        buf.clear();
        buf.resize(rt * d, 0.0);
        let mut cur = Tensor::from_vec(&[rt, d], buf);
        for (si, sp) in spans.iter().enumerate() {
            for r in 0..sp.rows {
                let row = scratch.row0[si] + r;
                let tok = tokens[row] as usize;
                assert!(tok < self.embed.dim(0), "token out of vocab");
                let dst = cur.row_mut(row);
                let e = self.embed.row(tok);
                let p = self.pos.row(scratch.p0s[si] + r);
                for j in 0..d {
                    dst[j] = e[j] + p[j];
                }
            }
        }
        for (bi, blk) in self.blocks.iter().enumerate() {
            let bp = &pack.packs[bi];
            let (nh, nkv, dh) = (blk.attn.n_heads, blk.attn.n_kv, blk.attn.d_head);
            let gs = blk.attn.group_size();
            let off = pack.kv_off[bi];
            let normed = blk.ln1.forward(&cur);
            let q = blk.attn.wq.forward_prepacked(bp.wq.as_ref(), &normed, Activation::Identity);
            let k = blk.attn.wk.forward_prepacked(bp.wk.as_ref(), &normed, Activation::Identity);
            let v = blk.attn.wv.forward_prepacked(bp.wv.as_ref(), &normed, Activation::Identity);
            // Serial append phase: page allocation needs `&mut` pool.
            for (si, sp) in spans.iter().enumerate() {
                for r in 0..sp.rows {
                    let row = scratch.row0[si] + r;
                    let krow = &k.data()[row * nkv * dh..(row + 1) * nkv * dh];
                    let vrow = &v.data()[row * nkv * dh..(row + 1) * nkv * dh];
                    kvs[sp.slot].append_block_row(
                        pool,
                        off,
                        nkv,
                        dh,
                        scratch.p0s[si] + r,
                        krow,
                        vrow,
                    );
                }
            }
            // Parallel attend phase: one job per (span, query head),
            // each writing a disjoint `rows × dh` chunk-row context
            // panel and reading only its own span's paged prefix —
            // worker count can never change the bits. Panels are
            // span-major (`[span][head][rows][dh]`), so variable-size
            // spans stay contiguous; a scatter pass below restores the
            // row-major `[rt, nh*dh]` tap.
            scratch.ctx.clear();
            scratch.ctx.resize(rt * nh * dh, 0.0);
            let ws = WriteSet::new("batch step chunk-row context panels", rt * nh * dh);
            let pool_ro: &KvPagePool = pool;
            let kvs_ro: &[PagedKv] = kvs;
            let (row0, p0s) = (&scratch.row0, &scratch.p0s);
            let qd = q.data();
            struct AttnJob<'a> {
                idx: usize,
                start: usize,
                si: usize,
                h: usize,
                panel: &'a mut [f32],
            }
            let mut jobs: Vec<AttnJob<'_>> = Vec::with_capacity(spans.len() * nh);
            {
                let mut rest: &mut [f32] = &mut scratch.ctx;
                let mut start = 0usize;
                for (si, sp) in spans.iter().enumerate() {
                    for h in 0..nh {
                        let (panel, tail) = std::mem::take(&mut rest).split_at_mut(sp.rows * dh);
                        rest = tail;
                        jobs.push(AttnJob { idx: jobs.len(), start, si, h, panel });
                        start += sp.rows * dh;
                    }
                }
            }
            let workers = default_threads().clamp(1, jobs.len());
            run_grid_mut(&mut jobs, workers, |_, job| {
                ws.claim(job.idx, job.start, job.panel.len());
                let sp = &spans[job.si];
                let (p0, rows) = (p0s[job.si], sp.rows);
                let kv = &kvs_ro[sp.slot];
                let flat = off + job.h / gs;
                // 1-row spans read their query row in place; chunk
                // spans gather the head's column block first.
                let mut qbuf = Vec::new();
                let qp: &[f32] = if rows == 1 {
                    &qd[(row0[job.si] * nh + job.h) * dh..][..dh]
                } else {
                    qbuf.resize(rows * dh, 0.0);
                    gather_block(qd, nh * dh, row0[job.si], job.h * dh, rows, dh, &mut qbuf);
                    &qbuf
                };
                let (mut kbuf, mut vbuf) = (Vec::new(), Vec::new());
                attend_paged(
                    qp,
                    |i| pool_ro.page(kv.k_page(flat, i)),
                    |i| pool_ro.page(kv.v_page(flat, i)),
                    ps,
                    rows,
                    p0 + rows,
                    dh,
                    p0,
                    blk.attn.causal,
                    &mut kbuf,
                    &mut vbuf,
                    job.panel,
                );
            });
            ws.verify();
            drop(jobs);
            let mut tbuf = std::mem::take(&mut scratch.tap);
            tbuf.clear();
            tbuf.resize(rt * nh * dh, 0.0);
            {
                let mut start = 0usize;
                for (si, sp) in spans.iter().enumerate() {
                    for h in 0..nh {
                        let panel = &scratch.ctx[start..start + sp.rows * dh];
                        scatter_block(
                            panel,
                            &mut tbuf,
                            nh * dh,
                            scratch.row0[si],
                            h * dh,
                            sp.rows,
                            dh,
                        );
                        start += sp.rows * dh;
                    }
                }
            }
            let tap = Tensor::from_vec(&[rt, nh * dh], tbuf);
            let attn_out = blk.attn.wo.forward_prepacked(bp.wo.as_ref(), &tap, Activation::Identity);
            scratch.tap = tap.into_vec();
            ops::axpy(&mut cur, 1.0, &attn_out);
            let normed = blk.ln2.forward(&cur);
            let hid = blk.fc.forward_prepacked(bp.fc.as_ref(), &normed, Activation::Gelu);
            let mlp_out = blk.proj.forward_prepacked(bp.proj.as_ref(), &hid, Activation::Identity);
            ops::axpy(&mut cur, 1.0, &mlp_out);
        }
        for sp in spans {
            kvs[sp.slot].advance(sp.rows);
        }
        // Lazy lm_head: gather only the rows whose logits a consumer
        // will read (each requesting span's last row) and project
        // those — prompt-interior rows never pay the vocab GEMM.
        let n_want = spans.iter().filter(|s| s.want_logits).count();
        let mut lbuf = std::mem::take(&mut scratch.last);
        lbuf.clear();
        lbuf.resize(n_want * d, 0.0);
        {
            let mut w = 0usize;
            for (si, sp) in spans.iter().enumerate() {
                if sp.want_logits {
                    let row = scratch.row0[si] + sp.rows - 1;
                    lbuf[w * d..(w + 1) * d].copy_from_slice(cur.row(row));
                    w += 1;
                }
            }
        }
        scratch.cur = cur.into_vec();
        let last = Tensor::from_vec(&[n_want, d], lbuf);
        let out = if n_want == 0 {
            Tensor::zeros(&[0, self.cfg.vocab])
        } else {
            let normed = self.ln_f.forward(&last);
            self.lm_head.forward_prepacked(pack.head_pack.as_ref(), &normed, Activation::Identity)
        };
        scratch.last = last.into_vec();
        out
    }
}

/// Copy the last row of `x` into a fresh `[1, d]` tensor — the lazy
/// lm_head path projects only this row. LayerNorm is row-local and the
/// head GEMM's dispatch and per-row accumulation are row-count-free,
/// so the result is bitwise the last row of the full projection.
fn last_row(x: &Tensor) -> Tensor {
    Tensor::from_vec(&[1, x.dim(1)], x.row(x.dim(0) - 1).to_vec())
}

/// One request's contribution to a coalesced mixed prefill+decode pass
/// ([`TinyLm::batch_step`]): `rows` new tokens appended to the
/// [`PagedKv`] at `kvs[slot]`, starting at its current length.
#[derive(Clone, Copy, Debug)]
pub struct RowSpan {
    /// Index of the request's cache in the `kvs` slab passed
    /// alongside the spans. Slots must be distinct within one pass.
    pub slot: usize,
    /// Token rows this request contributes: 1 for a decode step, up
    /// to the prefill-chunk budget for a prefilling request.
    pub rows: usize,
    /// Project this span's last row through `ln_f` + `lm_head` (true
    /// for decode rows and final prefill chunks; false for interior
    /// chunks, whose logits are dead).
    pub want_logits: bool,
}

/// Reusable buffers for [`TinyLm::batch_step`]: the per-step
/// allocations of the scheduler hot loop (residual stream, span
/// geometry, context panels, attention tap, lm_head row gather)
/// hoisted into one object whose capacity survives across steps.
/// Tensors borrow the buffers via `from_vec`/`into_vec` round-trips,
/// which preserve the allocation. Per-layer activation tensors inside
/// the pass (`q`/`k`/`v`/`normed`/`hid`) still allocate — the scratch
/// removes the *scheduler-owned* per-step allocations, and
/// `serve::batch`'s steady-state test pins these buffers in place.
#[derive(Default)]
pub struct BatchScratch {
    cur: Vec<f32>,
    ctx: Vec<f32>,
    tap: Vec<f32>,
    last: Vec<f32>,
    row0: Vec<usize>,
    p0s: Vec<usize>,
}

impl BatchScratch {
    /// Empty scratch; buffers grow to the workload's high-water mark
    /// and stay there.
    pub fn new() -> BatchScratch {
        BatchScratch::default()
    }

    /// `(pointer, capacity)` fingerprint of every buffer — the
    /// zero-steady-state-allocation test asserts these stay put across
    /// warmed scheduler steps.
    pub fn probe(&self) -> [(usize, usize); 6] {
        [
            (self.cur.as_ptr() as usize, self.cur.capacity()),
            (self.ctx.as_ptr() as usize, self.ctx.capacity()),
            (self.tap.as_ptr() as usize, self.tap.capacity()),
            (self.last.as_ptr() as usize, self.last.capacity()),
            (self.row0.as_ptr() as usize, self.row0.capacity()),
            (self.p0s.as_ptr() as usize, self.p0s.capacity()),
        ]
    }
}

/// Greedy pick from the last row of a logits tensor.
fn argmax_last(logits: &Tensor) -> u16 {
    argmax_rows(logits)[logits.dim(0) - 1] as u16
}

/// One block's prepacked serving weights (`None` where the layer's
/// shape dispatches to the scalar path anyway).
#[derive(Clone)]
struct BlockPack {
    wq: Option<PackedB>,
    wk: Option<PackedB>,
    wv: Option<PackedB>,
    wo: Option<PackedB>,
    fc: Option<PackedB>,
    proj: Option<PackedB>,
}

/// Incremental-decoding state for one sequence: per-block head-major
/// K/V caches (`[n_kv][capacity][d_head]`, sized by the model's
/// compressed layout) plus prepacked linear weights. Create with
/// [`TinyLm::decode_state`], fill with [`TinyLm::prefill`], extend
/// with [`TinyLm::decode_step`].
#[derive(Clone)]
pub struct DecodeState {
    len: usize,
    cap: usize,
    k_cache: Vec<Vec<f32>>,
    v_cache: Vec<Vec<f32>>,
    packs: Vec<BlockPack>,
    head_pack: Option<PackedB>,
}

impl DecodeState {
    /// Number of positions currently cached.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True until the first prefill.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Maximum sequence length the caches hold (the model's
    /// `max_seq` — the positional table is the binding limit).
    pub fn capacity(&self) -> usize {
        self.cap
    }
}

/// The model's serving weights prepacked **once and shared by every
/// request** (unlike [`DecodeState`], which prepacks per request),
/// plus the KV layout the paged cache is indexed by: each block's KV
/// heads get consecutive *flat stream indices* (`kv_off[bi] + h`), and
/// every stream stores `d_head`-wide position rows. Build with
/// [`TinyLm::serve_pack`]; consumed by [`TinyLm::paged_prefill`],
/// [`TinyLm::decode_batch_step`], and the continuous-batching
/// scheduler ([`crate::serve::batch::BatchScheduler`]).
pub struct LmServePack {
    packs: Vec<BlockPack>,
    head_pack: Option<PackedB>,
    /// Flat KV-stream offsets: block `bi`'s KV head `h` is stream
    /// `kv_off[bi] + h`; `kv_off[n_layers]` is the total stream count.
    kv_off: Vec<usize>,
    dh: usize,
}

impl LmServePack {
    /// Total number of K (equivalently V) position streams across all
    /// blocks — pruned/folded KV heads shrink this with the model.
    pub fn total_kv_streams(&self) -> usize {
        *self.kv_off.last().unwrap()
    }

    /// Uniform per-position row width of every stream.
    pub fn d_head(&self) -> usize {
        self.dh
    }

    /// Pool pages one request holding `positions` cached positions
    /// occupies: each of its K and V streams rounds up to whole pages.
    /// This is the scheduler's admission-accounting unit.
    pub fn pages_needed(&self, positions: usize, page_positions: usize) -> usize {
        assert!(page_positions > 0, "pages must hold at least one position");
        let per_stream = (positions + page_positions - 1) / page_positions;
        2 * self.total_kv_streams() * per_stream
    }

    /// Cache elements one per-request slab path ([`DecodeState`])
    /// allocates: every stream owns `max_seq` positions up front,
    /// live or not. The paged-vs-slab capacity comparison in
    /// `rust/tests/decode.rs` and `benches/serve.rs` is against this.
    pub fn slab_elems(&self, max_seq: usize) -> usize {
        2 * self.total_kv_streams() * max_seq * self.dh
    }
}

/// Per-request paged K/V cache state: a length plus one page table per
/// (K|V, flat KV stream), mapping position chunks to fixed-size
/// [`KvPagePool`] pages. Requests allocate pages as they grow and
/// return them on [`PagedKv::release`], so thousands of concurrent
/// states share a fixed pool budget instead of each owning `max_seq`
/// slots the way [`DecodeState`] does.
pub struct PagedKv {
    len: usize,
    cap: usize,
    /// `k_pages[stream][i]` = pool page holding positions
    /// `[i*page_positions, (i+1)*page_positions)` of K stream `stream`.
    k_pages: Vec<Vec<usize>>,
    v_pages: Vec<Vec<usize>>,
}

impl PagedKv {
    /// Empty state for one request against `pack`'s KV layout, capped
    /// at `cap` positions (the model's `max_seq`).
    pub fn new(pack: &LmServePack, cap: usize) -> PagedKv {
        let streams = pack.total_kv_streams();
        PagedKv {
            len: 0,
            cap,
            k_pages: vec![Vec::new(); streams],
            v_pages: vec![Vec::new(); streams],
        }
    }

    /// Number of positions currently cached.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True until the first prefill.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Maximum positions this request may cache.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Pool pages this request currently holds across all streams.
    pub fn pages_held(&self) -> usize {
        self.k_pages.iter().map(Vec::len).sum::<usize>()
            + self.v_pages.iter().map(Vec::len).sum::<usize>()
    }

    /// Return every held page to the pool and reset to empty.
    pub fn release(&mut self, pool: &mut KvPagePool) {
        for table in self.k_pages.iter_mut().chain(self.v_pages.iter_mut()) {
            for id in table.drain(..) {
                pool.release(id);
            }
        }
        self.len = 0;
    }

    /// Page id of chunk `i` of K stream `stream`.
    pub(crate) fn k_page(&self, stream: usize, i: usize) -> usize {
        self.k_pages[stream][i]
    }

    /// Gather the live content of K stream `stream` into one flat
    /// `[len, d_head]` vector. Test/conformance helper: chunked and
    /// one-shot prefills may hand out different page *ids*, but the
    /// bytes at every logical position must be identical
    /// (`rust/tests/decode.rs`).
    pub fn gather_k(&self, pool: &KvPagePool, stream: usize, dh: usize) -> Vec<f32> {
        self.gather_stream(&self.k_pages[stream], pool, dh)
    }

    /// [`Self::gather_k`] for the V stream.
    pub fn gather_v(&self, pool: &KvPagePool, stream: usize, dh: usize) -> Vec<f32> {
        self.gather_stream(&self.v_pages[stream], pool, dh)
    }

    fn gather_stream(&self, table: &[usize], pool: &KvPagePool, dh: usize) -> Vec<f32> {
        let ps = pool.page_positions();
        let mut out = Vec::with_capacity(self.len * dh);
        for pos in 0..self.len {
            let page = pool.page(table[pos / ps]);
            out.extend_from_slice(&page[(pos % ps) * dh..(pos % ps + 1) * dh]);
        }
        out
    }

    /// Page id of chunk `i` of V stream `stream`.
    pub(crate) fn v_page(&self, stream: usize, i: usize) -> usize {
        self.v_pages[stream][i]
    }

    /// Write one position's projected K/V rows (`[n_kv, dh]` each,
    /// row-major) into the page tables of block streams
    /// `off..off + nkv`, allocating fresh pool pages at chunk
    /// boundaries. `pos` must be the next unwritten position of this
    /// block's streams; the request-level length only advances via
    /// [`Self::advance`] once *all* blocks have appended the position.
    pub(crate) fn append_block_row(
        &mut self,
        pool: &mut KvPagePool,
        off: usize,
        nkv: usize,
        dh: usize,
        pos: usize,
        krow: &[f32],
        vrow: &[f32],
    ) {
        debug_assert_eq!(krow.len(), nkv * dh);
        debug_assert_eq!(vrow.len(), nkv * dh);
        let ps = pool.page_positions();
        let (pi, po) = (pos / ps, pos % ps);
        for h in 0..nkv {
            let kt = &mut self.k_pages[off + h];
            debug_assert!(kt.len() >= pi, "K stream {off}+{h} skipped a position chunk");
            if kt.len() == pi {
                kt.push(pool.alloc());
            }
            pool.page_mut(kt[pi])[po * dh..(po + 1) * dh]
                .copy_from_slice(&krow[h * dh..(h + 1) * dh]);
            let vt = &mut self.v_pages[off + h];
            if vt.len() == pi {
                vt.push(pool.alloc());
            }
            pool.page_mut(vt[pi])[po * dh..(po + 1) * dh]
                .copy_from_slice(&vrow[h * dh..(h + 1) * dh]);
        }
    }

    /// Commit `t` appended positions (call once per append pass, after
    /// every block has written its rows).
    pub(crate) fn advance(&mut self, t: usize) {
        self.len += t;
        debug_assert!(self.len <= self.cap);
    }
}

/// Segment-executor state: the residual stream at the current site's
/// boundary — before `ln1` for attention sites (even indices), before
/// `ln2` for MLP sites (odd indices) — plus the batch geometry the
/// attention forward needs.
#[derive(Clone, Debug)]
pub struct LmCalibState {
    cur: Tensor,
    b: usize,
    t: usize,
}

impl Compressible for TinyLm {
    type Input = LmBatch;
    type CalibState = LmCalibState;

    fn calib_begin(&self, input: &LmBatch) -> LmCalibState {
        LmCalibState { cur: self.embed_batch(input), b: input.b, t: input.t }
    }

    fn site_tap(&self, state: &mut LmCalibState, site: usize) -> Tensor {
        crate::bench_util::count_layer_forward();
        let blk = &self.blocks[site / 2];
        if site % 2 == 0 {
            let normed = blk.ln1.forward(&state.cur);
            let (_, tap) = blk.attn.forward(&normed, state.b, state.t);
            tap
        } else {
            let normed = blk.ln2.forward(&state.cur);
            blk.fc.forward_act(&normed, Activation::Gelu)
        }
    }

    fn forward_segment(&self, state: &mut LmCalibState, from_site: usize, to_site: usize) {
        for s in from_site..to_site {
            crate::bench_util::count_layer_forward();
            let blk = &self.blocks[s / 2];
            if s % 2 == 0 {
                // Through the attention site: re-runs the (possibly
                // just-compressed) attention — head reductions rewrite
                // q/k/v, so the pre-apply tap cannot be reused.
                let normed = blk.ln1.forward(&state.cur);
                let (attn_out, _) = blk.attn.forward(&normed, state.b, state.t);
                ops::axpy(&mut state.cur, 1.0, &attn_out);
            } else {
                let normed = blk.ln2.forward(&state.cur);
                let hid = blk.fc.forward_act(&normed, Activation::Gelu);
                let mlp_out = blk.proj.forward(&hid);
                ops::axpy(&mut state.cur, 1.0, &mlp_out);
            }
        }
    }

    fn split_input(&self, input: &LmBatch, max_shards: usize) -> Vec<LmBatch> {
        let t = input.t;
        ops::shard_ranges(input.b, max_shards)
            .into_iter()
            .map(|(start, len)| LmBatch {
                inputs: input.inputs[start * t..(start + len) * t].to_vec(),
                targets: input.targets[start * t..(start + len) * t].to_vec(),
                b: len,
                t,
            })
            .collect()
    }

    fn param_count(&self) -> usize {
        let mut n = self.embed.len() + self.pos.len();
        for blk in &self.blocks {
            n += blk.ln1.param_count()
                + blk.attn.param_count()
                + blk.ln2.param_count()
                + blk.fc.param_count()
                + blk.proj.param_count();
        }
        n + self.ln_f.param_count() + self.lm_head.param_count()
    }

    fn sites(&self) -> Vec<SiteInfo> {
        let mut sites = Vec::with_capacity(2 * self.blocks.len());
        for (i, blk) in self.blocks.iter().enumerate() {
            sites.push(SiteInfo {
                id: format!("block{i}.attn"),
                units: blk.attn.n_heads,
                unit_dim: blk.attn.d_head,
                groups: if blk.attn.group_size() > 1 { blk.attn.n_kv } else { 1 },
                kind: SiteKind::AttnHeads,
            });
            sites.push(SiteInfo {
                id: format!("block{i}.mlp"),
                units: blk.fc.out_dim(),
                unit_dim: 1,
                groups: 1,
                kind: SiteKind::MlpPair,
            });
        }
        sites
    }

    fn producer_row_norm(&self, site: usize, ord: u8) -> Vec<f32> {
        let blk = &self.blocks[site / 2];
        if site % 2 == 0 {
            // Attention heads: norm of each head's query-weight block.
            let dh = blk.attn.d_head;
            let per_row = super::mlp::row_norms(&blk.attn.wq.w, ord);
            (0..blk.attn.n_heads)
                .map(|h| per_row[h * dh..(h + 1) * dh].iter().sum())
                .collect()
        } else {
            super::mlp::row_norms(&blk.fc.w, ord)
        }
    }

    fn producer_features(&self, site: usize) -> Tensor {
        let blk = &self.blocks[site / 2];
        if site % 2 == 0 {
            crate::compress::heads::head_features(&blk.attn.wq.w, blk.attn.n_heads, blk.attn.d_head)
        } else {
            blk.fc.w.clone()
        }
    }

    fn consumer_col_norms(&self, site: usize) -> Vec<f32> {
        let blk = &self.blocks[site / 2];
        if site % 2 == 0 {
            blk.attn.wo.input_col_norms()
        } else {
            blk.proj.input_col_norms()
        }
    }

    fn consumer_matrix(&self, site: usize) -> Tensor {
        let blk = &self.blocks[site / 2];
        if site % 2 == 0 {
            blk.attn.wo.w.clone()
        } else {
            blk.proj.w.clone()
        }
    }

    fn apply(&mut self, site: usize, plan: &ReductionPlan) {
        let blk = &mut self.blocks[site / 2];
        if site % 2 == 1 {
            super::mlp::apply_dense_pair(&mut blk.fc, &mut blk.proj, plan);
            return;
        }
        // Attention heads: narrow the producer at the head level, then
        // update w_o on the Kronecker-lifted feature axis.
        let dh = blk.attn.d_head;
        let h_feat = blk.attn.feat_width();
        match &plan.reducer {
            Reducer::Select(heads) => blk.attn.select_heads(heads),
            Reducer::Fold { assign, k } => blk.attn.fold_heads(assign, *k),
        }
        if let Some(w) = &plan.consumer_override {
            assert_eq!(w.dim(0), blk.attn.wo.out_dim(), "override rows");
            assert_eq!(w.dim(1), plan.reducer.k() * dh, "override cols");
            blk.attn.wo.w = w.clone();
        } else if let Some(b_map) = &plan.compensation {
            blk.attn.wo.merge_input_map(b_map);
        } else {
            let lifted = plan.reducer.lift(dh);
            blk.attn.wo.merge_input_map(&lifted.consumer_matrix(h_feat));
        }
        if let Some(delta) = &plan.bias_delta {
            assert_eq!(delta.len(), blk.attn.wo.out_dim(), "wo bias delta");
            for (b, d) in blk.attn.wo.b.data_mut().iter_mut().zip(delta) {
                *b += d;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{SynthText, TextSplit};

    fn model(gqa: bool) -> TinyLm {
        let mut rng = Pcg64::seed(13);
        let cfg = if gqa { LmConfig::gqa() } else { LmConfig::default() };
        TinyLm::init(cfg, &mut rng)
    }

    fn batch(b: usize, t: usize) -> LmBatch {
        let ts = SynthText::new(5).generate(TextSplit::Train, b * (t + 1) + 10);
        LmBatch::from_tokens(&ts, t, b)
    }

    #[test]
    fn forward_shapes_and_taps() {
        let m = model(false);
        let bt = batch(2, 16);
        let (y, taps) = m.forward_with_taps(&bt);
        assert_eq!(y.shape(), &[32, m.cfg.vocab]);
        assert_eq!(taps.len(), 8); // 4 blocks × (attn, mlp)
        assert_eq!(taps[0].shape(), &[32, 64]); // 8 heads × dh 8
        assert_eq!(taps[1].shape(), &[32, 192]);
        assert!(y.all_finite());
    }

    #[test]
    fn batch_windows_shift_targets() {
        let bt = batch(2, 8);
        assert_eq!(bt.inputs.len(), 16);
        assert_eq!(bt.targets.len(), 16);
        // Targets are inputs shifted by one inside each window.
        assert_eq!(bt.inputs[1], bt.targets[0]);
    }

    #[test]
    fn bundle_roundtrip_preserves_function() {
        for gqa in [false, true] {
            let m = model(gqa);
            let bt = batch(1, 12);
            let y0 = m.forward(&bt);
            let r = TinyLm::from_bundle(&m.to_bundle(), m.cfg).unwrap();
            assert!(y0.max_abs_diff(&r.forward(&bt)) < 1e-5, "gqa={gqa}");
        }
    }

    #[test]
    fn sites_cover_attention_and_mlp() {
        let m = model(true);
        let sites = m.sites();
        assert_eq!(sites.len(), 8);
        assert_eq!(sites[0].kind, SiteKind::AttnHeads);
        assert_eq!(sites[0].units, 8);
        assert_eq!(sites[0].unit_dim, 8);
        assert_eq!(sites[0].groups, 4); // GQA groups
        assert_eq!(sites[1].kind, SiteKind::MlpPair);
        let mha = model(false);
        assert_eq!(mha.sites()[0].groups, 1);
    }

    #[test]
    fn head_prune_mha() {
        let mut m = model(false);
        let bt = batch(1, 8);
        m.apply(0, &ReductionPlan::bare(Reducer::Select(vec![0, 2, 5, 7])));
        assert_eq!(m.blocks[0].attn.n_heads, 4);
        assert_eq!(m.blocks[0].attn.wo.in_dim(), 32);
        assert!(m.forward(&bt).all_finite());
    }

    #[test]
    fn head_prune_gqa_balanced() {
        let mut m = model(true);
        let bt = batch(1, 8);
        // Keep 1 of 2 query heads per group.
        m.apply(0, &ReductionPlan::bare(Reducer::Select(vec![0, 2, 4, 6])));
        assert_eq!(m.blocks[0].attn.n_heads, 4);
        assert_eq!(m.blocks[0].attn.n_kv, 4); // kv untouched
        assert!(m.forward(&bt).all_finite());
    }

    #[test]
    fn full_head_selection_identity() {
        let mut m = model(false);
        let bt = batch(1, 8);
        let y0 = m.forward(&bt);
        m.apply(0, &ReductionPlan::bare(Reducer::Select((0..8).collect())));
        assert!(y0.max_abs_diff(&m.forward(&bt)) < 1e-5);
    }

    #[test]
    fn mlp_site_apply() {
        let mut m = model(false);
        let bt = batch(1, 8);
        m.apply(1, &ReductionPlan::bare(Reducer::Select((0..96).collect())));
        assert_eq!(m.blocks[0].fc.out_dim(), 96);
        assert_eq!(m.blocks[0].proj.in_dim(), 96);
        assert!(m.forward(&bt).all_finite());
    }

    #[test]
    fn staged_taps_match_forward_with_taps() {
        for gqa in [false, true] {
            let m = model(gqa);
            let bt = batch(2, 12);
            let (_, taps) = m.forward_with_taps(&bt);
            for site in 0..taps.len() {
                let staged = m.site_activations(&bt, site);
                assert_eq!(staged, taps[site], "gqa={gqa} site {site}");
            }
        }
    }

    #[test]
    fn split_input_preserves_windows() {
        let m = model(false);
        let bt = batch(5, 8);
        let shards = m.split_input(&bt, 2);
        assert_eq!(shards.len(), 2);
        assert_eq!(shards[0].b + shards[1].b, 5);
        let rejoined: Vec<u16> = shards
            .iter()
            .flat_map(|s| s.inputs.iter().copied())
            .collect();
        assert_eq!(rejoined, bt.inputs);
        for s in &shards {
            assert_eq!(s.t, 8);
            assert_eq!(s.inputs.len(), s.b * s.t);
            assert_eq!(s.targets.len(), s.b * s.t);
        }
    }

    #[test]
    fn attn_tap_matches_wo_input() {
        let m = model(false);
        let bt = batch(1, 8);
        let (_, taps) = m.forward_with_taps(&bt);
        // Rebuilding the attention output from the tap must match the
        // block's contribution: verified indirectly by width.
        assert_eq!(taps[0].dim(1), m.blocks[0].attn.feat_width());
    }
}
