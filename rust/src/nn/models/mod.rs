//! The model zoo: the repro substitutes for the paper's architectures
//! (DESIGN.md §2).
//!
//! | Paper                      | Here                               |
//! |----------------------------|------------------------------------|
//! | ResNet-18 / CIFAR-10       | [`MiniResNet`] / SynthVision       |
//! | ViT-B/32, CLIP ViT-B/32    | [`TinyViT`] / SynthVision          |
//! | LLaMA-2-7B                 | [`TinyLm`] (MHA + GQA) / SynthText |
//! | MLP probes                 | [`MlpNet`]                         |
//!
//! Every model implements [`crate::compress::Compressible`] and
//! round-trips through the `GRWB` weight format shared with the
//! Python training step.

mod lm;
mod mlp;
mod resnet;
mod vit;

pub use lm::{
    BatchScratch, LmBatch, LmCalibState, LmConfig, LmServePack, PagedKv, RowSpan, TinyLm,
};
pub use mlp::{MlpCalibState, MlpNet};
pub use resnet::{MiniResNet, ResNetCalibState};
pub use vit::{TinyViT, VitCalibState, VitConfig};
