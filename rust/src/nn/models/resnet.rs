//! MiniResNet — the repro stand-in for ResNet-18/CIFAR-10.
//!
//! Stem conv + 4 BasicBlocks (2 stages, the second strided with a 1×1
//! downsample skip) + global average pool + linear head. Compression
//! sites are each block's *internal* channels (conv1 out → conv2 in),
//! which keeps the residual topology intact — the standard structured-
//! pruning surface for ResNets.

use crate::compress::{Compressible, ReductionPlan, Reducer, SiteInfo, SiteKind};
use crate::data::VisionSet;
use crate::nn::weights::WeightBundle;
use crate::nn::{relu, BatchNorm2d, Conv2d, Linear};
use crate::rng::Pcg64;
use crate::tensor::{ops, Tensor};
use anyhow::Result;

/// One residual block: `relu(bn2(conv2(relu(bn1(conv1 x)))) + skip)`.
#[derive(Clone, Debug)]
pub struct BasicBlock {
    pub conv1: Conv2d,
    pub bn1: BatchNorm2d,
    pub conv2: Conv2d,
    pub bn2: BatchNorm2d,
    /// 1×1 conv + BN on the skip when shape changes.
    pub down: Option<(Conv2d, BatchNorm2d)>,
}

impl BasicBlock {
    fn init(c_in: usize, c_out: usize, stride: usize, rng: &mut Pcg64) -> Self {
        let down = if stride != 1 || c_in != c_out {
            Some((Conv2d::init(c_out, c_in, 1, stride, 0, rng), BatchNorm2d::new(c_out)))
        } else {
            None
        };
        BasicBlock {
            conv1: Conv2d::init(c_out, c_in, 3, stride, 1, rng),
            bn1: BatchNorm2d::new(c_out),
            conv2: Conv2d::init(c_out, c_out, 3, 1, 1, rng),
            bn2: BatchNorm2d::new(c_out),
            down,
        }
    }

    /// Forward over `[n, c_in*h*w]`; returns `(out, mid_tap, oh, ow)`
    /// where `mid_tap` is the post-`relu(bn1(conv1))` activation — the
    /// consumer input of `conv2`.
    fn forward(&self, x: &Tensor, h: usize, w: usize) -> (Tensor, Tensor, usize, usize) {
        let (oh, ow) = self.conv1.out_hw(h, w);
        let mut mid = self.conv1.forward(x, h, w);
        self.bn1.forward_inplace(&mut mid, oh * ow);
        relu(&mut mid);
        let mut out = self.conv2.forward(&mid, oh, ow);
        self.bn2.forward_inplace(&mut out, oh * ow);
        let skip = match &self.down {
            Some((conv, bn)) => {
                let mut s = conv.forward(x, h, w);
                bn.forward_inplace(&mut s, oh * ow);
                s
            }
            None => x.clone(),
        };
        ops::axpy(&mut out, 1.0, &skip);
        relu(&mut out);
        (out, mid, oh, ow)
    }
}

/// The full network.
#[derive(Clone, Debug)]
pub struct MiniResNet {
    pub stem_conv: Conv2d,
    pub stem_bn: BatchNorm2d,
    pub blocks: Vec<BasicBlock>,
    pub head: Linear,
    /// Input geometry `(c, h, w)`.
    pub chw: (usize, usize, usize),
}

impl MiniResNet {
    /// Standard configuration: widths 32/64 on 3×16×16 inputs,
    /// 10 classes.
    pub fn init(rng: &mut Pcg64) -> Self {
        MiniResNet {
            stem_conv: Conv2d::init(32, 3, 3, 1, 1, rng),
            stem_bn: BatchNorm2d::new(32),
            blocks: vec![
                BasicBlock::init(32, 32, 1, rng),
                BasicBlock::init(32, 32, 1, rng),
                BasicBlock::init(32, 64, 2, rng),
                BasicBlock::init(64, 64, 1, rng),
            ],
            head: Linear::init(10, 64, rng),
            chw: (3, 16, 16),
        }
    }

    /// Logits for `[n, c*h*w]`.
    pub fn forward(&self, x: &Tensor) -> Tensor {
        self.forward_with_taps(x).0
    }

    /// Logits plus one mid-block tap per site, already reshaped to
    /// `[n*oh*ow, c_mid]` rows (pixels are Gram samples).
    pub fn forward_with_taps(&self, x: &Tensor) -> (Tensor, Vec<Tensor>) {
        let (_, h0, w0) = self.chw;
        let mut cur = self.stem_conv.forward(x, h0, w0);
        let (mut h, mut w) = self.stem_conv.out_hw(h0, w0);
        self.stem_bn.forward_inplace(&mut cur, h * w);
        relu(&mut cur);
        let mut taps = Vec::with_capacity(self.blocks.len());
        for blk in &self.blocks {
            let (out, mid, oh, ow) = blk.forward(&cur, h, w);
            taps.push(chw_to_rows(&mid, blk.conv1.out_channels(), oh * ow));
            cur = out;
            h = oh;
            w = ow;
        }
        // Global average pool to [n, c].
        let c = self.blocks.last().map(|b| b.conv2.out_channels()).unwrap_or(0);
        let pooled = global_avg_pool(&cur, c, h * w);
        (self.head.forward(&pooled), taps)
    }

    /// REPAIR (Jordan et al.): recompute every BatchNorm's running
    /// statistics from calibration data flowing through the *current*
    /// (compressed) network.
    pub fn repair(&mut self, calib: &VisionSet) {
        let (_, h0, w0) = self.chw;
        let x = &calib.x;
        let mut pre = self.stem_conv.forward(x, h0, w0);
        let (mut h, mut w) = self.stem_conv.out_hw(h0, w0);
        self.stem_bn.recompute_stats(&pre, h * w);
        self.stem_bn.forward_inplace(&mut pre, h * w);
        relu(&mut pre);
        let mut cur = pre;
        for bi in 0..self.blocks.len() {
            let (oh, ow) = self.blocks[bi].conv1.out_hw(h, w);
            let mut mid = self.blocks[bi].conv1.forward(&cur, h, w);
            self.blocks[bi].bn1.recompute_stats(&mid, oh * ow);
            self.blocks[bi].bn1.forward_inplace(&mut mid, oh * ow);
            relu(&mut mid);
            let mut out = self.blocks[bi].conv2.forward(&mid, oh, ow);
            self.blocks[bi].bn2.recompute_stats(&out, oh * ow);
            self.blocks[bi].bn2.forward_inplace(&mut out, oh * ow);
            let skip = match &mut self.blocks[bi].down {
                Some((conv, bn)) => {
                    let mut s = conv.forward(&cur, h, w);
                    bn.recompute_stats(&s, oh * ow);
                    bn.forward_inplace(&mut s, oh * ow);
                    s
                }
                None => cur.clone(),
            };
            ops::axpy(&mut out, 1.0, &skip);
            relu(&mut out);
            cur = out;
            h = oh;
            w = ow;
        }
    }

    /// Serialize all parameters.
    pub fn to_bundle(&self) -> WeightBundle {
        let mut b = WeightBundle::new();
        push_conv(&mut b, "stem.conv", &self.stem_conv);
        push_bn(&mut b, "stem.bn", &self.stem_bn);
        for (i, blk) in self.blocks.iter().enumerate() {
            push_conv(&mut b, &format!("block{i}.conv1"), &blk.conv1);
            push_bn(&mut b, &format!("block{i}.bn1"), &blk.bn1);
            push_conv(&mut b, &format!("block{i}.conv2"), &blk.conv2);
            push_bn(&mut b, &format!("block{i}.bn2"), &blk.bn2);
            if let Some((conv, bn)) = &blk.down {
                push_conv(&mut b, &format!("block{i}.down.conv"), conv);
                push_bn(&mut b, &format!("block{i}.down.bn"), bn);
            }
        }
        b.insert("head.w", self.head.w.clone());
        b.insert("head.b", self.head.b.clone());
        b
    }

    /// Load from a bundle (the standard 4-block topology; strides are
    /// inferred from the presence of downsample weights).
    pub fn from_bundle(b: &WeightBundle) -> Result<Self> {
        let stem_conv = pull_conv(b, "stem.conv", 1, 1)?;
        let stem_bn = pull_bn(b, "stem.bn")?;
        let mut blocks = Vec::new();
        for i in 0.. {
            if b.get(&format!("block{i}.conv1.w")).is_err() {
                break;
            }
            let has_down = b.get(&format!("block{i}.down.conv.w")).is_ok();
            let stride = if has_down { 2 } else { 1 };
            let blk = BasicBlock {
                conv1: pull_conv(b, &format!("block{i}.conv1"), stride, 1)?,
                bn1: pull_bn(b, &format!("block{i}.bn1"))?,
                conv2: pull_conv(b, &format!("block{i}.conv2"), 1, 1)?,
                bn2: pull_bn(b, &format!("block{i}.bn2"))?,
                down: if has_down {
                    Some((
                        pull_conv(b, &format!("block{i}.down.conv"), stride, 0)?,
                        pull_bn(b, &format!("block{i}.down.bn"))?,
                    ))
                } else {
                    None
                },
            };
            blocks.push(blk);
        }
        anyhow::ensure!(!blocks.is_empty(), "no blocks in bundle");
        Ok(MiniResNet {
            stem_conv,
            stem_bn,
            blocks,
            head: Linear { w: b.get("head.w")?.clone(), b: b.get("head.b")?.clone() },
            chw: (3, 16, 16),
        })
    }
}

/// Reorder `[n, c*hw]` CHW activations into `[n*hw, c]` rows so each
/// pixel is one Gram sample over channels.
pub fn chw_to_rows(x: &Tensor, c: usize, hw: usize) -> Tensor {
    let n = x.dim(0);
    assert_eq!(x.dim(1), c * hw);
    let mut out = Tensor::zeros(&[n * hw, c]);
    let xd = x.data();
    let od = out.data_mut();
    for i in 0..n {
        for ch in 0..c {
            let src = &xd[i * c * hw + ch * hw..i * c * hw + (ch + 1) * hw];
            for (s, &v) in src.iter().enumerate() {
                od[(i * hw + s) * c + ch] = v;
            }
        }
    }
    out
}

/// Mean over the spatial axis: `[n, c*hw] -> [n, c]`.
pub fn global_avg_pool(x: &Tensor, c: usize, hw: usize) -> Tensor {
    let n = x.dim(0);
    assert_eq!(x.dim(1), c * hw);
    let mut out = Tensor::zeros(&[n, c]);
    for i in 0..n {
        for ch in 0..c {
            let s: f32 = x.data()[i * c * hw + ch * hw..i * c * hw + (ch + 1) * hw]
                .iter()
                .sum();
            out.set2(i, ch, s / hw as f32);
        }
    }
    out
}

fn push_conv(b: &mut WeightBundle, name: &str, c: &Conv2d) {
    b.insert(&format!("{name}.w"), c.w.clone());
    b.insert(&format!("{name}.b"), c.b.clone());
}

fn push_bn(b: &mut WeightBundle, name: &str, bn: &BatchNorm2d) {
    b.insert(&format!("{name}.gamma"), bn.gamma.clone());
    b.insert(&format!("{name}.beta"), bn.beta.clone());
    b.insert(&format!("{name}.mean"), bn.running_mean.clone());
    b.insert(&format!("{name}.var"), bn.running_var.clone());
}

fn pull_conv(b: &WeightBundle, name: &str, stride: usize, pad: usize) -> Result<Conv2d> {
    let w = b.get(&format!("{name}.w"))?.clone();
    anyhow::ensure!(w.ndim() == 4, "{name}: conv weight must be 4-D");
    Ok(Conv2d { w, b: b.get(&format!("{name}.b"))?.clone(), stride, pad })
}

fn pull_bn(b: &WeightBundle, name: &str) -> Result<BatchNorm2d> {
    Ok(BatchNorm2d {
        gamma: b.get(&format!("{name}.gamma"))?.clone(),
        beta: b.get(&format!("{name}.beta"))?.clone(),
        running_mean: b.get(&format!("{name}.mean"))?.clone(),
        running_var: b.get(&format!("{name}.var"))?.clone(),
    })
}

/// Segment-executor state: the current block's input activation plus
/// its spatial geometry (the stem has already run).
#[derive(Clone, Debug)]
pub struct ResNetCalibState {
    cur: Tensor,
    h: usize,
    w: usize,
}

impl Compressible for MiniResNet {
    type Input = Tensor;
    type CalibState = ResNetCalibState;

    fn calib_begin(&self, input: &Tensor) -> ResNetCalibState {
        crate::bench_util::count_layer_forward();
        let (_, h0, w0) = self.chw;
        let mut cur = self.stem_conv.forward(input, h0, w0);
        let (h, w) = self.stem_conv.out_hw(h0, w0);
        self.stem_bn.forward_inplace(&mut cur, h * w);
        relu(&mut cur);
        ResNetCalibState { cur, h, w }
    }

    fn site_tap(&self, state: &mut ResNetCalibState, site: usize) -> Tensor {
        crate::bench_util::count_layer_forward();
        let blk = &self.blocks[site];
        let (oh, ow) = blk.conv1.out_hw(state.h, state.w);
        let mut mid = blk.conv1.forward(&state.cur, state.h, state.w);
        blk.bn1.forward_inplace(&mut mid, oh * ow);
        relu(&mut mid);
        chw_to_rows(&mid, blk.conv1.out_channels(), oh * ow)
    }

    fn forward_segment(&self, state: &mut ResNetCalibState, from_site: usize, to_site: usize) {
        for s in from_site..to_site {
            crate::bench_util::count_layer_forward();
            let (out, _mid, oh, ow) = self.blocks[s].forward(&state.cur, state.h, state.w);
            state.cur = out;
            state.h = oh;
            state.w = ow;
        }
    }

    fn split_input(&self, input: &Tensor, max_shards: usize) -> Vec<Tensor> {
        ops::split_rows(input, max_shards)
    }

    fn param_count(&self) -> usize {
        let mut n = self.stem_conv.param_count() + self.stem_bn.param_count();
        for blk in &self.blocks {
            n += blk.conv1.param_count()
                + blk.bn1.param_count()
                + blk.conv2.param_count()
                + blk.bn2.param_count();
            if let Some((conv, bn)) = &blk.down {
                n += conv.param_count() + bn.param_count();
            }
        }
        n + self.head.param_count()
    }

    fn sites(&self) -> Vec<SiteInfo> {
        self.blocks
            .iter()
            .enumerate()
            .map(|(i, blk)| SiteInfo {
                id: format!("block{i}.mid"),
                units: blk.conv1.out_channels(),
                unit_dim: 1,
                groups: 1,
                kind: SiteKind::Conv,
            })
            .collect()
    }

    fn producer_row_norm(&self, site: usize, ord: u8) -> Vec<f32> {
        super::mlp::row_norms(&self.blocks[site].conv1.weight_matrix(), ord)
    }

    fn producer_features(&self, site: usize) -> Tensor {
        self.blocks[site].conv1.weight_matrix()
    }

    fn consumer_col_norms(&self, site: usize) -> Vec<f32> {
        self.blocks[site].conv2.input_col_norms()
    }

    fn consumer_matrix(&self, site: usize) -> Tensor {
        // conv2 as [o*kh*kw, c]: one output row per spatial tap.
        let conv = &self.blocks[site].conv2;
        let (o, c) = (conv.out_channels(), conv.in_channels());
        let (kh, kw) = conv.kernel();
        let mut m = Tensor::zeros(&[o * kh * kw, c]);
        for oo in 0..o {
            for cc in 0..c {
                for t in 0..kh * kw {
                    let v = conv.w.data()[(oo * c + cc) * kh * kw + t];
                    m.set2(oo * kh * kw + t, cc, v);
                }
            }
        }
        m
    }

    fn apply(&mut self, site: usize, plan: &ReductionPlan) {
        let blk = &mut self.blocks[site];
        let h = blk.conv1.out_channels();
        // 1. Narrow the producer conv + its BN.
        match &plan.reducer {
            Reducer::Select(idx) => {
                blk.conv1.select_outputs(idx);
                blk.bn1.select_channels(idx);
            }
            Reducer::Fold { assign, k } => {
                blk.conv1.fold_outputs(assign, *k);
                blk.bn1.fold_channels(assign, *k);
            }
        }
        // 2. Update the consumer conv along its input channels.
        if let Some(w) = &plan.consumer_override {
            let conv = &mut blk.conv2;
            let (o, _c) = (conv.out_channels(), conv.in_channels());
            let (kh, kw) = conv.kernel();
            let k = plan.reducer.k();
            assert_eq!(w.shape(), &[o * kh * kw, k], "conv override shape");
            let mut nw = Tensor::zeros(&[o, k, kh, kw]);
            for oo in 0..o {
                for cc in 0..k {
                    for t in 0..kh * kw {
                        nw.data_mut()[(oo * k + cc) * kh * kw + t] =
                            w.at2(oo * kh * kw + t, cc);
                    }
                }
            }
            conv.w = nw;
        } else if let Some(b_map) = &plan.compensation {
            blk.conv2.merge_input_map(b_map);
        } else {
            blk.conv2.merge_input_map(&plan.reducer.consumer_matrix(h));
        }
        // 3. Optional bias correction. Bias deltas are per consumer-
        // matrix row, i.e. one per (out-channel, spatial tap); the conv
        // bias has per-channel granularity, so sum a channel's taps
        // (each tap sees the removed features' mean).
        if let Some(delta) = &plan.bias_delta {
            let o = blk.conv2.out_channels();
            let (kh, kw) = blk.conv2.kernel();
            assert_eq!(delta.len(), o * kh * kw, "conv bias delta rows");
            for (oo, b) in blk.conv2.b.data_mut().iter_mut().enumerate() {
                *b += delta[oo * kh * kw..(oo + 1) * kh * kw].iter().sum::<f32>();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthVision;

    fn net() -> MiniResNet {
        let mut rng = Pcg64::seed(3);
        MiniResNet::init(&mut rng)
    }

    fn imgs(n: usize) -> Tensor {
        SynthVision::new(7).generate(n).x
    }

    #[test]
    fn forward_shapes() {
        let m = net();
        let x = imgs(4);
        let (y, taps) = m.forward_with_taps(&x);
        assert_eq!(y.shape(), &[4, 10]);
        assert_eq!(taps.len(), 4);
        assert_eq!(taps[0].shape(), &[4 * 256, 32]);
        assert_eq!(taps[2].shape(), &[4 * 64, 64]); // strided stage: 8×8
        assert!(y.all_finite());
    }

    #[test]
    fn bundle_roundtrip_preserves_function() {
        let m = net();
        let x = imgs(2);
        let y0 = m.forward(&x);
        let r = MiniResNet::from_bundle(&m.to_bundle()).unwrap();
        assert!(y0.max_abs_diff(&r.forward(&x)) < 1e-5);
    }

    #[test]
    fn chw_to_rows_layout() {
        // 1 sample, 2 channels, hw=3.
        let x = Tensor::from_vec(&[1, 6], vec![1., 2., 3., 10., 20., 30.]);
        let r = chw_to_rows(&x, 2, 3);
        assert_eq!(r.shape(), &[3, 2]);
        assert_eq!(r.row(0), &[1., 10.]);
        assert_eq!(r.row(2), &[3., 30.]);
    }

    #[test]
    fn avg_pool_means() {
        let x = Tensor::from_vec(&[1, 4], vec![1., 3., 10., 30.]); // c=2, hw=2
        let p = global_avg_pool(&x, 2, 2);
        assert_eq!(p.data(), &[2., 20.]);
    }

    #[test]
    fn prune_block_changes_width_keeps_finite() {
        let mut m = net();
        let x = imgs(2);
        m.apply(1, &ReductionPlan::bare(Reducer::Select((0..16).collect())));
        assert_eq!(m.blocks[1].conv1.out_channels(), 16);
        assert_eq!(m.blocks[1].conv2.in_channels(), 16);
        assert_eq!(m.blocks[1].bn1.channels(), 16);
        let y = m.forward(&x);
        assert!(y.all_finite());
    }

    #[test]
    fn full_selection_is_identity() {
        let mut m = net();
        let x = imgs(2);
        let y0 = m.forward(&x);
        m.apply(0, &ReductionPlan::bare(Reducer::Select((0..32).collect())));
        assert!(y0.max_abs_diff(&m.forward(&x)) < 1e-4);
    }

    #[test]
    fn repair_runs_and_updates_stats() {
        let mut m = net();
        let calib = SynthVision::new(7).generate(16);
        let before = m.blocks[0].bn1.running_mean.clone();
        m.repair(&calib);
        let after = &m.blocks[0].bn1.running_mean;
        assert!(before.max_abs_diff(after) > 1e-4, "stats should move");
        assert!(m.forward(&calib.x).all_finite());
    }

    #[test]
    fn staged_taps_match_forward_with_taps() {
        let m = net();
        let x = imgs(3);
        let (_, taps) = m.forward_with_taps(&x);
        for site in 0..m.blocks.len() {
            let staged = m.site_activations(&x, site);
            assert_eq!(staged, taps[site], "site {site}");
        }
    }

    #[test]
    fn consumer_matrix_matches_merge_semantics() {
        // consumer_matrix · M must equal conv2 after merge_input_map(M).
        let m = net();
        let site = 0;
        let cm = m.consumer_matrix(site);
        let h = m.blocks[site].conv1.out_channels();
        let reducer = Reducer::Select((0..h / 2).collect());
        let mm = reducer.matrix(h);
        let want = ops::matmul(&cm, &mm);
        let mut m2 = m.clone();
        m2.apply(site, &ReductionPlan::bare(reducer));
        let got = m2.consumer_matrix(site);
        assert!(want.max_abs_diff(&got) < 1e-5);
    }
}
