//! TinyViT — the repro stand-in for ViT-B/32 and CLIP ViT-B/32.
//!
//! Patch embedding + pre-LN encoder blocks + mean-pool head. Following
//! the paper (§3.1 "Compensation for ViTs and CLIP"), GRAIL targets the
//! MLP `(W_fc, W_proj)` producer–consumer pairs; attention is left at
//! full width for this architecture.

use crate::compress::{Compressible, ReductionPlan, SiteInfo, SiteKind};
use crate::nn::weights::WeightBundle;
use crate::nn::{Activation, LayerNorm, Linear, MultiHeadAttention};
use crate::rng::Pcg64;
use crate::tensor::{ops, Tensor};
use anyhow::Result;

/// Architecture hyper-parameters.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct VitConfig {
    pub image: (usize, usize, usize), // c, h, w
    pub patch: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub n_layers: usize,
    pub classes: usize,
}

impl Default for VitConfig {
    fn default() -> Self {
        VitConfig {
            image: (3, 16, 16),
            patch: 4,
            d_model: 64,
            n_heads: 4,
            d_ff: 128,
            n_layers: 3,
            classes: 10,
        }
    }
}

impl VitConfig {
    /// Tokens per image.
    pub fn tokens(&self) -> usize {
        let (_, h, w) = self.image;
        (h / self.patch) * (w / self.patch)
    }

    /// Flattened patch dimension.
    pub fn patch_dim(&self) -> usize {
        let (c, _, _) = self.image;
        c * self.patch * self.patch
    }
}

/// One pre-LN encoder block.
#[derive(Clone, Debug)]
pub struct VitBlock {
    pub ln1: LayerNorm,
    pub attn: MultiHeadAttention,
    pub ln2: LayerNorm,
    pub fc: Linear,
    pub proj: Linear,
}

/// The full encoder.
#[derive(Clone, Debug)]
pub struct TinyViT {
    pub cfg: VitConfig,
    pub patch_embed: Linear,
    pub pos: Tensor, // [tokens, d_model]
    pub blocks: Vec<VitBlock>,
    pub ln_f: LayerNorm,
    pub head: Linear,
}

impl TinyViT {
    /// Random-initialized encoder.
    pub fn init(cfg: VitConfig, rng: &mut Pcg64) -> Self {
        let d = cfg.d_model;
        let dh = d / cfg.n_heads;
        let blocks = (0..cfg.n_layers)
            .map(|_| VitBlock {
                ln1: LayerNorm::new(d),
                attn: MultiHeadAttention::init(d, cfg.n_heads, cfg.n_heads, dh, false, rng),
                ln2: LayerNorm::new(d),
                fc: Linear::init(cfg.d_ff, d, rng),
                proj: Linear::init(d, cfg.d_ff, rng),
            })
            .collect();
        let mut pos = Tensor::zeros(&[cfg.tokens(), d]);
        rng.fill_normal(pos.data_mut(), 0.02);
        TinyViT {
            cfg,
            patch_embed: Linear::init(d, cfg.patch_dim(), rng),
            pos,
            blocks,
            ln_f: LayerNorm::new(d),
            head: Linear::init(cfg.classes, d, rng),
        }
    }

    /// Split `[n, c*h*w]` CHW images into `[n*tokens, patch_dim]` rows
    /// ordered `(c, dy, dx)` per token, tokens row-major.
    pub fn patchify(&self, x: &Tensor) -> Tensor {
        let (c, h, w) = self.cfg.image;
        let p = self.cfg.patch;
        let (gh, gw) = (h / p, w / p);
        let n = x.dim(0);
        assert_eq!(x.dim(1), c * h * w, "image layout");
        let mut out = Tensor::zeros(&[n * gh * gw, c * p * p]);
        let xd = x.data();
        for i in 0..n {
            for ty in 0..gh {
                for tx in 0..gw {
                    let row = out.row_mut((i * gh + ty) * gw + tx);
                    for cc in 0..c {
                        for dy in 0..p {
                            for dx in 0..p {
                                row[(cc * p + dy) * p + dx] = xd[i * c * h * w
                                    + cc * h * w
                                    + (ty * p + dy) * w
                                    + (tx * p + dx)];
                            }
                        }
                    }
                }
            }
        }
        out
    }

    /// Logits for `[n, c*h*w]`.
    pub fn forward(&self, x: &Tensor) -> Tensor {
        self.forward_with_taps(x).0
    }

    /// Patch-embed plus positional embedding: `[n, c*h*w]` images to
    /// the `[n*tokens, d_model]` token stream entering block 0.
    pub fn embed(&self, x: &Tensor) -> Tensor {
        let n = x.dim(0);
        let t = self.cfg.tokens();
        let mut cur = self.patch_embed.forward(&self.patchify(x)); // [n*t, d]
        for r in 0..n * t {
            let pos_row = self.pos.row(r % t).to_vec();
            for (v, p) in cur.row_mut(r).iter_mut().zip(&pos_row) {
                *v += p;
            }
        }
        cur
    }

    /// Logits plus one post-GELU MLP tap per block (`[n*tokens, d_ff]`).
    pub fn forward_with_taps(&self, x: &Tensor) -> (Tensor, Vec<Tensor>) {
        let n = x.dim(0);
        let t = self.cfg.tokens();
        let d = self.cfg.d_model;
        let mut cur = self.embed(x);
        let mut taps = Vec::with_capacity(self.blocks.len());
        for blk in &self.blocks {
            // Pre-LN attention with residual.
            let normed = blk.ln1.forward(&cur);
            let (attn_out, _) = blk.attn.forward(&normed, n, t);
            ops::axpy(&mut cur, 1.0, &attn_out);
            // Pre-LN MLP with residual.
            let normed = blk.ln2.forward(&cur);
            let hid = blk.fc.forward_act(&normed, Activation::Gelu);
            taps.push(hid.clone());
            let mlp_out = blk.proj.forward(&hid);
            ops::axpy(&mut cur, 1.0, &mlp_out);
        }
        let normed = self.ln_f.forward(&cur);
        // Mean-pool tokens to [n, d].
        let mut pooled = Tensor::zeros(&[n, d]);
        for i in 0..n {
            for tok in 0..t {
                let src = normed.row(i * t + tok);
                for (p, &v) in pooled.row_mut(i).iter_mut().zip(src) {
                    *p += v;
                }
            }
            for v in pooled.row_mut(i) {
                *v /= t as f32;
            }
        }
        (self.head.forward(&pooled), taps)
    }

    /// Serialize all parameters.
    pub fn to_bundle(&self) -> WeightBundle {
        let mut b = WeightBundle::new();
        b.insert("patch.w", self.patch_embed.w.clone());
        b.insert("patch.b", self.patch_embed.b.clone());
        b.insert("pos", self.pos.clone());
        for (i, blk) in self.blocks.iter().enumerate() {
            push_ln(&mut b, &format!("block{i}.ln1"), &blk.ln1);
            push_attn(&mut b, &format!("block{i}.attn"), &blk.attn);
            push_ln(&mut b, &format!("block{i}.ln2"), &blk.ln2);
            push_lin(&mut b, &format!("block{i}.fc"), &blk.fc);
            push_lin(&mut b, &format!("block{i}.proj"), &blk.proj);
        }
        push_ln(&mut b, "ln_f", &self.ln_f);
        push_lin(&mut b, "head", &self.head);
        b
    }

    /// Load from a bundle.
    pub fn from_bundle(b: &WeightBundle, cfg: VitConfig) -> Result<Self> {
        let mut blocks = Vec::new();
        let dh = cfg.d_model / cfg.n_heads;
        for i in 0..cfg.n_layers {
            blocks.push(VitBlock {
                ln1: pull_ln(b, &format!("block{i}.ln1"))?,
                attn: pull_attn(b, &format!("block{i}.attn"), cfg.n_heads, cfg.n_heads, dh, false)?,
                ln2: pull_ln(b, &format!("block{i}.ln2"))?,
                fc: pull_lin(b, &format!("block{i}.fc"))?,
                proj: pull_lin(b, &format!("block{i}.proj"))?,
            });
        }
        Ok(TinyViT {
            cfg,
            patch_embed: Linear { w: b.get("patch.w")?.clone(), b: b.get("patch.b")?.clone() },
            pos: b.get("pos")?.clone(),
            blocks,
            ln_f: pull_ln(b, "ln_f")?,
            head: pull_lin(b, "head")?,
        })
    }
}

pub(crate) fn push_lin(b: &mut WeightBundle, name: &str, l: &Linear) {
    b.insert(&format!("{name}.w"), l.w.clone());
    b.insert(&format!("{name}.b"), l.b.clone());
}

pub(crate) fn pull_lin(b: &WeightBundle, name: &str) -> Result<Linear> {
    Ok(Linear { w: b.get(&format!("{name}.w"))?.clone(), b: b.get(&format!("{name}.b"))?.clone() })
}

pub(crate) fn push_ln(b: &mut WeightBundle, name: &str, l: &LayerNorm) {
    b.insert(&format!("{name}.gamma"), l.gamma.clone());
    b.insert(&format!("{name}.beta"), l.beta.clone());
}

pub(crate) fn pull_ln(b: &WeightBundle, name: &str) -> Result<LayerNorm> {
    Ok(LayerNorm {
        gamma: b.get(&format!("{name}.gamma"))?.clone(),
        beta: b.get(&format!("{name}.beta"))?.clone(),
    })
}

pub(crate) fn push_attn(b: &mut WeightBundle, name: &str, a: &MultiHeadAttention) {
    push_lin(b, &format!("{name}.wq"), &a.wq);
    push_lin(b, &format!("{name}.wk"), &a.wk);
    push_lin(b, &format!("{name}.wv"), &a.wv);
    push_lin(b, &format!("{name}.wo"), &a.wo);
}

pub(crate) fn pull_attn(
    b: &WeightBundle,
    name: &str,
    n_heads: usize,
    n_kv: usize,
    d_head: usize,
    causal: bool,
) -> Result<MultiHeadAttention> {
    Ok(MultiHeadAttention {
        wq: pull_lin(b, &format!("{name}.wq"))?,
        wk: pull_lin(b, &format!("{name}.wk"))?,
        wv: pull_lin(b, &format!("{name}.wv"))?,
        wo: pull_lin(b, &format!("{name}.wo"))?,
        n_heads,
        n_kv,
        d_head,
        causal,
    })
}

/// Segment-executor state: the residual stream entering the current
/// block, plus the post-attention residual cached by `site_tap` —
/// attention sits *upstream* of the MLP site, so the cache stays valid
/// across `apply` and saves re-running attention in the following
/// `forward_segment` call. The cache is tagged with its site index so a
/// stale entry is never reused.
#[derive(Clone, Debug)]
pub struct VitCalibState {
    cur: Tensor,
    n: usize,
    attn_mid: Option<(usize, Tensor)>,
}

impl TinyViT {
    /// Post-attention residual of `site`'s block (the MLP boundary),
    /// consuming a matching cache or recomputing attention from the
    /// state's residual stream.
    fn mlp_boundary(&self, state: &mut VitCalibState, site: usize) -> Tensor {
        if let Some((cached_site, mid)) = state.attn_mid.take() {
            if cached_site == site {
                return mid;
            }
        }
        let blk = &self.blocks[site];
        let normed = blk.ln1.forward(&state.cur);
        let (attn_out, _) = blk.attn.forward(&normed, state.n, self.cfg.tokens());
        let mut mid = state.cur.clone();
        ops::axpy(&mut mid, 1.0, &attn_out);
        mid
    }
}

impl Compressible for TinyViT {
    type Input = Tensor;
    type CalibState = VitCalibState;

    fn calib_begin(&self, input: &Tensor) -> VitCalibState {
        crate::bench_util::count_layer_forward();
        VitCalibState { cur: self.embed(input), n: input.dim(0), attn_mid: None }
    }

    fn site_tap(&self, state: &mut VitCalibState, site: usize) -> Tensor {
        crate::bench_util::count_layer_forward();
        let mid = self.mlp_boundary(state, site);
        let blk = &self.blocks[site];
        let normed = blk.ln2.forward(&mid);
        let hid = blk.fc.forward_act(&normed, Activation::Gelu);
        state.attn_mid = Some((site, mid));
        hid
    }

    fn forward_segment(&self, state: &mut VitCalibState, from_site: usize, to_site: usize) {
        for s in from_site..to_site {
            crate::bench_util::count_layer_forward();
            let mid = self.mlp_boundary(state, s);
            let blk = &self.blocks[s];
            let normed = blk.ln2.forward(&mid);
            let hid = blk.fc.forward_act(&normed, Activation::Gelu);
            let mlp_out = blk.proj.forward(&hid);
            let mut out = mid;
            ops::axpy(&mut out, 1.0, &mlp_out);
            state.cur = out;
        }
    }

    fn split_input(&self, input: &Tensor, max_shards: usize) -> Vec<Tensor> {
        ops::split_rows(input, max_shards)
    }

    fn param_count(&self) -> usize {
        let mut n = self.patch_embed.param_count() + self.pos.len();
        for blk in &self.blocks {
            n += blk.ln1.param_count()
                + blk.attn.param_count()
                + blk.ln2.param_count()
                + blk.fc.param_count()
                + blk.proj.param_count();
        }
        n + self.ln_f.param_count() + self.head.param_count()
    }

    fn sites(&self) -> Vec<SiteInfo> {
        self.blocks
            .iter()
            .enumerate()
            .map(|(i, blk)| SiteInfo {
                id: format!("block{i}.mlp"),
                units: blk.fc.out_dim(),
                unit_dim: 1,
                groups: 1,
                kind: SiteKind::MlpPair,
            })
            .collect()
    }

    fn producer_row_norm(&self, site: usize, ord: u8) -> Vec<f32> {
        super::mlp::row_norms(&self.blocks[site].fc.w, ord)
    }

    fn producer_features(&self, site: usize) -> Tensor {
        self.blocks[site].fc.w.clone()
    }

    fn consumer_col_norms(&self, site: usize) -> Vec<f32> {
        self.blocks[site].proj.input_col_norms()
    }

    fn consumer_matrix(&self, site: usize) -> Tensor {
        self.blocks[site].proj.w.clone()
    }

    fn apply(&mut self, site: usize, plan: &ReductionPlan) {
        let blk = &mut self.blocks[site];
        super::mlp::apply_dense_pair(&mut blk.fc, &mut blk.proj, plan);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Compressible, ReductionPlan, Reducer};
    use crate::data::SynthVision;

    fn net() -> TinyViT {
        let mut rng = Pcg64::seed(5);
        TinyViT::init(VitConfig::default(), &mut rng)
    }

    fn imgs(n: usize) -> Tensor {
        SynthVision::new(7).generate(n).x
    }

    #[test]
    fn forward_shapes() {
        let m = net();
        let x = imgs(3);
        let (y, taps) = m.forward_with_taps(&x);
        assert_eq!(y.shape(), &[3, 10]);
        assert_eq!(taps.len(), 3);
        assert_eq!(taps[0].shape(), &[3 * 16, 128]);
        assert!(y.all_finite());
    }

    #[test]
    fn patchify_layout() {
        // A single-channel delta image lands in exactly one patch cell.
        let mut cfg = VitConfig::default();
        cfg.image = (1, 8, 8);
        cfg.patch = 4;
        let mut rng = Pcg64::seed(1);
        let m = TinyViT::init(cfg, &mut rng);
        let mut x = Tensor::zeros(&[1, 64]);
        // Pixel (y=5, x=2) -> token (1,0), offset (dy=1, dx=2).
        x.data_mut()[5 * 8 + 2] = 1.0;
        let p = m.patchify(&x);
        assert_eq!(p.shape(), &[4, 16]);
        assert_eq!(p.at2(2, 1 * 4 + 2), 1.0);
        let total: f32 = p.data().iter().sum();
        assert_eq!(total, 1.0);
    }

    #[test]
    fn bundle_roundtrip_preserves_function() {
        let m = net();
        let x = imgs(2);
        let y0 = m.forward(&x);
        let r = TinyViT::from_bundle(&m.to_bundle(), m.cfg).unwrap();
        assert!(y0.max_abs_diff(&r.forward(&x)) < 1e-5);
    }

    #[test]
    fn mlp_prune_keeps_width_consistency() {
        let mut m = net();
        m.apply(1, &ReductionPlan::bare(Reducer::Select((0..64).collect())));
        assert_eq!(m.blocks[1].fc.out_dim(), 64);
        assert_eq!(m.blocks[1].proj.in_dim(), 64);
        assert!(m.forward(&imgs(2)).all_finite());
    }

    #[test]
    fn full_selection_identity() {
        let mut m = net();
        let x = imgs(2);
        let y0 = m.forward(&x);
        m.apply(0, &ReductionPlan::bare(Reducer::Select((0..128).collect())));
        assert!(y0.max_abs_diff(&m.forward(&x)) < 1e-5);
    }

    #[test]
    fn staged_taps_match_forward_with_taps() {
        let m = net();
        let x = imgs(2);
        let (_, taps) = m.forward_with_taps(&x);
        for site in 0..m.blocks.len() {
            let staged = m.site_activations(&x, site);
            assert_eq!(staged, taps[site], "site {site}");
        }
    }

    #[test]
    fn attn_cache_reused_only_for_matching_site() {
        // tap(site 0) then segment through site 0 reuses the cache;
        // tapping a *different* site afterwards must not.
        let m = net();
        let x = imgs(2);
        let mut st = m.calib_begin(&x);
        let t0 = m.site_tap(&mut st, 0);
        m.forward_segment(&mut st, 0, 1);
        let t1 = m.site_tap(&mut st, 1);
        let (_, taps) = m.forward_with_taps(&x);
        assert_eq!(t0, taps[0]);
        assert_eq!(t1, taps[1]);
    }
}
