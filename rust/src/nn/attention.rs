//! Multi-head self-attention with optional grouped-query attention
//! (GQA) and head-structured compression hooks.
//!
//! The feature axis before the output projection factorizes as
//! `H = n_heads · d_head`; any width reduction must act at the head
//! level (paper §3.2). The *consumer input* GRAIL compensates here is
//! the concatenated per-head feature vector just before `w_o`, which
//! [`MultiHeadAttention::forward`] exposes as a tap.

use super::{Linear, Tensor};
use crate::coordinator::scheduler::{audit::WriteSet, default_threads, run_grid_mut};
use crate::rng::Pcg64;
use crate::tensor::gemm::Epilogue;
use crate::tensor::ops;

/// Copy a `rows × width` block out of a row-major matrix (`stride`
/// columns per row, starting at row `r0`, column `off`) into a
/// contiguous `dst` — the strided head-gather primitive shared by the
/// batched forward, the reference forward, and the KV-cache decode
/// path (which uses it to append projected K/V rows into per-head
/// cache panels).
pub(crate) fn gather_block(
    src: &[f32],
    stride: usize,
    r0: usize,
    off: usize,
    rows: usize,
    width: usize,
    dst: &mut [f32],
) {
    debug_assert_eq!(dst.len(), rows * width);
    for r in 0..rows {
        let s = (r0 + r) * stride + off;
        dst[r * width..(r + 1) * width].copy_from_slice(&src[s..s + width]);
    }
}

/// Inverse of [`gather_block`]: write a contiguous `rows × width`
/// block into a strided destination.
pub(crate) fn scatter_block(
    src: &[f32],
    dst: &mut [f32],
    stride: usize,
    r0: usize,
    off: usize,
    rows: usize,
    width: usize,
) {
    debug_assert_eq!(src.len(), rows * width);
    for r in 0..rows {
        let d = (r0 + r) * stride + off;
        dst[d..d + width].copy_from_slice(&src[r * width..(r + 1) * width]);
    }
}

/// Fused masked softmax over one score row: the `lim` live entries are
/// scaled and max-reduced in one in-place sweep, exponentiated and
/// summed in a second, normalized in a third, and the masked tail is
/// zeroed — no materialized `-∞` mask row, no per-row temporaries, no
/// separate scale pass. Bit-identical to the old mask-then-
/// [`softmax_rows`](super::softmax_rows) sequence: `exp(-∞) = +0.0`
/// contributes nothing to the max or the sum, and the zeroed tail is
/// exactly what those entries normalized to.
pub(crate) fn softmax_row_masked(row: &mut [f32], lim: usize, scale: f32) {
    // Hard contract, not a debug_assert: with `lim == 0` the
    // normalizer `z` is 0.0 and a release build would silently divide
    // the row into NaNs that flow straight into the context product.
    // An empty live prefix is reachable the moment a zero-length
    // request slips through batch admission, so it dies loudly in
    // every profile.
    assert!(lim > 0, "softmax_row_masked: empty live prefix (lim == 0) would emit a NaN row");
    assert!(
        lim <= row.len(),
        "softmax_row_masked: live prefix {lim} exceeds row of {} scores",
        row.len()
    );
    let mut mx = f32::NEG_INFINITY;
    for v in row[..lim].iter_mut() {
        *v *= scale;
        mx = mx.max(*v);
    }
    let mut z = 0.0f32;
    for v in row[..lim].iter_mut() {
        *v = (*v - mx).exp();
        z += *v;
    }
    let inv = 1.0 / z;
    for v in row[..lim].iter_mut() {
        *v *= inv;
    }
    row[lim..].fill(0.0);
}

/// Attend a gathered query panel `qp: [t, dh]` holding absolute
/// positions `p0..p0+t` over key/value panels `kc`/`vc: [len, dh]`
/// (the first `len` cached positions), accumulating the context into
/// `ctx: [t, dh]` (which must arrive zeroed — the GEMMs accumulate).
///
/// This one function is the entire attention math of the crate: the
/// batched forward calls it with `len == t, p0 == 0`, the serial
/// reference forward calls it identically, and `TinyLm` decode calls
/// it against cache prefixes. Score (`Q·Kᵀ`) and context
/// (`softmax·V`) products go through the row-count-invariant serving
/// GEMMs, so a 1-row decode step reproduces the forward's bits.
#[allow(clippy::too_many_arguments)]
pub(crate) fn attend_cached(
    qp: &[f32],
    kc: &[f32],
    vc: &[f32],
    t: usize,
    len: usize,
    dh: usize,
    p0: usize,
    causal: bool,
    ctx: &mut [f32],
) {
    debug_assert_eq!(qp.len(), t * dh);
    debug_assert_eq!(kc.len(), len * dh);
    debug_assert_eq!(vc.len(), len * dh);
    debug_assert_eq!(ctx.len(), t * dh);
    let scale = 1.0 / (dh as f32).sqrt();
    let mut scores = vec![0.0f32; t * len];
    ops::gemm_nt_serve(qp, kc, &mut scores, t, dh, len, Epilogue::None);
    for (i, row) in scores.chunks_mut(len).enumerate() {
        let lim = if causal { (p0 + i + 1).min(len) } else { len };
        softmax_row_masked(row, lim, scale);
    }
    ops::gemm_nn_serve(&scores, vc, ctx, t, len, dh);
}

/// Gather a paged K or V position stream into a contiguous `[len, dh]`
/// panel. `page(i)` returns the backing slice of the stream's `i`-th
/// page (a fixed-size pool page of `page_positions * dh` elements);
/// cached position `p` lives in page `p / page_positions` at row
/// `p % page_positions`, row-major over `dh`. The panel is rebuilt in
/// position order, so downstream attention sees exactly the layout the
/// slab decode path stores directly.
pub(crate) fn gather_paged<'p>(
    page: impl Fn(usize) -> &'p [f32],
    page_positions: usize,
    len: usize,
    dh: usize,
    dst: &mut Vec<f32>,
) {
    dst.clear();
    dst.reserve(len * dh);
    let (mut pos, mut pi) = (0usize, 0usize);
    while pos < len {
        let take = page_positions.min(len - pos);
        let pg = page(pi);
        debug_assert!(pg.len() >= take * dh, "page {pi} shorter than its live rows");
        dst.extend_from_slice(&pg[..take * dh]);
        pos += take;
        pi += 1;
    }
    debug_assert_eq!(dst.len(), len * dh);
}

/// [`attend_cached`] against *paged* K/V streams: gather the first
/// `len` cached positions of each stream into contiguous scratch
/// panels (`kbuf`/`vbuf`, reused across calls by the decode paths),
/// then delegate to [`attend_cached`] verbatim. Paged storage changes
/// where the cache bytes live, never what attention computes — the
/// paged decode path is bitwise identical to the slab path by
/// construction.
#[allow(clippy::too_many_arguments)]
pub(crate) fn attend_paged<'p>(
    qp: &[f32],
    k_page: impl Fn(usize) -> &'p [f32],
    v_page: impl Fn(usize) -> &'p [f32],
    page_positions: usize,
    t: usize,
    len: usize,
    dh: usize,
    p0: usize,
    causal: bool,
    kbuf: &mut Vec<f32>,
    vbuf: &mut Vec<f32>,
    ctx: &mut [f32],
) {
    gather_paged(k_page, page_positions, len, dh, kbuf);
    gather_paged(v_page, page_positions, len, dh, vbuf);
    attend_cached(qp, kbuf, vbuf, t, len, dh, p0, causal, ctx);
}

/// Self-attention block. Weight layout (matching the Python side):
/// `wq: [n_heads·d_head, d_model]`, `wk/wv: [n_kv·d_head, d_model]`,
/// `wo: [d_model, n_heads·d_head]`. For plain MHA, `n_kv == n_heads`;
/// for GQA, `n_heads` is a multiple of `n_kv` and query head `h` reads
/// KV head `h / (n_heads / n_kv)`.
#[derive(Clone, Debug)]
pub struct MultiHeadAttention {
    pub wq: Linear,
    pub wk: Linear,
    pub wv: Linear,
    pub wo: Linear,
    pub n_heads: usize,
    pub n_kv: usize,
    pub d_head: usize,
    pub causal: bool,
}

impl MultiHeadAttention {
    /// Random-initialized attention (Rust-side tests).
    pub fn init(
        d_model: usize,
        n_heads: usize,
        n_kv: usize,
        d_head: usize,
        causal: bool,
        rng: &mut Pcg64,
    ) -> Self {
        assert!(n_heads % n_kv == 0, "query heads must be a multiple of KV heads");
        MultiHeadAttention {
            wq: Linear::init(n_heads * d_head, d_model, rng),
            wk: Linear::init(n_kv * d_head, d_model, rng),
            wv: Linear::init(n_kv * d_head, d_model, rng),
            wo: Linear::init(d_model, n_heads * d_head, rng),
            n_heads,
            n_kv,
            d_head,
            causal,
        }
    }

    /// Scalar parameter count (q/k/v/o projections).
    pub fn param_count(&self) -> usize {
        self.wq.param_count()
            + self.wk.param_count()
            + self.wv.param_count()
            + self.wo.param_count()
    }

    /// Query heads per KV head.
    pub fn group_size(&self) -> usize {
        self.n_heads / self.n_kv
    }

    /// Feature width before the output projection.
    pub fn feat_width(&self) -> usize {
        self.n_heads * self.d_head
    }

    /// Forward over `[b*t, d_model]` rows laid out batch-major.
    /// Returns `(output [b*t, d_model], tap [b*t, n_heads*d_head])`
    /// where the tap is the concatenated per-head context — the
    /// consumer input of `w_o`.
    ///
    /// Batched execution: every `(batch, head)` Q/K/V panel is gathered
    /// once into contiguous head-major buffers (strided block copies,
    /// [`gather_block`]), then the score/context products run as one
    /// [`attend_cached`] job per `(batch, head)` fanned over
    /// [`run_grid_mut`] under the scheduler's divided thread budget.
    /// Each job owns a disjoint context panel and reads only its own
    /// input panels, so the fan-out is bit-identical at any worker
    /// count. Deliberate tradeoff: the causal path computes the full
    /// `t×t` score product and discards the masked half — branch-free
    /// GEMM beats triangular skip loops at these sequence lengths; a
    /// triangular-blocked variant is the upgrade path if `t` grows
    /// past that crossover.
    pub fn forward(&self, x: &Tensor, b: usize, t: usize) -> (Tensor, Tensor) {
        let rows = b * t;
        assert_eq!(x.dim(0), rows, "rows must equal b*t");
        let q = self.wq.forward(x); // [rows, n_heads*dh]
        let k = self.wk.forward(x); // [rows, n_kv*dh]
        let v = self.wv.forward(x);
        let tap = self.attend_batched(&q, &k, &v, b, t);
        let y = self.wo.forward(&tap);
        (y, tap)
    }

    /// The batched attention core: head-major gathers, then one
    /// [`attend_cached`] job per `(batch, head)` context panel.
    fn attend_batched(&self, q: &Tensor, k: &Tensor, v: &Tensor, b: usize, t: usize) -> Tensor {
        let (nh, nkv, dh) = (self.n_heads, self.n_kv, self.d_head);
        let rows = b * t;
        let mut tap = Tensor::zeros(&[rows, nh * dh]);
        if rows == 0 {
            return tap;
        }
        let gs = self.group_size();
        let hd = t * dh; // elements per (batch, head) panel
        let mut qg = vec![0.0f32; b * nh * hd];
        let mut kg = vec![0.0f32; b * nkv * hd];
        let mut vg = vec![0.0f32; b * nkv * hd];
        for bi in 0..b {
            for h in 0..nh {
                let dst = &mut qg[(bi * nh + h) * hd..(bi * nh + h + 1) * hd];
                gather_block(q.data(), nh * dh, bi * t, h * dh, t, dh, dst);
            }
            for h in 0..nkv {
                let dst = &mut kg[(bi * nkv + h) * hd..(bi * nkv + h + 1) * hd];
                gather_block(k.data(), nkv * dh, bi * t, h * dh, t, dh, dst);
                let dst = &mut vg[(bi * nkv + h) * hd..(bi * nkv + h + 1) * hd];
                gather_block(v.data(), nkv * dh, bi * t, h * dh, t, dh, dst);
            }
        }
        // One job per (batch, head): disjoint output panels whose
        // values depend only on that job's own input panels — the
        // worker count can never change the bits. The write-set
        // auditor asserts the head-major scatter panels tile `ctx`
        // (debug/audit builds only).
        let mut ctx = vec![0.0f32; b * nh * hd];
        let ws = WriteSet::new("attention context head panels", ctx.len());
        let (qg, kg, vg) = (&qg, &kg, &vg);
        let mut jobs: Vec<(usize, &mut [f32])> = ctx.chunks_mut(hd).enumerate().collect();
        let workers = default_threads().clamp(1, jobs.len());
        run_grid_mut(&mut jobs, workers, |_, job| {
            ws.claim(job.0, job.0 * hd, job.1.len());
            let (bi, h) = (job.0 / nh, job.0 % nh);
            let qp = &qg[(bi * nh + h) * hd..(bi * nh + h + 1) * hd];
            let kp = &kg[(bi * nkv + h / gs) * hd..(bi * nkv + h / gs + 1) * hd];
            let vp = &vg[(bi * nkv + h / gs) * hd..(bi * nkv + h / gs + 1) * hd];
            let cp: &mut [f32] = &mut *job.1;
            attend_cached(qp, kp, vp, t, t, dh, 0, self.causal, cp);
        });
        ws.verify();
        for bi in 0..b {
            for h in 0..nh {
                let src = &ctx[(bi * nh + h) * hd..(bi * nh + h + 1) * hd];
                scatter_block(src, tap.data_mut(), nh * dh, bi * t, h * dh, t, dh);
            }
        }
        tap
    }

    /// Reference forward: the same gathers, serving GEMMs, and fused
    /// softmax as [`Self::forward`], executed serially per
    /// `(batch, head)` with no fan-out — the conformance oracle the
    /// batched path is asserted **bit-identical** against (it shares
    /// [`gather_block`] / [`attend_cached`] verbatim, so the only
    /// thing it checks — and the only thing that could differ — is the
    /// batching and scheduling structure).
    pub fn forward_ref(&self, x: &Tensor, b: usize, t: usize) -> (Tensor, Tensor) {
        let rows = b * t;
        assert_eq!(x.dim(0), rows, "rows must equal b*t");
        let (nh, nkv, dh) = (self.n_heads, self.n_kv, self.d_head);
        let gs = self.group_size();
        let q = self.wq.forward(x);
        let k = self.wk.forward(x);
        let v = self.wv.forward(x);
        let mut tap = Tensor::zeros(&[rows, nh * dh]);
        let mut qp = vec![0.0f32; t * dh];
        let mut kp = vec![0.0f32; t * dh];
        let mut vp = vec![0.0f32; t * dh];
        let mut ctx = vec![0.0f32; t * dh];
        for bi in 0..b {
            for h in 0..nh {
                gather_block(q.data(), nh * dh, bi * t, h * dh, t, dh, &mut qp);
                // Query heads of one KV group are consecutive, so the
                // shared K/V panels only need gathering once per group.
                if h % gs == 0 {
                    let kvh = h / gs;
                    gather_block(k.data(), nkv * dh, bi * t, kvh * dh, t, dh, &mut kp);
                    gather_block(v.data(), nkv * dh, bi * t, kvh * dh, t, dh, &mut vp);
                }
                ctx.fill(0.0);
                attend_cached(&qp, &kp, &vp, t, t, dh, 0, self.causal, &mut ctx);
                scatter_block(&ctx, tap.data_mut(), nh * dh, bi * t, h * dh, t, dh);
            }
        }
        let y = self.wo.forward(&tap);
        (y, tap)
    }

    /// Keep query heads `heads` (sorted ascending; for GQA the caller
    /// must keep an equal count per KV group — validated here). The
    /// output projection is narrowed separately by the compression
    /// plan (selection or a GRAIL merge).
    pub fn select_heads(&mut self, heads: &[usize]) {
        assert!(!heads.is_empty(), "cannot remove all heads");
        assert!(heads.windows(2).all(|w| w[0] < w[1]), "heads must be sorted unique");
        let gs = self.group_size();
        if gs > 1 {
            // True GQA: equal per-group counts keep the mapping valid.
            let mut per_group = vec![0usize; self.n_kv];
            for &h in heads {
                assert!(h < self.n_heads);
                per_group[h / gs] += 1;
            }
            let k0 = per_group[0];
            assert!(
                per_group.iter().all(|&c| c == k0) && k0 > 0,
                "GQA head selection must keep an equal, nonzero count per KV group: {per_group:?}"
            );
        }
        let dh = self.d_head;
        let rows: Vec<usize> =
            heads.iter().flat_map(|&h| h * dh..(h + 1) * dh).collect();
        self.wq.select_outputs(&rows);
        if gs == 1 {
            // Plain MHA: each query head owns its KV head — prune those
            // too so the head mapping stays 1:1.
            self.wk.select_outputs(&rows);
            self.wv.select_outputs(&rows);
            self.n_kv = heads.len();
        }
        self.n_heads = heads.len();
    }

    /// Fold query heads by cluster averaging (`assign[h] = cluster`).
    /// For GQA, clusters must not cross KV groups (validated).
    pub fn fold_heads(&mut self, assign: &[usize], k_total: usize) {
        assert_eq!(assign.len(), self.n_heads);
        let gs = self.group_size();
        if gs > 1 {
            // True GQA: each cluster must live inside one KV group.
            let mut cluster_group = vec![usize::MAX; k_total];
            for (h, &k) in assign.iter().enumerate() {
                let g = h / gs;
                if cluster_group[k] == usize::MAX {
                    cluster_group[k] = g;
                } else {
                    assert_eq!(
                        cluster_group[k], g,
                        "GQA head folding must not merge heads across KV groups"
                    );
                }
            }
        }
        let dh = self.d_head;
        // Lift head assignment to the feature axis (Kronecker with I_dh):
        // feature row h*dh+j folds into cluster k*dh+j.
        let feat_assign: Vec<usize> = (0..self.n_heads * dh)
            .map(|r| assign[r / dh] * dh + (r % dh))
            .collect();
        self.wq.fold_outputs(&feat_assign, k_total * dh);
        if gs == 1 {
            // Plain MHA: fold the 1:1 KV heads the same way.
            self.wk.fold_outputs(&feat_assign, k_total * dh);
            self.wv.fold_outputs(&feat_assign, k_total * dh);
            self.n_kv = k_total;
        } else {
            // True GQA: clusters stay within groups; group blocks must
            // remain contiguous and balanced so `group_size` is valid.
            assert_eq!(
                k_total % self.n_kv,
                0,
                "GQA folding must keep an equal cluster count per KV group"
            );
        }
        self.n_heads = k_total;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops;

    fn small_attn(causal: bool) -> MultiHeadAttention {
        let mut rng = Pcg64::seed(42);
        MultiHeadAttention::init(8, 4, 4, 2, causal, &mut rng)
    }

    fn randx(rows: usize, d: usize, seed: u64) -> Tensor {
        let mut rng = Pcg64::seed(seed);
        let mut x = Tensor::zeros(&[rows, d]);
        rng.fill_normal(x.data_mut(), 1.0);
        x
    }

    #[test]
    fn batched_forward_matches_reference_bitwise() {
        // MHA and true GQA, causal and not: the run_grid_mut fan-out
        // must reproduce the serial per-head loop exactly.
        for (nh, nkv, causal, seed) in [(4, 4, true, 11), (4, 2, true, 12), (4, 2, false, 13)] {
            let mut rng = Pcg64::seed(seed);
            let a = MultiHeadAttention::init(8, nh, nkv, 2, causal, &mut rng);
            let x = randx(3 * 5, 8, seed + 100);
            let (y, tap) = a.forward(&x, 3, 5);
            let (yr, tapr) = a.forward_ref(&x, 3, 5);
            for (p, q) in y.data().iter().zip(yr.data()) {
                assert_eq!(p.to_bits(), q.to_bits(), "y nh={nh} nkv={nkv} causal={causal}");
            }
            for (p, q) in tap.data().iter().zip(tapr.data()) {
                assert_eq!(p.to_bits(), q.to_bits(), "tap nh={nh} nkv={nkv}");
            }
        }
    }

    #[test]
    fn fused_softmax_matches_mask_then_softmax_rows() {
        let mut rng = Pcg64::seed(21);
        for lim in 1..=7usize {
            let n = 7usize;
            let mut row = vec![0.0f32; n];
            rng.fill_normal(&mut row, 2.0);
            let scale = 0.37f32;
            // Old path: scale live entries, -∞ the tail, softmax_rows.
            let mut old = Tensor::from_vec(&[1, n], row.clone());
            for v in old.row_mut(0)[..lim].iter_mut() {
                *v *= scale;
            }
            for v in old.row_mut(0)[lim..].iter_mut() {
                *v = f32::NEG_INFINITY;
            }
            crate::nn::softmax_rows(&mut old);
            softmax_row_masked(&mut row, lim, scale);
            for (f, o) in row.iter().zip(old.data()) {
                assert_eq!(f.to_bits(), o.to_bits(), "lim={lim}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "empty live prefix")]
    fn softmax_empty_prefix_panics_in_every_profile() {
        // Regression for the release-build NaN row: lim == 0 used to
        // be guarded only by a debug_assert, so optimized builds
        // divided by z = 0 and emitted NaNs silently. The contract is
        // now a hard assert — this test runs in the release CI pass.
        let mut row = vec![1.0f32, 2.0, 3.0];
        softmax_row_masked(&mut row, 0, 1.0);
    }

    #[test]
    #[should_panic(expected = "exceeds row")]
    fn softmax_oversized_prefix_panics() {
        let mut row = vec![1.0f32, 2.0];
        softmax_row_masked(&mut row, 3, 1.0);
    }

    #[test]
    fn gather_paged_reassembles_slab_layout() {
        // Chop a [len, dh] slab into fixed-size position pages, then
        // gather them back: the panel must equal the slab prefix for
        // every (len, page_positions) shape, including partial tails.
        let dh = 3usize;
        let slab: Vec<f32> = (0..13 * dh).map(|i| i as f32).collect();
        for ps in [1usize, 2, 4, 5, 16] {
            let pages: Vec<Vec<f32>> = slab
                .chunks(ps * dh)
                .map(|c| {
                    // Pool pages are fixed-size; the tail page's dead
                    // rows hold garbage the gather must never read.
                    let mut p = vec![f32::NAN; ps * dh];
                    p[..c.len()].copy_from_slice(c);
                    p
                })
                .collect();
            for len in [0usize, 1, 4, 7, 13] {
                let mut panel = Vec::new();
                gather_paged(|i| pages[i].as_slice(), ps, len, dh, &mut panel);
                assert_eq!(panel.len(), len * dh, "ps={ps} len={len}");
                for (a, b) in panel.iter().zip(&slab[..len * dh]) {
                    assert_eq!(a.to_bits(), b.to_bits(), "ps={ps} len={len}");
                }
            }
        }
    }

    #[test]
    fn attend_paged_matches_attend_cached_bitwise() {
        let (t, len, dh, ps) = (2usize, 11usize, 4usize, 4usize);
        let mut rng = Pcg64::seed(33);
        let mut qp = vec![0.0f32; t * dh];
        let mut kc = vec![0.0f32; len * dh];
        let mut vc = vec![0.0f32; len * dh];
        rng.fill_normal(&mut qp, 1.0);
        rng.fill_normal(&mut kc, 1.0);
        rng.fill_normal(&mut vc, 1.0);
        let page_of = |slab: &[f32]| -> Vec<Vec<f32>> {
            slab.chunks(ps * dh)
                .map(|c| {
                    let mut p = vec![0.0f32; ps * dh];
                    p[..c.len()].copy_from_slice(c);
                    p
                })
                .collect()
        };
        let (kp, vp) = (page_of(&kc), page_of(&vc));
        for causal in [true, false] {
            let p0 = len - t;
            let mut want = vec![0.0f32; t * dh];
            attend_cached(&qp, &kc, &vc, t, len, dh, p0, causal, &mut want);
            let mut got = vec![0.0f32; t * dh];
            let (mut kbuf, mut vbuf) = (Vec::new(), Vec::new());
            attend_paged(
                &qp,
                |i| kp[i].as_slice(),
                |i| vp[i].as_slice(),
                ps,
                t,
                len,
                dh,
                p0,
                causal,
                &mut kbuf,
                &mut vbuf,
                &mut got,
            );
            for (a, b) in got.iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits(), "causal={causal}");
            }
        }
    }

    #[test]
    fn chunked_attend_rows_match_one_shot_bitwise() {
        // The chunked-prefill primitive: attending rows [p0, p0+tc)
        // with len = p0+tc (causal) must reproduce those rows of the
        // one-shot t = len pass exactly. The trailing keys the one-shot
        // pass scores for these rows are causally masked to exact 0.0
        // weights, and the scalar context dot's trailing += 0.0·v
        // terms cannot change finite sums.
        let (len, dh) = (13usize, 8usize);
        let mut rng = Pcg64::seed(44);
        let mut qp = vec![0.0f32; len * dh];
        let mut kc = vec![0.0f32; len * dh];
        let mut vc = vec![0.0f32; len * dh];
        rng.fill_normal(&mut qp, 1.0);
        rng.fill_normal(&mut kc, 1.0);
        rng.fill_normal(&mut vc, 1.0);
        let mut want = vec![0.0f32; len * dh];
        attend_cached(&qp, &kc, &vc, len, len, dh, 0, true, &mut want);
        for chunk in [1usize, 4, 5, 13] {
            let mut got = vec![0.0f32; len * dh];
            let mut p0 = 0usize;
            while p0 < len {
                let tc = chunk.min(len - p0);
                let seen = p0 + tc;
                attend_cached(
                    &qp[p0 * dh..seen * dh],
                    &kc[..seen * dh],
                    &vc[..seen * dh],
                    tc,
                    seen,
                    dh,
                    p0,
                    true,
                    &mut got[p0 * dh..seen * dh],
                );
                p0 = seen;
            }
            for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "chunk={chunk} elem={i}");
            }
        }
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let stride = 6usize;
        let src: Vec<f32> = (0..5 * stride).map(|i| i as f32).collect();
        let mut blk = vec![0.0f32; 3 * 2];
        gather_block(&src, stride, 1, 4, 3, 2, &mut blk);
        assert_eq!(blk, vec![10.0, 11.0, 16.0, 17.0, 22.0, 23.0]);
        let mut back = vec![-1.0f32; 5 * stride];
        scatter_block(&blk, &mut back, stride, 1, 4, 3, 2);
        for r in 1..4 {
            assert_eq!(back[r * stride + 4], src[r * stride + 4]);
            assert_eq!(back[r * stride + 5], src[r * stride + 5]);
        }
        assert_eq!(back[0], -1.0, "untouched rows stay put");
    }

    #[test]
    fn output_shapes() {
        let a = small_attn(true);
        let x = randx(2 * 5, 8, 1);
        let (y, tap) = a.forward(&x, 2, 5);
        assert_eq!(y.shape(), &[10, 8]);
        assert_eq!(tap.shape(), &[10, 8]); // 4 heads * 2
        assert!(y.all_finite());
    }

    #[test]
    fn causal_first_token_attends_only_self() {
        let a = small_attn(true);
        let mut x1 = randx(4, 8, 2); // b=1, t=4
        let (y_full, _) = a.forward(&x1, 1, 4);
        // Changing later tokens must not affect position 0.
        for v in x1.data_mut()[8..].iter_mut() {
            *v += 10.0;
        }
        let (y_mod, _) = a.forward(&x1, 1, 4);
        for j in 0..8 {
            assert!((y_full.at2(0, j) - y_mod.at2(0, j)).abs() < 1e-5);
        }
        // ...but does affect later positions.
        assert!((0..8).any(|j| (y_full.at2(3, j) - y_mod.at2(3, j)).abs() > 1e-3));
    }

    #[test]
    fn non_causal_is_permutation_sensitive_but_full_context() {
        let a = small_attn(false);
        let mut x = randx(3, 8, 3);
        let (y0, _) = a.forward(&x, 1, 3);
        for v in x.data_mut()[16..].iter_mut() {
            *v += 5.0;
        }
        let (y1, _) = a.forward(&x, 1, 3);
        // Position 0 IS affected without the causal mask.
        assert!((0..8).any(|j| (y0.at2(0, j) - y1.at2(0, j)).abs() > 1e-3));
    }

    #[test]
    fn tap_feeds_output_projection() {
        let a = small_attn(true);
        let x = randx(6, 8, 4);
        let (y, tap) = a.forward(&x, 1, 6);
        let y2 = a.wo.forward(&tap);
        assert!(y.max_abs_diff(&y2) < 1e-6);
    }

    #[test]
    fn gqa_matches_mha_with_duplicated_kv() {
        // A GQA layer must equal a plain MHA layer whose KV weight rows
        // duplicate each KV head `group_size` times.
        let mut rng = Pcg64::seed(7);
        let gqa = MultiHeadAttention::init(8, 4, 2, 2, true, &mut rng);
        let dh = 2;
        // kv head of query head h is h / 2 -> duplication order 0,0,1,1.
        let kv_rows: Vec<usize> = [0usize, 0, 1, 1]
            .iter()
            .flat_map(|&h| (h * dh)..(h + 1) * dh)
            .collect();
        let mut mha = gqa.clone();
        mha.n_kv = 4;
        mha.wk.w = ops::gather_rows(&gqa.wk.w, &kv_rows);
        mha.wv.w = ops::gather_rows(&gqa.wv.w, &kv_rows);
        let kb: Vec<f32> = kv_rows.iter().map(|&r| gqa.wk.b.data()[r]).collect();
        let vb: Vec<f32> = kv_rows.iter().map(|&r| gqa.wv.b.data()[r]).collect();
        mha.wk.b = Tensor::from_vec(&[8], kb);
        mha.wv.b = Tensor::from_vec(&[8], vb);
        let x = randx(5, 8, 8);
        let (yg, _) = gqa.forward(&x, 1, 5);
        let (ym, _) = mha.forward(&x, 1, 5);
        assert!(yg.max_abs_diff(&ym) < 1e-5);
    }

    #[test]
    fn select_heads_drops_tap_features() {
        let a = small_attn(true);
        let x = randx(4, 8, 5);
        let (_, tap_full) = a.forward(&x, 1, 4);
        let mut pruned = a.clone();
        pruned.select_heads(&[1, 3]);
        pruned.wo.select_inputs(&[2, 3, 6, 7]); // features of heads 1,3
        let (_, tap) = pruned.forward(&x, 1, 4);
        assert_eq!(tap.shape(), &[4, 4]);
        // Kept heads compute identical features.
        for r in 0..4 {
            assert!((tap.at2(r, 0) - tap_full.at2(r, 2)).abs() < 1e-5);
            assert!((tap.at2(r, 3) - tap_full.at2(r, 7)).abs() < 1e-5);
        }
    }

    #[test]
    #[should_panic(expected = "equal, nonzero count per KV group")]
    fn gqa_unbalanced_selection_panics() {
        let mut rng = Pcg64::seed(9);
        let mut a = MultiHeadAttention::init(8, 4, 2, 2, true, &mut rng);
        a.select_heads(&[0, 1, 2]); // group0 keeps 2, group1 keeps 1
    }

    #[test]
    #[should_panic(expected = "across KV groups")]
    fn gqa_cross_group_fold_panics() {
        let mut rng = Pcg64::seed(10);
        let mut a = MultiHeadAttention::init(8, 4, 2, 2, true, &mut rng);
        a.fold_heads(&[0, 0, 0, 1], 2); // head 2 (group1) folded with group0
    }

    #[test]
    fn fold_heads_averages_query_rows() {
        let mut a = small_attn(true);
        let r0 = a.wq.w.row(0).to_vec();
        let r2 = a.wq.w.row(4).to_vec(); // head 2, feature 0
        a.fold_heads(&[0, 1, 0, 1], 2);
        assert_eq!(a.n_heads, 2);
        for j in 0..8 {
            let want = (r0[j] + r2[j]) / 2.0;
            assert!((a.wq.w.at2(0, j) - want).abs() < 1e-6);
        }
    }
}
