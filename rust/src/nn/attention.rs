//! Multi-head self-attention with optional grouped-query attention
//! (GQA) and head-structured compression hooks.
//!
//! The feature axis before the output projection factorizes as
//! `H = n_heads · d_head`; any width reduction must act at the head
//! level (paper §3.2). The *consumer input* GRAIL compensates here is
//! the concatenated per-head feature vector just before `w_o`, which
//! [`MultiHeadAttention::forward`] exposes as a tap.

use super::{softmax_rows, Linear, Tensor};
use crate::rng::Pcg64;
use crate::tensor::ops;

/// Self-attention block. Weight layout (matching the Python side):
/// `wq: [n_heads·d_head, d_model]`, `wk/wv: [n_kv·d_head, d_model]`,
/// `wo: [d_model, n_heads·d_head]`. For plain MHA, `n_kv == n_heads`;
/// for GQA, `n_heads` is a multiple of `n_kv` and query head `h` reads
/// KV head `h / (n_heads / n_kv)`.
#[derive(Clone, Debug)]
pub struct MultiHeadAttention {
    pub wq: Linear,
    pub wk: Linear,
    pub wv: Linear,
    pub wo: Linear,
    pub n_heads: usize,
    pub n_kv: usize,
    pub d_head: usize,
    pub causal: bool,
}

impl MultiHeadAttention {
    /// Random-initialized attention (Rust-side tests).
    pub fn init(
        d_model: usize,
        n_heads: usize,
        n_kv: usize,
        d_head: usize,
        causal: bool,
        rng: &mut Pcg64,
    ) -> Self {
        assert!(n_heads % n_kv == 0, "query heads must be a multiple of KV heads");
        MultiHeadAttention {
            wq: Linear::init(n_heads * d_head, d_model, rng),
            wk: Linear::init(n_kv * d_head, d_model, rng),
            wv: Linear::init(n_kv * d_head, d_model, rng),
            wo: Linear::init(d_model, n_heads * d_head, rng),
            n_heads,
            n_kv,
            d_head,
            causal,
        }
    }

    /// Scalar parameter count (q/k/v/o projections).
    pub fn param_count(&self) -> usize {
        self.wq.param_count()
            + self.wk.param_count()
            + self.wv.param_count()
            + self.wo.param_count()
    }

    /// Query heads per KV head.
    pub fn group_size(&self) -> usize {
        self.n_heads / self.n_kv
    }

    /// Feature width before the output projection.
    pub fn feat_width(&self) -> usize {
        self.n_heads * self.d_head
    }

    /// Forward over `[b*t, d_model]` rows laid out batch-major.
    /// Returns `(output [b*t, d_model], tap [b*t, n_heads*d_head])`
    /// where the tap is the concatenated per-head context — the
    /// consumer input of `w_o`.
    ///
    /// Score and context products run as per-(batch, head) GEMMs
    /// (`ops::matmul_nt` / `ops::matmul`) over contiguous head panels
    /// gathered from the projection outputs, so long-sequence shapes
    /// reach the packed engine instead of strided per-element dot
    /// loops; the causal mask is applied on the score matrix before the
    /// softmax, exactly as the strided loops did. Deliberate tradeoff:
    /// the causal path computes the full `t×t` product and discards the
    /// masked half — branch-free GEMM beats triangular skip loops at
    /// these sequence lengths; a triangular-blocked variant is the
    /// upgrade path if `t` grows past that crossover.
    pub fn forward(&self, x: &Tensor, b: usize, t: usize) -> (Tensor, Tensor) {
        let rows = b * t;
        assert_eq!(x.dim(0), rows, "rows must equal b*t");
        let dh = self.d_head;
        let q = self.wq.forward(x); // [rows, n_heads*dh]
        let k = self.wk.forward(x); // [rows, n_kv*dh]
        let v = self.wv.forward(x);
        let scale = 1.0 / (dh as f32).sqrt();
        let mut tap = Tensor::zeros(&[rows, self.n_heads * dh]);
        let gs = self.group_size();
        let mut qh = Tensor::zeros(&[t, dh]);
        let mut kh = Tensor::zeros(&[t, dh]);
        let mut vh = Tensor::zeros(&[t, dh]);
        for bi in 0..b {
            for h in 0..self.n_heads {
                let kvh = h / gs;
                for ti in 0..t {
                    let r = bi * t + ti;
                    qh.row_mut(ti).copy_from_slice(&q.row(r)[h * dh..(h + 1) * dh]);
                }
                // Query heads of one KV group are consecutive, so the
                // shared K/V panels only need gathering once per group.
                if h % gs == 0 {
                    for ti in 0..t {
                        let r = bi * t + ti;
                        kh.row_mut(ti).copy_from_slice(&k.row(r)[kvh * dh..(kvh + 1) * dh]);
                        vh.row_mut(ti).copy_from_slice(&v.row(r)[kvh * dh..(kvh + 1) * dh]);
                    }
                }
                // Scores for this (batch, head): [t, t] = Qh · Khᵀ.
                let mut scores = ops::matmul_nt(&qh, &kh);
                for ti in 0..t {
                    let srow = scores.row_mut(ti);
                    let lim = if self.causal { ti + 1 } else { t };
                    for sv in srow[..lim].iter_mut() {
                        *sv *= scale;
                    }
                    for sv in srow[lim..].iter_mut() {
                        *sv = f32::NEG_INFINITY;
                    }
                }
                softmax_rows(&mut scores);
                // Context = scores · V_head, back into the tap panel.
                let ctx = ops::matmul(&scores, &vh);
                for ti in 0..t {
                    tap.row_mut(bi * t + ti)[h * dh..(h + 1) * dh]
                        .copy_from_slice(ctx.row(ti));
                }
            }
        }
        let y = self.wo.forward(&tap);
        (y, tap)
    }

    /// Keep query heads `heads` (sorted ascending; for GQA the caller
    /// must keep an equal count per KV group — validated here). The
    /// output projection is narrowed separately by the compression
    /// plan (selection or a GRAIL merge).
    pub fn select_heads(&mut self, heads: &[usize]) {
        assert!(!heads.is_empty(), "cannot remove all heads");
        assert!(heads.windows(2).all(|w| w[0] < w[1]), "heads must be sorted unique");
        let gs = self.group_size();
        if gs > 1 {
            // True GQA: equal per-group counts keep the mapping valid.
            let mut per_group = vec![0usize; self.n_kv];
            for &h in heads {
                assert!(h < self.n_heads);
                per_group[h / gs] += 1;
            }
            let k0 = per_group[0];
            assert!(
                per_group.iter().all(|&c| c == k0) && k0 > 0,
                "GQA head selection must keep an equal, nonzero count per KV group: {per_group:?}"
            );
        }
        let dh = self.d_head;
        let rows: Vec<usize> =
            heads.iter().flat_map(|&h| h * dh..(h + 1) * dh).collect();
        self.wq.select_outputs(&rows);
        if gs == 1 {
            // Plain MHA: each query head owns its KV head — prune those
            // too so the head mapping stays 1:1.
            self.wk.select_outputs(&rows);
            self.wv.select_outputs(&rows);
            self.n_kv = heads.len();
        }
        self.n_heads = heads.len();
    }

    /// Fold query heads by cluster averaging (`assign[h] = cluster`).
    /// For GQA, clusters must not cross KV groups (validated).
    pub fn fold_heads(&mut self, assign: &[usize], k_total: usize) {
        assert_eq!(assign.len(), self.n_heads);
        let gs = self.group_size();
        if gs > 1 {
            // True GQA: each cluster must live inside one KV group.
            let mut cluster_group = vec![usize::MAX; k_total];
            for (h, &k) in assign.iter().enumerate() {
                let g = h / gs;
                if cluster_group[k] == usize::MAX {
                    cluster_group[k] = g;
                } else {
                    assert_eq!(
                        cluster_group[k], g,
                        "GQA head folding must not merge heads across KV groups"
                    );
                }
            }
        }
        let dh = self.d_head;
        // Lift head assignment to the feature axis (Kronecker with I_dh):
        // feature row h*dh+j folds into cluster k*dh+j.
        let feat_assign: Vec<usize> = (0..self.n_heads * dh)
            .map(|r| assign[r / dh] * dh + (r % dh))
            .collect();
        self.wq.fold_outputs(&feat_assign, k_total * dh);
        if gs == 1 {
            // Plain MHA: fold the 1:1 KV heads the same way.
            self.wk.fold_outputs(&feat_assign, k_total * dh);
            self.wv.fold_outputs(&feat_assign, k_total * dh);
            self.n_kv = k_total;
        } else {
            // True GQA: clusters stay within groups; group blocks must
            // remain contiguous and balanced so `group_size` is valid.
            assert_eq!(
                k_total % self.n_kv,
                0,
                "GQA folding must keep an equal cluster count per KV group"
            );
        }
        self.n_heads = k_total;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops;

    fn small_attn(causal: bool) -> MultiHeadAttention {
        let mut rng = Pcg64::seed(42);
        MultiHeadAttention::init(8, 4, 4, 2, causal, &mut rng)
    }

    fn randx(rows: usize, d: usize, seed: u64) -> Tensor {
        let mut rng = Pcg64::seed(seed);
        let mut x = Tensor::zeros(&[rows, d]);
        rng.fill_normal(x.data_mut(), 1.0);
        x
    }

    #[test]
    fn output_shapes() {
        let a = small_attn(true);
        let x = randx(2 * 5, 8, 1);
        let (y, tap) = a.forward(&x, 2, 5);
        assert_eq!(y.shape(), &[10, 8]);
        assert_eq!(tap.shape(), &[10, 8]); // 4 heads * 2
        assert!(y.all_finite());
    }

    #[test]
    fn causal_first_token_attends_only_self() {
        let a = small_attn(true);
        let mut x1 = randx(4, 8, 2); // b=1, t=4
        let (y_full, _) = a.forward(&x1, 1, 4);
        // Changing later tokens must not affect position 0.
        for v in x1.data_mut()[8..].iter_mut() {
            *v += 10.0;
        }
        let (y_mod, _) = a.forward(&x1, 1, 4);
        for j in 0..8 {
            assert!((y_full.at2(0, j) - y_mod.at2(0, j)).abs() < 1e-5);
        }
        // ...but does affect later positions.
        assert!((0..8).any(|j| (y_full.at2(3, j) - y_mod.at2(3, j)).abs() > 1e-3));
    }

    #[test]
    fn non_causal_is_permutation_sensitive_but_full_context() {
        let a = small_attn(false);
        let mut x = randx(3, 8, 3);
        let (y0, _) = a.forward(&x, 1, 3);
        for v in x.data_mut()[16..].iter_mut() {
            *v += 5.0;
        }
        let (y1, _) = a.forward(&x, 1, 3);
        // Position 0 IS affected without the causal mask.
        assert!((0..8).any(|j| (y0.at2(0, j) - y1.at2(0, j)).abs() > 1e-3));
    }

    #[test]
    fn tap_feeds_output_projection() {
        let a = small_attn(true);
        let x = randx(6, 8, 4);
        let (y, tap) = a.forward(&x, 1, 6);
        let y2 = a.wo.forward(&tap);
        assert!(y.max_abs_diff(&y2) < 1e-6);
    }

    #[test]
    fn gqa_matches_mha_with_duplicated_kv() {
        // A GQA layer must equal a plain MHA layer whose KV weight rows
        // duplicate each KV head `group_size` times.
        let mut rng = Pcg64::seed(7);
        let gqa = MultiHeadAttention::init(8, 4, 2, 2, true, &mut rng);
        let dh = 2;
        // kv head of query head h is h / 2 -> duplication order 0,0,1,1.
        let kv_rows: Vec<usize> = [0usize, 0, 1, 1]
            .iter()
            .flat_map(|&h| (h * dh)..(h + 1) * dh)
            .collect();
        let mut mha = gqa.clone();
        mha.n_kv = 4;
        mha.wk.w = ops::gather_rows(&gqa.wk.w, &kv_rows);
        mha.wv.w = ops::gather_rows(&gqa.wv.w, &kv_rows);
        let kb: Vec<f32> = kv_rows.iter().map(|&r| gqa.wk.b.data()[r]).collect();
        let vb: Vec<f32> = kv_rows.iter().map(|&r| gqa.wv.b.data()[r]).collect();
        mha.wk.b = Tensor::from_vec(&[8], kb);
        mha.wv.b = Tensor::from_vec(&[8], vb);
        let x = randx(5, 8, 8);
        let (yg, _) = gqa.forward(&x, 1, 5);
        let (ym, _) = mha.forward(&x, 1, 5);
        assert!(yg.max_abs_diff(&ym) < 1e-5);
    }

    #[test]
    fn select_heads_drops_tap_features() {
        let a = small_attn(true);
        let x = randx(4, 8, 5);
        let (_, tap_full) = a.forward(&x, 1, 4);
        let mut pruned = a.clone();
        pruned.select_heads(&[1, 3]);
        pruned.wo.select_inputs(&[2, 3, 6, 7]); // features of heads 1,3
        let (_, tap) = pruned.forward(&x, 1, 4);
        assert_eq!(tap.shape(), &[4, 4]);
        // Kept heads compute identical features.
        for r in 0..4 {
            assert!((tap.at2(r, 0) - tap_full.at2(r, 2)).abs() < 1e-5);
            assert!((tap.at2(r, 3) - tap_full.at2(r, 7)).abs() < 1e-5);
        }
    }

    #[test]
    #[should_panic(expected = "equal, nonzero count per KV group")]
    fn gqa_unbalanced_selection_panics() {
        let mut rng = Pcg64::seed(9);
        let mut a = MultiHeadAttention::init(8, 4, 2, 2, true, &mut rng);
        a.select_heads(&[0, 1, 2]); // group0 keeps 2, group1 keeps 1
    }

    #[test]
    #[should_panic(expected = "across KV groups")]
    fn gqa_cross_group_fold_panics() {
        let mut rng = Pcg64::seed(10);
        let mut a = MultiHeadAttention::init(8, 4, 2, 2, true, &mut rng);
        a.fold_heads(&[0, 0, 0, 1], 2); // head 2 (group1) folded with group0
    }

    #[test]
    fn fold_heads_averages_query_rows() {
        let mut a = small_attn(true);
        let r0 = a.wq.w.row(0).to_vec();
        let r2 = a.wq.w.row(4).to_vec(); // head 2, feature 0
        a.fold_heads(&[0, 1, 0, 1], 2);
        assert_eq!(a.n_heads, 2);
        for j in 0..8 {
            let want = (r0[j] + r2[j]) / 2.0;
            assert!((a.wq.w.at2(0, j) - want).abs() < 1e-6);
        }
    }
}
