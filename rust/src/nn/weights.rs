//! `GRWB` weight-bundle IO — the checkpoint interchange format between
//! the Python training step and the Rust coordinator.
//!
//! Layout (little-endian): u32 magic `GRWB`, u32 version, u32 tensor
//! count, then per tensor: u32 name length, UTF-8 name, u32 ndim,
//! u32 dims…, f32 data.

use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};

pub const MAGIC: u32 = 0x4752_5742; // "GRWB"
pub const VERSION: u32 = 1;

/// An ordered name → tensor map.
#[derive(Clone, Debug, Default)]
pub struct WeightBundle {
    map: BTreeMap<String, Tensor>,
}

impl WeightBundle {
    /// Empty bundle.
    pub fn new() -> Self {
        Self::default()
    }

    /// Insert (replaces an existing entry).
    pub fn insert(&mut self, name: &str, t: Tensor) {
        self.map.insert(name.to_string(), t);
    }

    /// Fetch a tensor by name.
    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.map.get(name).ok_or_else(|| anyhow::anyhow!("weight bundle missing `{name}`"))
    }

    /// Fetch and validate the shape.
    pub fn get_shaped(&self, name: &str, shape: &[usize]) -> Result<Tensor> {
        let t = self.get(name)?;
        if t.shape() != shape {
            bail!("`{name}`: expected shape {shape:?}, file has {:?}", t.shape());
        }
        Ok(t.clone())
    }

    /// Number of tensors.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// True if no tensors.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Iterate entries in name order.
    pub fn iter(&self) -> impl Iterator<Item = (&str, &Tensor)> {
        self.map.iter().map(|(k, v)| (k.as_str(), v))
    }

    /// Total parameter count.
    pub fn num_params(&self) -> usize {
        self.map.values().map(|t| t.len()).sum()
    }

    /// Serialize to a writer.
    pub fn write_to(&self, out: &mut impl Write) -> Result<()> {
        out.write_all(&MAGIC.to_le_bytes())?;
        out.write_all(&VERSION.to_le_bytes())?;
        out.write_all(&(self.map.len() as u32).to_le_bytes())?;
        for (name, t) in &self.map {
            let nb = name.as_bytes();
            out.write_all(&(nb.len() as u32).to_le_bytes())?;
            out.write_all(nb)?;
            out.write_all(&(t.ndim() as u32).to_le_bytes())?;
            for &d in t.shape() {
                out.write_all(&(d as u32).to_le_bytes())?;
            }
            let mut buf = Vec::with_capacity(t.len() * 4);
            for &v in t.data() {
                buf.extend_from_slice(&v.to_le_bytes());
            }
            out.write_all(&buf)?;
        }
        Ok(())
    }

    /// Save to a file.
    pub fn save(&self, path: &str) -> Result<()> {
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(path).with_context(|| format!("creating {path}"))?,
        );
        self.write_to(&mut f)
    }

    /// Deserialize from a reader.
    pub fn read_from(inp: &mut impl Read) -> Result<Self> {
        let mut u32buf = [0u8; 4];
        let mut rd_u32 = |inp: &mut dyn Read| -> Result<u32> {
            inp.read_exact(&mut u32buf)?;
            Ok(u32::from_le_bytes(u32buf))
        };
        if rd_u32(inp)? != MAGIC {
            bail!("not a GRWB weight bundle");
        }
        let version = rd_u32(inp)?;
        if version != VERSION {
            bail!("unsupported GRWB version {version}");
        }
        let count = rd_u32(inp)? as usize;
        let mut map = BTreeMap::new();
        for _ in 0..count {
            let name_len = rd_u32(inp)? as usize;
            if name_len > 4096 {
                bail!("implausible name length {name_len}");
            }
            let mut nb = vec![0u8; name_len];
            inp.read_exact(&mut nb)?;
            let name = String::from_utf8(nb).context("weight name not UTF-8")?;
            let ndim = rd_u32(inp)? as usize;
            if ndim > 8 {
                bail!("implausible ndim {ndim} for `{name}`");
            }
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(rd_u32(inp)? as usize);
            }
            let n: usize = shape.iter().product();
            let mut buf = vec![0u8; n * 4];
            inp.read_exact(&mut buf).with_context(|| format!("truncated data for `{name}`"))?;
            let data: Vec<f32> = buf
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect();
            map.insert(name, Tensor::from_vec(&shape, data));
        }
        Ok(WeightBundle { map })
    }

    /// Load from a file.
    pub fn load(path: &str) -> Result<Self> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("opening {path}"))?,
        );
        Self::read_from(&mut f).with_context(|| format!("parsing {path}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn tmp(name: &str) -> String {
        let dir = std::env::temp_dir().join("grail_wbin_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name).to_string_lossy().into_owned()
    }

    #[test]
    fn roundtrip() {
        let mut rng = Pcg64::seed(1);
        let mut b = WeightBundle::new();
        let mut t1 = Tensor::zeros(&[3, 4]);
        rng.fill_normal(t1.data_mut(), 1.0);
        let t2 = Tensor::from_vec(&[2, 2, 2, 2], (0..16).map(|i| i as f32).collect());
        b.insert("layer.w", t1.clone());
        b.insert("layer.b", Tensor::zeros(&[3]));
        b.insert("conv.w", t2.clone());
        let p = tmp("a.wbin");
        b.save(&p).unwrap();
        let r = WeightBundle::load(&p).unwrap();
        assert_eq!(r.len(), 3);
        assert_eq!(r.get("layer.w").unwrap(), &t1);
        assert_eq!(r.get("conv.w").unwrap(), &t2);
        assert_eq!(r.num_params(), 12 + 3 + 16);
    }

    #[test]
    fn shape_check() {
        let mut b = WeightBundle::new();
        b.insert("x", Tensor::zeros(&[2, 3]));
        assert!(b.get_shaped("x", &[2, 3]).is_ok());
        assert!(b.get_shaped("x", &[3, 2]).is_err());
        assert!(b.get("missing").is_err());
    }

    #[test]
    fn rejects_garbage() {
        let p = tmp("bad.wbin");
        std::fs::write(&p, b"not a bundle at all").unwrap();
        assert!(WeightBundle::load(&p).is_err());
    }

    #[test]
    fn rejects_truncation() {
        let mut b = WeightBundle::new();
        b.insert("w", Tensor::zeros(&[64, 64]));
        let p = tmp("t.wbin");
        b.save(&p).unwrap();
        let data = std::fs::read(&p).unwrap();
        std::fs::write(&p, &data[..100]).unwrap();
        assert!(WeightBundle::load(&p).is_err());
    }
}
