//! `grail serve`: a long-lived compression job queue over a
//! filesystem spool.
//!
//! Layout under one serve root (default `<artifacts>/serve/`):
//!
//! ```text
//! serve/
//!   jobs/<id>/        submitted spec + status.toml + log.txt
//!   results/<id>/     plans / reports of completed jobs
//!   cache/            the shared content-addressed ActStats cache
//! ```
//!
//! The queue is the set of jobs whose persisted state is `queued` —
//! there is no separate queue file to drift out of sync, and a daemon
//! restart resumes from whatever the disk says (stale `running`
//! records from a killed daemon are re-queued on scan). Each drain
//! cycle fans the queued jobs over
//! [`run_grid`](crate::coordinator::scheduler::run_grid) workers, so
//! every job inherits an equal share of the machine's thread budget
//! for its own shard calibration. A failing job (bad spec, missing
//! checkpoint, panic) lands in `failed` with the error captured in
//! `status.toml`, after `1 + retries` observable attempts; the queue
//! keeps draining around it.
//!
//! Job ids are content-derived (digest of verb + overrides + spec
//! bytes), so resubmitting the same work collapses onto the same job
//! and its already-computed result.

use super::cache::StatsCache;
use super::digest::digest_bytes;
use super::job::{JobRecord, JobState, JobVerb};
use super::provider;
use crate::cli::Args;
use crate::coordinator::scheduler::{default_threads, run_grid};
use crate::exp::runner::{execute_job, resolve_job_plan, tune_job, SpecJob};
use crate::exp::ExpOptions;
use crate::grail::BudgetMode;
use anyhow::{anyhow, bail, Context, Result};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

/// Resolved locations inside one serve root.
#[derive(Clone, Debug)]
pub struct ServeRoot {
    pub root: PathBuf,
}

impl ServeRoot {
    pub fn at(root: impl Into<PathBuf>) -> ServeRoot {
        ServeRoot { root: root.into() }
    }

    /// Serve root for CLI verbs: `--root` wins, else
    /// `<artifacts>/serve`.
    pub fn from_args(args: &Args, opts: &ExpOptions) -> ServeRoot {
        match args.opt("root") {
            Some(r) => ServeRoot::at(r),
            None => ServeRoot::at(opts.artifacts.serve_dir()),
        }
    }

    pub fn jobs_dir(&self) -> PathBuf {
        self.root.join("jobs")
    }

    pub fn results_dir(&self) -> PathBuf {
        self.root.join("results")
    }

    pub fn cache_dir(&self) -> PathBuf {
        self.root.join("cache")
    }

    pub fn job_dir(&self, id: &str) -> PathBuf {
        self.jobs_dir().join(id)
    }

    pub fn result_dir(&self, id: &str) -> PathBuf {
        self.results_dir().join(id)
    }

    /// Create the spool directories.
    pub fn ensure(&self) -> Result<()> {
        for d in [self.jobs_dir(), self.results_dir(), self.cache_dir()] {
            std::fs::create_dir_all(&d).with_context(|| format!("creating {d:?}"))?;
        }
        Ok(())
    }

    /// All job records on disk, sorted by id (records that fail to
    /// parse are reported and skipped, never fatal to the daemon).
    pub fn scan(&self) -> Result<Vec<JobRecord>> {
        let mut out = Vec::new();
        let dir = self.jobs_dir();
        if !dir.exists() {
            return Ok(out);
        }
        let mut entries: Vec<PathBuf> = std::fs::read_dir(&dir)
            .with_context(|| format!("listing {dir:?}"))?
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| p.is_dir())
            .collect();
        entries.sort();
        for p in entries {
            match JobRecord::load(&p) {
                Ok(rec) => out.push(rec),
                Err(e) => eprintln!("[serve] WARN: skipping unreadable job at {p:?}: {e:#}"),
            }
        }
        Ok(out)
    }
}

/// Content-derived job id: hex digest prefix of (verb, overrides, spec
/// bytes). 16 hex chars = 64 bits — collision-safe at spool scale
/// while keeping paths readable.
pub fn job_id(verb: JobVerb, family: &str, ckpt: &str, spec_bytes: &[u8]) -> String {
    let mut h = super::digest::Hasher128::new();
    h.update(b"grail-job-v1");
    h.update(verb.name().as_bytes());
    h.update(&[0]);
    h.update(family.as_bytes());
    h.update(&[0]);
    h.update(ckpt.as_bytes());
    h.update(&[0]);
    h.update(spec_bytes);
    h.finish().hex()[..16].to_string()
}

/// Submit one spec file: persist it under `jobs/<id>/` in state
/// `queued`. Returns `(id, resubmitted)`. Resubmitting an identical
/// job that already finished resets it to `queued` (idempotent
/// re-run); one that is still queued or running is left alone.
pub fn submit_file(
    root: &ServeRoot,
    spec_path: &str,
    verb: JobVerb,
    retries: usize,
    family: &str,
    ckpt: &str,
) -> Result<(String, bool)> {
    root.ensure()?;
    let bytes =
        std::fs::read(spec_path).with_context(|| format!("reading spec {spec_path}"))?;
    let id = job_id(verb, family, ckpt, &bytes);
    let dir = root.job_dir(&id);
    if let Ok(mut rec) = JobRecord::load(&dir) {
        if rec.state == JobState::Queued || rec.state == JobState::Running {
            return Ok((id, false));
        }
        rec.state = JobState::Queued;
        rec.attempts = 0;
        rec.retries = retries;
        rec.error.clear();
        rec.save(&dir)?;
        rec.log(&dir)?;
        return Ok((id, true));
    }
    std::fs::create_dir_all(&dir).with_context(|| format!("creating {dir:?}"))?;
    std::fs::write(dir.join("spec.toml"), &bytes)
        .with_context(|| format!("writing {dir:?}/spec.toml"))?;
    let rec = JobRecord::new(id.clone(), verb, retries, family, ckpt);
    rec.save(&dir)?;
    rec.log(&dir)?;
    Ok((id, false))
}

/// Execute one job body. Returns the result path (relative to the
/// serve root) on success.
fn run_job_inner(
    opts: &ExpOptions,
    root: &ServeRoot,
    rec: &JobRecord,
) -> Result<String> {
    let dir = root.job_dir(&rec.id);
    let spec_path = dir.join("spec.toml");
    let spec_str = spec_path
        .to_str()
        .ok_or_else(|| anyhow!("non-UTF8 job path {spec_path:?}"))?;
    let mut sj = SpecJob::load(spec_str)?;
    if !rec.family.is_empty() {
        sj.family = crate::exp::runner::Family::from_name(&rec.family)
            .ok_or_else(|| anyhow!("unknown family override `{}`", rec.family))?;
    }
    if !rec.ckpt.is_empty() {
        sj.ckpt = Some(rec.ckpt.clone());
    }
    let ckpt = sj.ckpt_or_default();
    let res_dir = root.result_dir(&rec.id);
    std::fs::create_dir_all(&res_dir).with_context(|| format!("creating {res_dir:?}"))?;
    // Jobs write into their own content-addressed results directory.
    let job_opts = ExpOptions {
        out_dir: res_dir.to_string_lossy().into_owned(),
        ..opts.clone()
    };
    let rel = format!("results/{}", rec.id);
    match rec.verb {
        JobVerb::Plan => {
            let plan = resolve_job_plan(&job_opts, sj.family, &ckpt, &sj.spec)?;
            let out = res_dir.join("plan.toml");
            std::fs::write(&out, plan.to_toml()).with_context(|| format!("writing {out:?}"))?;
            Ok(format!("{rel}/plan.toml"))
        }
        JobVerb::Run => {
            let out = execute_job(&job_opts, sj.family, &ckpt, &sj.spec, &rec.id)?;
            let mut text = format!(
                "{} {} [{}]: {} {:.4} -> {:.4}\n{}\n",
                out.family.name(),
                out.ckpt,
                rec.id,
                out.metric,
                out.before,
                out.after,
                out.report.summary()
            );
            for s in &out.report.sites {
                text.push_str(&format!(
                    "{}: {} -> {} ({}), recon err {:.4}\n",
                    s.id, s.units_before, s.units_after, s.method, s.recon_err
                ));
            }
            let path = res_dir.join("report.txt");
            std::fs::write(&path, text).with_context(|| format!("writing {path:?}"))?;
            Ok(format!("{rel}/report.txt"))
        }
        JobVerb::Tune => {
            if !matches!(sj.spec.budget, BudgetMode::Search { .. }) {
                bail!(
                    "tune job needs `[budget] mode = \"search\"` (got `{}`)",
                    sj.spec.budget.name()
                );
            }
            let out = tune_job(&job_opts, sj.family, &ckpt, &sj.spec, false)?;
            let summary = format!(
                "tune {} {}: held-out err {:.6} -> {:.6} (alpha_moves={} keep_moves={} evals={})\n",
                out.family.name(),
                out.ckpt,
                out.search.initial_err,
                out.search.final_err,
                out.search.alpha_moves,
                out.search.keep_moves,
                out.search.evals,
            );
            let path = res_dir.join("tune.txt");
            std::fs::write(&path, summary).with_context(|| format!("writing {path:?}"))?;
            Ok(rel)
        }
    }
}

/// Run one attempt of a queued job: `queued → running → done`, or back
/// to `queued` while attempts remain, else `failed`. Every transition
/// is persisted and logged; panics inside the job body are captured as
/// errors so one poisoned job cannot take the daemon down.
fn execute_attempt(opts: &ExpOptions, root: &ServeRoot, rec: &JobRecord) -> JobState {
    let dir = root.job_dir(&rec.id);
    let mut rec = rec.clone();
    rec.state = JobState::Running;
    rec.attempts += 1;
    rec.error.clear();
    let _ = rec.save(&dir);
    let _ = rec.log(&dir);
    let t0 = Instant::now();
    let (h0, m0) = provider::tally();
    let outcome = catch_unwind(AssertUnwindSafe(|| run_job_inner(opts, root, &rec)));
    let (h1, m1) = provider::tally();
    rec.wall_seconds = t0.elapsed().as_secs_f64();
    rec.cache_hits += h1 - h0;
    rec.cache_misses += m1 - m0;
    match outcome {
        Ok(Ok(result)) => {
            rec.state = JobState::Done;
            rec.result = result;
        }
        Ok(Err(e)) => {
            rec.error = format!("{e:#}");
            rec.state =
                if rec.attempts <= rec.retries { JobState::Queued } else { JobState::Failed };
        }
        Err(panic) => {
            let msg = panic
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| panic.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "panic (non-string payload)".to_string());
            rec.error = format!("panic: {msg}");
            rec.state =
                if rec.attempts <= rec.retries { JobState::Queued } else { JobState::Failed };
        }
    }
    let _ = rec.save(&dir);
    let _ = rec.log(&dir);
    rec.state
}

/// Daemon configuration.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Concurrent jobs per drain cycle (each gets an equal share of
    /// the thread budget).
    pub jobs: usize,
    /// Drain the queue (including retries) and exit instead of
    /// polling forever.
    pub once: bool,
    /// Idle poll interval.
    pub poll_ms: u64,
}

/// Run the daemon loop. Returns only in `--once` mode (after the queue
/// drains) or on a spool-level I/O error.
pub fn serve(opts: &ExpOptions, root: &ServeRoot, cfg: &ServeConfig) -> Result<()> {
    root.ensure()?;
    let cache = Arc::new(StatsCache::open(root.cache_dir())?);
    let opts = ExpOptions { cache: Some(cache.clone()), ..opts.clone() };
    println!(
        "serve: root {} · {} concurrent jobs · cache {}",
        root.root.display(),
        cfg.jobs,
        cache.root().display()
    );
    loop {
        let mut queued: Vec<JobRecord> = Vec::new();
        for mut rec in root.scan()? {
            match rec.state {
                JobState::Queued => queued.push(rec),
                // A `running` record with no daemon working on it is a
                // crash leftover; requeue it (attempts already spent
                // stay counted, so the retry bound still holds).
                JobState::Running => {
                    rec.state = JobState::Queued;
                    let dir = root.job_dir(&rec.id);
                    let _ = rec.save(&dir);
                    let _ = rec.log(&dir);
                    queued.push(rec);
                }
                _ => {}
            }
        }
        if queued.is_empty() {
            if cfg.once {
                let c = cache.counters();
                println!(
                    "serve: queue drained (cache: {} hits, {} misses, {} evictions)",
                    c.hits, c.misses, c.evictions
                );
                return Ok(());
            }
            std::thread::sleep(std::time::Duration::from_millis(cfg.poll_ms));
            continue;
        }
        let threads = cfg.jobs.clamp(1, queued.len());
        let opts_ref = &opts;
        run_grid(queued, threads, |_, rec| execute_attempt(opts_ref, root, rec));
    }
}

/// `grail serve [--root dir] [--jobs N] [--once] [--poll-ms M]`.
pub fn serve_cli(args: &Args) -> Result<()> {
    let opts = ExpOptions::from_args(args)?;
    let root = ServeRoot::from_args(args, &opts);
    let cfg = ServeConfig {
        jobs: args.opt_usize("jobs", default_threads().min(4))?,
        once: args.has("once"),
        poll_ms: args.opt_u64("poll-ms", 500)?,
    };
    serve(&opts, &root, &cfg)
}

/// `grail submit <spec.toml> [--verb plan|run|tune] [--retries N]
/// [--family f] [--ckpt c] [--root dir]`.
pub fn submit_cli(args: &Args) -> Result<()> {
    let spec_path = args.pos(1, "spec file")?;
    let opts = ExpOptions::from_args(args)?;
    let root = ServeRoot::from_args(args, &opts);
    // `[job]` section in the spec supplies defaults; flags win.
    let cfg = crate::config::Config::load(spec_path).unwrap_or_default();
    let verb_name = args.opt("verb").unwrap_or(cfg.str_or("job.verb", "run")).to_string();
    let verb = JobVerb::from_name(&verb_name)
        .ok_or_else(|| anyhow!("--verb: expected plan|run|tune, got `{verb_name}`"))?;
    let retries = args.opt_usize("retries", cfg.usize_or("job.retries", 1))?;
    let family = args.opt("family").unwrap_or("");
    let ckpt = args.opt("ckpt").unwrap_or("");
    let (id, resubmitted) = submit_file(&root, spec_path, verb, retries, family, ckpt)?;
    println!(
        "submitted {id} ({} {}){}",
        verb.name(),
        spec_path,
        if resubmitted { " [re-queued]" } else { "" }
    );
    Ok(())
}

/// `grail status <id> [--root dir]` — print one job's record (and
/// surface its result when done).
pub fn status_cli(args: &Args) -> Result<()> {
    let id = args.pos(1, "job id")?;
    let opts = ExpOptions::from_args(args)?;
    let root = ServeRoot::from_args(args, &opts);
    let rec = JobRecord::load(&root.job_dir(id))
        .with_context(|| format!("no job `{id}` under {:?}", root.jobs_dir()))?;
    println!("{}", rec.log_line());
    if rec.state == JobState::Done && !rec.result.is_empty() {
        println!("result: {}", root.root.join(&rec.result).display());
    }
    Ok(())
}

/// `grail jobs [--root dir]` — list every job in the spool.
pub fn jobs_cli(args: &Args) -> Result<()> {
    let opts = ExpOptions::from_args(args)?;
    let root = ServeRoot::from_args(args, &opts);
    let recs = root.scan()?;
    if recs.is_empty() {
        println!("no jobs under {:?}", root.jobs_dir());
        return Ok(());
    }
    let mut table = crate::exp::report::Table::new(&[
        "id", "verb", "state", "attempts", "secs", "c_hit", "c_miss", "result/error",
    ]);
    for r in &recs {
        let tail = if !r.error.is_empty() { r.error.clone() } else { r.result.clone() };
        table.row(vec![
            r.id.clone(),
            r.verb.name().to_string(),
            r.state.name().to_string(),
            format!("{}/{}", r.attempts, 1 + r.retries),
            format!("{:.2}", r.wall_seconds),
            r.cache_hits.to_string(),
            r.cache_misses.to_string(),
            tail,
        ]);
    }
    println!("{}", table.render());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_ids_are_content_derived() {
        let a = job_id(JobVerb::Plan, "", "", b"[pipeline]\nratio = 0.5\n");
        assert_eq!(a, job_id(JobVerb::Plan, "", "", b"[pipeline]\nratio = 0.5\n"));
        assert_ne!(a, job_id(JobVerb::Run, "", "", b"[pipeline]\nratio = 0.5\n"));
        assert_ne!(a, job_id(JobVerb::Plan, "mlp", "", b"[pipeline]\nratio = 0.5\n"));
        assert_ne!(a, job_id(JobVerb::Plan, "", "mlp_dev", b"[pipeline]\nratio = 0.5\n"));
        assert_ne!(a, job_id(JobVerb::Plan, "", "", b"[pipeline]\nratio = 0.4\n"));
        assert_eq!(a.len(), 16);
        assert!(a.chars().all(|c| c.is_ascii_hexdigit()));
    }

    #[test]
    fn submit_is_idempotent_until_terminal() {
        let tmp =
            std::env::temp_dir().join(format!("grail_daemon_submit_{}", std::process::id()));
        std::fs::remove_dir_all(&tmp).ok();
        std::fs::create_dir_all(&tmp).unwrap();
        let spec = tmp.join("j.spec.toml");
        std::fs::write(&spec, "[pipeline]\nratio = 0.5\n").unwrap();
        let root = ServeRoot::at(tmp.join("serve"));
        let (id, re) = submit_file(&root, spec.to_str().unwrap(), JobVerb::Plan, 1, "", "").unwrap();
        assert!(!re);
        // Same submission while queued: same id, untouched.
        let (id2, re2) =
            submit_file(&root, spec.to_str().unwrap(), JobVerb::Plan, 1, "", "").unwrap();
        assert_eq!(id, id2);
        assert!(!re2);
        // Terminal job: resubmission re-queues it.
        let dir = root.job_dir(&id);
        let mut rec = JobRecord::load(&dir).unwrap();
        rec.state = JobState::Failed;
        rec.attempts = 2;
        rec.save(&dir).unwrap();
        let (id3, re3) =
            submit_file(&root, spec.to_str().unwrap(), JobVerb::Plan, 1, "", "").unwrap();
        assert_eq!(id, id3);
        assert!(re3);
        let rec = JobRecord::load(&dir).unwrap();
        assert_eq!(rec.state, JobState::Queued);
        assert_eq!(rec.attempts, 0);
        std::fs::remove_dir_all(&tmp).ok();
    }
}
