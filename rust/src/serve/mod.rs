//! Compression-as-a-service: the statistics cache and the `grail
//! serve` daemon.
//!
//! GRAIL's whole downstream pipeline — budget allocators, plan search,
//! ridge compensation — consumes one sufficient statistic: the
//! streamed per-site [`ActStats`](crate::grail::ActStats)/Gram pass
//! over a `(model, calibration corpus)` pair. This module pays for
//! that pass once and serves unlimited plan/run/tune traffic against
//! it:
//!
//! - [`batch`] — token-serving scale-out: the continuous-batching
//!   decode scheduler and the shared KV page pool that let many
//!   in-flight generation requests share coalesced GEMMs and a fixed
//!   cache budget.
//! - [`digest`] — deterministic 128-bit content digests (stable across
//!   processes and releases; pinned unit tests catch drift).
//! - [`cache`] — a content-addressed on-disk store of per-site,
//!   per-shard `ActStats` in a versioned, checksummed binary format
//!   with atomic writes and hit/miss/evict counters.
//! - [`provider`] — a thread-ambient cache-aware statistics provider;
//!   installing a [`provider::StatsContext`] makes
//!   `grail plan`/`run`/`tune`/`batch` transparently skip the
//!   calibration forward pass on a hit, bit-identically to the cold
//!   path.
//! - [`job`] / [`daemon`] — `grail serve`: a long-lived filesystem job
//!   queue (`submit`/`status`/`jobs` client verbs) executing plan/run/
//!   tune specs against zoo checkpoints with a persisted
//!   queued → running → done/failed state machine, bounded retries,
//!   and a content-addressed results directory.
//!
//! See EXPERIMENTS.md §Serve daemon for the on-disk layout and CLI
//! walkthrough.

pub mod batch;
pub mod cache;
pub mod daemon;
pub mod digest;
pub mod job;
pub mod provider;

pub use batch::{BatchScheduler, BatchStats, Completion, KvPagePool, DEFAULT_PREFILL_CHUNK};
pub use cache::{CacheCounters, StatsCache};
pub use digest::{digest_bytes, digest_file, digest_tensor, Digest, Hasher128};
pub use job::{JobRecord, JobState, JobVerb};
pub use provider::{CacheScope, StatsContext};
