//! Deterministic 128-bit content digests for the statistics cache.
//!
//! Cache keys must be stable across processes, machines, and releases:
//! the same (model bytes, corpus bytes, site, shard split) has to map
//! to the same on-disk entry forever, or every upgrade silently turns
//! into a cold cache. `std::hash` guarantees none of that (SipHash keys
//! are randomized per process), so this module hand-rolls a small
//! streaming hash: two independent 64-bit lanes absorbing little-endian
//! 8-byte chunks through the SplitMix64 finalizer, combined with the
//! total length at the end. Non-cryptographic — it defends against
//! accidental collisions and format drift, not adversaries — which is
//! exactly the content-addressing contract the cache needs.
//!
//! The unit tests pin exact digest values; if this function ever
//! changes, those tests fail and the cache format version must be
//! bumped (see [`super::cache`]).

use anyhow::{Context, Result};
use std::fmt;
use std::io::Read;

/// A 128-bit content digest.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Digest(pub [u8; 16]);

impl Digest {
    /// Lower-case 32-char hex form (stable file-name encoding).
    pub fn hex(&self) -> String {
        let mut s = String::with_capacity(32);
        for b in self.0 {
            s.push_str(&format!("{b:02x}"));
        }
        s
    }

    /// Parse the [`hex`](Digest::hex) form back.
    pub fn parse_hex(s: &str) -> Option<Digest> {
        let s = s.as_bytes();
        if s.len() != 32 {
            return None;
        }
        let nib = |c: u8| -> Option<u8> {
            match c {
                b'0'..=b'9' => Some(c - b'0'),
                b'a'..=b'f' => Some(c - b'a' + 10),
                _ => None,
            }
        };
        let mut out = [0u8; 16];
        for i in 0..16 {
            out[i] = nib(s[2 * i])? << 4 | nib(s[2 * i + 1])?;
        }
        Some(Digest(out))
    }
}

impl fmt::Debug for Digest {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Digest({})", self.hex())
    }
}

impl fmt::Display for Digest {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.hex())
    }
}

/// Checked usize → u32 little-endian wire field. Panics — rather than
/// silently wrapping into a wrong-but-plausible on-disk value — when
/// `v` does not fit; `what` names the field in the panic message.
/// The wire-format modules must use this (or [`wire_u64`] /
/// `try_from`) instead of `as` casts; `grail check`'s
/// `wire-format-casts` lint enforces it.
pub fn wire_u32(v: usize, what: &str) -> [u8; 4] {
    u32::try_from(v)
        .unwrap_or_else(|_| panic!("{what} ({v}) exceeds the u32 wire field"))
        .to_le_bytes()
}

/// Checked usize → u64 little-endian wire field (see [`wire_u32`];
/// infallible on ≤ 64-bit targets, checked everywhere by
/// construction).
pub fn wire_u64(v: usize, what: &str) -> [u8; 8] {
    u64::try_from(v)
        .unwrap_or_else(|_| panic!("{what} ({v}) exceeds the u64 wire field"))
        .to_le_bytes()
}

/// SplitMix64 finalizer: a full-avalanche 64-bit mixer.
#[inline]
fn mix(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Streaming 128-bit hasher. Incremental [`update`](Hasher128::update)
/// calls produce the same digest as one call over the concatenation.
pub struct Hasher128 {
    lo: u64,
    hi: u64,
    /// Partial chunk carried across `update` boundaries.
    buf: [u8; 8],
    buf_len: usize,
    total: u64,
}

impl Default for Hasher128 {
    fn default() -> Self {
        Self::new()
    }
}

impl Hasher128 {
    pub fn new() -> Self {
        // Arbitrary distinct lane seeds (digits of π and e).
        Hasher128 {
            lo: 0x2436_3F84_A425_2210,
            hi: 0xB7E1_5162_8AED_2A6A,
            buf: [0u8; 8],
            buf_len: 0,
            total: 0,
        }
    }

    #[inline]
    fn absorb(&mut self, k: u64) {
        self.lo = mix(self.lo ^ k.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        self.hi = mix(self.hi ^ k.rotate_left(32).wrapping_mul(0xC2B2_AE3D_27D4_EB4F));
    }

    /// Absorb `bytes` (chunk boundaries do not affect the result).
    pub fn update(&mut self, bytes: &[u8]) {
        let len = u64::try_from(bytes.len()).expect("slice length exceeds u64");
        self.total = self.total.wrapping_add(len);
        let mut rest = bytes;
        if self.buf_len > 0 {
            let need = 8 - self.buf_len;
            let take = need.min(rest.len());
            self.buf[self.buf_len..self.buf_len + take].copy_from_slice(&rest[..take]);
            self.buf_len += take;
            rest = &rest[take..];
            if self.buf_len == 8 {
                self.absorb(u64::from_le_bytes(self.buf));
                self.buf_len = 0;
            }
        }
        let mut chunks = rest.chunks_exact(8);
        for c in &mut chunks {
            self.absorb(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let tail = chunks.remainder();
        self.buf[..tail.len()].copy_from_slice(tail);
        self.buf_len = tail.len();
    }

    /// Finish the stream and return the digest.
    pub fn finish(mut self) -> Digest {
        if self.buf_len > 0 {
            // Zero-pad the final partial chunk; the length absorbed
            // below disambiguates it from genuine trailing zeros.
            for i in self.buf_len..8 {
                self.buf[i] = 0;
            }
            let chunk = u64::from_le_bytes(self.buf);
            self.absorb(chunk);
        }
        self.absorb(self.total ^ 0x1F0A_5C4D_3B2E_1908);
        // Cross-mix the lanes so each output half depends on both.
        let a = mix(self.lo.wrapping_add(self.hi.rotate_left(17)));
        let b = mix(self.hi ^ self.lo.rotate_left(43));
        let mut out = [0u8; 16];
        out[..8].copy_from_slice(&a.to_le_bytes());
        out[8..].copy_from_slice(&b.to_le_bytes());
        Digest(out)
    }
}

/// Digest of a byte slice.
pub fn digest_bytes(bytes: &[u8]) -> Digest {
    let mut h = Hasher128::new();
    h.update(bytes);
    h.finish()
}

/// Digest of an f32 slice via the exact little-endian bit patterns
/// (so `-0.0` ≠ `0.0` and NaN payloads are significant — byte
/// identity, not numeric equality).
pub fn digest_f32s(vals: &[f32]) -> Digest {
    let mut h = Hasher128::new();
    update_f32s(&mut h, vals);
    h.finish()
}

/// Stream an f32 slice into an existing hasher.
pub fn update_f32s(h: &mut Hasher128, vals: &[f32]) {
    let mut buf = [0u8; 8 * 256];
    for chunk in vals.chunks(2 * 256) {
        let mut n = 0;
        for v in chunk {
            buf[n..n + 4].copy_from_slice(&v.to_le_bytes());
            n += 4;
        }
        h.update(&buf[..n]);
    }
}

/// Digest of a tensor: shape (as little-endian u64 dims) then data
/// bits, so `[2,3]` and `[3,2]` views of the same buffer differ.
pub fn digest_tensor(t: &crate::tensor::Tensor) -> Digest {
    let mut h = Hasher128::new();
    h.update(&wire_u64(t.ndim(), "tensor rank"));
    for d in 0..t.ndim() {
        h.update(&wire_u64(t.dim(d), "tensor dimension"));
    }
    update_f32s(&mut h, t.data());
    h.finish()
}

/// Digest of a file's raw bytes (streamed; the file never loads whole).
pub fn digest_file(path: &str) -> Result<Digest> {
    let mut f = std::fs::File::open(path).with_context(|| format!("digesting {path}"))?;
    let mut h = Hasher128::new();
    let mut buf = vec![0u8; 1 << 16];
    loop {
        let n = f.read(&mut buf).with_context(|| format!("digesting {path}"))?;
        if n == 0 {
            break;
        }
        h.update(&buf[..n]);
    }
    Ok(h.finish())
}

#[cfg(test)]
mod tests {
    use super::*;

    // Pinned values: the cache's on-disk keys derive from this exact
    // function. If any of these change, bump `cache::FORMAT_VERSION`
    // (old entries must not be served under new keys or vice versa).
    #[test]
    fn digests_are_pinned_against_drift() {
        assert_eq!(digest_bytes(b"").hex(), "69340e35dec347fe3517bf37054718a9");
        assert_eq!(digest_bytes(b"grail").hex(), "98b33e73a3b727d7b3862fd7fd7a44f3");
        assert_eq!(
            digest_bytes(b"the quick brown fox jumps over the lazy dog").hex(),
            "a52347248a8332731776410e7f5e5497"
        );
        assert_eq!(
            digest_f32s(&[0.0, 1.0, -1.0, 0.5]).hex(),
            "88bb231e5eece4e2f65e21ca6fc05c87"
        );
    }

    #[test]
    fn incremental_equals_one_shot() {
        let data: Vec<u8> = (0..=255u8).cycle().take(1000).collect();
        let whole = digest_bytes(&data);
        for split in [0usize, 1, 3, 7, 8, 9, 500, 999, 1000] {
            let mut h = Hasher128::new();
            h.update(&data[..split]);
            h.update(&data[split..]);
            assert_eq!(h.finish(), whole, "split at {split}");
        }
        // Three-way split with awkward boundaries.
        let mut h = Hasher128::new();
        h.update(&data[..5]);
        h.update(&data[5..13]);
        h.update(&data[13..]);
        assert_eq!(h.finish(), whole);
    }

    #[test]
    fn content_sensitivity() {
        let a = digest_bytes(b"abcdefgh");
        let mut flipped = *b"abcdefgh";
        flipped[7] ^= 1;
        assert_ne!(a, digest_bytes(&flipped));
        // Length is significant even when the tail pads with zeros.
        assert_ne!(digest_bytes(&[0u8; 7]), digest_bytes(&[0u8; 8]));
        assert_ne!(digest_bytes(&[]), digest_bytes(&[0u8]));
    }

    #[test]
    fn float_digests_are_bit_exact() {
        assert_ne!(digest_f32s(&[0.0]), digest_f32s(&[-0.0]));
        assert_eq!(digest_f32s(&[f32::NAN]), digest_f32s(&[f32::NAN]));
    }

    #[test]
    fn tensor_digest_includes_shape() {
        use crate::tensor::Tensor;
        let data = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let a = Tensor::from_vec(&[2, 3], data.clone());
        let b = Tensor::from_vec(&[3, 2], data.clone());
        let c = Tensor::from_vec(&[6], data);
        assert_ne!(digest_tensor(&a), digest_tensor(&b));
        assert_ne!(digest_tensor(&a), digest_tensor(&c));
        assert_eq!(digest_tensor(&a), digest_tensor(&a.clone()));
    }

    #[test]
    fn hex_roundtrip() {
        let d = digest_bytes(b"roundtrip");
        assert_eq!(Digest::parse_hex(&d.hex()), Some(d));
        assert_eq!(Digest::parse_hex("zz"), None);
        assert_eq!(Digest::parse_hex(&"0".repeat(31)), None);
        assert_eq!(Digest::parse_hex(&"G".repeat(32)), None);
    }

    #[test]
    fn file_digest_matches_bytes() {
        let p = std::env::temp_dir().join("grail_digest_file_test.bin");
        // Miri interprets every byte; keep its copy of the fixture
        // small (the digest math is identical at any length).
        #[cfg(miri)]
        let count = 64u32;
        #[cfg(not(miri))]
        let count = 10_000u32;
        let data: Vec<u8> = (0..count).flat_map(|i| i.to_le_bytes()).collect();
        std::fs::write(&p, &data).unwrap();
        assert_eq!(digest_file(p.to_str().unwrap()).unwrap(), digest_bytes(&data));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn wire_fields_roundtrip() {
        assert_eq!(wire_u32(0, "x"), 0u32.to_le_bytes());
        assert_eq!(wire_u32(77, "x"), 77u32.to_le_bytes());
        assert_eq!(wire_u64(1 << 20, "x"), (1u64 << 20).to_le_bytes());
        assert_eq!(u32::from_le_bytes(wire_u32(12345, "x")), 12345);
    }

    // Oversize geometry must be *rejected*, not wrapped into a small,
    // plausible-looking wire value (a wrapped shard count or row count
    // would silently poison every digest derived from it).
    #[cfg(target_pointer_width = "64")]
    #[test]
    #[should_panic(expected = "exceeds the u32 wire field")]
    fn oversize_u32_wire_field_panics() {
        let _ = wire_u32(u32::MAX as usize + 1, "shard count");
    }
}
