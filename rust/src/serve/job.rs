//! Persisted job records for the `grail serve` daemon.
//!
//! One job = one submitted spec file plus execution metadata, living
//! at `<serve-root>/jobs/<id>/`:
//!
//! ```text
//! jobs/<id>/spec.toml    the submitted spec, verbatim
//! jobs/<id>/status.toml  this record (atomic rewrite on every change)
//! jobs/<id>/log.txt      append-only structured per-attempt lines
//! ```
//!
//! The state machine is `queued → running → done | failed`, with a
//! bounded retry edge `running → queued` while `attempts ≤ retries`.
//! `status.toml` is the single source of truth: the daemon's queue is
//! simply "every job whose persisted state is `queued`", so a daemon
//! restart resumes exactly where the disk says it was (a job killed
//! mid-`running` is re-queued on startup, which the bounded attempt
//! counter keeps finite).

use anyhow::{anyhow, Context, Result};
use std::path::Path;

/// What the daemon does with a job's spec.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobVerb {
    /// Resolve and persist the plan; mutate nothing.
    Plan,
    /// Compress + evaluate; persist the report.
    Run,
    /// Calibration-driven plan search; persist the winning plan.
    Tune,
}

impl JobVerb {
    pub fn name(&self) -> &'static str {
        match self {
            JobVerb::Plan => "plan",
            JobVerb::Run => "run",
            JobVerb::Tune => "tune",
        }
    }

    pub fn from_name(s: &str) -> Option<JobVerb> {
        Some(match s {
            "plan" => JobVerb::Plan,
            "run" => JobVerb::Run,
            "tune" => JobVerb::Tune,
            _ => return None,
        })
    }
}

/// Lifecycle state of a job.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobState {
    Queued,
    Running,
    Done,
    Failed,
}

impl JobState {
    pub fn name(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Failed => "failed",
        }
    }

    pub fn from_name(s: &str) -> Option<JobState> {
        Some(match s {
            "queued" => JobState::Queued,
            "running" => JobState::Running,
            "done" => JobState::Done,
            "failed" => JobState::Failed,
            _ => return None,
        })
    }

    /// Whether the job has finished (successfully or not).
    pub fn is_terminal(&self) -> bool {
        matches!(self, JobState::Done | JobState::Failed)
    }
}

/// One job's persisted record.
#[derive(Clone, Debug)]
pub struct JobRecord {
    /// Content-derived hex id (digest of verb + overrides + spec
    /// bytes), so identical submissions collapse onto one job.
    pub id: String,
    pub verb: JobVerb,
    /// `--family` override carried from submission ("" = none).
    pub family: String,
    /// `--ckpt` override carried from submission ("" = none).
    pub ckpt: String,
    pub state: JobState,
    /// Execution attempts so far (0 until first pickup).
    pub attempts: usize,
    /// Extra attempts allowed after the first failure.
    pub retries: usize,
    /// Last error ("" when none).
    pub error: String,
    /// Result location relative to the serve root ("" until done).
    pub result: String,
    /// Wall time of the last attempt.
    pub wall_seconds: f64,
    /// Statistics-cache entry hits/misses across all attempts.
    pub cache_hits: u64,
    pub cache_misses: u64,
}

/// Collapse a free-form error message into the TOML-subset string
/// grammar (one line, `'` for `"`).
fn sanitize(s: &str) -> String {
    s.chars()
        .map(|c| match c {
            '"' => '\'',
            '\\' => '/',
            '\n' | '\r' | '\t' => ' ',
            c => c,
        })
        .collect()
}

impl JobRecord {
    pub fn new(id: String, verb: JobVerb, retries: usize, family: &str, ckpt: &str) -> JobRecord {
        JobRecord {
            id,
            verb,
            family: family.to_string(),
            ckpt: ckpt.to_string(),
            state: JobState::Queued,
            attempts: 0,
            retries,
            error: String::new(),
            result: String::new(),
            wall_seconds: 0.0,
            cache_hits: 0,
            cache_misses: 0,
        }
    }

    /// Serialize as a `[job]` TOML section.
    pub fn to_toml(&self) -> String {
        format!(
            "[job]\nid = \"{}\"\nverb = \"{}\"\nfamily = \"{}\"\nckpt = \"{}\"\n\
             state = \"{}\"\nattempts = {}\nretries = {}\nerror = \"{}\"\n\
             result = \"{}\"\nwall_seconds = {:.6}\ncache_hits = {}\ncache_misses = {}\n",
            sanitize(&self.id),
            self.verb.name(),
            sanitize(&self.family),
            sanitize(&self.ckpt),
            self.state.name(),
            self.attempts,
            self.retries,
            sanitize(&self.error),
            sanitize(&self.result),
            self.wall_seconds,
            self.cache_hits,
            self.cache_misses,
        )
    }

    /// Parse a `status.toml`.
    pub fn parse(text: &str) -> Result<JobRecord> {
        let cfg = crate::config::Config::parse(text)?;
        let verb_name = cfg.str("job.verb")?;
        let verb = JobVerb::from_name(verb_name)
            .ok_or_else(|| anyhow!("job.verb: unknown verb `{verb_name}`"))?;
        let state_name = cfg.str("job.state")?;
        let state = JobState::from_name(state_name)
            .ok_or_else(|| anyhow!("job.state: unknown state `{state_name}`"))?;
        Ok(JobRecord {
            id: cfg.str("job.id")?.to_string(),
            verb,
            family: cfg.str_or("job.family", "").to_string(),
            ckpt: cfg.str_or("job.ckpt", "").to_string(),
            state,
            attempts: cfg.usize_or("job.attempts", 0),
            retries: cfg.usize_or("job.retries", 0),
            error: cfg.str_or("job.error", "").to_string(),
            result: cfg.str_or("job.result", "").to_string(),
            wall_seconds: cfg.f64_or("job.wall_seconds", 0.0),
            cache_hits: cfg.usize_or("job.cache_hits", 0) as u64,
            cache_misses: cfg.usize_or("job.cache_misses", 0) as u64,
        })
    }

    /// Atomically rewrite `<dir>/status.toml` (temp file + rename, so
    /// concurrent readers never see a torn record).
    pub fn save(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir).with_context(|| format!("creating {dir:?}"))?;
        let tmp = dir.join(format!(".status.tmp.{}", std::process::id()));
        std::fs::write(&tmp, self.to_toml()).with_context(|| format!("writing {tmp:?}"))?;
        let path = dir.join("status.toml");
        std::fs::rename(&tmp, &path).with_context(|| format!("publishing {path:?}"))
    }

    /// Load `<dir>/status.toml`.
    pub fn load(dir: &Path) -> Result<JobRecord> {
        let path = dir.join("status.toml");
        let text =
            std::fs::read_to_string(&path).with_context(|| format!("reading {path:?}"))?;
        JobRecord::parse(&text).with_context(|| format!("parsing {path:?}"))
    }

    /// One structured log line describing the current state.
    pub fn log_line(&self) -> String {
        let mut line = format!(
            "job={} verb={} state={} attempt={}/{}",
            self.id,
            self.verb.name(),
            self.state.name(),
            self.attempts,
            1 + self.retries,
        );
        if !self.family.is_empty() {
            line.push_str(&format!(" family={}", self.family));
        }
        if !self.ckpt.is_empty() {
            line.push_str(&format!(" ckpt={}", self.ckpt));
        }
        if self.state.is_terminal() || self.wall_seconds > 0.0 {
            line.push_str(&format!(
                " secs={:.3} cache_hits={} cache_misses={}",
                self.wall_seconds, self.cache_hits, self.cache_misses
            ));
        }
        if !self.error.is_empty() {
            line.push_str(&format!(" error=\"{}\"", sanitize(&self.error)));
        }
        line
    }

    /// Append the current [`log_line`](JobRecord::log_line) to
    /// `<dir>/log.txt` and echo it to stdout.
    pub fn log(&self, dir: &Path) -> Result<()> {
        use std::io::Write;
        let line = self.log_line();
        println!("[serve] {line}");
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(dir.join("log.txt"))
            .with_context(|| format!("opening {dir:?}/log.txt"))?;
        writeln!(f, "{line}").with_context(|| format!("appending {dir:?}/log.txt"))
    }
}

/// Round-trip sanity for the whole record (used by `status`/`jobs`).
pub fn verbs_and_states() -> (Vec<JobVerb>, Vec<JobState>) {
    (
        vec![JobVerb::Plan, JobVerb::Run, JobVerb::Tune],
        vec![JobState::Queued, JobState::Running, JobState::Done, JobState::Failed],
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_roundtrip() {
        let (verbs, states) = verbs_and_states();
        for v in verbs {
            assert_eq!(JobVerb::from_name(v.name()), Some(v));
        }
        for s in states {
            assert_eq!(JobState::from_name(s.name()), Some(s));
        }
        assert!(JobVerb::from_name("nope").is_none());
        assert!(JobState::from_name("nope").is_none());
        assert!(!JobState::Queued.is_terminal());
        assert!(!JobState::Running.is_terminal());
        assert!(JobState::Done.is_terminal());
        assert!(JobState::Failed.is_terminal());
    }

    #[test]
    fn record_toml_roundtrips_including_hostile_error() {
        let mut rec = JobRecord::new("abc123".into(), JobVerb::Tune, 2, "lm", "tinylm_gqa");
        rec.state = JobState::Failed;
        rec.attempts = 3;
        rec.error = "boom: \"quoted\"\nwith\tnewline \\ backslash".into();
        rec.result = "results/abc123".into();
        rec.wall_seconds = 1.25;
        rec.cache_hits = 7;
        rec.cache_misses = 3;
        let back = JobRecord::parse(&rec.to_toml()).unwrap();
        assert_eq!(back.id, rec.id);
        assert_eq!(back.verb, rec.verb);
        assert_eq!(back.family, "lm");
        assert_eq!(back.ckpt, "tinylm_gqa");
        assert_eq!(back.state, JobState::Failed);
        assert_eq!(back.attempts, 3);
        assert_eq!(back.retries, 2);
        assert_eq!(back.error, "boom: 'quoted' with newline / backslash");
        assert_eq!(back.result, "results/abc123");
        assert!((back.wall_seconds - 1.25).abs() < 1e-9);
        assert_eq!((back.cache_hits, back.cache_misses), (7, 3));
    }

    #[test]
    fn save_load_roundtrip_and_log_append() {
        let dir = std::env::temp_dir().join(format!("grail_job_unit_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let mut rec = JobRecord::new("deadbeef".into(), JobVerb::Plan, 1, "", "");
        rec.save(&dir).unwrap();
        assert_eq!(JobRecord::load(&dir).unwrap().state, JobState::Queued);
        rec.state = JobState::Running;
        rec.attempts = 1;
        rec.save(&dir).unwrap();
        rec.log(&dir).unwrap();
        rec.state = JobState::Done;
        rec.log(&dir).unwrap();
        let log = std::fs::read_to_string(dir.join("log.txt")).unwrap();
        assert_eq!(log.lines().count(), 2);
        assert!(log.contains("state=running"));
        assert!(log.contains("state=done"));
        assert!(log.contains("attempt=1/2"));
        assert_eq!(JobRecord::load(&dir).unwrap().state, JobState::Running);
        std::fs::remove_dir_all(&dir).ok();
    }
}
