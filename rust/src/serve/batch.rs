//! Continuous-batching decode scheduler with paged KV storage.
//!
//! PR 6's serving primitives step one request at a time: every
//! in-flight stream pays its own 1-row GEMM per layer and owns a full
//! `max_seq` cache slab. This module adds the two serving-scale
//! levers on top of that path:
//!
//! - [`KvPagePool`] — a fixed budget of fixed-size *position pages*
//!   shared by all requests. A request's K/V streams grow page by page
//!   through per-request page tables
//!   ([`PagedKv`](crate::nn::models::PagedKv)) and return their pages
//!   on completion, so concurrent capacity is bounded by *live*
//!   positions, not by `requests × max_seq`. Exhaustion panics loudly;
//!   the scheduler's admission accounting makes it unreachable from
//!   scheduled traffic.
//! - [`BatchScheduler`] — cross-request **continuous batching**:
//!   queued requests are admitted mid-flight whenever batch room and
//!   page budget allow (FIFO, head-of-line), every scheduler step runs
//!   *one* coalesced multi-row
//!   [`decode_batch_step`](crate::nn::models::TinyLm::decode_batch_step)
//!   for all active requests, and completed requests are evicted at
//!   the step they finish, freeing their pages for the queue.
//!
//! The whole point of coalescing is that it is **free of numerical
//! consequence**: the serving GEMMs dispatch on `(k, n)` only
//! ([`use_packed_cols`](crate::tensor::gemm::use_packed_cols) has no
//! row-count argument) and every other stage is row-local, so an
//! m-row coalesced step is bitwise equal to m solo 1-row steps. Each
//! request's token stream is therefore bit-identical to its solo
//! [`generate`](crate::nn::models::TinyLm::generate) run at any batch
//! composition, admission order, and worker count —
//! `rust/tests/decode.rs` asserts all three.
//!
//! Determinism: admission is FIFO in submit order, steps are explicit
//! (no wall-clock anywhere), and page ids come off a LIFO free list —
//! a replayed workload reproduces the exact same schedule.

use std::collections::VecDeque;

use crate::nn::argmax_rows;
use crate::nn::models::{LmServePack, PagedKv, TinyLm};

/// A fixed budget of fixed-size K/V position pages shared by every
/// in-flight request. One page holds `page_positions` cache rows of
/// one (K|V, KV-head) stream, `d_head` floats each; all pages live in
/// one flat allocation made up front, so serving never allocates on
/// the decode path beyond page-table bookkeeping.
pub struct KvPagePool {
    data: Vec<f32>,
    page_positions: usize,
    dh: usize,
    /// LIFO free list: deterministic page handout, hot pages reused
    /// first.
    free: Vec<usize>,
    total_pages: usize,
    peak_in_use: usize,
}

impl KvPagePool {
    /// Pool of `total_pages` pages, each holding `page_positions`
    /// rows of `dh` floats.
    pub fn new(page_positions: usize, dh: usize, total_pages: usize) -> KvPagePool {
        assert!(page_positions > 0, "pages must hold at least one position");
        assert!(dh > 0, "zero-width cache rows");
        assert!(total_pages > 0, "a pool needs at least one page");
        KvPagePool {
            data: vec![0.0f32; total_pages * page_positions * dh],
            page_positions,
            dh,
            free: (0..total_pages).rev().collect(),
            total_pages,
            peak_in_use: 0,
        }
    }

    /// Positions per page.
    pub fn page_positions(&self) -> usize {
        self.page_positions
    }

    /// Floats per page (`page_positions * d_head`).
    pub fn page_elems(&self) -> usize {
        self.page_positions * self.dh
    }

    /// Total page budget.
    pub fn total_pages(&self) -> usize {
        self.total_pages
    }

    /// Pages currently free.
    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    /// Pages currently held by requests.
    pub fn pages_in_use(&self) -> usize {
        self.total_pages - self.free.len()
    }

    /// High-water mark of [`Self::pages_in_use`] over the pool's life.
    pub fn peak_pages_in_use(&self) -> usize {
        self.peak_in_use
    }

    /// Backing slice of page `id`.
    pub fn page(&self, id: usize) -> &[f32] {
        let pe = self.page_elems();
        &self.data[id * pe..(id + 1) * pe]
    }

    pub(crate) fn page_mut(&mut self, id: usize) -> &mut [f32] {
        let pe = self.page_elems();
        &mut self.data[id * pe..(id + 1) * pe]
    }

    /// Take a free page. Panics loudly on exhaustion — silent
    /// truncation of a KV cache would corrupt every later token of the
    /// affected request, so an over-committed pool is a hard error;
    /// [`BatchScheduler`] admission accounting keeps scheduled traffic
    /// strictly inside the budget.
    pub(crate) fn alloc(&mut self) -> usize {
        let id = self.free.pop().unwrap_or_else(|| {
            panic!(
                "KV page pool exhausted: all {} pages ({} positions each) are live — \
                 admit fewer concurrent requests or grow the pool budget",
                self.total_pages, self.page_positions
            )
        });
        self.peak_in_use = self.peak_in_use.max(self.pages_in_use());
        id
    }

    /// Return a page to the free list.
    pub(crate) fn release(&mut self, id: usize) {
        debug_assert!(id < self.total_pages, "foreign page id {id}");
        debug_assert!(!self.free.contains(&id), "double free of page {id}");
        self.free.push(id);
    }
}

/// One finished request: its id (from [`BatchScheduler::submit`]) and
/// the full token stream, prompt included — exactly what the solo
/// [`generate`](crate::nn::models::TinyLm::generate) returns.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Completion {
    pub id: usize,
    pub tokens: Vec<u16>,
}

/// Scheduler counters, for tests, benches, and capacity accounting.
#[derive(Clone, Copy, Debug, Default)]
pub struct BatchStats {
    /// Requests accepted by [`BatchScheduler::submit`].
    pub submitted: usize,
    /// Requests completed and evicted.
    pub completed: usize,
    /// Coalesced decode steps executed.
    pub decode_steps: usize,
    /// Total rows across all coalesced steps (`/ decode_steps` =
    /// mean batch occupancy).
    pub coalesced_rows: usize,
    /// High-water mark of concurrently active requests.
    pub peak_active: usize,
}

struct Pending {
    id: usize,
    prompt: Vec<u16>,
    n_new: usize,
}

struct Active {
    id: usize,
    kv: PagedKv,
    out: Vec<u16>,
    n_new: usize,
    emitted: usize,
    last: u16,
    /// Worst-case page count reserved at admission.
    worst_pages: usize,
}

/// Continuous-batching greedy-decode scheduler over one model. See
/// the [module docs](self) for the design; driving protocol:
///
/// 1. [`Self::submit`] any number of requests (FIFO queue).
/// 2. Call [`Self::step`] repeatedly — each step admits whatever fits,
///    prefills newcomers, runs one coalesced decode step over all
///    active requests, and returns the requests that completed.
/// 3. [`Self::run_to_completion`] loops until idle.
pub struct BatchScheduler<'m> {
    model: &'m TinyLm,
    pack: LmServePack,
    pool: KvPagePool,
    queue: VecDeque<Pending>,
    active: Vec<Active>,
    max_batch: usize,
    /// Σ worst-case pages over active requests — admission headroom.
    committed_pages: usize,
    next_id: usize,
    stats: BatchStats,
}

impl<'m> BatchScheduler<'m> {
    /// Scheduler over `model` with a pool of `pool_pages` pages of
    /// `page_positions` positions each, coalescing at most `max_batch`
    /// requests per step. Weights are prepacked once, here.
    pub fn new(
        model: &'m TinyLm,
        page_positions: usize,
        pool_pages: usize,
        max_batch: usize,
    ) -> BatchScheduler<'m> {
        assert!(max_batch >= 1, "a batch must admit at least one request");
        let pack = model.serve_pack();
        let pool = KvPagePool::new(page_positions, pack.d_head(), pool_pages);
        BatchScheduler {
            model,
            pack,
            pool,
            queue: VecDeque::new(),
            active: Vec::new(),
            max_batch,
            committed_pages: 0,
            next_id: 0,
            stats: BatchStats::default(),
        }
    }

    /// Enqueue a greedy-generation request (prompt plus `n_new` new
    /// tokens); returns its completion id. Panics if the request could
    /// *never* be admitted (worst-case pages exceed the whole pool) —
    /// queueing it would deadlock the FIFO.
    pub fn submit(&mut self, prompt: &[u16], n_new: usize) -> usize {
        assert!(!prompt.is_empty(), "empty prompt");
        assert!(n_new >= 1, "a request must generate at least one token");
        assert!(
            prompt.len() + n_new <= self.model.cfg.max_seq,
            "generation would exceed max_seq"
        );
        let worst = self.pack.pages_needed(prompt.len() + n_new, self.pool.page_positions());
        assert!(
            worst <= self.pool.total_pages(),
            "request needs {worst} pages at full length but the pool holds only {} — \
             it can never be admitted",
            self.pool.total_pages()
        );
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back(Pending { id, prompt: prompt.to_vec(), n_new });
        self.stats.submitted += 1;
        id
    }

    /// True when no work remains (empty queue, empty batch).
    pub fn is_idle(&self) -> bool {
        self.queue.is_empty() && self.active.is_empty()
    }

    /// Requests currently in the coalesced batch.
    pub fn active_requests(&self) -> usize {
        self.active.len()
    }

    /// Requests waiting for admission.
    pub fn queued_requests(&self) -> usize {
        self.queue.len()
    }

    /// Scheduler counters so far.
    pub fn stats(&self) -> BatchStats {
        self.stats
    }

    /// The shared page pool (for capacity accounting in tests and
    /// benches).
    pub fn pool(&self) -> &KvPagePool {
        &self.pool
    }

    /// One scheduler step: admit, prefill, coalesce-decode, evict.
    /// Returns the requests that completed during this step, in
    /// completion order.
    ///
    /// Admission is FIFO with head-of-line blocking, reserving each
    /// request's **worst-case** page count (`pages_needed(prompt +
    /// n_new)`) up front, so an admitted request can always grow to
    /// its full length — mid-decode pool exhaustion is structurally
    /// unreachable.
    pub fn step(&mut self) -> Vec<Completion> {
        let mut done = Vec::new();
        while self.active.len() < self.max_batch {
            let fits = self.queue.front().is_some_and(|p| {
                let worst =
                    self.pack.pages_needed(p.prompt.len() + p.n_new, self.pool.page_positions());
                self.committed_pages + worst <= self.pool.total_pages()
            });
            if !fits {
                break;
            }
            let p = self.queue.pop_front().unwrap();
            let worst =
                self.pack.pages_needed(p.prompt.len() + p.n_new, self.pool.page_positions());
            self.committed_pages += worst;
            let mut kv = PagedKv::new(&self.pack, self.model.cfg.max_seq);
            let logits = self.model.paged_prefill(&self.pack, &mut self.pool, &mut kv, &p.prompt);
            let first = argmax_rows(&logits)[logits.dim(0) - 1] as u16;
            let mut out = p.prompt;
            out.push(first);
            self.active.push(Active {
                id: p.id,
                kv,
                out,
                n_new: p.n_new,
                emitted: 1,
                last: first,
                worst_pages: worst,
            });
        }
        self.stats.peak_active = self.stats.peak_active.max(self.active.len());
        // n_new == 1 requests finish at prefill, before any decode.
        self.evict_completed(&mut done);
        if !self.active.is_empty() {
            let tokens: Vec<u16> = self.active.iter().map(|a| a.last).collect();
            let mut refs: Vec<&mut PagedKv> =
                self.active.iter_mut().map(|a| &mut a.kv).collect();
            let logits =
                self.model.decode_batch_step(&self.pack, &mut self.pool, &mut refs, &tokens);
            drop(refs);
            let picks = argmax_rows(&logits);
            for (r, a) in self.active.iter_mut().enumerate() {
                let next = picks[r] as u16;
                a.out.push(next);
                a.emitted += 1;
                a.last = next;
            }
            self.stats.decode_steps += 1;
            self.stats.coalesced_rows += tokens.len();
            self.evict_completed(&mut done);
        }
        done
    }

    /// Drive [`Self::step`] until idle; completions in completion
    /// order (ties within a step in admission order).
    pub fn run_to_completion(&mut self) -> Vec<Completion> {
        let mut done = Vec::new();
        while !self.is_idle() {
            done.extend(self.step());
        }
        done
    }

    fn evict_completed(&mut self, done: &mut Vec<Completion>) {
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].emitted >= self.active[i].n_new {
                let mut a = self.active.remove(i);
                a.kv.release(&mut self.pool);
                self.committed_pages -= a.worst_pages;
                self.stats.completed += 1;
                done.push(Completion { id: a.id, tokens: a.out });
            } else {
                i += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::models::LmConfig;
    use crate::rng::Pcg64;

    #[test]
    fn pool_alloc_release_accounting() {
        let mut pool = KvPagePool::new(4, 8, 3);
        assert_eq!(pool.free_pages(), 3);
        assert_eq!(pool.page_elems(), 32);
        let a = pool.alloc();
        let b = pool.alloc();
        assert_eq!(pool.pages_in_use(), 2);
        assert_eq!(pool.peak_pages_in_use(), 2);
        pool.release(a);
        assert_eq!(pool.pages_in_use(), 1);
        // LIFO: the page released last comes back first.
        assert_eq!(pool.alloc(), a);
        pool.release(a);
        pool.release(b);
        assert_eq!(pool.free_pages(), 3);
        assert_eq!(pool.peak_pages_in_use(), 2, "peak survives release");
    }

    #[test]
    #[should_panic(expected = "KV page pool exhausted")]
    fn pool_exhaustion_panics() {
        let mut pool = KvPagePool::new(4, 8, 2);
        let _ = pool.alloc();
        let _ = pool.alloc();
        let _ = pool.alloc();
    }

    #[test]
    fn pages_needed_rounds_up_per_stream() {
        let mut rng = Pcg64::seed(3);
        let m = TinyLm::init(LmConfig::default(), &mut rng);
        let pack = m.serve_pack();
        // Default config: 4 blocks × 8 KV heads = 32 streams, K and V.
        assert_eq!(pack.total_kv_streams(), 32);
        assert_eq!(pack.pages_needed(1, 16), 64, "one position still takes a page per stream");
        assert_eq!(pack.pages_needed(16, 16), 64);
        assert_eq!(pack.pages_needed(17, 16), 128);
        // Slab comparison baseline: every stream owns max_seq rows.
        assert_eq!(pack.slab_elems(64), 2 * 32 * 64 * 8);
    }

    #[test]
    #[should_panic(expected = "can never be admitted")]
    fn oversized_request_rejected_at_submit() {
        let mut rng = Pcg64::seed(4);
        let m = TinyLm::init(LmConfig::default(), &mut rng);
        // 64 streams × 2 needed pages each at ps=16 for len 17 — give
        // the pool less than that.
        let mut sched = BatchScheduler::new(&m, 16, 64, 8);
        sched.submit(&[1; 9], 8); // len 17 → 128 pages > 64
    }

    #[test]
    fn scheduler_matches_solo_generate_and_frees_pages() {
        let mut rng = Pcg64::seed(5);
        let m = TinyLm::init(LmConfig::default(), &mut rng);
        let prompts: Vec<Vec<u16>> = (0..3)
            .map(|i| (0..4 + i).map(|j| ((i * 7 + j * 3) % 60) as u16).collect())
            .collect();
        let n_new = [5usize, 1, 3];
        let mut sched = BatchScheduler::new(&m, 8, 512, 8);
        let ids: Vec<usize> =
            prompts.iter().zip(n_new).map(|(p, n)| sched.submit(p, n)).collect();
        let done = sched.run_to_completion();
        assert_eq!(done.len(), 3);
        for (i, id) in ids.iter().enumerate() {
            let c = done.iter().find(|c| c.id == *id).unwrap();
            assert_eq!(c.tokens, m.generate(&prompts[i], n_new[i]), "request {i}");
        }
        // Everything evicted: all pages back in the pool.
        assert!(sched.is_idle());
        assert_eq!(sched.pool().pages_in_use(), 0, "completed requests leak no pages");
        let st = sched.stats();
        assert_eq!(st.submitted, 3);
        assert_eq!(st.completed, 3);
        assert!(st.peak_active >= 2, "requests actually coalesced: {st:?}");
        assert!(st.coalesced_rows >= st.decode_steps);
    }
}
