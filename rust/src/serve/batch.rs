//! Continuous-batching decode scheduler with paged KV storage.
//!
//! PR 6's serving primitives step one request at a time: every
//! in-flight stream pays its own 1-row GEMM per layer and owns a full
//! `max_seq` cache slab. This module adds the two serving-scale
//! levers on top of that path:
//!
//! - [`KvPagePool`] — a fixed budget of fixed-size *position pages*
//!   shared by all requests. A request's K/V streams grow page by page
//!   through per-request page tables
//!   ([`PagedKv`](crate::nn::models::PagedKv)) and return their pages
//!   on completion, so concurrent capacity is bounded by *live*
//!   positions, not by `requests × max_seq`. Exhaustion panics loudly;
//!   the scheduler's admission accounting makes it unreachable from
//!   scheduled traffic.
//! - [`BatchScheduler`] — cross-request **continuous batching with
//!   chunked prefill**: queued requests are admitted mid-flight
//!   whenever batch room and page budget allow (FIFO, head-of-line)
//!   and enter a *prefilling* phase; every scheduler step builds one
//!   mixed multi-row [`batch_step`](crate::nn::models::TinyLm::batch_step)
//!   pass — all active decode rows plus up to `prefill_chunk` prompt
//!   rows drawn round-robin from prefilling requests — so one long
//!   prompt never serializes in front of in-flight decodes (the
//!   head-of-line latency cliff chunked-prefill schedulers exist to
//!   remove). Completed requests are evicted at the step they finish,
//!   freeing their pages and their cache slab slot for the queue.
//!
//! The whole point of coalescing — and of chunking — is that it is
//! **free of numerical consequence**: the serving GEMMs dispatch on
//! `(k, n)` only
//! ([`use_packed_cols`](crate::tensor::gemm::use_packed_cols) has no
//! row-count argument) and every other stage is row-local, so an
//! m-row coalesced step is bitwise equal to m solo 1-row steps, and
//! any chunking of a prompt writes the same K/V bytes and final
//! logits as the one-shot
//! [`paged_prefill`](crate::nn::models::TinyLm::paged_prefill). Each
//! request's token stream is therefore bit-identical to its solo
//! [`generate`](crate::nn::models::TinyLm::generate) run at any chunk
//! size, batch composition, admission order, and worker count —
//! `rust/tests/decode.rs` asserts all four.
//!
//! Determinism: admission is FIFO in submit order, the prefill row
//! budget round-robins one token at a time over prefilling requests in
//! admission order (a long prompt cannot starve a short one behind
//! it), steps are explicit (no wall-clock anywhere), and page ids come
//! off a LIFO free list — a replayed workload reproduces the exact
//! same schedule.

use std::collections::VecDeque;

use crate::nn::argmax_rows;
use crate::nn::models::{BatchScratch, LmServePack, PagedKv, RowSpan, TinyLm};

/// Default per-step prefill row budget. Sized a little above the
/// default page so a fresh request reaches its first token quickly,
/// while a long prompt still yields the pass to live decode rows every
/// step. `usize::MAX` restores one-shot (unchunked) prefill.
pub const DEFAULT_PREFILL_CHUNK: usize = 16;

/// A fixed budget of fixed-size K/V position pages shared by every
/// in-flight request. One page holds `page_positions` cache rows of
/// one (K|V, KV-head) stream, `d_head` floats each; all pages live in
/// one flat allocation made up front, so serving never allocates on
/// the decode path beyond page-table bookkeeping.
pub struct KvPagePool {
    data: Vec<f32>,
    page_positions: usize,
    dh: usize,
    /// LIFO free list: deterministic page handout, hot pages reused
    /// first.
    free: Vec<usize>,
    total_pages: usize,
    peak_in_use: usize,
}

impl KvPagePool {
    /// Pool of `total_pages` pages, each holding `page_positions`
    /// rows of `dh` floats.
    pub fn new(page_positions: usize, dh: usize, total_pages: usize) -> KvPagePool {
        assert!(page_positions > 0, "pages must hold at least one position");
        assert!(dh > 0, "zero-width cache rows");
        assert!(total_pages > 0, "a pool needs at least one page");
        KvPagePool {
            data: vec![0.0f32; total_pages * page_positions * dh],
            page_positions,
            dh,
            free: (0..total_pages).rev().collect(),
            total_pages,
            peak_in_use: 0,
        }
    }

    /// Positions per page.
    pub fn page_positions(&self) -> usize {
        self.page_positions
    }

    /// Floats per page (`page_positions * d_head`).
    pub fn page_elems(&self) -> usize {
        self.page_positions * self.dh
    }

    /// Total page budget.
    pub fn total_pages(&self) -> usize {
        self.total_pages
    }

    /// Pages currently free.
    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    /// Pages currently held by requests.
    pub fn pages_in_use(&self) -> usize {
        self.total_pages - self.free.len()
    }

    /// High-water mark of [`Self::pages_in_use`] over the pool's life.
    pub fn peak_pages_in_use(&self) -> usize {
        self.peak_in_use
    }

    /// Backing slice of page `id`.
    pub fn page(&self, id: usize) -> &[f32] {
        let pe = self.page_elems();
        &self.data[id * pe..(id + 1) * pe]
    }

    pub(crate) fn page_mut(&mut self, id: usize) -> &mut [f32] {
        let pe = self.page_elems();
        &mut self.data[id * pe..(id + 1) * pe]
    }

    /// Take a free page. Panics loudly on exhaustion — silent
    /// truncation of a KV cache would corrupt every later token of the
    /// affected request, so an over-committed pool is a hard error;
    /// [`BatchScheduler`] admission accounting keeps scheduled traffic
    /// strictly inside the budget.
    pub(crate) fn alloc(&mut self) -> usize {
        let id = self.free.pop().unwrap_or_else(|| {
            panic!(
                "KV page pool exhausted: all {} pages ({} positions each) are live — \
                 admit fewer concurrent requests or grow the pool budget",
                self.total_pages, self.page_positions
            )
        });
        self.peak_in_use = self.peak_in_use.max(self.pages_in_use());
        id
    }

    /// Return a page to the free list.
    pub(crate) fn release(&mut self, id: usize) {
        debug_assert!(id < self.total_pages, "foreign page id {id}");
        debug_assert!(!self.free.contains(&id), "double free of page {id}");
        self.free.push(id);
    }
}

/// One finished request: its id (from [`BatchScheduler::submit`]) and
/// the full token stream, prompt included — exactly what the solo
/// [`generate`](crate::nn::models::TinyLm::generate) returns.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Completion {
    pub id: usize,
    pub tokens: Vec<u16>,
}

/// Scheduler counters, for tests, benches, and capacity accounting.
#[derive(Clone, Copy, Debug, Default)]
pub struct BatchStats {
    /// Requests accepted by [`BatchScheduler::submit`].
    pub submitted: usize,
    /// Requests completed and evicted.
    pub completed: usize,
    /// Coalesced forward passes executed (any row mix).
    pub passes: usize,
    /// Total rows across all passes (`/ passes` = per-step occupancy,
    /// see [`Self::occupancy`]).
    pub pass_rows: usize,
    /// Passes containing at least one decode row.
    pub decode_steps: usize,
    /// Decode rows across all passes (`/ decode_steps` = mean decode
    /// batch occupancy).
    pub coalesced_rows: usize,
    /// Prefill chunks (spans) scheduled across all passes.
    pub prefill_chunks: usize,
    /// Prompt rows prefilled through coalesced passes.
    pub prefill_rows: usize,
    /// Passes mixing at least one decode row with at least one
    /// prefill chunk — the head-of-line overlap chunking buys.
    pub mixed_steps: usize,
    /// Vocab-projection rows the lazy prefill `lm_head` skipped
    /// (`prompt_len − 1` per request vs the eager full-prompt GEMM).
    pub lm_head_rows_saved: usize,
    /// High-water mark of concurrently active requests.
    pub peak_active: usize,
}

impl BatchStats {
    /// Mean rows per coalesced pass (decode rows + prefill rows).
    pub fn occupancy(&self) -> f64 {
        self.pass_rows as f64 / self.passes.max(1) as f64
    }
}

struct Pending {
    id: usize,
    prompt: Vec<u16>,
    n_new: usize,
}

/// Where an active request is in its lifecycle.
#[derive(Clone, Copy)]
enum Phase {
    /// `filled` prompt tokens are in the cache; the rest feed future
    /// chunks.
    Prefilling { filled: usize },
    Decoding,
}

struct Active {
    id: usize,
    /// Index of this request's cache in the scheduler's `kvs` slab.
    slot: usize,
    /// Full token stream: the prompt, then generated tokens.
    out: Vec<u16>,
    prompt_len: usize,
    n_new: usize,
    emitted: usize,
    /// Last generated token (meaningful once `phase == Decoding`).
    last: u16,
    phase: Phase,
    /// Worst-case page count reserved at admission.
    worst_pages: usize,
}

/// Continuous-batching greedy-decode scheduler over one model. See
/// the [module docs](self) for the design; driving protocol:
///
/// 1. [`Self::submit`] any number of requests (FIFO queue).
/// 2. Call [`Self::step`] repeatedly — each step admits whatever fits,
///    runs one mixed prefill+decode pass over all active requests,
///    and returns the requests that completed.
/// 3. [`Self::run_to_completion`] loops until idle.
pub struct BatchScheduler<'m> {
    model: &'m TinyLm,
    pack: LmServePack,
    pool: KvPagePool,
    queue: VecDeque<Pending>,
    active: Vec<Active>,
    /// Slab of per-request page-table states; evicted slots go on
    /// `free_slots` and are recycled (their table `Vec`s keep their
    /// capacity), so steady-state admission allocates nothing.
    kvs: Vec<PagedKv>,
    free_slots: Vec<usize>,
    max_batch: usize,
    /// Per-step prefill row budget ([`DEFAULT_PREFILL_CHUNK`]).
    prefill_chunk: usize,
    /// Σ worst-case pages over active requests — admission headroom.
    committed_pages: usize,
    next_id: usize,
    stats: BatchStats,
    // Step scratch, reused across steps: the per-step `tokens` vec,
    // span list, owner map, and round-robin grant counts, plus the
    // model-side buffers. Capacities survive between steps, so a
    // warmed steady-state step performs none of these allocations.
    tokens: Vec<u16>,
    spans: Vec<RowSpan>,
    span_owner: Vec<usize>,
    take: Vec<usize>,
    scratch: BatchScratch,
}

impl<'m> BatchScheduler<'m> {
    /// Scheduler over `model` with a pool of `pool_pages` pages of
    /// `page_positions` positions each, coalescing at most `max_batch`
    /// requests per step. Weights are prepacked once, here. Prefill
    /// chunking defaults to [`DEFAULT_PREFILL_CHUNK`]; see
    /// [`Self::with_prefill_chunk`].
    pub fn new(
        model: &'m TinyLm,
        page_positions: usize,
        pool_pages: usize,
        max_batch: usize,
    ) -> BatchScheduler<'m> {
        assert!(max_batch >= 1, "a batch must admit at least one request");
        let pack = model.serve_pack();
        let pool = KvPagePool::new(page_positions, pack.d_head(), pool_pages);
        BatchScheduler {
            model,
            pack,
            pool,
            queue: VecDeque::new(),
            active: Vec::new(),
            kvs: Vec::new(),
            free_slots: Vec::new(),
            max_batch,
            prefill_chunk: DEFAULT_PREFILL_CHUNK,
            committed_pages: 0,
            next_id: 0,
            stats: BatchStats::default(),
            tokens: Vec::new(),
            spans: Vec::new(),
            span_owner: Vec::new(),
            take: Vec::new(),
            scratch: BatchScratch::new(),
        }
    }

    /// Set the per-step prefill row budget: each step coalesces up to
    /// `chunk` prompt rows (round-robin across prefilling requests)
    /// with the live decode rows. `usize::MAX` restores one-shot
    /// prefill — the whole prompt lands in a single admission-step
    /// chunk, reproducing the old head-of-line schedule. Chunking
    /// never reaches the tokens (`rust/tests/decode.rs`).
    pub fn with_prefill_chunk(mut self, chunk: usize) -> Self {
        assert!(chunk >= 1, "the prefill chunk must admit at least one row per step");
        self.prefill_chunk = chunk;
        self
    }

    /// Enqueue a greedy-generation request (prompt plus `n_new` new
    /// tokens); returns its completion id. Panics if the request could
    /// *never* be admitted (worst-case pages exceed the whole pool) —
    /// queueing it would deadlock the FIFO.
    pub fn submit(&mut self, prompt: &[u16], n_new: usize) -> usize {
        assert!(!prompt.is_empty(), "empty prompt");
        assert!(n_new >= 1, "a request must generate at least one token");
        assert!(
            prompt.len() + n_new <= self.model.cfg.max_seq,
            "generation would exceed max_seq"
        );
        let worst = self.pack.pages_needed(prompt.len() + n_new, self.pool.page_positions());
        assert!(
            worst <= self.pool.total_pages(),
            "request needs {worst} pages at full length but the pool holds only {} — \
             it can never be admitted",
            self.pool.total_pages()
        );
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back(Pending { id, prompt: prompt.to_vec(), n_new });
        self.stats.submitted += 1;
        id
    }

    /// True when no work remains (empty queue, empty batch).
    pub fn is_idle(&self) -> bool {
        self.queue.is_empty() && self.active.is_empty()
    }

    /// Requests currently in the coalesced batch.
    pub fn active_requests(&self) -> usize {
        self.active.len()
    }

    /// Requests waiting for admission.
    pub fn queued_requests(&self) -> usize {
        self.queue.len()
    }

    /// Scheduler counters so far.
    pub fn stats(&self) -> BatchStats {
        self.stats
    }

    /// The shared page pool (for capacity accounting in tests and
    /// benches).
    pub fn pool(&self) -> &KvPagePool {
        &self.pool
    }

    /// One scheduler step: admit, build one mixed prefill+decode
    /// pass, consume its logits, evict. Returns the requests that
    /// completed during this step, in completion order.
    ///
    /// Admission is FIFO with head-of-line blocking, reserving each
    /// request's **worst-case** page count (`pages_needed(prompt +
    /// n_new)`) up front, so an admitted request can always grow to
    /// its full length — mid-decode pool exhaustion is structurally
    /// unreachable. Admitted prompts do *not* run a forward here:
    /// they enter [`Phase::Prefilling`] and feed the coalesced pass
    /// `prefill_chunk` rows at a time, so live decode rows keep
    /// flowing while a long prompt fills.
    pub fn step(&mut self) -> Vec<Completion> {
        let mut done = Vec::new();
        while self.active.len() < self.max_batch {
            let fits = self.queue.front().is_some_and(|p| {
                let worst =
                    self.pack.pages_needed(p.prompt.len() + p.n_new, self.pool.page_positions());
                self.committed_pages + worst <= self.pool.total_pages()
            });
            if !fits {
                break;
            }
            let p = self.queue.pop_front().unwrap();
            let worst =
                self.pack.pages_needed(p.prompt.len() + p.n_new, self.pool.page_positions());
            self.committed_pages += worst;
            let slot = self.free_slots.pop().unwrap_or_else(|| {
                self.kvs.push(PagedKv::new(&self.pack, self.model.cfg.max_seq));
                self.kvs.len() - 1
            });
            let prompt_len = p.prompt.len();
            self.active.push(Active {
                id: p.id,
                slot,
                out: p.prompt,
                prompt_len,
                n_new: p.n_new,
                emitted: 0,
                last: 0,
                phase: Phase::Prefilling { filled: 0 },
                worst_pages: worst,
            });
        }
        self.stats.peak_active = self.stats.peak_active.max(self.active.len());
        if self.active.is_empty() {
            return done;
        }
        // Round-robin the prefill row budget one token at a time over
        // prefilling requests in admission order: concurrent prompts
        // share every chunk instead of serializing behind each other,
        // and the grant pattern is deterministic.
        self.take.clear();
        self.take.resize(self.active.len(), 0);
        let mut budget = self.prefill_chunk;
        let mut granted = true;
        while budget > 0 && granted {
            granted = false;
            for (i, a) in self.active.iter().enumerate() {
                if budget == 0 {
                    break;
                }
                if let Phase::Prefilling { filled } = a.phase {
                    if filled + self.take[i] < a.prompt_len {
                        self.take[i] += 1;
                        budget -= 1;
                        granted = true;
                    }
                }
            }
        }
        // One mixed multi-row pass: every decoding request contributes
        // its 1-token row, every granted prefilling request its chunk.
        self.tokens.clear();
        self.spans.clear();
        self.span_owner.clear();
        let (mut decode_rows, mut prefill_rows, mut prefill_chunks) = (0usize, 0usize, 0usize);
        for (i, a) in self.active.iter().enumerate() {
            match a.phase {
                Phase::Decoding => {
                    self.tokens.push(a.last);
                    self.spans.push(RowSpan { slot: a.slot, rows: 1, want_logits: true });
                    self.span_owner.push(i);
                    decode_rows += 1;
                }
                Phase::Prefilling { filled } => {
                    let rows = self.take[i];
                    if rows == 0 {
                        continue; // chunk budget exhausted this step
                    }
                    self.tokens.extend_from_slice(&a.out[filled..filled + rows]);
                    self.spans.push(RowSpan {
                        slot: a.slot,
                        rows,
                        // Only the prompt's last row seeds generation;
                        // interior chunks skip the vocab projection.
                        want_logits: filled + rows == a.prompt_len,
                    });
                    self.span_owner.push(i);
                    prefill_rows += rows;
                    prefill_chunks += 1;
                }
            }
        }
        debug_assert!(!self.spans.is_empty(), "active batch built an empty pass");
        let logits = self.model.batch_step(
            &self.pack,
            &mut self.pool,
            &mut self.kvs,
            &self.spans,
            &self.tokens,
            &mut self.scratch,
        );
        let picks = argmax_rows(&logits);
        let mut li = 0usize;
        for (s, &ai) in self.span_owner.iter().enumerate() {
            let sp = self.spans[s];
            let a = &mut self.active[ai];
            match a.phase {
                Phase::Decoding => {
                    let next = picks[li] as u16;
                    li += 1;
                    a.out.push(next);
                    a.emitted += 1;
                    a.last = next;
                }
                Phase::Prefilling { filled } => {
                    if sp.want_logits {
                        // Final chunk: the prompt's last row yields
                        // the request's first generated token.
                        let next = picks[li] as u16;
                        li += 1;
                        a.out.push(next);
                        a.emitted = 1;
                        a.last = next;
                        a.phase = Phase::Decoding;
                        self.stats.lm_head_rows_saved += a.prompt_len - 1;
                    } else {
                        a.phase = Phase::Prefilling { filled: filled + sp.rows };
                    }
                }
            }
        }
        debug_assert_eq!(li, picks.len(), "every projected logits row consumed");
        self.stats.passes += 1;
        self.stats.pass_rows += self.tokens.len();
        if decode_rows > 0 {
            self.stats.decode_steps += 1;
            self.stats.coalesced_rows += decode_rows;
        }
        self.stats.prefill_chunks += prefill_chunks;
        self.stats.prefill_rows += prefill_rows;
        if decode_rows > 0 && prefill_rows > 0 {
            self.stats.mixed_steps += 1;
        }
        self.evict_completed(&mut done);
        done
    }

    /// Drive [`Self::step`] until idle; completions in completion
    /// order (ties within a step in admission order).
    pub fn run_to_completion(&mut self) -> Vec<Completion> {
        let mut done = Vec::new();
        while !self.is_idle() {
            done.extend(self.step());
        }
        done
    }

    fn evict_completed(&mut self, done: &mut Vec<Completion>) {
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].emitted >= self.active[i].n_new {
                let a = self.active.remove(i);
                self.kvs[a.slot].release(&mut self.pool);
                self.free_slots.push(a.slot);
                self.committed_pages -= a.worst_pages;
                self.stats.completed += 1;
                done.push(Completion { id: a.id, tokens: a.out });
            } else {
                i += 1;
            }
        }
    }

    /// (pointer, capacity) of every reusable step buffer — the
    /// steady-state zero-allocation test fingerprints these across
    /// warmed steps.
    #[cfg(test)]
    fn scratch_probe(&self) -> Vec<(usize, usize)> {
        let mut p = vec![
            (self.tokens.as_ptr() as usize, self.tokens.capacity()),
            (self.spans.as_ptr() as usize, self.spans.capacity()),
            (self.span_owner.as_ptr() as usize, self.span_owner.capacity()),
            (self.take.as_ptr() as usize, self.take.capacity()),
        ];
        p.extend(self.scratch.probe());
        p
    }

    /// Size of the recyclable `PagedKv` slab.
    #[cfg(test)]
    fn kv_slab_len(&self) -> usize {
        self.kvs.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::models::LmConfig;
    use crate::rng::Pcg64;

    #[test]
    fn pool_alloc_release_accounting() {
        let mut pool = KvPagePool::new(4, 8, 3);
        assert_eq!(pool.free_pages(), 3);
        assert_eq!(pool.page_elems(), 32);
        let a = pool.alloc();
        let b = pool.alloc();
        assert_eq!(pool.pages_in_use(), 2);
        assert_eq!(pool.peak_pages_in_use(), 2);
        pool.release(a);
        assert_eq!(pool.pages_in_use(), 1);
        // LIFO: the page released last comes back first.
        assert_eq!(pool.alloc(), a);
        pool.release(a);
        pool.release(b);
        assert_eq!(pool.free_pages(), 3);
        assert_eq!(pool.peak_pages_in_use(), 2, "peak survives release");
    }

    #[test]
    #[should_panic(expected = "KV page pool exhausted")]
    fn pool_exhaustion_panics() {
        let mut pool = KvPagePool::new(4, 8, 2);
        let _ = pool.alloc();
        let _ = pool.alloc();
        let _ = pool.alloc();
    }

    #[test]
    fn pages_needed_rounds_up_per_stream() {
        let mut rng = Pcg64::seed(3);
        let m = TinyLm::init(LmConfig::default(), &mut rng);
        let pack = m.serve_pack();
        // Default config: 4 blocks × 8 KV heads = 32 streams, K and V.
        assert_eq!(pack.total_kv_streams(), 32);
        assert_eq!(pack.pages_needed(1, 16), 64, "one position still takes a page per stream");
        assert_eq!(pack.pages_needed(16, 16), 64);
        assert_eq!(pack.pages_needed(17, 16), 128);
        // Slab comparison baseline: every stream owns max_seq rows.
        assert_eq!(pack.slab_elems(64), 2 * 32 * 64 * 8);
    }

    #[test]
    #[should_panic(expected = "can never be admitted")]
    fn oversized_request_rejected_at_submit() {
        let mut rng = Pcg64::seed(4);
        let m = TinyLm::init(LmConfig::default(), &mut rng);
        // 64 streams × 2 needed pages each at ps=16 for len 17 — give
        // the pool less than that.
        let mut sched = BatchScheduler::new(&m, 16, 64, 8);
        sched.submit(&[1; 9], 8); // len 17 → 128 pages > 64
    }

    #[test]
    fn scheduler_matches_solo_generate_and_frees_pages() {
        let mut rng = Pcg64::seed(5);
        let m = TinyLm::init(LmConfig::default(), &mut rng);
        let prompts: Vec<Vec<u16>> = (0..3)
            .map(|i| (0..4 + i).map(|j| ((i * 7 + j * 3) % 60) as u16).collect())
            .collect();
        let n_new = [5usize, 1, 3];
        let mut sched = BatchScheduler::new(&m, 8, 512, 8);
        let ids: Vec<usize> =
            prompts.iter().zip(n_new).map(|(p, n)| sched.submit(p, n)).collect();
        let done = sched.run_to_completion();
        assert_eq!(done.len(), 3);
        for (i, id) in ids.iter().enumerate() {
            let c = done.iter().find(|c| c.id == *id).unwrap();
            assert_eq!(c.tokens, m.generate(&prompts[i], n_new[i]), "request {i}");
        }
        // Everything evicted: all pages back in the pool.
        assert!(sched.is_idle());
        assert_eq!(sched.pool().pages_in_use(), 0, "completed requests leak no pages");
        let st = sched.stats();
        assert_eq!(st.submitted, 3);
        assert_eq!(st.completed, 3);
        assert!(st.peak_active >= 2, "requests actually coalesced: {st:?}");
        assert!(st.coalesced_rows >= st.decode_steps);
        assert_eq!(
            st.lm_head_rows_saved,
            prompts.iter().map(|p| p.len() - 1).sum::<usize>(),
            "lazy prefill lm_head skipped every interior prompt row"
        );
    }

    #[test]
    fn steady_state_steps_reuse_all_scratch() {
        let mut rng = Pcg64::seed(6);
        let m = TinyLm::init(LmConfig::default(), &mut rng);
        let mut sched = BatchScheduler::new(&m, 8, 512, 4).with_prefill_chunk(4);
        let prompt: Vec<u16> = (0..8).map(|j| (j * 5 % 60) as u16).collect();
        for _ in 0..4 {
            sched.submit(&prompt, 24);
        }
        // Warm until every request is decoding and at least one decode
        // logits row has been produced (scratch.last grows on the first
        // want_logits span; tokens/spans hit max occupancy once all 4
        // requests contribute rows).
        for _ in 0..12 {
            sched.step();
        }
        let slab = sched.kv_slab_len();
        let probe0 = sched.scratch_probe();
        for _ in 0..8 {
            sched.step();
        }
        assert_eq!(
            sched.scratch_probe(),
            probe0,
            "warmed steps must not reallocate any step buffer"
        );
        assert_eq!(sched.kv_slab_len(), slab, "warmed steps must not grow the kv slab");
        // Drain; recycled slots keep the slab flat too.
        sched.run_to_completion();
        for _ in 0..2 {
            sched.submit(&prompt, 4);
        }
        sched.run_to_completion();
        assert_eq!(sched.kv_slab_len(), slab, "evicted slots are recycled, not leaked");
        assert_eq!(sched.pool().pages_in_use(), 0);
    }

    #[test]
    fn chunked_prefill_overlaps_decode_and_matches_streams() {
        let mut rng = Pcg64::seed(7);
        let m = TinyLm::init(LmConfig::default(), &mut rng);
        let short: Vec<u16> = (0..5).map(|j| (j * 11 % 60) as u16).collect();
        let long: Vec<u16> = (0..24).map(|j| (j * 7 % 60) as u16).collect();
        let mut sched = BatchScheduler::new(&m, 8, 512, 4).with_prefill_chunk(3);
        let a = sched.submit(&short, 12);
        let done1 = sched.step(); // short starts prefilling
        assert!(done1.is_empty());
        let b = sched.submit(&long, 4); // long prompt arrives mid-decode
        let done = sched.run_to_completion();
        let sa = done.iter().find(|c| c.id == a).unwrap();
        let sb = done.iter().find(|c| c.id == b).unwrap();
        assert_eq!(sa.tokens, m.generate(&short, 12), "short stream unaffected by chunking");
        assert_eq!(sb.tokens, m.generate(&long, 4), "chunked long prompt decodes identically");
        let st = sched.stats();
        assert!(st.mixed_steps > 0, "long prefill overlapped live decode: {st:?}");
        assert!(
            st.prefill_chunks > (short.len() + long.len()).div_ceil(3) - 2,
            "prompts actually split into chunks: {st:?}"
        );
        assert_eq!(st.prefill_rows, short.len() + long.len());
        assert!(st.occupancy() > 1.0, "mixed passes carried more than one row");
    }
}
