//! Ambient cache-aware statistics provider.
//!
//! The entire open-loop statistics surface funnels through one choke
//! point — `per_shard_site_stats` in `grail::pipeline` — which is
//! generic over [`Compressible`](crate::compress::Compressible) and
//! called from `plan`, `run`, `tune`, and `batch`. Threading an
//! `Option<&StatsContext>` through every one of those generic
//! signatures would churn the whole public API for what is a pure
//! execution-environment concern, so the provider is *ambient*
//! instead: [`install`] binds a [`StatsContext`] to the current thread
//! (RAII scope, previous context restored on drop), and the choke
//! point consults [`active`] on its calling thread. Inner `run_grid`
//! worker threads never see the context — by the time shards fan out,
//! the hit/miss decision has already been made on the caller.
//!
//! Correctness contract: a statistics pass is served from the cache
//! only when **every** site of the pass hits, and the cached bytes are
//! the verbatim un-finalized per-shard accumulators the cold path
//! produced — so warm results are bit-identical to cold ones by
//! construction, not by numerical accident (`rust/tests/serve.rs`).
//!
//! Accounting: the context keeps a **thread-local monotonic tally** of
//! entry hits/misses in addition to the shared [`StatsCache`]
//! counters. Consumers that need per-job numbers under concurrency
//! (the daemon and `grail batch` run jobs on scheduler worker threads
//! sharing one cache) snapshot [`tally`] before and after the job on
//! their own thread and report the delta; the shared cache counters
//! keep the global totals.

use super::cache::StatsCache;
use super::digest::{Digest, Hasher128};
use crate::grail::ActStats;
use std::cell::{Cell, RefCell};
use std::sync::Arc;

/// Key-derivation version: participates in every site key, so changing
/// how keys are built (not just how entries are encoded) also retires
/// the old entries.
const KEY_VERSION: &str = "grail-stats-v1";

/// Identity of one `(model, corpus)` calibration pairing plus the
/// cache the statistics live in.
#[derive(Clone)]
pub struct StatsContext {
    pub cache: Arc<StatsCache>,
    /// Digest of the model weights (e.g. the checkpoint file bytes).
    pub model: Digest,
    /// Digest of the calibration corpus identity (e.g. the corpus file
    /// bytes plus any slicing geometry).
    pub corpus: Digest,
}

impl std::fmt::Debug for StatsContext {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("StatsContext")
            .field("model", &self.model)
            .field("corpus", &self.corpus)
            .field("cache", &self.cache.root())
            .finish()
    }
}

impl StatsContext {
    pub fn new(cache: Arc<StatsCache>, model: Digest, corpus: Digest) -> StatsContext {
        StatsContext { cache, model, corpus }
    }

    /// Cache key of one site's statistics. The *actual* shard count
    /// (after the model clamps the requested split to the available
    /// samples) is part of the key: different splits accumulate in
    /// different float orders and must never alias.
    pub fn site_key(&self, site_id: &str, site_idx: usize, n_shards: usize) -> Digest {
        let mut h = Hasher128::new();
        h.update(KEY_VERSION.as_bytes());
        h.update(&super::cache::FORMAT_VERSION.to_le_bytes());
        h.update(&self.model.0);
        h.update(&self.corpus.0);
        h.update(&(site_id.len() as u64).to_le_bytes());
        h.update(site_id.as_bytes());
        h.update(&(site_idx as u64).to_le_bytes());
        h.update(&(n_shards as u64).to_le_bytes());
        h.finish()
    }

    /// Try to serve a whole statistics pass from the cache. Returns the
    /// shard-major `[shard][site]` layout `per_shard_site_stats`
    /// produces, or `None` unless **every** site hits (partial hits
    /// recompute everything: the pass is one streamed forward anyway,
    /// so per-site salvage would complicate the bitwise contract for
    /// zero saved work). `widths[si]` is the model's expected feature
    /// width — a cached entry disagreeing with it is a fail-loud key
    /// collision, not a miss.
    pub fn load_pass(
        &self,
        site_ids: &[&str],
        widths: &[usize],
        n_shards: usize,
    ) -> Option<Vec<Vec<ActStats>>> {
        assert_eq!(site_ids.len(), widths.len());
        let n_sites = site_ids.len();
        let mut per_site: Vec<Vec<ActStats>> = Vec::with_capacity(n_sites);
        for (si, id) in site_ids.iter().enumerate() {
            let key = self.site_key(id, si, n_shards);
            let Some(shards) = self.cache.load(&key) else {
                // Cold pass: every site recomputes (and later
                // re-stores), so the whole pass counts as misses.
                self.cache.count_misses(n_sites as u64);
                note(0, n_sites as u64);
                return None;
            };
            assert_eq!(
                shards.len(),
                n_shards,
                "stats cache entry {key} for site `{id}` holds {} shards, expected {n_shards} — \
                 key collision (the shard split participates in the key)",
                shards.len()
            );
            for s in &shards {
                assert_eq!(
                    s.gram.dim(0),
                    widths[si],
                    "stats cache entry {key} for site `{id}` has width {}, model expects {} — \
                     key collision",
                    s.gram.dim(0),
                    widths[si]
                );
            }
            per_site.push(shards);
        }
        self.cache.count_hits(n_sites as u64);
        note(n_sites as u64, 0);
        // Transpose site-major storage into the shard-major layout the
        // pipeline consumes.
        let mut out: Vec<Vec<ActStats>> = (0..n_shards).map(|_| Vec::with_capacity(n_sites)).collect();
        for site_shards in per_site {
            for (shard_idx, s) in site_shards.into_iter().enumerate() {
                out[shard_idx].push(s);
            }
        }
        Some(out)
    }

    /// Persist a freshly computed pass (shard-major input, one
    /// site-major entry per site). Write failures are warned about and
    /// swallowed: the computed statistics in hand are still valid, and
    /// a read-only or full cache directory must not fail the job.
    pub fn store_pass(&self, site_ids: &[&str], per_shard: &[Vec<ActStats>]) {
        let n_shards = per_shard.len();
        for (si, id) in site_ids.iter().enumerate() {
            let key = self.site_key(id, si, n_shards);
            let site_shards: Vec<ActStats> =
                per_shard.iter().map(|shard| shard[si].clone()).collect();
            if let Err(e) = self.cache.store(&key, &site_shards) {
                eprintln!("[serve] WARN: failed to store stats cache entry {key}: {e:#}");
            }
        }
    }
}

thread_local! {
    static ACTIVE: RefCell<Option<Arc<StatsContext>>> = const { RefCell::new(None) };
    /// Monotonic per-thread (entry hits, entry misses); consumers read
    /// deltas, so it never resets.
    static TALLY: Cell<(u64, u64)> = const { Cell::new((0, 0)) };
}

/// The context installed on the current thread, if any.
pub fn active() -> Option<Arc<StatsContext>> {
    ACTIVE.with(|a| a.borrow().clone())
}

/// Monotonic (hits, misses) of statistics-cache entries accounted on
/// this thread. Snapshot before and after a job and subtract.
pub fn tally() -> (u64, u64) {
    TALLY.with(|t| t.get())
}

fn note(hits: u64, misses: u64) {
    TALLY.with(|t| {
        let (h, m) = t.get();
        t.set((h + hits, m + misses));
    });
}

/// Install `ctx` as the current thread's statistics provider for the
/// lifetime of the returned scope. Nests: dropping the scope restores
/// whatever was installed before.
#[must_use = "the context is uninstalled when the scope drops"]
pub fn install(ctx: StatsContext) -> CacheScope {
    let prev = ACTIVE.with(|a| a.borrow_mut().replace(Arc::new(ctx)));
    CacheScope { prev }
}

/// RAII guard for an installed [`StatsContext`].
pub struct CacheScope {
    prev: Option<Arc<StatsContext>>,
}

impl Drop for CacheScope {
    fn drop(&mut self) {
        let prev = self.prev.take();
        ACTIVE.with(|a| *a.borrow_mut() = prev);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::digest::digest_bytes;

    fn ctx(root: &std::path::Path) -> StatsContext {
        StatsContext::new(
            Arc::new(StatsCache::open(root).unwrap()),
            digest_bytes(b"model"),
            digest_bytes(b"corpus"),
        )
    }

    #[test]
    fn install_is_scoped_and_nests() {
        let root = std::env::temp_dir().join(format!("grail_provider_unit_{}", std::process::id()));
        assert!(active().is_none());
        {
            let _outer = install(ctx(&root));
            let outer_model = active().unwrap().model;
            {
                let mut inner = ctx(&root);
                inner.model = digest_bytes(b"other-model");
                let _inner = install(inner);
                assert_ne!(active().unwrap().model, outer_model);
            }
            assert_eq!(active().unwrap().model, outer_model);
        }
        assert!(active().is_none());
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn site_keys_separate_every_identity_axis() {
        let root = std::env::temp_dir().join(format!("grail_provider_keys_{}", std::process::id()));
        let c = ctx(&root);
        let base = c.site_key("fc1", 0, 16);
        assert_eq!(base, c.site_key("fc1", 0, 16), "keys are deterministic");
        assert_ne!(base, c.site_key("fc2", 0, 16), "site id");
        assert_ne!(base, c.site_key("fc1", 1, 16), "site index");
        assert_ne!(base, c.site_key("fc1", 0, 8), "shard split");
        let mut other = ctx(&root);
        other.model = digest_bytes(b"model2");
        assert_ne!(base, other.site_key("fc1", 0, 16), "model identity");
        let mut other = ctx(&root);
        other.corpus = digest_bytes(b"corpus2");
        assert_ne!(base, other.site_key("fc1", 0, 16), "corpus identity");
        std::fs::remove_dir_all(&root).ok();
    }
}
