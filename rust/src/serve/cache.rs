//! Content-addressed on-disk store for streamed calibration
//! statistics.
//!
//! One cache entry holds the per-shard [`ActStats`] accumulators of a
//! single site for one `(model, corpus, shard-split)` combination —
//! the exact `Vec<ActStats>` a streamed open-loop pass produces for
//! that site, byte for byte. Keys are deterministic 128-bit digests
//! ([`super::digest`]) over (model weights, calibration-corpus
//! identity, site id, shard count, format version); entries are
//! immutable once written, so there is no invalidation — a new model
//! or corpus simply addresses different files.
//!
//! On-disk format (all little-endian):
//!
//! ```text
//! magic    u32   0x4753_5443 ("GSTC")
//! version  u32   FORMAT_VERSION
//! key      16 B  the entry's own digest (collision tripwire)
//! n_shards u32
//! shards   n_shards × ActStats::encode_into payloads
//! checksum 16 B  digest of every preceding byte
//! ```
//!
//! Robustness contract: a missing file, bad magic/version, truncation,
//! or checksum mismatch is **corruption → a miss** (the entry is
//! evicted, counted, and warned about; the caller recomputes and
//! rewrites it). A file whose checksum is intact but whose embedded
//! key differs from the requested key is a **digest collision or
//! cross-wired cache root → fail loud** (panic): serving those
//! statistics would silently corrupt downstream plans. Writes are
//! atomic (unique temp file + rename), so a crashed writer can leave a
//! stale temp file but never a half-written entry under a real key.

use super::digest::{digest_bytes, wire_u32, Digest};
use crate::grail::ActStats;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

/// Bump when the entry layout, [`ActStats`] encoding, or the digest
/// function changes — the version participates in every key, so old
/// entries become unreachable instead of misparsed.
pub const FORMAT_VERSION: u32 = 1;

const MAGIC: u32 = 0x4753_5443; // "GSTC"

/// Hit/miss/evict counters of a cache (monotonic totals).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheCounters {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

/// A content-addressed statistics cache rooted at one directory.
pub struct StatsCache {
    root: PathBuf,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    write_nonce: AtomicU64,
}

impl std::fmt::Debug for StatsCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("StatsCache")
            .field("root", &self.root)
            .field("counters", &self.counters())
            .finish()
    }
}

impl StatsCache {
    /// Open (creating if needed) a cache rooted at `root`.
    pub fn open(root: impl AsRef<Path>) -> Result<StatsCache> {
        let root = root.as_ref().to_path_buf();
        std::fs::create_dir_all(&root)
            .with_context(|| format!("creating stats cache dir {root:?}"))?;
        Ok(StatsCache {
            root,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            write_nonce: AtomicU64::new(0),
        })
    }

    /// Cache root directory.
    pub fn root(&self) -> &Path {
        &self.root
    }

    /// File path of an entry.
    pub fn entry_path(&self, key: &Digest) -> PathBuf {
        self.root.join(format!("{}.stats", key.hex()))
    }

    /// Load one entry. `None` means miss — absent, or corrupt (in
    /// which case the bad file is evicted and counted). Hit/miss
    /// counters are **not** touched here;
    /// [`count_hits`](StatsCache::count_hits) /
    /// [`count_misses`](StatsCache::count_misses) belong to the
    /// provider, which accounts whole statistics passes.
    pub fn load(&self, key: &Digest) -> Option<Vec<ActStats>> {
        let path = self.entry_path(key);
        let bytes = match std::fs::read(&path) {
            Ok(b) => b,
            Err(_) => return None,
        };
        match decode_entry(key, &bytes) {
            DecodeOutcome::Ok(shards) => Some(shards),
            DecodeOutcome::Corrupt(why) => {
                eprintln!(
                    "[serve] WARN: evicting corrupt stats cache entry {path:?} ({why}); \
                     treating as a miss"
                );
                self.evictions.fetch_add(1, Ordering::Relaxed);
                std::fs::remove_file(&path).ok();
                None
            }
            DecodeOutcome::KeyMismatch(found) => panic!(
                "stats cache entry {path:?} passes its checksum but embeds key {found} — \
                 digest collision or a cache root shared across incompatible digest \
                 versions; refusing to serve it (delete the file to recover)"
            ),
        }
    }

    /// Atomically write one entry (temp file + rename; concurrent
    /// writers of the same key race benignly — identical content).
    pub fn store(&self, key: &Digest, shards: &[ActStats]) -> Result<()> {
        let bytes = encode_entry(key, shards);
        let nonce = self.write_nonce.fetch_add(1, Ordering::Relaxed);
        let tmp = self
            .root
            .join(format!(".{}.tmp.{}.{nonce}", key.hex(), std::process::id()));
        std::fs::write(&tmp, &bytes).with_context(|| format!("writing {tmp:?}"))?;
        let path = self.entry_path(key);
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("publishing {path:?}"))
            .inspect_err(|_| {
                std::fs::remove_file(&tmp).ok();
            })?;
        Ok(())
    }

    /// Record `n` entry hits (a fully cache-served statistics pass).
    pub fn count_hits(&self, n: u64) {
        self.hits.fetch_add(n, Ordering::Relaxed);
    }

    /// Record `n` entry misses (a recomputed statistics pass).
    pub fn count_misses(&self, n: u64) {
        self.misses.fetch_add(n, Ordering::Relaxed);
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// Snapshot of all counters.
    pub fn counters(&self) -> CacheCounters {
        CacheCounters {
            hits: self.hits(),
            misses: self.misses(),
            evictions: self.evictions(),
        }
    }
}

/// Serialize an entry (header + per-shard payloads + checksum).
fn encode_entry(key: &Digest, shards: &[ActStats]) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
    out.extend_from_slice(&key.0);
    out.extend_from_slice(&wire_u32(shards.len(), "stats shard count"));
    for s in shards {
        s.encode_into(&mut out);
    }
    let sum = digest_bytes(&out);
    out.extend_from_slice(&sum.0);
    out
}

enum DecodeOutcome {
    Ok(Vec<ActStats>),
    Corrupt(&'static str),
    KeyMismatch(Digest),
}

fn decode_entry(expect_key: &Digest, bytes: &[u8]) -> DecodeOutcome {
    use DecodeOutcome::Corrupt;
    // Header (4 + 4 + 16 + 4) + trailing checksum (16).
    if bytes.len() < 44 {
        return Corrupt("truncated header");
    }
    let (body, sum) = bytes.split_at(bytes.len() - 16);
    if digest_bytes(body).0 != sum {
        return Corrupt("checksum mismatch");
    }
    let mut pos = 0usize;
    let magic = u32::from_le_bytes(body[0..4].try_into().unwrap());
    let version = u32::from_le_bytes(body[4..8].try_into().unwrap());
    if magic != MAGIC {
        return Corrupt("bad magic");
    }
    if version != FORMAT_VERSION {
        return Corrupt("unsupported format version");
    }
    let mut key = [0u8; 16];
    key.copy_from_slice(&body[8..24]);
    if key != expect_key.0 {
        // The checksum proved the file self-consistent, so this is not
        // bit rot: the wrong content lives under this name.
        return DecodeOutcome::KeyMismatch(Digest(key));
    }
    let n_shards = match usize::try_from(u32::from_le_bytes(body[24..28].try_into().unwrap())) {
        Ok(n) => n,
        // u32 → usize can only fail on <32-bit targets; a count this
        // machine cannot even index is corruption, not a panic.
        Err(_) => return Corrupt("shard count exceeds usize"),
    };
    pos += 28;
    // A shard payload is at least 12 bytes (width u32 + rows u64); a
    // count larger than the remaining payload could ever hold is
    // corrupt geometry — reject it *before* reserving memory for it.
    if n_shards > (body.len() - pos) / 12 {
        return Corrupt("shard count exceeds payload");
    }
    let mut shards = Vec::with_capacity(n_shards);
    for _ in 0..n_shards {
        match ActStats::decode_from(body, &mut pos) {
            Some(s) => shards.push(s),
            None => return Corrupt("truncated shard payload"),
        }
    }
    if pos != body.len() {
        return Corrupt("trailing bytes");
    }
    DecodeOutcome::Ok(shards)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::tensor::Tensor;

    fn stats(h: usize, rows: usize, seed: u64) -> ActStats {
        let mut rng = Pcg64::seed(seed);
        let mut x = Tensor::zeros(&[rows, h]);
        rng.fill_normal(x.data_mut(), 1.0);
        let mut s = ActStats::new(h);
        s.update(&x);
        s
    }

    fn tmp_root(name: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!("grail_cache_unit_{name}_{}", std::process::id()));
        std::fs::remove_dir_all(&p).ok();
        p
    }

    #[test]
    fn store_load_roundtrip_is_byte_exact() {
        let root = tmp_root("roundtrip");
        let cache = StatsCache::open(&root).unwrap();
        let key = digest_bytes(b"site-0");
        let shards: Vec<ActStats> = (0..3).map(|i| stats(5, 8 + i, i as u64)).collect();
        cache.store(&key, &shards).unwrap();
        let back = cache.load(&key).expect("entry present");
        assert_eq!(back.len(), 3);
        for (a, b) in shards.iter().zip(&back) {
            assert_eq!(a.rows, b.rows);
            for (x, y) in a.gram.data().iter().zip(b.gram.data()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
            for (x, y) in a.mean.iter().zip(&b.mean) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn absent_entry_is_a_miss() {
        let root = tmp_root("absent");
        let cache = StatsCache::open(&root).unwrap();
        assert!(cache.load(&digest_bytes(b"nope")).is_none());
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn corrupt_entry_is_evicted_and_missed() {
        let root = tmp_root("corrupt");
        let cache = StatsCache::open(&root).unwrap();
        let key = digest_bytes(b"site-1");
        cache.store(&key, &[stats(4, 6, 1)]).unwrap();
        let path = cache.entry_path(&key);
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        assert!(cache.load(&key).is_none(), "flipped byte must fail the checksum");
        assert_eq!(cache.evictions(), 1);
        assert!(!path.exists(), "corrupt entry must be evicted from disk");
        // And the next store/load cycle recovers.
        cache.store(&key, &[stats(4, 6, 1)]).unwrap();
        assert!(cache.load(&key).is_some());
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn truncated_entry_is_rejected() {
        let root = tmp_root("truncated");
        let cache = StatsCache::open(&root).unwrap();
        let key = digest_bytes(b"site-2");
        cache.store(&key, &[stats(4, 6, 2), stats(4, 3, 3)]).unwrap();
        let path = cache.entry_path(&key);
        let bytes = std::fs::read(&path).unwrap();
        for cut in [0, 10, 43, bytes.len() / 2, bytes.len() - 1] {
            std::fs::write(&path, &bytes[..cut]).unwrap();
            assert!(cache.load(&key).is_none(), "cut at {cut} must miss");
        }
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn oversize_shard_count_is_rejected_not_wrapped() {
        // Rewrite a valid entry's shard-count field to u32::MAX and
        // re-sign the checksum: geometry the payload cannot hold must
        // decode as corruption (→ evicted miss), never allocate for
        // 4 billion shards or wrap into a wrong small count.
        let root = tmp_root("oversize");
        let cache = StatsCache::open(&root).unwrap();
        let key = digest_bytes(b"site-3");
        cache.store(&key, &[stats(4, 6, 5)]).unwrap();
        let path = cache.entry_path(&key);
        let bytes = std::fs::read(&path).unwrap();
        let mut body = bytes[..bytes.len() - 16].to_vec();
        body[24..28].copy_from_slice(&u32::MAX.to_le_bytes());
        let sum = digest_bytes(&body);
        body.extend_from_slice(&sum.0);
        std::fs::write(&path, &body).unwrap();
        assert!(cache.load(&key).is_none(), "oversize geometry must miss");
        assert_eq!(cache.evictions(), 1);
        assert!(!path.exists(), "the corrupt entry must be evicted");
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    #[should_panic(expected = "digest collision")]
    fn key_mismatch_fails_loud() {
        let root = tmp_root("mismatch");
        let cache = StatsCache::open(&root).unwrap();
        let key_a = digest_bytes(b"site-a");
        let key_b = digest_bytes(b"site-b");
        cache.store(&key_a, &[stats(4, 6, 4)]).unwrap();
        // A self-consistent entry filed under the wrong name.
        std::fs::rename(cache.entry_path(&key_a), cache.entry_path(&key_b)).unwrap();
        let _ = cache.load(&key_b);
    }
}
