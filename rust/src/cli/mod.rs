//! Hand-rolled CLI argument parsing (no `clap` offline).
//!
//! Grammar: `grail <command> [subcommand] [--flag value] [--switch]
//! [positional...]`. Flags may appear anywhere after the command.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// Positional arguments in order (command word(s) first).
    pub positional: Vec<String>,
    /// `--key value` options.
    pub options: BTreeMap<String, String>,
    /// Bare `--switch` flags.
    pub switches: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args> {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    bail!("bare `--` is not supported");
                }
                if let Some((k, v)) = name.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    args.options.insert(name.to_string(), v);
                } else {
                    args.switches.push(name.to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        Ok(args)
    }

    /// Parse from the process environment.
    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    /// Positional argument `i` or error.
    pub fn pos(&self, i: usize, what: &str) -> Result<&str> {
        self.positional
            .get(i)
            .map(|s| s.as_str())
            .ok_or_else(|| anyhow::anyhow!("missing {what} (positional {i})"))
    }

    /// Option value (string).
    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// Option with default.
    pub fn opt_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.opt(key).unwrap_or(default)
    }

    /// usize option with default; errors on malformed values.
    pub fn opt_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{key}: not an integer: {v}")),
        }
    }

    /// f64 option with default; errors on malformed values.
    pub fn opt_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{key}: not a number: {v}")),
        }
    }

    /// u64 option with default.
    pub fn opt_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{key}: not an integer: {v}")),
        }
    }

    /// Whether a bare switch was given.
    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    /// Comma-separated f64 list option.
    pub fn opt_f64_list(&self, key: &str, default: &[f64]) -> Result<Vec<f64>> {
        match self.opt(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim()
                        .parse()
                        .map_err(|_| anyhow::anyhow!("--{key}: bad list element `{p}`"))
                })
                .collect(),
        }
    }

    /// Comma-separated string list option.
    pub fn opt_str_list(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.opt(key) {
            None => default.iter().map(|s| s.to_string()).collect(),
            Some(v) => v.split(',').map(|p| p.trim().to_string()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn commands_and_flags() {
        // NB: a value-less switch must come last or be followed by
        // another flag — `--verbose out.csv` would bind greedily.
        let a = parse("exp table1 out.csv --ratios 0.1,0.5 --seed 7 --verbose");
        assert_eq!(a.pos(0, "cmd").unwrap(), "exp");
        assert_eq!(a.pos(1, "sub").unwrap(), "table1");
        assert_eq!(a.positional[2], "out.csv");
        assert_eq!(a.opt("seed"), Some("7"));
        assert!(a.has("verbose"));
        assert_eq!(a.opt_f64_list("ratios", &[]).unwrap(), vec![0.1, 0.5]);
    }

    #[test]
    fn equals_form() {
        let a = parse("run --alpha=0.001 --name=x");
        assert_eq!(a.opt_f64("alpha", 0.0).unwrap(), 0.001);
        assert_eq!(a.opt("name"), Some("x"));
    }

    #[test]
    fn trailing_switch() {
        let a = parse("run --fast");
        assert!(a.has("fast"));
        assert_eq!(a.opt("fast"), None);
    }

    #[test]
    fn typed_errors() {
        let a = parse("run --n abc");
        assert!(a.opt_usize("n", 1).is_err());
        assert_eq!(a.opt_usize("m", 5).unwrap(), 5);
    }

    #[test]
    fn missing_positional_errors() {
        let a = parse("run");
        assert!(a.pos(1, "sub").is_err());
    }
}
