//! # GRAIL — post-hoc compensation by linear reconstruction
//!
//! A from-scratch reproduction of *GRAIL: Post-hoc Compensation by
//! Linear Reconstruction for Compressed Networks* as a three-layer
//! Rust + JAX + Pallas system:
//!
//! - **L3 (this crate)** — the compression coordinator: structured
//!   pruning/folding selectors, the GRAIL Gram-ridge compensation
//!   engine, closed-loop per-layer pipeline, evaluation, experiments.
//! - **L2 (`python/compile/model.py`)** — JAX forward graphs, AOT-
//!   lowered once to HLO text artifacts executed via PJRT.
//! - **L1 (`python/compile/kernels/`)** — Pallas kernels (tiled Gram
//!   accumulation, blocked matmul) inside those graphs.
//!
//! Python never runs at request time: `make artifacts` produces
//! `artifacts/*.hlo.txt` + trained checkpoint weights, and the Rust
//! binary is self-contained afterwards.

// Numeric-kernel idioms (index-heavy loops, GEMM-style signatures)
// read better than iterator chains here; silence the corresponding
// style lints crate-wide so `clippy -D warnings` stays useful.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]
#![allow(clippy::manual_memcpy)]
#![allow(clippy::uninlined_format_args)]
// Every `unsafe` operation inside an `unsafe fn` must carry its own
// `unsafe {}` block (and, by `grail check`, its own SAFETY comment).
#![deny(unsafe_op_in_unsafe_fn)]

pub mod analysis;
pub mod bench_util;
pub mod cli;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod exp;
pub mod grail;
pub mod linalg;
pub mod nn;
pub mod rng;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod testing;
