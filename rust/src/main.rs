//! `grail` — the L3 coordinator CLI.
//!
//! ```text
//! grail datagen [--out artifacts]          write the canonical datasets
//! grail exp <id|all> [--out results]       regenerate a paper table/figure
//! grail compress --family <f> ...          one-off uniform compression + eval
//! grail plan --spec spec.toml              resolve + print a compression plan
//! grail run --spec spec.toml               execute a declarative spec
//! grail batch <spec.toml>...               fan specs over the model zoo
//! grail tune --spec spec.toml              calibration-driven plan search
//! grail serve [--root dir] [--once]        job-queue daemon with stats cache
//! grail submit <spec.toml> [--verb v]      enqueue a job for the daemon
//! grail status <job-id>                    one job's state
//! grail jobs                               all jobs in the queue
//! grail check [--deny] [--json file]       repo-native static analysis
//! grail info                               artifact / runtime inventory
//! ```

use anyhow::{bail, Result};
use grail::cli::Args;
use grail::coordinator::{generate_all, Artifacts};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "datagen" => {
            let art = Artifacts::at(args.opt_or("out", "artifacts"));
            generate_all(&art, &mut |m| println!("{m}"))?;
            if args.has("dev-ckpts") {
                grail::coordinator::write_dev_checkpoints(&art, &mut |m| println!("{m}"))?;
            }
            Ok(())
        }
        "exp" => grail::exp::run_cli(&args),
        "compress" => grail::exp::compress_cli(&args),
        "plan" => grail::exp::runner::plan_cli(&args),
        "run" => grail::exp::runner::run_cli(&args),
        "batch" => grail::exp::runner::batch_cli(&args),
        "tune" => grail::exp::runner::tune_cli(&args),
        "serve" => grail::serve::daemon::serve_cli(&args),
        "submit" => grail::serve::daemon::submit_cli(&args),
        "status" => grail::serve::daemon::status_cli(&args),
        "jobs" => grail::serve::daemon::jobs_cli(&args),
        "check" => grail::analysis::check_cli(&args),
        "info" => {
            let art = Artifacts::at(args.opt_or("out", "artifacts"));
            println!("artifacts root: {:?}", art.root);
            println!("data present:   {}", art.has_data());
            #[cfg(feature = "pjrt")]
            match grail::runtime::Runtime::cpu(art) {
                Ok(rt) => println!("pjrt platform:  {}", rt.platform()),
                Err(e) => println!("pjrt:           unavailable ({e})"),
            }
            #[cfg(not(feature = "pjrt"))]
            {
                let _ = art;
                println!("pjrt:           disabled (build with --features pjrt)");
            }
            Ok(())
        }
        "help" | "--help" => {
            println!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown command `{other}` (try `grail help`)"),
    }
}

const HELP: &str = "\
grail — GRAIL post-hoc compensation coordinator

USAGE:
  grail datagen [--out artifacts] [--dev-ckpts]
  grail exp <fig2|fig3|fig5|fig6|fig7|table1|table2|table3|fig4|all>
            [--out results] [--artifacts artifacts] [--quick]
  grail compress --family <mlp|resnet|vit|lm> --ckpt <name>
            --method <mag-l1|mag-l2|wanda|gram|random|fold|random-fold|wanda++|slimgpt|ziplm|flap>
            --ratio <0..1> [--grail] [--alpha 1e-3]
  grail plan  --spec <spec.toml> [--family f] [--ckpt c] [--toml]
  grail run   --spec <spec.toml> [--family f] [--ckpt c]
  grail run   --plan <plan.toml> --family <f> [--ckpt c]
  grail batch <spec.toml>... [--jobs N] [--out results]
  grail tune  --spec <spec.toml> [--family f] [--ckpt c] [--jobs N]
              [--out results] [--eval]
  grail serve  [--root results/serve] [--jobs N] [--once] [--poll-ms 500]
  grail submit <spec.toml> [--verb plan|run|tune] [--retries N]
               [--family f] [--ckpt c] [--root results/serve]
  grail status <job-id> [--root results/serve]
  grail jobs   [--root results/serve]
  grail check  [--root .] [--deny] [--json file] [--allowlist file]
  grail info

SPEC FILES (TOML subset; full reference in EXPERIMENTS.md, commented
example in examples/lm_depth_ramp.spec.toml):
  [model]     family = \"lm\"           mlp|resnet|vit|lm
              ckpt = \"tinylm_mha\"     omit to fan over the zoo in `batch`
  [pipeline]  default policy: method, ratio, grail, alpha,
              seed, closed_loop, shards, workers
  [rule.N]    ordered per-site overrides; matchers (ANDed):
                match_id    = \"block*.attn\"   id glob (* and ?)
                match_kind  = \"attn-heads\"    dense|conv|mlp-pair|attn-heads
                match_depth = [lo, hi]        inclusive site-index range
              overrides: method / ratio / grail / alpha.
              Later rules win; defaults fill the rest.
  [budget]    mode = \"per-site\" (default) — every site its own ratio
              mode = \"depth-ramp\"       target_ratio, gamma: ratios ramp
                linearly with depth around target_ratio
              mode = \"gram-sensitivity\" target_ratio: keep counts
                allocated from the global unit budget by each site's mean
                Gram-diagonal activation energy (dense model)
              mode = \"search\"           target_ratio, alpha_grid, rounds:
                calibration-driven coordinate search — per-site ridge α
                tuned over the grid and keep counts reallocated across
                sites at a fixed weighted-unit budget, scored by held-out
                Gram reconstruction error (`grail tune` emits the winner
                as a plan TOML; results are worker-count invariant);
                seed = \"gram-sensitivity\" seeds the search allocation
                by activation energy from the same statistics pass
              Budget allocators re-assign every ratio no rule pinned.

METHOD NAMES:
  selectors  mag-l1 mag-l2 prune-wanda gram random   (structured pruning)
  folding    fold random-fold
  baselines  wanda wanda++ slimgpt ziplm flap        (own recovery; bare
             `wanda` is the baseline — `prune-wanda` forces the selector)

SERVE (EXPERIMENTS.md §Serve daemon):
  `grail serve` drains a filesystem job queue under --root
  (default <out>/serve): submit plan/run/tune specs with `grail submit`
  (optionally a [job] section in the spec: verb, retries), poll with
  `grail status <id>` / `grail jobs`. Job ids are content-addressed
  (same spec+verb+target = same id; resubmitting a finished job
  re-queues it). Results land in <root>/results/<id>/; failed jobs are
  retried up to --retries times, then recorded with the error.
  Calibration statistics are cached content-addressed in <root>/cache
  (also usable outside the daemon via --cache <dir> on plan/run/
  tune/batch): repeat jobs against the same (checkpoint, calibration
  corpus) skip the forward pass entirely, bit-identically.
  `grail datagen --dev-ckpts` seeds untrained zoo checkpoints so the
  daemon can run without the Python training step.";
