//! `grail` — the L3 coordinator CLI.
//!
//! ```text
//! grail datagen [--out artifacts]          write the canonical datasets
//! grail exp <id|all> [--out results]       regenerate a paper table/figure
//! grail compress --model <ckpt> ...        one-off compression + eval
//! grail info                               artifact / runtime inventory
//! ```

use anyhow::{bail, Result};
use grail::cli::Args;
use grail::coordinator::{generate_all, Artifacts};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "datagen" => {
            let art = Artifacts::at(args.opt_or("out", "artifacts"));
            generate_all(&art, &mut |m| println!("{m}"))?;
            Ok(())
        }
        "exp" => grail::exp::run_cli(&args),
        "compress" => grail::exp::compress_cli(&args),
        "info" => {
            let art = Artifacts::at(args.opt_or("out", "artifacts"));
            println!("artifacts root: {:?}", art.root);
            println!("data present:   {}", art.has_data());
            #[cfg(feature = "pjrt")]
            match grail::runtime::Runtime::cpu(art) {
                Ok(rt) => println!("pjrt platform:  {}", rt.platform()),
                Err(e) => println!("pjrt:           unavailable ({e})"),
            }
            #[cfg(not(feature = "pjrt"))]
            {
                let _ = art;
                println!("pjrt:           disabled (build with --features pjrt)");
            }
            Ok(())
        }
        "help" | "--help" => {
            println!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown command `{other}` (try `grail help`)"),
    }
}

const HELP: &str = "\
grail — GRAIL post-hoc compensation coordinator

USAGE:
  grail datagen [--out artifacts]
  grail exp <fig2|fig3|fig5|fig6|fig7|table1|table2|table3|fig4|all>
            [--out results] [--artifacts artifacts] [--quick]
  grail compress --family <mlp|resnet|vit|lm> --ckpt <name>
            --method <mag-l1|mag-l2|wanda|gram|random|fold|random-fold|wanda++|slimgpt|ziplm|flap>
            --ratio <0..1> [--grail] [--alpha 1e-3]
  grail info";
