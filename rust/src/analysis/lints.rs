//! The five repo-specific lints behind `grail check`.
//!
//! Each lint is a pure function from scanned sources to [`Finding`]s;
//! policy (which modules are blessed, which tokens are forbidden) is
//! encoded in the `const` tables here so a reviewer can audit the
//! whole ruleset in one screen. Exemptions for *specific sites* live
//! in the committed allowlist (`analysis/allowlist.txt`), not here.
//!
//! What each lint protects (see `docs/INVARIANTS.md` for the map):
//!
//! - `undocumented-unsafe` — every `unsafe` keyword carries a
//!   `// SAFETY:` contract (same line, or in the comment/attribute
//!   block immediately above).
//! - `forbidden-nondeterminism` — no wall clocks, `std::hash`
//!   randomized containers, raw thread spawns, or env reads outside
//!   the allowlisted modules; this is the lint that protects digest
//!   stability and worker-count bit-invariance.
//! - `float-reduction-discipline` — no `+=` accumulation over a loop
//!   variable outside the blessed kernels (`tensor::ops`,
//!   `tensor::gemm`, `linalg`), so every reduction flows through the
//!   oracle-checked engine.
//! - `wire-format-casts` — no bare `as` narrowing casts in the wire
//!   format modules; lengths and geometry go through the checked
//!   `wire_u32`/`wire_u64`/`try_from` helpers.
//! - `oracle-pairing` — every `*_ref` oracle has a fast counterpart
//!   and a test referencing it, and the known fast entry points keep
//!   their oracles test-covered.

use super::report::Finding;
use super::scan::{has_word, is_word_byte, line_of, word_find_all, SourceFile};

/// Tokens whose presence outside allowlisted modules breaks the
/// determinism contract (wall clocks, randomized hashing, ad-hoc
/// threads, environment reads).
const FORBIDDEN_NONDET: &[&str] = &[
    "Instant::now",
    "SystemTime",
    "thread::spawn",
    "env::var",
    "env::vars",
    "env::var_os",
    "HashMap",
    "HashSet",
    "RandomState",
    "DefaultHasher",
];

/// Substrings marking an integer-typed accumulation (rescues `+=`
/// counters from the float-reduction lint).
const INT_HINTS: &[&str] = &[".len()", "usize", "u64", "u32", "i64", "i32", "u8", "count("];

/// Modules whose reductions are the blessed, oracle-checked kernels.
const FLOAT_BLESSED: &[&str] =
    &["rust/src/tensor/ops.rs", "rust/src/tensor/gemm.rs", "rust/src/linalg/"];

/// Wire-format modules where `as` narrowing casts are forbidden.
const WIRE_MODULES: &[&str] =
    &["rust/src/serve/digest.rs", "rust/src/serve/cache.rs", "rust/src/grail/mod.rs"];

/// Integer target types of a narrowing/reinterpreting `as` cast.
const INT_CAST_TARGETS: &[&str] =
    &["u8", "u16", "u32", "u64", "usize", "i8", "i16", "i32", "i64", "isize"];

/// Known fast entry point → oracle pairs, beyond the generic `*_ref`
/// suffix rule (rescan oracles follow a different naming scheme).
const ORACLE_PAIRS: &[(&str, &str)] = &[
    ("gemm_acc", "gemm_acc_ref"),
    ("gemm_nt_acc", "gemm_nt_acc_ref"),
    ("syrk_upper_acc", "syrk_upper_acc_ref"),
    ("solve_spd_multi", "solve_spd_multi_ref"),
    ("forward", "forward_ref"),
    ("generate", "generate_rescan"),
    ("compress_model", "compress_model_rescan"),
];

/// `undocumented-unsafe`: every `unsafe` keyword needs a `SAFETY:`
/// marker on the same line or in the contiguous comment/attribute
/// block above it (`/// # Safety` doc sections count).
pub fn lint_unsafe(f: &SourceFile) -> Vec<Finding> {
    let raw_lines: Vec<&str> = f.raw.split('\n').collect();
    let mut out = Vec::new();
    for pos in word_find_all(&f.masked, "unsafe") {
        let ln = line_of(&f.masked, pos);
        if unsafe_is_documented(&raw_lines, ln) {
            continue;
        }
        out.push(Finding::new(
            "undocumented-unsafe",
            &f.rel,
            ln,
            "`unsafe` without a `// SAFETY:` contract".to_string(),
        ));
    }
    out
}

fn unsafe_is_documented(raw_lines: &[&str], ln: usize) -> bool {
    if raw_lines[ln - 1].contains("SAFETY:") {
        return true;
    }
    // Walk up through the contiguous comment/attribute/blank block.
    let lo = ln.saturating_sub(31);
    for up in (lo..ln.saturating_sub(1)).rev() {
        let t = raw_lines[up].trim();
        if t.starts_with("//") {
            if t.contains("SAFETY:") || t.contains("# Safety") {
                return true;
            }
        } else if !(t.starts_with("#[") || t.starts_with("#![") || t.is_empty()) {
            break;
        }
    }
    false
}

/// `forbidden-nondeterminism`: forbidden tokens outside test regions.
/// Module-level exemptions go through the allowlist, not this lint.
pub fn lint_nondet(f: &SourceFile) -> Vec<Finding> {
    let mut out = Vec::new();
    for tok in FORBIDDEN_NONDET {
        for pos in word_find_all(&f.masked, tok) {
            let ln = line_of(&f.masked, pos);
            if f.in_test[ln - 1] {
                continue;
            }
            out.push(Finding::new(
                "forbidden-nondeterminism",
                &f.rel,
                ln,
                format!("forbidden nondeterminism source `{tok}`"),
            ));
        }
    }
    out
}

/// `float-reduction-discipline`: a `+=` whose right-hand side varies
/// with an enclosing loop variable while the target does not is a
/// serial reduction — those belong in the blessed kernels where the
/// `*_ref` oracles pin the summation order. Integer accumulations
/// (literal RHS or `INT_HINTS` on either side) are rescued.
pub fn lint_float_reduction(f: &SourceFile) -> Vec<Finding> {
    if f.is_testfile || FLOAT_BLESSED.iter().any(|m| f.rel.starts_with(m)) {
        return Vec::new();
    }
    let mut out = Vec::new();
    let mut loop_stack: Vec<(i64, String)> = Vec::new();
    let mut depth = 0i64;
    for (ln0, line) in f.masked.split('\n').enumerate() {
        let opens = line.bytes().filter(|&c| c == b'{').count() as i64;
        let closes = line.bytes().filter(|&c| c == b'}').count() as i64;
        if opens > 0 {
            if let Some(var) = for_loop_var(line) {
                loop_stack.push((depth + 1, var));
            }
        }
        depth += opens - closes;
        while loop_stack.last().is_some_and(|(d, _)| depth < *d) {
            loop_stack.pop();
        }
        if f.in_test[ln0] {
            continue;
        }
        let Some(idx) = line.find("+=") else { continue };
        let target = line[..idx].trim().trim_start_matches('*').trim();
        let rhs = line[idx + 2..].split(';').next().unwrap_or("").trim();
        if !rhs.is_empty() && rhs.bytes().all(|c| c.is_ascii_digit()) {
            continue; // integer counter
        }
        if INT_HINTS.iter().any(|h| rhs.contains(h) || target.contains(h)) {
            continue; // integer-typed accumulation
        }
        for (_, var) in &loop_stack {
            if has_word(rhs, var) && !has_word(target, var) {
                out.push(Finding::new(
                    "float-reduction-discipline",
                    &f.rel,
                    ln0 + 1,
                    format!("`+=` reduction over loop variable `{var}` outside blessed kernels"),
                ));
                break;
            }
        }
    }
    out
}

/// Extract the (first) binding of a `for` pattern on this masked line:
/// `for x in`, `for (a, b) in` → `a`, `for &mut v in` → `v`.
fn for_loop_var(line: &str) -> Option<String> {
    let pos = *word_find_all(line, "for").first()?;
    let mut rest = line[pos + 3..].trim_start();
    rest = rest.strip_prefix('(').unwrap_or(rest).trim_start();
    rest = rest.strip_prefix('&').unwrap_or(rest).trim_start();
    if let Some(r) = rest.strip_prefix("mut ") {
        rest = r.trim_start();
    }
    let end = rest.bytes().position(|c| !is_word_byte(c)).unwrap_or(rest.len());
    let ident = &rest[..end];
    if ident.is_empty() || ident.bytes().next().is_some_and(|c| c.is_ascii_digit()) {
        return None;
    }
    Some(ident.to_string())
}

/// `wire-format-casts`: `as <int>` in the wire modules, outside test
/// regions. Wire lengths and geometry must go through checked
/// conversions (`serve::digest::wire_u32`/`wire_u64`, `try_from`).
pub fn lint_wire_casts(f: &SourceFile) -> Vec<Finding> {
    if !WIRE_MODULES.iter().any(|m| f.rel.starts_with(m)) {
        return Vec::new();
    }
    let mut out = Vec::new();
    for (ln0, line) in f.masked.split('\n').enumerate() {
        if f.in_test[ln0] {
            continue;
        }
        for pos in word_find_all(line, "as") {
            let rest = line[pos + 2..].trim_start();
            let end = rest.bytes().position(|c| !is_word_byte(c)).unwrap_or(rest.len());
            let ty = &rest[..end];
            if INT_CAST_TARGETS.contains(&ty) {
                out.push(Finding::new(
                    "wire-format-casts",
                    &f.rel,
                    ln0 + 1,
                    format!("unchecked `as {ty}` cast in a wire-format module"),
                ));
            }
        }
    }
    out
}

/// `oracle-pairing` over the whole source set: the generic `*_ref`
/// rule (every oracle defined outside a test region needs a fast
/// counterpart and a test reference) plus the configured
/// [`ORACLE_PAIRS`]. `test_text` is the concatenated masked text of
/// every test region and test/bench file.
pub fn lint_oracles(files: &[SourceFile], test_text: &str) -> Vec<Finding> {
    let mut defs: Vec<(String, String, usize)> = Vec::new(); // (name, file, line)
    for f in files {
        if f.is_testfile || !f.rel.starts_with("rust/src") {
            continue;
        }
        for pos in word_find_all(&f.masked, "fn") {
            let ln = line_of(&f.masked, pos);
            if f.in_test[ln - 1] {
                continue;
            }
            let rest = f.masked[pos + 2..].trim_start();
            let end = rest.bytes().position(|c| !is_word_byte(c)).unwrap_or(rest.len());
            let name = &rest[..end];
            if !name.is_empty() && !defs.iter().any(|(n, _, _)| n == name) {
                defs.push((name.to_string(), f.rel.clone(), ln));
            }
        }
    }
    let lookup = |name: &str| defs.iter().find(|(n, _, _)| n == name);
    let mut out = Vec::new();
    for (name, file, ln) in &defs {
        let Some(stem) = name.strip_suffix("_ref") else { continue };
        if lookup(stem).is_none() {
            out.push(Finding::new(
                "oracle-pairing",
                file,
                *ln,
                format!("oracle `{name}` has no fast counterpart `{stem}`"),
            ));
        }
        if !has_word(test_text, name) {
            out.push(Finding::new(
                "oracle-pairing",
                file,
                *ln,
                format!("oracle `{name}` is not referenced by any test"),
            ));
        }
    }
    for (fast, oracle) in ORACLE_PAIRS {
        let Some((_, file, ln)) = lookup(fast) else { continue };
        if lookup(oracle).is_none() {
            out.push(Finding::new(
                "oracle-pairing",
                file,
                *ln,
                format!("fast entry `{fast}` has no oracle `{oracle}`"),
            ));
        } else if !has_word(test_text, oracle) {
            out.push(Finding::new(
                "oracle-pairing",
                file,
                *ln,
                format!("oracle `{oracle}` for `{fast}` is not referenced by any test"),
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn src(rel: &str, text: &str) -> SourceFile {
        SourceFile::new(rel.to_string(), text.to_string())
    }

    #[test]
    fn unsafe_lint_requires_safety_contract() {
        let bad = src("rust/src/x.rs", "fn f() {\n    unsafe { g() }\n}\n");
        assert_eq!(lint_unsafe(&bad).len(), 1);
        assert_eq!(lint_unsafe(&bad)[0].line, 2);
        let good = src(
            "rust/src/x.rs",
            "fn f() {\n    // SAFETY: g is sound.\n    unsafe { g() }\n}\n",
        );
        assert!(lint_unsafe(&good).is_empty());
        let doc = src(
            "rust/src/x.rs",
            "/// # Safety\n/// Caller checks cpu features.\nunsafe fn g() {}\n",
        );
        assert!(lint_unsafe(&doc).is_empty());
        let masked = src("rust/src/x.rs", "let s = \"unsafe\"; // unsafe in comment\n");
        assert!(lint_unsafe(&masked).is_empty(), "strings and comments are masked");
    }

    #[test]
    fn nondet_lint_flags_tokens_outside_tests() {
        let bad = src("rust/src/x.rs", "use std::collections::HashMap;\n");
        let f = lint_nondet(&bad);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].line, 1);
        let in_test = src(
            "rust/src/x.rs",
            "fn a() {}\n#[cfg(test)]\nmod tests {\n    use std::time::Instant;\n}\n",
        );
        assert!(lint_nondet(&in_test).is_empty(), "test regions are exempt");
    }

    #[test]
    fn float_reduction_lint_flags_loop_accumulation() {
        let bad = src(
            "rust/src/nn/x.rs",
            "fn s(x: &[f32]) -> f32 {\n    let mut s = 0.0;\n    for v in x {\n        s += v;\n    }\n    s\n}\n",
        );
        let f = lint_float_reduction(&bad);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].line, 4);
        // Integer counters and .len()-typed sums are rescued.
        let ok = src(
            "rust/src/nn/x.rs",
            "fn c(x: &[Vec<u8>]) -> usize {\n    let mut n = 0usize;\n    for v in x {\n        n += v.len();\n    }\n    n\n}\n",
        );
        assert!(lint_float_reduction(&ok).is_empty());
        // Blessed kernels are exempt wholesale.
        let blessed = src(
            "rust/src/tensor/ops.rs",
            "fn s(x: &[f32]) -> f32 {\n    let mut s = 0.0;\n    for v in x {\n        s += v;\n    }\n    s\n}\n",
        );
        assert!(lint_float_reduction(&blessed).is_empty());
    }

    #[test]
    fn wire_cast_lint_scoped_to_wire_modules() {
        let bad = src("rust/src/serve/cache.rs", "let n = shards.len() as u32;\n");
        let f = lint_wire_casts(&bad);
        assert_eq!(f.len(), 1);
        assert!(f[0].message.contains("as u32"));
        let outside = src("rust/src/nn/x.rs", "let n = shards.len() as u32;\n");
        assert!(lint_wire_casts(&outside).is_empty());
        let float = src("rust/src/serve/cache.rs", "let x = n as f64;\n");
        assert!(lint_wire_casts(&float).is_empty(), "float casts are not wire narrowing");
    }

    #[test]
    fn oracle_lint_pairs_refs_with_fast_paths() {
        let files = vec![
            src("rust/src/a.rs", "pub fn lonely_ref() {}\n"),
            src("rust/src/b.rs", "pub fn fast() {}\npub fn fast_ref() {}\n"),
        ];
        let f = lint_oracles(&files, "fn t() { fast_ref(); }");
        assert!(f.iter().any(|x| x.message.contains("`lonely_ref` has no fast counterpart")));
        assert!(f.iter().any(|x| x.message.contains("`lonely_ref` is not referenced")));
        assert!(!f.iter().any(|x| x.message.contains("`fast_ref`")));
    }

    #[test]
    fn for_loop_var_parses_common_patterns() {
        assert_eq!(for_loop_var("for i in 0..n {").as_deref(), Some("i"));
        assert_eq!(for_loop_var("for (h, k) in xs.iter() {").as_deref(), Some("h"));
        assert_eq!(for_loop_var("for &mut v in xs {").as_deref(), Some("v"));
        assert_eq!(for_loop_var("let x = 1;"), None);
        assert_eq!(for_loop_var("for ((a, b), c) in xs {"), None, "nested tuples give up");
    }
}
