//! Findings, the check report, and its human/JSON renderings.

use super::allowlist::AllowEntry;

/// One lint finding at a source location.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Finding {
    /// Lint id (`undocumented-unsafe`, `forbidden-nondeterminism`, …).
    pub lint: &'static str,
    /// Repo-relative file path (`/` separators).
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    pub message: String,
    /// Justification of the allowlist entry that waived this finding
    /// (`None` = denied).
    pub allowed: Option<String>,
}

impl Finding {
    pub fn new(lint: &'static str, file: &str, line: usize, message: String) -> Finding {
        Finding { lint, file: file.to_string(), line, message, allowed: None }
    }
}

/// The result of one `grail check` run.
#[derive(Debug, Default)]
pub struct CheckReport {
    /// All findings, sorted by (file, line, lint).
    pub findings: Vec<Finding>,
    /// Allowlist entries that matched nothing (`stale-allowlist`
    /// warnings — reported, never denied).
    pub stale: Vec<AllowEntry>,
    /// Files scanned.
    pub files_scanned: usize,
}

impl CheckReport {
    /// Findings not waived by the allowlist.
    pub fn denied(&self) -> impl Iterator<Item = &Finding> {
        self.findings.iter().filter(|f| f.allowed.is_none())
    }

    pub fn denied_count(&self) -> usize {
        self.denied().count()
    }

    pub fn allowed_count(&self) -> usize {
        self.findings.len() - self.denied_count()
    }

    /// Human-readable table (one line per finding, denied first).
    pub fn render_table(&self) -> String {
        let mut out = String::new();
        let mut rows: Vec<&Finding> = self.findings.iter().collect();
        rows.sort_by(|a, b| {
            (a.allowed.is_some(), &a.file, a.line, a.lint)
                .cmp(&(b.allowed.is_some(), &b.file, b.line, b.lint))
        });
        for f in rows {
            let mark = if f.allowed.is_some() { "allow" } else { "DENY " };
            out.push_str(&format!(
                "{mark} {:<28} {}:{}  {}\n",
                f.lint,
                f.file,
                f.line,
                f.message
            ));
            if let Some(why) = &f.allowed {
                out.push_str(&format!("      └─ allowlisted: {why}\n"));
            }
        }
        for e in &self.stale {
            out.push_str(&format!(
                "warn  stale-allowlist            {} `{}` (line {}) matched nothing\n",
                e.lint, e.glob, e.src_line
            ));
        }
        out.push_str(&format!(
            "grail check: {} file(s), {} finding(s) — {} denied, {} allowlisted, {} stale entr{}\n",
            self.files_scanned,
            self.findings.len(),
            self.denied_count(),
            self.allowed_count(),
            self.stale.len(),
            if self.stale.len() == 1 { "y" } else { "ies" }
        ));
        out
    }

    /// Machine-readable report (schema `grail-check-v1`).
    pub fn render_json(&self) -> String {
        let mut s = String::from("{\n  \"schema\": \"grail-check-v1\",\n");
        s.push_str(&format!("  \"files_scanned\": {},\n", self.files_scanned));
        s.push_str(&format!(
            "  \"counts\": {{\"total\": {}, \"denied\": {}, \"allowed\": {}}},\n",
            self.findings.len(),
            self.denied_count(),
            self.allowed_count()
        ));
        s.push_str("  \"findings\": [\n");
        for (i, f) in self.findings.iter().enumerate() {
            let sep = if i + 1 == self.findings.len() { "" } else { "," };
            let allowed = match &f.allowed {
                Some(why) => format!("\"{}\"", json_escape(why)),
                None => "null".to_string(),
            };
            s.push_str(&format!(
                "    {{\"lint\": \"{}\", \"file\": \"{}\", \"line\": {}, \
                 \"message\": \"{}\", \"allowed\": {}}}{sep}\n",
                f.lint,
                json_escape(&f.file),
                f.line,
                json_escape(&f.message),
                allowed
            ));
        }
        s.push_str("  ],\n  \"stale_allowlist\": [\n");
        for (i, e) in self.stale.iter().enumerate() {
            let sep = if i + 1 == self.stale.len() { "" } else { "," };
            s.push_str(&format!(
                "    {{\"lint\": \"{}\", \"glob\": \"{}\", \"line\": {}}}{sep}\n",
                json_escape(&e.lint),
                json_escape(&e.glob),
                e.src_line
            ));
        }
        s.push_str("  ]\n}\n");
        s
    }
}

/// Minimal JSON string escaping (quotes, backslashes, control chars).
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report() -> CheckReport {
        let mut denied = Finding::new("undocumented-unsafe", "rust/src/a.rs", 3, "x".into());
        denied.message = "`unsafe` without contract".into();
        let mut allowed = Finding::new("forbidden-nondeterminism", "rust/src/b.rs", 7, "y".into());
        allowed.allowed = Some("wall-clock is report-only".into());
        CheckReport { findings: vec![denied, allowed], stale: Vec::new(), files_scanned: 2 }
    }

    #[test]
    fn table_marks_denied_and_allowed() {
        let t = report().render_table();
        assert!(t.contains("DENY  undocumented-unsafe"));
        assert!(t.contains("allow forbidden-nondeterminism"));
        assert!(t.contains("rust/src/a.rs:3"));
        assert!(t.contains("1 denied, 1 allowlisted"));
    }

    #[test]
    fn json_is_well_formed_enough() {
        let j = report().render_json();
        assert!(j.contains("\"schema\": \"grail-check-v1\""));
        assert!(j.contains("\"denied\": 1"));
        assert!(j.contains("\"line\": 3"));
        assert!(j.contains("\"allowed\": null"));
        assert_eq!(j.matches('{').count(), j.matches('}').count());
    }

    #[test]
    fn json_escape_handles_specials() {
        assert_eq!(json_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(json_escape("\u{1}"), "\\u0001");
    }
}
