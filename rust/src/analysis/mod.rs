//! `grail check` — the repo-native static-analysis pass.
//!
//! A dependency-free, comment/string-aware token scanner over
//! `rust/src`, `rust/tests`, and `benches` that enforces the crate's
//! determinism and oracle invariants as lints (see [`lints`]), with a
//! committed allowlist ([`allowlist`], `analysis/allowlist.txt`) and
//! both a human table and a JSON report ([`report`]). CI runs
//! `grail check --deny` on every push; the committed tree must come
//! back clean (every exemption justified in the allowlist), so a PR
//! that introduces a stray `HashMap` iteration, an unannotated
//! `unsafe`, or an un-oracled reduction fails loudly at the source
//! line instead of silently weakening a bit-identity guarantee.
//!
//! The runtime half of the same story — the scheduler write-set race
//! auditor — lives in [`crate::coordinator::scheduler::audit`].

pub mod allowlist;
pub mod lints;
pub mod report;
pub mod scan;

use allowlist::{apply_allowlist, parse_allowlist, AllowEntry};
use anyhow::{bail, Context, Result};
use report::CheckReport;
use scan::SourceFile;
use std::path::{Path, PathBuf};

/// Directories scanned, relative to the repo root.
const SCAN_DIRS: &[&str] = &["rust/src", "rust/tests", "benches"];

/// Default allowlist path, relative to the repo root.
pub const DEFAULT_ALLOWLIST: &str = "analysis/allowlist.txt";

/// Run every lint over the tree at `root` and apply the allowlist at
/// `allowlist_path` (relative paths resolve against `root`; a missing
/// file means an empty allowlist). This is the library entry the CLI
/// verb and the self-tests share.
pub fn run_check(root: &Path, allowlist_path: &Path) -> Result<CheckReport> {
    let files = collect_sources(root)?;
    let mut findings = Vec::new();
    let mut test_text = String::new();
    for f in &files {
        test_text.push_str(&f.test_text());
        test_text.push('\n');
        findings.extend(lints::lint_unsafe(f));
        findings.extend(lints::lint_nondet(f));
        findings.extend(lints::lint_float_reduction(f));
        findings.extend(lints::lint_wire_casts(f));
    }
    findings.extend(lints::lint_oracles(&files, &test_text));
    findings.sort_by(|a, b| (&a.file, a.line, a.lint).cmp(&(&b.file, b.line, b.lint)));

    let alist = if allowlist_path.is_absolute() {
        allowlist_path.to_path_buf()
    } else {
        root.join(allowlist_path)
    };
    let mut entries: Vec<AllowEntry> = match std::fs::read_to_string(&alist) {
        Ok(text) => parse_allowlist(&text)
            .with_context(|| format!("parsing allowlist {}", alist.display()))?,
        Err(_) => Vec::new(),
    };
    apply_allowlist(&mut entries, &mut findings);
    let stale: Vec<AllowEntry> = entries.into_iter().filter(|e| e.used == 0).collect();
    Ok(CheckReport { findings, stale, files_scanned: files.len() })
}

/// Collect every `.rs` file under [`SCAN_DIRS`], sorted by relative
/// path so findings, ratchet consumption, and reports are
/// deterministic. Missing directories are skipped (the self-test
/// builds minimal temp trees).
fn collect_sources(root: &Path) -> Result<Vec<SourceFile>> {
    let mut paths: Vec<(String, PathBuf)> = Vec::new();
    for base in SCAN_DIRS {
        let dir = root.join(base);
        if dir.is_dir() {
            walk(&dir, &mut |p| {
                if p.extension().and_then(|e| e.to_str()) == Some("rs") {
                    let rel = p
                        .strip_prefix(root)
                        .unwrap_or(p)
                        .components()
                        .map(|c| c.as_os_str().to_string_lossy().into_owned())
                        .collect::<Vec<_>>()
                        .join("/");
                    paths.push((rel, p.to_path_buf()));
                }
            })?;
        }
    }
    paths.sort();
    let mut out = Vec::with_capacity(paths.len());
    for (rel, p) in paths {
        let raw = std::fs::read_to_string(&p)
            .with_context(|| format!("reading {}", p.display()))?;
        out.push(SourceFile::new(rel, raw));
    }
    Ok(out)
}

fn walk(dir: &Path, visit: &mut dyn FnMut(&Path)) -> Result<()> {
    let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)
        .with_context(|| format!("scanning {}", dir.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            walk(&p, visit)?;
        } else {
            visit(&p);
        }
    }
    Ok(())
}

/// `grail check [--root DIR] [--allowlist FILE] [--json FILE] [--deny]`
pub fn check_cli(args: &crate::cli::Args) -> Result<()> {
    let root = PathBuf::from(args.opt_or("root", "."));
    let alist = PathBuf::from(args.opt_or("allowlist", DEFAULT_ALLOWLIST));
    let report = run_check(&root, &alist)?;
    print!("{}", report.render_table());
    if let Some(json_path) = args.opt("json") {
        std::fs::write(json_path, report.render_json())
            .with_context(|| format!("writing {json_path}"))?;
        println!("json report: {json_path}");
    }
    if args.has("deny") && report.denied_count() > 0 {
        bail!("grail check: {} denied finding(s)", report.denied_count());
    }
    Ok(())
}
