//! The committed lint allowlist (`analysis/allowlist.txt`).
//!
//! Line grammar (one entry per line; `#` comments and blanks ignored):
//!
//! ```text
//! <lint-id> <path-glob> [allow=N] -- <one-line justification>
//! ```
//!
//! The glob uses the spec matcher's `*`/`?` wildcards
//! ([`crate::grail::spec::glob_match`]; `*` crosses `/`). `allow=N`
//! ratchets the entry: it waives at most `N` findings, so new
//! violations in an already-exempted file still fail `--deny` instead
//! of hiding behind a blanket exemption. A missing justification is a
//! configuration error — every exemption must say *why* — and an
//! entry that matches nothing is reported as a `stale-allowlist`
//! warning so dead exemptions get pruned.

use crate::grail::spec::glob_match;
use anyhow::{bail, Result};

/// One parsed allowlist entry.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct AllowEntry {
    pub lint: String,
    pub glob: String,
    /// Max findings this entry may waive (`None` = unbounded).
    pub max: Option<usize>,
    pub justification: String,
    /// 1-based line in the allowlist file (for stale reports).
    pub src_line: usize,
    /// Findings waived so far (for the ratchet and staleness).
    pub used: usize,
}

impl AllowEntry {
    /// Whether this entry can waive one more `(lint, file)` finding.
    fn covers(&self, lint: &str, file: &str) -> bool {
        let budget_left = match self.max {
            Some(m) => self.used < m,
            None => true,
        };
        self.lint == lint && budget_left && glob_match(&self.glob, file)
    }
}

/// Parse the allowlist text. Errors on malformed lines or entries
/// without a justification.
pub fn parse_allowlist(text: &str) -> Result<Vec<AllowEntry>> {
    let mut out = Vec::new();
    for (ln0, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let src_line = ln0 + 1;
        let Some((head, justification)) = line.split_once("--") else {
            bail!("allowlist line {src_line}: missing `-- <justification>`: {line}");
        };
        let justification = justification.trim().to_string();
        if justification.is_empty() {
            bail!("allowlist line {src_line}: empty justification");
        }
        let fields: Vec<&str> = head.split_whitespace().collect();
        let (lint, glob, rest) = match fields.as_slice() {
            [lint, glob] => (*lint, *glob, None),
            [lint, glob, rest] => (*lint, *glob, Some(*rest)),
            _ => bail!("allowlist line {src_line}: expected `<lint> <glob> [allow=N]`: {line}"),
        };
        let max = match rest {
            None => None,
            Some(r) => {
                let Some(n) = r.strip_prefix("allow=") else {
                    bail!("allowlist line {src_line}: unknown field `{r}` (want allow=N)");
                };
                let n: usize = n
                    .parse()
                    .map_err(|_| anyhow::anyhow!("allowlist line {src_line}: bad allow count"))?;
                Some(n)
            }
        };
        out.push(AllowEntry {
            lint: lint.to_string(),
            glob: glob.to_string(),
            max,
            justification,
            src_line,
            used: 0,
        });
    }
    Ok(out)
}

/// Apply the allowlist to findings (in their sorted order, so ratchet
/// budgets are consumed deterministically). Returns the entries with
/// their `used` counters updated; findings that matched get their
/// `allowed` justification set.
pub fn apply_allowlist(entries: &mut [AllowEntry], findings: &mut [super::report::Finding]) {
    for f in findings.iter_mut() {
        for e in entries.iter_mut() {
            if e.covers(f.lint, &f.file) {
                e.used += 1;
                f.allowed = Some(e.justification.clone());
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::report::Finding;
    use super::*;

    #[test]
    fn parses_entries_and_ratchets() {
        let text = "\
# comment
forbidden-nondeterminism rust/src/serve/daemon.rs -- wall-clock is operator telemetry
float-reduction-discipline rust/src/nn/*.rs allow=2 -- fold sums, fixed order
";
        let es = parse_allowlist(text).unwrap();
        assert_eq!(es.len(), 2);
        assert_eq!(es[0].max, None);
        assert_eq!(es[1].max, Some(2));
        assert_eq!(es[1].src_line, 4);
        assert!(es[1].justification.contains("fixed order"));
    }

    #[test]
    fn missing_justification_is_an_error() {
        assert!(parse_allowlist("undocumented-unsafe rust/src/a.rs\n").is_err());
        assert!(parse_allowlist("undocumented-unsafe rust/src/a.rs --   \n").is_err());
        assert!(parse_allowlist("undocumented-unsafe rust/src/a.rs allow=x -- y\n").is_err());
        assert!(parse_allowlist("undocumented-unsafe rust/src/a.rs bogus=1 -- y\n").is_err());
    }

    #[test]
    fn ratchet_waives_only_n_findings() {
        let mut es =
            parse_allowlist("lint-a rust/src/x.rs allow=1 -- one known site\n").unwrap();
        let mut fs = vec![
            Finding::new("lint-a", "rust/src/x.rs", 1, "m".into()),
            Finding::new("lint-a", "rust/src/x.rs", 2, "m".into()),
            Finding::new("lint-b", "rust/src/x.rs", 3, "m".into()),
        ];
        apply_allowlist(&mut es, &mut fs);
        assert!(fs[0].allowed.is_some());
        assert!(fs[1].allowed.is_none(), "ratchet exhausted after one waiver");
        assert!(fs[2].allowed.is_none(), "different lint never matches");
        assert_eq!(es[0].used, 1);
    }

    #[test]
    fn globs_cross_directories() {
        let mut es = parse_allowlist("lint-a rust/src/bench_util/* -- bench timing\n").unwrap();
        let mut fs = vec![Finding::new("lint-a", "rust/src/bench_util/mod.rs", 5, "m".into())];
        apply_allowlist(&mut es, &mut fs);
        assert!(fs[0].allowed.is_some());
    }
}
