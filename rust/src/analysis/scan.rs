//! Comment/string-aware lexical scanning for [`super`] (`grail check`).
//!
//! The lints operate on a *masked* view of each source file: comments,
//! string literals (plain, byte, raw), and char literals are blanked
//! with spaces (newlines preserved), so a `HashMap` mentioned in a doc
//! comment or an `unsafe` inside a test-fixture string never trips a
//! lint. Masking is a small hand-rolled byte scanner — no dependencies
//! — that understands nested block comments, escape sequences, raw
//! strings (`r#"…"#`), and the `'a` lifetime vs `'a'` char-literal
//! ambiguity.
//!
//! The scanner also tracks `#[cfg(test)] mod …` regions by brace depth
//! so lints can treat in-file unit tests like integration tests
//! (nondeterminism in test scaffolding is fine; the production paths
//! are what the lints protect).

/// One scanned source file.
pub struct SourceFile {
    /// Repo-relative path with `/` separators (stable report keys).
    pub rel: String,
    /// Raw text (comment contents stay visible — SAFETY markers live
    /// here).
    pub raw: String,
    /// Comment/string/char-masked text, newline-aligned with `raw`.
    pub masked: String,
    /// Per line (0-based): inside a `#[cfg(test)] mod` region, or in a
    /// test/bench file entirely.
    pub in_test: Vec<bool>,
    /// Whole file is test scaffolding (`rust/tests/`, `benches/`).
    pub is_testfile: bool,
}

impl SourceFile {
    pub fn new(rel: String, raw: String) -> SourceFile {
        let masked = mask_source(&raw);
        let is_testfile = rel.starts_with("rust/tests/") || rel.starts_with("benches/");
        let mut in_test = test_region_lines(&masked);
        if is_testfile {
            in_test.iter_mut().for_each(|t| *t = true);
        }
        SourceFile { rel, raw, masked, in_test, is_testfile }
    }

    /// The masked text of only the test-region lines (newline-joined)
    /// — what counts as "referenced by a test" for the oracle lint.
    pub fn test_text(&self) -> String {
        self.masked
            .lines()
            .zip(&self.in_test)
            .filter(|(_, &t)| t)
            .map(|(l, _)| l)
            .collect::<Vec<_>>()
            .join("\n")
    }
}

/// Blank comments and string/char literals with spaces, preserving
/// newlines (so line numbers in `masked` match `raw`).
pub fn mask_source(src: &str) -> String {
    let b = src.as_bytes();
    let n = b.len();
    let mut out = b.to_vec();
    let blank = |out: &mut [u8], a: usize, end: usize| {
        for v in out[a..end.min(n)].iter_mut() {
            if *v != b'\n' {
                *v = b' ';
            }
        }
    };
    let mut i = 0usize;
    while i < n {
        let c = b[i];
        if c == b'/' && i + 1 < n && b[i + 1] == b'/' {
            let mut j = i;
            while j < n && b[j] != b'\n' {
                j += 1;
            }
            blank(&mut out, i, j);
            i = j;
        } else if c == b'/' && i + 1 < n && b[i + 1] == b'*' {
            // Block comments nest in Rust.
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if j + 1 < n && b[j] == b'/' && b[j + 1] == b'*' {
                    depth += 1;
                    j += 2;
                } else if j + 1 < n && b[j] == b'*' && b[j + 1] == b'/' {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            blank(&mut out, i, j);
            i = j;
        } else if c == b'"' || (c == b'b' && i + 1 < n && b[i + 1] == b'"') {
            // Plain or byte string; honour escapes.
            let mut j = if c == b'"' { i + 1 } else { i + 2 };
            while j < n {
                if b[j] == b'\\' {
                    j += 2;
                } else if b[j] == b'"' {
                    j += 1;
                    break;
                } else {
                    j += 1;
                }
            }
            blank(&mut out, i, j);
            i = j;
        } else if let Some(end) = raw_string_end(b, i) {
            blank(&mut out, i, end);
            i = end;
        } else if c == b'\'' {
            // Char literal vs lifetime.
            if i + 1 < n && b[i + 1] == b'\\' {
                let mut j = i + 2;
                while j < n && b[j] != b'\'' {
                    j += 1;
                }
                let j = (j + 1).min(n);
                blank(&mut out, i, j);
                i = j;
            } else if i + 2 < n && b[i + 2] == b'\'' {
                blank(&mut out, i, i + 3);
                i += 3;
            } else {
                i += 1; // lifetime
            }
        } else {
            i += 1;
        }
    }
    String::from_utf8_lossy(&out).into_owned()
}

/// If a raw (byte) string literal `r#*"` / `br#*"` starts at `i`,
/// return the byte index one past its closing `"#*`.
fn raw_string_end(b: &[u8], i: usize) -> Option<usize> {
    let n = b.len();
    let mut j = i;
    if j < n && b[j] == b'b' {
        j += 1;
    }
    if j >= n || b[j] != b'r' {
        return None;
    }
    // A raw string must not be the tail of an identifier (`for`,
    // `attr`…): the byte before `i` must be a non-word boundary.
    if i > 0 && is_word_byte(b[i - 1]) {
        return None;
    }
    j += 1;
    let mut hashes = 0usize;
    while j < n && b[j] == b'#' {
        hashes += 1;
        j += 1;
    }
    if j >= n || b[j] != b'"' {
        return None;
    }
    j += 1;
    // Find `"` followed by `hashes` `#`s.
    while j < n {
        let close_ok = b[j] == b'"'
            && j + 1 + hashes <= n
            && b[j + 1..j + 1 + hashes].iter().all(|&h| h == b'#');
        if close_ok {
            return Some(j + 1 + hashes);
        }
        j += 1;
    }
    Some(n)
}

/// Per masked line: is it inside a `#[cfg(test)] mod …` block?
pub fn test_region_lines(masked: &str) -> Vec<bool> {
    let lines: Vec<&str> = masked.split('\n').collect();
    let mut in_test = vec![false; lines.len()];
    let mut pending_cfg = false;
    let mut depth = 0i64;
    let mut test_until_depth: Option<i64> = None;
    for (ln, line) in lines.iter().enumerate() {
        let t = line.trim();
        if test_until_depth.is_none() && pending_cfg && t.starts_with("mod ") {
            test_until_depth = Some(depth);
        }
        if t.starts_with("#[cfg(test)]") || t.starts_with("#[cfg(all(test") {
            pending_cfg = true;
        } else if !t.is_empty()
            && !t.starts_with("#[")
            && test_until_depth.is_none()
            && !t.starts_with("mod ")
        {
            pending_cfg = false;
        }
        if test_until_depth.is_some() {
            in_test[ln] = true;
        }
        let opens = line.bytes().filter(|&c| c == b'{').count() as i64;
        let closes = line.bytes().filter(|&c| c == b'}').count() as i64;
        depth += opens - closes;
        if let Some(td) = test_until_depth {
            if closes > 0 && depth <= td {
                test_until_depth = None;
                pending_cfg = false;
            }
        }
    }
    in_test
}

pub fn is_word_byte(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

/// Byte offsets of word-bounded occurrences of `needle` in `text`.
pub fn word_find_all(text: &str, needle: &str) -> Vec<usize> {
    let t = text.as_bytes();
    let mut out = Vec::new();
    let mut start = 0usize;
    while let Some(off) = text[start..].find(needle) {
        let i = start + off;
        let before_ok = i == 0 || !is_word_byte(t[i - 1]);
        let after = i + needle.len();
        let after_ok = after >= t.len() || !is_word_byte(t[after]);
        if before_ok && after_ok {
            out.push(i);
        }
        start = i + 1;
    }
    out
}

/// Whether `text` contains a word-bounded occurrence of `needle`.
pub fn has_word(text: &str, needle: &str) -> bool {
    !word_find_all(text, needle).is_empty()
}

/// 1-based line number of byte offset `pos`.
pub fn line_of(text: &str, pos: usize) -> usize {
    text.as_bytes()[..pos].iter().filter(|&&c| c == b'\n').count() + 1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn masks_line_and_block_comments() {
        let m = mask_source("let a = 1; // HashMap here\n/* unsafe\n nested /* x */ */ let b;");
        assert!(!m.contains("HashMap"));
        assert!(!m.contains("unsafe"));
        assert!(m.contains("let a = 1;"));
        assert!(m.contains("let b;"));
        assert_eq!(m.matches('\n').count(), 2, "newlines survive masking");
    }

    #[test]
    fn masks_strings_and_chars_but_not_lifetimes() {
        let m = mask_source(r#"let s = "unsafe \" HashMap"; let c = '"'; fn f<'a>(x: &'a u8) {}"#);
        assert!(!m.contains("unsafe"));
        assert!(!m.contains("HashMap"));
        assert!(m.contains("<'a>"), "lifetimes are not char literals: {m}");
        assert!(m.contains("&'a u8"));
    }

    #[test]
    fn masks_raw_strings() {
        let src = "let s = r#\"has \"quotes\" and unsafe\"#; let t = r\"x\"; keep();";
        let m = mask_source(src);
        assert!(!m.contains("unsafe"));
        assert!(!m.contains("quotes"));
        assert!(m.contains("keep();"));
        // `r` as an identifier tail must not start a raw string.
        let m2 = mask_source("for x in y {} attr\"s\"");
        assert!(m2.contains("for x in y {}"));
    }

    #[test]
    fn test_regions_track_brace_depth() {
        let src = "fn a() {}\n#[cfg(test)]\nmod tests {\n    fn b() {}\n}\nfn c() {}\n";
        let m = mask_source(src);
        let t = test_region_lines(&m);
        assert!(!t[0], "fn a is production code");
        assert!(t[2] && t[3] && t[4], "mod tests body is a test region");
        assert!(!t[5], "fn c after the closing brace is production code");
    }

    #[test]
    fn word_boundaries_respected() {
        assert_eq!(word_find_all("HashMap HashMapX XHashMap", "HashMap"), vec![0]);
        assert!(has_word("let x: HashMap<u32,u32>;", "HashMap"));
        assert!(!has_word("let map = my_HashMap;", "HashMap"));
        assert_eq!(line_of("a\nb\nc", 4), 3);
    }
}
