//! TOML-subset configuration parser (no `serde`/`toml` offline).
//!
//! Supports the subset the launcher needs: `[section]` and
//! `[section.sub]` headers, `key = value` with string / integer / float
//! / boolean / homogeneous-array values, `#` comments. Values are kept
//! as typed [`Value`]s in a flat `section.key` map with typed accessors
//! and helpful errors.

use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;

/// A parsed configuration value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    fn type_name(&self) -> &'static str {
        match self {
            Value::Str(_) => "string",
            Value::Int(_) => "integer",
            Value::Float(_) => "float",
            Value::Bool(_) => "boolean",
            Value::Array(_) => "array",
        }
    }
}

/// Flat `section.key -> Value` configuration.
#[derive(Clone, Debug, Default)]
pub struct Config {
    map: BTreeMap<String, Value>,
}

impl Config {
    /// Parse TOML-subset text.
    pub fn parse(text: &str) -> Result<Self> {
        let mut map = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    bail!("line {}: unterminated section header", lineno + 1);
                }
                section = line[1..line.len() - 1].trim().to_string();
                if section.is_empty() {
                    bail!("line {}: empty section name", lineno + 1);
                }
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| anyhow!("line {}: expected `key = value`", lineno + 1))?;
            let key = line[..eq].trim();
            if key.is_empty() {
                bail!("line {}: empty key", lineno + 1);
            }
            let val = parse_value(line[eq + 1..].trim())
                .with_context(|| format!("line {}", lineno + 1))?;
            let full = if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
            map.insert(full, val);
        }
        Ok(Config { map })
    }

    /// Load and parse a file.
    pub fn load(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        Self::parse(&text).with_context(|| format!("parsing {path}"))
    }

    /// Raw lookup.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.map.get(key)
    }

    /// All keys (sorted).
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.map.keys().map(|s| s.as_str())
    }

    /// Set/override a value (used to apply CLI overrides on top).
    pub fn set(&mut self, key: &str, v: Value) {
        self.map.insert(key.to_string(), v);
    }

    /// Typed accessor: string.
    pub fn str(&self, key: &str) -> Result<&str> {
        match self.get(key) {
            Some(Value::Str(s)) => Ok(s),
            Some(v) => bail!("{key}: expected string, found {}", v.type_name()),
            None => bail!("missing config key `{key}`"),
        }
    }

    /// Typed accessor: integer (as usize).
    pub fn usize(&self, key: &str) -> Result<usize> {
        match self.get(key) {
            Some(Value::Int(i)) if *i >= 0 => Ok(*i as usize),
            Some(Value::Int(i)) => bail!("{key}: negative integer {i}"),
            Some(v) => bail!("{key}: expected integer, found {}", v.type_name()),
            None => bail!("missing config key `{key}`"),
        }
    }

    /// Typed accessor: float (integers coerce).
    pub fn f64(&self, key: &str) -> Result<f64> {
        match self.get(key) {
            Some(Value::Float(f)) => Ok(*f),
            Some(Value::Int(i)) => Ok(*i as f64),
            Some(v) => bail!("{key}: expected float, found {}", v.type_name()),
            None => bail!("missing config key `{key}`"),
        }
    }

    /// Typed accessor: bool.
    pub fn bool(&self, key: &str) -> Result<bool> {
        match self.get(key) {
            Some(Value::Bool(b)) => Ok(*b),
            Some(v) => bail!("{key}: expected boolean, found {}", v.type_name()),
            None => bail!("missing config key `{key}`"),
        }
    }

    /// Typed accessor: array of floats (ints coerce).
    pub fn f64_array(&self, key: &str) -> Result<Vec<f64>> {
        match self.get(key) {
            Some(Value::Array(xs)) => xs
                .iter()
                .map(|v| match v {
                    Value::Float(f) => Ok(*f),
                    Value::Int(i) => Ok(*i as f64),
                    other => bail!("{key}: array element is {}", other.type_name()),
                })
                .collect(),
            Some(v) => bail!("{key}: expected array, found {}", v.type_name()),
            None => bail!("missing config key `{key}`"),
        }
    }

    /// Typed accessor: array of strings.
    pub fn str_array(&self, key: &str) -> Result<Vec<String>> {
        match self.get(key) {
            Some(Value::Array(xs)) => xs
                .iter()
                .map(|v| match v {
                    Value::Str(s) => Ok(s.clone()),
                    other => bail!("{key}: array element is {}", other.type_name()),
                })
                .collect(),
            Some(v) => bail!("{key}: expected array, found {}", v.type_name()),
            None => bail!("missing config key `{key}`"),
        }
    }

    /// Accessor with default.
    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.usize(key).unwrap_or(default)
    }

    /// Accessor with default.
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.f64(key).unwrap_or(default)
    }

    /// Accessor with default.
    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.str(key).unwrap_or(default)
    }
}

fn strip_comment(line: &str) -> &str {
    // `#` starts a comment unless inside a quoted string. `\"` inside
    // a string is an escaped quote, not a closing delimiter (and `\\`
    // does not escape the quote that follows it).
    let mut in_str = false;
    let mut escaped = false;
    for (i, c) in line.char_indices() {
        if in_str {
            if escaped {
                escaped = false;
            } else if c == '\\' {
                escaped = true;
            } else if c == '"' {
                in_str = false;
            }
        } else {
            match c {
                '"' => in_str = true,
                '#' => return &line[..i],
                _ => {}
            }
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value> {
    let s = s.trim();
    if s.is_empty() {
        bail!("empty value");
    }
    if let Some(inner) = s.strip_prefix('"') {
        // Unescape `\"`, `\\`, `\n`, `\t`; the closing quote must end
        // the value (no trailing garbage).
        let mut out = String::new();
        let mut escaped = false;
        let mut close = None;
        for (i, c) in inner.char_indices() {
            if escaped {
                match c {
                    '"' => out.push('"'),
                    '\\' => out.push('\\'),
                    'n' => out.push('\n'),
                    't' => out.push('\t'),
                    other => bail!("unknown escape `\\{other}` in string: {s}"),
                }
                escaped = false;
            } else if c == '\\' {
                escaped = true;
            } else if c == '"' {
                close = Some(i);
                break;
            } else {
                out.push(c);
            }
        }
        let Some(close) = close else {
            bail!("unterminated string: {s}");
        };
        if !inner[close + 1..].trim().is_empty() {
            bail!("trailing characters after string: {s}");
        }
        return Ok(Value::Str(out));
    }
    if s.starts_with('[') {
        if !s.ends_with(']') {
            bail!("unterminated array: {s}");
        }
        let inner = &s[1..s.len() - 1];
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            let p = part.trim();
            if !p.is_empty() {
                items.push(parse_value(p)?);
            }
        }
        return Ok(Value::Array(items));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("cannot parse value: `{s}`")
}

/// Split on commas that are not inside nested brackets or strings
/// (respecting `\"` escapes inside strings).
fn split_top_level(s: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut escaped = false;
    let mut cur = String::new();
    for c in s.chars() {
        if in_str {
            if escaped {
                escaped = false;
            } else if c == '\\' {
                escaped = true;
            } else if c == '"' {
                in_str = false;
            }
            cur.push(c);
            continue;
        }
        match c {
            '"' => {
                in_str = true;
                cur.push(c);
            }
            '[' => {
                depth += 1;
                cur.push(c);
            }
            ']' => {
                depth = depth.saturating_sub(1);
                cur.push(c);
            }
            ',' if depth == 0 => {
                out.push(std::mem::take(&mut cur));
            }
            _ => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        out.push(cur);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# top-level
name = "grail"      # trailing comment
threads = 4

[model]
kind = "tinylm"
layers = 4
dropout = 0.0
gqa = true
ratios = [0.1, 0.2, 0.5]
tags = ["a", "b"]

[model.attn]
heads = 8
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.str("name").unwrap(), "grail");
        assert_eq!(c.usize("threads").unwrap(), 4);
        assert_eq!(c.str("model.kind").unwrap(), "tinylm");
        assert_eq!(c.usize("model.layers").unwrap(), 4);
        assert_eq!(c.f64("model.dropout").unwrap(), 0.0);
        assert!(c.bool("model.gqa").unwrap());
        assert_eq!(c.f64_array("model.ratios").unwrap(), vec![0.1, 0.2, 0.5]);
        assert_eq!(c.str_array("model.tags").unwrap(), vec!["a", "b"]);
        assert_eq!(c.usize("model.attn.heads").unwrap(), 8);
    }

    #[test]
    fn int_coerces_to_float() {
        let c = Config::parse("x = 3").unwrap();
        assert_eq!(c.f64("x").unwrap(), 3.0);
    }

    #[test]
    fn missing_and_wrong_type_errors() {
        let c = Config::parse("x = 3").unwrap();
        assert!(c.str("x").is_err());
        assert!(c.usize("nope").is_err());
        assert_eq!(c.usize_or("nope", 7), 7);
    }

    #[test]
    fn comment_inside_string_kept() {
        let c = Config::parse(r##"s = "a # b""##).unwrap();
        assert_eq!(c.str("s").unwrap(), "a # b");
    }

    #[test]
    fn malformed_lines_error() {
        assert!(Config::parse("[unterminated").is_err());
        assert!(Config::parse("novalue").is_err());
        assert!(Config::parse("x = ").is_err());
        assert!(Config::parse("x = [1, 2").is_err());
        assert!(Config::parse("x = \"abc").is_err());
    }

    #[test]
    fn escaped_quotes_in_strings() {
        let c = Config::parse(r#"s = "a \"quoted\" part""#).unwrap();
        assert_eq!(c.str("s").unwrap(), r#"a "quoted" part"#);
        // Escaped backslash does not re-open the escape.
        let c = Config::parse(r#"s = "tail\\""#).unwrap();
        assert_eq!(c.str("s").unwrap(), r"tail\");
        // \n and \t unescape.
        let c = Config::parse(r#"s = "a\nb\tc""#).unwrap();
        assert_eq!(c.str("s").unwrap(), "a\nb\tc");
        // A `#` after an escaped quote is still inside the string …
        let c = Config::parse(r#"s = "x \" # y"  # real comment"#).unwrap();
        assert_eq!(c.str("s").unwrap(), r#"x " # y"#);
        // … and arrays carry escapes through element splitting.
        let c = Config::parse(r#"xs = ["a\"b", "c,d"]"#).unwrap();
        assert_eq!(c.str_array("xs").unwrap(), vec![r#"a"b"#, "c,d"]);
    }

    #[test]
    fn malformed_escapes_error() {
        // Regression: `"abc\"` used to parse as the string `abc\` —
        // the escaped quote must not terminate the value.
        assert!(Config::parse(r#"x = "abc\""#).is_err());
        // Unknown escape.
        assert!(Config::parse(r#"x = "a\qb""#).is_err());
        // Trailing garbage after the closing quote.
        assert!(Config::parse(r#"x = "a" b"#).is_err());
        // Dangling backslash at end of value.
        assert!(Config::parse(r#"x = "a\"#).is_err());
    }

    #[test]
    fn override_set() {
        let mut c = Config::parse("a = 1").unwrap();
        c.set("a", Value::Int(9));
        assert_eq!(c.usize("a").unwrap(), 9);
    }
}
