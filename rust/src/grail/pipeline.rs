//! The sequential closed-loop compression pipeline (paper §3.2: "re-
//! evaluating the Gram matrix for each layer based on the output of the
//! already-pruned previous layers").
//!
//! For every site in forward order: run the calibration batch through
//! the *current* (partially compressed) model, accumulate consumer-
//! input statistics, build the reduction (selector / folding /
//! baseline), optionally attach the GRAIL reconstruction map, apply.

use crate::compress::baselines::{baseline_plan, Baseline};
use crate::compress::heads::validate_head_reducer;
use crate::compress::select::{self, ScoreInputs, Selector};
use crate::compress::{fold, Compressible, ReductionPlan, SiteKind};
use crate::rng::Pcg64;
use std::time::Instant;

/// How each site's reduction is chosen.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// Structured pruning with a criterion from [`Selector`].
    Prune(Selector),
    /// k-means folding over producer features.
    Fold,
    /// Random folding (fig. 6).
    RandomFold,
    /// A baseline with its own recovery mechanism (Tables 1–2).
    Baseline(Baseline),
}

impl Method {
    /// Stable display name.
    pub fn name(&self) -> String {
        match self {
            Method::Prune(s) => s.name().to_string(),
            Method::Fold => "fold".to_string(),
            Method::RandomFold => "random-fold".to_string(),
            Method::Baseline(b) => b.name().to_string(),
        }
    }

    /// Parse a CLI/config name.
    pub fn from_name(s: &str) -> Option<Method> {
        if s == "fold" {
            return Some(Method::Fold);
        }
        if s == "random-fold" {
            return Some(Method::RandomFold);
        }
        // Baselines win name clashes ("wanda" is both a selector and a
        // baseline with identical behaviour when uncompensated).
        if let Some(b) = Baseline::from_name(s) {
            return Some(Method::Baseline(b));
        }
        Selector::from_name(s).map(Method::Prune)
    }
}

/// Pipeline configuration.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    pub method: Method,
    /// Fraction of units removed per site (layer-wise uniform
    /// compression ratio, 0.0–1.0).
    pub ratio: f64,
    /// Apply the GRAIL compensation map.
    pub grail: bool,
    /// Ridge scale α (λ = α · mean diag(G_PP)).
    pub alpha: f32,
    pub seed: u64,
    /// Sequential closed-loop calibration (paper §3.2: re-evaluate the
    /// Gram on the already-compressed prefix). `false` = open loop:
    /// all statistics come from the dense model — the ablation that
    /// shows why the closed loop matters.
    pub closed_loop: bool,
}

impl PipelineConfig {
    /// A pipeline with sensible defaults.
    pub fn new(method: Method, ratio: f64, grail: bool) -> Self {
        PipelineConfig {
            method,
            ratio,
            grail,
            alpha: super::DEFAULT_ALPHA,
            seed: 0,
            closed_loop: true,
        }
    }
}

/// Outcome of one site's reduction.
#[derive(Clone, Debug)]
pub struct SiteOutcome {
    pub id: String,
    pub units_before: usize,
    pub units_after: usize,
    /// Relative consumer-input reconstruction error of the applied map.
    pub recon_err: f32,
}

/// Outcome of a full pipeline run.
#[derive(Clone, Debug)]
pub struct Report {
    pub sites: Vec<SiteOutcome>,
    /// Seconds spent in calibration forwards + statistics.
    pub calib_seconds: f64,
    /// Seconds spent building/applying compensations.
    pub comp_seconds: f64,
}

impl Report {
    /// Mean relative reconstruction error across sites.
    pub fn mean_recon_err(&self) -> f32 {
        if self.sites.is_empty() {
            return 0.0;
        }
        self.sites.iter().map(|s| s.recon_err).sum::<f32>() / self.sites.len() as f32
    }
}

/// Units kept for a site of `units` units in `groups` groups at
/// removal `ratio` — always ≥1 per group and a multiple of `groups`.
pub fn uniform_keep(units: usize, groups: usize, ratio: f64) -> usize {
    let g = groups.max(1);
    let per_group = units / g;
    let keep_pg = ((per_group as f64) * (1.0 - ratio)).round() as usize;
    keep_pg.clamp(1, per_group) * g
}

/// Run the closed-loop pipeline over every site of `model`.
pub fn compress_model<M: Compressible>(
    model: &mut M,
    calib: &M::Input,
    cfg: &PipelineConfig,
) -> Report {
    let n_sites = model.sites().len();
    let mut rng = Pcg64::seed_stream(cfg.seed, 0x6121);
    let mut outcomes = Vec::with_capacity(n_sites);
    let mut calib_seconds = 0.0;
    let mut comp_seconds = 0.0;

    // Open-loop ablation: freeze all activations from the dense model
    // up front (error propagation becomes visible at depth).
    let dense_acts: Vec<crate::tensor::Tensor> = if cfg.closed_loop {
        Vec::new()
    } else {
        let t0 = Instant::now();
        let acts = (0..n_sites).map(|si| model.site_activations(calib, si)).collect();
        calib_seconds += t0.elapsed().as_secs_f64();
        acts
    };

    for si in 0..n_sites {
        let info = &model.sites()[si];
        let keep = uniform_keep(info.units, info.groups, cfg.ratio);
        if keep >= info.units {
            outcomes.push(SiteOutcome {
                id: info.id.clone(),
                units_before: info.units,
                units_after: info.units,
                recon_err: 0.0,
            });
            continue;
        }

        // --- calibration: consumer-input statistics on the current
        // (closed loop) or dense (open loop) model.
        let t0 = Instant::now();
        let acts = if cfg.closed_loop {
            model.site_activations(calib, si)
        } else {
            dense_acts[si].clone()
        };
        let stats = super::ActStats::from_acts(&acts);
        calib_seconds += t0.elapsed().as_secs_f64();

        let t1 = Instant::now();
        let l1 = model.producer_row_norm(si, 1);
        let l2 = model.producer_row_norm(si, 2);
        let consumer = model.consumer_matrix(si);
        let gd = select::gram_diag(&stats.gram);
        let consumer_cols = crate::tensor::ops::col_l2(&consumer);

        // --- choose the reduction
        let mut plan: ReductionPlan = match cfg.method {
            Method::Prune(sel) => {
                let inputs = ScoreInputs {
                    site: info,
                    producer_l1: &l1,
                    producer_l2: &l2,
                    gram_diag: &gd,
                    consumer_cols: &consumer_cols,
                };
                ReductionPlan::bare(select::select_reducer(sel, &inputs, keep, &mut rng))
            }
            Method::Fold => {
                let feats = model.producer_features(si);
                ReductionPlan::bare(fold::fold_reducer(&feats, info, keep, &mut rng))
            }
            Method::RandomFold => ReductionPlan::bare(fold::random_fold(info, keep, &mut rng)),
            Method::Baseline(b) => {
                baseline_plan(b, info, &stats, &l1, &l2, &consumer, keep, &mut rng)
            }
        };

        // --- optional GRAIL compensation: keep the selection, replace
        // the weight-space update with the closed-form reconstruction.
        if cfg.grail {
            let b = super::reconstruction(&stats.gram, &plan.reducer, info.unit_dim, cfg.alpha);
            plan.compensation = Some(b);
            plan.consumer_override = None;
            // The ridge solution on uncentered moments already carries
            // the removed features' conditional mean; a separate bias
            // shift would double-count it.
            plan.bias_delta = None;
        }

        if info.kind == SiteKind::AttnHeads {
            validate_head_reducer(&plan.reducer, info).expect("invalid head reducer");
        }

        // --- diagnostics + apply
        let eff_map = if let Some(b) = &plan.compensation {
            b.clone()
        } else {
            plan.reducer.lift(info.unit_dim).consumer_matrix(info.feat_width())
        };
        let recon_err =
            super::reconstruction_error(&acts, &plan.reducer, info.unit_dim, &eff_map);
        model.apply(si, &plan);
        comp_seconds += t1.elapsed().as_secs_f64();

        outcomes.push(SiteOutcome {
            id: info.id.clone(),
            units_before: info.units,
            units_after: keep,
            recon_err,
        });
    }
    Report { sites: outcomes, calib_seconds, comp_seconds }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthVision;
    use crate::nn::models::MlpNet;
    use crate::rng::Pcg64;

    #[test]
    fn uniform_keep_bounds() {
        assert_eq!(uniform_keep(100, 1, 0.5), 50);
        assert_eq!(uniform_keep(100, 1, 0.99), 1);
        assert_eq!(uniform_keep(100, 1, 0.0), 100);
        // Grouped: 8 units, 4 groups, ratio 0.5 -> 1 per group.
        assert_eq!(uniform_keep(8, 4, 0.5), 4);
        // Never below one per group.
        assert_eq!(uniform_keep(8, 4, 0.95), 4);
    }

    fn trained_ish_mlp() -> (MlpNet, crate::tensor::Tensor) {
        // A random MLP on SynthVision inputs; the statistics are real
        // even if the model is untrained.
        let mut rng = Pcg64::seed(77);
        let m = MlpNet::init(768, 32, 10, &mut rng);
        let x = SynthVision::new(3).generate(64).x;
        (m, x)
    }

    #[test]
    fn grail_reduces_output_distortion_vs_bare() {
        let (m0, x) = trained_ish_mlp();
        let y_ref = m0.forward(&x);
        let run = |grail: bool| {
            let mut m = m0.clone();
            let cfg = PipelineConfig::new(Method::Prune(Selector::MagnitudeL2), 0.5, grail);
            let rep = compress_model(&mut m, &x, &cfg);
            assert_eq!(rep.sites.len(), 2);
            let mut d = m.forward(&x);
            crate::tensor::ops::axpy(&mut d, -1.0, &y_ref);
            d.frobenius()
        };
        let bare = run(false);
        let grail = run(true);
        assert!(
            grail < bare,
            "GRAIL must reduce output distortion: grail={grail} bare={bare}"
        );
    }

    #[test]
    fn fold_pipeline_runs_and_reports() {
        let (mut m, x) = trained_ish_mlp();
        let cfg = PipelineConfig::new(Method::Fold, 0.4, true);
        let rep = compress_model(&mut m, &x, &cfg);
        assert_eq!(rep.sites.len(), 2);
        for s in &rep.sites {
            assert_eq!(s.units_before, 32);
            assert_eq!(s.units_after, 19);
            assert!(s.recon_err.is_finite());
        }
        assert!(m.forward(&x).all_finite());
        assert!(rep.calib_seconds >= 0.0 && rep.comp_seconds >= 0.0);
    }

    #[test]
    fn ratio_zero_is_identity() {
        let (m0, x) = trained_ish_mlp();
        let mut m = m0.clone();
        let cfg = PipelineConfig::new(Method::Prune(Selector::Wanda), 0.0, true);
        let rep = compress_model(&mut m, &x, &cfg);
        assert!(rep.sites.iter().all(|s| s.units_after == s.units_before));
        assert!(m0.forward(&x).max_abs_diff(&m.forward(&x)) < 1e-6);
    }

    #[test]
    fn deterministic_given_seed() {
        let (m0, x) = trained_ish_mlp();
        let run = || {
            let mut m = m0.clone();
            let cfg = PipelineConfig::new(Method::RandomFold, 0.5, true);
            compress_model(&mut m, &x, &cfg);
            m.forward(&x)
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn method_names_roundtrip() {
        for name in ["mag-l1", "mag-l2", "fold", "random-fold", "wanda", "ziplm", "flap"] {
            let m = Method::from_name(name).unwrap();
            // wanda maps to the baseline spelling of the same name.
            assert_eq!(Method::from_name(&m.name()).unwrap(), m);
        }
        assert!(Method::from_name("nope").is_none());
    }
}
