//! The sequential closed-loop compression pipeline (paper §3.2: "re-
//! evaluating the Gram matrix for each layer based on the output of the
//! already-pruned previous layers").
//!
//! Execution is the last stage of the Spec → Plan → Execute API
//! ([`super::spec`]): a [`CompressionPlan`] carries one concrete
//! [`SitePolicy`](super::spec::SitePolicy) and keep count per site, and
//! [`execute_plan`] walks the sites in forward order: obtain the
//! consumer-input statistics on the *current* (partially compressed)
//! model, build the reduction (selector / folding / baseline) under
//! that site's policy, optionally attach the GRAIL reconstruction map,
//! apply. [`compress_model`] is the one-call convenience that resolves
//! a [`CompressionSpec`] against the model and executes the plan.
//!
//! Calibration is *staged*: the input is split into shards
//! ([`Compressible::split_input`]), each shard carries a
//! [`Compressible::CalibState`] cached at the current site's boundary,
//! and after every `apply` the states advance one segment
//! ([`Compressible::forward_segment`]) — O(L) total layer forwards for
//! the whole loop instead of the O(L²) of re-running the network per
//! site. Shard taps are folded into [`super::ActStats`] immediately
//! (bounded peak memory; no `[all_rows, h]` materialization) and shards
//! execute on scoped worker threads, which parallelizes both the
//! calibration forwards and the `syrk_upper_acc` Gram accumulation.
//! Statistics merge in shard order, so results are deterministic
//! regardless of thread scheduling.
//!
//! [`compress_model_rescan`] / [`execute_plan_rescan`] keep the
//! pre-staging O(L²) strategy (rebuild every state from scratch at
//! every site) as a reference implementation: they produce
//! bit-identical `Report::sites`, which the equivalence tests and
//! `benches/hotpath.rs` rely on.

use super::spec::{BudgetMode, CompressionPlan, CompressionSpec};
use crate::compress::baselines::{baseline_plan, Baseline};
use crate::compress::heads::validate_head_reducer;
use crate::compress::select::{self, ScoreInputs, Selector};
use crate::compress::{fold, Compressible, ReductionPlan, SiteKind};
use crate::coordinator::scheduler::{default_threads, run_grid, run_grid_mut};
use crate::rng::Pcg64;
use std::time::Instant;

/// How each site's reduction is chosen.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// Structured pruning with a criterion from [`Selector`].
    Prune(Selector),
    /// k-means folding over producer features.
    Fold,
    /// Random folding (fig. 6).
    RandomFold,
    /// A baseline with its own recovery mechanism (Tables 1–2).
    Baseline(Baseline),
}

impl Method {
    /// Stable display name. `from_name` ∘ `name` is the identity for
    /// every constructible `Method` (see `method_names_roundtrip`).
    pub fn name(&self) -> String {
        match self {
            // Bare "wanda" parses to the baseline of the same name, so
            // the selector spelling needs its explicit prefix to
            // round-trip.
            Method::Prune(Selector::Wanda) => "prune-wanda".to_string(),
            Method::Prune(s) => s.name().to_string(),
            Method::Fold => "fold".to_string(),
            Method::RandomFold => "random-fold".to_string(),
            Method::Baseline(b) => b.name().to_string(),
        }
    }

    /// Parse a CLI/config name.
    pub fn from_name(s: &str) -> Option<Method> {
        if s == "fold" {
            return Some(Method::Fold);
        }
        if s == "random-fold" {
            return Some(Method::RandomFold);
        }
        // `prune-<selector>` forces the selector spelling — the only
        // way to reach `Prune(Selector::Wanda)`, whose bare name is
        // shadowed by the baseline below.
        if let Some(rest) = s.strip_prefix("prune-") {
            return Selector::from_name(rest).map(Method::Prune);
        }
        // Baselines win name clashes ("wanda" is both a selector and a
        // baseline with identical behaviour when uncompensated).
        if let Some(b) = Baseline::from_name(s) {
            return Some(Method::Baseline(b));
        }
        Selector::from_name(s).map(Method::Prune)
    }

    /// Every constructible method (round-trip tests and `grail help`).
    pub fn all() -> Vec<Method> {
        let mut out: Vec<Method> = [
            Selector::MagnitudeL1,
            Selector::MagnitudeL2,
            Selector::Wanda,
            Selector::GramDiag,
            Selector::Random,
        ]
        .into_iter()
        .map(Method::Prune)
        .collect();
        out.push(Method::Fold);
        out.push(Method::RandomFold);
        out.extend(
            [
                Baseline::Wanda,
                Baseline::WandaPP,
                Baseline::SlimGPT,
                Baseline::ZipLM,
                Baseline::Flap,
            ]
            .into_iter()
            .map(Method::Baseline),
        );
        out
    }
}

/// Outcome of one site's reduction.
#[derive(Clone, Debug)]
pub struct SiteOutcome {
    pub id: String,
    pub units_before: usize,
    pub units_after: usize,
    /// Relative consumer-input reconstruction error of the applied map.
    pub recon_err: f32,
    /// Provenance: the method the plan assigned to this site.
    pub method: String,
    /// Provenance: the removal ratio the plan resolved for this site.
    pub ratio: f64,
    /// Provenance: whether GRAIL compensation was applied here.
    pub grail: bool,
}

/// Outcome of a full pipeline run.
#[derive(Clone, Debug)]
pub struct Report {
    pub sites: Vec<SiteOutcome>,
    /// Seconds spent in calibration forwards + statistics.
    pub calib_seconds: f64,
    /// Seconds spent building/applying compensations.
    pub comp_seconds: f64,
    /// Scalar parameter count of the model before compression.
    pub params_before: usize,
    /// Scalar parameter count after compression.
    pub params_after: usize,
    /// Statistics-cache entry hits accounted to this run on the calling
    /// thread ([`crate::serve::provider`]); 0 without an installed
    /// cache context or on the closed loop (which never caches).
    pub cache_hits: u64,
    /// Statistics-cache entry misses accounted to this run.
    pub cache_misses: u64,
}

impl Report {
    /// Mean relative reconstruction error across sites.
    pub fn mean_recon_err(&self) -> f32 {
        if self.sites.is_empty() {
            return 0.0;
        }
        self.sites.iter().map(|s| s.recon_err).sum::<f32>() / self.sites.len() as f32
    }

    /// Overall fraction of parameters removed.
    pub fn compression_ratio(&self) -> f64 {
        if self.params_before == 0 {
            return 0.0;
        }
        1.0 - self.params_after as f64 / self.params_before as f64
    }

    /// One-line parameter summary for CLI output.
    pub fn summary(&self) -> String {
        format!(
            "params {} -> {} ({:.1}% removed)",
            self.params_before,
            self.params_after,
            100.0 * self.compression_ratio()
        )
    }
}

/// Default calibration shard count when [`CompressionSpec::shards`] is
/// 0. Deliberately a fixed constant — never derived from detected core
/// count — so float summation order, and therefore compressed-model
/// numerics, are identical across machines (the repo's bitwise
/// reproducibility contract). Worker threads may still vary freely:
/// partial statistics merge in shard-index order regardless of
/// scheduling.
pub const DEFAULT_SHARDS: usize = 16;

/// Units kept for a site of `units` units in `groups` groups at
/// removal `ratio` — always ≥1 per group and, for divisible grouped
/// sites, a multiple of `groups`. When `units` is not a multiple of
/// `groups` the per-group arithmetic would silently truncate (e.g.
/// `ratio = 0.0` dropping units), so such sites fall back to ungrouped
/// rounding on the total.
pub fn uniform_keep(units: usize, groups: usize, ratio: f64) -> usize {
    let g = groups.max(1);
    if units % g != 0 {
        let keep = ((units as f64) * (1.0 - ratio)).round() as usize;
        return keep.clamp(1, units);
    }
    let per_group = units / g;
    let keep_pg = ((per_group as f64) * (1.0 - ratio)).round() as usize;
    keep_pg.clamp(1, per_group) * g
}

/// Which calibration strategy drives the closed loop.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Engine {
    /// Staged segment execution: persistent per-shard boundary states,
    /// O(L) total layer forwards.
    Staged,
    /// Reference strategy: rebuild every state from scratch at every
    /// site, O(L²) layer forwards. Same statistics, bit-identical
    /// outcomes.
    Rescan,
}

/// Resolve `spec` against the model and run the staged O(L) pipeline.
///
/// Panics on an unresolvable spec (e.g. inconsistent rule set); callers
/// that need recoverable errors resolve explicitly via
/// [`plan_for_model`] and run [`execute_plan`].
pub fn compress_model<M>(model: &mut M, calib: &M::Input, spec: &CompressionSpec) -> Report
where
    M: Compressible + Sync,
    M::Input: Sync,
    M::CalibState: Send,
{
    let plan = plan_for_model(&*model, calib, spec).expect("unresolvable compression spec");
    run_pipeline(model, calib, &plan, Engine::Staged)
}

/// Reference pipeline: identical statistics and outcomes, but every
/// site re-executes the full prefix (O(L²) layer forwards). Kept for
/// equivalence tests and the `benches/hotpath.rs` before/after
/// comparison.
pub fn compress_model_rescan<M>(model: &mut M, calib: &M::Input, spec: &CompressionSpec) -> Report
where
    M: Compressible + Sync,
    M::Input: Sync,
    M::CalibState: Send,
{
    let plan = plan_for_model(&*model, calib, spec).expect("unresolvable compression spec");
    run_pipeline(model, calib, &plan, Engine::Rescan)
}

/// Resolve a spec into a concrete per-site plan for `model` without
/// mutating anything. Budget allocators that need activation statistics
/// (Gram-diagonal sensitivity) run one streamed open-loop pass over the
/// dense model here, and the `search` budget mode runs the full
/// calibration-driven α/keep search
/// ([`search_plan`](super::search::search_plan)) — which derives a
/// gram-sensitivity *seed* allocation from its own statistics pass when
/// `budget.seed = "gram-sensitivity"`, so composing the two allocators
/// still costs exactly one pass (asserted via the layer-forward counter
/// in `rust/tests/forward_count.rs`). All other specs resolve from site
/// metadata alone. (Known duplication: statistics-driven budgets
/// combined with `closed_loop = false` pay a second dense pass inside
/// [`execute_plan`] for the open-loop statistics — keeping plan
/// resolution side-effect free is worth the extra O(L) forwards.)
pub fn plan_for_model<M>(
    model: &M,
    calib: &M::Input,
    spec: &CompressionSpec,
) -> anyhow::Result<CompressionPlan>
where
    M: Compressible + Sync,
    M::Input: Sync,
    M::CalibState: Send,
{
    if matches!(spec.budget, BudgetMode::Search { .. }) {
        return Ok(super::search::search_plan(model, calib, spec)?.plan);
    }
    let sites = model.sites();
    let sens = if spec.needs_sensitivity() {
        Some(site_sensitivities(model, calib, spec.shards, spec.workers))
    } else {
        None
    };
    spec.resolve(&sites, sens.as_deref())
}

/// One streamed open-loop pass over the dense model: per-shard
/// [`super::ActStats`] for every site, in shard order. Shared by the
/// open-loop engine, the Gram-sensitivity allocator, and the plan
/// search's train/held-out scoring ([`super::search`]); callers merge
/// the per-shard partials in shard order, which keeps the result
/// independent of the worker count.
///
/// This is the cache choke point: when the calling thread has a
/// [`StatsContext`](crate::serve::provider::StatsContext) installed
/// ([`crate::serve::provider::install`]), a fully cached pass is
/// served verbatim from disk — the stored bytes are the un-finalized
/// accumulators a cold pass produces, so warm results are
/// bit-identical by construction — and a cold pass is stored on the
/// way out. Only the *caller's* thread is consulted; the `run_grid`
/// shard workers below never touch the provider.
pub(crate) fn per_shard_site_stats<M>(
    model: &M,
    shard_inputs: &[M::Input],
    workers: usize,
) -> Vec<Vec<super::ActStats>>
where
    M: Compressible + Sync,
    M::Input: Sync,
    M::CalibState: Send,
{
    let sites = model.sites();
    let widths: Vec<usize> = sites.iter().map(|s| s.feat_width()).collect();
    if let Some(ctx) = crate::serve::provider::active() {
        let ids: Vec<&str> = sites.iter().map(|s| s.id.as_str()).collect();
        if let Some(cached) = ctx.load_pass(&ids, &widths, shard_inputs.len()) {
            return cached;
        }
        let computed = compute_per_shard_site_stats(model, shard_inputs, workers, &widths);
        ctx.store_pass(&ids, &computed);
        return computed;
    }
    compute_per_shard_site_stats(model, shard_inputs, workers, &widths)
}

/// The actual streamed pass behind [`per_shard_site_stats`] (cache
/// misses and uncached callers).
fn compute_per_shard_site_stats<M>(
    model: &M,
    shard_inputs: &[M::Input],
    workers: usize,
    widths: &[usize],
) -> Vec<Vec<super::ActStats>>
where
    M: Compressible + Sync,
    M::Input: Sync,
    M::CalibState: Send,
{
    run_grid(shard_inputs.iter().collect(), workers, |_, inp| {
        let mut st = model.calib_begin(inp);
        let mut local: Vec<super::ActStats> =
            widths.iter().map(|&w| super::ActStats::new(w)).collect();
        for si in 0..widths.len() {
            let tap = model.site_tap(&mut st, si);
            local[si].update(&tap);
            if si + 1 < widths.len() {
                model.forward_segment(&mut st, si, si + 1);
            }
        }
        local
    })
}

/// Per-site mean activation energy (mean Gram diagonal) on the *dense*
/// model — the signal behind the Gram-diagonal-sensitivity budget
/// allocator. One streamed O(L) pass; partial sums merge in shard
/// order, so the result is independent of worker count.
///
/// Derived from [`per_shard_site_stats`] — `tr(G) = Σ x²` on the
/// un-finalized accumulators — rather than a bespoke tap-squared pass,
/// so sensitivity-budget plans are served from the statistics cache
/// exactly like the open-loop engine and the plan search (the Gram
/// accumulates in f32, so values differ from a direct f64 sum in the
/// last few bits; the allocator consumes only their ratios).
pub fn site_sensitivities<M>(
    model: &M,
    calib: &M::Input,
    shards: usize,
    workers: usize,
) -> Vec<f64>
where
    M: Compressible + Sync,
    M::Input: Sync,
    M::CalibState: Send,
{
    let sites = model.sites();
    let n_sites = sites.len();
    let widths: Vec<usize> = sites.iter().map(|s| s.feat_width()).collect();
    let workers = if workers != 0 { workers } else { default_threads() };
    let shard_target = if shards != 0 { shards } else { DEFAULT_SHARDS };
    let shard_inputs: Vec<M::Input> = model.split_input(calib, shard_target);
    let per_shard = per_shard_site_stats(model, &shard_inputs, workers);
    (0..n_sites)
        .map(|si| {
            let mut sq = 0.0f64;
            let mut rows = 0usize;
            for shard in &per_shard {
                sq += super::gram_trace(&shard[si].gram);
                rows += shard[si].rows;
            }
            sq / ((rows.max(1) * widths[si].max(1)) as f64)
        })
        .collect()
}

/// Execute a resolved plan with the staged O(L) engine.
pub fn execute_plan<M>(model: &mut M, calib: &M::Input, plan: &CompressionPlan) -> Report
where
    M: Compressible + Sync,
    M::Input: Sync,
    M::CalibState: Send,
{
    run_pipeline(model, calib, plan, Engine::Staged)
}

/// Execute a resolved plan with the O(L²) rescan reference engine.
pub fn execute_plan_rescan<M>(model: &mut M, calib: &M::Input, plan: &CompressionPlan) -> Report
where
    M: Compressible + Sync,
    M::Input: Sync,
    M::CalibState: Send,
{
    run_pipeline(model, calib, plan, Engine::Rescan)
}

fn run_pipeline<M>(
    model: &mut M,
    calib: &M::Input,
    plan: &CompressionPlan,
    engine: Engine,
) -> Report
where
    M: Compressible + Sync,
    M::Input: Sync,
    M::CalibState: Send,
{
    let n_sites = model.sites().len();
    assert_eq!(
        plan.sites.len(),
        n_sites,
        "plan has {} sites but the model exposes {n_sites} — resolve the plan against this model",
        plan.sites.len()
    );
    let params_before = model.param_count();
    let (tally_hits0, tally_misses0) = crate::serve::provider::tally();
    let mut rng = Pcg64::seed_stream(plan.seed, 0x6121);
    let mut outcomes = Vec::with_capacity(n_sites);
    let mut calib_seconds = 0.0f64;
    let mut comp_seconds = 0.0f64;
    let workers = if plan.workers != 0 { plan.workers } else { default_threads() };
    let shard_target = if plan.shards != 0 { plan.shards } else { DEFAULT_SHARDS };

    let t_init = Instant::now();
    let shard_inputs: Vec<M::Input> = model.split_input(calib, shard_target);

    // Open-loop ablation: one streamed pass over the dense model
    // accumulates every site's statistics up front (error propagation
    // becomes visible at depth). Peak memory is one tap per in-flight
    // shard plus `shards × Σ h²` partial Gram accumulators — bounded
    // by the fixed shard count, and merged strictly in shard order so
    // the result is independent of worker count.
    let open_stats: Vec<super::ActStats> = if plan.closed_loop {
        Vec::new()
    } else {
        let widths: Vec<usize> = model.sites().iter().map(|s| s.feat_width()).collect();
        let per_shard = per_shard_site_stats(&*model, &shard_inputs, workers);
        (0..widths.len())
            .map(|si| {
                let mut s = super::ActStats::new(widths[si]);
                for shard in &per_shard {
                    s.merge(&shard[si]);
                }
                s.finalize();
                s
            })
            .collect()
    };

    // Staged closed loop: per-shard boundary states at site 0.
    let mut states: Vec<M::CalibState> = if plan.closed_loop && engine == Engine::Staged {
        let mref: &M = &*model;
        run_grid(shard_inputs.iter().collect(), workers, |_, inp| mref.calib_begin(inp))
    } else {
        Vec::new()
    };
    calib_seconds += t_init.elapsed().as_secs_f64();

    for si in 0..n_sites {
        let info = model.sites()[si].clone();
        let site_plan = &plan.sites[si];
        assert_eq!(
            site_plan.id, info.id,
            "plan site {si} is `{}` but the model exposes `{}`",
            site_plan.id, info.id
        );
        let policy = &site_plan.policy;
        let keep = site_plan.keep.min(info.units);
        if keep >= info.units {
            outcomes.push(SiteOutcome {
                id: info.id.clone(),
                units_before: info.units,
                units_after: info.units,
                recon_err: 0.0,
                method: policy.method.name(),
                ratio: policy.ratio,
                grail: policy.grail,
            });
            // The boundary still has to move past the untouched site.
            if plan.closed_loop && engine == Engine::Staged && si + 1 < n_sites {
                let t = Instant::now();
                let mref: &M = &*model;
                run_grid_mut(&mut states, workers, |_, st| {
                    mref.forward_segment(st, si, si + 1);
                });
                calib_seconds += t.elapsed().as_secs_f64();
            }
            continue;
        }

        // --- calibration: stream shard taps into the statistics on
        // the current (closed loop) or dense (open loop) model.
        let tc = Instant::now();
        let width = info.feat_width();
        let stats = if !plan.closed_loop {
            open_stats[si].clone()
        } else {
            let mref: &M = &*model;
            let partials: Vec<super::ActStats> = match engine {
                Engine::Staged => run_grid_mut(&mut states, workers, |_, st| {
                    let tap = mref.site_tap(st, si);
                    let mut s = super::ActStats::new(width);
                    s.update(&tap);
                    s
                }),
                Engine::Rescan => {
                    run_grid(shard_inputs.iter().collect(), workers, |_, inp| {
                        let mut st = mref.calib_begin(inp);
                        mref.forward_segment(&mut st, 0, si);
                        let tap = mref.site_tap(&mut st, si);
                        let mut s = super::ActStats::new(width);
                        s.update(&tap);
                        s
                    })
                }
            };
            let mut stats = super::ActStats::new(width);
            for p in &partials {
                stats.merge(p);
            }
            stats.finalize();
            stats
        };
        calib_seconds += tc.elapsed().as_secs_f64();

        let t1 = Instant::now();
        let l1 = model.producer_row_norm(si, 1);
        let l2 = model.producer_row_norm(si, 2);
        let consumer = model.consumer_matrix(si);
        let gd = select::gram_diag(&stats.gram);
        let consumer_cols = crate::tensor::ops::col_l2(&consumer);

        // --- choose the reduction under this site's policy
        let mut red_plan: ReductionPlan = match policy.method {
            Method::Prune(sel) => {
                let inputs = ScoreInputs {
                    site: &info,
                    producer_l1: &l1,
                    producer_l2: &l2,
                    gram_diag: &gd,
                    consumer_cols: &consumer_cols,
                };
                ReductionPlan::bare(select::select_reducer(sel, &inputs, keep, &mut rng))
            }
            Method::Fold => {
                let feats = model.producer_features(si);
                ReductionPlan::bare(fold::fold_reducer(&feats, &info, keep, &mut rng))
            }
            Method::RandomFold => {
                ReductionPlan::bare(fold::random_fold(&info, keep, &mut rng))
            }
            // Solver fan-out gets `plan.workers` (0 = auto) rather
            // than the resolved count: auto keeps the solver's
            // small-system serial threshold, an explicit pin bounds it.
            Method::Baseline(b) => baseline_plan(
                b,
                &info,
                &stats,
                &l1,
                &l2,
                &consumer,
                keep,
                plan.workers,
                &mut rng,
            ),
        };

        // --- optional GRAIL compensation: keep the selection, replace
        // the weight-space update with the closed-form reconstruction.
        if policy.grail {
            let b = super::reconstruction_with(
                &stats.gram,
                &red_plan.reducer,
                info.unit_dim,
                policy.alpha,
                plan.workers,
            );
            red_plan.compensation = Some(b);
            red_plan.consumer_override = None;
            // The ridge solution on uncentered moments already carries
            // the removed features' conditional mean; a separate bias
            // shift would double-count it.
            red_plan.bias_delta = None;
        }

        if info.kind == SiteKind::AttnHeads {
            validate_head_reducer(&red_plan.reducer, &info).expect("invalid head reducer");
        }

        // --- diagnostics + apply. The reconstruction error comes from
        // the Gram matrix (tr-form), so no raw activations are kept.
        let eff_map = if let Some(b) = &red_plan.compensation {
            b.clone()
        } else {
            red_plan.reducer.lift(info.unit_dim).consumer_matrix(info.feat_width())
        };
        let recon_err = super::reconstruction_error_from_gram(
            &stats.gram,
            &red_plan.reducer,
            info.unit_dim,
            &eff_map,
        );
        model.apply(si, &red_plan);
        comp_seconds += t1.elapsed().as_secs_f64();

        // --- advance the boundary through the now-compressed site.
        if plan.closed_loop && engine == Engine::Staged && si + 1 < n_sites {
            let t = Instant::now();
            let mref: &M = &*model;
            run_grid_mut(&mut states, workers, |_, st| {
                mref.forward_segment(st, si, si + 1);
            });
            calib_seconds += t.elapsed().as_secs_f64();
        }

        outcomes.push(SiteOutcome {
            id: info.id.clone(),
            units_before: info.units,
            units_after: keep,
            recon_err,
            method: policy.method.name(),
            ratio: policy.ratio,
            grail: policy.grail,
        });
    }
    let (tally_hits1, tally_misses1) = crate::serve::provider::tally();
    Report {
        sites: outcomes,
        calib_seconds,
        comp_seconds,
        params_before,
        params_after: model.param_count(),
        cache_hits: tally_hits1 - tally_hits0,
        cache_misses: tally_misses1 - tally_misses0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthVision;
    use crate::nn::models::MlpNet;
    use crate::rng::Pcg64;

    #[test]
    fn uniform_keep_bounds() {
        assert_eq!(uniform_keep(100, 1, 0.5), 50);
        assert_eq!(uniform_keep(100, 1, 0.99), 1);
        assert_eq!(uniform_keep(100, 1, 0.0), 100);
        // Grouped: 8 units, 4 groups, ratio 0.5 -> 1 per group.
        assert_eq!(uniform_keep(8, 4, 0.5), 4);
        // Never below one per group.
        assert_eq!(uniform_keep(8, 4, 0.95), 4);
    }

    #[test]
    fn uniform_keep_non_divisible_groups() {
        // Regression: `units / groups` used to truncate, so ratio 0.0
        // silently dropped units (10 units / 3 groups kept only 9).
        assert_eq!(uniform_keep(10, 3, 0.0), 10);
        assert_eq!(uniform_keep(7, 2, 0.0), 7);
        assert_eq!(uniform_keep(10, 3, 0.5), 5);
        assert_eq!(uniform_keep(10, 3, 1.0), 1);
        // Divisible grouped behaviour unchanged.
        assert_eq!(uniform_keep(8, 4, 0.0), 8);
        assert_eq!(uniform_keep(8, 4, 0.5), 4);
    }

    fn trained_ish_mlp() -> (MlpNet, crate::tensor::Tensor) {
        // A random MLP on SynthVision inputs; the statistics are real
        // even if the model is untrained.
        let mut rng = Pcg64::seed(77);
        let m = MlpNet::init(768, 32, 10, &mut rng);
        let x = SynthVision::new(3).generate(64).x;
        (m, x)
    }

    #[test]
    fn grail_reduces_output_distortion_vs_bare() {
        let (m0, x) = trained_ish_mlp();
        let y_ref = m0.forward(&x);
        let run = |grail: bool| {
            let mut m = m0.clone();
            let cfg = CompressionSpec::uniform(Method::Prune(Selector::MagnitudeL2), 0.5, grail);
            let rep = compress_model(&mut m, &x, &cfg);
            assert_eq!(rep.sites.len(), 2);
            let mut d = m.forward(&x);
            crate::tensor::ops::axpy(&mut d, -1.0, &y_ref);
            d.frobenius()
        };
        let bare = run(false);
        let grail = run(true);
        assert!(
            grail < bare,
            "GRAIL must reduce output distortion: grail={grail} bare={bare}"
        );
    }

    #[test]
    fn fold_pipeline_runs_and_reports() {
        let (mut m, x) = trained_ish_mlp();
        let cfg = CompressionSpec::uniform(Method::Fold, 0.4, true);
        let rep = compress_model(&mut m, &x, &cfg);
        assert_eq!(rep.sites.len(), 2);
        for s in &rep.sites {
            assert_eq!(s.units_before, 32);
            assert_eq!(s.units_after, 19);
            assert!(s.recon_err.is_finite());
            assert_eq!(s.method, "fold");
            assert_eq!(s.ratio, 0.4);
            assert!(s.grail);
        }
        assert!(m.forward(&x).all_finite());
        assert!(rep.calib_seconds >= 0.0 && rep.comp_seconds >= 0.0);
        assert!(rep.params_after < rep.params_before);
    }

    #[test]
    fn report_pins_param_counts_for_known_mlp_spec() {
        // MlpNet::init(768, 32, 10): fc1 32×768+32, fc2 32×32+32,
        // head 10×32+10 = 25 994 params. Pruning both hidden sites to
        // 16 units: fc1 16×768+16, fc2 16×16+16, head 10×16+10.
        let (mut m, x) = trained_ish_mlp();
        let cfg = CompressionSpec::uniform(Method::Prune(Selector::MagnitudeL2), 0.5, true);
        let rep = compress_model(&mut m, &x, &cfg);
        assert_eq!(rep.params_before, 24_608 + 1_056 + 330);
        assert_eq!(rep.params_after, 12_304 + 272 + 170);
        assert!((rep.compression_ratio() - (1.0 - 12_746.0 / 25_994.0)).abs() < 1e-12);
        assert!(rep.summary().contains("25994 -> 12746"));
    }

    #[test]
    fn ratio_zero_is_identity() {
        let (m0, x) = trained_ish_mlp();
        let mut m = m0.clone();
        let cfg = CompressionSpec::uniform(Method::Prune(Selector::Wanda), 0.0, true);
        let rep = compress_model(&mut m, &x, &cfg);
        assert!(rep.sites.iter().all(|s| s.units_after == s.units_before));
        assert_eq!(rep.params_before, rep.params_after);
        assert!(m0.forward(&x).max_abs_diff(&m.forward(&x)) < 1e-6);
    }

    #[test]
    fn deterministic_given_seed() {
        let (m0, x) = trained_ish_mlp();
        let run = || {
            let mut m = m0.clone();
            let cfg = CompressionSpec::uniform(Method::RandomFold, 0.5, true);
            compress_model(&mut m, &x, &cfg);
            m.forward(&x)
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn shard_and_worker_counts_do_not_change_widths() {
        // Float summation order differs across shard counts, but the
        // structural outcome (selection sizes, finiteness) must not.
        let (m0, x) = trained_ish_mlp();
        for (shards, workers) in [(1usize, 1usize), (3, 2), (16, 4)] {
            let mut m = m0.clone();
            let mut cfg = CompressionSpec::uniform(Method::Prune(Selector::Wanda), 0.5, true);
            cfg.shards = shards;
            cfg.workers = workers;
            let rep = compress_model(&mut m, &x, &cfg);
            assert_eq!(rep.sites.len(), 2);
            assert!(rep.sites.iter().all(|s| s.units_after == 16));
            assert!(m.forward(&x).all_finite(), "shards={shards}");
        }
    }

    #[test]
    fn method_names_roundtrip() {
        // Regression (`Method::Prune(Selector::Wanda)` used to be
        // unreachable from names): `from_name` ∘ `name` must be the
        // identity for *every* constructible method.
        for m in Method::all() {
            assert_eq!(Method::from_name(&m.name()), Some(m), "{m:?} via `{}`", m.name());
        }
        // The selector spelling of the clash is reachable and distinct
        // from the baseline spelling.
        assert_eq!(
            Method::from_name("prune-wanda"),
            Some(Method::Prune(Selector::Wanda))
        );
        assert_eq!(
            Method::from_name("wanda"),
            Some(Method::Baseline(Baseline::Wanda))
        );
        // Prefix form works for every selector, not just the clash.
        assert_eq!(
            Method::from_name("prune-mag-l2"),
            Some(Method::Prune(Selector::MagnitudeL2))
        );
        assert!(Method::from_name("nope").is_none());
        assert!(Method::from_name("prune-nope").is_none());
    }

    #[test]
    fn sensitivities_reflect_activation_energy() {
        let (m, x) = trained_ish_mlp();
        let s = site_sensitivities(&m, &x, 4, 2);
        assert_eq!(s.len(), 2);
        assert!(s.iter().all(|&v| v.is_finite() && v >= 0.0));
        // At a fixed shard split the result is bit-identical at any
        // worker count (partials merge in shard order).
        let s_serial = site_sensitivities(&m, &x, 4, 1);
        assert_eq!(s, s_serial);
        // Across shard counts only the f32 Gram summation order moves.
        let s2 = site_sensitivities(&m, &x, 1, 1);
        for (a, b) in s.iter().zip(&s2) {
            assert!((a - b).abs() < 1e-4 * (1.0 + a.abs()), "{a} vs {b}");
        }
    }
}
