//! The sequential closed-loop compression pipeline (paper §3.2: "re-
//! evaluating the Gram matrix for each layer based on the output of the
//! already-pruned previous layers").
//!
//! For every site in forward order: obtain the consumer-input
//! statistics on the *current* (partially compressed) model, build the
//! reduction (selector / folding / baseline), optionally attach the
//! GRAIL reconstruction map, apply.
//!
//! Calibration is *staged*: the input is split into shards
//! ([`Compressible::split_input`]), each shard carries a
//! [`Compressible::CalibState`] cached at the current site's boundary,
//! and after every `apply` the states advance one segment
//! ([`Compressible::forward_segment`]) — O(L) total layer forwards for
//! the whole loop instead of the O(L²) of re-running the network per
//! site. Shard taps are folded into [`super::ActStats`] immediately
//! (bounded peak memory; no `[all_rows, h]` materialization) and shards
//! execute on scoped worker threads, which parallelizes both the
//! calibration forwards and the `syrk_upper_acc` Gram accumulation.
//! Statistics merge in shard order, so results are deterministic
//! regardless of thread scheduling.
//!
//! [`compress_model_rescan`] keeps the pre-staging O(L²) strategy
//! (rebuild every state from scratch at every site) as a reference
//! implementation: it produces bit-identical `Report::sites`, which the
//! equivalence tests and `benches/hotpath.rs` rely on.

use crate::compress::baselines::{baseline_plan, Baseline};
use crate::compress::heads::validate_head_reducer;
use crate::compress::select::{self, ScoreInputs, Selector};
use crate::compress::{fold, Compressible, ReductionPlan, SiteKind};
use crate::coordinator::scheduler::{default_threads, run_grid, run_grid_mut};
use crate::rng::Pcg64;
use std::time::Instant;

/// How each site's reduction is chosen.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// Structured pruning with a criterion from [`Selector`].
    Prune(Selector),
    /// k-means folding over producer features.
    Fold,
    /// Random folding (fig. 6).
    RandomFold,
    /// A baseline with its own recovery mechanism (Tables 1–2).
    Baseline(Baseline),
}

impl Method {
    /// Stable display name.
    pub fn name(&self) -> String {
        match self {
            Method::Prune(s) => s.name().to_string(),
            Method::Fold => "fold".to_string(),
            Method::RandomFold => "random-fold".to_string(),
            Method::Baseline(b) => b.name().to_string(),
        }
    }

    /// Parse a CLI/config name.
    pub fn from_name(s: &str) -> Option<Method> {
        if s == "fold" {
            return Some(Method::Fold);
        }
        if s == "random-fold" {
            return Some(Method::RandomFold);
        }
        // Baselines win name clashes ("wanda" is both a selector and a
        // baseline with identical behaviour when uncompensated).
        if let Some(b) = Baseline::from_name(s) {
            return Some(Method::Baseline(b));
        }
        Selector::from_name(s).map(Method::Prune)
    }
}

/// Pipeline configuration.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    pub method: Method,
    /// Fraction of units removed per site (layer-wise uniform
    /// compression ratio, 0.0–1.0).
    pub ratio: f64,
    /// Apply the GRAIL compensation map.
    pub grail: bool,
    /// Ridge scale α (λ = α · mean diag(G_PP)).
    pub alpha: f32,
    pub seed: u64,
    /// Sequential closed-loop calibration (paper §3.2: re-evaluate the
    /// Gram on the already-compressed prefix). `false` = open loop:
    /// all statistics come from the dense model — the ablation that
    /// shows why the closed loop matters.
    pub closed_loop: bool,
    /// Calibration shards (micro-batches) for streamed statistics and
    /// parallel segment execution. `0` = [`DEFAULT_SHARDS`] (models
    /// clamp to the available sample count). More shards lower peak
    /// tap memory; results are shard-count-dependent only in float
    /// summation order, which is why the default is a fixed constant
    /// rather than a function of the machine.
    pub shards: usize,
    /// Worker threads for calibration forwards. `0` = auto
    /// (`GRAIL_THREADS` env or available parallelism).
    pub workers: usize,
}

impl PipelineConfig {
    /// A pipeline with sensible defaults.
    pub fn new(method: Method, ratio: f64, grail: bool) -> Self {
        PipelineConfig {
            method,
            ratio,
            grail,
            alpha: super::DEFAULT_ALPHA,
            seed: 0,
            closed_loop: true,
            shards: 0,
            workers: 0,
        }
    }
}

/// Outcome of one site's reduction.
#[derive(Clone, Debug)]
pub struct SiteOutcome {
    pub id: String,
    pub units_before: usize,
    pub units_after: usize,
    /// Relative consumer-input reconstruction error of the applied map.
    pub recon_err: f32,
}

/// Outcome of a full pipeline run.
#[derive(Clone, Debug)]
pub struct Report {
    pub sites: Vec<SiteOutcome>,
    /// Seconds spent in calibration forwards + statistics.
    pub calib_seconds: f64,
    /// Seconds spent building/applying compensations.
    pub comp_seconds: f64,
}

impl Report {
    /// Mean relative reconstruction error across sites.
    pub fn mean_recon_err(&self) -> f32 {
        if self.sites.is_empty() {
            return 0.0;
        }
        self.sites.iter().map(|s| s.recon_err).sum::<f32>() / self.sites.len() as f32
    }
}

/// Default calibration shard count when [`PipelineConfig::shards`] is
/// 0. Deliberately a fixed constant — never derived from detected core
/// count — so float summation order, and therefore compressed-model
/// numerics, are identical across machines (the repo's bitwise
/// reproducibility contract). Worker threads may still vary freely:
/// partial statistics merge in shard-index order regardless of
/// scheduling.
pub const DEFAULT_SHARDS: usize = 16;

/// Units kept for a site of `units` units in `groups` groups at
/// removal `ratio` — always ≥1 per group and, for divisible grouped
/// sites, a multiple of `groups`. When `units` is not a multiple of
/// `groups` the per-group arithmetic would silently truncate (e.g.
/// `ratio = 0.0` dropping units), so such sites fall back to ungrouped
/// rounding on the total.
pub fn uniform_keep(units: usize, groups: usize, ratio: f64) -> usize {
    let g = groups.max(1);
    if units % g != 0 {
        let keep = ((units as f64) * (1.0 - ratio)).round() as usize;
        return keep.clamp(1, units);
    }
    let per_group = units / g;
    let keep_pg = ((per_group as f64) * (1.0 - ratio)).round() as usize;
    keep_pg.clamp(1, per_group) * g
}

/// Which calibration strategy drives the closed loop.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Engine {
    /// Staged segment execution: persistent per-shard boundary states,
    /// O(L) total layer forwards.
    Staged,
    /// Reference strategy: rebuild every state from scratch at every
    /// site, O(L²) layer forwards. Same statistics, bit-identical
    /// outcomes.
    Rescan,
}

/// Run the closed-loop pipeline over every site of `model` using the
/// staged O(L) segment executor.
pub fn compress_model<M>(model: &mut M, calib: &M::Input, cfg: &PipelineConfig) -> Report
where
    M: Compressible + Sync,
    M::Input: Sync,
    M::CalibState: Send,
{
    run_pipeline(model, calib, cfg, Engine::Staged)
}

/// Reference pipeline: identical statistics and outcomes, but every
/// site re-executes the full prefix (O(L²) layer forwards). Kept for
/// equivalence tests and the `benches/hotpath.rs` before/after
/// comparison.
pub fn compress_model_rescan<M>(model: &mut M, calib: &M::Input, cfg: &PipelineConfig) -> Report
where
    M: Compressible + Sync,
    M::Input: Sync,
    M::CalibState: Send,
{
    run_pipeline(model, calib, cfg, Engine::Rescan)
}

fn run_pipeline<M>(
    model: &mut M,
    calib: &M::Input,
    cfg: &PipelineConfig,
    engine: Engine,
) -> Report
where
    M: Compressible + Sync,
    M::Input: Sync,
    M::CalibState: Send,
{
    let n_sites = model.sites().len();
    let mut rng = Pcg64::seed_stream(cfg.seed, 0x6121);
    let mut outcomes = Vec::with_capacity(n_sites);
    let mut calib_seconds = 0.0f64;
    let mut comp_seconds = 0.0f64;
    let workers = if cfg.workers != 0 { cfg.workers } else { default_threads() };
    let shard_target = if cfg.shards != 0 { cfg.shards } else { DEFAULT_SHARDS };

    let t_init = Instant::now();
    let shard_inputs: Vec<M::Input> = model.split_input(calib, shard_target);

    // Open-loop ablation: one streamed pass over the dense model
    // accumulates every site's statistics up front (error propagation
    // becomes visible at depth). Peak memory is one tap per in-flight
    // shard plus `shards × Σ h²` partial Gram accumulators — bounded
    // by the fixed shard count, and merged strictly in shard order so
    // the result is independent of worker count.
    let open_stats: Vec<super::ActStats> = if cfg.closed_loop {
        Vec::new()
    } else {
        let widths: Vec<usize> = model.sites().iter().map(|s| s.feat_width()).collect();
        let widths_ref = &widths;
        let mref: &M = &*model;
        let per_shard: Vec<Vec<super::ActStats>> =
            run_grid(shard_inputs.iter().collect(), workers, |_, inp| {
                let mut st = mref.calib_begin(inp);
                let mut local: Vec<super::ActStats> =
                    widths_ref.iter().map(|&w| super::ActStats::new(w)).collect();
                for si in 0..widths_ref.len() {
                    let tap = mref.site_tap(&mut st, si);
                    local[si].update(&tap);
                    if si + 1 < widths_ref.len() {
                        mref.forward_segment(&mut st, si, si + 1);
                    }
                }
                local
            });
        (0..widths.len())
            .map(|si| {
                let mut s = super::ActStats::new(widths[si]);
                for shard in &per_shard {
                    s.merge(&shard[si]);
                }
                s.finalize();
                s
            })
            .collect()
    };

    // Staged closed loop: per-shard boundary states at site 0.
    let mut states: Vec<M::CalibState> = if cfg.closed_loop && engine == Engine::Staged {
        let mref: &M = &*model;
        run_grid(shard_inputs.iter().collect(), workers, |_, inp| mref.calib_begin(inp))
    } else {
        Vec::new()
    };
    calib_seconds += t_init.elapsed().as_secs_f64();

    for si in 0..n_sites {
        let info = model.sites()[si].clone();
        let keep = uniform_keep(info.units, info.groups, cfg.ratio);
        if keep >= info.units {
            outcomes.push(SiteOutcome {
                id: info.id.clone(),
                units_before: info.units,
                units_after: info.units,
                recon_err: 0.0,
            });
            // The boundary still has to move past the untouched site.
            if cfg.closed_loop && engine == Engine::Staged && si + 1 < n_sites {
                let t = Instant::now();
                let mref: &M = &*model;
                run_grid_mut(&mut states, workers, |_, st| {
                    mref.forward_segment(st, si, si + 1);
                });
                calib_seconds += t.elapsed().as_secs_f64();
            }
            continue;
        }

        // --- calibration: stream shard taps into the statistics on
        // the current (closed loop) or dense (open loop) model.
        let tc = Instant::now();
        let width = info.feat_width();
        let stats = if !cfg.closed_loop {
            open_stats[si].clone()
        } else {
            let mref: &M = &*model;
            let partials: Vec<super::ActStats> = match engine {
                Engine::Staged => run_grid_mut(&mut states, workers, |_, st| {
                    let tap = mref.site_tap(st, si);
                    let mut s = super::ActStats::new(width);
                    s.update(&tap);
                    s
                }),
                Engine::Rescan => {
                    run_grid(shard_inputs.iter().collect(), workers, |_, inp| {
                        let mut st = mref.calib_begin(inp);
                        mref.forward_segment(&mut st, 0, si);
                        let tap = mref.site_tap(&mut st, si);
                        let mut s = super::ActStats::new(width);
                        s.update(&tap);
                        s
                    })
                }
            };
            let mut stats = super::ActStats::new(width);
            for p in &partials {
                stats.merge(p);
            }
            stats.finalize();
            stats
        };
        calib_seconds += tc.elapsed().as_secs_f64();

        let t1 = Instant::now();
        let l1 = model.producer_row_norm(si, 1);
        let l2 = model.producer_row_norm(si, 2);
        let consumer = model.consumer_matrix(si);
        let gd = select::gram_diag(&stats.gram);
        let consumer_cols = crate::tensor::ops::col_l2(&consumer);

        // --- choose the reduction
        let mut plan: ReductionPlan = match cfg.method {
            Method::Prune(sel) => {
                let inputs = ScoreInputs {
                    site: &info,
                    producer_l1: &l1,
                    producer_l2: &l2,
                    gram_diag: &gd,
                    consumer_cols: &consumer_cols,
                };
                ReductionPlan::bare(select::select_reducer(sel, &inputs, keep, &mut rng))
            }
            Method::Fold => {
                let feats = model.producer_features(si);
                ReductionPlan::bare(fold::fold_reducer(&feats, &info, keep, &mut rng))
            }
            Method::RandomFold => {
                ReductionPlan::bare(fold::random_fold(&info, keep, &mut rng))
            }
            Method::Baseline(b) => {
                baseline_plan(b, &info, &stats, &l1, &l2, &consumer, keep, &mut rng)
            }
        };

        // --- optional GRAIL compensation: keep the selection, replace
        // the weight-space update with the closed-form reconstruction.
        if cfg.grail {
            let b = super::reconstruction(&stats.gram, &plan.reducer, info.unit_dim, cfg.alpha);
            plan.compensation = Some(b);
            plan.consumer_override = None;
            // The ridge solution on uncentered moments already carries
            // the removed features' conditional mean; a separate bias
            // shift would double-count it.
            plan.bias_delta = None;
        }

        if info.kind == SiteKind::AttnHeads {
            validate_head_reducer(&plan.reducer, &info).expect("invalid head reducer");
        }

        // --- diagnostics + apply. The reconstruction error comes from
        // the Gram matrix (tr-form), so no raw activations are kept.
        let eff_map = if let Some(b) = &plan.compensation {
            b.clone()
        } else {
            plan.reducer.lift(info.unit_dim).consumer_matrix(info.feat_width())
        };
        let recon_err = super::reconstruction_error_from_gram(
            &stats.gram,
            &plan.reducer,
            info.unit_dim,
            &eff_map,
        );
        model.apply(si, &plan);
        comp_seconds += t1.elapsed().as_secs_f64();

        // --- advance the boundary through the now-compressed site.
        if cfg.closed_loop && engine == Engine::Staged && si + 1 < n_sites {
            let t = Instant::now();
            let mref: &M = &*model;
            run_grid_mut(&mut states, workers, |_, st| {
                mref.forward_segment(st, si, si + 1);
            });
            calib_seconds += t.elapsed().as_secs_f64();
        }

        outcomes.push(SiteOutcome {
            id: info.id.clone(),
            units_before: info.units,
            units_after: keep,
            recon_err,
        });
    }
    Report { sites: outcomes, calib_seconds, comp_seconds }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthVision;
    use crate::nn::models::MlpNet;
    use crate::rng::Pcg64;

    #[test]
    fn uniform_keep_bounds() {
        assert_eq!(uniform_keep(100, 1, 0.5), 50);
        assert_eq!(uniform_keep(100, 1, 0.99), 1);
        assert_eq!(uniform_keep(100, 1, 0.0), 100);
        // Grouped: 8 units, 4 groups, ratio 0.5 -> 1 per group.
        assert_eq!(uniform_keep(8, 4, 0.5), 4);
        // Never below one per group.
        assert_eq!(uniform_keep(8, 4, 0.95), 4);
    }

    #[test]
    fn uniform_keep_non_divisible_groups() {
        // Regression: `units / groups` used to truncate, so ratio 0.0
        // silently dropped units (10 units / 3 groups kept only 9).
        assert_eq!(uniform_keep(10, 3, 0.0), 10);
        assert_eq!(uniform_keep(7, 2, 0.0), 7);
        assert_eq!(uniform_keep(10, 3, 0.5), 5);
        assert_eq!(uniform_keep(10, 3, 1.0), 1);
        // Divisible grouped behaviour unchanged.
        assert_eq!(uniform_keep(8, 4, 0.0), 8);
        assert_eq!(uniform_keep(8, 4, 0.5), 4);
    }

    fn trained_ish_mlp() -> (MlpNet, crate::tensor::Tensor) {
        // A random MLP on SynthVision inputs; the statistics are real
        // even if the model is untrained.
        let mut rng = Pcg64::seed(77);
        let m = MlpNet::init(768, 32, 10, &mut rng);
        let x = SynthVision::new(3).generate(64).x;
        (m, x)
    }

    #[test]
    fn grail_reduces_output_distortion_vs_bare() {
        let (m0, x) = trained_ish_mlp();
        let y_ref = m0.forward(&x);
        let run = |grail: bool| {
            let mut m = m0.clone();
            let cfg = PipelineConfig::new(Method::Prune(Selector::MagnitudeL2), 0.5, grail);
            let rep = compress_model(&mut m, &x, &cfg);
            assert_eq!(rep.sites.len(), 2);
            let mut d = m.forward(&x);
            crate::tensor::ops::axpy(&mut d, -1.0, &y_ref);
            d.frobenius()
        };
        let bare = run(false);
        let grail = run(true);
        assert!(
            grail < bare,
            "GRAIL must reduce output distortion: grail={grail} bare={bare}"
        );
    }

    #[test]
    fn fold_pipeline_runs_and_reports() {
        let (mut m, x) = trained_ish_mlp();
        let cfg = PipelineConfig::new(Method::Fold, 0.4, true);
        let rep = compress_model(&mut m, &x, &cfg);
        assert_eq!(rep.sites.len(), 2);
        for s in &rep.sites {
            assert_eq!(s.units_before, 32);
            assert_eq!(s.units_after, 19);
            assert!(s.recon_err.is_finite());
        }
        assert!(m.forward(&x).all_finite());
        assert!(rep.calib_seconds >= 0.0 && rep.comp_seconds >= 0.0);
    }

    #[test]
    fn ratio_zero_is_identity() {
        let (m0, x) = trained_ish_mlp();
        let mut m = m0.clone();
        let cfg = PipelineConfig::new(Method::Prune(Selector::Wanda), 0.0, true);
        let rep = compress_model(&mut m, &x, &cfg);
        assert!(rep.sites.iter().all(|s| s.units_after == s.units_before));
        assert!(m0.forward(&x).max_abs_diff(&m.forward(&x)) < 1e-6);
    }

    #[test]
    fn deterministic_given_seed() {
        let (m0, x) = trained_ish_mlp();
        let run = || {
            let mut m = m0.clone();
            let cfg = PipelineConfig::new(Method::RandomFold, 0.5, true);
            compress_model(&mut m, &x, &cfg);
            m.forward(&x)
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn shard_and_worker_counts_do_not_change_widths() {
        // Float summation order differs across shard counts, but the
        // structural outcome (selection sizes, finiteness) must not.
        let (m0, x) = trained_ish_mlp();
        for (shards, workers) in [(1usize, 1usize), (3, 2), (16, 4)] {
            let mut m = m0.clone();
            let mut cfg = PipelineConfig::new(Method::Prune(Selector::Wanda), 0.5, true);
            cfg.shards = shards;
            cfg.workers = workers;
            let rep = compress_model(&mut m, &x, &cfg);
            assert_eq!(rep.sites.len(), 2);
            assert!(rep.sites.iter().all(|s| s.units_after == 16));
            assert!(m.forward(&x).all_finite(), "shards={shards}");
        }
    }

    #[test]
    fn method_names_roundtrip() {
        for name in ["mag-l1", "mag-l2", "fold", "random-fold", "wanda", "ziplm", "flap"] {
            let m = Method::from_name(name).unwrap();
            // wanda maps to the baseline spelling of the same name.
            assert_eq!(Method::from_name(&m.name()).unwrap(), m);
        }
        assert!(Method::from_name("nope").is_none());
    }
}
