//! The GRAIL compensation engine (paper §3).
//!
//! Given the consumer-input Gram matrix `G = Σ x xᵀ` of a site and a
//! width reducer `M`, GRAIL solves the ridge system
//!
//! ```text
//! B = G·M · (Mᵀ·G·M + λI)⁻¹,   λ = α · mean diag(Mᵀ G M)
//! ```
//!
//! and merges `B` into the consumer weights. [`pipeline`] runs the
//! sequential closed loop over a model's sites: each site's Gram is
//! recomputed on the output of the already-compressed prefix.

pub mod pipeline;
pub mod search;
pub mod spec;

pub use pipeline::{
    compress_model, compress_model_rescan, execute_plan, execute_plan_rescan, plan_for_model,
    site_sensitivities, Method, Report, SiteOutcome, DEFAULT_SHARDS,
};
pub use search::{score_plan, search_plan, SearchOutcome};
pub use spec::{
    BudgetMode, CompressionPlan, CompressionSpec, PlannedSite, PolicyOverrides, PolicyRule,
    SearchSeed, SiteMatcher, SitePolicy, DEFAULT_ALPHA_GRID, DEFAULT_SEARCH_ROUNDS,
};

use crate::compress::Reducer;
use crate::linalg::{mean_diag, ridge_reconstruction_with};
use crate::serve::digest::{wire_u32, wire_u64};
use crate::tensor::{ops, Tensor};

/// Default ridge scale α — the top of the paper’s range (α ∈
/// [1e-4, 5e-3]): dense sites here see far fewer Gram rows than the
/// paper’s token/pixel-rich LLaMA/ResNet sites, so the stronger ridge
/// is the faithful operating point.
pub const DEFAULT_ALPHA: f32 = 5e-3;

/// Second-order activation statistics of one site, accumulated over
/// calibration batches.
#[derive(Clone, Debug)]
pub struct ActStats {
    /// Uncentered second moment `Σ x xᵀ`, `[h, h]`.
    pub gram: Tensor,
    /// Mean activation per feature (FLAP-style bias compensation and
    /// fluctuation scores need first moments too).
    pub mean: Vec<f32>,
    /// Samples accumulated.
    pub rows: usize,
}

impl ActStats {
    /// Empty statistics of width `h`.
    pub fn new(h: usize) -> Self {
        ActStats { gram: Tensor::zeros(&[h, h]), mean: vec![0.0; h], rows: 0 }
    }

    /// Fold one batch of activations `[rows, h]` into the statistics.
    pub fn update(&mut self, acts: &Tensor) {
        let h = self.mean.len();
        assert_eq!(acts.dim(1), h, "activation width");
        ops::syrk_upper_acc(acts, &mut self.gram);
        let n_new = acts.dim(0);
        let sums = ops::col_mean(acts);
        let total = (self.rows + n_new) as f64;
        for (m, &batch_mean) in self.mean.iter_mut().zip(&sums) {
            *m = ((*m as f64 * self.rows as f64 + batch_mean as f64 * n_new as f64) / total)
                as f32;
        }
        self.rows += n_new;
    }

    /// Fold another (un-finalized) accumulator into this one — used to
    /// combine per-shard partial statistics in shard order, which keeps
    /// the merged result deterministic under parallel calibration.
    pub fn merge(&mut self, other: &ActStats) {
        assert_eq!(self.width(), other.width(), "stat widths");
        ops::axpy(&mut self.gram, 1.0, &other.gram);
        let total = self.rows + other.rows;
        if total > 0 {
            for (m, &om) in self.mean.iter_mut().zip(&other.mean) {
                *m = ((*m as f64 * self.rows as f64 + om as f64 * other.rows as f64)
                    / total as f64) as f32;
            }
        }
        self.rows = total;
    }

    /// Finish accumulation (mirror the Gram's upper triangle).
    pub fn finalize(&mut self) {
        ops::symmetrize_from_upper(&mut self.gram);
    }

    /// One-shot construction from a single activation matrix.
    pub fn from_acts(acts: &Tensor) -> Self {
        let mut s = ActStats::new(acts.dim(1));
        s.update(acts);
        s.finalize();
        s
    }

    /// Feature width.
    pub fn width(&self) -> usize {
        self.mean.len()
    }

    /// Serialize into `out` (little-endian, byte-exact): width `u32`,
    /// rows `u64`, then the mean and Gram f32 bit patterns. The Gram is
    /// written verbatim — un-finalized accumulators stay un-finalized —
    /// so a decoded accumulator is byte-identical to the original and
    /// downstream merges/solves reproduce the cold path bit for bit.
    /// This is the payload unit of the statistics cache
    /// ([`crate::serve::cache`]).
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        let h = self.width();
        out.extend_from_slice(&wire_u32(h, "ActStats width"));
        out.extend_from_slice(&wire_u64(self.rows, "ActStats rows"));
        for v in &self.mean {
            out.extend_from_slice(&v.to_le_bytes());
        }
        for v in self.gram.data() {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }

    /// Decode one accumulator from `buf` starting at `*pos`, advancing
    /// `*pos` past it. Returns `None` on truncation — the caller treats
    /// that as a corrupt cache entry.
    pub fn decode_from(buf: &[u8], pos: &mut usize) -> Option<ActStats> {
        let take = |pos: &mut usize, n: usize| -> Option<&[u8]> {
            let s = buf.get(*pos..*pos + n)?;
            *pos += n;
            Some(s)
        };
        // Checked narrowing: geometry this machine cannot index (u64
        // rows on a 32-bit target) decodes as `None` → the caller's
        // corrupt-entry path, never a silent wrap.
        let h = usize::try_from(u32::from_le_bytes(take(pos, 4)?.try_into().ok()?)).ok()?;
        let rows = usize::try_from(u64::from_le_bytes(take(pos, 8)?.try_into().ok()?)).ok()?;
        let mut mean = Vec::with_capacity(h);
        for _ in 0..h {
            mean.push(f32::from_le_bytes(take(pos, 4)?.try_into().ok()?));
        }
        let mut gram = Vec::with_capacity(h * h);
        for _ in 0..h * h {
            gram.push(f32::from_le_bytes(take(pos, 4)?.try_into().ok()?));
        }
        Some(ActStats { gram: Tensor::from_vec(&[h, h], gram), mean, rows })
    }

    /// Per-feature variance (uncentered moment minus squared mean,
    /// scaled by sample count) — FLAP's fluctuation signal.
    pub fn variance(&self) -> Vec<f32> {
        let n = self.rows.max(1) as f32;
        (0..self.width())
            .map(|j| (self.gram.at2(j, j) / n - self.mean[j] * self.mean[j]).max(0.0))
            .collect()
    }
}

/// Trace of a (square) Gram matrix in f64 — `tr(G) = Σ x²` of the
/// accumulated activations. Valid on un-finalized accumulators too
/// (the diagonal lives in the upper triangle). Shared by the
/// sensitivity allocator and the search's gram-sensitivity seed so
/// both derive the identical signal from cached statistics.
pub(crate) fn gram_trace(g: &Tensor) -> f64 {
    (0..g.dim(0)).map(|i| g.at2(i, i) as f64).sum()
}

/// Compute the GRAIL reconstruction map `B: [h_feat, k_feat]` for a
/// *unit-level* reducer on a site with `unit_dim` features per unit.
///
/// For pruning, the Gram sub-blocks are gathered directly
/// (`G_PP = G[P,P]`); for folding, the merge map enters as
/// `Mᵀ G M` (paper §3.1, "which generalizes the pruning case").
pub fn reconstruction(gram: &Tensor, reducer: &Reducer, unit_dim: usize, alpha: f32) -> Tensor {
    reconstruction_with(gram, reducer, unit_dim, alpha, 0)
}

/// [`reconstruction`] with an explicit worker count for the ridge
/// solve's RHS panel fan-out (`0` = auto). The pipeline passes its
/// resolved worker budget here so solver parallelism honours the
/// spec's `workers` setting; results are bit-identical at every value.
pub fn reconstruction_with(
    gram: &Tensor,
    reducer: &Reducer,
    unit_dim: usize,
    alpha: f32,
    workers: usize,
) -> Tensor {
    let h = gram.dim(0);
    assert_eq!(gram.dim(1), h, "gram must be square");
    let lifted = reducer.lift(unit_dim);
    match &lifted {
        Reducer::Select(idx) => {
            let g_ph = ops::gather_rows(gram, idx); // [K, H] = Mᵀ G
            let g_pp = ops::gather_cols(&g_ph, idx); // [K, K]
            let lambda = alpha * mean_diag(&g_pp);
            ridge_reconstruction_with(&g_pp, &g_ph, lambda, workers)
        }
        Reducer::Fold { .. } => {
            let m = lifted.matrix(h); // [H, K]
            let gm = ops::matmul(gram, &m); // [H, K]
            let g_pp = ops::matmul(&ops::transpose(&m), &gm); // [K, K]
            let g_ph = ops::transpose(&gm); // [K, H]
            let lambda = alpha * mean_diag(&g_pp);
            ridge_reconstruction_with(&g_pp, &g_ph, lambda, workers)
        }
    }
}

/// Relative reconstruction error `‖X − X_red·Bᵀ‖_F / ‖X‖_F` on an
/// activation matrix (reporting/diagnostics only — the solve itself
/// never touches raw activations).
pub fn reconstruction_error(
    acts: &Tensor,
    reducer: &Reducer,
    unit_dim: usize,
    b_map: &Tensor,
) -> f32 {
    let h = acts.dim(1);
    let m = reducer.lift(unit_dim).matrix(h);
    let reduced = ops::matmul(acts, &m); // [rows, K]
    let recon = ops::matmul(&reduced, &ops::transpose(b_map)); // [rows, H]
    let mut diff = recon;
    ops::axpy(&mut diff, -1.0, acts);
    let denom = acts.frobenius().max(1e-12);
    diff.frobenius() / denom
}

/// The raw quadratic forms behind [`reconstruction_error_from_gram`]:
/// `(tr(Eᵀ·G·E), tr(G))` with `E = I − M·Bᵀ` — numerator and
/// denominator of the *squared* relative reconstruction error. The
/// plan search ([`search`]) sums these across sites to score candidate
/// plans on held-out Gram statistics.
pub fn reconstruction_err2_terms(
    gram: &Tensor,
    reducer: &Reducer,
    unit_dim: usize,
    b_map: &Tensor,
) -> (f64, f64) {
    let h = gram.dim(0);
    assert_eq!(gram.dim(1), h, "gram must be square");
    let m = reducer.lift(unit_dim).matrix(h); // [H, K]
    let mut e = ops::matmul(&m, &ops::transpose(b_map)); // [H, H] = M·Bᵀ
    for v in e.data_mut().iter_mut() {
        *v = -*v;
    }
    for i in 0..h {
        let v = e.at2(i, i) + 1.0;
        e.set2(i, i, v); // E = I − M·Bᵀ
    }
    let ge = ops::matmul(gram, &e); // [H, H]
    let mut err2 = 0.0f64;
    for (&ev, &gv) in e.data().iter().zip(ge.data()) {
        err2 += (ev as f64) * (gv as f64); // tr(Eᵀ·G·E)
    }
    let denom2: f64 = (0..h).map(|i| gram.at2(i, i) as f64).sum();
    (err2.max(0.0), denom2)
}

/// Relative reconstruction error computed from the Gram matrix alone:
/// with `E = I − M·Bᵀ`, `‖X − X·M·Bᵀ‖²_F = tr(Eᵀ·G·E)` and
/// `‖X‖²_F = tr(G)`, so the streamed pipeline never has to materialize
/// raw activations to report the same diagnostic as
/// [`reconstruction_error`].
pub fn reconstruction_error_from_gram(
    gram: &Tensor,
    reducer: &Reducer,
    unit_dim: usize,
    b_map: &Tensor,
) -> f32 {
    let (err2, denom2) = reconstruction_err2_terms(gram, reducer, unit_dim, b_map);
    (err2.sqrt() / denom2.max(1e-24).sqrt()) as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn correlated_acts(n: usize, h: usize, seed: u64) -> Tensor {
        // x = A z with z of lower dimension -> strongly correlated
        // channels that a linear map can reconstruct.
        let mut rng = Pcg64::seed(seed);
        let d = h / 2;
        let mut a = Tensor::zeros(&[h, d]);
        rng.fill_normal(a.data_mut(), 1.0);
        let mut z = Tensor::zeros(&[n, d]);
        rng.fill_normal(z.data_mut(), 1.0);
        let mut x = ops::matmul(&z, &ops::transpose(&a));
        // small independent noise
        for v in x.data_mut().iter_mut() {
            *v += 0.01 * rng.normal();
        }
        x
    }

    #[test]
    fn stats_accumulate_like_one_shot() {
        let x = correlated_acts(64, 10, 1);
        let one = ActStats::from_acts(&x);
        let mut two = ActStats::new(10);
        two.update(&crate::data::VisionSet {
            x: x.clone(),
            y: vec![0; 64],
            chw: (1, 1, 10),
        }
        .slice(0, 32)
        .x);
        two.update(
            &crate::data::VisionSet { x: x.clone(), y: vec![0; 64], chw: (1, 1, 10) }
                .slice(32, 32)
                .x,
        );
        two.finalize();
        assert!(one.gram.max_abs_diff(&two.gram) < 1e-3);
        for (a, b) in one.mean.iter().zip(&two.mean) {
            assert!((a - b).abs() < 1e-5);
        }
        assert_eq!(two.rows, 64);
    }

    #[test]
    fn identity_gram_reduces_to_pruning() {
        // Paper: "recovers classic pruning/folding when the Gram matrix
        // is near identity".
        let g = Tensor::eye(6);
        let r = Reducer::Select(vec![1, 4]);
        let b = reconstruction(&g, &r, 1, 0.0);
        let m = r.matrix(6);
        assert!(b.max_abs_diff(&m) < 1e-5);
    }

    #[test]
    fn correlated_channels_reconstruct_well() {
        let x = correlated_acts(256, 12, 2);
        let stats = ActStats::from_acts(&x);
        let r = Reducer::Select((0..6).collect());
        let b = reconstruction(&stats.gram, &r, 1, 1e-4);
        let err = reconstruction_error(&x, &r, 1, &b);
        // Rank-6 signal from 6 kept channels: near-perfect linear
        // reconstruction.
        assert!(err < 0.05, "err={err}");
        // Data-free pruning (B = M) must be much worse.
        let err_bare = reconstruction_error(&x, &r, 1, &r.matrix(12));
        assert!(err_bare > 3.0 * err, "bare={err_bare} grail={err}");
    }

    #[test]
    fn fold_reconstruction_uses_merge_gram() {
        let x = correlated_acts(256, 8, 3);
        let stats = ActStats::from_acts(&x);
        let r = Reducer::Fold { assign: vec![0, 0, 1, 1, 2, 2, 3, 3], k: 4 };
        let b = reconstruction(&stats.gram, &r, 1, 1e-4);
        assert_eq!(b.shape(), &[8, 4]);
        let err = reconstruction_error(&x, &r, 1, &b);
        let err_bare = reconstruction_error(&x, &r, 1, &r.consumer_matrix(8));
        assert!(err <= err_bare + 1e-4, "grail {err} vs bare {err_bare}");
    }

    #[test]
    fn head_level_lift_shapes() {
        let x = correlated_acts(128, 12, 4); // 3 heads × dh 4
        let stats = ActStats::from_acts(&x);
        let r = Reducer::Select(vec![0, 2]); // head-level
        let b = reconstruction(&stats.gram, &r, 4, 1e-3);
        assert_eq!(b.shape(), &[12, 8]);
    }

    #[test]
    fn merge_matches_sequential_updates() {
        let x = correlated_acts(48, 6, 9);
        let a = crate::tensor::ops::split_rows(&x, 3);
        let mut merged = ActStats::new(6);
        for part in &a {
            let mut p = ActStats::new(6);
            p.update(part);
            merged.merge(&p);
        }
        merged.finalize();
        let one = ActStats::from_acts(&x);
        assert_eq!(merged.rows, 48);
        assert!(merged.gram.max_abs_diff(&one.gram) < 1e-3);
        for (m, o) in merged.mean.iter().zip(&one.mean) {
            assert!((m - o).abs() < 1e-5);
        }
    }

    #[test]
    fn gram_recon_error_matches_activation_recon_error() {
        let x = correlated_acts(256, 12, 5);
        let stats = ActStats::from_acts(&x);
        let r = Reducer::Select((0..6).collect());
        for b in [
            reconstruction(&stats.gram, &r, 1, 1e-4),
            r.matrix(12), // data-free map: large error path
        ] {
            let from_acts = reconstruction_error(&x, &r, 1, &b);
            let from_gram = reconstruction_error_from_gram(&stats.gram, &r, 1, &b);
            assert!(
                (from_acts - from_gram).abs() < 1e-3 * (1.0 + from_acts),
                "acts {from_acts} vs gram {from_gram}"
            );
        }
    }

    #[test]
    fn actstats_encode_decode_is_byte_exact() {
        let x = correlated_acts(40, 6, 12);
        let mut s = ActStats::new(6);
        s.update(&x); // un-finalized: lower triangle still zero
        let mut buf = Vec::new();
        s.encode_into(&mut buf);
        let mut pos = 0;
        let d = ActStats::decode_from(&buf, &mut pos).unwrap();
        assert_eq!(pos, buf.len());
        assert_eq!(d.rows, s.rows);
        assert_eq!(d.width(), s.width());
        for (a, b) in s.gram.data().iter().zip(d.gram.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in s.mean.iter().zip(&d.mean) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // Truncation at any boundary is a decode failure, not a panic.
        for cut in [0, 3, 11, buf.len() - 1] {
            let mut p = 0;
            assert!(ActStats::decode_from(&buf[..cut], &mut p).is_none(), "cut={cut}");
        }
    }

    #[test]
    fn variance_matches_definition() {
        let x = Tensor::from_vec(&[4, 1], vec![1., 3., 1., 3.]);
        let s = ActStats::from_acts(&x);
        let v = s.variance();
        assert!((v[0] - 1.0).abs() < 1e-5, "{v:?}"); // var of {1,3} = 1
        assert!((s.mean[0] - 2.0).abs() < 1e-6);
    }
}
