//! Declarative compression specs: the Spec → Plan → Execute API.
//!
//! The paper's method is selector-agnostic and site-local, so nothing
//! forces one global `{method, ratio}` on every site. A
//! [`CompressionSpec`] states *intent*: global default policy, an
//! ordered list of [`PolicyRule`]s matched per site (by id glob,
//! [`SiteKind`], or depth range), and an optional global
//! [`BudgetMode`] that allocates non-uniform keep counts from a target
//! parameter budget. [`CompressionSpec::resolve`] turns that into a
//! [`CompressionPlan`] — one concrete [`SitePolicy`] and keep count per
//! site, inspectable (`grail plan`) and serializable *before* any
//! weight is touched. [`super::pipeline::execute_plan`] then drives the
//! staged engine from the plan.
//!
//! Precedence: rules apply in order on top of the defaults (later
//! rules win); a budget allocator then re-assigns ratios for every
//! site whose ratio no rule pinned explicitly. Specs load from the
//! TOML subset of [`crate::config`] (`grail run --spec spec.toml`);
//! see `examples/lm_depth_ramp.spec.toml` for the format.

use super::pipeline::{uniform_keep, Method};
use crate::compress::{SiteInfo, SiteKind};
use crate::config::Config;
use anyhow::{anyhow, bail, Result};

/// Fully resolved per-site policy: how one site gets compressed.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SitePolicy {
    pub method: Method,
    /// Fraction of units removed at this site (0.0–1.0).
    pub ratio: f64,
    /// Apply the GRAIL compensation map.
    pub grail: bool,
    /// Ridge scale α (λ = α · mean diag(G_PP)).
    pub alpha: f32,
}

/// Partial policy: the fields a rule overrides.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PolicyOverrides {
    pub method: Option<Method>,
    pub ratio: Option<f64>,
    pub grail: Option<bool>,
    pub alpha: Option<f32>,
}

impl PolicyOverrides {
    fn apply(&self, p: &mut SitePolicy) {
        if let Some(m) = self.method {
            p.method = m;
        }
        if let Some(r) = self.ratio {
            p.ratio = r;
        }
        if let Some(g) = self.grail {
            p.grail = g;
        }
        if let Some(a) = self.alpha {
            p.alpha = a;
        }
    }
}

/// Which sites a rule applies to. All present conditions must hold
/// (AND); an empty matcher matches every site.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SiteMatcher {
    /// Glob over the site id (`*` any substring, `?` one char), e.g.
    /// `block*.attn`.
    pub id_glob: Option<String>,
    /// Site kind (`dense` / `conv` / `mlp-pair` / `attn-heads`).
    pub kind: Option<SiteKind>,
    /// Inclusive site-index range `[lo, hi]` in forward order.
    pub depth: Option<(usize, usize)>,
}

impl SiteMatcher {
    /// Does this matcher select `site` at forward position `index`?
    pub fn matches(&self, site: &SiteInfo, index: usize) -> bool {
        if let Some(g) = &self.id_glob {
            if !glob_match(g, &site.id) {
                return false;
            }
        }
        if let Some(k) = self.kind {
            if site.kind != k {
                return false;
            }
        }
        if let Some((lo, hi)) = self.depth {
            if index < lo || index > hi {
                return false;
            }
        }
        true
    }

    /// Compact display form for plan rendering.
    pub fn render(&self) -> String {
        let mut parts = Vec::new();
        if let Some(g) = &self.id_glob {
            parts.push(format!("id~{g}"));
        }
        if let Some(k) = self.kind {
            parts.push(format!("kind={}", k.name()));
        }
        if let Some((lo, hi)) = self.depth {
            parts.push(format!("depth={lo}..={hi}"));
        }
        if parts.is_empty() {
            "*".to_string()
        } else {
            parts.join(" & ")
        }
    }
}

/// One ordered policy rule: matcher + overrides.
#[derive(Clone, Debug, PartialEq)]
pub struct PolicyRule {
    pub matcher: SiteMatcher,
    pub set: PolicyOverrides,
}

/// Which policy fields some rule pinned explicitly for a site.
#[derive(Clone, Copy, Debug)]
struct PinnedFields {
    ratio: bool,
    alpha: bool,
}

/// Default per-site ridge-α grid for `budget.mode = "search"`:
/// log-spaced through the paper's α range around the crate default
/// ([`super::DEFAULT_ALPHA`]).
pub const DEFAULT_ALPHA_GRID: [f64; 6] = [1e-6, 1e-5, 1e-4, 1e-3, 5e-3, 5e-2];

/// Default number of search rounds (one α sweep plus one keep
/// reallocation pass per round).
pub const DEFAULT_SEARCH_ROUNDS: usize = 2;

/// How `budget.mode = "search"` seeds its initial keep allocation
/// (config key `budget.seed`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SearchSeed {
    /// Budget-conserving uniform allocation at the target ratio
    /// (allocation proportional to site size).
    #[default]
    Uniform,
    /// Seed keeps proportional to per-site mean Gram-diagonal
    /// activation energy — the gram-sensitivity allocator composed
    /// with search. The sensitivities are derived from the search's
    /// *own* streamed statistics pass
    /// ([`search_plan`](super::search::search_plan)), so the
    /// composition costs no extra pass over the model.
    GramSensitivity,
}

/// Global keep-count allocation across sites.
#[derive(Clone, Debug, PartialEq)]
pub enum BudgetMode {
    /// Every site uses its own resolved ratio (layer-wise uniform
    /// unless rules say otherwise) — the legacy behaviour.
    PerSite,
    /// Ratios ramp linearly with depth around `target_ratio`:
    /// `ratio(i) = target · (1 + gamma·(2·pos − 1))` with `pos` the
    /// normalized site position. `gamma > 0` prunes deeper sites more
    /// (the free-lunch retraining literature's shape); the mean ratio
    /// stays ≈ `target_ratio`.
    DepthRamp { target_ratio: f64, gamma: f64 },
    /// Keep counts allocated from a global unit budget
    /// `(1 − target_ratio)·Σ units`, proportionally to each site's
    /// mean Gram-diagonal activation energy on the dense model —
    /// high-energy sites keep more units.
    GramSensitivity { target_ratio: f64 },
    /// Calibration-driven plan search ([`super::search`]): start from
    /// a budget-conserving uniform allocation at `target_ratio`, then
    /// tune per-site ridge α over `alpha_grid` and reallocate keep
    /// counts across sites under the fixed weighted-unit budget,
    /// scored by held-out Gram-domain reconstruction error.
    ///
    /// [`CompressionSpec::resolve`] produces only the *seed* plan (it
    /// has no model to calibrate on);
    /// [`plan_for_model`](super::pipeline::plan_for_model) — and
    /// therefore `grail tune` / `grail plan` / [`super::compress_model`]
    /// — runs the full search via
    /// [`search_plan`](super::search::search_plan). An empty
    /// `alpha_grid` means [`DEFAULT_ALPHA_GRID`].
    Search { target_ratio: f64, alpha_grid: Vec<f64>, rounds: usize },
}

impl BudgetMode {
    /// Stable display name.
    pub fn name(&self) -> &'static str {
        match self {
            BudgetMode::PerSite => "per-site",
            BudgetMode::DepthRamp { .. } => "depth-ramp",
            BudgetMode::GramSensitivity { .. } => "gram-sensitivity",
            BudgetMode::Search { .. } => "search",
        }
    }
}

/// A declarative compression spec: defaults + rules + budget.
#[derive(Clone, Debug)]
pub struct CompressionSpec {
    /// Policy for sites no rule touches.
    pub defaults: SitePolicy,
    /// Ordered rules; later matching rules override earlier ones.
    pub rules: Vec<PolicyRule>,
    pub budget: BudgetMode,
    pub seed: u64,
    /// Sequential closed-loop calibration (paper §3.2: re-evaluate the
    /// Gram on the already-compressed prefix). `false` = open loop:
    /// all statistics come from the dense model — the ablation that
    /// shows why the closed loop matters.
    pub closed_loop: bool,
    /// Calibration shards (micro-batches) for streamed statistics and
    /// parallel segment execution. `0` =
    /// [`DEFAULT_SHARDS`](super::pipeline::DEFAULT_SHARDS) (models
    /// clamp to the available sample count).
    pub shards: usize,
    /// Worker threads for calibration forwards. `0` = auto: the
    /// scheduler's thread budget for the current thread — the machine
    /// (`GRAIL_THREADS` env or available parallelism) on single-stream
    /// paths, an equal share of it inside an outer parallel fan-out
    /// such as `grail batch`
    /// ([`default_threads`](crate::coordinator::scheduler::default_threads)).
    pub workers: usize,
    /// Seed-allocation mode for `budget.mode = "search"`; ignored by
    /// every other budget mode.
    pub search_seed: SearchSeed,
}

impl CompressionSpec {
    /// A layer-wise uniform spec — the drop-in replacement for the old
    /// flat `PipelineConfig::new(method, ratio, grail)`.
    pub fn uniform(method: Method, ratio: f64, grail: bool) -> Self {
        CompressionSpec {
            defaults: SitePolicy { method, ratio, grail, alpha: super::DEFAULT_ALPHA },
            rules: Vec::new(),
            budget: BudgetMode::PerSite,
            seed: 0,
            closed_loop: true,
            shards: 0,
            workers: 0,
            search_seed: SearchSeed::Uniform,
        }
    }

    /// Whether resolving this spec needs per-site activation
    /// sensitivities (one streamed pass over the dense model).
    pub fn needs_sensitivity(&self) -> bool {
        matches!(self.budget, BudgetMode::GramSensitivity { .. })
    }

    /// Resolved policy for one site, plus the indices of the rules
    /// that fired and which policy fields a rule pinned explicitly.
    fn policy_for(&self, site: &SiteInfo, index: usize) -> (SitePolicy, Vec<usize>, PinnedFields) {
        let mut p = self.defaults;
        let mut applied = Vec::new();
        let mut pinned = PinnedFields { ratio: false, alpha: false };
        for (ri, rule) in self.rules.iter().enumerate() {
            if rule.matcher.matches(site, index) {
                rule.set.apply(&mut p);
                pinned.ratio |= rule.set.ratio.is_some();
                pinned.alpha |= rule.set.alpha.is_some();
                applied.push(ri);
            }
        }
        (p, applied, pinned)
    }

    /// Which policy fields the spec's rules pin for `site` —
    /// `(ratio_pinned, alpha_pinned)`. The plan search freezes exactly
    /// these, mirroring the resolve-time contract that explicit rules
    /// win over budget allocation.
    pub(super) fn rule_pins(&self, site: &SiteInfo, index: usize) -> (bool, bool) {
        let (_, _, pinned) = self.policy_for(site, index);
        (pinned.ratio, pinned.alpha)
    }

    /// Resolve the spec into a concrete plan for `sites`.
    /// `sensitivities` (per-site, same order) is required exactly when
    /// [`needs_sensitivity`](Self::needs_sensitivity) — the pipeline's
    /// [`plan_for_model`](super::pipeline::plan_for_model) computes it.
    /// The `search` budget mode accepts them *optionally* as seed
    /// weights ([`SearchSeed::GramSensitivity`], supplied by
    /// [`search_plan`](super::search::search_plan) from its own
    /// statistics pass); `None` seeds uniformly.
    pub fn resolve(
        &self,
        sites: &[SiteInfo],
        sensitivities: Option<&[f64]>,
    ) -> Result<CompressionPlan> {
        let n = sites.len();
        let mut planned: Vec<PlannedSite> = Vec::with_capacity(n);
        let mut pinned = vec![false; n];
        for (i, s) in sites.iter().enumerate() {
            let (policy, rules_applied, pins) = self.policy_for(s, i);
            pinned[i] = pins.ratio;
            planned.push(PlannedSite {
                id: s.id.clone(),
                index: i,
                units: s.units,
                unit_dim: s.unit_dim,
                groups: s.groups,
                kind: s.kind,
                keep: uniform_keep(s.units, s.groups, policy.ratio),
                policy,
                rules_applied,
            });
        }
        match &self.budget {
            BudgetMode::PerSite => {}
            BudgetMode::DepthRamp { target_ratio, gamma } => {
                for ps in planned.iter_mut() {
                    if pinned[ps.index] {
                        continue;
                    }
                    let pos = if n <= 1 { 0.5 } else { ps.index as f64 / (n - 1) as f64 };
                    let ratio =
                        (target_ratio * (1.0 + gamma * (2.0 * pos - 1.0))).clamp(0.0, 0.95);
                    ps.policy.ratio = ratio;
                    ps.keep = uniform_keep(ps.units, ps.groups, ratio);
                }
            }
            BudgetMode::GramSensitivity { target_ratio } => {
                let sens = sensitivities.ok_or_else(|| {
                    anyhow!("gram-sensitivity budget needs per-site sensitivities")
                })?;
                if sens.len() != n {
                    bail!("got {} sensitivities for {n} sites", sens.len());
                }
                allocate_by_sensitivity(&mut planned, &pinned, sens, *target_ratio);
            }
            BudgetMode::Search { target_ratio, .. } => {
                // Seed allocation only: budget-conserving at
                // `target_ratio` with the per-site rounding drift
                // walked back to the exact unit budget. Weights are
                // uniform (allocation proportional to site size) unless
                // the caller supplies per-site sensitivities —
                // `search_plan` does for the gram-sensitivity seed,
                // derived from its own statistics pass. The α/keep
                // search itself needs model statistics and runs in
                // `plan_for_model`.
                match sensitivities {
                    Some(sens) => {
                        if sens.len() != n {
                            bail!("got {} sensitivities for {n} sites", sens.len());
                        }
                        allocate_by_sensitivity(&mut planned, &pinned, sens, *target_ratio);
                    }
                    None => {
                        let ones = vec![1.0f64; n];
                        allocate_by_sensitivity(&mut planned, &pinned, &ones, *target_ratio);
                    }
                }
            }
        }
        Ok(CompressionPlan {
            sites: planned,
            seed: self.seed,
            closed_loop: self.closed_loop,
            shards: self.shards,
            workers: self.workers,
        })
    }

    /// Load a spec from parsed TOML-subset config. Reads the
    /// `[pipeline]`, `[budget]`, and `[rule.N]` sections; other
    /// sections (e.g. the runner's `[model]`) are ignored.
    pub fn from_config(cfg: &Config) -> Result<Self> {
        for key in cfg.keys() {
            if let Some(field) = key.strip_prefix("pipeline.") {
                if !matches!(
                    field,
                    "method" | "ratio" | "grail" | "alpha" | "seed" | "closed_loop" | "shards"
                        | "workers"
                ) {
                    bail!("unknown spec key `{key}`");
                }
            } else if let Some(field) = key.strip_prefix("budget.") {
                if !matches!(
                    field,
                    "mode" | "target_ratio" | "gamma" | "alpha_grid" | "rounds" | "seed"
                ) {
                    bail!("unknown spec key `{key}`");
                }
            }
        }
        let method_name = cfg.str_or("pipeline.method", "wanda");
        let method = Method::from_name(method_name)
            .ok_or_else(|| anyhow!("pipeline.method: unknown method `{method_name}`"))?;
        let ratio = cfg.f64_or("pipeline.ratio", 0.5);
        let grail = match cfg.get("pipeline.grail") {
            Some(_) => cfg.bool("pipeline.grail")?,
            None => true,
        };
        let mut spec = CompressionSpec::uniform(method, ratio, grail);
        spec.defaults.alpha = cfg.f64_or("pipeline.alpha", super::DEFAULT_ALPHA as f64) as f32;
        spec.seed = cfg.usize_or("pipeline.seed", 0) as u64;
        spec.closed_loop = match cfg.get("pipeline.closed_loop") {
            Some(_) => cfg.bool("pipeline.closed_loop")?,
            None => true,
        };
        spec.shards = cfg.usize_or("pipeline.shards", 0);
        spec.workers = cfg.usize_or("pipeline.workers", 0);
        spec.budget = match cfg.str_or("budget.mode", "per-site") {
            "per-site" => BudgetMode::PerSite,
            "depth-ramp" => BudgetMode::DepthRamp {
                target_ratio: cfg.f64_or("budget.target_ratio", ratio),
                gamma: cfg.f64_or("budget.gamma", 0.5),
            },
            "gram-sensitivity" => BudgetMode::GramSensitivity {
                target_ratio: cfg.f64_or("budget.target_ratio", ratio),
            },
            "search" => {
                let alpha_grid = match cfg.get("budget.alpha_grid") {
                    Some(_) => cfg.f64_array("budget.alpha_grid")?,
                    None => DEFAULT_ALPHA_GRID.to_vec(),
                };
                if alpha_grid.is_empty()
                    || alpha_grid.iter().any(|&a| !a.is_finite() || a <= 0.0)
                {
                    bail!(
                        "budget.alpha_grid: need a non-empty list of positive finite α values"
                    );
                }
                BudgetMode::Search {
                    target_ratio: cfg.f64_or("budget.target_ratio", ratio),
                    alpha_grid,
                    rounds: cfg.usize_or("budget.rounds", DEFAULT_SEARCH_ROUNDS),
                }
            }
            other => bail!("budget.mode: unknown allocator `{other}`"),
        };
        spec.search_seed = match cfg.str_or("budget.seed", "uniform") {
            "uniform" => SearchSeed::Uniform,
            "gram-sensitivity" => SearchSeed::GramSensitivity,
            other => bail!(
                "budget.seed: unknown seed mode `{other}` (expected `uniform` or \
                 `gram-sensitivity`)"
            ),
        };
        if spec.search_seed != SearchSeed::Uniform
            && !matches!(spec.budget, BudgetMode::Search { .. })
        {
            bail!("budget.seed applies only to `budget.mode = \"search\"`");
        }
        spec.rules = parse_rules(cfg)?;
        Ok(spec)
    }
}

/// Parse the ordered `[rule.N]` sections of a spec file.
fn parse_rules(cfg: &Config) -> Result<Vec<PolicyRule>> {
    let mut indices: Vec<usize> = Vec::new();
    for key in cfg.keys() {
        if let Some(rest) = key.strip_prefix("rule.") {
            let (idx, field) = rest
                .split_once('.')
                .ok_or_else(|| anyhow!("`{key}`: expected `rule.<index>.<field>`"))?;
            let n: usize = idx
                .parse()
                .map_err(|_| anyhow!("`{key}`: rule index `{idx}` is not an integer"))?;
            if !matches!(
                field,
                "match_id" | "match_kind" | "match_depth" | "method" | "ratio" | "grail"
                    | "alpha"
            ) {
                bail!("unknown rule key `{key}`");
            }
            if !indices.contains(&n) {
                indices.push(n);
            }
        }
    }
    indices.sort_unstable();
    let mut rules = Vec::with_capacity(indices.len());
    for n in indices {
        let k = |f: &str| format!("rule.{n}.{f}");
        let mut matcher = SiteMatcher::default();
        if cfg.get(&k("match_id")).is_some() {
            matcher.id_glob = Some(cfg.str(&k("match_id"))?.to_string());
        }
        if cfg.get(&k("match_kind")).is_some() {
            let name = cfg.str(&k("match_kind"))?;
            matcher.kind = Some(
                SiteKind::from_name(name)
                    .ok_or_else(|| anyhow!("rule.{n}.match_kind: unknown kind `{name}`"))?,
            );
        }
        if cfg.get(&k("match_depth")).is_some() {
            let range = cfg.f64_array(&k("match_depth"))?;
            if range.len() != 2 || range[0] < 0.0 || range[1] < range[0] {
                bail!("rule.{n}.match_depth: expected [lo, hi] with 0 <= lo <= hi");
            }
            // Depth bounds are site indices; a fractional bound would
            // silently truncate (`[0, 2.9]` behaving as `[0, 2]`), so
            // reject it outright.
            for &v in &range {
                if v.fract() != 0.0 || v > usize::MAX as f64 {
                    bail!("rule.{n}.match_depth: bound {v} is not an integer site index");
                }
            }
            matcher.depth = Some((range[0] as usize, range[1] as usize));
        }
        let mut set = PolicyOverrides::default();
        if cfg.get(&k("method")).is_some() {
            let name = cfg.str(&k("method"))?;
            set.method = Some(
                Method::from_name(name)
                    .ok_or_else(|| anyhow!("rule.{n}.method: unknown method `{name}`"))?,
            );
        }
        if cfg.get(&k("ratio")).is_some() {
            set.ratio = Some(cfg.f64(&k("ratio"))?);
        }
        if cfg.get(&k("grail")).is_some() {
            set.grail = Some(cfg.bool(&k("grail"))?);
        }
        if cfg.get(&k("alpha")).is_some() {
            set.alpha = Some(cfg.f64(&k("alpha"))? as f32);
        }
        if set == PolicyOverrides::default() {
            bail!("rule.{n}: sets no policy field (method/ratio/grail/alpha)");
        }
        rules.push(PolicyRule { matcher, set });
    }
    Ok(rules)
}

/// One site of a resolved plan.
#[derive(Clone, Debug, PartialEq)]
pub struct PlannedSite {
    pub id: String,
    /// Forward position of the site.
    pub index: usize,
    pub units: usize,
    /// Features per unit (`d_head` for attention heads, 1 otherwise) —
    /// the per-unit parameter weight the search's budget accounting
    /// uses.
    pub unit_dim: usize,
    pub groups: usize,
    pub kind: SiteKind,
    /// Concrete unit count kept at this site (group-constrained).
    pub keep: usize,
    pub policy: SitePolicy,
    /// Indices of the spec rules that fired for this site.
    pub rules_applied: Vec<usize>,
}

/// A fully resolved compression plan: one [`PlannedSite`] per model
/// site, in forward order. Nothing is mutated until
/// [`execute_plan`](super::pipeline::execute_plan) runs it.
#[derive(Clone, Debug, PartialEq)]
pub struct CompressionPlan {
    pub sites: Vec<PlannedSite>,
    pub seed: u64,
    pub closed_loop: bool,
    pub shards: usize,
    pub workers: usize,
}

impl CompressionPlan {
    /// Total units kept across sites.
    pub fn total_keep(&self) -> usize {
        self.sites.iter().map(|s| s.keep).sum()
    }

    /// Total units before compression.
    pub fn total_units(&self) -> usize {
        self.sites.iter().map(|s| s.units).sum()
    }

    /// Kept units weighted by per-unit feature width `Σ keep·unit_dim`
    /// — the parameter-proportional budget the plan search conserves.
    pub fn total_keep_weighted(&self) -> usize {
        self.sites.iter().map(|s| s.keep * s.unit_dim).sum()
    }

    /// Pre-compression weighted units `Σ units·unit_dim`.
    pub fn total_units_weighted(&self) -> usize {
        self.sites.iter().map(|s| s.units * s.unit_dim).sum()
    }

    /// Human-readable table for `grail plan`.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<3} {:<16} {:<10} {:>5} {:>5} {:>6}  {:<12} {:>5} {:>8}  rules\n",
            "#", "site", "kind", "units", "keep", "ratio", "method", "grail", "alpha"
        ));
        for s in &self.sites {
            let rules = if s.rules_applied.is_empty() {
                "-".to_string()
            } else {
                s.rules_applied
                    .iter()
                    .map(|r| r.to_string())
                    .collect::<Vec<_>>()
                    .join(",")
            };
            out.push_str(&format!(
                "{:<3} {:<16} {:<10} {:>5} {:>5} {:>6.2}  {:<12} {:>5} {:>8.1e}  {}\n",
                s.index,
                s.id,
                s.kind.name(),
                s.units,
                s.keep,
                s.policy.ratio,
                s.policy.method.name(),
                if s.policy.grail { "yes" } else { "no" },
                s.policy.alpha,
                rules
            ));
        }
        out.push_str(&format!(
            "total units {} -> {} (seed {}, {} loop, shards {}, workers {})\n",
            self.total_units(),
            self.total_keep(),
            self.seed,
            if self.closed_loop { "closed" } else { "open" },
            self.shards,
            self.workers
        ));
        out
    }

    /// Serialize to the TOML subset. Lossless: floats print in their
    /// shortest round-trip form and ids escape `\`, `"`, newline, and
    /// tab, so [`Self::parse`] reconstructs an identical plan
    /// (`rust/tests/plan_invariants.rs::prop_plan_toml_roundtrip`).
    /// One bound: the config layer stores integers as `i64`, so seeds
    /// above `i64::MAX` serialize but fail to parse back — loudly, not
    /// lossily.
    pub fn to_toml(&self) -> String {
        let esc = |s: &str| {
            s.replace('\\', "\\\\")
                .replace('"', "\\\"")
                .replace('\n', "\\n")
                .replace('\t', "\\t")
        };
        let mut out = String::new();
        out.push_str("[plan]\n");
        out.push_str(&format!("seed = {}\n", self.seed));
        out.push_str(&format!("closed_loop = {}\n", self.closed_loop));
        out.push_str(&format!("shards = {}\n", self.shards));
        out.push_str(&format!("workers = {}\n\n", self.workers));
        for s in &self.sites {
            let rules = s
                .rules_applied
                .iter()
                .map(|r| r.to_string())
                .collect::<Vec<_>>()
                .join(", ");
            out.push_str(&format!("[site.{}]\n", s.index));
            out.push_str(&format!("id = \"{}\"\n", esc(&s.id)));
            out.push_str(&format!("kind = \"{}\"\n", s.kind.name()));
            out.push_str(&format!("units = {}\n", s.units));
            out.push_str(&format!("unit_dim = {}\n", s.unit_dim));
            out.push_str(&format!("groups = {}\n", s.groups));
            out.push_str(&format!("keep = {}\n", s.keep));
            out.push_str(&format!("method = \"{}\"\n", esc(&s.policy.method.name())));
            // `{}` prints the shortest decimal that parses back to the
            // same float — `{:.6}` truncated and broke round-trips.
            out.push_str(&format!("ratio = {}\n", s.policy.ratio));
            out.push_str(&format!("grail = {}\n", s.policy.grail));
            out.push_str(&format!("alpha = {}\n", s.policy.alpha));
            out.push_str(&format!("rules = [{rules}]\n\n"));
        }
        out
    }

    /// Parse a serialized plan text ([`Self::to_toml`]'s inverse).
    pub fn parse(text: &str) -> Result<CompressionPlan> {
        Self::from_config(&Config::parse(text)?)
    }

    /// Reconstruct a plan from parsed config (`[plan]` + `[site.N]`
    /// sections). Rejects unknown keys, non-contiguous site indices,
    /// and out-of-range keep counts.
    pub fn from_config(cfg: &Config) -> Result<CompressionPlan> {
        let mut indices: Vec<usize> = Vec::new();
        for key in cfg.keys() {
            if let Some(field) = key.strip_prefix("plan.") {
                if !matches!(field, "seed" | "closed_loop" | "shards" | "workers") {
                    bail!("unknown plan key `{key}`");
                }
            } else if let Some(rest) = key.strip_prefix("site.") {
                let (idx, field) = rest
                    .split_once('.')
                    .ok_or_else(|| anyhow!("`{key}`: expected `site.<index>.<field>`"))?;
                if !matches!(
                    field,
                    "id" | "kind" | "units" | "unit_dim" | "groups" | "keep" | "method"
                        | "ratio" | "grail" | "alpha" | "rules"
                ) {
                    bail!("unknown plan key `{key}`");
                }
                let n: usize = idx
                    .parse()
                    .map_err(|_| anyhow!("`{key}`: site index `{idx}` is not an integer"))?;
                if !indices.contains(&n) {
                    indices.push(n);
                }
            }
        }
        indices.sort_unstable();
        let mut sites = Vec::with_capacity(indices.len());
        for (pos, &n) in indices.iter().enumerate() {
            if n != pos {
                bail!("plan site indices must be contiguous from 0 (missing site.{pos})");
            }
            let k = |f: &str| format!("site.{n}.{f}");
            let kind_name = cfg.str(&k("kind"))?;
            let kind = SiteKind::from_name(kind_name)
                .ok_or_else(|| anyhow!("site.{n}.kind: unknown kind `{kind_name}`"))?;
            let method_name = cfg.str(&k("method"))?;
            let method = Method::from_name(method_name)
                .ok_or_else(|| anyhow!("site.{n}.method: unknown method `{method_name}`"))?;
            let units = cfg.usize(&k("units"))?;
            let unit_dim = cfg.usize(&k("unit_dim"))?;
            let groups = cfg.usize(&k("groups"))?;
            let keep = cfg.usize(&k("keep"))?;
            if units == 0 || unit_dim == 0 || groups == 0 {
                bail!("site.{n}: units/unit_dim/groups must be positive");
            }
            if keep == 0 || keep > units {
                bail!("site.{n}: keep {keep} out of range for {units} units");
            }
            let mut rules_applied = Vec::new();
            if cfg.get(&k("rules")).is_some() {
                for v in cfg.f64_array(&k("rules"))? {
                    if v.fract() != 0.0 || v < 0.0 {
                        bail!("site.{n}.rules: `{v}` is not a rule index");
                    }
                    rules_applied.push(v as usize);
                }
            }
            sites.push(PlannedSite {
                id: cfg.str(&k("id"))?.to_string(),
                index: n,
                units,
                unit_dim,
                groups,
                kind,
                keep,
                policy: SitePolicy {
                    method,
                    ratio: cfg.f64(&k("ratio"))?,
                    grail: cfg.bool(&k("grail"))?,
                    alpha: cfg.f64(&k("alpha"))? as f32,
                },
                rules_applied,
            });
        }
        Ok(CompressionPlan {
            sites,
            seed: cfg.usize("plan.seed")? as u64,
            closed_loop: cfg.bool("plan.closed_loop")?,
            shards: cfg.usize("plan.shards")?,
            workers: cfg.usize("plan.workers")?,
        })
    }
}

/// Keep count clamped to the site's group structure (mirrors
/// [`uniform_keep`]'s constraints: ≥1 unit per group, multiples of
/// `groups` for divisible grouped sites).
fn constrain_keep(units: usize, groups: usize, keep: usize) -> usize {
    let g = groups.max(1);
    if units % g != 0 {
        return keep.clamp(1, units);
    }
    let per_group = units / g;
    let kpg = ((keep as f64) / g as f64).round() as usize;
    kpg.clamp(1, per_group) * g
}

/// Smallest step by which a site's keep count can change.
pub(super) fn keep_step(units: usize, groups: usize) -> usize {
    let g = groups.max(1);
    if g > 1 && units % g == 0 {
        g
    } else {
        1
    }
}

/// Smallest admissible keep count for a site.
pub(super) fn keep_floor(units: usize, groups: usize) -> usize {
    let g = groups.max(1);
    if g > 1 && units % g == 0 {
        g
    } else {
        1
    }
}

/// Distribute a global unit budget over the non-pinned sites
/// proportionally to sensitivity, then walk the rounding drift back to
/// the target greedily (shrink the least sensitive site first, grow the
/// most sensitive). Deterministic: ties break on site index.
fn allocate_by_sensitivity(
    planned: &mut [PlannedSite],
    pinned: &[bool],
    sens: &[f64],
    target_ratio: f64,
) {
    let free: Vec<usize> =
        (0..planned.len()).filter(|&i| !pinned[i] && planned[i].units > 0).collect();
    if free.is_empty() {
        return;
    }
    let total_units: usize = free.iter().map(|&i| planned[i].units).sum();
    let target_keep = ((total_units as f64) * (1.0 - target_ratio)).round() as usize;
    let min_total: usize =
        free.iter().map(|&i| keep_floor(planned[i].units, planned[i].groups)).sum();
    let target_keep = target_keep.clamp(min_total, total_units);
    // Guard degenerate signals (all-zero sensitivity → uniform).
    let weight = |i: usize| sens[i].max(1e-12);
    let denom: f64 = free.iter().map(|&i| weight(i) * planned[i].units as f64).sum();
    for &i in &free {
        let raw = target_keep as f64 * weight(i) * planned[i].units as f64 / denom.max(1e-300);
        planned[i].keep =
            constrain_keep(planned[i].units, planned[i].groups, raw.round() as usize);
    }
    // Walk rounding drift back toward the target.
    let mut total: usize = free.iter().map(|&i| planned[i].keep).sum();
    while total > target_keep {
        // Shrink the least sensitive site that can still shrink.
        let cand = free
            .iter()
            .copied()
            .filter(|&i| {
                planned[i].keep
                    >= keep_floor(planned[i].units, planned[i].groups)
                        + keep_step(planned[i].units, planned[i].groups)
            })
            .min_by(|&a, &b| weight(a).total_cmp(&weight(b)).then(a.cmp(&b)));
        let Some(i) = cand else { break };
        let step = keep_step(planned[i].units, planned[i].groups);
        planned[i].keep -= step;
        total -= step;
    }
    while total < target_keep {
        // Grow the most sensitive site that has headroom.
        let cand = free
            .iter()
            .copied()
            .filter(|&i| {
                planned[i].keep + keep_step(planned[i].units, planned[i].groups)
                    <= planned[i].units
            })
            .max_by(|&a, &b| weight(a).total_cmp(&weight(b)).then(b.cmp(&a)));
        let Some(i) = cand else { break };
        let step = keep_step(planned[i].units, planned[i].groups);
        planned[i].keep += step;
        total += step;
    }
    for &i in &free {
        planned[i].policy.ratio = 1.0 - planned[i].keep as f64 / planned[i].units as f64;
    }
}

/// Minimal glob: `*` matches any substring (including empty), `?` any
/// single character; everything else is literal. Site ids are ASCII.
///
/// Iterative two-pointer wildcard match: on a mismatch after a `*`,
/// the star's match greedily absorbs one more input character and the
/// tail retries from just past the star. Each retry advances the
/// star's anchor, so the walk is O(|pattern|·|s|) worst case and uses
/// no recursion — the previous backtracking-recursive version was
/// exponential on patterns like `*a*a*a*a*` against non-matching ids
/// and recursed O(|s|) deep (a stack-overflow risk on the long site
/// ids deep models produce).
pub fn glob_match(pattern: &str, s: &str) -> bool {
    let p = pattern.as_bytes();
    let t = s.as_bytes();
    let (mut pi, mut ti) = (0usize, 0usize);
    // Most recent `*` in the pattern and the input position its match
    // currently ends at.
    let mut star: Option<(usize, usize)> = None;
    while ti < t.len() {
        // `*` must be tested first: a literal `*` byte in the input
        // would otherwise satisfy the equality branch and silently
        // demote the wildcard to a one-character literal.
        if pi < p.len() && p[pi] == b'*' {
            star = Some((pi, ti));
            pi += 1;
        } else if pi < p.len() && (p[pi] == b'?' || p[pi] == t[ti]) {
            pi += 1;
            ti += 1;
        } else if let Some((sp, st)) = star {
            // Backtrack: widen the star's match by one character.
            star = Some((sp, st + 1));
            pi = sp + 1;
            ti = st + 1;
        } else {
            return false;
        }
    }
    // Only trailing stars may remain unconsumed.
    p[pi..].iter().all(|&c| c == b'*')
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Selector;

    fn site(id: &str, units: usize, groups: usize, kind: SiteKind) -> SiteInfo {
        SiteInfo { id: id.into(), units, unit_dim: 1, groups, kind }
    }

    fn lm_like_sites() -> Vec<SiteInfo> {
        (0..4)
            .flat_map(|i| {
                [
                    site(&format!("block{i}.attn"), 8, 1, SiteKind::AttnHeads),
                    site(&format!("block{i}.mlp"), 32, 1, SiteKind::MlpPair),
                ]
            })
            .collect()
    }

    #[test]
    fn glob_basics() {
        assert!(glob_match("block*.attn", "block0.attn"));
        assert!(glob_match("block*.attn", "block12.attn"));
        assert!(!glob_match("block*.attn", "block0.mlp"));
        assert!(glob_match("*", "anything"));
        assert!(glob_match("*", ""));
        assert!(glob_match("block?.mlp", "block3.mlp"));
        assert!(!glob_match("block?.mlp", "block12.mlp"));
        assert!(glob_match("fc1>fc2", "fc1>fc2"));
    }

    #[test]
    fn glob_multi_star_patterns() {
        assert!(glob_match("*a*b*", "xxaxxbxx"));
        assert!(glob_match("*a*b*", "ab"));
        assert!(!glob_match("*a*b*", "xxbxxaxx"));
        assert!(glob_match("**", "anything"));
        assert!(glob_match("a**b", "ab"));
        assert!(glob_match("a**b", "a123b"));
        assert!(!glob_match("a*b", "a"));
        assert!(glob_match("*.mlp", "encoder.block17.layer.3.mlp"));
        assert!(!glob_match("*.mlp", "encoder.block17.layer.3.attn"));
        assert!(glob_match("block*.*.proj?", "block9.attn.proj2"));
        // `?` must not match the empty string, even after a star.
        assert!(!glob_match("*?", ""));
        assert!(glob_match("*?", "x"));
        // A literal `*` byte in the *input* must not demote a pattern
        // wildcard to a one-character literal (branch-order regression).
        assert!(glob_match("*b", "*ab"));
        assert!(glob_match("*", "**"));
        assert!(!glob_match("?b", "*a"));
    }

    #[test]
    fn glob_pathological_pattern_is_fast() {
        // Regression: the recursive matcher was exponential here —
        // `*a*a*a*…` against a long all-`a` id that fails only at the
        // final literal forced ~2^k backtracks (effectively a hang) and
        // recursed O(|id|) deep. The iterative matcher is O(p·s).
        let id = "a".repeat(4000) + "b";
        let pattern = "*a".repeat(24) + "*c";
        let t0 = std::time::Instant::now();
        assert!(!glob_match(&pattern, &id));
        // Matching variant of the same shape, same budget.
        let pattern_ok = "*a".repeat(24) + "*b";
        assert!(glob_match(&pattern_ok, &id));
        assert!(
            t0.elapsed() < std::time::Duration::from_millis(500),
            "pathological glob took {:?}",
            t0.elapsed()
        );
    }

    #[test]
    fn uniform_spec_resolves_layerwise_uniform() {
        let sites = lm_like_sites();
        let spec = CompressionSpec::uniform(Method::Fold, 0.5, true);
        let plan = spec.resolve(&sites, None).unwrap();
        assert_eq!(plan.sites.len(), 8);
        for (ps, s) in plan.sites.iter().zip(&sites) {
            assert_eq!(ps.id, s.id);
            assert_eq!(ps.keep, uniform_keep(s.units, s.groups, 0.5));
            assert_eq!(ps.policy.ratio, 0.5);
            assert_eq!(ps.policy.method, Method::Fold);
            assert!(ps.rules_applied.is_empty());
        }
    }

    #[test]
    fn rules_match_and_later_rules_win() {
        let sites = lm_like_sites();
        let mut spec = CompressionSpec::uniform(Method::Prune(Selector::MagnitudeL2), 0.5, true);
        spec.rules = vec![
            // All attention sites: gentler ratio.
            PolicyRule {
                matcher: SiteMatcher {
                    kind: Some(SiteKind::AttnHeads),
                    ..Default::default()
                },
                set: PolicyOverrides { ratio: Some(0.25), ..Default::default() },
            },
            // Deep half: fold instead of prune.
            PolicyRule {
                matcher: SiteMatcher { depth: Some((4, 7)), ..Default::default() },
                set: PolicyOverrides { method: Some(Method::Fold), ..Default::default() },
            },
            // One specific site by glob: no GRAIL, pinned ratio.
            PolicyRule {
                matcher: SiteMatcher {
                    id_glob: Some("block3.mlp".into()),
                    ..Default::default()
                },
                set: PolicyOverrides {
                    grail: Some(false),
                    ratio: Some(0.75),
                    ..Default::default()
                },
            },
        ];
        let plan = spec.resolve(&sites, None).unwrap();
        // block0.attn: rule 0 only.
        assert_eq!(plan.sites[0].policy.ratio, 0.25);
        assert_eq!(plan.sites[0].keep, 6);
        assert_eq!(plan.sites[0].rules_applied, vec![0]);
        // block0.mlp: default.
        assert_eq!(plan.sites[1].policy.ratio, 0.5);
        assert_eq!(plan.sites[1].keep, 16);
        // block2.attn (index 4): rules 0 and 1 — folded attention at 0.25.
        assert_eq!(plan.sites[4].policy.method, Method::Fold);
        assert_eq!(plan.sites[4].policy.ratio, 0.25);
        assert_eq!(plan.sites[4].rules_applied, vec![0, 1]);
        // block3.mlp (index 7): rules 1 and 2 — fold, no GRAIL, 0.75.
        let last = &plan.sites[7];
        assert_eq!(last.policy.method, Method::Fold);
        assert!(!last.policy.grail);
        assert_eq!(last.policy.ratio, 0.75);
        assert_eq!(last.keep, 8);
        assert_eq!(last.rules_applied, vec![1, 2]);
    }

    #[test]
    fn depth_ramp_ramps_and_preserves_mean() {
        let sites: Vec<SiteInfo> =
            (0..5).map(|i| site(&format!("s{i}"), 100, 1, SiteKind::Dense)).collect();
        let mut spec = CompressionSpec::uniform(Method::Fold, 0.5, true);
        spec.budget = BudgetMode::DepthRamp { target_ratio: 0.5, gamma: 0.6 };
        let plan = spec.resolve(&sites, None).unwrap();
        let ratios: Vec<f64> = plan.sites.iter().map(|s| s.policy.ratio).collect();
        for w in ratios.windows(2) {
            assert!(w[1] > w[0], "ratios must increase with depth: {ratios:?}");
        }
        let mean = ratios.iter().sum::<f64>() / ratios.len() as f64;
        assert!((mean - 0.5).abs() < 1e-9, "mean ratio {mean}");
        assert!((ratios[0] - 0.2).abs() < 1e-9 && (ratios[4] - 0.8).abs() < 1e-9);
    }

    #[test]
    fn depth_ramp_respects_pinned_rules_and_clamps() {
        let sites: Vec<SiteInfo> =
            (0..3).map(|i| site(&format!("s{i}"), 10, 1, SiteKind::Dense)).collect();
        let mut spec = CompressionSpec::uniform(Method::Fold, 0.5, true);
        spec.budget = BudgetMode::DepthRamp { target_ratio: 0.6, gamma: 1.0 };
        spec.rules = vec![PolicyRule {
            matcher: SiteMatcher { id_glob: Some("s1".into()), ..Default::default() },
            set: PolicyOverrides { ratio: Some(0.1), ..Default::default() },
        }];
        let plan = spec.resolve(&sites, None).unwrap();
        // s0: 0.6·(1−1) = 0.0; s2 would be 1.2 → clamped to 0.95.
        assert_eq!(plan.sites[0].policy.ratio, 0.0);
        assert_eq!(plan.sites[0].keep, 10);
        assert_eq!(plan.sites[1].policy.ratio, 0.1, "rule-pinned site untouched");
        assert_eq!(plan.sites[2].policy.ratio, 0.95);
        assert_eq!(plan.sites[2].keep, 1);
    }

    #[test]
    fn gram_sensitivity_allocates_toward_energy() {
        let sites: Vec<SiteInfo> =
            (0..4).map(|i| site(&format!("s{i}"), 40, 1, SiteKind::Dense)).collect();
        let mut spec = CompressionSpec::uniform(Method::Fold, 0.5, true);
        spec.budget = BudgetMode::GramSensitivity { target_ratio: 0.5 };
        assert!(spec.needs_sensitivity());
        assert!(spec.resolve(&sites, None).is_err(), "must demand sensitivities");
        let sens = [4.0, 2.0, 1.0, 1.0];
        let plan = spec.resolve(&sites, Some(&sens)).unwrap();
        let keeps: Vec<usize> = plan.sites.iter().map(|s| s.keep).collect();
        // Budget hit exactly: 50% of 160 units.
        assert_eq!(keeps.iter().sum::<usize>(), 80);
        // Monotone in sensitivity.
        assert!(keeps[0] > keeps[1] && keeps[1] > keeps[2]);
        assert_eq!(keeps[2], keeps[3]);
        // Provenance ratios match the allocated keeps.
        for (ps, &k) in plan.sites.iter().zip(&keeps) {
            assert!((ps.policy.ratio - (1.0 - k as f64 / 40.0)).abs() < 1e-12);
        }
    }

    #[test]
    fn gram_sensitivity_respects_groups() {
        // GQA-like site: keeps must stay multiples of the group count.
        let sites = vec![
            site("attn", 8, 4, SiteKind::AttnHeads),
            site("mlp", 32, 1, SiteKind::MlpPair),
        ];
        let mut spec = CompressionSpec::uniform(Method::Fold, 0.5, true);
        spec.budget = BudgetMode::GramSensitivity { target_ratio: 0.5 };
        let plan = spec.resolve(&sites, Some(&[5.0, 1.0])).unwrap();
        assert_eq!(plan.sites[0].keep % 4, 0);
        assert!(plan.sites[0].keep >= 4);
        assert!(plan.sites[1].keep >= 1);
    }

    #[test]
    fn plan_renders_and_serializes() {
        let sites = lm_like_sites();
        let mut spec = CompressionSpec::uniform(Method::Prune(Selector::Wanda), 0.5, true);
        spec.seed = 7;
        let plan = spec.resolve(&sites, None).unwrap();
        let rendered = plan.render();
        assert!(rendered.contains("block0.attn"));
        assert!(rendered.contains("prune-wanda"));
        assert!(rendered.contains("total units 160 -> 80"));
        // TOML round-trip through the config parser.
        let toml = plan.to_toml();
        let cfg = Config::parse(&toml).unwrap();
        assert_eq!(cfg.usize("plan.seed").unwrap(), 7);
        assert!(cfg.bool("plan.closed_loop").unwrap());
        assert_eq!(cfg.str("site.0.id").unwrap(), "block0.attn");
        assert_eq!(cfg.str("site.0.method").unwrap(), "prune-wanda");
        assert_eq!(cfg.usize("site.7.keep").unwrap(), 16);
    }

    #[test]
    fn spec_parses_from_toml() {
        let text = r#"
[model]
family = "lm"            # ignored here (runner metadata)

[pipeline]
method = "prune-wanda"
ratio = 0.4
grail = true
alpha = 0.001
seed = 9
shards = 4

[budget]
mode = "depth-ramp"
target_ratio = 0.4
gamma = 0.8

[rule.0]
match_kind = "attn-heads"
ratio = 0.25

[rule.1]
match_id = "block3.*"
match_depth = [6, 7]
method = "fold"
grail = false
"#;
        let cfg = Config::parse(text).unwrap();
        let spec = CompressionSpec::from_config(&cfg).unwrap();
        assert_eq!(spec.defaults.method, Method::Prune(Selector::Wanda));
        assert_eq!(spec.defaults.ratio, 0.4);
        assert_eq!(spec.defaults.alpha, 0.001);
        assert_eq!(spec.seed, 9);
        assert_eq!(spec.shards, 4);
        assert!(spec.closed_loop);
        assert_eq!(
            spec.budget,
            BudgetMode::DepthRamp { target_ratio: 0.4, gamma: 0.8 }
        );
        assert_eq!(spec.rules.len(), 2);
        assert_eq!(spec.rules[0].matcher.kind, Some(SiteKind::AttnHeads));
        assert_eq!(spec.rules[0].set.ratio, Some(0.25));
        assert_eq!(spec.rules[1].matcher.id_glob.as_deref(), Some("block3.*"));
        assert_eq!(spec.rules[1].matcher.depth, Some((6, 7)));
        assert_eq!(spec.rules[1].set.method, Some(Method::Fold));
        assert_eq!(spec.rules[1].set.grail, Some(false));
    }

    #[test]
    fn spec_toml_errors_are_helpful() {
        let bad_key = Config::parse("[pipeline]\nmehtod = \"fold\"").unwrap();
        let err = CompressionSpec::from_config(&bad_key).unwrap_err().to_string();
        assert!(err.contains("pipeline.mehtod"), "{err}");

        let bad_method = Config::parse("[pipeline]\nmethod = \"nope\"").unwrap();
        assert!(CompressionSpec::from_config(&bad_method).is_err());

        let bad_mode = Config::parse("[budget]\nmode = \"psychic\"").unwrap();
        assert!(CompressionSpec::from_config(&bad_mode).is_err());

        let empty_rule = Config::parse("[rule.0]\nmatch_id = \"x\"").unwrap();
        let err = CompressionSpec::from_config(&empty_rule).unwrap_err().to_string();
        assert!(err.contains("sets no policy field"), "{err}");

        let bad_depth = Config::parse("[rule.0]\nmatch_depth = [5, 2]\nratio = 0.1").unwrap();
        assert!(CompressionSpec::from_config(&bad_depth).is_err());

        let bad_rule_key = Config::parse("[rule.0]\nratoi = 0.5").unwrap();
        assert!(CompressionSpec::from_config(&bad_rule_key).is_err());
    }

    #[test]
    fn fractional_match_depth_is_rejected() {
        // Regression: `[0, 2.9]` used to silently truncate to `[0, 2]`.
        let frac_hi = Config::parse("[rule.0]\nmatch_depth = [0, 2.9]\nratio = 0.1").unwrap();
        let err = CompressionSpec::from_config(&frac_hi).unwrap_err().to_string();
        assert!(err.contains("not an integer"), "{err}");
        assert!(err.contains("2.9"), "{err}");

        let frac_lo = Config::parse("[rule.0]\nmatch_depth = [0.5, 3]\nratio = 0.1").unwrap();
        assert!(CompressionSpec::from_config(&frac_lo).is_err());

        // Integral bounds still parse.
        let ok = Config::parse("[rule.0]\nmatch_depth = [0, 3]\nratio = 0.1").unwrap();
        let spec = CompressionSpec::from_config(&ok).unwrap();
        assert_eq!(spec.rules[0].matcher.depth, Some((0, 3)));
    }

    #[test]
    fn rule_order_is_numeric_not_lexical() {
        // rule.10 must apply after rule.2 (lexically "10" < "2").
        let text = r#"
[rule.2]
match_id = "*"
ratio = 0.3
[rule.10]
match_id = "*"
ratio = 0.7
"#;
        let cfg = Config::parse(text).unwrap();
        let spec = CompressionSpec::from_config(&cfg).unwrap();
        let sites = vec![site("a", 10, 1, SiteKind::Dense)];
        let plan = spec.resolve(&sites, None).unwrap();
        assert_eq!(plan.sites[0].policy.ratio, 0.7, "later (numeric) rule wins");
    }

    #[test]
    fn search_mode_parses_and_seeds_uniformly() {
        let text = r#"
[pipeline]
method = "prune-wanda"
ratio = 0.5

[budget]
mode = "search"
target_ratio = 0.5
alpha_grid = [1e-5, 1e-3]
rounds = 3
"#;
        let cfg = Config::parse(text).unwrap();
        let spec = CompressionSpec::from_config(&cfg).unwrap();
        assert_eq!(
            spec.budget,
            BudgetMode::Search {
                target_ratio: 0.5,
                alpha_grid: vec![1e-5, 1e-3],
                rounds: 3
            }
        );
        // Defaults: grid + rounds.
        let cfg = Config::parse("[budget]\nmode = \"search\"").unwrap();
        let spec2 = CompressionSpec::from_config(&cfg).unwrap();
        match &spec2.budget {
            BudgetMode::Search { alpha_grid, rounds, .. } => {
                assert_eq!(alpha_grid, &DEFAULT_ALPHA_GRID.to_vec());
                assert_eq!(*rounds, DEFAULT_SEARCH_ROUNDS);
            }
            other => panic!("wrong budget {other:?}"),
        }
        // Bad grids are rejected.
        let bad = Config::parse("[budget]\nmode = \"search\"\nalpha_grid = [0.0]").unwrap();
        assert!(CompressionSpec::from_config(&bad).is_err());
        let bad = Config::parse("[budget]\nmode = \"search\"\nalpha_grid = []").unwrap();
        assert!(CompressionSpec::from_config(&bad).is_err());

        // The seed plan is budget-conserving uniform at target_ratio.
        let sites: Vec<SiteInfo> =
            (0..3).map(|i| site(&format!("s{i}"), 30, 1, SiteKind::Dense)).collect();
        let plan = spec.resolve(&sites, None).unwrap();
        assert_eq!(plan.total_keep(), 45);
        for ps in &plan.sites {
            assert_eq!(ps.keep, 15);
        }
    }

    #[test]
    fn search_seed_parses_and_seeds_allocation() {
        let cfg =
            Config::parse("[budget]\nmode = \"search\"\nseed = \"gram-sensitivity\"").unwrap();
        let spec = CompressionSpec::from_config(&cfg).unwrap();
        assert_eq!(spec.search_seed, SearchSeed::GramSensitivity);
        // Default is the uniform seed.
        let cfg = Config::parse("[budget]\nmode = \"search\"").unwrap();
        let spec = CompressionSpec::from_config(&cfg).unwrap();
        assert_eq!(spec.search_seed, SearchSeed::Uniform);
        // Unknown seed modes and non-search budgets are rejected.
        let bad = Config::parse("[budget]\nmode = \"search\"\nseed = \"psychic\"").unwrap();
        assert!(CompressionSpec::from_config(&bad).is_err());
        let bad =
            Config::parse("[budget]\nmode = \"per-site\"\nseed = \"gram-sensitivity\"").unwrap();
        let err = CompressionSpec::from_config(&bad).unwrap_err().to_string();
        assert!(err.contains("budget.seed"), "{err}");

        // When sensitivities are supplied, the search seed allocates
        // toward energy under the same conserved unit budget.
        let sites: Vec<SiteInfo> =
            (0..2).map(|i| site(&format!("s{i}"), 20, 1, SiteKind::Dense)).collect();
        let mut spec = CompressionSpec::uniform(Method::Fold, 0.5, true);
        spec.budget =
            BudgetMode::Search { target_ratio: 0.5, alpha_grid: vec![1e-4], rounds: 1 };
        let plan = spec.resolve(&sites, Some(&[4.0, 1.0])).unwrap();
        assert_eq!(plan.total_keep(), 20, "seed conserves the unit budget");
        assert!(plan.sites[0].keep > plan.sites[1].keep, "{plan:?}");
        // Without sensitivities the seed stays uniform.
        let plan = spec.resolve(&sites, None).unwrap();
        assert_eq!(plan.sites[0].keep, 10);
        assert_eq!(plan.sites[1].keep, 10);
    }

    #[test]
    fn plan_toml_parses_back_identical() {
        let sites = vec![
            site("block0.attn", 8, 4, SiteKind::AttnHeads),
            site(r#"odd "id" \ with*glob"#, 32, 1, SiteKind::MlpPair),
        ];
        let mut spec = CompressionSpec::uniform(Method::Prune(Selector::Wanda), 0.37, true);
        spec.seed = 11;
        spec.rules = vec![PolicyRule {
            matcher: SiteMatcher { kind: Some(SiteKind::AttnHeads), ..Default::default() },
            set: PolicyOverrides { alpha: Some(1.5e-4), ..Default::default() },
        }];
        let plan = spec.resolve(&sites, None).unwrap();
        let back = CompressionPlan::parse(&plan.to_toml()).unwrap();
        assert_eq!(back, plan);
        // Malformed inputs are rejected, not mangled.
        assert!(CompressionPlan::parse("[plan]\nseed = 0").is_err());
        let mut missing = plan.clone();
        missing.sites[1].index = 2; // hole at index 1
        assert!(CompressionPlan::parse(&missing.to_toml()).is_err());
    }
}
