//! Calibration-driven plan search: per-site α tuning and keep
//! reallocation behind the spec surface (`budget.mode = "search"`).
//!
//! The ridge compensation is data-aware by construction — the same few
//! calibration forwards that build the Gram matrices can score
//! candidate plans with zero extra labels. [`search_plan`] turns that
//! into a closed, gradient-free optimization loop over resolved
//! [`CompressionPlan`]s:
//!
//! 1. **Statistics pass** — one streamed open-loop pass over the dense
//!    model accumulates per-shard [`ActStats`] at every site. Shards
//!    split into a *train* set (whose merged Grams the candidate ridge
//!    solves use) and a *held-out* set (whose Grams candidates are
//!    scored on), so α tuning measures generalization instead of
//!    in-sample fit — in-sample, the ridge residual is monotone in λ
//!    and the sweep would degenerate to "always pick the smallest α".
//! 2. **α sweep** — every GRAIL site whose rule set does not pin α is
//!    scored over the spec's log-grid; the per-site argmin wins (ties
//!    break toward the earlier grid entry).
//! 3. **Keep reallocation** — under the fixed weighted-unit budget
//!    `Σ keep·unit_dim` of the seed plan, units move from the site
//!    with the cheapest marginal error increase to the site with the
//!    largest marginal error decrease. Only strictly improving moves
//!    are accepted, so the loop terminates and the winning plan never
//!    scores worse than the seed.
//!
//! Candidate evaluations fan out over
//! [`run_grid`](crate::coordinator::scheduler::run_grid) with the same
//! disjoint-output discipline as the blocked solver: every job writes
//! its own result slot, each job is internally deterministic (pure
//! function of the spec seed and the shard-ordered statistics), and
//! all accept/reject decisions happen serially on the gathered
//! results — so the winning plan is **bit-identical at any worker
//! count** (`rust/tests/tune.rs`).

use super::pipeline::{per_shard_site_stats, Method, DEFAULT_SHARDS};
use super::spec::{
    keep_floor, keep_step, BudgetMode, CompressionPlan, CompressionSpec, SearchSeed,
};
use super::ActStats;
use crate::compress::select::{self, ScoreInputs, Selector};
use crate::compress::{fold, Compressible, Reducer, SiteInfo};
use crate::coordinator::scheduler::{default_threads, run_grid};
use crate::rng::Pcg64;
use crate::tensor::{ops, Tensor};
use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Outcome of one plan search.
#[derive(Clone, Debug)]
pub struct SearchOutcome {
    /// The winning plan — execute with [`super::execute_plan`], or
    /// persist via [`CompressionPlan::to_toml`] (`grail tune`).
    pub plan: CompressionPlan,
    /// Held-out global relative reconstruction error of the seed plan.
    pub initial_err: f64,
    /// Held-out global relative reconstruction error of the winner.
    pub final_err: f64,
    /// Search rounds actually run (≤ the spec's `rounds`; the loop
    /// stops early once a round accepts nothing).
    pub rounds_run: usize,
    /// Sites whose α moved off the seed value.
    pub alpha_moves: usize,
    /// Accepted keep-reallocation moves (grows, shrinks, and pairs
    /// each count once).
    pub keep_moves: usize,
    /// Candidate evaluations performed.
    pub evals: usize,
}

/// Per-site calibration statistics and selector inputs, gathered once
/// on the dense model.
struct SiteCal {
    info: SiteInfo,
    /// Finalized Gram statistics over the train shards.
    train: ActStats,
    /// Finalized Gram statistics over the held-out shards (a clone of
    /// `train` when only one shard exists).
    hold: ActStats,
    /// Diagonal of the train Gram (selector scores).
    gram_diag: Vec<f32>,
    l1: Vec<f32>,
    l2: Vec<f32>,
    consumer_cols: Vec<f32>,
    /// Producer features, only for folding-method sites.
    feats: Option<Tensor>,
}

/// One streamed open-loop pass over the dense model: per-site train +
/// held-out statistics plus the static selector inputs, and the
/// *actual* shard count the input split into (models clamp the
/// requested count to the available samples). Shard partial statistics
/// merge in shard order, so the result is independent of the worker
/// count.
fn gather_stats<M>(
    model: &M,
    calib: &M::Input,
    shards: usize,
    workers: usize,
) -> (Vec<SiteCal>, usize)
where
    M: Compressible + Sync,
    M::Input: Sync,
    M::CalibState: Send,
{
    let sites = model.sites();
    let widths: Vec<usize> = sites.iter().map(|s| s.feat_width()).collect();
    let shard_inputs: Vec<M::Input> = model.split_input(calib, shards);
    let per_shard = per_shard_site_stats(model, &shard_inputs, workers);
    // Last quarter of the shards (at least one, once two exist) holds
    // out; the split depends only on the shard count, never on worker
    // scheduling. With a single shard the score degrades to in-sample
    // fit (hold = train) — `search_plan` rejects that outright.
    let n_shards = per_shard.len();
    let n_hold = if n_shards >= 2 { (n_shards / 4).max(1) } else { 0 };
    let n_train = n_shards - n_hold;
    let cals = sites
        .into_iter()
        .enumerate()
        .map(|(si, info)| {
            let mut train = ActStats::new(widths[si]);
            for shard in &per_shard[..n_train] {
                train.merge(&shard[si]);
            }
            train.finalize();
            let hold = if n_hold == 0 {
                train.clone()
            } else {
                let mut h = ActStats::new(widths[si]);
                for shard in &per_shard[n_train..] {
                    h.merge(&shard[si]);
                }
                h.finalize();
                h
            };
            let gram_diag = select::gram_diag(&train.gram);
            let l1 = model.producer_row_norm(si, 1);
            let l2 = model.producer_row_norm(si, 2);
            let consumer_cols = ops::col_l2(&model.consumer_matrix(si));
            SiteCal { info, train, hold, gram_diag, l1, l2, consumer_cols, feats: None }
        })
        .collect();
    (cals, n_shards)
}

/// Deterministic reducer for a `(site, keep)` candidate — a pure
/// function of the plan seed, so evaluation order and worker count
/// cannot change it.
fn reducer_for(cal: &SiteCal, method: Method, keep: usize, seed: u64, site_idx: usize) -> Reducer {
    let mut rng =
        Pcg64::seed_stream(seed ^ 0x7E57_5EA4C, ((site_idx as u64) << 32) ^ keep as u64);
    let inputs = ScoreInputs {
        site: &cal.info,
        producer_l1: &cal.l1,
        producer_l2: &cal.l2,
        gram_diag: &cal.gram_diag,
        consumer_cols: &cal.consumer_cols,
    };
    match method {
        Method::Prune(sel) => select::select_reducer(sel, &inputs, keep, &mut rng),
        Method::Fold => fold::fold_reducer(
            cal.feats.as_ref().expect("fold-method site needs producer features"),
            &cal.info,
            keep,
            &mut rng,
        ),
        Method::RandomFold => fold::random_fold(&cal.info, keep, &mut rng),
        // Baselines carry their own recovery mechanism the search
        // cannot cheaply re-run per candidate; score them through the
        // Gram-energy selection proxy instead.
        Method::Baseline(_) => select::select_reducer(Selector::GramDiag, &inputs, keep, &mut rng),
    }
}

/// Held-out squared reconstruction error `tr(Eᵀ·G_hold·E)` of one
/// `(keep, α)` candidate at a site; `0` for untouched sites.
fn candidate_err2(
    cal: &SiteCal,
    method: Method,
    grail_on: bool,
    keep: usize,
    alpha: f64,
    seed: u64,
    site_idx: usize,
) -> f64 {
    if keep >= cal.info.units {
        return 0.0;
    }
    let reducer = reducer_for(cal, method, keep, seed, site_idx);
    let ud = cal.info.unit_dim;
    let b = if grail_on {
        // Serial inner solve: parallelism lives at the candidate
        // level, and the solver is bit-invariant at any width anyway.
        super::reconstruction_with(&cal.train.gram, &reducer, ud, alpha as f32, 1)
    } else {
        reducer.lift(ud).consumer_matrix(cal.info.feat_width())
    };
    let (err2, _) = super::reconstruction_err2_terms(&cal.hold.gram, &reducer, ud, &b);
    err2
}

fn rel_err(err2: f64, denom2: f64) -> f64 {
    (err2.max(0.0) / denom2.max(1e-24)).sqrt()
}

/// Attach producer features to every folding-method site of `plan`.
fn attach_fold_features<M: Compressible>(model: &M, plan: &CompressionPlan, cals: &mut [SiteCal]) {
    for (si, cal) in cals.iter_mut().enumerate() {
        if plan.sites[si].policy.method == Method::Fold {
            cal.feats = Some(model.producer_features(si));
        }
    }
}

/// Score an arbitrary resolved plan with the search's held-out
/// objective: the global relative reconstruction error
/// `sqrt(Σᵢ tr(Eᵢᵀ·G_hold·Eᵢ) / Σᵢ tr(G_hold))` of its per-site
/// `(keep, α)` choices, using the same train/held-out shard split as
/// [`search_plan`]. Plans with equal `shards` are directly comparable;
/// the winner of a search never scores worse than its seed.
pub fn score_plan<M>(model: &M, calib: &M::Input, plan: &CompressionPlan) -> f64
where
    M: Compressible + Sync,
    M::Input: Sync,
    M::CalibState: Send,
{
    let workers = if plan.workers != 0 { plan.workers } else { default_threads() };
    let shard_target = if plan.shards != 0 { plan.shards } else { DEFAULT_SHARDS };
    let (mut cals, _) = gather_stats(model, calib, shard_target, workers);
    assert_eq!(plan.sites.len(), cals.len(), "plan resolved against a different model");
    attach_fold_features(model, plan, &mut cals);
    let n = cals.len();
    let plan_ref = &plan;
    let cals_ref = &cals;
    let idx: Vec<usize> = (0..n).collect();
    let err2: Vec<f64> = run_grid(idx, workers, |_, &i| {
        let ps = &plan_ref.sites[i];
        candidate_err2(
            &cals_ref[i],
            ps.policy.method,
            ps.policy.grail,
            ps.keep,
            ps.policy.alpha as f64,
            plan_ref.seed,
            i,
        )
    });
    let denom2: f64 = cals.iter().map(|c| super::gram_trace(&c.hold.gram)).sum();
    rel_err(err2.iter().sum(), denom2)
}

/// Run the calibration-driven coordinate search for a spec with
/// `budget.mode = "search"` and return the winning plan plus search
/// diagnostics. See the module docs for the algorithm; the result is
/// deterministic in `(spec, calib)` and bit-identical at any worker
/// count.
pub fn search_plan<M>(model: &M, calib: &M::Input, spec: &CompressionSpec) -> Result<SearchOutcome>
where
    M: Compressible + Sync,
    M::Input: Sync,
    M::CalibState: Send,
{
    let BudgetMode::Search { alpha_grid, rounds, .. } = &spec.budget else {
        bail!("search_plan needs `budget.mode = \"search\"` (got `{}`)", spec.budget.name());
    };
    let alpha_grid: Vec<f64> = if alpha_grid.is_empty() {
        super::spec::DEFAULT_ALPHA_GRID.to_vec()
    } else {
        alpha_grid.clone()
    };
    if alpha_grid.iter().any(|a| !a.is_finite() || *a <= 0.0) {
        bail!("alpha_grid must be positive and finite: {alpha_grid:?}");
    }
    let rounds = *rounds;
    let sites = model.sites();
    let n = sites.len();
    // Fail fast on an unresolvable spec (bad rules, infeasible budget)
    // *before* paying the streamed statistics pass; the uniform-seed
    // plan this produces is final unless a gram-sensitivity seed
    // re-resolves it below.
    let mut plan = spec.resolve(&sites, None)?;
    let workers = if spec.workers != 0 { spec.workers } else { default_threads() };
    let shard_target = if spec.shards != 0 { spec.shards } else { DEFAULT_SHARDS };
    let (mut cals, n_shards) = gather_stats(model, calib, shard_target, workers);
    if n_shards < 2 {
        // A single shard — whether requested via `shards = 1` or
        // forced by a one-sample calibration input — leaves nothing to
        // hold out: candidates would be scored in-sample, where the
        // ridge residual is monotone in λ and the α sweep degenerates
        // to "smallest grid value".
        bail!(
            "search scoring needs at least 2 calibration shards for the held-out split \
             (input split into {n_shards})"
        );
    }
    // Seed weights for the initial allocation. The gram-sensitivity
    // seed (`budget.seed = "gram-sensitivity"`) derives each site's
    // mean Gram-diagonal activation energy from the statistics pass
    // just gathered — the sensitivity allocator composes with search
    // at **no extra streamed pass** (asserted by the layer-forward
    // counter in `rust/tests/forward_count.rs`). Train and held-out
    // shards together cover the full calibration input, matching the
    // dense-model signal `site_sensitivities` measures.
    if spec.search_seed == SearchSeed::GramSensitivity {
        let sens: Vec<f64> = cals
            .iter()
            .map(|c| {
                let rows = (c.train.rows + c.hold.rows).max(1) as f64;
                let width = c.info.feat_width().max(1) as f64;
                (super::gram_trace(&c.train.gram) + super::gram_trace(&c.hold.gram)) / (rows * width)
            })
            .collect();
        plan = spec.resolve(&sites, Some(&sens))?;
    }
    let seed = plan.seed;
    attach_fold_features(model, &plan, &mut cals);

    // Which sites the search may touch: rule-pinned ratios freeze the
    // keep count, rule-pinned αs (and non-GRAIL sites) freeze the α.
    let mut ratio_free = vec![false; n];
    let mut alpha_free = vec![false; n];
    for (i, s) in sites.iter().enumerate() {
        let (rp, ap) = spec.rule_pins(s, i);
        ratio_free[i] = !rp && plan.sites[i].units > 0;
        alpha_free[i] = !ap && plan.sites[i].policy.grail;
    }

    // Seed scores.
    let cals_ref = &cals;
    let plan_ref = &plan;
    let idx: Vec<usize> = (0..n).collect();
    let mut err2: Vec<f64> = run_grid(idx, workers, |_, &i| {
        let ps = &plan_ref.sites[i];
        candidate_err2(
            &cals_ref[i],
            ps.policy.method,
            ps.policy.grail,
            ps.keep,
            ps.policy.alpha as f64,
            seed,
            i,
        )
    });
    let denom2: f64 = cals.iter().map(|c| super::gram_trace(&c.hold.gram)).sum();
    let initial_err = rel_err(err2.iter().sum::<f64>(), denom2);
    let seed_alphas: Vec<f32> = plan.sites.iter().map(|s| s.policy.alpha).collect();
    let mut evals = n;
    let mut keep_moves = 0usize;
    let mut rounds_run = 0usize;

    // Weighted-unit budget over the reallocatable sites; moves must
    // never push `used_w` above the seed plan's footprint.
    let budget_w: usize = (0..n)
        .filter(|&i| ratio_free[i])
        .map(|i| plan.sites[i].keep * plan.sites[i].unit_dim)
        .sum();
    let mut used_w = budget_w;

    // α-sweep evaluations memoized across rounds by
    // `(site, keep, α bits)`: rounds repeat the sweep after keep
    // moves, but an already-scored `(keep, α)` pair never changes, so
    // converged sites cost nothing on later rounds.
    let mut sweep_memo: BTreeMap<(usize, usize, u64), f64> = BTreeMap::new();

    for _ in 0..rounds {
        rounds_run += 1;
        let mut improved = false;

        // --- per-site α sweep over the grid, held-out scored.
        let sweep_sites: Vec<usize> = (0..n)
            .filter(|&i| alpha_free[i] && plan.sites[i].keep < plan.sites[i].units)
            .collect();
        let jobs: Vec<(usize, usize)> = sweep_sites
            .iter()
            .flat_map(|&i| (0..alpha_grid.len()).map(move |ai| (i, ai)))
            .filter(|&(i, ai)| {
                !sweep_memo.contains_key(&(i, plan.sites[i].keep, alpha_grid[ai].to_bits()))
            })
            .collect();
        let grid_ref = &alpha_grid;
        let plan_ref = &plan;
        let sweep: Vec<f64> = run_grid(jobs.clone(), workers, |_, &(i, ai)| {
            let ps = &plan_ref.sites[i];
            candidate_err2(&cals_ref[i], ps.policy.method, true, ps.keep, grid_ref[ai], seed, i)
        });
        evals += sweep.len();
        for (&(i, ai), &e) in jobs.iter().zip(&sweep) {
            sweep_memo.insert((i, plan.sites[i].keep, alpha_grid[ai].to_bits()), e);
        }
        for &i in &sweep_sites {
            let keep = plan.sites[i].keep;
            let mut best: Option<(f64, usize)> = None;
            for (ai, a) in alpha_grid.iter().enumerate() {
                let e = sweep_memo[&(i, keep, a.to_bits())];
                let better = match best {
                    None => true,
                    Some((be, _)) => e < be,
                };
                if better {
                    best = Some((e, ai));
                }
            }
            if let Some((e, ai)) = best {
                if e < err2[i] {
                    plan.sites[i].policy.alpha = alpha_grid[ai] as f32;
                    err2[i] = e;
                    improved = true;
                }
            }
        }

        // --- keep reallocation under the weighted-unit budget.
        let movable: Vec<usize> = (0..n).filter(|&i| ratio_free[i]).collect();
        if !movable.is_empty() {
            // Admissible neighbour keeps for every movable site.
            let mut grow_to: Vec<Option<usize>> = vec![None; n];
            let mut shrink_to: Vec<Option<usize>> = vec![None; n];
            for &i in &movable {
                let ps = &plan.sites[i];
                let step = keep_step(ps.units, ps.groups);
                grow_to[i] = (ps.keep + step <= ps.units).then_some(ps.keep + step);
                shrink_to[i] =
                    (ps.keep >= keep_floor(ps.units, ps.groups) + step).then_some(ps.keep - step);
            }
            let mut cand_jobs: Vec<(usize, usize)> = Vec::new();
            for &i in &movable {
                if let Some(kk) = grow_to[i] {
                    cand_jobs.push((i, kk));
                }
                if let Some(kk) = shrink_to[i] {
                    cand_jobs.push((i, kk));
                }
            }
            let plan_ref = &plan;
            let cand_err: Vec<f64> = run_grid(cand_jobs.clone(), workers, |_, &(i, kk)| {
                let ps = &plan_ref.sites[i];
                candidate_err2(
                    &cals_ref[i],
                    ps.policy.method,
                    ps.policy.grail,
                    kk,
                    ps.policy.alpha as f64,
                    seed,
                    i,
                )
            });
            evals += cand_err.len();
            let mut err_at: BTreeMap<(usize, usize), f64> = BTreeMap::new();
            for (&key, &e) in cand_jobs.iter().zip(&cand_err) {
                err_at.insert(key, e);
            }

            // Greedy move loop: bounded, strictly improving, with
            // index tie-breaks — entirely serial on gathered scores.
            enum Move {
                Grow(usize),
                Shrink(usize),
                Pair(usize, usize),
            }
            let max_moves = 2 * movable.len();
            for _ in 0..max_moves {
                let slack = budget_w - used_w;
                let mut action: Option<Move> = None;

                // 1) A shrink that *improves* the held-out error is a
                // free budget win (noise-level sites) — take the best.
                let mut neg_shrink: Option<(f64, usize)> = None;
                for &i in &movable {
                    let Some(sk) = shrink_to[i] else { continue };
                    let Some(&e) = err_at.get(&(i, sk)) else { continue };
                    let cost = e - err2[i];
                    if cost < 0.0 {
                        let better = match neg_shrink {
                            None => true,
                            Some((bc, _)) => cost < bc,
                        };
                        if better {
                            neg_shrink = Some((cost, i));
                        }
                    }
                }
                if let Some((_, d)) = neg_shrink {
                    action = Some(Move::Shrink(d));
                }

                // 2) Otherwise: receivers in descending gain per
                // weighted unit; for each, either grow from slack or
                // find the cheapest single donor that frees enough
                // budget. Sites whose step no donor can fund (e.g. an
                // attention head vs one-unit donors) fall through to
                // the next receiver instead of stalling the loop.
                if action.is_none() {
                    let mut receivers: Vec<(f64, usize)> = Vec::new();
                    for &i in &movable {
                        let Some(kk) = grow_to[i] else { continue };
                        let Some(&e) = err_at.get(&(i, kk)) else { continue };
                        let gain = err2[i] - e;
                        if gain <= 0.0 {
                            continue;
                        }
                        let w = ((kk - plan.sites[i].keep) * plan.sites[i].unit_dim) as f64;
                        receivers.push((gain / w, i));
                    }
                    receivers.sort_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
                    for &(_, r) in &receivers {
                        let gk = grow_to[r].unwrap();
                        let grow_w = (gk - plan.sites[r].keep) * plan.sites[r].unit_dim;
                        if grow_w <= slack {
                            action = Some(Move::Grow(r));
                            break;
                        }
                        let gain = err2[r] - err_at[&(r, gk)];
                        // Cheapest donor (absolute held-out cost) that
                        // frees enough weighted units for this step.
                        let mut best_d: Option<(f64, usize)> = None;
                        for &d in &movable {
                            if d == r {
                                continue;
                            }
                            let Some(sk) = shrink_to[d] else { continue };
                            let Some(&e) = err_at.get(&(d, sk)) else { continue };
                            let freed_w =
                                (plan.sites[d].keep - sk) * plan.sites[d].unit_dim;
                            if freed_w + slack < grow_w {
                                continue;
                            }
                            let cost = e - err2[d];
                            let better = match best_d {
                                None => true,
                                Some((bc, _)) => cost < bc,
                            };
                            if better {
                                best_d = Some((cost, d));
                            }
                        }
                        if let Some((cost, d)) = best_d {
                            if gain > cost {
                                action = Some(Move::Pair(d, r));
                                break;
                            }
                        }
                    }
                }
                let Some(action) = action else { break };
                // Resolve the touched (site, new-keep) targets before
                // `apply` mutably captures the candidate tables. For a
                // pair, both targets come from the pre-move state.
                let targets: Vec<(usize, usize)> = match action {
                    Move::Grow(r) => vec![(r, grow_to[r].unwrap())],
                    Move::Shrink(d) => vec![(d, shrink_to[d].unwrap())],
                    Move::Pair(d, r) => {
                        vec![(d, shrink_to[d].unwrap()), (r, grow_to[r].unwrap())]
                    }
                };

                // Apply the move and refresh the touched sites'
                // neighbour candidates (serial, deterministic).
                let mut apply = |i: usize, kk: usize| {
                    let old_w = plan.sites[i].keep * plan.sites[i].unit_dim;
                    plan.sites[i].keep = kk;
                    plan.sites[i].policy.ratio = 1.0 - kk as f64 / plan.sites[i].units as f64;
                    err2[i] = err_at[&(i, kk)];
                    used_w = used_w + kk * plan.sites[i].unit_dim - old_w;
                    let ps = &plan.sites[i];
                    let step = keep_step(ps.units, ps.groups);
                    grow_to[i] = (ps.keep + step <= ps.units).then_some(ps.keep + step);
                    shrink_to[i] = (ps.keep >= keep_floor(ps.units, ps.groups) + step)
                        .then_some(ps.keep - step);
                    for kk2 in [grow_to[i], shrink_to[i]].into_iter().flatten() {
                        if let std::collections::btree_map::Entry::Vacant(slot) =
                            err_at.entry((i, kk2))
                        {
                            let e = candidate_err2(
                                &cals[i],
                                ps.policy.method,
                                ps.policy.grail,
                                kk2,
                                ps.policy.alpha as f64,
                                seed,
                                i,
                            );
                            evals += 1;
                            slot.insert(e);
                        }
                    }
                };
                for (i, kk) in targets {
                    apply(i, kk);
                }
                keep_moves += 1;
                improved = true;
            }
        }

        if !improved {
            break;
        }
    }

    let final_err = rel_err(err2.iter().sum::<f64>(), denom2);
    let alpha_moves = (0..n).filter(|&i| plan.sites[i].policy.alpha != seed_alphas[i]).count();
    Ok(SearchOutcome {
        plan,
        initial_err,
        final_err,
        rounds_run,
        alpha_moves,
        keep_moves,
        evals,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthVision;
    use crate::nn::models::MlpNet;

    fn fixture() -> (MlpNet, Tensor) {
        let mut rng = Pcg64::seed(31);
        let m = MlpNet::init(768, 32, 10, &mut rng);
        let x = SynthVision::new(13).generate(96).x;
        (m, x)
    }

    fn search_spec(ratio: f64) -> CompressionSpec {
        let mut spec =
            CompressionSpec::uniform(Method::Prune(Selector::Wanda), ratio, true);
        spec.budget = BudgetMode::Search {
            target_ratio: ratio,
            alpha_grid: vec![1e-6, 1e-4, 5e-3],
            rounds: 2,
        };
        spec
    }

    #[test]
    fn search_never_worse_than_seed_and_conserves_budget() {
        let (m, x) = fixture();
        let spec = search_spec(0.5);
        let out = search_plan(&m, &x, &spec).unwrap();
        assert!(out.final_err.is_finite() && out.initial_err.is_finite());
        assert!(out.final_err <= out.initial_err, "{} > {}", out.final_err, out.initial_err);
        assert!(out.rounds_run >= 1 && out.evals >= 2);
        // Budget: the winner spends no more weighted units than the
        // budget-conserving seed plan.
        let seed_plan = spec.resolve(&m.sites(), None).unwrap();
        assert!(out.plan.total_keep_weighted() <= seed_plan.total_keep_weighted());
        for ps in &out.plan.sites {
            assert!(ps.keep >= 1 && ps.keep <= ps.units);
        }
    }

    #[test]
    fn search_requires_search_budget() {
        let (m, x) = fixture();
        let spec = CompressionSpec::uniform(Method::Prune(Selector::Wanda), 0.5, true);
        assert!(search_plan(&m, &x, &spec).is_err());
    }

    #[test]
    fn score_plan_matches_search_bookkeeping() {
        // The outcome's final_err is exactly score_plan of the winner.
        let (m, x) = fixture();
        let out = search_plan(&m, &x, &search_spec(0.5)).unwrap();
        let rescored = score_plan(&m, &x, &out.plan);
        assert_eq!(rescored.to_bits(), out.final_err.to_bits());
    }

    #[test]
    fn gram_sensitivity_seed_composes_with_search() {
        let (m, x) = fixture();
        let mut spec = search_spec(0.5);
        spec.search_seed = SearchSeed::GramSensitivity;
        let out = search_plan(&m, &x, &spec).unwrap();
        assert!(out.final_err.is_finite());
        assert!(out.final_err <= out.initial_err, "{} > {}", out.final_err, out.initial_err);
        // The sensitivity seed conserves the same unit budget the
        // uniform seed would (unit_dim = 1 on the MLP fixture), so the
        // winner's footprint is bounded by it.
        let uniform_seed = search_spec(0.5).resolve(&m.sites(), None).unwrap();
        assert!(out.plan.total_keep_weighted() <= uniform_seed.total_keep_weighted());
        for ps in &out.plan.sites {
            assert!(ps.keep >= 1 && ps.keep <= ps.units);
        }
    }

    #[test]
    fn rule_pinned_sites_are_frozen() {
        let (m, x) = fixture();
        let mut spec = search_spec(0.5);
        // Pin the first site's ratio and α by rule.
        spec.rules = vec![crate::grail::PolicyRule {
            matcher: crate::grail::SiteMatcher {
                depth: Some((0, 0)),
                ..Default::default()
            },
            set: crate::grail::PolicyOverrides {
                ratio: Some(0.25),
                alpha: Some(2e-3),
                ..Default::default()
            },
        }];
        let out = search_plan(&m, &x, &spec).unwrap();
        assert_eq!(out.plan.sites[0].policy.ratio, 0.25);
        assert_eq!(out.plan.sites[0].policy.alpha, 2e-3);
    }
}
