//! Spec-driven compression jobs: `grail run`, `grail plan`, and the
//! `grail batch` fan-out over the model zoo.
//!
//! A *spec file* is a TOML-subset document with a `[model]` section
//! naming the target (family + optional checkpoint) and the
//! [`CompressionSpec`] sections (`[pipeline]`, `[budget]`, `[rule.N]`)
//! — see `examples/lm_depth_ramp.spec.toml` and EXPERIMENTS.md for the
//! format. `grail plan` resolves and prints the per-site plan without
//! touching any weight; `grail run` executes it and evaluates the
//! model before/after; `grail batch` expands several spec files over
//! the checkpoint zoo (a spec without `model.ckpt` fans over every
//! checkpoint of its family) and runs the jobs on
//! [`coordinator::scheduler`](crate::coordinator::scheduler) workers.

use super::report::Table;
use super::vision::{Family as VisionFamily, VisionModel};
use super::ExpOptions;
use crate::cli::Args;
use crate::config::Config;
use crate::coordinator::scheduler::{default_threads, run_grid};
use crate::eval::lm_perplexity;
use crate::eval::probes::{probe_accuracy, probe_suite};
use crate::grail::{
    compress_model, execute_plan, plan_for_model, search_plan, BudgetMode, CompressionPlan,
    CompressionSpec, Report, SearchOutcome,
};
use crate::nn::models::LmBatch;
use crate::serve::digest::{digest_file, Hasher128};
use crate::serve::provider::{self, CacheScope, StatsContext};
use anyhow::{anyhow, bail, Context, Result};
use std::time::Instant;

/// LM calibration/evaluation geometry (matches `grail compress
/// --family lm`, so a uniform spec reproduces its results exactly).
const LM_SEQ: usize = 32;
const LM_CALIB_WINDOWS: usize = 64;
const LM_EVAL_WINDOWS: usize = 64;

/// Model family a spec job targets.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Family {
    Mlp,
    Resnet,
    Vit,
    Lm,
}

impl Family {
    /// Parse a `model.family` / `--family` name.
    pub fn from_name(s: &str) -> Option<Family> {
        Some(match s {
            "mlp" => Family::Mlp,
            "resnet" => Family::Resnet,
            "vit" => Family::Vit,
            "lm" | "tinylm" => Family::Lm,
            _ => return None,
        })
    }

    /// Stable display name.
    pub fn name(&self) -> &'static str {
        match self {
            Family::Mlp => "mlp",
            Family::Resnet => "resnet",
            Family::Vit => "vit",
            Family::Lm => "lm",
        }
    }

    /// Checkpoint-name prefix in the zoo.
    pub fn zoo_prefix(&self) -> &'static str {
        match self {
            Family::Mlp => "mlp",
            Family::Resnet => "resnet",
            Family::Vit => "vit",
            Family::Lm => "tinylm",
        }
    }

    /// Default checkpoint when a spec names none.
    pub fn default_ckpt(&self) -> &'static str {
        match self {
            Family::Mlp => "mlp_seed0",
            Family::Resnet => "resnet_seed0",
            Family::Vit => "vit_seed0",
            Family::Lm => "tinylm_mha",
        }
    }

    fn vision(&self) -> Option<VisionFamily> {
        match self {
            Family::Mlp => Some(VisionFamily::Mlp),
            Family::Resnet => Some(VisionFamily::Resnet),
            Family::Vit => Some(VisionFamily::Vit),
            Family::Lm => None,
        }
    }
}

/// A loaded spec file: target model + compression spec.
#[derive(Clone, Debug)]
pub struct SpecJob {
    pub path: String,
    pub family: Family,
    /// `None` = fan over every zoo checkpoint of the family (batch) or
    /// use the family default (run/plan).
    pub ckpt: Option<String>,
    pub spec: CompressionSpec,
}

impl SpecJob {
    /// Load and validate a spec file.
    pub fn load(path: &str) -> Result<SpecJob> {
        let cfg = Config::load(path)?;
        // Typos in `[model]` must not silently fall back to defaults
        // (`CompressionSpec::from_config` rejects unknown keys in its
        // sections the same way).
        for key in cfg.keys() {
            if let Some(field) = key.strip_prefix("model.") {
                if !matches!(field, "family" | "ckpt") {
                    bail!("{path}: unknown spec key `{key}`");
                }
            }
        }
        let fam_name = cfg.str_or("model.family", "lm");
        let family = Family::from_name(fam_name)
            .ok_or_else(|| anyhow!("{path}: model.family: unknown family `{fam_name}`"))?;
        let ckpt = match cfg.get("model.ckpt") {
            Some(_) => Some(cfg.str("model.ckpt")?.to_string()),
            None => None,
        };
        let spec = CompressionSpec::from_config(&cfg).with_context(|| format!("loading {path}"))?;
        Ok(SpecJob { path: path.to_string(), family, ckpt, spec })
    }

    /// Apply `--family` / `--ckpt` CLI overrides.
    pub fn apply_overrides(&mut self, args: &Args) -> Result<()> {
        if let Some(f) = args.opt("family") {
            self.family = Family::from_name(f)
                .ok_or_else(|| anyhow!("--family: unknown family `{f}`"))?;
        }
        if let Some(c) = args.opt("ckpt") {
            self.ckpt = Some(c.to_string());
        }
        Ok(())
    }

    /// Concrete checkpoint for single-job commands.
    pub fn ckpt_or_default(&self) -> String {
        self.ckpt.clone().unwrap_or_else(|| self.family.default_ckpt().to_string())
    }
}

/// Outcome of one executed spec job.
#[derive(Clone, Debug)]
pub struct JobOutcome {
    pub spec_path: String,
    pub family: Family,
    pub ckpt: String,
    /// `"acc"` (vision) or `"ppl"` (lm).
    pub metric: &'static str,
    pub before: f64,
    pub after: f64,
    pub report: Report,
    /// Wall time of the whole job (load + evaluate + compress).
    pub wall_seconds: f64,
    /// Statistics-cache entry hits/misses accounted to this job's
    /// thread (0/0 without `--cache`).
    pub cache_hits: u64,
    pub cache_misses: u64,
}

/// Install the statistics-cache provider for a job when `--cache` is
/// active. The model identity is the checkpoint file's bytes; the
/// corpus identity is the calibration file's bytes plus the slicing
/// geometry the job applies to it (so changing `LM_SEQ` or the vision
/// calib slice retires the entries). Returns `None` — run cold — when
/// no cache is configured or the checkpoint file is absent (the model
/// loader owns that error).
pub(crate) fn stats_scope(
    opts: &ExpOptions,
    family: Family,
    ckpt: &str,
) -> Result<Option<CacheScope>> {
    let Some(cache) = &opts.cache else { return Ok(None) };
    let ckpt_path = opts.artifacts.ckpt(ckpt);
    if !std::path::Path::new(&ckpt_path).exists() {
        return Ok(None);
    }
    let model = digest_file(&ckpt_path)?;
    let mut h = Hasher128::new();
    if family.vision().is_some() {
        h.update(b"vision-calib");
        h.update(&digest_file(&opts.artifacts.data("vision_calib.imgs"))?.0);
        h.update(&128u64.to_le_bytes());
    } else {
        h.update(b"lm-calib");
        h.update(&digest_file(&opts.artifacts.data("text_calib.tokens"))?.0);
        h.update(&(LM_SEQ as u64).to_le_bytes());
        h.update(&(LM_CALIB_WINDOWS as u64).to_le_bytes());
    }
    Ok(Some(provider::install(StatsContext::new(cache.clone(), model, h.finish()))))
}

/// Resolve the plan for a job without mutating anything.
pub fn resolve_job_plan(
    opts: &ExpOptions,
    family: Family,
    ckpt: &str,
    spec: &CompressionSpec,
) -> Result<CompressionPlan> {
    let zoo = opts.zoo()?;
    let _cache = stats_scope(opts, family, ckpt)?;
    if let Some(vf) = family.vision() {
        let calib = crate::data::io::read_images(&opts.artifacts.data("vision_calib.imgs"))?
            .slice(0, 128);
        let m = VisionModel::load(&zoo, vf, ckpt)?;
        m.plan(&calib.x, spec)
    } else {
        let m = zoo.lm(ckpt)?;
        let calib_toks =
            crate::data::io::read_tokens(&opts.artifacts.data("text_calib.tokens"))?;
        let calib = LmBatch::from_tokens(&calib_toks, LM_SEQ, LM_CALIB_WINDOWS);
        plan_for_model(&m, &calib, spec)
    }
}

/// What a compression job applies: resolve-and-run a spec, or execute
/// an already-resolved plan verbatim.
enum Compression<'a> {
    Spec(&'a CompressionSpec),
    Plan(&'a CompressionPlan),
}

/// Shared job scaffolding: load the checkpoint and calibration data,
/// evaluate before, apply `how`, evaluate after.
fn run_compression_job(
    opts: &ExpOptions,
    family: Family,
    ckpt: &str,
    how: Compression<'_>,
    label: &str,
) -> Result<JobOutcome> {
    let zoo = opts.zoo()?;
    let t0 = Instant::now();
    let (tally_h0, tally_m0) = provider::tally();
    let _cache = stats_scope(opts, family, ckpt)?;
    let (metric, before, after, report) = if let Some(vf) = family.vision() {
        let calib = crate::data::io::read_images(&opts.artifacts.data("vision_calib.imgs"))?
            .slice(0, 128);
        let test = crate::data::io::read_images(&opts.artifacts.data("vision_test.imgs"))?;
        let mut m = VisionModel::load(&zoo, vf, ckpt)?;
        let before = m.accuracy(&test);
        let report = match how {
            Compression::Spec(spec) => m.compress(&calib.x, spec),
            Compression::Plan(plan) => m.execute(&calib.x, plan),
        };
        ("acc", before, m.accuracy(&test), report)
    } else {
        let mut m = zoo.lm(ckpt)?;
        let calib_toks =
            crate::data::io::read_tokens(&opts.artifacts.data("text_calib.tokens"))?;
        let calib = LmBatch::from_tokens(&calib_toks, LM_SEQ, LM_CALIB_WINDOWS);
        let eval_toks =
            crate::data::io::read_tokens(&opts.artifacts.data("text_wt2s.tokens"))?;
        let before = lm_perplexity(&m, &eval_toks, LM_SEQ, LM_EVAL_WINDOWS, 16);
        let report = match how {
            Compression::Spec(spec) => compress_model(&mut m, &calib, spec),
            Compression::Plan(plan) => execute_plan(&mut m, &calib, plan),
        };
        ("ppl", before, lm_perplexity(&m, &eval_toks, LM_SEQ, LM_EVAL_WINDOWS, 16), report)
    };
    let (tally_h1, tally_m1) = provider::tally();
    Ok(JobOutcome {
        spec_path: label.to_string(),
        family,
        ckpt: ckpt.to_string(),
        metric,
        before,
        after,
        report,
        wall_seconds: t0.elapsed().as_secs_f64(),
        cache_hits: tally_h1 - tally_h0,
        cache_misses: tally_m1 - tally_m0,
    })
}

/// Compress `ckpt` under `spec` and evaluate it before/after.
pub fn execute_job(
    opts: &ExpOptions,
    family: Family,
    ckpt: &str,
    spec: &CompressionSpec,
    spec_path: &str,
) -> Result<JobOutcome> {
    run_compression_job(opts, family, ckpt, Compression::Spec(spec), spec_path)
}

/// Compress `ckpt` with an already-resolved plan and evaluate it
/// before/after — the consumer of the plan TOMLs `grail tune` emits
/// (`grail run --plan <plan.toml>`).
pub fn execute_plan_job(
    opts: &ExpOptions,
    family: Family,
    ckpt: &str,
    plan: &CompressionPlan,
    label: &str,
) -> Result<JobOutcome> {
    run_compression_job(opts, family, ckpt, Compression::Plan(plan), label)
}

/// Per-site lines + parameter summary for CLI output.
pub fn print_report(report: &Report) {
    for s in &report.sites {
        println!(
            "  {}: {} -> {} units ({} ratio={:.2}{}), recon err {:.4}",
            s.id,
            s.units_before,
            s.units_after,
            s.method,
            s.ratio,
            if s.grail { " +grail" } else { "" },
            s.recon_err
        );
    }
    println!("  {}", report.summary());
    if report.cache_hits + report.cache_misses > 0 {
        println!(
            "  stats cache: {} hits, {} misses",
            report.cache_hits, report.cache_misses
        );
    }
}

/// `grail run --spec spec.toml [--family f] [--ckpt c]`, or
/// `grail run --plan plan.toml --family f [--ckpt c]` to execute an
/// already-resolved plan (e.g. a `grail tune` winner) verbatim.
pub fn run_cli(args: &Args) -> Result<()> {
    if let Some(plan_path) = args.opt("plan") {
        let opts = ExpOptions::from_args(args)?;
        let text = std::fs::read_to_string(plan_path)
            .with_context(|| format!("reading {plan_path}"))?;
        let plan =
            CompressionPlan::parse(&text).with_context(|| format!("parsing {plan_path}"))?;
        // Plan files carry no model metadata, and executing a plan
        // against the wrong family only fails deep in the pipeline —
        // demand the family up front.
        let fam_name = args.opt("family").ok_or_else(|| {
            anyhow!("--plan needs --family <mlp|resnet|vit|lm> (plan files name no model)")
        })?;
        let family = Family::from_name(fam_name)
            .ok_or_else(|| anyhow!("--family: unknown family `{fam_name}`"))?;
        let ckpt = args
            .opt("ckpt")
            .map(|s| s.to_string())
            .unwrap_or_else(|| family.default_ckpt().to_string());
        let out = execute_plan_job(&opts, family, &ckpt, &plan, plan_path)?;
        println!(
            "{} {} [{}]: {} {:.4} -> {:.4}",
            out.family.name(),
            out.ckpt,
            plan_path,
            out.metric,
            out.before,
            out.after
        );
        print_report(&out.report);
        return Ok(());
    }
    let spec_path = args
        .opt("spec")
        .ok_or_else(|| anyhow!("usage: grail run --spec <spec.toml> | --plan <plan.toml>"))?;
    let opts = ExpOptions::from_args(args)?;
    let mut job = SpecJob::load(spec_path)?;
    job.apply_overrides(args)?;
    let ckpt = job.ckpt_or_default();
    let out = execute_job(&opts, job.family, &ckpt, &job.spec, &job.path)?;
    println!(
        "{} {} [{}]: {} {:.4} -> {:.4}",
        out.family.name(),
        out.ckpt,
        spec_path,
        out.metric,
        out.before,
        out.after
    );
    print_report(&out.report);
    Ok(())
}

/// `grail plan --spec spec.toml [--family f] [--ckpt c] [--toml]` —
/// resolve and print the plan; mutates nothing.
pub fn plan_cli(args: &Args) -> Result<()> {
    let spec_path =
        args.opt("spec").ok_or_else(|| anyhow!("usage: grail plan --spec <spec.toml>"))?;
    let opts = ExpOptions::from_args(args)?;
    let mut job = SpecJob::load(spec_path)?;
    job.apply_overrides(args)?;
    let ckpt = job.ckpt_or_default();
    let plan = resolve_job_plan(&opts, job.family, &ckpt, &job.spec)?;
    if let Some(out) = args.opt("plan-out") {
        std::fs::write(out, plan.to_toml()).with_context(|| format!("writing {out}"))?;
        println!("plan for {} {} [{}] -> {}", job.family.name(), ckpt, spec_path, out);
    } else if args.has("toml") {
        print!("{}", plan.to_toml());
    } else {
        println!("plan for {} {} [{}]:", job.family.name(), ckpt, spec_path);
        print!("{}", plan.render());
    }
    Ok(())
}

/// `grail batch <spec.toml>... [--jobs N] [--out results]` — expand
/// every spec over the zoo and run the jobs in parallel.
pub fn batch_cli(args: &Args) -> Result<()> {
    let paths: Vec<String> = args.positional.get(1..).unwrap_or(&[]).to_vec();
    if paths.is_empty() {
        bail!("usage: grail batch <spec.toml>... [--jobs N] [--out results]");
    }
    let opts = ExpOptions::from_args(args)?;
    let zoo = opts.zoo()?;
    let mut jobs: Vec<(String, Family, String, CompressionSpec)> = Vec::new();
    for p in &paths {
        let sj = SpecJob::load(p)?;
        let ckpts = match &sj.ckpt {
            Some(c) => vec![c.clone()],
            None => zoo.list(sj.family.zoo_prefix()),
        };
        if ckpts.is_empty() {
            bail!("{p}: no `{}` checkpoints in the zoo (run `make artifacts`)", sj.family.name());
        }
        for c in ckpts {
            jobs.push((p.clone(), sj.family, c, sj.spec.clone()));
        }
    }
    // Each job's pipeline parallelizes internally too; cap the outer
    // fan-out by --jobs to avoid oversubscription (specs can also pin
    // `pipeline.workers`).
    let threads = args.opt_usize("jobs", default_threads().min(jobs.len().max(1)))?;
    println!("batch: {} jobs from {} specs on {} workers", jobs.len(), paths.len(), threads);
    let opts_ref = &opts;
    let results: Vec<std::result::Result<JobOutcome, String>> =
        run_grid(jobs, threads, |_, (path, fam, ckpt, spec)| {
            execute_job(opts_ref, *fam, ckpt, spec, path).map_err(|e| format!("{e:#}"))
        });

    let mut table = Table::new(&[
        "spec", "family", "ckpt", "metric", "before", "after", "params_before", "params_after",
        "removed", "secs", "c_hit", "c_miss",
    ]);
    let mut failures = 0usize;
    for r in &results {
        match r {
            Ok(o) => table.row(vec![
                o.spec_path.clone(),
                o.family.name().to_string(),
                o.ckpt.clone(),
                o.metric.to_string(),
                format!("{:.4}", o.before),
                format!("{:.4}", o.after),
                o.report.params_before.to_string(),
                o.report.params_after.to_string(),
                format!("{:.1}%", 100.0 * o.report.compression_ratio()),
                format!("{:.2}", o.wall_seconds),
                o.cache_hits.to_string(),
                o.cache_misses.to_string(),
            ]),
            Err(e) => {
                failures += 1;
                eprintln!("job failed: {e}");
            }
        }
    }
    println!("{}", table.render());
    table.write_csv(&opts.out_path("batch.csv")?)?;
    if failures > 0 {
        bail!("{failures} of {} jobs failed", results.len());
    }
    Ok(())
}

/// Outcome of one `grail tune` job.
#[derive(Clone, Debug)]
pub struct TuneOutcome {
    pub family: Family,
    pub ckpt: String,
    pub search: SearchOutcome,
    /// Where the winning plan's TOML was written.
    pub plan_path: String,
    /// `--eval` metrics: `(name, before, after)` on the executed
    /// winning plan — accuracy for vision, probe-suite accuracy for lm.
    pub eval: Option<(&'static str, f64, f64)>,
    /// Wall time of the whole tune job.
    pub wall_seconds: f64,
    /// Statistics-cache entry hits/misses accounted to this job's
    /// thread (0/0 without `--cache`).
    pub cache_hits: u64,
    pub cache_misses: u64,
}

/// Run the calibration-driven search for one checkpoint and emit the
/// winning plan as TOML under the output directory.
pub fn tune_job(
    opts: &ExpOptions,
    family: Family,
    ckpt: &str,
    spec: &CompressionSpec,
    eval: bool,
) -> Result<TuneOutcome> {
    let zoo = opts.zoo()?;
    let t0 = Instant::now();
    let (tally_h0, tally_m0) = provider::tally();
    let _cache = stats_scope(opts, family, ckpt)?;
    let (search, eval_out) = if let Some(vf) = family.vision() {
        let calib = crate::data::io::read_images(&opts.artifacts.data("vision_calib.imgs"))?
            .slice(0, 128);
        let mut m = VisionModel::load(&zoo, vf, ckpt)?;
        let search = m.tune(&calib.x, spec)?;
        let ev = if eval {
            let test = crate::data::io::read_images(&opts.artifacts.data("vision_test.imgs"))?;
            let before = m.accuracy(&test);
            m.execute(&calib.x, &search.plan);
            Some(("acc", before, m.accuracy(&test)))
        } else {
            None
        };
        (search, ev)
    } else {
        let m = zoo.lm(ckpt)?;
        let calib_toks =
            crate::data::io::read_tokens(&opts.artifacts.data("text_calib.tokens"))?;
        let calib = LmBatch::from_tokens(&calib_toks, LM_SEQ, LM_CALIB_WINDOWS);
        let search = search_plan(&m, &calib, spec)?;
        let ev = if eval {
            let text = crate::data::SynthText::new(crate::coordinator::datagen::TASK_SEED);
            let items = probe_suite(&text, 32, opts.seed + 7);
            let before = probe_accuracy(&m, &items);
            let mut mm = m.clone();
            execute_plan(&mut mm, &calib, &search.plan);
            Some(("probe-acc", before, probe_accuracy(&mm, &items)))
        } else {
            None
        };
        (search, ev)
    };
    let plan_path = opts.out_path(&format!("tune_{}_{}.plan.toml", family.name(), ckpt))?;
    std::fs::write(&plan_path, search.plan.to_toml())
        .with_context(|| format!("writing {plan_path}"))?;
    let (tally_h1, tally_m1) = provider::tally();
    Ok(TuneOutcome {
        family,
        ckpt: ckpt.to_string(),
        search,
        plan_path,
        eval: eval_out,
        wall_seconds: t0.elapsed().as_secs_f64(),
        cache_hits: tally_h1 - tally_h0,
        cache_misses: tally_m1 - tally_m0,
    })
}

/// `grail tune --spec spec.toml [--family f] [--ckpt c] [--jobs N]
/// [--out results] [--eval]` — run the calibration-driven plan search
/// and emit the winning plan(s) as TOML. A spec without `model.ckpt`
/// fans over every checkpoint of its family (the batch mode); `--eval`
/// additionally executes each winning plan and reports model quality
/// before/after.
pub fn tune_cli(args: &Args) -> Result<()> {
    let spec_path = args
        .opt("spec")
        .ok_or_else(|| anyhow!("usage: grail tune --spec <spec.toml> [--eval]"))?;
    let opts = ExpOptions::from_args(args)?;
    let mut job = SpecJob::load(spec_path)?;
    job.apply_overrides(args)?;
    if !matches!(job.spec.budget, BudgetMode::Search { .. }) {
        bail!(
            "{spec_path}: `grail tune` needs `[budget] mode = \"search\"` (got `{}`)",
            job.spec.budget.name()
        );
    }
    let zoo = opts.zoo()?;
    let ckpts = match &job.ckpt {
        Some(c) => vec![c.clone()],
        None => zoo.list(job.family.zoo_prefix()),
    };
    if ckpts.is_empty() {
        bail!(
            "{spec_path}: no `{}` checkpoints in the zoo (run `make artifacts`)",
            job.family.name()
        );
    }
    let eval = args.has("eval");
    let threads = args.opt_usize("jobs", default_threads().min(ckpts.len()))?;
    println!("tune: {} checkpoint(s) from {spec_path} on {} workers", ckpts.len(), threads);
    let opts_ref = &opts;
    let spec_ref = &job.spec;
    let family = job.family;
    let results: Vec<std::result::Result<TuneOutcome, String>> =
        run_grid(ckpts, threads, |_, ckpt| {
            tune_job(opts_ref, family, ckpt, spec_ref, eval).map_err(|e| format!("{e:#}"))
        });

    let mut table = Table::new(&[
        "family", "ckpt", "err_before", "err_after", "alpha_moves", "keep_moves", "metric",
        "before", "after", "secs", "c_hit", "c_miss", "plan",
    ]);
    let mut failures = 0usize;
    for r in &results {
        match r {
            Ok(o) => {
                let (metric, before, after) = match o.eval {
                    Some((m, b, a)) => (m.to_string(), format!("{b:.4}"), format!("{a:.4}")),
                    None => ("-".into(), "-".into(), "-".into()),
                };
                table.row(vec![
                    o.family.name().to_string(),
                    o.ckpt.clone(),
                    format!("{:.5}", o.search.initial_err),
                    format!("{:.5}", o.search.final_err),
                    o.search.alpha_moves.to_string(),
                    o.search.keep_moves.to_string(),
                    metric,
                    before,
                    after,
                    format!("{:.2}", o.wall_seconds),
                    o.cache_hits.to_string(),
                    o.cache_misses.to_string(),
                    o.plan_path.clone(),
                ]);
            }
            Err(e) => {
                failures += 1;
                eprintln!("tune job failed: {e}");
            }
        }
    }
    println!("{}", table.render());
    table.write_csv(&opts.out_path("tune.csv")?)?;
    if failures > 0 {
        bail!("{failures} of {} tune jobs failed", results.len());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn family_names_roundtrip() {
        for f in [Family::Mlp, Family::Resnet, Family::Vit, Family::Lm] {
            assert_eq!(Family::from_name(f.name()), Some(f));
            assert!(!f.zoo_prefix().is_empty());
            assert!(f.default_ckpt().starts_with(f.zoo_prefix()));
        }
        assert_eq!(Family::from_name("tinylm"), Some(Family::Lm));
        assert!(Family::from_name("gpt5").is_none());
    }

    #[test]
    fn spec_job_loads_from_file() {
        let dir = std::env::temp_dir().join("grail_runner_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("job.spec.toml");
        std::fs::write(
            &p,
            "[model]\nfamily = \"lm\"\nckpt = \"tinylm_gqa\"\n\n[pipeline]\nmethod = \"flap\"\nratio = 0.3\n",
        )
        .unwrap();
        let job = SpecJob::load(p.to_str().unwrap()).unwrap();
        assert_eq!(job.family, Family::Lm);
        assert_eq!(job.ckpt.as_deref(), Some("tinylm_gqa"));
        assert_eq!(job.spec.defaults.ratio, 0.3);
        assert_eq!(job.ckpt_or_default(), "tinylm_gqa");
    }

    #[test]
    fn spec_job_rejects_unknown_family() {
        let dir = std::env::temp_dir().join("grail_runner_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.spec.toml");
        std::fs::write(&p, "[model]\nfamily = \"gpt5\"\n").unwrap();
        assert!(SpecJob::load(p.to_str().unwrap()).is_err());
    }
}
