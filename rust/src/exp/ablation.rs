//! Design-choice ablations (DESIGN.md §5 expected shapes):
//!
//! 1. **Ridge scale α** — the paper fixes α ∈ [1e-4, 5e-3]; we sweep
//!    wider to show the stability plateau and the under/over-
//!    regularization cliffs.
//! 2. **Closed vs open loop** — paper §3.2 argues sequential
//!    re-calibration "prevents error propagation"; the open-loop
//!    variant freezes all statistics on the dense model.

use super::report::{f, Table};
use super::ExpOptions;
use crate::compress::Selector;
use crate::data::TextSplit;
use crate::eval::{lm_perplexity, vision_accuracy};
use crate::grail::{compress_model, Method, CompressionSpec};
use crate::nn::models::LmBatch;
use anyhow::Result;

/// Run both ablations.
pub fn run(opts: &ExpOptions) -> Result<()> {
    let zoo = opts.zoo()?;
    let calib = crate::data::io::read_images(&opts.artifacts.data("vision_calib.imgs"))?
        .slice(0, 128);
    let test = crate::data::io::read_images(&opts.artifacts.data("vision_test.imgs"))?
        .slice(0, if opts.quick { 256 } else { 512 });
    let resnet = zoo.resnet("resnet_seed0")?;
    let calib_toks = crate::data::io::read_tokens(&opts.artifacts.data("text_calib.tokens"))?;
    let lm_calib = LmBatch::from_tokens(&calib_toks, 32, if opts.quick { 64 } else { 128 });
    let eval_toks = crate::data::io::read_tokens(
        &opts.artifacts.data(&format!("text_{}.tokens", TextSplit::Wt2s.name())),
    )?;
    let lm = zoo.lm("tinylm_mha")?;
    let eval_windows = if opts.quick { 32 } else { 96 };

    // ---- 1. alpha sweep
    let alphas: &[f32] = if opts.quick {
        &[1e-4, 5e-3, 1e-1]
    } else {
        &[1e-5, 1e-4, 1e-3, 5e-3, 1e-2, 1e-1, 1.0]
    };
    let mut t1 = Table::new(&["alpha", "resnet@0.6 acc", "lm@0.4 ppl"]);
    for &alpha in alphas {
        let mut r = resnet.clone();
        let mut cfg = CompressionSpec::uniform(Method::Prune(Selector::MagnitudeL2), 0.6, true);
        cfg.defaults.alpha = alpha;
        compress_model(&mut r, &calib.x, &cfg);
        let acc = vision_accuracy(|x| r.forward(x), &test, 128);
        let mut m = lm.clone();
        let mut cfg = CompressionSpec::uniform(Method::Prune(Selector::Wanda), 0.4, true);
        cfg.defaults.alpha = alpha;
        compress_model(&mut m, &lm_calib, &cfg);
        let ppl = lm_perplexity(&m, &eval_toks, 32, eval_windows, 16);
        t1.row(vec![format!("{alpha:.0e}"), format!("{acc:.4}"), f(ppl)]);
    }
    println!("Ablation 1 — ridge scale α:\n{}", t1.render());
    t1.write_csv(&opts.out_path("ablation_alpha.csv")?)?;

    // ---- 2. closed vs open loop
    let ratios: &[f64] = if opts.quick { &[0.3, 0.6] } else { &[0.2, 0.4, 0.6, 0.8] };
    let mut t2 = Table::new(&["ratio", "resnet closed", "resnet open", "lm closed", "lm open"]);
    for &ratio in ratios {
        let mut cells = vec![format!("{ratio:.1}")];
        for closed in [true, false] {
            let mut r = resnet.clone();
            let mut cfg = CompressionSpec::uniform(Method::Prune(Selector::MagnitudeL2), ratio, true);
            cfg.closed_loop = closed;
            compress_model(&mut r, &calib.x, &cfg);
            cells.push(format!("{:.4}", vision_accuracy(|x| r.forward(x), &test, 128)));
        }
        for closed in [true, false] {
            let mut m = lm.clone();
            let mut cfg = CompressionSpec::uniform(Method::Prune(Selector::Wanda), ratio, true);
            cfg.closed_loop = closed;
            compress_model(&mut m, &lm_calib, &cfg);
            cells.push(f(lm_perplexity(&m, &eval_toks, 32, eval_windows, 16)));
        }
        // Reorder: resnet closed/open then lm closed/open.
        let row = vec![cells[0].clone(), cells[1].clone(), cells[2].clone(), cells[3].clone(), cells[4].clone()];
        t2.row(row);
    }
    println!("Ablation 2 — closed vs open loop:\n{}", t2.render());
    t2.write_csv(&opts.out_path("ablation_loop.csv")?)?;
    Ok(())
}
