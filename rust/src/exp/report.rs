//! Report formatting: ASCII tables for the console, CSV for files.

use anyhow::{Context, Result};
use std::io::Write;

/// A simple column-aligned table builder.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with headers.
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Append a row (must match header count).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width");
        self.rows.push(cells);
    }

    /// Render as an aligned ASCII table.
    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for i in 0..ncols {
                if i > 0 {
                    s.push_str("  ");
                }
                s.push_str(&format!("{:<w$}", cells[i], w = widths[i]));
            }
            s.push('\n');
            s
        };
        let mut out = line(&self.headers);
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncols - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
        }
        out
    }

    /// Write as CSV.
    pub fn write_csv(&self, path: &str) -> Result<()> {
        let mut f = std::fs::File::create(path).with_context(|| format!("creating {path}"))?;
        writeln!(f, "{}", self.headers.join(","))?;
        for row in &self.rows {
            writeln!(f, "{}", row.join(","))?;
        }
        Ok(())
    }
}

/// Format a float compactly (paper-table style).
pub fn f(v: f64) -> String {
    if v.abs() >= 1000.0 {
        format!("{v:.0}")
    } else if v.abs() >= 100.0 {
        format!("{v:.1}")
    } else {
        format!("{v:.2}")
    }
}

/// Format a probability/accuracy with 4 decimals (Table 2 style).
pub fn acc(v: f64) -> String {
    format!("{v:.4}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["method", "ppl"]);
        t.row(vec!["wanda".into(), "8.25".into()]);
        t.row(vec!["wanda + GRAIL".into(), "8.05".into()]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("method"));
        assert!(lines[3].contains("GRAIL"));
    }

    #[test]
    fn csv_roundtrip() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let dir = std::env::temp_dir().join("grail_report_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.csv").to_string_lossy().into_owned();
        t.write_csv(&p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text, "a,b\n1,2\n");
    }

    #[test]
    fn float_formats() {
        assert_eq!(f(8.046), "8.05");
        assert_eq!(f(131.54), "131.5");
        assert_eq!(f(2291.52), "2292");
        assert_eq!(acc(0.37634), "0.3763");
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn row_width_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["1".into()]);
    }
}
