//! Figure 7 — GRAIL across pruning and folding for all three vision
//! architectures (MiniResNet, TinyViT, and the MLP standing alongside
//! as the third family): per-(architecture, method) before/after
//! accuracy shift, averaged over the ratio grid.

use super::report::{acc, Table};
use super::vision::{aggregate, ratio_grid, sweep, Family, SweepSpec, Variant};
use super::ExpOptions;
use crate::compress::Selector;
use crate::grail::Method;
use anyhow::Result;

/// Run the Fig. 7 grid.
pub fn run(opts: &ExpOptions) -> Result<()> {
    let zoo = opts.zoo()?;
    let mut table = Table::new(&["family", "method", "mean_acc_base", "mean_acc_grail", "shift"]);
    for (family, label) in [
        (Family::Resnet, "resnet"),
        (Family::Vit, "vit"),
        (Family::Mlp, "mlp"),
    ] {
        let mut ckpts = zoo.list(family.prefix());
        ckpts.truncate(if opts.quick { 1 } else { 2 });
        anyhow::ensure!(!ckpts.is_empty(), "no {label} checkpoints");
        let spec = SweepSpec {
            family,
            ckpts,
            methods: vec![
                Method::Fold,
                Method::Prune(Selector::MagnitudeL1),
                Method::Prune(Selector::MagnitudeL2),
                Method::Prune(Selector::Wanda),
            ],
            ratios: ratio_grid(opts.quick),
            variants: vec![Variant::Base, Variant::Grail],
            // MLP sites see one Gram row per image (conv/ViT sites see
            // 256/16 rows per image), so the MLP leg gets a larger
            // image budget to match the paper's effective row count.
            calib_n: if family == Family::Mlp { 256 } else { 128 },
            test_n: if opts.quick { 256 } else { 512 },
            seed: opts.seed,
        };
        let rows = sweep(opts, &spec)?;
        let agg = aggregate(&rows);
        // Collapse over ratios per method.
        let methods: Vec<String> = {
            let mut m: Vec<String> = agg.iter().map(|(m, ..)| m.clone()).collect();
            m.sort();
            m.dedup();
            m
        };
        for method in methods {
            let base: Vec<f64> = agg
                .iter()
                .filter(|(m, _, v, _, _)| *m == method && *v == "base")
                .map(|(_, _, _, a, _)| *a)
                .collect();
            let grail: Vec<f64> = agg
                .iter()
                .filter(|(m, _, v, _, _)| *m == method && *v == "grail")
                .map(|(_, _, _, a, _)| *a)
                .collect();
            let mb = base.iter().sum::<f64>() / base.len().max(1) as f64;
            let mg = grail.iter().sum::<f64>() / grail.len().max(1) as f64;
            table.row(vec![
                label.to_string(),
                method,
                acc(mb),
                acc(mg),
                format!("{:+.4}", mg - mb),
            ]);
        }
        println!("  done: {label}");
    }
    println!("{}", table.render());
    table.write_csv(&opts.out_path("fig7.csv")?)?;
    Ok(())
}
