//! Table 3 — calibration vs compensation overhead (time + memory) for
//! every architecture. The paper's shape: calibration dominates,
//! compensation is lightweight.

use super::report::Table;
use super::ExpOptions;
use crate::compress::Selector;
use crate::coordinator::metrics::{peak_rss_mib, rss_mib};
use crate::grail::{compress_model, Method, CompressionSpec};
use crate::nn::models::LmBatch;
use anyhow::Result;

/// Run the Table 3 measurements.
pub fn run(opts: &ExpOptions) -> Result<()> {
    let zoo = opts.zoo()?;
    let calib = crate::data::io::read_images(&opts.artifacts.data("vision_calib.imgs"))?
        .slice(0, 128);
    let calib_toks = crate::data::io::read_tokens(&opts.artifacts.data("text_calib.tokens"))?;
    let lm_calib = LmBatch::from_tokens(&calib_toks, 32, if opts.quick { 32 } else { 128 });

    let mut table = Table::new(&[
        "model",
        "calib_time_s",
        "comp_time_s",
        "rss_before_mib",
        "peak_rss_mib",
    ]);
    let cfg = CompressionSpec::uniform(Method::Prune(Selector::Wanda), 0.5, true);

    {
        let mut m = zoo.mlp("mlp_seed0")?;
        let before = rss_mib();
        let rep = compress_model(&mut m, &calib.x, &cfg);
        table.row(vec![
            "MLP".into(),
            format!("{:.3}", rep.calib_seconds),
            format!("{:.3}", rep.comp_seconds),
            format!("{before:.1}"),
            format!("{:.1}", peak_rss_mib()),
        ]);
    }
    {
        let mut m = zoo.resnet("resnet_seed0")?;
        let before = rss_mib();
        let rep = compress_model(&mut m, &calib.x, &cfg);
        table.row(vec![
            "MiniResNet".into(),
            format!("{:.3}", rep.calib_seconds),
            format!("{:.3}", rep.comp_seconds),
            format!("{before:.1}"),
            format!("{:.1}", peak_rss_mib()),
        ]);
    }
    {
        let mut m = zoo.vit("vit_seed0")?;
        let before = rss_mib();
        let rep = compress_model(&mut m, &calib.x, &cfg);
        table.row(vec![
            "TinyViT".into(),
            format!("{:.3}", rep.calib_seconds),
            format!("{:.3}", rep.comp_seconds),
            format!("{before:.1}"),
            format!("{:.1}", peak_rss_mib()),
        ]);
    }
    {
        let mut m = zoo.lm("tinylm_mha")?;
        let before = rss_mib();
        let rep = compress_model(&mut m, &lm_calib, &cfg);
        table.row(vec![
            "TinyLm".into(),
            format!("{:.3}", rep.calib_seconds),
            format!("{:.3}", rep.comp_seconds),
            format!("{before:.1}"),
            format!("{:.1}", peak_rss_mib()),
        ]);
    }
    println!("{}", table.render());
    table.write_csv(&opts.out_path("table3.csv")?)?;
    Ok(())
}
