//! Table 1 — perplexity on TinyLm (the LLaMA-2-7B substitute) across
//! three SynthText splits × sparsity levels × five structured pruning
//! baselines, with and without GRAIL (ZipLM excluded from stacking —
//! its selection and update are inseparable, paper §4.2).

use super::report::{f, Table};
use super::ExpOptions;
use crate::compress::baselines::Baseline;
use crate::data::TextSplit;
use crate::eval::lm_perplexity;
use crate::grail::{compress_model, Method, CompressionSpec};
use crate::nn::models::LmBatch;
use anyhow::Result;

/// Sequence length for calibration/eval windows (the paper uses 2048
/// for LLaMA; TinyLm's context is 64).
pub const SEQ: usize = 32;
/// Calibration windows (paper: 128 sequences).
pub const CALIB_WINDOWS: usize = 128;
/// Eval windows per split.
pub const EVAL_WINDOWS: usize = 96;

/// The method column of Table 1: `(label, baseline, grail)`.
pub fn method_rows() -> Vec<(String, Baseline, bool)> {
    let mut rows = Vec::new();
    for b in [
        Baseline::ZipLM,
        Baseline::Wanda,
        Baseline::WandaPP,
        Baseline::SlimGPT,
        Baseline::Flap,
    ] {
        rows.push((b.name().to_string(), b, false));
        if b.grail_compatible() {
            rows.push((format!("{} + GRAIL", b.name()), b, true));
        }
    }
    rows
}

/// Run the Table 1 grid.
pub fn run(opts: &ExpOptions) -> Result<()> {
    let zoo = opts.zoo()?;
    let base = zoo.lm("tinylm_mha")?;
    let calib_toks = crate::data::io::read_tokens(&opts.artifacts.data("text_calib.tokens"))?;
    let calib = LmBatch::from_tokens(&calib_toks, SEQ, CALIB_WINDOWS);

    let sparsities: Vec<f64> = if opts.quick {
        vec![0.2, 0.5]
    } else {
        (1..=7).map(|i| i as f64 / 10.0).collect()
    };
    let splits = [TextSplit::C4s, TextSplit::Wt2s, TextSplit::Ptbs];
    let eval_toks: Vec<_> = splits
        .iter()
        .map(|s| crate::data::io::read_tokens(&opts.artifacts.data(&format!("text_{}.tokens", s.name()))))
        .collect::<Result<_>>()?;
    let eval_windows = if opts.quick { 32 } else { EVAL_WINDOWS };

    let mut header = vec!["dataset".to_string(), "method".to_string()];
    header.extend(sparsities.iter().map(|s| format!("{:.0}%", s * 100.0)));
    let hdr_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&hdr_refs);

    // Dense reference per split (not in the paper table but useful).
    let mut dense_row = vec!["(all)".to_string(), "dense".to_string()];
    let dense: Vec<f64> = eval_toks
        .iter()
        .map(|t| lm_perplexity(&base, t, SEQ, eval_windows, 16))
        .collect();
    dense_row.extend(sparsities.iter().map(|_| {
        format!("{}", f(dense.iter().sum::<f64>() / dense.len() as f64))
    }));
    table.row(dense_row);

    // Compression is split-independent (calibration uses its own
    // split), so compress once per (method, sparsity) and evaluate all
    // three datasets from the same compressed model.
    let methods = method_rows();
    // ppl[method][sparsity][split]
    let mut ppl = vec![vec![vec![0.0f64; splits.len()]; sparsities.len()]; methods.len()];
    for (mi, (label, baseline, grail)) in methods.iter().enumerate() {
        for (pi, &sp) in sparsities.iter().enumerate() {
            let mut m = base.clone();
            let mut cfg = CompressionSpec::uniform(Method::Baseline(*baseline), sp, *grail);
            cfg.seed = opts.seed;
            compress_model(&mut m, &calib, &cfg);
            for (si, toks) in eval_toks.iter().enumerate() {
                ppl[mi][pi][si] = lm_perplexity(&m, toks, SEQ, eval_windows, 16);
            }
        }
        println!("  done: {label}");
    }
    for (si, split) in splits.iter().enumerate() {
        for (mi, (label, _, _)) in methods.iter().enumerate() {
            let mut cells = vec![split.name().to_string(), label.clone()];
            for pi in 0..sparsities.len() {
                cells.push(f(ppl[mi][pi][si]));
            }
            table.row(cells);
        }
    }
    println!("{}", table.render());
    table.write_csv(&opts.out_path("table1.csv")?)?;
    Ok(())
}
