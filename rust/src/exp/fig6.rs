//! Figure 6 — GRAIL under *random* pruning and folding on MiniResNet
//! and TinyViT: before/after scatter plus accuracy gains across
//! compression ratios. Random reducers remove any selector signal, so
//! any gain is attributable purely to the compensation.

use super::report::{acc, Table};
use super::vision::{ratio_grid, sweep, Family, SweepSpec, Variant};
use super::ExpOptions;
use crate::compress::Selector;
use crate::grail::Method;
use anyhow::Result;

/// Run the Fig. 6 grids.
pub fn run(opts: &ExpOptions) -> Result<()> {
    let zoo = opts.zoo()?;
    let mut table = Table::new(&["family", "mode", "ckpt", "ratio", "acc_before", "acc_after", "gain"]);
    for (family, label) in [(Family::Resnet, "resnet"), (Family::Vit, "vit")] {
        let mut ckpts = zoo.list(family.prefix());
        ckpts.truncate(if opts.quick { 1 } else { 2 });
        anyhow::ensure!(!ckpts.is_empty(), "no {label} checkpoints");
        for (mode, method) in [
            ("random-prune", Method::Prune(Selector::Random)),
            ("random-fold", Method::RandomFold),
        ] {
            let spec = SweepSpec {
                family,
                ckpts: ckpts.clone(),
                methods: vec![method],
                ratios: ratio_grid(opts.quick),
                variants: vec![Variant::Base, Variant::Grail],
                calib_n: 128,
                test_n: if opts.quick { 256 } else { 512 },
                seed: opts.seed,
            };
            let rows = sweep(opts, &spec)?;
            // Pair base/grail rows (same ckpt+ratio, adjacent by construction).
            for pair in rows.chunks(2) {
                if pair.len() != 2 {
                    continue;
                }
                let (b, g) = (&pair[0], &pair[1]);
                table.row(vec![
                    label.to_string(),
                    mode.to_string(),
                    b.ckpt.clone(),
                    format!("{:.1}", b.ratio),
                    acc(b.acc),
                    acc(g.acc),
                    format!("{:+.4}", g.acc - b.acc),
                ]);
            }
            println!("  done: {label} / {mode}");
        }
    }
    println!("{}", table.render());
    table.write_csv(&opts.out_path("fig6.csv")?)?;
    Ok(())
}
