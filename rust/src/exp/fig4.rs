//! Figure 4 — calibration-set-size ablation: accuracy/perplexity
//! recovery vs number of calibration samples. Left panel: MiniResNet
//! at 75% sparsity; right panel: TinyLm at 40% sparsity. Expected
//! shape: log-like growth with fast saturation.

use super::report::{f, Table};
use super::ExpOptions;
use crate::compress::baselines::Baseline;
use crate::compress::Selector;
use crate::data::TextSplit;
use crate::eval::{lm_perplexity, vision_accuracy};
use crate::grail::{compress_model, Method, CompressionSpec};
use crate::nn::models::LmBatch;
use anyhow::Result;

/// Run the Fig. 4 ablations.
pub fn run(opts: &ExpOptions) -> Result<()> {
    let zoo = opts.zoo()?;
    let methods = [
        Method::Prune(Selector::MagnitudeL1),
        Method::Prune(Selector::Wanda),
        Method::Baseline(Baseline::Flap),
        Method::Fold,
    ];

    // ---- left panel: MiniResNet @ 75% sparsity
    let calib_full = crate::data::io::read_images(&opts.artifacts.data("vision_calib.imgs"))?;
    let test = crate::data::io::read_images(&opts.artifacts.data("vision_test.imgs"))?
        .slice(0, if opts.quick { 256 } else { 512 });
    let sizes: &[usize] = if opts.quick { &[8, 64, 256] } else { &[4, 8, 16, 32, 64, 128, 256, 512] };
    let base = zoo.resnet("resnet_seed0")?;
    let base_acc = vision_accuracy(|x| base.forward(x), &test, 128);

    let mut left = Table::new(&["method", "calib_n", "acc", "gain_vs_uncompensated"]);
    for method in methods {
        // Uncompensated reference for the gain column.
        let mut plain = base.clone();
        let mut cfg0 = CompressionSpec::uniform(method, 0.75, false);
        cfg0.seed = opts.seed;
        // Even "uncompensated" pipelines need calibration for
        // data-aware selectors; give them the full set.
        compress_model(&mut plain, &calib_full.x, &cfg0);
        let plain_acc = vision_accuracy(|x| plain.forward(x), &test, 128);
        for &n in sizes {
            let mut m = base.clone();
            let mut cfg = CompressionSpec::uniform(method, 0.75, true);
            cfg.seed = opts.seed;
            let calib = calib_full.slice(0, n);
            compress_model(&mut m, &calib.x, &cfg);
            let acc = vision_accuracy(|x| m.forward(x), &test, 128);
            left.row(vec![
                method.name(),
                n.to_string(),
                format!("{acc:.4}"),
                format!("{:+.4}", acc - plain_acc),
            ]);
        }
    }
    println!("Fig.4 left — MiniResNet @75% (dense acc {base_acc:.4}):\n{}", left.render());
    left.write_csv(&opts.out_path("fig4_resnet.csv")?)?;

    // ---- right panel: TinyLm @ 40% sparsity
    let calib_toks = crate::data::io::read_tokens(&opts.artifacts.data("text_calib.tokens"))?;
    let eval_toks =
        crate::data::io::read_tokens(&opts.artifacts.data(&format!("text_{}.tokens", TextSplit::Wt2s.name())))?;
    let eval_windows = if opts.quick { 32 } else { 96 };
    let lm = zoo.lm("tinylm_mha")?;
    let window_counts: &[usize] = if opts.quick { &[4, 32, 128] } else { &[2, 4, 8, 16, 32, 64, 128, 256] };

    let mut right = Table::new(&["method", "calib_windows", "ppl"]);
    for method in [
        Method::Baseline(Baseline::Wanda),
        Method::Baseline(Baseline::SlimGPT),
        Method::Baseline(Baseline::Flap),
    ] {
        for &w in window_counts {
            let mut m = lm.clone();
            let mut cfg = CompressionSpec::uniform(method, 0.4, true);
            cfg.seed = opts.seed;
            let calib = LmBatch::from_tokens(&calib_toks, 32, w);
            compress_model(&mut m, &calib, &cfg);
            let ppl = lm_perplexity(&m, &eval_toks, 32, eval_windows, 16);
            right.row(vec![method.name(), w.to_string(), f(ppl)]);
        }
        println!("  done: {}", method.name());
    }
    println!("Fig.4 right — TinyLm @40%:\n{}", right.render());
    right.write_csv(&opts.out_path("fig4_lm.csv")?)?;
    Ok(())
}
