//! Figure 2 — GRAIL on MiniResNet / SynthVision (the ResNet-18 /
//! CIFAR-10 panels): (a) accuracy vs layer-wise uniform compression
//! ratio, (b) mean accuracy vs sparsity against REPAIR (with the
//! uncompressed-oracle line standing in for the paper's 5-epoch
//! finetuning reference — no training exists in the Rust runtime; see
//! DESIGN.md §2), (c) relative improvement from GRAIL.

use super::report::{acc, Table};
use super::vision::{aggregate, ratio_grid, sweep, Family, SweepSpec, Variant};
use super::ExpOptions;
use crate::compress::Selector;
use crate::grail::Method;
use anyhow::Result;

/// Run the Fig. 2 sweep.
pub fn run(opts: &ExpOptions) -> Result<()> {
    let zoo = opts.zoo()?;
    let mut ckpts = zoo.list("resnet");
    if opts.quick {
        ckpts.truncate(1);
    } else {
        ckpts.truncate(4);
    }
    anyhow::ensure!(!ckpts.is_empty(), "no resnet checkpoints (run `make artifacts`)");

    // Panels (a) + (c): four reduction methods × {base, grail}.
    let spec = SweepSpec {
        family: Family::Resnet,
        ckpts: ckpts.clone(),
        methods: vec![
            Method::Prune(Selector::MagnitudeL1),
            Method::Prune(Selector::MagnitudeL2),
            Method::Prune(Selector::Wanda),
            Method::Fold,
        ],
        ratios: ratio_grid(opts.quick),
        variants: vec![Variant::Base, Variant::Grail],
        calib_n: 128,
        test_n: if opts.quick { 256 } else { 512 },
        seed: opts.seed,
    };
    let rows = sweep(opts, &spec)?;

    // Panel (b): REPAIR comparison on one representative selector.
    let spec_b = SweepSpec {
        methods: vec![Method::Prune(Selector::MagnitudeL2)],
        variants: vec![Variant::Repair, Variant::GrailRepair],
        ckpts,
        ..spec
    };
    let rows_b = sweep(opts, &spec_b)?;

    let mut table = Table::new(&["method", "ratio", "variant", "mean_acc", "oracle_acc"]);
    let mut all = rows.clone();
    all.extend(rows_b.clone());
    for (m, ratio, v, a, b) in aggregate(&all) {
        table.row(vec![m, format!("{ratio:.1}"), v.to_string(), acc(a), acc(b)]);
    }
    println!("{}", table.render());
    table.write_csv(&opts.out_path("fig2.csv")?)?;

    // Panel (c) summary: mean relative improvement per (method, ratio).
    let mut improve = Table::new(&["method", "ratio", "grail_gain"]);
    let agg = aggregate(&all);
    for (m, ratio, v, a, _) in &agg {
        if *v != "grail" {
            continue;
        }
        if let Some((_, _, _, base, _)) = agg
            .iter()
            .find(|(m2, r2, v2, _, _)| m2 == m && (r2 - ratio).abs() < 1e-9 && *v2 == "base")
        {
            improve.row(vec![m.clone(), format!("{ratio:.1}"), acc(a - base)]);
        }
    }
    println!("Relative improvement from GRAIL (panel c):\n{}", improve.render());
    improve.write_csv(&opts.out_path("fig2_improvement.csv")?)?;
    Ok(())
}
