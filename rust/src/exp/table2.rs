//! Table 2 — zero-shot probe accuracy on TinyLm at 20% / 50% sparsity
//! for the five baselines ± GRAIL. The six probe tasks substitute for
//! ARC-C/E, HellaSwag, PIQA, BoolQ, Winogrande (DESIGN.md §2) — same
//! evaluation shape: likelihood-ranked multiple choice.

use super::report::{acc, Table};
use super::table1::{method_rows, CALIB_WINDOWS, SEQ};
use super::ExpOptions;
use crate::data::SynthText;
use crate::eval::probes::{probe_accuracy, probe_items, ProbeTask};
use crate::grail::{compress_model, Method, CompressionSpec};
use crate::nn::models::LmBatch;
use anyhow::Result;

/// Run the Table 2 grid.
pub fn run(opts: &ExpOptions) -> Result<()> {
    let zoo = opts.zoo()?;
    let base = zoo.lm("tinylm_mha")?;
    let calib_toks = crate::data::io::read_tokens(&opts.artifacts.data("text_calib.tokens"))?;
    let calib = LmBatch::from_tokens(&calib_toks, SEQ, CALIB_WINDOWS);
    let text = SynthText::new(crate::coordinator::datagen::TASK_SEED);
    let n_items = if opts.quick { 24 } else { 96 };
    let items: Vec<_> = ProbeTask::ALL
        .iter()
        .map(|&t| probe_items(t, &text, n_items, opts.seed + 7))
        .collect();

    let mut header = vec!["sparsity".to_string(), "method".to_string()];
    header.extend(ProbeTask::ALL.iter().map(|t| t.name().to_string()));
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&hdr);

    // Dense reference row.
    let mut row = vec!["0%".to_string(), "dense".to_string()];
    for it in &items {
        row.push(acc(probe_accuracy(&base, it)));
    }
    table.row(row);

    for &sp in if opts.quick { &[0.5][..] } else { &[0.2, 0.5][..] } {
        for (label, baseline, grail) in method_rows() {
            let mut m = base.clone();
            let mut cfg = CompressionSpec::uniform(Method::Baseline(baseline), sp, grail);
            cfg.seed = opts.seed;
            compress_model(&mut m, &calib, &cfg);
            let mut row = vec![format!("{:.0}%", sp * 100.0), label.clone()];
            for it in &items {
                row.push(acc(probe_accuracy(&m, it)));
            }
            table.row(row);
            println!("  done: {:.0}% / {label}", sp * 100.0);
        }
    }
    println!("{}", table.render());
    table.write_csv(&opts.out_path("table2.csv")?)?;
    Ok(())
}
