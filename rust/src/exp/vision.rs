//! Shared sweep engine for the vision experiments (Figs. 2/3/5/6/7):
//! checkpoints × methods × ratios × {base, +GRAIL, +REPAIR, …} grids.

use super::ExpOptions;
use crate::coordinator::Zoo;
use crate::data::VisionSet;
use crate::eval::vision_accuracy;
use crate::grail::{
    compress_model, execute_plan, plan_for_model, search_plan, CompressionPlan, CompressionSpec,
    Method, SearchOutcome,
};
use crate::nn::models::{MiniResNet, MlpNet, TinyViT};
use crate::tensor::Tensor;
use anyhow::Result;

/// Model family of a sweep.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Family {
    Mlp,
    Resnet,
    Vit,
}

impl Family {
    /// Checkpoint-name prefix in the zoo.
    pub fn prefix(&self) -> &'static str {
        match self {
            Family::Mlp => "mlp",
            Family::Resnet => "resnet",
            Family::Vit => "vit",
        }
    }
}

/// A loaded vision model (enum dispatch keeps the sweep engine free of
/// generics over `Compressible`).
pub enum VisionModel {
    Mlp(MlpNet),
    Resnet(MiniResNet),
    Vit(TinyViT),
}

impl VisionModel {
    /// Load a checkpoint.
    pub fn load(zoo: &Zoo, family: Family, name: &str) -> Result<VisionModel> {
        Ok(match family {
            Family::Mlp => VisionModel::Mlp(zoo.mlp(name)?),
            Family::Resnet => VisionModel::Resnet(zoo.resnet(name)?),
            Family::Vit => VisionModel::Vit(zoo.vit(name)?),
        })
    }

    /// Logits for a flattened image batch.
    pub fn forward(&self, x: &Tensor) -> Tensor {
        match self {
            VisionModel::Mlp(m) => m.forward(x),
            VisionModel::Resnet(m) => m.forward(x),
            VisionModel::Vit(m) => m.forward(x),
        }
    }

    /// Run the closed-loop compression pipeline.
    pub fn compress(&mut self, calib_x: &Tensor, spec: &CompressionSpec) -> crate::grail::Report {
        match self {
            VisionModel::Mlp(m) => compress_model(m, calib_x, spec),
            VisionModel::Resnet(m) => compress_model(m, calib_x, spec),
            VisionModel::Vit(m) => compress_model(m, calib_x, spec),
        }
    }

    /// Resolve a spec into a plan without mutating the model
    /// (`grail plan`).
    pub fn plan(&self, calib_x: &Tensor, spec: &CompressionSpec) -> Result<CompressionPlan> {
        match self {
            VisionModel::Mlp(m) => plan_for_model(m, calib_x, spec),
            VisionModel::Resnet(m) => plan_for_model(m, calib_x, spec),
            VisionModel::Vit(m) => plan_for_model(m, calib_x, spec),
        }
    }

    /// Run the calibration-driven plan search (`grail tune`) — needs a
    /// spec with `budget.mode = "search"`.
    pub fn tune(&self, calib_x: &Tensor, spec: &CompressionSpec) -> Result<SearchOutcome> {
        match self {
            VisionModel::Mlp(m) => search_plan(m, calib_x, spec),
            VisionModel::Resnet(m) => search_plan(m, calib_x, spec),
            VisionModel::Vit(m) => search_plan(m, calib_x, spec),
        }
    }

    /// Execute an already-resolved plan.
    pub fn execute(&mut self, calib_x: &Tensor, plan: &CompressionPlan) -> crate::grail::Report {
        match self {
            VisionModel::Mlp(m) => execute_plan(m, calib_x, plan),
            VisionModel::Resnet(m) => execute_plan(m, calib_x, plan),
            VisionModel::Vit(m) => execute_plan(m, calib_x, plan),
        }
    }

    /// REPAIR BN-statistics reset (MiniResNet only; no-op otherwise).
    pub fn repair(&mut self, calib: &VisionSet) -> bool {
        match self {
            VisionModel::Resnet(m) => {
                m.repair(calib);
                true
            }
            _ => false,
        }
    }

    /// Test accuracy (batched).
    pub fn accuracy(&self, test: &VisionSet) -> f64 {
        vision_accuracy(|x| self.forward(x), test, 128)
    }
}

/// Post-compression recovery variant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    Base,
    Grail,
    Repair,
    GrailRepair,
}

impl Variant {
    /// Stable display name.
    pub fn name(&self) -> &'static str {
        match self {
            Variant::Base => "base",
            Variant::Grail => "grail",
            Variant::Repair => "repair",
            Variant::GrailRepair => "grail+repair",
        }
    }

    fn wants_grail(&self) -> bool {
        matches!(self, Variant::Grail | Variant::GrailRepair)
    }

    fn wants_repair(&self) -> bool {
        matches!(self, Variant::Repair | Variant::GrailRepair)
    }
}

/// One sweep measurement.
#[derive(Clone, Debug)]
pub struct SweepRow {
    pub ckpt: String,
    pub method: String,
    pub ratio: f64,
    pub variant: &'static str,
    pub acc: f64,
    /// Uncompressed accuracy of the same checkpoint (the oracle line).
    pub base_acc: f64,
}

/// Sweep configuration.
pub struct SweepSpec {
    pub family: Family,
    pub ckpts: Vec<String>,
    pub methods: Vec<Method>,
    pub ratios: Vec<f64>,
    pub variants: Vec<Variant>,
    pub calib_n: usize,
    pub test_n: usize,
    pub seed: u64,
}

/// Default ratio grid (paper: 0.1–0.9 layer-wise uniform).
pub fn ratio_grid(quick: bool) -> Vec<f64> {
    if quick {
        vec![0.1, 0.3, 0.5, 0.7, 0.9]
    } else {
        (1..=9).map(|i| i as f64 / 10.0).collect()
    }
}

/// Run a sweep; rows come back in (ckpt, method, ratio, variant) order.
pub fn sweep(opts: &ExpOptions, spec: &SweepSpec) -> Result<Vec<SweepRow>> {
    let zoo = opts.zoo()?;
    let calib = crate::data::io::read_images(&opts.artifacts.data("vision_calib.imgs"))?
        .slice(0, spec.calib_n);
    let test =
        crate::data::io::read_images(&opts.artifacts.data("vision_test.imgs"))?.slice(0, spec.test_n);
    let mut rows = Vec::new();
    for ckpt in &spec.ckpts {
        let original = VisionModel::load(&zoo, spec.family, ckpt)?;
        let base_acc = original.accuracy(&test);
        for method in &spec.methods {
            for &ratio in &spec.ratios {
                for &variant in &spec.variants {
                    let mut m = VisionModel::load(&zoo, spec.family, ckpt)?;
                    let mut cfg = CompressionSpec::uniform(*method, ratio, variant.wants_grail());
                    cfg.seed = spec.seed;
                    m.compress(&calib.x, &cfg);
                    if variant.wants_repair() {
                        m.repair(&calib);
                    }
                    let acc = m.accuracy(&test);
                    rows.push(SweepRow {
                        ckpt: ckpt.clone(),
                        method: method.name(),
                        ratio,
                        variant: variant.name(),
                        acc,
                        base_acc,
                    });
                }
            }
        }
    }
    Ok(rows)
}

/// Mean accuracy over checkpoints for each (method, ratio, variant)
/// cell — the paper's "mean accuracy vs sparsity" panels.
pub fn aggregate(rows: &[SweepRow]) -> Vec<(String, f64, &'static str, f64, f64)> {
    use std::collections::BTreeMap;
    let mut acc: BTreeMap<(String, String, &'static str), (f64, f64, usize)> = BTreeMap::new();
    for r in rows {
        let key = (r.method.clone(), format!("{:.2}", r.ratio), r.variant);
        let e = acc.entry(key).or_insert((0.0, 0.0, 0));
        e.0 += r.acc;
        e.1 += r.base_acc;
        e.2 += 1;
    }
    acc.into_iter()
        .map(|((m, ratio, v), (a, b, n))| {
            (m, ratio.parse::<f64>().unwrap(), v, a / n as f64, b / n as f64)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratio_grids() {
        assert_eq!(ratio_grid(false).len(), 9);
        assert_eq!(ratio_grid(true).len(), 5);
        assert!((ratio_grid(false)[8] - 0.9).abs() < 1e-9);
    }

    #[test]
    fn variant_flags() {
        assert!(!Variant::Base.wants_grail());
        assert!(Variant::Grail.wants_grail() && !Variant::Grail.wants_repair());
        assert!(Variant::GrailRepair.wants_grail() && Variant::GrailRepair.wants_repair());
    }

    #[test]
    fn aggregate_means() {
        let rows = vec![
            SweepRow { ckpt: "a".into(), method: "wanda".into(), ratio: 0.5, variant: "base", acc: 0.4, base_acc: 0.9 },
            SweepRow { ckpt: "b".into(), method: "wanda".into(), ratio: 0.5, variant: "base", acc: 0.6, base_acc: 0.8 },
        ];
        let agg = aggregate(&rows);
        assert_eq!(agg.len(), 1);
        let (m, ratio, v, a, b) = &agg[0];
        assert_eq!(m, "wanda");
        assert!((ratio - 0.5).abs() < 1e-9);
        assert_eq!(*v, "base");
        assert!((a - 0.5).abs() < 1e-9);
        assert!((b - 0.85).abs() < 1e-9);
    }
}
