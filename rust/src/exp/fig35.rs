//! Figures 3 & 5 — GRAIL on TinyViT / SynthVision.
//!
//! The paper's Fig. 3 uses 72 CLIP ViT-B/32 checkpoints (ImageNet) and
//! Fig. 5 uses 125 ViT-B/32 checkpoints (CIFAR-10). With one TinyViT
//! family in the zoo, the two figures run the same grid over disjoint
//! seed subsets (DESIGN.md §2); the expected *shape* is shared: GRAIL
//! helps pruning more than folding, and compensated folds trail
//! compensated prunes.

use super::report::{acc, Table};
use super::vision::{aggregate, ratio_grid, sweep, Family, SweepSpec, Variant as V};
use super::ExpOptions;
use crate::compress::Selector;
use crate::grail::Method;
use anyhow::Result;

/// Which paper figure this run regenerates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    Fig3,
    Fig5,
}

/// Run the Fig. 3 / Fig. 5 sweep.
pub fn run(opts: &ExpOptions, which: Variant) -> Result<()> {
    let zoo = opts.zoo()?;
    let all = zoo.list("vit");
    anyhow::ensure!(!all.is_empty(), "no vit checkpoints (run `make artifacts`)");
    // Disjoint seed subsets per figure.
    let ckpts: Vec<String> = match which {
        Variant::Fig3 => all.iter().step_by(2).cloned().collect(),
        Variant::Fig5 => all.iter().skip(1).step_by(2).cloned().collect(),
    };
    let ckpts = if ckpts.is_empty() { all } else { ckpts };
    let spec = SweepSpec {
        family: Family::Vit,
        ckpts: if opts.quick { ckpts[..1].to_vec() } else { ckpts },
        methods: vec![
            Method::Prune(Selector::MagnitudeL1),
            Method::Prune(Selector::MagnitudeL2),
            Method::Prune(Selector::Wanda),
            Method::Fold,
        ],
        ratios: ratio_grid(opts.quick),
        variants: vec![V::Base, V::Grail],
        calib_n: 128,
        test_n: if opts.quick { 256 } else { 1024 },
        seed: opts.seed,
    };
    let rows = sweep(opts, &spec)?;
    let name = match which {
        Variant::Fig3 => "fig3",
        Variant::Fig5 => "fig5",
    };
    let mut table = Table::new(&["method", "ratio", "variant", "mean_acc", "oracle_acc"]);
    for (m, ratio, v, a, b) in aggregate(&rows) {
        table.row(vec![m, format!("{ratio:.1}"), v.to_string(), acc(a), acc(b)]);
    }
    println!("{}", table.render());
    table.write_csv(&opts.out_path(&format!("{name}.csv"))?)?;
    Ok(())
}
