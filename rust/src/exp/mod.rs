//! Experiment harnesses: one module per paper table/figure.
//!
//! | module   | regenerates                                            |
//! |----------|--------------------------------------------------------|
//! | `vision` | shared engine for Figs. 2/3/5/6/7                      |
//! | `fig2`   | Fig. 2 — MiniResNet acc vs ratio, REPAIR comparison    |
//! | `fig35`  | Figs. 3 & 5 — TinyViT sweeps                           |
//! | `fig4`   | Fig. 4 — calibration-size ablation                     |
//! | `fig6`   | Fig. 6 — random pruning/folding before/after           |
//! | `fig7`   | Fig. 7 — per-method improvement grid                   |
//! | `table1` | Table 1 — TinyLm perplexity grid                       |
//! | `table2` | Table 2 — zero-shot probe accuracy                     |
//! | `table3` | Table 3 — calibration/compensation overhead            |
//!
//! Every experiment prints the paper-shaped rows and writes CSV under
//! `--out` (default `results/`). EXPERIMENTS.md records paper-vs-
//! measured for each.

pub mod ablation;
pub mod fig2;
pub mod runner;
pub mod fig35;
pub mod fig4;
pub mod fig6;
pub mod fig7;
pub mod report;
pub mod table1;
pub mod table2;
pub mod table3;
pub mod vision;

use crate::cli::Args;
use crate::coordinator::{Artifacts, Zoo};
use anyhow::{bail, Context, Result};

/// Options shared by all experiments.
#[derive(Clone, Debug)]
pub struct ExpOptions {
    pub out_dir: String,
    pub artifacts: Artifacts,
    /// Trim grids for smoke runs.
    pub quick: bool,
    pub seed: u64,
    /// Content-addressed statistics cache (`--cache <dir>`); when set,
    /// spec jobs serve calibration statistics through
    /// [`crate::serve::provider`] instead of recomputing them.
    pub cache: Option<std::sync::Arc<crate::serve::StatsCache>>,
}

impl ExpOptions {
    /// Parse from CLI args; `--config <file>` (TOML subset) supplies
    /// defaults under an `[exp]` section, explicit flags win.
    pub fn from_args(args: &Args) -> Result<ExpOptions> {
        let file = match args.opt("config") {
            Some(path) => crate::config::Config::load(path)?,
            None => crate::config::Config::default(),
        };
        let cache_dir = args
            .opt("cache")
            .map(|s| s.to_string())
            .or_else(|| file.str("exp.cache").ok().map(|s| s.to_string()));
        let cache = match cache_dir {
            Some(dir) => Some(std::sync::Arc::new(crate::serve::StatsCache::open(&dir)?)),
            None => None,
        };
        Ok(ExpOptions {
            out_dir: args
                .opt("out")
                .unwrap_or(file.str_or("exp.out", "results"))
                .to_string(),
            artifacts: Artifacts::at(
                args.opt("artifacts").unwrap_or(file.str_or("exp.artifacts", "artifacts")),
            ),
            quick: args.has("quick") || file.bool("exp.quick").unwrap_or(false),
            seed: match args.opt("seed") {
                Some(_) => args.opt_u64("seed", 0)?,
                None => file.usize_or("exp.seed", 0) as u64,
            },
            cache,
        })
    }

    /// Open the checkpoint zoo.
    pub fn zoo(&self) -> Result<Zoo> {
        Zoo::open(self.artifacts.clone())
    }

    /// Ensure the output directory exists; return a file path in it.
    pub fn out_path(&self, name: &str) -> Result<String> {
        std::fs::create_dir_all(&self.out_dir)
            .with_context(|| format!("creating {}", self.out_dir))?;
        Ok(format!("{}/{}", self.out_dir, name))
    }
}

/// `grail exp <id>` entrypoint.
pub fn run_cli(args: &Args) -> Result<()> {
    let id = args.pos(1, "experiment id")?.to_string();
    let opts = ExpOptions::from_args(args)?;
    match id.as_str() {
        "fig2" => fig2::run(&opts),
        "fig3" => fig35::run(&opts, fig35::Variant::Fig3),
        "fig5" => fig35::run(&opts, fig35::Variant::Fig5),
        "fig4" => fig4::run(&opts),
        "fig6" => fig6::run(&opts),
        "fig7" => fig7::run(&opts),
        "table1" => table1::run(&opts),
        "table2" => table2::run(&opts),
        "table3" => table3::run(&opts),
        "ablation" => ablation::run(&opts),
        "all" => {
            for (name, f) in EXPERIMENTS {
                println!("\n================ {name} ================");
                f(&opts)?;
            }
            Ok(())
        }
        other => bail!("unknown experiment `{other}`"),
    }
}

/// All experiments in run order.
pub const EXPERIMENTS: &[(&str, fn(&ExpOptions) -> Result<()>)] = &[
    ("fig2", fig2::run),
    ("fig3", |o| fig35::run(o, fig35::Variant::Fig3)),
    ("fig5", |o| fig35::run(o, fig35::Variant::Fig5)),
    ("fig6", fig6::run),
    ("fig7", fig7::run),
    ("table1", table1::run),
    ("table2", table2::run),
    ("table3", table3::run),
    ("fig4", fig4::run),
    ("ablation", ablation::run),
];

/// `grail compress` — a one-off layer-wise-uniform compression +
/// evaluation run. Heterogeneous per-site policies go through
/// `grail run --spec` ([`runner`]).
pub fn compress_cli(args: &Args) -> Result<()> {
    use crate::grail::{compress_model, CompressionSpec, Method};

    let opts = ExpOptions::from_args(args)?;
    let zoo = opts.zoo()?;
    let family = args.opt("family").unwrap_or("lm");
    let method_name = args.opt_or("method", "wanda");
    let method = Method::from_name(method_name)
        .ok_or_else(|| anyhow::anyhow!("unknown method `{method_name}`"))?;
    let ratio = args.opt_f64("ratio", 0.5)?;
    let grail = args.has("grail");
    let mut cfg = CompressionSpec::uniform(method, ratio, grail);
    cfg.defaults.alpha = args.opt_f64("alpha", crate::grail::DEFAULT_ALPHA as f64)? as f32;
    cfg.seed = opts.seed;

    match family {
        "mlp" | "resnet" | "vit" => {
            let calib = crate::data::io::read_images(&opts.artifacts.data("vision_calib.imgs"))?
                .slice(0, 128);
            let test = crate::data::io::read_images(&opts.artifacts.data("vision_test.imgs"))?;
            let (base, after, report) = match family {
                "mlp" => {
                    let name = args.opt_or("ckpt", "mlp_seed0");
                    let mut m = zoo.mlp(name)?;
                    let base = crate::eval::vision_accuracy(|x| m.forward(x), &test, 128);
                    let rep = compress_model(&mut m, &calib.x, &cfg);
                    (base, crate::eval::vision_accuracy(|x| m.forward(x), &test, 128), rep)
                }
                "resnet" => {
                    let name = args.opt_or("ckpt", "resnet_seed0");
                    let mut m = zoo.resnet(name)?;
                    let base = crate::eval::vision_accuracy(|x| m.forward(x), &test, 128);
                    let rep = compress_model(&mut m, &calib.x, &cfg);
                    if args.has("repair") {
                        m.repair(&calib);
                    }
                    (base, crate::eval::vision_accuracy(|x| m.forward(x), &test, 128), rep)
                }
                _ => {
                    let name = args.opt_or("ckpt", "vit_seed0");
                    let mut m = zoo.vit(name)?;
                    let base = crate::eval::vision_accuracy(|x| m.forward(x), &test, 128);
                    let rep = compress_model(&mut m, &calib.x, &cfg);
                    (base, crate::eval::vision_accuracy(|x| m.forward(x), &test, 128), rep)
                }
            };
            println!(
                "{family} {method_name} ratio={ratio} grail={grail}: acc {base:.4} -> {after:.4}"
            );
            runner::print_report(&report);
        }
        "lm" => {
            let name = args.opt_or("ckpt", "tinylm_mha");
            let mut m = zoo.lm(name)?;
            let calib_toks =
                crate::data::io::read_tokens(&opts.artifacts.data("text_calib.tokens"))?;
            let calib = crate::nn::models::LmBatch::from_tokens(&calib_toks, 32, 64);
            let eval_toks = crate::data::io::read_tokens(&opts.artifacts.data("text_wt2s.tokens"))?;
            let base = crate::eval::lm_perplexity(&m, &eval_toks, 32, 64, 16);
            let rep = compress_model(&mut m, &calib, &cfg);
            let after = crate::eval::lm_perplexity(&m, &eval_toks, 32, 64, 16);
            println!("lm {method_name} ratio={ratio} grail={grail}: ppl {base:.2} -> {after:.2}");
            runner::print_report(&rep);
        }
        other => bail!("unknown family `{other}`"),
    }
    Ok(())
}
