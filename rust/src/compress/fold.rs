//! Model folding: cluster units and replace each cluster by its
//! centroid (paper §3.1, following Wang et al. "model folding").
//!
//! Units are clustered over their *producer weight rows* (the standard
//! folding feature space); attention heads are clustered over their
//! flattened per-head query rows. GQA sites cluster within KV groups
//! so the block-diagonal reducer constraint holds.

use super::{Reducer, SiteInfo};
use crate::linalg::kmeans;
use crate::rng::Pcg64;
use crate::tensor::{ops, Tensor};

/// Build a folding reducer for a site by k-means clustering the rows
/// of `features: [units, d]` into `k_units` clusters.
///
/// For grouped sites (GQA), clustering happens independently inside
/// each group with `k_units / groups` clusters, and cluster ids are
/// offset so each group owns a contiguous block.
pub fn fold_reducer(
    features: &Tensor,
    site: &SiteInfo,
    k_units: usize,
    rng: &mut Pcg64,
) -> Reducer {
    let units = site.units;
    assert_eq!(features.dim(0), units, "one feature row per unit");
    assert!(k_units >= 1 && k_units <= units);
    if site.groups <= 1 {
        let r = kmeans(features, k_units, rng, 100);
        return Reducer::Fold { assign: r.assign, k: k_units };
    }
    assert_eq!(k_units % site.groups, 0, "grouped folding needs equal per-group counts");
    assert_eq!(units % site.groups, 0);
    let per_group = units / site.groups;
    let k_per_group = k_units / site.groups;
    let mut assign = vec![0usize; units];
    for g in 0..site.groups {
        let rows: Vec<usize> = (g * per_group..(g + 1) * per_group).collect();
        let feats = ops::gather_rows(features, &rows);
        let r = kmeans(&feats, k_per_group, rng, 100);
        for (local, &a) in r.assign.iter().enumerate() {
            assign[g * per_group + local] = g * k_per_group + a;
        }
    }
    Reducer::Fold { assign, k: k_units }
}

/// Random folding (fig. 6 baseline): uniform random assignment with
/// every cluster non-empty.
pub fn random_fold(site: &SiteInfo, k_units: usize, rng: &mut Pcg64) -> Reducer {
    let units = site.units;
    assert!(k_units >= 1 && k_units <= units);
    if site.groups > 1 {
        assert_eq!(k_units % site.groups, 0);
        assert_eq!(units % site.groups, 0);
        let per_group = units / site.groups;
        let k_per_group = k_units / site.groups;
        let mut assign = vec![0usize; units];
        for g in 0..site.groups {
            let local = random_assignment(per_group, k_per_group, rng);
            for (i, &a) in local.iter().enumerate() {
                assign[g * per_group + i] = g * k_per_group + a;
            }
        }
        return Reducer::Fold { assign, k: k_units };
    }
    Reducer::Fold { assign: random_assignment(units, k_units, rng), k: k_units }
}

/// Uniform random assignment of `n` units to `k` clusters such that
/// every cluster receives at least one unit.
fn random_assignment(n: usize, k: usize, rng: &mut Pcg64) -> Vec<usize> {
    let mut assign: Vec<usize> = (0..n).map(|_| rng.below(k)).collect();
    // Guarantee non-empty clusters: claim one distinct unit per cluster.
    let owners = rng.choose_k(n, k);
    for (c, &u) in owners.iter().enumerate() {
        assign[u] = c;
    }
    assign
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::SiteKind;

    fn site(units: usize, groups: usize) -> SiteInfo {
        SiteInfo {
            id: "t".into(),
            units,
            unit_dim: 1,
            groups,
            kind: SiteKind::Dense,
        }
    }

    fn clustered_features() -> Tensor {
        // 6 units: rows 0-2 near (0,0), rows 3-5 near (5,5).
        Tensor::from_vec(
            &[6, 2],
            vec![0., 0., 0.1, 0., 0., 0.1, 5., 5., 5.1, 5., 5., 5.1],
        )
    }

    #[test]
    fn folds_similar_units_together() {
        let f = clustered_features();
        let r = fold_reducer(&f, &site(6, 1), 2, &mut Pcg64::seed(1));
        if let Reducer::Fold { assign, k } = r {
            assert_eq!(k, 2);
            assert_eq!(assign[0], assign[1]);
            assert_eq!(assign[1], assign[2]);
            assert_eq!(assign[3], assign[4]);
            assert_ne!(assign[0], assign[3]);
        } else {
            panic!("expected fold");
        }
    }

    #[test]
    fn grouped_fold_stays_in_groups() {
        let f = clustered_features();
        // 2 groups of 3 units; 2 clusters per group.
        let r = fold_reducer(&f, &site(6, 2), 4, &mut Pcg64::seed(2));
        if let Reducer::Fold { assign, k } = r {
            assert_eq!(k, 4);
            // Group 0 units get clusters {0,1}; group 1 gets {2,3}.
            for &a in &assign[..3] {
                assert!(a < 2, "{assign:?}");
            }
            for &a in &assign[3..] {
                assert!((2..4).contains(&a), "{assign:?}");
            }
        } else {
            panic!("expected fold");
        }
    }

    #[test]
    fn random_fold_covers_all_clusters() {
        for seed in 0..10 {
            let r = random_fold(&site(10, 1), 4, &mut Pcg64::seed(seed));
            if let Reducer::Fold { assign, k } = r {
                let mut seen = vec![false; k];
                for &a in &assign {
                    seen[a] = true;
                }
                assert!(seen.iter().all(|&s| s), "seed {seed}: {assign:?}");
            }
        }
    }

    #[test]
    fn fold_reducer_deterministic() {
        let f = clustered_features();
        let a = fold_reducer(&f, &site(6, 1), 3, &mut Pcg64::seed(9));
        let b = fold_reducer(&f, &site(6, 1), 3, &mut Pcg64::seed(9));
        assert_eq!(a, b);
    }
}
