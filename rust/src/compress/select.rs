//! Structured pruning selectors (paper §3.1: "the selection step is
//! method-agnostic").
//!
//! All selectors reduce to a per-unit score; the top `K` units are
//! kept. Scores may use producer weight norms (magnitude), calibration
//! activation statistics (Gram diagonal), consumer weight norms, or
//! their product (structured Wanda: `|W|·‖X‖`).

use super::{Reducer, SiteInfo};
use crate::rng::Pcg64;
use crate::tensor::Tensor;

/// Available pruning criteria.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Selector {
    /// Producer weight-row L1 norm.
    MagnitudeL1,
    /// Producer weight-row L2 norm.
    MagnitudeL2,
    /// Structured Wanda: activation norm × consumer column norm,
    /// aggregated per unit.
    Wanda,
    /// Gram-based selection: per-unit activation energy `Σ G_jj`.
    GramDiag,
    /// Uniform random (the fig. 6 baseline).
    Random,
}

impl Selector {
    /// Parse a CLI/config name.
    pub fn from_name(s: &str) -> Option<Selector> {
        Some(match s {
            "mag-l1" | "l1" => Selector::MagnitudeL1,
            "mag-l2" | "l2" => Selector::MagnitudeL2,
            "wanda" => Selector::Wanda,
            "gram" => Selector::GramDiag,
            "random" => Selector::Random,
            _ => return None,
        })
    }

    /// Stable display name.
    pub fn name(&self) -> &'static str {
        match self {
            Selector::MagnitudeL1 => "mag-l1",
            Selector::MagnitudeL2 => "mag-l2",
            Selector::Wanda => "wanda",
            Selector::GramDiag => "gram",
            Selector::Random => "random",
        }
    }
}

/// Everything a selector may consult. Feature-level vectors have
/// length `site.feat_width()`; producer norms are per unit.
pub struct ScoreInputs<'a> {
    pub site: &'a SiteInfo,
    /// Producer row norms per unit (L1).
    pub producer_l1: &'a [f32],
    /// Producer row norms per unit (L2).
    pub producer_l2: &'a [f32],
    /// Gram diagonal per feature (`‖X_j‖²` over the calibration set).
    pub gram_diag: &'a [f32],
    /// Consumer column L2 norms per feature.
    pub consumer_cols: &'a [f32],
}

/// Per-unit scores for a selector (higher = keep).
pub fn unit_scores(sel: Selector, inp: &ScoreInputs, rng: &mut Pcg64) -> Vec<f32> {
    let units = inp.site.units;
    let dh = inp.site.unit_dim;
    match sel {
        Selector::MagnitudeL1 => inp.producer_l1.to_vec(),
        Selector::MagnitudeL2 => inp.producer_l2.to_vec(),
        Selector::Wanda => {
            assert_eq!(inp.gram_diag.len(), units * dh, "gram diag length");
            assert_eq!(inp.consumer_cols.len(), units * dh, "consumer col length");
            (0..units)
                .map(|u| {
                    (0..dh)
                        .map(|j| {
                            let f = u * dh + j;
                            inp.gram_diag[f].max(0.0).sqrt() * inp.consumer_cols[f]
                        })
                        .sum()
                })
                .collect()
        }
        Selector::GramDiag => {
            assert_eq!(inp.gram_diag.len(), units * dh, "gram diag length");
            (0..units)
                .map(|u| (0..dh).map(|j| inp.gram_diag[u * dh + j]).sum())
                .collect()
        }
        Selector::Random => (0..units).map(|_| rng.next_f32()).collect(),
    }
}

/// Keep the `k` highest-scoring units (indices sorted ascending).
pub fn top_k(scores: &[f32], k: usize) -> Vec<usize> {
    assert!(k >= 1 && k <= scores.len(), "top_k: k={k} of {}", scores.len());
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_by(|&a, &b| scores[b].total_cmp(&scores[a]).then(a.cmp(&b)));
    let mut keep = idx[..k].to_vec();
    keep.sort_unstable();
    keep
}

/// Group-aware top-k: keep `k_total / groups` units per group (the
/// GQA block-diagonal constraint — paper §3.2). `k_total` must be a
/// multiple of `groups`.
pub fn top_k_grouped(scores: &[f32], groups: usize, k_total: usize) -> Vec<usize> {
    assert_eq!(k_total % groups, 0, "grouped selection needs equal per-group counts");
    assert_eq!(scores.len() % groups, 0, "units must split evenly into groups");
    let per_group = scores.len() / groups;
    let keep_per_group = k_total / groups;
    let mut keep = Vec::with_capacity(k_total);
    for g in 0..groups {
        let base = g * per_group;
        let local = top_k(&scores[base..base + per_group], keep_per_group);
        keep.extend(local.into_iter().map(|u| base + u));
    }
    keep
}

/// Build a selection reducer for a site: scores units, honours GQA
/// grouping, returns `Reducer::Select`.
pub fn select_reducer(
    sel: Selector,
    inp: &ScoreInputs,
    k_units: usize,
    rng: &mut Pcg64,
) -> Reducer {
    let scores = unit_scores(sel, inp, rng);
    let keep = if inp.site.groups > 1 {
        top_k_grouped(&scores, inp.site.groups, k_units)
    } else {
        top_k(&scores, k_units)
    };
    Reducer::Select(keep)
}

/// The Gram diagonal of an activation statistics matrix, as a vector.
pub fn gram_diag(g: &Tensor) -> Vec<f32> {
    (0..g.dim(0)).map(|i| g.at2(i, i)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::SiteKind;

    fn site(units: usize, dh: usize, groups: usize) -> SiteInfo {
        SiteInfo { id: "t".into(), units, unit_dim: dh, groups, kind: SiteKind::Dense }
    }

    #[test]
    fn top_k_orders_and_sorts() {
        let s = [0.1f32, 5.0, 3.0, 4.0];
        assert_eq!(top_k(&s, 2), vec![1, 3]);
        assert_eq!(top_k(&s, 4), vec![0, 1, 2, 3]);
    }

    #[test]
    fn top_k_ties_break_by_index() {
        let s = [1.0f32, 1.0, 1.0];
        assert_eq!(top_k(&s, 2), vec![0, 1]);
    }

    #[test]
    fn grouped_respects_groups() {
        // 2 groups of 3; best units are all in group 0, but selection
        // must keep 1 per group.
        let s = [9.0f32, 8.0, 7.0, 0.3, 0.1, 0.2];
        let keep = top_k_grouped(&s, 2, 2);
        assert_eq!(keep, vec![0, 3]);
    }

    #[test]
    fn wanda_scores_combine_both_signals() {
        let st = site(2, 1, 1);
        // unit 0: big weights, tiny activations. unit 1: the reverse.
        let inp = ScoreInputs {
            site: &st,
            producer_l1: &[10.0, 1.0],
            producer_l2: &[10.0, 1.0],
            gram_diag: &[0.01, 100.0],
            consumer_cols: &[1.0, 1.0],
        };
        let mut rng = Pcg64::seed(0);
        let mag = unit_scores(Selector::MagnitudeL1, &inp, &mut rng);
        let wanda = unit_scores(Selector::Wanda, &inp, &mut rng);
        assert!(mag[0] > mag[1]);
        assert!(wanda[1] > wanda[0], "wanda must weigh activations");
    }

    #[test]
    fn head_level_aggregation() {
        let st = site(2, 2, 1); // 2 heads × 2 features
        let inp = ScoreInputs {
            site: &st,
            producer_l1: &[0.0, 0.0],
            producer_l2: &[0.0, 0.0],
            gram_diag: &[1.0, 1.0, 3.0, 5.0],
            consumer_cols: &[1.0, 1.0, 1.0, 1.0],
        };
        let mut rng = Pcg64::seed(0);
        let s = unit_scores(Selector::GramDiag, &inp, &mut rng);
        assert_eq!(s, vec![2.0, 8.0]);
    }

    #[test]
    fn random_selection_is_seeded() {
        let st = site(8, 1, 1);
        let inp = ScoreInputs {
            site: &st,
            producer_l1: &[0.0; 8],
            producer_l2: &[0.0; 8],
            gram_diag: &[0.0; 8],
            consumer_cols: &[0.0; 8],
        };
        let a = select_reducer(Selector::Random, &inp, 3, &mut Pcg64::seed(5));
        let b = select_reducer(Selector::Random, &inp, 3, &mut Pcg64::seed(5));
        assert_eq!(a, b);
        if let Reducer::Select(keep) = a {
            assert_eq!(keep.len(), 3);
            assert!(keep.windows(2).all(|w| w[0] < w[1]));
        } else {
            panic!("expected selection");
        }
    }

    #[test]
    fn selector_names_roundtrip() {
        for s in [
            Selector::MagnitudeL1,
            Selector::MagnitudeL2,
            Selector::Wanda,
            Selector::GramDiag,
            Selector::Random,
        ] {
            assert_eq!(Selector::from_name(s.name()), Some(s));
        }
        assert_eq!(Selector::from_name("bogus"), None);
    }
}
