//! Structured-pruning baselines with their own recovery mechanisms
//! (the comparison methods of paper Tables 1–2).
//!
//! Each baseline is implemented from its published *mechanism* (the
//! original codebases target CUDA/HuggingFace stacks unavailable here —
//! DESIGN.md §2 documents the substitutions):
//!
//! - **FLAP-like** — fluctuation-based scores (activation variance ×
//!   consumer column norm) plus closed-form bias compensation
//!   `Δb = W_removed · mean(x_removed)`.
//! - **SlimGPT-like** — greedy OBS column removal with *diagonal*
//!   curvature updates (the cheap curvature correction; degrades at
//!   high sparsity exactly as Table 1 shows for SlimGPT).
//! - **ZipLM-like** — structured SparseGPT: joint selection + exact
//!   block-OBS consumer update from the full inverse Hessian. Selection
//!   and update are inseparable, so GRAIL does not stack on it (paper
//!   §4.2).
//! - **Wanda++-like** — Wanda selection followed by *regional
//!   optimization*: a few explicit gradient-descent steps on the local
//!   output-reconstruction objective (the gradient of a linear map is
//!   closed-form, so no autodiff is required).

use super::select::{self, ScoreInputs, Selector};
use super::{Reducer, ReductionPlan, SiteInfo};
use crate::grail::ActStats;
use crate::linalg::{mean_diag, BlockedCholesky};
use crate::rng::Pcg64;
use crate::tensor::{ops, Tensor};

/// Which baseline recovery mechanism to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Baseline {
    Wanda,
    WandaPP,
    SlimGPT,
    ZipLM,
    Flap,
}

impl Baseline {
    /// Parse a CLI/config name.
    pub fn from_name(s: &str) -> Option<Baseline> {
        Some(match s {
            "wanda" => Baseline::Wanda,
            "wanda++" | "wandapp" => Baseline::WandaPP,
            "slimgpt" => Baseline::SlimGPT,
            "ziplm" => Baseline::ZipLM,
            "flap" => Baseline::Flap,
            _ => return None,
        })
    }

    /// Stable display name.
    pub fn name(&self) -> &'static str {
        match self {
            Baseline::Wanda => "wanda",
            Baseline::WandaPP => "wanda++",
            Baseline::SlimGPT => "slimgpt",
            Baseline::ZipLM => "ziplm",
            Baseline::Flap => "flap",
        }
    }

    /// Whether GRAIL can stack on top (everything except ZipLM, whose
    /// selection and update are coupled).
    pub fn grail_compatible(&self) -> bool {
        !matches!(self, Baseline::ZipLM)
    }
}

/// Build a baseline's reduction plan for one site.
///
/// `consumer` is the site's consumer matrix `[o_eff, h_feat]`; `stats`
/// the consumer-input activation statistics. `workers` bounds the
/// solver's RHS-panel fan-out for the OBS Hessian inverse (`0` = auto;
/// results are bit-identical at every value). Returns a plan carrying
/// the baseline's own compensation (override / bias delta); callers
/// stacking GRAIL keep the reducer (and FLAP's bias delta) and replace
/// the weight update with the GRAIL map.
pub fn baseline_plan(
    method: Baseline,
    site: &SiteInfo,
    stats: &ActStats,
    producer_l1: &[f32],
    producer_l2: &[f32],
    consumer: &Tensor,
    k_units: usize,
    workers: usize,
    rng: &mut Pcg64,
) -> ReductionPlan {
    let consumer_cols = consumer_col_l2(consumer);
    let gd = select::gram_diag(&stats.gram);
    let inputs = ScoreInputs {
        site,
        producer_l1,
        producer_l2,
        gram_diag: &gd,
        consumer_cols: &consumer_cols,
    };
    match method {
        Baseline::Wanda => {
            ReductionPlan::bare(select::select_reducer(Selector::Wanda, &inputs, k_units, rng))
        }
        Baseline::WandaPP => {
            let reducer = select::select_reducer(Selector::Wanda, &inputs, k_units, rng);
            let w_new = regional_optimization(consumer, &stats.gram, &reducer, site.unit_dim, 8);
            ReductionPlan {
                reducer,
                compensation: None,
                bias_delta: None,
                consumer_override: Some(w_new),
            }
        }
        Baseline::SlimGPT => slimgpt_plan(site, stats, consumer, k_units, workers),
        Baseline::ZipLM => ziplm_plan(site, stats, consumer, k_units, workers),
        Baseline::Flap => flap_plan(site, stats, consumer, k_units, &inputs, rng),
    }
}

/// Per-feature L2 column norms of a consumer matrix.
pub fn consumer_col_l2(consumer: &Tensor) -> Vec<f32> {
    ops::col_l2(consumer)
}

// ---------------------------------------------------------------- FLAP

/// FLAP-like: fluctuation scores + bias compensation.
fn flap_plan(
    site: &SiteInfo,
    stats: &ActStats,
    consumer: &Tensor,
    k_units: usize,
    inputs: &ScoreInputs,
    _rng: &mut Pcg64,
) -> ReductionPlan {
    let var = stats.variance();
    let dh = site.unit_dim;
    // Per-unit fluctuation score: Σ_j var_j · ‖W[:,j]‖².
    let scores: Vec<f32> = (0..site.units)
        .map(|u| {
            (0..dh)
                .map(|j| {
                    let f = u * dh + j;
                    var[f] * inputs.consumer_cols[f] * inputs.consumer_cols[f]
                })
                .sum()
        })
        .collect();
    let keep = if site.groups > 1 {
        select::top_k_grouped(&scores, site.groups, k_units)
    } else {
        select::top_k(&scores, k_units)
    };
    let keep_feats: std::collections::BTreeSet<usize> =
        keep.iter().flat_map(|&u| (u * dh)..(u + 1) * dh).collect();
    // Bias compensation: the removed features' mean contribution is
    // baked into the consumer bias, Δ = Σ_{j removed} W[:,j]·mean_j.
    // Delta is per consumer-matrix row; models with coarser bias
    // granularity (conv taps) aggregate (see MiniResNet::apply).
    let o = consumer.dim(0);
    let h = consumer.dim(1);
    let mut delta = vec![0.0f32; o];
    for j in 0..h {
        if keep_feats.contains(&j) || stats.mean[j] == 0.0 {
            continue;
        }
        let mu = stats.mean[j];
        for (r, d) in delta.iter_mut().enumerate() {
            *d += consumer.at2(r, j) * mu;
        }
    }
    ReductionPlan {
        reducer: Reducer::Select(keep),
        compensation: None,
        bias_delta: Some(delta),
        consumer_override: None,
    }
}

// ------------------------------------------------------ OBS machinery

/// Exact block-OBS: given Hessian proxy `H = G + λI` and its inverse,
/// greedily remove units, applying the *full* OBS update to the
/// remaining consumer columns. This is the ZipLM-like mechanism.
fn ziplm_plan(
    site: &SiteInfo,
    stats: &ActStats,
    consumer: &Tensor,
    k_units: usize,
    workers: usize,
) -> ReductionPlan {
    obs_prune(site, stats, consumer, k_units, workers, /*full_update=*/ true)
}

/// SlimGPT-like: same greedy OBS ranking, but the curvature correction
/// uses only the Hessian diagonal — cheaper, and visibly lossier at
/// high sparsity (the collapse GRAIL rescues in Table 1).
fn slimgpt_plan(
    site: &SiteInfo,
    stats: &ActStats,
    consumer: &Tensor,
    k_units: usize,
    workers: usize,
) -> ReductionPlan {
    obs_prune(site, stats, consumer, k_units, workers, /*full_update=*/ false)
}

/// Greedy structured OBS over units.
///
/// Repeats until `k_units` remain: score every remaining unit by the
/// OBS error increase `tr(W_u (H⁻¹_uu)⁻¹ W_uᵀ)` and remove the
/// cheapest; with `full_update` the remaining columns absorb
/// `ΔW = −W_u (H⁻¹_uu)⁻¹ H⁻¹_{u,·}` (exact), otherwise only the
/// diagonal-curvature rescaling is applied (SlimGPT-like).
fn obs_prune(
    site: &SiteInfo,
    stats: &ActStats,
    consumer: &Tensor,
    k_units: usize,
    workers: usize,
    full_update: bool,
) -> ReductionPlan {
    let dh = site.unit_dim;
    let h_feat = stats.width();
    let units = site.units;
    assert_eq!(consumer.dim(1), h_feat);
    // Hessian proxy and inverse (λ keeps it SPD).
    let mut hess = stats.gram.clone();
    let lambda = (1e-2 * mean_diag(&hess)).max(1e-8);
    crate::linalg::add_diag(&mut hess, lambda);
    // Blocked factor + panel solve against the identity: the Hessian
    // inverse is the one H×H solve of the OBS setup. `workers` bounds
    // the panel fan-out (the per-block downdates below are too small to
    // parallelize and stay on the serial path).
    let chol = BlockedCholesky::factor_jittered(&hess).expect("OBS hessian factorization");
    let mut hinv = chol.solve_multi_with(&Tensor::eye(h_feat), workers);
    let mut w = consumer.clone();
    let mut alive: Vec<bool> = vec![true; units];
    let mut alive_count = units;
    let per_group = if site.groups > 1 { units / site.groups } else { units };
    let keep_per_group = if site.groups > 1 { k_units / site.groups } else { k_units };
    let mut group_alive: Vec<usize> = vec![per_group; site.groups.max(1)];

    while alive_count > k_units {
        // Score alive units (respecting group floors for GQA).
        let mut best: Option<(usize, f64)> = None;
        for u in 0..units {
            if !alive[u] {
                continue;
            }
            if site.groups > 1 && group_alive[u / per_group] <= keep_per_group {
                continue; // this group already at its floor
            }
            let feats: Vec<usize> = ((u * dh)..(u + 1) * dh).collect();
            let err = obs_error(&w, &hinv, &feats);
            if best.map(|(_, e)| err < e).unwrap_or(true) {
                best = Some((u, err));
            }
        }
        let (u, _) = best.expect("no removable unit (group constraints too tight?)");
        let feats: Vec<usize> = ((u * dh)..(u + 1) * dh).collect();
        if full_update {
            obs_full_update(&mut w, &mut hinv, &feats);
        } else {
            obs_diag_update(&mut w, &hinv, &feats);
        }
        // Zero the removed columns so later scores ignore them.
        for &f in &feats {
            for r in 0..w.dim(0) {
                w.set2(r, f, 0.0);
            }
        }
        alive[u] = false;
        alive_count -= 1;
        if site.groups > 1 {
            group_alive[u / per_group] -= 1;
        }
    }
    let keep: Vec<usize> = (0..units).filter(|&u| alive[u]).collect();
    // Extract the kept columns of the updated consumer.
    let keep_feats: Vec<usize> = keep.iter().flat_map(|&u| (u * dh)..(u + 1) * dh).collect();
    let w_new = ops::gather_cols(&w, &keep_feats);
    ReductionPlan {
        reducer: Reducer::Select(keep),
        compensation: None,
        bias_delta: None,
        consumer_override: Some(w_new),
    }
}

/// OBS error increase for removing feature block `feats`:
/// `tr(W_B (H⁻¹_BB)⁻¹ W_Bᵀ)`.
fn obs_error(w: &Tensor, hinv: &Tensor, feats: &[usize]) -> f64 {
    let hbb = block(hinv, feats);
    let wb = ops::gather_cols(w, feats); // [O, dh]
    match BlockedCholesky::factor_jittered(&hbb) {
        Ok(c) => {
            // tr(W_B Hbb⁻¹ W_Bᵀ) = Σ_rows w_r · Hbb⁻¹ w_r.
            let mut total = 0.0f64;
            for r in 0..wb.dim(0) {
                let x = c.solve_vec(wb.row(r));
                total += wb
                    .row(r)
                    .iter()
                    .zip(&x)
                    .map(|(a, b)| (*a as f64) * (*b as f64))
                    .sum::<f64>();
            }
            total
        }
        Err(_) => f64::INFINITY,
    }
}

/// Exact OBS update: `W ← W − W_B (H⁻¹_BB)⁻¹ H⁻¹_{B,·}` and the
/// Schur-complement downdate of `H⁻¹`.
fn obs_full_update(w: &mut Tensor, hinv: &mut Tensor, feats: &[usize]) {
    let h = hinv.dim(0);
    let hbb = block(hinv, feats);
    let hb_all = ops::gather_rows(hinv, feats); // [dh, H]
    let c = match BlockedCholesky::factor_jittered(&hbb) {
        Ok(c) => c,
        Err(_) => return,
    };
    let z = c.solve_multi(&hb_all); // [dh, H] = Hbb⁻¹ H_{B,·}
    // Weight update.
    let wb = ops::gather_cols(w, feats); // [O, dh]
    let dw = ops::matmul(&wb, &z); // [O, H]
    ops::axpy(w, -1.0, &dw);
    // H⁻¹ downdate: H⁻¹ ← H⁻¹ − H⁻¹_{·,B} Hbb⁻¹ H⁻¹_{B,·}.
    let cols = ops::transpose(&hb_all); // [H, dh] (hinv symmetric)
    let delta = ops::matmul(&cols, &z); // [H, H]
    ops::axpy(hinv, -1.0, &delta);
    // Keep removed rows/cols harmless (identity-ish) for stability.
    for &f in feats {
        for j in 0..h {
            hinv.set2(f, j, 0.0);
            hinv.set2(j, f, 0.0);
        }
        hinv.set2(f, f, 1.0);
    }
}

/// Diagonal-curvature-only update (SlimGPT-like): redistribute the
/// removed columns onto the rest using only `diag(H⁻¹)` — a first-order
/// correction that ignores cross terms.
fn obs_diag_update(w: &mut Tensor, hinv: &Tensor, feats: &[usize]) {
    let h = hinv.dim(0);
    for &f in feats {
        let d = hinv.at2(f, f).max(1e-12);
        for j in 0..h {
            if j == f || feats.contains(&j) {
                continue;
            }
            let coef = hinv.at2(f, j) / d;
            if coef == 0.0 {
                continue;
            }
            for r in 0..w.dim(0) {
                let v = w.at2(r, j) - coef * w.at2(r, f);
                w.set2(r, j, v);
            }
        }
    }
}

/// Square sub-block `m[feats, feats]`.
fn block(m: &Tensor, feats: &[usize]) -> Tensor {
    let rows = ops::gather_rows(m, feats);
    ops::gather_cols(&rows, feats)
}

// ------------------------------------------------- Wanda++ regional opt

/// Regional optimization: `T` explicit gradient steps on
/// `‖X_red W'ᵀ − X Wᵀ‖²` in Gram form,
/// `∇ = 2(W' G_red − W G M)`, starting from the data-free consumer.
/// This is the gradient-based local recovery of Wanda++ without
/// autodiff; with `T → ∞` it approaches the closed-form GRAIL merge.
pub fn regional_optimization(
    consumer: &Tensor,
    gram: &Tensor,
    reducer: &Reducer,
    unit_dim: usize,
    steps: usize,
) -> Tensor {
    let h = gram.dim(0);
    let m = reducer.lift(unit_dim).matrix(h); // [H, K]
    let gm = ops::matmul(gram, &m); // [H, K]
    let g_red = ops::matmul(&ops::transpose(&m), &gm); // [K, K]
    let w_gm = ops::matmul(consumer, &gm); // [O, K] = W G M
    // Start from the data-free update.
    let mut w = ops::matmul(consumer, &reducer.lift(unit_dim).consumer_matrix(h));
    // Step size from the curvature bound: 1 / tr(G_red) is safely
    // below 1/λ_max.
    let tr = (0..g_red.dim(0)).map(|i| g_red.at2(i, i) as f64).sum::<f64>().max(1e-9);
    let lr = (1.0 / tr) as f32;
    for _ in 0..steps {
        let mut grad = ops::matmul(&w, &g_red); // [O, K]
        ops::axpy(&mut grad, -1.0, &w_gm);
        ops::axpy(&mut w, -2.0 * lr, &grad);
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::SiteKind;
    use crate::grail::ActStats;

    fn dense_site(units: usize) -> SiteInfo {
        SiteInfo { id: "t".into(), units, unit_dim: 1, groups: 1, kind: SiteKind::Dense }
    }

    fn correlated(n: usize, h: usize, seed: u64) -> Tensor {
        let mut rng = Pcg64::seed(seed);
        let d = (h / 2).max(1);
        let mut a = Tensor::zeros(&[h, d]);
        rng.fill_normal(a.data_mut(), 1.0);
        let mut z = Tensor::zeros(&[n, d]);
        rng.fill_normal(z.data_mut(), 1.0);
        let mut x = ops::matmul(&z, &ops::transpose(&a));
        for v in x.data_mut().iter_mut() {
            *v += 0.05 * rng.normal();
        }
        x
    }

    fn output_err(consumer: &Tensor, acts: &Tensor, plan: &ReductionPlan, dh: usize) -> f32 {
        // ‖X W_newᵀ after reduction − X Wᵀ‖ / ‖X Wᵀ‖.
        let h = acts.dim(1);
        let m = plan.reducer.lift(dh).matrix(h);
        let reduced = ops::matmul(acts, &m);
        let w_new = if let Some(w) = &plan.consumer_override {
            w.clone()
        } else if let Some(b) = &plan.compensation {
            ops::matmul(consumer, b)
        } else {
            ops::matmul(consumer, &plan.reducer.lift(dh).consumer_matrix(h))
        };
        let y_new = ops::matmul(&reduced, &ops::transpose(&w_new));
        let y_ref = ops::matmul(acts, &ops::transpose(consumer));
        let mut d = y_new;
        ops::axpy(&mut d, -1.0, &y_ref);
        d.frobenius() / y_ref.frobenius().max(1e-12)
    }

    #[test]
    fn names_roundtrip() {
        for b in [Baseline::Wanda, Baseline::WandaPP, Baseline::SlimGPT, Baseline::ZipLM, Baseline::Flap]
        {
            assert_eq!(Baseline::from_name(b.name()), Some(b));
        }
        assert!(Baseline::ZipLM.grail_compatible() == false);
        assert!(Baseline::Flap.grail_compatible());
    }

    #[test]
    fn ziplm_beats_bare_wanda_on_output_error() {
        let acts = correlated(300, 12, 1);
        let stats = ActStats::from_acts(&acts);
        let mut rng = Pcg64::seed(2);
        let mut consumer = Tensor::zeros(&[5, 12]);
        rng.fill_normal(consumer.data_mut(), 1.0);
        let site = dense_site(12);
        let l1 = vec![1.0f32; 12];
        let zip = baseline_plan(
            Baseline::ZipLM, &site, &stats, &l1, &l1, &consumer, 6, 1, &mut Pcg64::seed(3),
        );
        let wanda = baseline_plan(
            Baseline::Wanda, &site, &stats, &l1, &l1, &consumer, 6, 1, &mut Pcg64::seed(3),
        );
        let e_zip = output_err(&consumer, &acts, &zip, 1);
        let e_wanda = output_err(&consumer, &acts, &wanda, 1);
        assert!(e_zip < e_wanda, "ziplm {e_zip} vs wanda {e_wanda}");
    }

    #[test]
    fn wandapp_improves_on_wanda() {
        let acts = correlated(300, 10, 4);
        let stats = ActStats::from_acts(&acts);
        let mut rng = Pcg64::seed(5);
        let mut consumer = Tensor::zeros(&[4, 10]);
        rng.fill_normal(consumer.data_mut(), 1.0);
        let site = dense_site(10);
        let l1 = vec![1.0f32; 10];
        let pp = baseline_plan(
            Baseline::WandaPP, &site, &stats, &l1, &l1, &consumer, 5, 1, &mut Pcg64::seed(6),
        );
        let plain = baseline_plan(
            Baseline::Wanda, &site, &stats, &l1, &l1, &consumer, 5, 1, &mut Pcg64::seed(6),
        );
        assert_eq!(pp.reducer, plain.reducer, "same selector");
        let e_pp = output_err(&consumer, &acts, &pp, 1);
        let e_plain = output_err(&consumer, &acts, &plain, 1);
        assert!(e_pp < e_plain, "wanda++ {e_pp} vs wanda {e_plain}");
    }

    #[test]
    fn ziplm_beats_slimgpt_at_high_sparsity() {
        // The diagonal-only curvature update loses to the exact one.
        let acts = correlated(400, 16, 7);
        let stats = ActStats::from_acts(&acts);
        let mut rng = Pcg64::seed(8);
        let mut consumer = Tensor::zeros(&[6, 16]);
        rng.fill_normal(consumer.data_mut(), 1.0);
        let site = dense_site(16);
        let l1 = vec![1.0f32; 16];
        let zip = baseline_plan(
            Baseline::ZipLM, &site, &stats, &l1, &l1, &consumer, 4, 1, &mut Pcg64::seed(9),
        );
        let slim = baseline_plan(
            Baseline::SlimGPT, &site, &stats, &l1, &l1, &consumer, 4, 1, &mut Pcg64::seed(9),
        );
        let e_zip = output_err(&consumer, &acts, &zip, 1);
        let e_slim = output_err(&consumer, &acts, &slim, 1);
        assert!(e_zip <= e_slim + 1e-5, "ziplm {e_zip} vs slimgpt {e_slim}");
    }

    #[test]
    fn flap_bias_centers_removed_mass() {
        // Features with a large constant offset: removing them without
        // bias compensation shifts outputs; FLAP's delta fixes the mean.
        let n = 200;
        let h = 6;
        let mut rng = Pcg64::seed(10);
        let mut acts = Tensor::zeros(&[n, h]);
        rng.fill_normal(acts.data_mut(), 0.3);
        for i in 0..n {
            acts.row_mut(i)[5] += 4.0; // feature 5: big mean, low variance
        }
        let stats = ActStats::from_acts(&acts);
        let mut consumer = Tensor::zeros(&[3, h]);
        rng.fill_normal(consumer.data_mut(), 1.0);
        let site = dense_site(h);
        let l1 = vec![1.0f32; h];
        let plan = baseline_plan(
            Baseline::Flap, &site, &stats, &l1, &l1, &consumer, 3, 1, &mut Pcg64::seed(11),
        );
        // Low-variance/high-mean feature 5 should be dropped by the
        // fluctuation metric...
        if let Reducer::Select(keep) = &plan.reducer {
            assert!(!keep.contains(&5), "keep={keep:?}");
        }
        // ... and the bias delta should carry roughly W[:,5]·4.
        let delta = plan.bias_delta.as_ref().unwrap();
        for r in 0..3 {
            let expected_contrib = consumer.at2(r, 5) * 4.0;
            assert!(
                (delta[r] - expected_contrib).abs() < 1.0,
                "row {r}: delta {} vs {}",
                delta[r],
                expected_contrib
            );
        }
    }

    #[test]
    fn obs_respects_gqa_groups() {
        let acts = correlated(200, 8, 12); // 4 heads × dh 2, 2 groups
        let stats = ActStats::from_acts(&acts);
        let mut rng = Pcg64::seed(13);
        let mut consumer = Tensor::zeros(&[4, 8]);
        rng.fill_normal(consumer.data_mut(), 1.0);
        let site = SiteInfo {
            id: "attn".into(),
            units: 4,
            unit_dim: 2,
            groups: 2,
            kind: SiteKind::AttnHeads,
        };
        let l1 = vec![1.0f32; 4];
        let plan = baseline_plan(
            Baseline::ZipLM, &site, &stats, &l1, &l1, &consumer, 2, 1, &mut Pcg64::seed(14),
        );
        if let Reducer::Select(keep) = &plan.reducer {
            assert_eq!(keep.len(), 2);
            // one head from each group {0,1} and {2,3}
            assert!(keep[0] < 2 && keep[1] >= 2, "keep={keep:?}");
        } else {
            panic!("expected selection");
        }
        crate::compress::heads::validate_head_reducer(&plan.reducer, &site).unwrap();
    }
}
