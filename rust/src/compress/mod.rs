//! Structured width reduction: reducers, selectors, folding, baselines.
//!
//! A *site* is one producer→consumer pair the library can compress: a
//! dense hidden layer, a conv block's internal channels, a transformer
//! MLP's fc/proj pair, or an attention block's heads. Models implement
//! [`Compressible`] to expose their sites; everything else (selectors,
//! folding, the GRAIL engine, baselines) is model-agnostic.

pub mod baselines;
pub mod fold;
pub mod heads;
pub mod select;

pub use fold::fold_reducer;
pub use select::{select_reducer, Selector};

use crate::tensor::Tensor;

/// How a producer's units (channels or heads) are reduced from `H` to
/// `K` units.
#[derive(Clone, Debug, PartialEq)]
pub enum Reducer {
    /// Structured pruning: keep these unit indices (sorted ascending).
    Select(Vec<usize>),
    /// Folding: `assign[h]` maps each unit to one of `k` clusters.
    Fold { assign: Vec<usize>, k: usize },
}

impl Reducer {
    /// Reduced unit count `K`.
    pub fn k(&self) -> usize {
        match self {
            Reducer::Select(idx) => idx.len(),
            Reducer::Fold { k, .. } => *k,
        }
    }

    /// Original unit count `H` this reducer applies to (only known for
    /// folds; selections return `None`).
    pub fn h(&self) -> Option<usize> {
        match self {
            Reducer::Select(_) => None,
            Reducer::Fold { assign, .. } => Some(assign.len()),
        }
    }

    /// The width-reduction matrix `M ∈ R^{H×K}` (paper §3.1):
    /// selection columns are standard basis vectors; folding columns
    /// average cluster members (`1/|C_k|`).
    pub fn matrix(&self, h: usize) -> Tensor {
        let k = self.k();
        let mut m = Tensor::zeros(&[h, k]);
        match self {
            Reducer::Select(idx) => {
                for (col, &row) in idx.iter().enumerate() {
                    assert!(row < h, "select index {row} out of {h}");
                    m.set2(row, col, 1.0);
                }
            }
            Reducer::Fold { assign, k } => {
                assert_eq!(assign.len(), h, "fold assignment length");
                let mut counts = vec![0usize; *k];
                for &c in assign {
                    counts[c] += 1;
                }
                for (row, &c) in assign.iter().enumerate() {
                    m.set2(row, c, 1.0 / counts[c].max(1) as f32);
                }
            }
        }
        m
    }

    /// The *data-free consumer* update matrix `N ∈ R^{H×K}` — what
    /// classic pruning/folding does to the consumer when no GRAIL
    /// compensation is applied. For selection this equals `M`; for
    /// folding it is the unnormalized indicator (the consumer sums the
    /// cluster's columns because the producer emits the cluster mean).
    pub fn consumer_matrix(&self, h: usize) -> Tensor {
        match self {
            Reducer::Select(_) => self.matrix(h),
            Reducer::Fold { assign, k } => {
                let mut n = Tensor::zeros(&[h, *k]);
                for (row, &c) in assign.iter().enumerate() {
                    n.set2(row, c, 1.0);
                }
                n
            }
        }
    }

    /// Kronecker lift to the feature axis: a head-level reducer acting
    /// on `n_heads` units becomes `R ⊗ I_dh` acting on
    /// `n_heads·dh` features (paper Eq. 2). `dh == 1` is the identity
    /// lift for channel sites.
    pub fn lift(&self, dh: usize) -> Reducer {
        if dh == 1 {
            return self.clone();
        }
        match self {
            Reducer::Select(idx) => Reducer::Select(
                idx.iter().flat_map(|&h| (h * dh)..(h + 1) * dh).collect(),
            ),
            Reducer::Fold { assign, k } => Reducer::Fold {
                assign: (0..assign.len() * dh)
                    .map(|r| assign[r / dh] * dh + (r % dh))
                    .collect(),
                k: k * dh,
            },
        }
    }
}

/// A fully specified reduction of one site.
#[derive(Clone, Debug)]
pub struct ReductionPlan {
    /// Unit-level reducer (channels or heads).
    pub reducer: Reducer,
    /// GRAIL reconstruction map `B: [feat_H, feat_K]`, merged into the
    /// consumer (`W' = W·B`). `None` = the data-free consumer update.
    pub compensation: Option<Tensor>,
    /// FLAP-style additive consumer bias correction.
    pub bias_delta: Option<Vec<f32>>,
    /// SlimGPT/ZipLM write the compensated consumer directly (already
    /// at reduced width `[O_eff, feat_K]`); overrides `compensation`.
    pub consumer_override: Option<Tensor>,
}

impl ReductionPlan {
    /// Plain structured reduction with the data-free consumer update.
    pub fn bare(reducer: Reducer) -> Self {
        ReductionPlan { reducer, compensation: None, bias_delta: None, consumer_override: None }
    }

    /// Reduction with a GRAIL compensation map.
    pub fn compensated(reducer: Reducer, b: Tensor) -> Self {
        ReductionPlan {
            reducer,
            compensation: Some(b),
            bias_delta: None,
            consumer_override: None,
        }
    }
}

/// What kind of producer→consumer pair a site is.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SiteKind {
    /// Dense hidden layer between two fully connected layers.
    Dense,
    /// Conv block internals: conv1 out-channels → conv2 in-channels.
    Conv,
    /// Transformer MLP: `w_fc` rows → `w_proj` columns.
    MlpPair,
    /// Attention heads: q/k/v head rows → `w_o` columns.
    AttnHeads,
}

impl SiteKind {
    /// Stable display/config name.
    pub fn name(&self) -> &'static str {
        match self {
            SiteKind::Dense => "dense",
            SiteKind::Conv => "conv",
            SiteKind::MlpPair => "mlp-pair",
            SiteKind::AttnHeads => "attn-heads",
        }
    }

    /// Parse a config name (spec rule `match_kind`).
    pub fn from_name(s: &str) -> Option<SiteKind> {
        Some(match s {
            "dense" => SiteKind::Dense,
            "conv" => SiteKind::Conv,
            "mlp-pair" | "mlp" => SiteKind::MlpPair,
            "attn-heads" | "attn" => SiteKind::AttnHeads,
            _ => return None,
        })
    }
}

/// Static description of a compressible site.
#[derive(Clone, Debug)]
pub struct SiteInfo {
    /// Stable identifier, e.g. `block2.mlp` or `block0.attn`.
    pub id: String,
    /// Prunable unit count (channels, or heads).
    pub units: usize,
    /// Per-unit feature width (`d_head` for attention, 1 otherwise).
    pub unit_dim: usize,
    /// KV groups (GQA) — head reduction must stay within groups and
    /// keep equal counts. 1 for ungrouped sites.
    pub groups: usize,
    pub kind: SiteKind,
}

impl SiteInfo {
    /// Feature width `H` of the Gram matrix at this site.
    pub fn feat_width(&self) -> usize {
        self.units * self.unit_dim
    }
}

/// The model-side interface for structured compression.
///
/// All methods refer to the *current* state of the model — after
/// earlier sites have been compressed, later sites' activations come
/// from the already-compressed prefix (the paper's sequential
/// closed-loop compensation).
///
/// # Staged segment execution
///
/// The closed loop visits sites in forward order and re-calibrates each
/// one on the already-compressed prefix. Re-running the whole network
/// per site would cost O(L²) layer forwards, so calibration is staged:
/// a [`CalibState`](Compressible::CalibState) caches the activations at
/// the *boundary* of the current site (the input of that site's
/// producer), [`site_tap`](Compressible::site_tap) derives the site's
/// consumer-input activations from the boundary, and
/// [`forward_segment`](Compressible::forward_segment) advances the
/// boundary through the (by then compressed) site to the next one —
/// O(L) layer forwards for the whole loop. States are per input shard
/// ([`split_input`](Compressible::split_input)), so the pipeline can
/// stream shards through `ActStats` with bounded peak memory and
/// execute them on parallel threads.
pub trait Compressible {
    /// The calibration/evaluation input batch type.
    type Input;

    /// Cached boundary activations between consecutive sites. Holds
    /// whatever the model needs to resume a forward pass at the current
    /// site's producer input (activations plus geometry).
    type CalibState;

    /// All compressible sites, in forward order.
    fn sites(&self) -> Vec<SiteInfo>;

    /// Total scalar parameter count (weights, biases, norms) of the
    /// model's *current* state — `Report` uses the before/after pair
    /// for the overall compression-ratio summary.
    fn param_count(&self) -> usize;

    /// Run the pre-site prefix (stem / embedding) and return a state
    /// positioned at site 0's boundary.
    fn calib_begin(&self, input: &Self::Input) -> Self::CalibState;

    /// Consumer-input activations at `site`: `[rows, feat_width]` where
    /// rows are samples, tokens, or pixels. `state` must sit at
    /// `site`'s boundary; it is not advanced (though the model may
    /// cache intermediate work in it for the following
    /// [`forward_segment`](Compressible::forward_segment) call).
    fn site_tap(&self, state: &mut Self::CalibState, site: usize) -> Tensor;

    /// Advance `state` from `from_site`'s boundary to `to_site`'s
    /// boundary through the model's *current* weights (i.e. through
    /// sites `from_site..to_site` as already compressed).
    fn forward_segment(&self, state: &mut Self::CalibState, from_site: usize, to_site: usize);

    /// Split a calibration input into at most `max_shards` non-empty
    /// sample shards whose concatenation, in order, is the original
    /// input. Shards are the unit of parallel segment execution and of
    /// streamed statistics accumulation.
    fn split_input(&self, input: &Self::Input, max_shards: usize) -> Vec<Self::Input>;

    /// One-shot oracle built on the staged API: consumer-input
    /// activations at `site` from a fresh forward pass. Costs O(site)
    /// layer forwards — the closed loop uses the staged methods
    /// directly instead of calling this per site.
    fn site_activations(&self, input: &Self::Input, site: usize) -> Tensor {
        let mut state = self.calib_begin(input);
        self.forward_segment(&mut state, 0, site);
        self.site_tap(&mut state, site)
    }

    /// Per-unit producer weight-row norm (`ord` 1 or 2) — magnitude
    /// selector scores.
    fn producer_row_norm(&self, site: usize, ord: u8) -> Vec<f32>;

    /// Per-unit producer feature rows `[units, d]` — the clustering
    /// space for folding (weight rows for channels, flattened query
    /// blocks for heads).
    fn producer_features(&self, site: usize) -> Tensor;

    /// Per-*feature* consumer column L2 norms (Wanda/FLAP scoring).
    fn consumer_col_norms(&self, site: usize) -> Vec<f32>;

    /// The consumer viewed as a matrix `[O_eff, feat_width]` (conv
    /// consumers are flattened over their spatial taps).
    fn consumer_matrix(&self, site: usize) -> Tensor;

    /// Apply a reduction plan to `site`, narrowing the producer and
    /// updating the consumer.
    fn apply(&mut self, site: usize, plan: &ReductionPlan);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn select_matrix_is_basis() {
        let r = Reducer::Select(vec![0, 3]);
        let m = r.matrix(4);
        assert_eq!(m.shape(), &[4, 2]);
        assert_eq!(m.data(), &[1., 0., 0., 0., 0., 0., 0., 1.]);
        assert_eq!(r.k(), 2);
    }

    #[test]
    fn fold_matrix_averages() {
        let r = Reducer::Fold { assign: vec![0, 0, 1], k: 2 };
        let m = r.matrix(3);
        assert_eq!(m.data(), &[0.5, 0., 0.5, 0., 0., 1.]);
        let n = r.consumer_matrix(3);
        assert_eq!(n.data(), &[1., 0., 1., 0., 0., 1.]);
    }

    #[test]
    fn lift_select() {
        let r = Reducer::Select(vec![1]);
        let l = r.lift(3);
        assert_eq!(l, Reducer::Select(vec![3, 4, 5]));
    }

    #[test]
    fn lift_fold() {
        let r = Reducer::Fold { assign: vec![0, 0], k: 1 };
        let l = r.lift(2);
        assert_eq!(l, Reducer::Fold { assign: vec![0, 1, 0, 1], k: 2 });
    }

    #[test]
    fn lift_identity_when_dh1() {
        let r = Reducer::Select(vec![0, 2]);
        assert_eq!(r.lift(1), r);
    }

    #[test]
    fn lifted_matrix_is_kronecker() {
        // (R ⊗ I_dh) check on a fold.
        let r = Reducer::Fold { assign: vec![0, 1, 0], k: 2 };
        let dh = 2;
        let m_units = r.matrix(3);
        let m_feat = r.lift(dh).matrix(6);
        for hu in 0..3 {
            for ku in 0..2 {
                for a in 0..dh {
                    for b in 0..dh {
                        let want = if a == b { m_units.at2(hu, ku) } else { 0.0 };
                        assert_eq!(m_feat.at2(hu * dh + a, ku * dh + b), want);
                    }
                }
            }
        }
    }

    #[test]
    fn site_kind_names_roundtrip() {
        for k in [SiteKind::Dense, SiteKind::Conv, SiteKind::MlpPair, SiteKind::AttnHeads] {
            assert_eq!(SiteKind::from_name(k.name()), Some(k));
        }
        // Short aliases for the transformer kinds.
        assert_eq!(SiteKind::from_name("mlp"), Some(SiteKind::MlpPair));
        assert_eq!(SiteKind::from_name("attn"), Some(SiteKind::AttnHeads));
        assert!(SiteKind::from_name("nope").is_none());
    }

    #[test]
    fn site_feat_width() {
        let s = SiteInfo {
            id: "b0.attn".into(),
            units: 8,
            unit_dim: 16,
            groups: 4,
            kind: SiteKind::AttnHeads,
        };
        assert_eq!(s.feat_width(), 128);
    }
}
