//! Head-structured reduction helpers for attention sites (paper §3.2).
//!
//! Attention reductions act at the *head* level and reach the feature
//! axis only through the Kronecker lift `R ⊗ I_dh`
//! ([`crate::compress::Reducer::lift`]). This module provides the
//! clustering feature space for head folding and validation of head
//! reducers against GQA constraints.

use super::{Reducer, SiteInfo};
use crate::tensor::Tensor;
use anyhow::{bail, ensure, Result};

/// Per-head feature rows for folding: head `h`'s block of query weight
/// rows `[h·dh .. (h+1)·dh)` flattened to one row of length
/// `dh · d_model`.
pub fn head_features(wq: &Tensor, n_heads: usize, d_head: usize) -> Tensor {
    assert_eq!(wq.dim(0), n_heads * d_head, "query weight rows");
    let d_model = wq.dim(1);
    let mut out = Tensor::zeros(&[n_heads, d_head * d_model]);
    for h in 0..n_heads {
        let dst = out.row_mut(h);
        for r in 0..d_head {
            dst[r * d_model..(r + 1) * d_model].copy_from_slice(wq.row(h * d_head + r));
        }
    }
    out
}

/// Validate a *head-level* reducer against a site's GQA structure:
/// selections must keep an equal nonzero count per group; folds must
/// not merge across groups and must keep group blocks contiguous.
pub fn validate_head_reducer(reducer: &Reducer, site: &SiteInfo) -> Result<()> {
    let units = site.units;
    let groups = site.groups;
    match reducer {
        Reducer::Select(keep) => {
            ensure!(!keep.is_empty(), "cannot remove all heads");
            ensure!(
                keep.windows(2).all(|w| w[0] < w[1]),
                "head selection must be sorted unique"
            );
            for &h in keep {
                ensure!(h < units, "head {h} out of {units}");
            }
            if groups > 1 {
                let per_group = units / groups;
                let mut counts = vec![0usize; groups];
                for &h in keep {
                    counts[h / per_group] += 1;
                }
                let k0 = counts[0];
                ensure!(
                    k0 > 0 && counts.iter().all(|&c| c == k0),
                    "GQA selection must keep an equal nonzero count per group: {counts:?}"
                );
            }
        }
        Reducer::Fold { assign, k } => {
            ensure!(assign.len() == units, "fold assignment length");
            let mut seen = vec![false; *k];
            for &a in assign {
                ensure!(a < *k, "cluster {a} out of {k}");
                seen[a] = true;
            }
            ensure!(seen.iter().all(|&s| s), "folding produced an empty cluster");
            if groups > 1 {
                ensure!(*k % groups == 0, "GQA folding needs equal cluster counts per group");
                let per_group = units / groups;
                let k_per_group = *k / groups;
                for (h, &a) in assign.iter().enumerate() {
                    let g = h / per_group;
                    if a / k_per_group != g {
                        bail!(
                            "GQA folding must not merge across groups \
                             (head {h} in group {g} assigned cluster {a})"
                        );
                    }
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::SiteKind;

    fn site(units: usize, groups: usize) -> SiteInfo {
        SiteInfo {
            id: "attn".into(),
            units,
            unit_dim: 4,
            groups,
            kind: SiteKind::AttnHeads,
        }
    }

    #[test]
    fn head_features_layout() {
        // 2 heads, dh=2, d_model=3.
        let wq = Tensor::from_vec(&[4, 3], (0..12).map(|i| i as f32).collect());
        let f = head_features(&wq, 2, 2);
        assert_eq!(f.shape(), &[2, 6]);
        assert_eq!(f.row(0), &[0., 1., 2., 3., 4., 5.]);
        assert_eq!(f.row(1), &[6., 7., 8., 9., 10., 11.]);
    }

    #[test]
    fn valid_ungrouped_selection() {
        assert!(validate_head_reducer(&Reducer::Select(vec![0, 2]), &site(4, 1)).is_ok());
        assert!(validate_head_reducer(&Reducer::Select(vec![]), &site(4, 1)).is_err());
        assert!(validate_head_reducer(&Reducer::Select(vec![2, 0]), &site(4, 1)).is_err());
        assert!(validate_head_reducer(&Reducer::Select(vec![9]), &site(4, 1)).is_err());
    }

    #[test]
    fn gqa_selection_balance() {
        // 8 heads, 2 groups of 4.
        assert!(validate_head_reducer(&Reducer::Select(vec![0, 1, 4, 5]), &site(8, 2)).is_ok());
        assert!(validate_head_reducer(&Reducer::Select(vec![0, 1, 2, 4]), &site(8, 2)).is_err());
    }

    #[test]
    fn fold_empty_cluster_rejected() {
        let r = Reducer::Fold { assign: vec![0, 0, 0, 0], k: 2 };
        assert!(validate_head_reducer(&r, &site(4, 1)).is_err());
    }

    #[test]
    fn gqa_fold_group_blocks() {
        // 4 heads, 2 groups; clusters {0,1}: ok.
        let ok = Reducer::Fold { assign: vec![0, 0, 1, 1], k: 2 };
        assert!(validate_head_reducer(&ok, &site(4, 2)).is_ok());
        // Cross-group merge: head 2 (group 1) in cluster 0.
        let bad = Reducer::Fold { assign: vec![0, 0, 0, 1], k: 2 };
        assert!(validate_head_reducer(&bad, &site(4, 2)).is_err());
    }
}
