//! Minimal property-based testing support (no `proptest` offline).
//!
//! [`check`] runs a property over many seeded random cases and, on
//! failure, retries with progressively "smaller" cases drawn from the
//! same failing seed family (shrink-lite), then panics with the seed so
//! the case is reproducible.

use crate::rng::Pcg64;

/// Configuration for a property run.
pub struct Config {
    /// Number of random cases.
    pub cases: usize,
    /// Base seed (each case forks a child generator).
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 64, seed: 0xC0FFEE }
    }
}

/// A sizing hint passed to generators: starts at 1.0 and is reduced
/// while shrinking, letting generators produce smaller shapes/values.
#[derive(Clone, Copy, Debug)]
pub struct Size(pub f64);

impl Size {
    /// Scale an upper bound; always at least `min`.
    pub fn scale(&self, max: usize, min: usize) -> usize {
        min.max(((max as f64) * self.0).round() as usize)
    }
}

/// Run `prop(rng, size)` for `cfg.cases` random cases. `prop` returns
/// `Err(msg)` (or panics) to signal failure.
pub fn check<F>(cfg: Config, mut prop: F)
where
    F: FnMut(&mut Pcg64, Size) -> Result<(), String>,
{
    let mut master = Pcg64::seed(cfg.seed);
    for case in 0..cfg.cases {
        let case_seed = master.next_u64();
        let mut rng = Pcg64::seed(case_seed);
        if let Err(msg) = prop(&mut rng, Size(1.0)) {
            // Shrink-lite: same seed, smaller size hints.
            let mut smallest = (Size(1.0), msg.clone());
            for &s in &[0.5, 0.25, 0.1, 0.05] {
                let mut rng = Pcg64::seed(case_seed);
                if let Err(m) = prop(&mut rng, Size(s)) {
                    smallest = (Size(s), m);
                }
            }
            panic!(
                "property failed (case {case}, seed {case_seed:#x}, size {:?}): {}",
                smallest.0, smallest.1
            );
        }
    }
}

/// Assert two f32 slices are elementwise close; formats a useful error.
pub fn assert_close(a: &[f32], b: &[f32], tol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        if (x - y).abs() > tol {
            return Err(format!("elem {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(Config { cases: 16, seed: 1 }, |rng, size| {
            let n = size.scale(100, 1);
            let v: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
            if v.len() == n {
                Ok(())
            } else {
                Err("bad".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports_seed() {
        check(Config { cases: 8, seed: 2 }, |rng, _| {
            if rng.next_u64() % 2 < 2 {
                Err("always fails".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn assert_close_works() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.0001], 1e-3).is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-3).is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1e-3).is_err());
    }
}
