//! The L3 coordinator: artifact layout, data generation, checkpoint
//! zoo loading, job scheduling, and metrics.
//!
//! The coordinator owns the process lifecycle: `grail datagen` writes
//! the canonical datasets (Python trains from the same files at build
//! time), `grail exp <id>` schedules experiment grids over worker
//! threads, and [`metrics`] records the wall-clock/memory numbers that
//! regenerate paper Table 3.

pub mod datagen;
pub mod metrics;
pub mod paths;
pub mod scheduler;
pub mod zoo;

pub use datagen::{generate_all, write_dev_checkpoints};
pub use paths::Artifacts;
pub use scheduler::{run_grid, GridResult};
pub use zoo::Zoo;
