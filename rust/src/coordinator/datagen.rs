//! `grail datagen` — materialize the canonical synthetic datasets.
//!
//! Rust is the single source of truth for data; the Python training
//! step reads these exact files, so there is no cross-language
//! generator drift (DESIGN.md §2).

use super::paths::Artifacts;
use crate::data::{io, SynthText, SynthVision, TextSplit};
use anyhow::{Context, Result};

/// The fixed task seed: all experiments share one data distribution.
pub const TASK_SEED: u64 = 42;

/// Sizes of the generated splits.
pub const VISION_TRAIN: usize = 4096;
pub const VISION_TEST: usize = 1024;
pub const VISION_CALIB: usize = 512;
pub const TEXT_TRAIN: usize = 200_000;
pub const TEXT_CALIB: usize = 40_000;
pub const TEXT_EVAL: usize = 30_000;

/// Write every dataset under `artifacts/data/`. Idempotent.
pub fn generate_all(art: &Artifacts, log: &mut dyn FnMut(&str)) -> Result<()> {
    std::fs::create_dir_all(art.data_dir()).context("creating data dir")?;

    // One task (one set of class prototypes); disjoint sample streams
    // per split.
    let vision = SynthVision::new(TASK_SEED);
    for (name, n, split) in [
        ("vision_train", VISION_TRAIN, 0u64),
        ("vision_test", VISION_TEST, 1),
        ("vision_calib", VISION_CALIB, 2),
    ] {
        let set = vision.generate_split(n, split);
        let path = art.data(&format!("{name}.imgs"));
        io::write_images(&path, &set)?;
        log(&format!("wrote {path} ({n} images)"));
    }

    let text = SynthText::new(TASK_SEED);
    for split in TextSplit::ALL {
        let n = match split {
            TextSplit::Train => TEXT_TRAIN,
            TextSplit::Calib => TEXT_CALIB,
            _ => TEXT_EVAL,
        };
        let ts = text.generate(split, n);
        let path = art.data(&format!("text_{}.tokens", split.name()));
        io::write_tokens(&path, &ts)?;
        log(&format!("wrote {path} ({n} tokens)"));
    }
    Ok(())
}

/// `grail datagen --dev-ckpts` — seed the zoo with untrained (randomly
/// initialized, fixed-seed) checkpoints of every family, so spec/plan/
/// serve workflows run end-to-end without the Python training step
/// (CI, smoke tests). The activation statistics are real even if the
/// weights are untrained. Existing checkpoints are never overwritten —
/// a trained zoo wins.
pub fn write_dev_checkpoints(art: &Artifacts, log: &mut dyn FnMut(&str)) -> Result<()> {
    use crate::nn::models::{LmConfig, MiniResNet, MlpNet, TinyLm, TinyViT, VitConfig};
    use crate::nn::weights::WeightBundle;
    use crate::rng::Pcg64;
    std::fs::create_dir_all(art.ckpt_dir()).context("creating checkpoints dir")?;
    let mut write = |name: &str, bundle: WeightBundle| -> Result<()> {
        let path = art.ckpt(name);
        if std::path::Path::new(&path).exists() {
            log(&format!("kept {path} (already present)"));
            return Ok(());
        }
        bundle.save(&path)?;
        log(&format!("wrote {path}"));
        Ok(())
    };
    write("mlp_dev", MlpNet::init(768, 32, 10, &mut Pcg64::seed(TASK_SEED ^ 0xD0)).to_bundle())?;
    write("resnet_dev", MiniResNet::init(&mut Pcg64::seed(TASK_SEED ^ 0xD1)).to_bundle())?;
    write(
        "vit_dev",
        TinyViT::init(VitConfig::default(), &mut Pcg64::seed(TASK_SEED ^ 0xD2)).to_bundle(),
    )?;
    // `tinylm_mha` doubles as the family-default checkpoint and the
    // marker `Artifacts::ensure_ready` looks for.
    write(
        "tinylm_mha",
        TinyLm::init(LmConfig::default(), &mut Pcg64::seed(TASK_SEED ^ 0xD3)).to_bundle(),
    )?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_and_reloads() {
        let dir = std::env::temp_dir().join("grail_datagen_test");
        let _ = std::fs::remove_dir_all(&dir);
        let art = Artifacts::at(dir.to_str().unwrap());
        let mut msgs = Vec::new();
        generate_all(&art, &mut |m| msgs.push(m.to_string())).unwrap();
        assert_eq!(msgs.len(), 8);
        let v = crate::data::io::read_images(&art.data("vision_test.imgs")).unwrap();
        assert_eq!(v.len(), VISION_TEST);
        let t = crate::data::io::read_tokens(&art.data("text_ptbs.tokens")).unwrap();
        assert_eq!(t.tokens.len(), TEXT_EVAL);
        assert!(art.has_data());
        // Idempotent.
        generate_all(&art, &mut |_| {}).unwrap();
    }
}
