//! `grail datagen` — materialize the canonical synthetic datasets.
//!
//! Rust is the single source of truth for data; the Python training
//! step reads these exact files, so there is no cross-language
//! generator drift (DESIGN.md §2).

use super::paths::Artifacts;
use crate::data::{io, SynthText, SynthVision, TextSplit};
use anyhow::{Context, Result};

/// The fixed task seed: all experiments share one data distribution.
pub const TASK_SEED: u64 = 42;

/// Sizes of the generated splits.
pub const VISION_TRAIN: usize = 4096;
pub const VISION_TEST: usize = 1024;
pub const VISION_CALIB: usize = 512;
pub const TEXT_TRAIN: usize = 200_000;
pub const TEXT_CALIB: usize = 40_000;
pub const TEXT_EVAL: usize = 30_000;

/// Write every dataset under `artifacts/data/`. Idempotent.
pub fn generate_all(art: &Artifacts, log: &mut dyn FnMut(&str)) -> Result<()> {
    std::fs::create_dir_all(art.data_dir()).context("creating data dir")?;

    // One task (one set of class prototypes); disjoint sample streams
    // per split.
    let vision = SynthVision::new(TASK_SEED);
    for (name, n, split) in [
        ("vision_train", VISION_TRAIN, 0u64),
        ("vision_test", VISION_TEST, 1),
        ("vision_calib", VISION_CALIB, 2),
    ] {
        let set = vision.generate_split(n, split);
        let path = art.data(&format!("{name}.imgs"));
        io::write_images(&path, &set)?;
        log(&format!("wrote {path} ({n} images)"));
    }

    let text = SynthText::new(TASK_SEED);
    for split in TextSplit::ALL {
        let n = match split {
            TextSplit::Train => TEXT_TRAIN,
            TextSplit::Calib => TEXT_CALIB,
            _ => TEXT_EVAL,
        };
        let ts = text.generate(split, n);
        let path = art.data(&format!("text_{}.tokens", split.name()));
        io::write_tokens(&path, &ts)?;
        log(&format!("wrote {path} ({n} tokens)"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_and_reloads() {
        let dir = std::env::temp_dir().join("grail_datagen_test");
        let _ = std::fs::remove_dir_all(&dir);
        let art = Artifacts::at(dir.to_str().unwrap());
        let mut msgs = Vec::new();
        generate_all(&art, &mut |m| msgs.push(m.to_string())).unwrap();
        assert_eq!(msgs.len(), 8);
        let v = crate::data::io::read_images(&art.data("vision_test.imgs")).unwrap();
        assert_eq!(v.len(), VISION_TEST);
        let t = crate::data::io::read_tokens(&art.data("text_ptbs.tokens")).unwrap();
        assert_eq!(t.tokens.len(), TEXT_EVAL);
        assert!(art.has_data());
        // Idempotent.
        generate_all(&art, &mut |_| {}).unwrap();
    }
}
