//! Wall-clock and memory metrics (regenerates paper Table 3's
//! calibration/compensation overhead numbers).

use std::time::Instant;

/// A named stage timer with peak-RSS deltas.
#[derive(Debug, Clone)]
pub struct StageMetrics {
    pub name: String,
    pub seconds: f64,
    /// Peak resident set (MiB) observed at stage end.
    pub peak_rss_mib: f64,
}

/// Current peak resident set size in MiB (`VmHWM` from
/// `/proc/self/status`; 0.0 if unavailable).
pub fn peak_rss_mib() -> f64 {
    let Ok(text) = std::fs::read_to_string("/proc/self/status") else {
        return 0.0;
    };
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: f64 = rest
                .trim()
                .trim_end_matches("kB")
                .trim()
                .parse()
                .unwrap_or(0.0);
            return kb / 1024.0;
        }
    }
    0.0
}

/// Current resident set size in MiB (`VmRSS`).
pub fn rss_mib() -> f64 {
    let Ok(text) = std::fs::read_to_string("/proc/self/status") else {
        return 0.0;
    };
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("VmRSS:") {
            let kb: f64 = rest
                .trim()
                .trim_end_matches("kB")
                .trim()
                .parse()
                .unwrap_or(0.0);
            return kb / 1024.0;
        }
    }
    0.0
}

/// Time a closure, returning `(result, StageMetrics)`.
pub fn timed<T>(name: &str, f: impl FnOnce() -> T) -> (T, StageMetrics) {
    let t0 = Instant::now();
    let out = f();
    let m = StageMetrics {
        name: name.to_string(),
        seconds: t0.elapsed().as_secs_f64(),
        peak_rss_mib: peak_rss_mib(),
    };
    (out, m)
}

/// A registry collecting stage metrics across an experiment run.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    pub stages: Vec<StageMetrics>,
}

impl MetricsRegistry {
    /// New empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure and record it.
    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let (out, m) = timed(name, f);
        self.stages.push(m);
        out
    }

    /// Sum of seconds for stages whose name starts with `prefix`.
    pub fn total_seconds(&self, prefix: &str) -> f64 {
        self.stages
            .iter()
            .filter(|s| s.name.starts_with(prefix))
            .map(|s| s.seconds)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rss_is_positive_on_linux() {
        assert!(rss_mib() > 1.0);
        assert!(peak_rss_mib() >= rss_mib() * 0.5);
    }

    #[test]
    fn timed_measures() {
        let (v, m) = timed("work", || {
            std::thread::sleep(std::time::Duration::from_millis(12));
            7
        });
        assert_eq!(v, 7);
        assert!(m.seconds >= 0.010, "{}", m.seconds);
    }

    #[test]
    fn registry_accumulates() {
        let mut r = MetricsRegistry::new();
        r.time("calib.a", || ());
        r.time("calib.b", || ());
        r.time("comp.a", || ());
        assert_eq!(r.stages.len(), 3);
        assert!(r.total_seconds("calib") >= 0.0);
        assert_eq!(
            r.stages.iter().filter(|s| s.name.starts_with("comp")).count(),
            1
        );
    }
}
