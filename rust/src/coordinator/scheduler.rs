//! Work scheduling for experiment grids.
//!
//! Experiment sweeps (checkpoints × methods × ratios) are embarrassingly
//! parallel; [`run_grid`] fans the job list over scoped worker threads
//! (std::thread — no tokio in the offline build) with a shared atomic
//! cursor, preserving input order in the output.
//!
//! The scheduler also owns the crate's **thread-budget policy**: every
//! thread carries a budget of worker threads its nested fan-outs may
//! use (the whole machine for fresh threads; `GRAIL_THREADS` caps it).
//! When a fan-out actually goes parallel, each worker inherits an
//! equal-as-possible share of its caller's budget (`budget / workers`,
//! with the first `budget % workers` workers carrying one extra — see
//! [`budget_shares`]), so
//! auto-sized nested parallelism — shard calibration inside `grail
//! batch` jobs, the packed GEMM/SYRK engine
//! ([`crate::tensor::gemm`]), the blocked solver's RHS fan-out —
//! fills the machine without oversubscribing it: a 2-job batch on 16
//! cores gives each job 8 threads for its shards, whose workers in
//! turn run their kernels serially. Single-stream callers (CLI
//! inference, probe suites, plain `model.forward`) keep the full
//! budget, so big GEMMs from those paths get the threads. The budget
//! only ever affects *scheduling*: every consumer is bit-identical at
//! any worker count.

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

thread_local! {
    /// Worker-thread budget for nested fan-outs on this thread.
    /// `None` = the machine-level budget ([`machine_threads`]); set to
    /// an equal share of the caller's budget for the lifetime of the
    /// scoped worker threads spawned by [`run_grid`] / [`run_grid_mut`].
    static THREAD_BUDGET: Cell<Option<usize>> = Cell::new(None);
}

/// Machine-level worker count: `GRAIL_THREADS` env (the total-thread
/// cap) or available parallelism.
fn machine_threads() -> usize {
    if let Ok(v) = std::env::var("GRAIL_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// One grid cell result.
#[derive(Debug, Clone)]
pub struct GridResult<T> {
    pub index: usize,
    pub value: T,
}

/// Equal-as-possible split of `budget` across `workers` parallel
/// fan-out workers: every worker gets `budget / workers`, and the
/// first `budget % workers` workers carry one extra share, so a
/// non-dividing budget (7 threads over 4 workers) keeps all 7 shares
/// usable instead of dropping the integer-division remainder on the
/// floor. Every worker keeps a ≥ 1 floor; whenever `budget ≥ workers`
/// the shares sum to exactly `budget` — the no-oversubscription
/// invariant.
fn budget_shares(budget: usize, workers: usize) -> Vec<usize> {
    debug_assert!(workers > 0, "budget_shares needs at least one worker");
    let (base, extra) = (budget / workers, budget % workers);
    (0..workers).map(|w| (base + usize::from(w < extra)).max(1)).collect()
}

/// Run `jobs` through `worker` on `threads` scoped threads. Results
/// come back sorted by job index. Panics in workers propagate.
pub fn run_grid<J, T, F>(jobs: Vec<J>, threads: usize, worker: F) -> Vec<T>
where
    J: Send + Sync,
    T: Send,
    F: Fn(usize, &J) -> T + Sync,
{
    let n = jobs.len();
    let threads = threads.clamp(1, n.max(1));
    if threads == 1 {
        // Serial fan-out runs on the caller's thread and inherits its
        // thread budget (a single big job may still want the machine
        // for its own kernels).
        return jobs.iter().enumerate().map(|(i, j)| worker(i, j)).collect();
    }
    // Each worker gets an equal-as-possible share of this thread's
    // budget for its own nested fan-outs (kernels, solves, deeper
    // grids); a non-dividing budget spreads its remainder over the
    // first workers instead of idling it.
    let shares = budget_shares(default_threads(), threads);
    let cursor = AtomicUsize::new(0);
    let results: Mutex<Vec<Option<T>>> = Mutex::new((0..n).map(|_| None).collect());
    let jobs_ref = &jobs;
    std::thread::scope(|scope| {
        for &share in &shares {
            let (cursor, results, worker) = (&cursor, &results, &worker);
            scope.spawn(move || {
                THREAD_BUDGET.with(|c| c.set(Some(share)));
                loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let out = worker(i, &jobs_ref[i]);
                    results.lock().unwrap()[i] = Some(out);
                }
            });
        }
    });
    results
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|r| r.expect("worker completed"))
        .collect()
}

/// Run `worker` over mutable jobs in place, fanned over scoped threads
/// in contiguous chunks. Results come back in job order. Unlike
/// [`run_grid`] the jobs stay owned by the caller — this is the
/// primitive the closed-loop calibration pipeline uses to advance its
/// per-shard [`crate::compress::Compressible::CalibState`]s in
/// parallel.
pub fn run_grid_mut<J, T, F>(jobs: &mut [J], threads: usize, worker: F) -> Vec<T>
where
    J: Send,
    T: Send,
    F: Fn(usize, &mut J) -> T + Sync,
{
    let n = jobs.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        return jobs.iter_mut().enumerate().map(|(i, j)| worker(i, j)).collect();
    }
    let chunk = (n + threads - 1) / threads;
    let shares = budget_shares(default_threads(), threads);
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    std::thread::scope(|scope| {
        for (ci, (job_chunk, out_chunk)) in
            jobs.chunks_mut(chunk).zip(out.chunks_mut(chunk)).enumerate()
        {
            let worker = &worker;
            let share = shares[ci];
            scope.spawn(move || {
                THREAD_BUDGET.with(|c| c.set(Some(share)));
                for (off, (j, o)) in
                    job_chunk.iter_mut().zip(out_chunk.iter_mut()).enumerate()
                {
                    *o = Some(worker(ci * chunk + off, j));
                }
            });
        }
    });
    out.into_iter().map(|r| r.expect("worker completed")).collect()
}

/// Worker-thread count for auto-sized fan-outs: the current thread's
/// budget — the machine-level count (`GRAIL_THREADS` env or available
/// parallelism) on fresh threads, an equal-as-possible share of the
/// caller's budget inside [`run_grid`] / [`run_grid_mut`] workers. Nested
/// fan-outs thus fill the machine without oversubscribing it (see the
/// module docs). Scheduling only: all consumers are worker-count
/// invariant.
pub fn default_threads() -> usize {
    THREAD_BUDGET.with(|c| c.get()).unwrap_or_else(machine_threads)
}

/// Write-set race auditor for disjoint-output fan-outs.
///
/// The crate's parallel kernels (gemm C row panels, the attention
/// head-major scatter, the blocked solver's RHS panels, the mixed
/// decode+prefill batch step's per-`(span, head)` context panels —
/// these are *variable-width*: a decode span claims one `d_head` row
/// while a prefill chunk claims `rows · d_head`, and the claims must
/// still tile the pass exactly) rely on a *structural* guarantee:
/// every [`run_grid_mut`] / [`run_grid`] job writes a distinct range
/// of the output buffer, and the ranges tile it exactly. That property is what makes worker-count
/// bit-invariance trivially true — no output element has two writers,
/// at any parallelism. The auditor turns the guarantee into a runtime
/// assertion: each job *claims* the `(start, len)` range it is about
/// to write, and [`WriteSet::verify`] panics unless the claims are
/// pairwise disjoint and cover `[0, total)` with no gaps.
///
/// Enabled under `cfg(debug_assertions)` or the `audit` cargo
/// feature; otherwise [`WriteSet`] is a zero-sized no-op and the
/// claims compile away, so release kernels pay nothing.
pub mod audit {
    #[cfg(any(debug_assertions, feature = "audit"))]
    mod imp {
        use std::sync::Mutex;

        /// Collects per-job write claims for one fan-out; see the
        /// [module docs](super).
        pub struct WriteSet {
            label: &'static str,
            total: usize,
            /// `(start, len, job)` claims, in claim order.
            claims: Mutex<Vec<(usize, usize, usize)>>,
        }

        impl WriteSet {
            /// New auditor for an output buffer of `total` elements.
            pub fn new(label: &'static str, total: usize) -> WriteSet {
                WriteSet { label, total, claims: Mutex::new(Vec::new()) }
            }

            /// Record that job `job` writes `[start, start + len)`.
            /// Panics immediately if the range exceeds the buffer.
            pub fn claim(&self, job: usize, start: usize, len: usize) {
                assert!(
                    start + len <= self.total,
                    "write-set audit [{}]: job {job} claim {start}..{} exceeds \
                     buffer of {} elements",
                    self.label,
                    start + len,
                    self.total
                );
                self.claims.lock().unwrap().push((start, len, job));
            }

            /// Assert the claims tile `[0, total)` exactly: pairwise
            /// disjoint, no gaps, full coverage. Call after the
            /// fan-out joins.
            pub fn verify(&self) {
                let mut claims = self.claims.lock().unwrap().clone();
                claims.sort_unstable();
                let mut covered = 0usize;
                let mut prev_job = usize::MAX;
                for &(start, len, job) in &claims {
                    assert!(
                        start >= covered,
                        "write-set audit [{}]: jobs {prev_job} and {job} overlap at \
                         element {start} (prior claims cover 0..{covered})",
                        self.label
                    );
                    assert!(
                        start <= covered,
                        "write-set audit [{}]: elements {covered}..{start} are uncovered \
                         (no job claimed them before job {job})",
                        self.label
                    );
                    covered = start + len;
                    prev_job = job;
                }
                assert!(
                    covered == self.total,
                    "write-set audit [{}]: elements {covered}..{} are uncovered \
                     (tail past the last claim)",
                    self.label,
                    self.total
                );
            }
        }
    }

    #[cfg(not(any(debug_assertions, feature = "audit")))]
    mod imp {
        /// Zero-cost stand-in: without `debug_assertions` or the
        /// `audit` feature, claims and verification compile away.
        pub struct WriteSet;

        impl WriteSet {
            #[inline(always)]
            pub fn new(_label: &'static str, _total: usize) -> WriteSet {
                WriteSet
            }

            #[inline(always)]
            pub fn claim(&self, _job: usize, _start: usize, _len: usize) {}

            #[inline(always)]
            pub fn verify(&self) {}
        }
    }

    pub use imp::WriteSet;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let jobs: Vec<usize> = (0..50).collect();
        let out = run_grid(jobs, 4, |_, &j| j * 2);
        assert_eq!(out, (0..50).map(|j| j * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_works() {
        let out = run_grid(vec![1, 2, 3], 1, |i, &j| i + j);
        assert_eq!(out, vec![1, 3, 5]);
    }

    #[test]
    fn empty_jobs_ok() {
        let out: Vec<i32> = run_grid(Vec::<i32>::new(), 4, |_, &j| j);
        assert!(out.is_empty());
    }

    #[test]
    fn more_threads_than_jobs() {
        let out = run_grid(vec![7], 16, |_, &j| j);
        assert_eq!(out, vec![7]);
    }

    #[test]
    fn default_threads_positive() {
        assert!(default_threads() >= 1);
    }

    #[test]
    fn run_grid_mut_mutates_in_order() {
        let mut jobs: Vec<u64> = (0..23).collect();
        let out = run_grid_mut(&mut jobs, 4, |i, j| {
            *j += 100;
            (i as u64, *j)
        });
        assert_eq!(jobs, (100..123).collect::<Vec<_>>());
        for (i, (idx, v)) in out.iter().enumerate() {
            assert_eq!(*idx, i as u64);
            assert_eq!(*v, 100 + i as u64);
        }
    }

    #[test]
    fn budget_shares_distribute_remainder() {
        // Dividing budgets stay uniform.
        assert_eq!(budget_shares(8, 4), vec![2, 2, 2, 2]);
        // Non-dividing budgets hand the remainder to the first workers
        // instead of idling it: 7 over 4 used to yield [1,1,1,1] (4
        // usable threads, 3 permanently idle).
        assert_eq!(budget_shares(7, 4), vec![2, 2, 2, 1]);
        assert_eq!(budget_shares(5, 4), vec![2, 1, 1, 1]);
        assert_eq!(budget_shares(9, 2), vec![5, 4]);
        // Budget below the worker count: the ≥ 1 floor keeps every
        // worker runnable.
        assert_eq!(budget_shares(3, 4), vec![1, 1, 1, 1]);
        assert_eq!(budget_shares(1, 8), vec![1; 8]);
        // No oversubscription: whenever budget >= workers the shares
        // sum to exactly the budget, and shares are within 1 of each
        // other (equal-as-possible).
        for budget in 1..=24usize {
            for workers in 1..=8usize {
                let s = budget_shares(budget, workers);
                assert_eq!(s.len(), workers);
                if budget >= workers {
                    assert_eq!(s.iter().sum::<usize>(), budget, "budget={budget} workers={workers}");
                }
                let (mn, mx) = (s.iter().min().unwrap(), s.iter().max().unwrap());
                assert!(mx - mn <= 1, "budget={budget} workers={workers}: {s:?}");
                assert!(*mn >= 1);
            }
        }
    }

    #[test]
    fn thread_budget_divides_across_parallel_workers() {
        let total = default_threads();
        assert!(total >= 1, "fresh test thread owns the machine budget");
        // Parallel fan-outs hand each worker an equal-as-possible
        // budget share. run_grid's job→worker mapping is cursor-based
        // (nondeterministic), so each observation must be *some*
        // worker's share; run_grid_mut with jobs == threads maps chunk
        // ci → worker ci deterministically, so the observed vector is
        // exactly the share vector.
        let shares = budget_shares(total, 4);
        let inner = run_grid(vec![(); 8], 4, |_, _| default_threads());
        assert!(
            inner.iter().all(|&t| shares.contains(&t)),
            "{inner:?} not drawn from shares {shares:?}"
        );
        // No oversubscription beyond the ≥ 1-thread floor each worker
        // keeps: the shares sum to the budget whenever it divides out.
        if total >= 4 {
            assert_eq!(shares.iter().sum::<usize>(), total);
        }
        // Non-dividing budgets must not strand the remainder: with
        // total = 7 over 4 workers the old truncating split left 3
        // threads permanently idle.
        let mut jobs = [0u8; 3];
        let observed = run_grid_mut(&mut jobs, 3, |_, _| default_threads());
        assert_eq!(observed, budget_shares(total, 3), "chunked fan-out share vector");
        // Serial fan-outs inherit the caller's full budget…
        let inner = run_grid(vec![(); 3], 1, |_, _| default_threads());
        assert!(inner.iter().all(|&t| t == total));
        let mut jobs = [0u8; 3];
        let inner = run_grid_mut(&mut jobs, 1, |_, _| default_threads());
        assert!(inner.iter().all(|&t| t == total));
        // …and the caller's own budget is never touched.
        assert_eq!(default_threads(), total);
    }

    #[test]
    fn nested_budget_shares_cover_non_dividing_budgets() {
        // Pin a synthetic budget on this thread (exactly what run_grid
        // workers do for their nested fan-outs), then fan out a
        // non-dividing grid and check the remainder is distributed,
        // not dropped.
        for (budget, workers) in [(7usize, 4usize), (5, 2), (11, 3), (2, 4)] {
            THREAD_BUDGET.with(|c| c.set(Some(budget)));
            let mut jobs = vec![0u8; workers];
            let observed = run_grid_mut(&mut jobs, workers, |_, _| default_threads());
            THREAD_BUDGET.with(|c| c.set(None));
            assert_eq!(
                observed,
                budget_shares(budget, workers),
                "budget={budget} workers={workers}"
            );
            if budget >= workers {
                assert_eq!(observed.iter().sum::<usize>(), budget, "no stranded remainder");
            }
        }
    }

    #[test]
    fn run_grid_mut_empty_and_single() {
        let mut empty: Vec<u8> = Vec::new();
        let out: Vec<u8> = run_grid_mut(&mut empty, 8, |_, j| *j);
        assert!(out.is_empty());
        let mut one = vec![7u8];
        assert_eq!(run_grid_mut(&mut one, 8, |_, j| *j + 1), vec![8]);
    }

    #[test]
    fn run_grid_remainder_ordering() {
        // n not divisible by threads: outputs must still land at
        // their job index, for several awkward (n, threads) pairs.
        for (n, threads) in [(7usize, 3usize), (5, 4), (9, 2), (11, 8)] {
            let jobs: Vec<usize> = (0..n).collect();
            let out = run_grid(jobs, threads, |i, &j| {
                assert_eq!(i, j, "worker sees its own job");
                j * 10 + 1
            });
            let want: Vec<usize> = (0..n).map(|j| j * 10 + 1).collect();
            assert_eq!(out, want, "n={n} threads={threads}");
        }
    }

    #[test]
    fn run_grid_mut_remainder_ordering() {
        // The chunked fan-out: the last chunk is short when
        // threads ∤ n; index arithmetic must still line up jobs,
        // outputs, and mutations.
        for (n, threads) in [(7usize, 3usize), (5, 4), (23, 4), (10, 7)] {
            let mut jobs: Vec<usize> = (0..n).collect();
            let out = run_grid_mut(&mut jobs, threads, |i, j| {
                assert_eq!(i, *j, "chunk offset arithmetic");
                *j += 1000;
                i
            });
            assert_eq!(out, (0..n).collect::<Vec<_>>(), "n={n} threads={threads}");
            assert_eq!(jobs, (1000..1000 + n).collect::<Vec<_>>());
        }
    }

    #[test]
    fn run_grid_panic_propagates_without_dropping_siblings() {
        use std::panic::{catch_unwind, AssertUnwindSafe};
        use std::sync::atomic::AtomicUsize;
        let completed = AtomicUsize::new(0);
        let result = catch_unwind(AssertUnwindSafe(|| {
            run_grid((0..16).collect::<Vec<usize>>(), 4, |i, _| {
                if i == 7 {
                    panic!("worker 7 exploded");
                }
                completed.fetch_add(1, Ordering::SeqCst);
                i
            })
        }));
        assert!(result.is_err(), "the worker panic must propagate to the caller");
        // The panicking thread dies, but the cursor keeps serving the
        // remaining jobs to its siblings: nothing is silently dropped.
        assert_eq!(completed.load(Ordering::SeqCst), 15);
    }

    #[test]
    fn run_grid_mut_panic_propagates_without_dropping_siblings() {
        use std::panic::{catch_unwind, AssertUnwindSafe};
        use std::sync::atomic::AtomicUsize;
        let completed = AtomicUsize::new(0);
        let mut jobs: Vec<usize> = (0..8).collect();
        let result = catch_unwind(AssertUnwindSafe(|| {
            run_grid_mut(&mut jobs, 4, |i, j| {
                if i == 3 {
                    panic!("worker on job 3 exploded");
                }
                *j += 100;
                completed.fetch_add(1, Ordering::SeqCst);
            })
        }));
        assert!(result.is_err(), "the worker panic must propagate to the caller");
        // Chunks are [0,1] [2,3] [4,5] [6,7]: the panicking chunk
        // loses only the job that panicked; every other chunk drains.
        assert_eq!(completed.load(Ordering::SeqCst), 7);
        assert_eq!(jobs[2], 102, "the panicking chunk's earlier job still ran");
        assert_eq!(jobs[3], 3, "the panicking job left its input untouched");
        for (i, &j) in jobs.iter().enumerate() {
            if i != 3 {
                assert_eq!(j, i + 100, "sibling job {i} completed");
            }
        }
    }
}

#[cfg(all(test, any(debug_assertions, feature = "audit")))]
mod audit_tests {
    use super::audit::WriteSet;
    use super::run_grid_mut;

    #[test]
    fn disjoint_full_cover_passes() {
        let ws = WriteSet::new("unit", 10);
        ws.claim(0, 0, 4);
        ws.claim(1, 4, 6);
        ws.verify();
        // Claim order must not matter.
        let ws = WriteSet::new("unit-rev", 10);
        ws.claim(1, 6, 4);
        ws.claim(0, 0, 6);
        ws.verify();
        // Empty buffer, no claims.
        WriteSet::new("empty", 0).verify();
    }

    #[test]
    #[should_panic(expected = "overlap")]
    fn overlapping_claims_panic() {
        // The deliberately-overlapping negative case from ISSUE 8:
        // two workers claiming intersecting output ranges must die in
        // verify, not silently race.
        let ws = WriteSet::new("overlap", 12);
        let mut jobs: Vec<(usize, usize)> = vec![(0, 8), (4, 8)];
        run_grid_mut(&mut jobs, 2, |ji, job| ws.claim(ji, job.0, job.1));
        ws.verify();
    }

    #[test]
    #[should_panic(expected = "uncovered")]
    fn coverage_gap_panics() {
        let ws = WriteSet::new("gap", 10);
        ws.claim(0, 0, 4);
        ws.claim(1, 6, 4);
        ws.verify();
    }

    #[test]
    #[should_panic(expected = "uncovered")]
    fn uncovered_tail_panics() {
        let ws = WriteSet::new("tail", 10);
        ws.claim(0, 0, 4);
        ws.verify();
    }

    #[test]
    #[should_panic(expected = "exceeds")]
    fn claim_past_end_panics() {
        let ws = WriteSet::new("oob", 10);
        ws.claim(0, 8, 4);
    }
}
