//! Work scheduling for experiment grids.
//!
//! Experiment sweeps (checkpoints × methods × ratios) are embarrassingly
//! parallel; [`run_grid`] fans the job list over scoped worker threads
//! (std::thread — no tokio in the offline build) with a shared atomic
//! cursor, preserving input order in the output.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// One grid cell result.
#[derive(Debug, Clone)]
pub struct GridResult<T> {
    pub index: usize,
    pub value: T,
}

/// Run `jobs` through `worker` on `threads` scoped threads. Results
/// come back sorted by job index. Panics in workers propagate.
pub fn run_grid<J, T, F>(jobs: Vec<J>, threads: usize, worker: F) -> Vec<T>
where
    J: Send + Sync,
    T: Send,
    F: Fn(usize, &J) -> T + Sync,
{
    let n = jobs.len();
    let threads = threads.clamp(1, n.max(1));
    let cursor = AtomicUsize::new(0);
    let results: Mutex<Vec<Option<T>>> = Mutex::new((0..n).map(|_| None).collect());
    let jobs_ref = &jobs;
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let out = worker(i, &jobs_ref[i]);
                results.lock().unwrap()[i] = Some(out);
            });
        }
    });
    results
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|r| r.expect("worker completed"))
        .collect()
}

/// Run `worker` over mutable jobs in place, fanned over scoped threads
/// in contiguous chunks. Results come back in job order. Unlike
/// [`run_grid`] the jobs stay owned by the caller — this is the
/// primitive the closed-loop calibration pipeline uses to advance its
/// per-shard [`crate::compress::Compressible::CalibState`]s in
/// parallel.
pub fn run_grid_mut<J, T, F>(jobs: &mut [J], threads: usize, worker: F) -> Vec<T>
where
    J: Send,
    T: Send,
    F: Fn(usize, &mut J) -> T + Sync,
{
    let n = jobs.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        return jobs.iter_mut().enumerate().map(|(i, j)| worker(i, j)).collect();
    }
    let chunk = (n + threads - 1) / threads;
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    std::thread::scope(|scope| {
        for (ci, (job_chunk, out_chunk)) in
            jobs.chunks_mut(chunk).zip(out.chunks_mut(chunk)).enumerate()
        {
            let worker = &worker;
            scope.spawn(move || {
                for (off, (j, o)) in
                    job_chunk.iter_mut().zip(out_chunk.iter_mut()).enumerate()
                {
                    *o = Some(worker(ci * chunk + off, j));
                }
            });
        }
    });
    out.into_iter().map(|r| r.expect("worker completed")).collect()
}

/// Worker-thread count: `GRAIL_THREADS` env or available parallelism.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("GRAIL_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let jobs: Vec<usize> = (0..50).collect();
        let out = run_grid(jobs, 4, |_, &j| j * 2);
        assert_eq!(out, (0..50).map(|j| j * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_works() {
        let out = run_grid(vec![1, 2, 3], 1, |i, &j| i + j);
        assert_eq!(out, vec![1, 3, 5]);
    }

    #[test]
    fn empty_jobs_ok() {
        let out: Vec<i32> = run_grid(Vec::<i32>::new(), 4, |_, &j| j);
        assert!(out.is_empty());
    }

    #[test]
    fn more_threads_than_jobs() {
        let out = run_grid(vec![7], 16, |_, &j| j);
        assert_eq!(out, vec![7]);
    }

    #[test]
    fn default_threads_positive() {
        assert!(default_threads() >= 1);
    }

    #[test]
    fn run_grid_mut_mutates_in_order() {
        let mut jobs: Vec<u64> = (0..23).collect();
        let out = run_grid_mut(&mut jobs, 4, |i, j| {
            *j += 100;
            (i as u64, *j)
        });
        assert_eq!(jobs, (100..123).collect::<Vec<_>>());
        for (i, (idx, v)) in out.iter().enumerate() {
            assert_eq!(*idx, i as u64);
            assert_eq!(*v, 100 + i as u64);
        }
    }

    #[test]
    fn run_grid_mut_empty_and_single() {
        let mut empty: Vec<u8> = Vec::new();
        let out: Vec<u8> = run_grid_mut(&mut empty, 8, |_, j| *j);
        assert!(out.is_empty());
        let mut one = vec![7u8];
        assert_eq!(run_grid_mut(&mut one, 8, |_, j| *j + 1), vec![8]);
    }
}
