//! Checkpoint-zoo loading: the trained `.wbin` bundles written by the
//! Python build step, instantiated as Rust models.

use super::paths::Artifacts;
use crate::nn::models::{LmConfig, MiniResNet, MlpNet, TinyLm, TinyViT, VitConfig};
use crate::nn::weights::WeightBundle;
use anyhow::{Context, Result};

/// Lazy handle over the artifacts directory.
pub struct Zoo {
    art: Artifacts,
}

impl Zoo {
    /// Open the zoo (errors if `make artifacts` has not run).
    pub fn open(art: Artifacts) -> Result<Zoo> {
        art.ensure_ready()?;
        Ok(Zoo { art })
    }

    /// Checkpoint names of a family present on disk (`mlp`, `resnet`,
    /// `vit`, `tinylm`).
    pub fn list(&self, family: &str) -> Vec<String> {
        let mut names = Vec::new();
        if let Ok(dir) = std::fs::read_dir(self.art.ckpt_dir()) {
            for e in dir.flatten() {
                let f = e.file_name().to_string_lossy().into_owned();
                if let Some(stem) = f.strip_suffix(".wbin") {
                    if stem.starts_with(family) {
                        names.push(stem.to_string());
                    }
                }
            }
        }
        names.sort();
        names
    }

    fn bundle(&self, name: &str) -> Result<WeightBundle> {
        WeightBundle::load(&self.art.ckpt(name)).with_context(|| format!("loading {name}"))
    }

    /// Load an MLP checkpoint.
    pub fn mlp(&self, name: &str) -> Result<MlpNet> {
        MlpNet::from_bundle(&self.bundle(name)?)
    }

    /// Load a MiniResNet checkpoint.
    pub fn resnet(&self, name: &str) -> Result<MiniResNet> {
        MiniResNet::from_bundle(&self.bundle(name)?)
    }

    /// Load a TinyViT checkpoint.
    pub fn vit(&self, name: &str) -> Result<TinyViT> {
        TinyViT::from_bundle(&self.bundle(name)?, VitConfig::default())
    }

    /// Load a TinyLm checkpoint (`tinylm_mha` / `tinylm_gqa`).
    pub fn lm(&self, name: &str) -> Result<TinyLm> {
        let cfg = if name.contains("gqa") { LmConfig::gqa() } else { LmConfig::default() };
        TinyLm::from_bundle(&self.bundle(name)?, cfg)
    }

    /// The artifacts handle.
    pub fn artifacts(&self) -> &Artifacts {
        &self.art
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Zoo loading against real artifacts is exercised by
    // rust/tests/integration.rs (requires `make artifacts`). Here we
    // only test the no-artifacts error path and name listing logic.
    #[test]
    fn open_without_artifacts_errors() {
        let art = Artifacts::at("/nonexistent/zoo");
        assert!(Zoo::open(art).is_err());
    }

    #[test]
    fn list_scans_wbin_files() {
        let dir = std::env::temp_dir().join("grail_zoo_test");
        let ck = dir.join("checkpoints");
        std::fs::create_dir_all(&ck).unwrap();
        std::fs::write(ck.join("mlp_seed0.wbin"), b"x").unwrap();
        std::fs::write(ck.join("mlp_seed1.wbin"), b"x").unwrap();
        std::fs::write(ck.join("resnet_seed0.wbin"), b"x").unwrap();
        std::fs::write(ck.join("notes.txt"), b"x").unwrap();
        let zoo = Zoo { art: Artifacts::at(dir.to_str().unwrap()) };
        assert_eq!(zoo.list("mlp"), vec!["mlp_seed0", "mlp_seed1"]);
        assert_eq!(zoo.list("resnet"), vec!["resnet_seed0"]);
        assert!(zoo.list("vit").is_empty());
    }
}
