//! Artifact directory layout shared with the Python build step.

use anyhow::{ensure, Result};
use std::path::{Path, PathBuf};

/// Resolved locations of everything `make artifacts` produces.
#[derive(Clone, Debug)]
pub struct Artifacts {
    pub root: PathBuf,
}

impl Artifacts {
    /// Use an explicit artifacts root.
    pub fn at(root: &str) -> Self {
        Artifacts { root: PathBuf::from(root) }
    }

    /// Default root: `$GRAIL_ARTIFACTS` or `./artifacts`.
    pub fn default_root() -> Self {
        let root = std::env::var("GRAIL_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Artifacts { root: PathBuf::from(root) }
    }

    /// `artifacts/data/`.
    pub fn data_dir(&self) -> PathBuf {
        self.root.join("data")
    }

    /// `artifacts/checkpoints/`.
    pub fn ckpt_dir(&self) -> PathBuf {
        self.root.join("checkpoints")
    }

    /// `artifacts/hlo/`.
    pub fn hlo_dir(&self) -> PathBuf {
        self.root.join("hlo")
    }

    /// `artifacts/serve/` — default root of the `grail serve` daemon
    /// spool (queue, job records, results, statistics cache).
    pub fn serve_dir(&self) -> PathBuf {
        self.root.join("serve")
    }

    /// Path of a data file.
    pub fn data(&self, name: &str) -> String {
        self.data_dir().join(name).to_string_lossy().into_owned()
    }

    /// Path of a checkpoint bundle.
    pub fn ckpt(&self, name: &str) -> String {
        self.ckpt_dir().join(format!("{name}.wbin")).to_string_lossy().into_owned()
    }

    /// Path of an HLO computation.
    pub fn hlo(&self, name: &str) -> String {
        self.hlo_dir().join(format!("{name}.hlo.txt")).to_string_lossy().into_owned()
    }

    /// Error out with a helpful message if the build step hasn't run.
    pub fn ensure_ready(&self) -> Result<()> {
        ensure!(
            Path::new(&self.ckpt("tinylm_mha")).exists(),
            "artifacts missing at {:?} — run `make artifacts` first",
            self.root
        );
        Ok(())
    }

    /// Whether the datagen outputs exist.
    pub fn has_data(&self) -> bool {
        Path::new(&self.data("vision_train.imgs")).exists()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_paths() {
        let a = Artifacts::at("/tmp/x");
        assert_eq!(a.data("t.imgs"), "/tmp/x/data/t.imgs");
        assert_eq!(a.ckpt("m"), "/tmp/x/checkpoints/m.wbin");
        assert_eq!(a.hlo("f"), "/tmp/x/hlo/f.hlo.txt");
    }

    #[test]
    fn missing_artifacts_reported() {
        let a = Artifacts::at("/definitely/not/here");
        let err = a.ensure_ready().unwrap_err().to_string();
        assert!(err.contains("make artifacts"));
    }
}
