//! PJRT runtime: load and execute the AOT-compiled HLO artifacts.
//!
//! Wraps the `xla` crate (`PjRtClient::cpu` →
//! `HloModuleProto::from_text_file` → `compile` → `execute`). One
//! compiled executable per artifact, cached in a registry. HLO *text*
//! is the interchange format (see `python/compile/aot.py` and
//! /opt/xla-example/README.md for the 64-bit-id gotcha).

use crate::coordinator::paths::Artifacts;
use crate::tensor::Tensor;
use anyhow::{Context, Result};
use std::collections::BTreeMap;

/// A PJRT CPU client plus a cache of compiled executables.
pub struct Runtime {
    client: xla::PjRtClient,
    art: Artifacts,
    cache: BTreeMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Create a CPU runtime over an artifacts directory.
    pub fn cpu(art: Artifacts) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, art, cache: BTreeMap::new() })
    }

    /// Platform string (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an artifact by name (cached).
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.cache.contains_key(name) {
            return Ok(());
        }
        let path = self.art.hlo(name);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        self.cache.insert(name.to_string(), exe);
        Ok(())
    }

    /// Names currently compiled.
    pub fn loaded(&self) -> Vec<&str> {
        self.cache.keys().map(|s| s.as_str()).collect()
    }

    /// Execute an artifact on f32 tensor inputs; returns all outputs
    /// (the AOT graphs are lowered with `return_tuple=True`).
    pub fn run_f32(&mut self, name: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        self.load(name)?;
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| {
                let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(t.data()).reshape(&dims).context("reshaping input literal")
            })
            .collect::<Result<_>>()?;
        self.execute(name, lits, inputs.first().map(|t| t.shape().to_vec()))
    }

    /// Execute an artifact whose first input is an i32 token matrix
    /// `[b, t]` (the LM graphs).
    pub fn run_tokens(&mut self, name: &str, tokens: &[u16], b: usize, t: usize) -> Result<Vec<Tensor>> {
        self.load(name)?;
        assert_eq!(tokens.len(), b * t, "token count");
        let ids: Vec<i32> = tokens.iter().map(|&x| x as i32).collect();
        let lit = xla::Literal::vec1(&ids).reshape(&[b as i64, t as i64])?;
        self.execute(name, vec![lit], None)
    }

    fn execute(
        &mut self,
        name: &str,
        lits: Vec<xla::Literal>,
        _hint: Option<Vec<usize>>,
    ) -> Result<Vec<Tensor>> {
        let exe = self.cache.get(name).expect("loaded above");
        let result = exe
            .execute::<xla::Literal>(&lits)
            .with_context(|| format!("executing {name}"))?[0][0]
            .to_literal_sync()?;
        let outs = result.to_tuple()?;
        outs.into_iter()
            .map(|lit| {
                let shape = lit.array_shape().context("output shape")?;
                let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
                let data = lit.to_vec::<f32>().context("output to f32")?;
                Ok(Tensor::from_vec(&dims, data))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    // Runtime tests require compiled artifacts; they live in
    // rust/tests/runtime_pjrt.rs (run after `make artifacts`). The
    // pure-logic pieces here have no artifact-free behaviour to test
    // beyond construction:
    use super::*;

    #[test]
    fn cpu_client_constructs() {
        // With the offline xla stub (or a missing PJRT install) client
        // construction fails cleanly; both outcomes are acceptable.
        let Ok(rt) = Runtime::cpu(Artifacts::at("/tmp/nonexistent")) else {
            eprintln!("skipping: PJRT unavailable");
            return;
        };
        assert!(rt.platform().to_lowercase().contains("cpu") || !rt.platform().is_empty());
        assert!(rt.loaded().is_empty());
    }

    #[test]
    fn missing_artifact_errors() {
        let Ok(mut rt) = Runtime::cpu(Artifacts::at("/tmp/nonexistent")) else {
            eprintln!("skipping: PJRT unavailable");
            return;
        };
        assert!(rt.load("nope").is_err());
    }
}
