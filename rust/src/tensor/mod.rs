//! Dense row-major f32 tensors.
//!
//! This is the numeric substrate for everything whose shape depends on
//! the compression ratio (the PJRT artifacts have fixed shapes and run
//! the full-width calibration path; compressed-model evaluation and all
//! GRAIL algebra run here). Deliberately minimal: contiguous row-major
//! `f32` storage, explicit shapes, no broadcasting magic — every op the
//! library needs is implemented (and tested) in [`ops`].

pub mod gemm;
pub mod ops;

use std::fmt;

/// A dense, contiguous, row-major f32 tensor.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    /// Zero-filled tensor with the given shape.
    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    /// Tensor filled with a constant.
    pub fn full(shape: &[usize], v: f32) -> Self {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![v; n] }
    }

    /// Build from existing data (must match the shape's element count).
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, data.len(), "shape {shape:?} needs {n} elements, got {}", data.len());
        Tensor { shape: shape.to_vec(), data }
    }

    /// Identity matrix of size `n`.
    pub fn eye(n: usize) -> Self {
        let mut t = Tensor::zeros(&[n, n]);
        for i in 0..n {
            t.data[i * n + i] = 1.0;
        }
        t
    }

    /// The tensor's shape.
    #[inline]
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Number of dimensions.
    #[inline]
    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    /// Total element count.
    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True if the tensor holds no elements.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Dimension `i` (panics if out of range).
    #[inline]
    pub fn dim(&self, i: usize) -> usize {
        self.shape[i]
    }

    /// Raw data slice.
    #[inline]
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Mutable raw data slice.
    #[inline]
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume into the raw data vector.
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Reinterpret with a new shape of equal element count.
    pub fn reshape(mut self, shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, self.data.len(), "reshape {:?} -> {shape:?}", self.shape);
        self.shape = shape.to_vec();
        self
    }

    /// 2-D element accessor.
    #[inline]
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.ndim(), 2);
        self.data[i * self.shape[1] + j]
    }

    /// 2-D element setter.
    #[inline]
    pub fn set2(&mut self, i: usize, j: usize, v: f32) {
        debug_assert_eq!(self.ndim(), 2);
        let cols = self.shape[1];
        self.data[i * cols + j] = v;
    }

    /// Row `i` of a 2-D tensor as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert_eq!(self.ndim(), 2);
        let c = self.shape[1];
        &self.data[i * c..(i + 1) * c]
    }

    /// Mutable row of a 2-D tensor.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        debug_assert_eq!(self.ndim(), 2);
        let c = self.shape[1];
        &mut self.data[i * c..(i + 1) * c]
    }

    /// Apply `f` elementwise in place.
    pub fn map_inplace(&mut self, f: impl Fn(f32) -> f32) {
        for v in &mut self.data {
            *v = f(*v);
        }
    }

    /// New tensor with `f` applied elementwise.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        let mut out = self.clone();
        out.map_inplace(f);
        out
    }

    /// Max absolute elementwise difference against another tensor.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Frobenius norm.
    pub fn frobenius(&self) -> f32 {
        self.data.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>().sqrt() as f32
    }

    /// True if all elements are finite.
    pub fn all_finite(&self) -> bool {
        self.data.iter().all(|v| v.is_finite())
    }
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}", self.shape)?;
        if self.data.len() <= 16 {
            write!(f, " {:?}", self.data)?;
        } else {
            write!(f, " [{:?}.., fro={:.4}]", &self.data[..8], self.frobenius())?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_index() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.at2(0, 2), 3.0);
        assert_eq!(t.at2(1, 0), 4.0);
        assert_eq!(t.row(1), &[4., 5., 6.]);
        assert_eq!(t.len(), 6);
    }

    #[test]
    fn eye_diagonal() {
        let i = Tensor::eye(4);
        for r in 0..4 {
            for c in 0..4 {
                assert_eq!(i.at2(r, c), if r == c { 1.0 } else { 0.0 });
            }
        }
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).reshape(&[3, 2]);
        assert_eq!(t.shape(), &[3, 2]);
        assert_eq!(t.at2(2, 1), 6.0);
    }

    #[test]
    #[should_panic]
    fn reshape_wrong_size_panics() {
        let _ = Tensor::zeros(&[2, 3]).reshape(&[4, 2]);
    }

    #[test]
    fn map_and_norm() {
        let t = Tensor::from_vec(&[3], vec![3., 0., 4.]);
        assert!((t.frobenius() - 5.0).abs() < 1e-6);
        let u = t.map(|v| v * 2.0);
        assert_eq!(u.data(), &[6., 0., 8.]);
    }

    #[test]
    fn finite_detection() {
        let mut t = Tensor::zeros(&[2]);
        assert!(t.all_finite());
        t.data_mut()[1] = f32::NAN;
        assert!(!t.all_finite());
    }
}
