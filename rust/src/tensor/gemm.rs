//! Packed, cache-blocked, register-tiled f32 GEMM/SYRK engine.
//!
//! Every forward-path matmul in the crate — `Linear::forward`
//! (`ops::matmul_nt`), `Conv2d::forward`'s im2col GEMM, the attention
//! score/context matmuls, the GRAIL reducer/absorb algebra, and the
//! streamed `ops::syrk_upper_acc` Gram accumulation — lands here via
//! the dispatching entries in [`super::ops`]. The design mirrors the
//! contract the blocked Cholesky engine proved out
//! ([`crate::linalg::BlockedCholesky`]):
//!
//! - **Packing** — the shared operand `B` is packed once per call into
//!   [`KC`]-deep, [`NR`]-wide column panels; each row-panel job packs
//!   its own [`MC`]×[`KC`] block of `A` into [`MR`]-wide strips (with
//!   `alpha` folded in). Packed panels make every microkernel access
//!   contiguous and edge tiles zero-padded, so there is **no
//!   data-dependent branch** in the inner loops: `0·NaN` / `0·∞`
//!   propagate by construction (the old per-element zero-skip and its
//!   whole-buffer finiteness rescan are gone).
//! - **Register tiling** — an [`MR`]×[`NR`] accumulator tile lives in
//!   registers across the k loop. On x86-64 the microkernel is
//!   additionally monomorphized under `avx2,fma` (selected by runtime
//!   feature detection) so LLVM emits 256-bit FMAs; elsewhere the
//!   generic version autovectorizes at the baseline ISA.
//! - **Deterministic accumulation** — for every output element the k
//!   dimension accumulates in increasing order in a single chain
//!   (the tile is reloaded from `C` per [`KC`] strip), so results are a
//!   pure function of the operands and tile geometry.
//! - **Parallel row panels** — work is pre-split into fixed [`MC`]-row
//!   jobs writing disjoint `C` panels and fanned over
//!   [`run_grid_mut`](crate::coordinator::scheduler::run_grid_mut).
//!   Job boundaries never depend on the worker count, so results are
//!   **bit-identical at any parallelism**. Auto worker resolution
//!   defers to the scheduler's divided thread budget
//!   ([`default_threads`]): big GEMMs from single-stream paths get the
//!   machine, kernels inside shard-level calibration workers get that
//!   worker's share (typically serial), and `GRAIL_THREADS` caps the
//!   total.
//!
//! - **Fused epilogues** — the serving path attaches an [`Epilogue`]
//!   (`None | Bias | BiasRelu | BiasGelu`) that is applied to the
//!   accumulator tile on the final KC strip, while it is still in
//!   registers: an activation-following `Linear::forward` is one pass
//!   over `C` instead of GEMM + `add_bias` + activation sweeps, and
//!   the result is bit-identical to the unfused sequence (same scalar
//!   ops, same order, one shared [`Epilogue::apply`]).
//! - **Prepacked weights** — [`PackedB`] holds a weight operand packed
//!   once for repeated serving calls ([`gemm_nt_prepacked`]); KV-cache
//!   decode pushes one row at a time through the same weights, where
//!   per-call packing would dominate. Packing and compute bodies are
//!   shared with the per-call entries, so results match to the bit.
//!
//! The scalar loops survive in [`super::ops`] as `*_ref` oracles; the
//! property suite in `rust/tests/gemm_engine.rs` sweeps panel-boundary
//! shapes, NaN/∞ propagation, worker-count bit-invariance, epilogue
//! conformance, and prepacked-vs-per-call equality, and
//! `benches/hotpath.rs` asserts the packed path wins (and by ≥ 2× on
//! 512-dim GEMM) on every CI run.

use crate::coordinator::scheduler::{audit::WriteSet, default_threads, run_grid_mut};
use std::sync::atomic::{AtomicBool, Ordering};

/// Microkernel row count (rows of `C` held in registers).
pub const MR: usize = 4;
/// Microkernel column count (columns of `C` held in registers).
pub const NR: usize = 16;
/// Depth of one packed k strip (shared dimension blocking).
pub const KC: usize = 256;
/// Rows per parallel row-panel job (also the A-block height).
pub const MC: usize = 64;

/// Minimum `2·m·k·n` flop volume before the dispatching entries in
/// [`super::ops`] take the packed path; below it the packing overhead
/// dominates and the scalar `*_ref` loops win.
pub const PACKED_MIN_FLOPS: usize = 1 << 18;

/// Minimum `k·n` weight volume before the *serving* entries in
/// [`super::ops`] (`gemm_nt_serve` / `gemm_nn_serve`) take the packed
/// path. This is [`PACKED_MIN_FLOPS`] evaluated at one [`MC`]-row
/// panel (`2·MC·k·n`), so the two rules agree on calibration-sized
/// batches — but unlike the flop rule it is independent of the row
/// count `m`. That row-invariance is what lets a 1-row KV-cache decode
/// step take the same kernel — and produce the same bits — as the
/// multi-row forward it must match.
pub const PACKED_MIN_COLS: usize = PACKED_MIN_FLOPS / (2 * MC);

/// Minimum flop volume before row panels fan over worker threads
/// (same spirit as the blocked solver's `PARALLEL_MIN_FLOPS`).
const PARALLEL_MIN_FLOPS: usize = 1 << 23;

/// Global packed-path switch. Only `benches/hotpath.rs` flips it, to
/// measure end-to-end packed-vs-scalar pipeline wall-clock; it must
/// stay `true` everywhere else (tests compare against the `*_ref`
/// oracles directly instead).
static PACKED_ENABLED: AtomicBool = AtomicBool::new(true);

/// Enable/disable the packed dispatch globally. Bench-only: the
/// hotpath bench flips it to measure end-to-end packed-vs-scalar
/// pipeline wall-clock; leave it `true` everywhere else.
pub fn set_packed_enabled(on: bool) {
    PACKED_ENABLED.store(on, Ordering::Relaxed);
}

/// Whether the dispatching entries currently use the packed engine.
pub fn packed_enabled() -> bool {
    PACKED_ENABLED.load(Ordering::Relaxed)
}

/// Shape-based dispatch: should an `m×k×n` product take the packed
/// path? Deterministic in the shape alone.
pub(crate) fn use_packed(m: usize, k: usize, n: usize) -> bool {
    packed_enabled() && m != 0 && k != 0 && n != 0 && flops(m, k, n) >= PACKED_MIN_FLOPS
}

/// Row-count-invariant dispatch for the serving path: packed iff the
/// `k·n` weight volume is large enough, regardless of how many rows
/// are being pushed through. See [`PACKED_MIN_COLS`].
///
/// Chunked prefill leans on the missing `m` here: splitting a prompt
/// across `batch_step` passes only changes row counts, never `(k, n)`,
/// so no chunk size can flip a layer between the packed and scalar
/// kernels. (At serving *attention* shapes, `k·n = d_head · len` sits
/// below [`PACKED_MIN_COLS`] anyway, so those products always take the
/// scalar path regardless of how the prompt is chunked.)
pub fn use_packed_cols(k: usize, n: usize) -> bool {
    packed_enabled() && k != 0 && n != 0 && k.saturating_mul(n) >= PACKED_MIN_COLS
}

#[inline]
fn flops(m: usize, k: usize, n: usize) -> usize {
    2usize.saturating_mul(m).saturating_mul(k).saturating_mul(n)
}

/// A fused GEMM epilogue: bias and activation applied to the
/// accumulator tile on the *final* KC strip — while it is still in
/// registers — so an activation-following linear layer is one pass
/// over `C` instead of GEMM + `add_bias` + activation sweeps.
///
/// The fused result is **bit-identical** to the unfused sequence: the
/// epilogue performs the same scalar ops (`v + bias[j]`, then the
/// activation) in the same order on the same accumulated values, and
/// [`Epilogue::apply`] is the single shared implementation used both
/// inside [`gemm_block`] and by the scalar fallback sweep in
/// `ops::gemm_nt_serve` — so there is no second epilogue codepath to
/// drift.
#[derive(Clone, Copy, Debug, Default)]
pub enum Epilogue<'a> {
    /// Plain accumulate-and-store (the calibration/algebra default).
    #[default]
    None,
    /// `c[i][j] += bias[j]`.
    Bias(&'a [f32]),
    /// `c[i][j] = max(c[i][j] + bias[j], 0)`.
    BiasRelu(&'a [f32]),
    /// `c[i][j] = gelu(c[i][j] + bias[j])` — the tanh approximation,
    /// exactly [`crate::nn::gelu_scalar`].
    BiasGelu(&'a [f32]),
}

impl Epilogue<'_> {
    /// Apply to a run of output columns starting at absolute column
    /// `j0`. Shared by the packed register-tile path and the scalar
    /// fallback so both produce the same bits.
    #[inline]
    pub fn apply(&self, j0: usize, row: &mut [f32]) {
        match *self {
            Epilogue::None => {}
            Epilogue::Bias(bias) => {
                for (v, &bj) in row.iter_mut().zip(&bias[j0..]) {
                    *v += bj;
                }
            }
            Epilogue::BiasRelu(bias) => {
                for (v, &bj) in row.iter_mut().zip(&bias[j0..]) {
                    *v = (*v + bj).max(0.0);
                }
            }
            Epilogue::BiasGelu(bias) => {
                for (v, &bj) in row.iter_mut().zip(&bias[j0..]) {
                    *v = crate::nn::gelu_scalar(*v + bj);
                }
            }
        }
    }
}

#[cfg(target_arch = "x86_64")]
fn fma_available() -> bool {
    is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
}

#[cfg(not(target_arch = "x86_64"))]
fn fma_available() -> bool {
    false
}

/// The microkernel body: `acc[r][j] += Σ_p ap[p·MR+r] · bp[p·NR+j]`
/// with `p` ascending — a single accumulation chain per element.
/// `FUSED` selects `mul_add` (one rounding per step) vs separate
/// multiply-and-add (two roundings): Rust never contracts `a*b + c`
/// into an FMA on its own, so the fused variant must be explicit —
/// and is only used where runtime detection guarantees a hardware FMA
/// instruction (a libm soft fall-back would be ruinously slow).
#[inline(always)]
fn microkernel_body<const FUSED: bool>(
    kl: usize,
    ap: &[f32],
    bp: &[f32],
    acc: &mut [[f32; NR]; MR],
) {
    debug_assert!(ap.len() >= kl * MR);
    debug_assert!(bp.len() >= kl * NR);
    for p in 0..kl {
        let a = &ap[p * MR..p * MR + MR];
        let b = &bp[p * NR..p * NR + NR];
        for (r, arow) in acc.iter_mut().enumerate() {
            let av = a[r];
            for (j, cv) in arow.iter_mut().enumerate() {
                if FUSED {
                    *cv = av.mul_add(b[j], *cv);
                } else {
                    *cv += av * b[j];
                }
            }
        }
    }
}

/// Portable microkernel: plain multiply-and-add, autovectorized at the
/// target's baseline ISA.
#[inline(always)]
fn microkernel_generic(kl: usize, ap: &[f32], bp: &[f32], acc: &mut [[f32; NR]; MR]) {
    microkernel_body::<false>(kl, ap, bp, acc);
}

/// The microkernel monomorphized under AVX2+FMA with explicit
/// `mul_add`, so LLVM emits 256-bit `vfmadd` instructions.
///
/// # Safety
///
/// - The caller must have verified `avx2` **and** `fma` via runtime
///   feature detection ([`fma_available`]); calling this on a CPU
///   without them is undefined behavior (illegal instruction at
///   best).
/// - `kl ≤ KC` (one packed strip depth), `ap.len() ≥ kl * MR`, and
///   `bp.len() ≥ kl * NR` — the packed-panel preconditions
///   [`microkernel_body`] indexes under. These are slice-checked in
///   debug builds (the body is safe code), so the contract exists to
///   keep release-mode bounds-check elision honest, not to permit
///   unchecked access.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn microkernel_avx2(kl: usize, ap: &[f32], bp: &[f32], acc: &mut [[f32; NR]; MR]) {
    microkernel_body::<true>(kl, ap, bp, acc);
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn microkernel(use_fma: bool, kl: usize, ap: &[f32], bp: &[f32], acc: &mut [[f32; NR]; MR]) {
    if use_fma {
        // SAFETY: `use_fma` is only set by `fma_available()`.
        unsafe { microkernel_avx2(kl, ap, bp, acc) }
    } else {
        microkernel_generic(kl, ap, bp, acc);
    }
}

#[cfg(not(target_arch = "x86_64"))]
#[inline]
fn microkernel(use_fma: bool, kl: usize, ap: &[f32], bp: &[f32], acc: &mut [[f32; NR]; MR]) {
    let _ = use_fma;
    microkernel_generic(kl, ap, bp, acc);
}

/// `(start, len)` blocking of `0..len` in `chunk`-sized strips.
fn strips(len: usize, chunk: usize) -> Vec<(usize, usize)> {
    let mut out = Vec::with_capacity(len / chunk + 1);
    let mut start = 0usize;
    while start < len {
        let l = chunk.min(len - start);
        out.push((start, l));
        start += l;
    }
    out
}

/// Pack one KC strip of row-major `B: [k, n]` into `nblk` column panels
/// of layout `[p][j]` (`NR`-wide, zero-padded at the right edge).
fn pack_b_strip_kn(b: &[f32], n: usize, k0: usize, kl: usize, nblk: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), nblk * kl * NR);
    out.fill(0.0);
    for jb in 0..nblk {
        let j0 = jb * NR;
        let nl = NR.min(n - j0);
        let dst = &mut out[jb * kl * NR..(jb + 1) * kl * NR];
        for p in 0..kl {
            let src = &b[(k0 + p) * n + j0..(k0 + p) * n + j0 + nl];
            dst[p * NR..p * NR + nl].copy_from_slice(src);
        }
    }
}

/// Pack one KC strip of `Bᵀ` where `B: [n, k]` row-major (the
/// `matmul_nt` layout): `out[p·NR + j] = B[j0+j][k0+p]`. Reads are
/// contiguous rows of `B`; the transpose happens in the strided write.
fn pack_b_strip_nk(
    b: &[f32],
    k: usize,
    n: usize,
    k0: usize,
    kl: usize,
    nblk: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), nblk * kl * NR);
    out.fill(0.0);
    for jb in 0..nblk {
        let j0 = jb * NR;
        let nl = NR.min(n - j0);
        let dst = &mut out[jb * kl * NR..(jb + 1) * kl * NR];
        for jj in 0..nl {
            let src = &b[(j0 + jj) * k + k0..(j0 + jj) * k + k0 + kl];
            for (p, &v) in src.iter().enumerate() {
                dst[p * NR + jj] = v;
            }
        }
    }
}

/// Pack `rl ≤ MR` rows of `A: [m, k]` for one KC strip into `[p][r]`
/// layout with `alpha` folded in (zero-padded below `MR`).
fn pack_a_strip(
    a: &[f32],
    k: usize,
    r0: usize,
    rl: usize,
    k0: usize,
    kl: usize,
    alpha: f32,
    out: &mut [f32],
) {
    debug_assert!(out.len() >= kl * MR);
    out[..kl * MR].fill(0.0);
    for rr in 0..rl {
        let src = &a[(r0 + rr) * k + k0..(r0 + rr) * k + k0 + kl];
        for (p, &v) in src.iter().enumerate() {
            out[p * MR + rr] = alpha * v;
        }
    }
}

/// Pack `rl ≤ MR` *columns* of row-major `X: [rows, h]` (i.e. rows of
/// `Xᵀ`) for one KC strip of the sample dimension — the SYRK "A"
/// operand: `out[p·MR + r] = X[k0+p][r0+r]`.
fn pack_a_strip_t(
    x: &[f32],
    h: usize,
    r0: usize,
    rl: usize,
    k0: usize,
    kl: usize,
    out: &mut [f32],
) {
    debug_assert!(out.len() >= kl * MR);
    out[..kl * MR].fill(0.0);
    for p in 0..kl {
        let src = &x[(k0 + p) * h + r0..(k0 + p) * h + r0 + rl];
        out[p * MR..p * MR + rl].copy_from_slice(src);
    }
}

/// Pack every KC strip of `B` (`[k, n]` row-major, or `[n, k]` when
/// `b_is_nk`) into the engine's panel layout. Returns the packed
/// buffer, the strip list, and the column-panel count. Single shared
/// implementation for per-call packing ([`gemm_packed`]) and ahead-of-
/// time packing ([`PackedB::pack_nt`]), so prepacked weights are
/// byte-identical to what a per-call GEMM would have packed.
fn pack_b_full(
    b: &[f32],
    k: usize,
    n: usize,
    b_is_nk: bool,
) -> (Vec<f32>, Vec<(usize, usize)>, usize) {
    let nblk = (n + NR - 1) / NR;
    let kc_strips = strips(k, KC);
    let mut bpack = vec![0.0f32; k * nblk * NR];
    let mut off = 0usize;
    for &(k0, kl) in &kc_strips {
        let out = &mut bpack[off..off + kl * nblk * NR];
        if b_is_nk {
            pack_b_strip_nk(b, k, n, k0, kl, nblk, out);
        } else {
            pack_b_strip_kn(b, n, k0, kl, nblk, out);
        }
        off += kl * nblk * NR;
    }
    (bpack, kc_strips, nblk)
}

/// The shared `B` operand of an NT GEMM (`B: [n, k]` row-major — a
/// linear layer's `[out, in]` weight), prepacked once into the
/// engine's KC-strip × NR-panel layout for repeated serving calls via
/// [`gemm_nt_prepacked`]. Decode steps push one row at a time through
/// the same weights hundreds of times; packing per call would dominate
/// the m=1 GEMM. Packing here goes through [`pack_b_full`] — the exact
/// code the per-call path uses — so prepacked and per-call results
/// match to the bit.
#[derive(Clone)]
pub struct PackedB {
    data: Vec<f32>,
    k: usize,
    n: usize,
    nblk: usize,
    kc_strips: Vec<(usize, usize)>,
}

impl PackedB {
    /// Pack `b: [n, k]` row-major (the `matmul_nt` weight layout).
    pub fn pack_nt(b: &[f32], k: usize, n: usize) -> PackedB {
        assert!(k > 0 && n > 0, "PackedB needs non-empty operands");
        assert_eq!(b.len(), n * k);
        let (data, kc_strips, nblk) = pack_b_full(b, k, n, true);
        PackedB { data, k, n, nblk, kc_strips }
    }

    /// Inner (shared) dimension `k`.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Output dimension `n`.
    pub fn n(&self) -> usize {
        self.n
    }
}

/// Resolve the effective worker count for a row-panel fan-out:
/// explicit `workers` wins; auto (`0`) applies a flop threshold and
/// then defers to [`default_threads`] — the current thread's share of
/// the scheduler's divided budget (the machine on single-stream paths,
/// typically 1 inside parallel calibration workers). Purely a
/// scheduling decision — results are bit-identical at every value.
fn resolve_workers(workers: usize, m: usize, k: usize, n: usize) -> usize {
    let blocks = (m + MC - 1) / MC;
    let w = if workers != 0 {
        workers
    } else if flops(m, k, n) < PARALLEL_MIN_FLOPS {
        1
    } else {
        default_threads()
    };
    w.clamp(1, blocks.max(1))
}

/// `C += alpha · A · B` on row-major buffers (`A: [m,k]`, `B: [k,n]`,
/// `C: [m,n]`) through the packed engine. `workers = 0` resolves
/// automatically under the thread-budget policy.
pub fn gemm_nn_packed(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    alpha: f32,
    workers: usize,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    gemm_packed(a, b, c, m, k, n, alpha, false, workers);
}

/// `C += A · Bᵀ` on row-major buffers (`A: [m,k]`, `B: [n,k]`,
/// `C: [m,n]`) through the packed engine — the linear-layer layout.
pub fn gemm_nt_packed(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    workers: usize,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    gemm_packed(a, b, c, m, k, n, 1.0, true, Epilogue::None, workers);
}

/// `C += A · Bᵀ` with a fused epilogue — the serving-path entry behind
/// `ops::gemm_nt_serve`. Callers dispatch via [`use_packed_cols`], so
/// `k > 0` here (an all-bias `k = 0` product takes the scalar path,
/// where the epilogue sweep still runs).
pub fn gemm_nt_packed_ep(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    ep: Epilogue<'_>,
    workers: usize,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    gemm_packed(a, b, c, m, k, n, 1.0, true, ep, workers);
}

/// `C += A · Bᵀ` against a [`PackedB`] with a fused epilogue — the
/// decode path's entry: the weight operand is packed once per sequence
/// (not per step), and the compute body is the same
/// [`gemm_with_packed_b`] the per-call entries use, so results are
/// bit-identical to [`gemm_nt_packed_ep`].
///
/// **Coalescing contract** (what continuous batching leans on): the
/// dispatch rule [`use_packed_cols`] has no `m` argument, and every
/// output row is computed from row-local accumulator state in the same
/// `k` order regardless of `m` — so one m-row call against a shared
/// pack is bitwise equal to m separate 1-row calls. The decode
/// scheduler ([`crate::serve::batch`]) coalesces the per-layer GEMMs
/// of all in-flight requests into single calls on exactly this
/// guarantee (asserted by `prepacked_m_rows_equal_m_single_rows`
/// below and end-to-end in `rust/tests/decode.rs`).
pub fn gemm_nt_prepacked(
    a: &[f32],
    pb: &PackedB,
    c: &mut [f32],
    m: usize,
    ep: Epilogue<'_>,
    workers: usize,
) {
    debug_assert_eq!(a.len(), m * pb.k);
    debug_assert_eq!(c.len(), m * pb.n);
    gemm_with_packed_b(a, c, m, pb.k, pb.n, 1.0, &pb.data, &pb.kc_strips, pb.nblk, ep, workers);
}

#[allow(clippy::too_many_arguments)]
fn gemm_packed(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    alpha: f32,
    b_is_nk: bool,
    ep: Epilogue<'_>,
    workers: usize,
) {
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    // Shared packed B: one panel set per KC strip, packed once on the
    // calling thread so every row-panel job reads identical data.
    let (bpack, kc_strips, nblk) = pack_b_full(b, k, n, b_is_nk);
    gemm_with_packed_b(a, c, m, k, n, alpha, &bpack, &kc_strips, nblk, ep, workers);
}

/// The row-panel fan-out over an already-packed B — shared by per-call
/// packing and [`PackedB`] reuse.
#[allow(clippy::too_many_arguments)]
fn gemm_with_packed_b(
    a: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    alpha: f32,
    bpack: &[f32],
    kc_strips: &[(usize, usize)],
    nblk: usize,
    ep: Epilogue<'_>,
    workers: usize,
) {
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let use_fma = fma_available();
    let workers = resolve_workers(workers, m, k, n);
    // Fixed MC-row jobs with disjoint C panels: job boundaries are a
    // function of the shape alone, so any worker count produces the
    // same bits. The write-set auditor asserts the panels really are
    // disjoint and cover C (debug/audit builds only).
    let ws = WriteSet::new("gemm C row panels", c.len());
    let mut jobs: Vec<(usize, &mut [f32])> = c.chunks_mut(MC * n).enumerate().collect();
    run_grid_mut(&mut jobs, workers, |_, job| {
        ws.claim(job.0, job.0 * MC * n, job.1.len());
        let i0 = job.0 * MC;
        let cblk: &mut [f32] = &mut *job.1;
        gemm_block(a, k, n, alpha, i0, cblk, bpack, kc_strips, nblk, ep, use_fma);
    });
    ws.verify();
}

/// Compute one MC-row panel of `C += alpha·A·op(B)` from the shared
/// packed B, applying `ep` to the register tile on the final KC strip.
#[allow(clippy::too_many_arguments)]
fn gemm_block(
    a: &[f32],
    k: usize,
    n: usize,
    alpha: f32,
    i0: usize,
    cblk: &mut [f32],
    bpack: &[f32],
    kc_strips: &[(usize, usize)],
    nblk: usize,
    ep: Epilogue<'_>,
    use_fma: bool,
) {
    let ml = cblk.len() / n;
    let rstrips = strips(ml, MR);
    let mut abuf = vec![0.0f32; rstrips.len() * MR * KC];
    let mut boff = 0usize;
    for (si, &(k0, kl)) in kc_strips.iter().enumerate() {
        // The epilogue belongs to the last KC strip only: earlier
        // strips hold partial sums that later strips still accumulate
        // onto.
        let last = si + 1 == kc_strips.len();
        for (rbi, &(r0, rl)) in rstrips.iter().enumerate() {
            pack_a_strip(
                a,
                k,
                i0 + r0,
                rl,
                k0,
                kl,
                alpha,
                &mut abuf[rbi * MR * KC..rbi * MR * KC + kl * MR],
            );
        }
        let bstrip = &bpack[boff..boff + kl * nblk * NR];
        for jb in 0..nblk {
            let j0 = jb * NR;
            let nl = NR.min(n - j0);
            let bp = &bstrip[jb * kl * NR..(jb + 1) * kl * NR];
            for (rbi, &(r0, rl)) in rstrips.iter().enumerate() {
                let ap = &abuf[rbi * MR * KC..rbi * MR * KC + kl * MR];
                // The tile is reloaded from C per KC strip, keeping a
                // single ascending-k accumulation chain per element.
                let mut acc = [[0.0f32; NR]; MR];
                for rr in 0..rl {
                    let crow = &cblk[(r0 + rr) * n + j0..(r0 + rr) * n + j0 + nl];
                    acc[rr][..nl].copy_from_slice(crow);
                }
                microkernel(use_fma, kl, ap, bp, &mut acc);
                for rr in 0..rl {
                    let arow = &mut acc[rr][..nl];
                    if last {
                        // Bias + activation on the accumulator while it
                        // is still hot: one pass over C total.
                        ep.apply(j0, arow);
                    }
                    let crow = &mut cblk[(r0 + rr) * n + j0..(r0 + rr) * n + j0 + nl];
                    crow.copy_from_slice(arow);
                }
            }
        }
        boff += kl * nblk * NR;
    }
}

/// `G += Xᵀ·X` restricted to the upper triangle (`X: [rows, h]`,
/// `G: [h, h]`) through the packed engine — the streamed Gram
/// accumulation kernel. Only upper-triangle entries of `G` are
/// written; sample order accumulates ascending, so batching and worker
/// count never change the bits.
pub fn syrk_upper_packed(x: &[f32], g: &mut [f32], rows: usize, h: usize, workers: usize) {
    debug_assert_eq!(x.len(), rows * h);
    debug_assert_eq!(g.len(), h * h);
    if rows == 0 || h == 0 {
        return;
    }
    let use_fma = fma_available();
    let (bpack, kc_strips, nblk) = pack_b_full(x, rows, h, false);
    let workers = resolve_workers(workers, h, rows, h);
    let bpack_ref = &bpack;
    let kc_ref = &kc_strips;
    // Each job owns one MC-row panel of G exclusively (it only writes
    // the panel's upper-triangle lanes, but no other job may touch the
    // panel at all) — claimed and verified like the GEMM fan-out.
    let ws = WriteSet::new("syrk G row panels", g.len());
    let mut jobs: Vec<(usize, &mut [f32])> = g.chunks_mut(MC * h).enumerate().collect();
    run_grid_mut(&mut jobs, workers, |_, job| {
        ws.claim(job.0, job.0 * MC * h, job.1.len());
        let i0 = job.0 * MC;
        let gblk: &mut [f32] = &mut *job.1;
        syrk_block(x, h, i0, gblk, bpack_ref, kc_ref, nblk, use_fma);
    });
    ws.verify();
}

/// One MC-row panel of the upper-triangular SYRK update.
fn syrk_block(
    x: &[f32],
    h: usize,
    i0: usize,
    gblk: &mut [f32],
    bpack: &[f32],
    kc_strips: &[(usize, usize)],
    nblk: usize,
    use_fma: bool,
) {
    let ml = gblk.len() / h;
    let rstrips = strips(ml, MR);
    let mut abuf = vec![0.0f32; rstrips.len() * MR * KC];
    let mut boff = 0usize;
    for &(k0, kl) in kc_strips {
        for (rbi, &(r0, rl)) in rstrips.iter().enumerate() {
            pack_a_strip_t(
                x,
                h,
                i0 + r0,
                rl,
                k0,
                kl,
                &mut abuf[rbi * MR * KC..rbi * MR * KC + kl * MR],
            );
        }
        let bstrip = &bpack[boff..boff + kl * nblk * NR];
        for jb in 0..nblk {
            let j0 = jb * NR;
            let nl = NR.min(h - j0);
            let bp = &bstrip[jb * kl * NR..(jb + 1) * kl * NR];
            for (rbi, &(r0, rl)) in rstrips.iter().enumerate() {
                let i_base = i0 + r0;
                // Tiles strictly below the diagonal contribute nothing
                // to the upper triangle of these rows.
                if j0 + nl <= i_base {
                    continue;
                }
                let ap = &abuf[rbi * MR * KC..rbi * MR * KC + kl * MR];
                let mut acc = [[0.0f32; NR]; MR];
                for rr in 0..rl {
                    let grow = &gblk[(r0 + rr) * h + j0..(r0 + rr) * h + j0 + nl];
                    acc[rr][..nl].copy_from_slice(grow);
                }
                microkernel(use_fma, kl, ap, bp, &mut acc);
                for rr in 0..rl {
                    let gi = i_base + rr;
                    // First tile column on/above the diagonal for this
                    // row; lower-triangle lanes are computed but never
                    // stored.
                    let lo = gi.saturating_sub(j0).min(nl);
                    let grow = &mut gblk[(r0 + rr) * h + j0 + lo..(r0 + rr) * h + j0 + nl];
                    grow.copy_from_slice(&acc[rr][lo..nl]);
                }
            }
        }
        boff += kl * nblk * NR;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strips_cover_range_in_order() {
        assert_eq!(strips(0, 4), vec![]);
        assert_eq!(strips(3, 4), vec![(0, 3)]);
        assert_eq!(strips(8, 4), vec![(0, 4), (4, 4)]);
        assert_eq!(strips(9, 4), vec![(0, 4), (4, 4), (8, 1)]);
        let s = strips(KC * 2 + 7, KC);
        assert_eq!(s.len(), 3);
        assert_eq!(s[2], (2 * KC, 7));
    }

    #[test]
    fn use_packed_respects_threshold() {
        // Note: the global switch itself is NOT toggled here — lib
        // tests share one process, and flipping it would silently
        // reroute concurrently running dispatch tests to the scalar
        // path. The switch is exercised by `benches/hotpath.rs`
        // (single-threaded main), which toggles it around the
        // end-to-end pipeline comparison.
        assert!(packed_enabled(), "packed dispatch is on by default");
        assert!(!use_packed(0, 8, 8));
        assert!(!use_packed(4, 4, 4), "tiny shapes stay on the scalar path");
        assert!(use_packed(128, 128, 128));
    }

    #[test]
    fn microkernel_matches_naive_tile() {
        // One packed strip, exact integer values: the kernel must equal
        // the naive tile product bit-for-bit.
        let kl = 5usize;
        let ap: Vec<f32> = (0..kl * MR).map(|i| (i % 7) as f32 - 3.0).collect();
        let bp: Vec<f32> = (0..kl * NR).map(|i| (i % 5) as f32 - 2.0).collect();
        let mut acc = [[1.0f32; NR]; MR];
        microkernel(fma_available(), kl, &ap, &bp, &mut acc);
        for r in 0..MR {
            for j in 0..NR {
                let mut want = 1.0f32;
                for p in 0..kl {
                    want += ap[p * MR + r] * bp[p * NR + j];
                }
                assert_eq!(acc[r][j], want, "tile ({r},{j})");
            }
        }
    }

    #[test]
    fn use_packed_cols_is_row_count_free() {
        assert!(packed_enabled());
        assert!(!use_packed_cols(0, 4096), "empty k stays scalar");
        assert!(!use_packed_cols(4096, 0), "empty n stays scalar");
        assert!(!use_packed_cols(8, 64), "8·64 = 512 < {PACKED_MIN_COLS}");
        assert!(use_packed_cols(64, 64), "64·64 = 4096 ≥ {PACKED_MIN_COLS}");
        assert!(use_packed_cols(PACKED_MIN_COLS, 1));
        // The whole point: the rule has no m argument, so decode (m=1)
        // and batch forward (m=t) agree by construction.
    }

    #[test]
    fn epilogue_apply_matches_unfused_ops() {
        let bias: Vec<f32> = (0..8).map(|i| 0.25 * i as f32 - 1.0).collect();
        let vals: Vec<f32> = (0..6).map(|i| 0.7 * i as f32 - 2.0).collect();
        // Bias at a column offset.
        let mut r = vals.clone();
        Epilogue::Bias(&bias).apply(2, &mut r);
        for (i, v) in r.iter().enumerate() {
            assert_eq!(v.to_bits(), (vals[i] + bias[2 + i]).to_bits());
        }
        // BiasRelu == add then clamp.
        let mut r = vals.clone();
        Epilogue::BiasRelu(&bias).apply(0, &mut r);
        for (i, v) in r.iter().enumerate() {
            assert_eq!(v.to_bits(), (vals[i] + bias[i]).max(0.0).to_bits());
        }
        // BiasGelu == add then the shared scalar gelu.
        let mut r = vals.clone();
        Epilogue::BiasGelu(&bias).apply(0, &mut r);
        for (i, v) in r.iter().enumerate() {
            assert_eq!(v.to_bits(), crate::nn::gelu_scalar(vals[i] + bias[i]).to_bits());
        }
        // None is the identity.
        let mut r = vals.clone();
        Epilogue::None.apply(3, &mut r);
        assert_eq!(r, vals);
    }

    #[test]
    fn prepacked_m_rows_equal_m_single_rows() {
        // The coalescing contract behind continuous batching
        // (`serve::batch`): one m-row prepacked GEMM must be bitwise
        // equal to m separate 1-row calls against the same pack, for
        // every epilogue. Shapes straddle packing block edges, and the
        // fan-out path is exercised with several worker counts.
        let (m, k, n) = (7usize, KC + 3, NR + 5);
        let a: Vec<f32> = (0..m * k).map(|i| ((i * 29 % 23) as f32) * 0.37 - 4.0).collect();
        let b: Vec<f32> = (0..n * k).map(|i| ((i * 41 % 17) as f32) * 0.21 - 2.0).collect();
        let bias: Vec<f32> = (0..n).map(|i| 0.11 * i as f32 - 0.6).collect();
        let pb = PackedB::pack_nt(&b, k, n);
        let eps: [Epilogue<'_>; 4] = [
            Epilogue::None,
            Epilogue::Bias(&bias),
            Epilogue::BiasRelu(&bias),
            Epilogue::BiasGelu(&bias),
        ];
        for ep in eps {
            let mut solo = vec![0.0f32; m * n];
            for r in 0..m {
                gemm_nt_prepacked(&a[r * k..(r + 1) * k], &pb, &mut solo[r * n..(r + 1) * n], 1, ep, 1);
            }
            for workers in [1usize, 2, 4] {
                let mut coalesced = vec![0.0f32; m * n];
                gemm_nt_prepacked(&a, &pb, &mut coalesced, m, ep, workers);
                for (i, (x, y)) in coalesced.iter().zip(&solo).enumerate() {
                    assert_eq!(x.to_bits(), y.to_bits(), "elem {i} workers={workers}");
                }
            }
        }
    }

    #[test]
    fn prepacked_b_matches_per_call_packing() {
        let (k, n) = (KC + 5, NR + 3);
        let b: Vec<f32> = (0..n * k).map(|i| ((i * 37 % 19) as f32) - 9.0).collect();
        let pb = PackedB::pack_nt(&b, k, n);
        assert_eq!(pb.k(), k);
        assert_eq!(pb.n(), n);
        let (direct, kc_strips, nblk) = pack_b_full(&b, k, n, true);
        assert_eq!(pb.kc_strips, kc_strips);
        assert_eq!(pb.nblk, nblk);
        assert_eq!(pb.data, direct, "PackedB must reuse the per-call packing");
    }

    #[test]
    fn resolve_workers_clamps_to_blocks() {
        // Explicit worker counts are honoured but never exceed jobs.
        assert_eq!(resolve_workers(8, MC, 1024, 1024), 1, "one block, one worker");
        assert_eq!(resolve_workers(3, 4 * MC, 1024, 1024), 3);
        // Tiny auto shapes stay serial.
        assert_eq!(resolve_workers(0, 4 * MC, 4, 4), 1);
    }
}
