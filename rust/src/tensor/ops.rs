//! Linear-algebra kernels over [`Tensor`].
//!
//! These are the Rust-side hot paths: compressed-model evaluation and
//! all GRAIL algebra (Gram accumulation, reducer application, weight
//! merges) run through the GEMM/SYRK routines here. Shapes above
//! [`gemm::PACKED_MIN_FLOPS`] dispatch to the packed, cache-blocked,
//! register-tiled engine in [`super::gemm`] (parallel row panels,
//! bit-identical at any worker count); smaller shapes use the scalar
//! loops, which also survive as the `*_ref` oracles the packed engine
//! is property-tested against (`rust/tests/gemm_engine.rs`). The
//! *serving* entries ([`gemm_nt_serve`] / [`gemm_nn_serve`]) dispatch
//! on the row-count-free `k·n` rule ([`gemm::use_packed_cols`]) and
//! carry a fused [`gemm::Epilogue`], so single-row decode steps pick
//! the same kernel as multi-row forwards and stay bit-identical to
//! them. No kernel has a data-dependent branch: `0·NaN` / `0·∞`
//! propagate as NaN by construction. See EXPERIMENTS.md §Perf and
//! §Serving for measurements.

use super::{gemm, Tensor};

/// `C = A · B` for `A: [m,k]`, `B: [k,n]`.
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.dim(0), a.dim(1));
    let (k2, n) = (b.dim(0), b.dim(1));
    assert_eq!(k, k2, "matmul inner dims: {:?} x {:?}", a.shape(), b.shape());
    let mut c = Tensor::zeros(&[m, n]);
    gemm_acc(a.data(), b.data(), c.data_mut(), m, k, n, 1.0);
    c
}

/// `C += alpha * A · B` on raw row-major buffers. Large shapes run the
/// packed engine ([`gemm::gemm_nn_packed`]); small ones the scalar
/// reference ([`gemm_acc_ref`]).
pub fn gemm_acc(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize, alpha: f32) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    if gemm::use_packed(m, k, n) {
        gemm::gemm_nn_packed(a, b, c, m, k, n, alpha, 0);
    } else {
        gemm_acc_ref(a, b, c, m, k, n, alpha);
    }
}

/// Scalar `C += alpha · A · B` (ikj loop order: the inner `j` loop is a
/// contiguous axpy over a row of B and C) — the small-shape path and
/// the packed engine's test oracle. Every product is computed, so
/// `0·NaN` / `0·∞` propagate exactly like the packed path.
pub fn gemm_acc_ref(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize, alpha: f32) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    for i in 0..m {
        let a_row = &a[i * k..(i + 1) * k];
        let c_row = &mut c[i * n..(i + 1) * n];
        for (p, &a_ip) in a_row.iter().enumerate() {
            let s = alpha * a_ip;
            let b_row = &b[p * n..(p + 1) * n];
            for (cv, &bv) in c_row.iter_mut().zip(b_row) {
                *cv += s * bv;
            }
        }
    }
}

/// `C += alpha · A · B` in f64 over strided row-major views — the
/// precision the SPD solves run at. Same ikj loop order as [`gemm_acc`]
/// (inner loop is a contiguous axpy over rows of B and C); the explicit
/// leading dimensions (`lda`/`ldb`/`ldc` ≥ the logical row width) let
/// the blocked Cholesky engine ([`crate::linalg::BlockedCholesky`])
/// address panels inside a larger factor buffer without packing copies.
/// No sparse fast path: factor panels are dense.
pub fn gemm_acc_f64(
    a: &[f64],
    lda: usize,
    b: &[f64],
    ldb: usize,
    c: &mut [f64],
    ldc: usize,
    m: usize,
    k: usize,
    n: usize,
    alpha: f64,
) {
    debug_assert!(m == 0 || (lda >= k && a.len() >= (m - 1) * lda + k));
    debug_assert!(k == 0 || (ldb >= n && b.len() >= (k - 1) * ldb + n));
    debug_assert!(m == 0 || (ldc >= n && c.len() >= (m - 1) * ldc + n));
    for i in 0..m {
        let a_row = &a[i * lda..i * lda + k];
        let c_row = &mut c[i * ldc..i * ldc + n];
        for (p, &a_ip) in a_row.iter().enumerate() {
            let s = alpha * a_ip;
            let b_row = &b[p * ldb..p * ldb + n];
            for (cv, &bv) in c_row.iter_mut().zip(b_row) {
                *cv += s * bv;
            }
        }
    }
}

/// `C += alpha · A · Bᵀ` in f64 over strided row-major views (`A:
/// [m,k]`, `B: [n,k]`, `C: [m,n]`) — the f64/strided sibling of
/// [`gemm_nt_acc`], with the same row-dot inner loop via [`dot_f64`].
/// This is the trailing-update (SYRK-shaped) kernel of the blocked
/// Cholesky: both operands are panels of the factor, traversed row-wise.
pub fn gemm_nt_acc_f64(
    a: &[f64],
    lda: usize,
    b: &[f64],
    ldb: usize,
    c: &mut [f64],
    ldc: usize,
    m: usize,
    k: usize,
    n: usize,
    alpha: f64,
) {
    debug_assert!(m == 0 || (lda >= k && a.len() >= (m - 1) * lda + k));
    debug_assert!(n == 0 || (ldb >= k && b.len() >= (n - 1) * ldb + k));
    debug_assert!(m == 0 || (ldc >= n && c.len() >= (m - 1) * ldc + n));
    for i in 0..m {
        let a_row = &a[i * lda..i * lda + k];
        let c_row = &mut c[i * ldc..i * ldc + n];
        for (j, cv) in c_row.iter_mut().enumerate() {
            let b_row = &b[j * ldb..j * ldb + k];
            *cv += alpha * dot_f64(a_row, b_row);
        }
    }
}

/// `C += alpha · Aᵀ · B` in f64 over strided row-major views (`A:
/// [k,m]`, `B: [k,n]`, `C: [m,n]`). Outer loop walks the shared `k`
/// dimension so every inner access — the coefficient row of A and the
/// axpy rows of B and C — stays contiguous; the blocked back
/// substitution uses this to apply `Lᵀ` panels without materializing a
/// transpose.
pub fn gemm_tn_acc_f64(
    a: &[f64],
    lda: usize,
    b: &[f64],
    ldb: usize,
    c: &mut [f64],
    ldc: usize,
    m: usize,
    k: usize,
    n: usize,
    alpha: f64,
) {
    debug_assert!(k == 0 || (lda >= m && a.len() >= (k - 1) * lda + m));
    debug_assert!(k == 0 || (ldb >= n && b.len() >= (k - 1) * ldb + n));
    debug_assert!(m == 0 || (ldc >= n && c.len() >= (m - 1) * ldc + n));
    for p in 0..k {
        let a_row = &a[p * lda..p * lda + m];
        let b_row = &b[p * ldb..p * ldb + n];
        for (i, &a_pi) in a_row.iter().enumerate() {
            let s = alpha * a_pi;
            let c_row = &mut c[i * ldc..i * ldc + n];
            for (cv, &bv) in c_row.iter_mut().zip(b_row) {
                *cv += s * bv;
            }
        }
    }
}

/// f64 dot product with 4 independent accumulators (same pipelining
/// trick as [`dot`]).
#[inline]
pub fn dot_f64(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let mut acc = [0.0f64; 4];
    let chunks = x.len() / 4;
    for c in 0..chunks {
        let xi = &x[c * 4..c * 4 + 4];
        let yi = &y[c * 4..c * 4 + 4];
        acc[0] += xi[0] * yi[0];
        acc[1] += xi[1] * yi[1];
        acc[2] += xi[2] * yi[2];
        acc[3] += xi[3] * yi[3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..x.len() {
        s += x[i] * y[i];
    }
    s
}

/// `C = A · Bᵀ` for `A: [m,k]`, `B: [n,k]` — both operands traversed
/// row-wise, so this is the preferred layout for linear layers
/// (`y = x Wᵀ`).
pub fn matmul_nt(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.dim(0), a.dim(1));
    let (n, k2) = (b.dim(0), b.dim(1));
    assert_eq!(k, k2, "matmul_nt inner dims: {:?} x {:?}ᵀ", a.shape(), b.shape());
    let mut c = Tensor::zeros(&[m, n]);
    gemm_nt_acc(a.data(), b.data(), c.data_mut(), m, k, n);
    c
}

/// `C += A · Bᵀ` on raw buffers. Large shapes run the packed engine
/// ([`gemm::gemm_nt_packed`], which transposes B while packing); small
/// ones the scalar reference ([`gemm_nt_acc_ref`]).
pub fn gemm_nt_acc(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    if gemm::use_packed(m, k, n) {
        gemm::gemm_nt_packed(a, b, c, m, k, n, 0);
    } else {
        gemm_nt_acc_ref(a, b, c, m, k, n);
    }
}

/// `C += A · Bᵀ` with a fused [`gemm::Epilogue`] under the **serving
/// dispatch**: packed iff [`gemm::use_packed_cols`] says the `k·n`
/// weight volume warrants it. Unlike the flop rule in [`gemm_nt_acc`],
/// this rule never looks at the row count `m`, so a 1-row KV-cache
/// decode step takes the same kernel — and produces the same bits — as
/// the multi-row forward it must match. The scalar fallback applies
/// the epilogue as a per-row sweep *after* [`gemm_nt_acc_ref`], via
/// the same [`gemm::Epilogue::apply`] the packed tile uses, so fused
/// and unfused agree to the bit on either side of the threshold.
pub fn gemm_nt_serve(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    ep: gemm::Epilogue<'_>,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    if gemm::use_packed_cols(k, n) {
        gemm::gemm_nt_packed_ep(a, b, c, m, k, n, ep, 0);
    } else {
        gemm_nt_acc_ref(a, b, c, m, k, n);
        for row in c.chunks_mut(n.max(1)) {
            ep.apply(0, row);
        }
    }
}

/// `C += A · B` under the serving dispatch (row-count-invariant, see
/// [`gemm_nt_serve`]) — the attention context product's entry.
pub fn gemm_nn_serve(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    if gemm::use_packed_cols(k, n) {
        gemm::gemm_nn_packed(a, b, c, m, k, n, 1.0, 0);
    } else {
        gemm_acc_ref(a, b, c, m, k, n, 1.0);
    }
}

/// Scalar `C += A · Bᵀ`: the inner loop is a dot of two contiguous
/// rows, unrolled 4-wide into independent accumulators — the
/// small-shape path and the packed engine's test oracle.
pub fn gemm_nt_acc_ref(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    for i in 0..m {
        let a_row = &a[i * k..(i + 1) * k];
        let c_row = &mut c[i * n..(i + 1) * n];
        for j in 0..n {
            let b_row = &b[j * k..(j + 1) * k];
            c_row[j] += dot(a_row, b_row);
        }
    }
}

/// Dot product with 4 independent accumulators (keeps the FMA pipeline
/// busy; LLVM vectorizes the chunks).
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let mut acc = [0.0f32; 4];
    let chunks = x.len() / 4;
    for c in 0..chunks {
        let xi = &x[c * 4..c * 4 + 4];
        let yi = &y[c * 4..c * 4 + 4];
        acc[0] += xi[0] * yi[0];
        acc[1] += xi[1] * yi[1];
        acc[2] += xi[2] * yi[2];
        acc[3] += xi[3] * yi[3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..x.len() {
        s += x[i] * y[i];
    }
    s
}

/// `G += Xᵀ·X` for `X: [n,h]` — the Gram accumulation kernel (paper §3:
/// `G = Σ x xᵀ`). Only the upper triangle is written; the mirror is
/// filled at the end by [`symmetrize_from_upper`]. Callers stream
/// batches through this and symmetrize once. Large shapes run the
/// packed SYRK ([`gemm::syrk_upper_packed`]); small ones the scalar
/// reference. Neither path has a data-dependent branch — post-ReLU
/// zero-heavy shards cost exactly what dense shards cost, and `0·NaN`
/// / `0·∞` cross terms propagate (the old zero-skip re-scanned the
/// whole buffer for finiteness on every zero-bearing call).
pub fn syrk_upper_acc(x: &Tensor, g: &mut Tensor) {
    let (n, h) = (x.dim(0), x.dim(1));
    assert_eq!(g.shape(), &[h, h], "gram shape");
    if gemm::use_packed(h, n, h) {
        gemm::syrk_upper_packed(x.data(), g.data_mut(), n, h, 0);
    } else {
        syrk_upper_acc_ref(x, g);
    }
}

/// Scalar upper-triangular SYRK: each sample row performs a rank-1
/// update over the upper triangle — the small-shape path and the
/// packed engine's test oracle.
pub fn syrk_upper_acc_ref(x: &Tensor, g: &mut Tensor) {
    let (n, h) = (x.dim(0), x.dim(1));
    assert_eq!(g.shape(), &[h, h], "gram shape");
    let xd = x.data();
    let gd = g.data_mut();
    for s in 0..n {
        let row = &xd[s * h..(s + 1) * h];
        for i in 0..h {
            let xi = row[i];
            let g_row = &mut gd[i * h + i..(i + 1) * h];
            let r = &row[i..];
            for (gv, &xv) in g_row.iter_mut().zip(r) {
                *gv += xi * xv;
            }
        }
    }
}

/// Copy the upper triangle onto the lower one, making `G` symmetric.
pub fn symmetrize_from_upper(g: &mut Tensor) {
    let h = g.dim(0);
    assert_eq!(g.dim(1), h);
    let gd = g.data_mut();
    for i in 0..h {
        for j in (i + 1)..h {
            gd[j * h + i] = gd[i * h + j];
        }
    }
}

/// Full Gram matrix `Xᵀ·X` of a batch (convenience over
/// [`syrk_upper_acc`] + [`symmetrize_from_upper`]).
pub fn gram(x: &Tensor) -> Tensor {
    let h = x.dim(1);
    let mut g = Tensor::zeros(&[h, h]);
    syrk_upper_acc(x, &mut g);
    symmetrize_from_upper(&mut g);
    g
}

/// Transpose a 2-D tensor.
pub fn transpose(a: &Tensor) -> Tensor {
    let (m, n) = (a.dim(0), a.dim(1));
    let mut t = Tensor::zeros(&[n, m]);
    // Blocked to keep both sides cache-resident.
    const B: usize = 32;
    let ad = a.data();
    let td = t.data_mut();
    for ib in (0..m).step_by(B) {
        for jb in (0..n).step_by(B) {
            for i in ib..(ib + B).min(m) {
                for j in jb..(jb + B).min(n) {
                    td[j * m + i] = ad[i * n + j];
                }
            }
        }
    }
    t
}

/// Gather columns of a 2-D tensor: `out[:, k] = a[:, idx[k]]`.
pub fn gather_cols(a: &Tensor, idx: &[usize]) -> Tensor {
    let (m, n) = (a.dim(0), a.dim(1));
    let k = idx.len();
    for &j in idx {
        assert!(j < n, "gather_cols index {j} out of {n}");
    }
    let mut out = Tensor::zeros(&[m, k]);
    for i in 0..m {
        let src = a.row(i);
        let dst = out.row_mut(i);
        for (d, &j) in dst.iter_mut().zip(idx) {
            *d = src[j];
        }
    }
    out
}

/// Gather rows of a 2-D tensor: `out[k, :] = a[idx[k], :]`.
pub fn gather_rows(a: &Tensor, idx: &[usize]) -> Tensor {
    let n = a.dim(1);
    let mut out = Tensor::zeros(&[idx.len(), n]);
    for (k, &i) in idx.iter().enumerate() {
        assert!(i < a.dim(0), "gather_rows index {i} out of {}", a.dim(0));
        out.row_mut(k).copy_from_slice(a.row(i));
    }
    out
}

/// Balanced contiguous chunking of `n` items into at most `max_shards`
/// non-empty `(start, len)` ranges covering `0..n` in order — the
/// shared sharding arithmetic behind [`split_rows`] and the model
/// families' `Compressible::split_input` impls.
pub fn shard_ranges(n: usize, max_shards: usize) -> Vec<(usize, usize)> {
    let shards = max_shards.clamp(1, n.max(1));
    let base = n / shards;
    let rem = n % shards;
    let mut out = Vec::with_capacity(shards);
    let mut start = 0usize;
    for i in 0..shards {
        let len = base + usize::from(i < rem);
        if len == 0 {
            continue;
        }
        out.push((start, len));
        start += len;
    }
    out
}

/// Split a 2-D tensor into at most `max_shards` contiguous row chunks
/// (each non-empty, sizes as balanced as possible, concatenation order
/// preserved) — the calibration-sharding primitive of the segment
/// executor.
pub fn split_rows(x: &Tensor, max_shards: usize) -> Vec<Tensor> {
    let d = x.dim(1);
    shard_ranges(x.dim(0), max_shards)
        .into_iter()
        .map(|(start, len)| {
            Tensor::from_vec(&[len, d], x.data()[start * d..(start + len) * d].to_vec())
        })
        .collect()
}

/// Elementwise `a + b`.
pub fn add(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.shape(), b.shape());
    let mut out = a.clone();
    for (o, &v) in out.data_mut().iter_mut().zip(b.data()) {
        *o += v;
    }
    out
}

/// In-place `a += alpha * b`.
pub fn axpy(a: &mut Tensor, alpha: f32, b: &Tensor) {
    assert_eq!(a.shape(), b.shape());
    for (o, &v) in a.data_mut().iter_mut().zip(b.data()) {
        *o += alpha * v;
    }
}

/// Add a bias row vector to every row of a 2-D tensor, in place.
pub fn add_bias(a: &mut Tensor, bias: &[f32]) {
    let (m, n) = (a.dim(0), a.dim(1));
    assert_eq!(bias.len(), n, "bias length");
    for i in 0..m {
        for (v, &b) in a.row_mut(i).iter_mut().zip(bias) {
            *v += b;
        }
    }
}

/// Column-wise mean of a 2-D tensor.
pub fn col_mean(a: &Tensor) -> Vec<f32> {
    let (m, n) = (a.dim(0), a.dim(1));
    let mut mu = vec![0.0f64; n];
    for i in 0..m {
        for (s, &v) in mu.iter_mut().zip(a.row(i)) {
            *s += v as f64;
        }
    }
    mu.iter().map(|s| (*s / m.max(1) as f64) as f32).collect()
}

/// Per-column L2 norm of a 2-D tensor.
pub fn col_l2(a: &Tensor) -> Vec<f32> {
    let (m, n) = (a.dim(0), a.dim(1));
    let mut acc = vec![0.0f64; n];
    for i in 0..m {
        for (s, &v) in acc.iter_mut().zip(a.row(i)) {
            *s += (v as f64) * (v as f64);
        }
    }
    acc.iter().map(|s| s.sqrt() as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn randn(r: &mut Pcg64, shape: &[usize]) -> Tensor {
        let mut t = Tensor::zeros(shape);
        r.fill_normal(t.data_mut(), 1.0);
        t
    }

    /// O(mnk) reference matmul for cross-checking the kernels.
    fn matmul_ref(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k, n) = (a.dim(0), a.dim(1), b.dim(1));
        let mut c = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0f64;
                for p in 0..k {
                    s += (a.at2(i, p) as f64) * (b.at2(p, j) as f64);
                }
                c.set2(i, j, s as f32);
            }
        }
        c
    }

    #[test]
    fn matmul_small_exact() {
        let a = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let b = Tensor::from_vec(&[3, 2], vec![7., 8., 9., 10., 11., 12.]);
        let c = matmul(&a, &b);
        assert_eq!(c.data(), &[58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_matches_reference_random() {
        let mut r = Pcg64::seed(1);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 2), (17, 9, 13), (32, 64, 8)] {
            let a = randn(&mut r, &[m, k]);
            let b = randn(&mut r, &[k, n]);
            let c = matmul(&a, &b);
            let cr = matmul_ref(&a, &b);
            assert!(c.max_abs_diff(&cr) < 1e-3, "({m},{k},{n})");
        }
    }

    #[test]
    fn matmul_nt_matches_transpose_path() {
        let mut r = Pcg64::seed(2);
        let a = randn(&mut r, &[7, 11]);
        let b = randn(&mut r, &[5, 11]);
        let c1 = matmul_nt(&a, &b);
        let c2 = matmul(&a, &transpose(&b));
        assert!(c1.max_abs_diff(&c2) < 1e-4);
    }

    #[test]
    fn gram_matches_explicit() {
        let mut r = Pcg64::seed(3);
        let x = randn(&mut r, &[20, 9]);
        let g = gram(&x);
        let gr = matmul(&transpose(&x), &x);
        assert!(g.max_abs_diff(&gr) < 1e-3);
        // Symmetry.
        for i in 0..9 {
            for j in 0..9 {
                assert!((g.at2(i, j) - g.at2(j, i)).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn syrk_accumulates_across_batches() {
        let mut r = Pcg64::seed(4);
        let x1 = randn(&mut r, &[8, 6]);
        let x2 = randn(&mut r, &[5, 6]);
        let mut g = Tensor::zeros(&[6, 6]);
        syrk_upper_acc(&x1, &mut g);
        syrk_upper_acc(&x2, &mut g);
        symmetrize_from_upper(&mut g);
        // Equals gram of the concatenated batch.
        let mut all = Tensor::zeros(&[13, 6]);
        all.data_mut()[..48].copy_from_slice(x1.data());
        all.data_mut()[48..].copy_from_slice(x2.data());
        assert!(g.max_abs_diff(&gram(&all)) < 1e-4);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut r = Pcg64::seed(5);
        let a = randn(&mut r, &[37, 19]);
        let t = transpose(&transpose(&a));
        assert_eq!(a, t);
    }

    #[test]
    fn gather_cols_selects() {
        let a = Tensor::from_vec(&[2, 4], vec![0., 1., 2., 3., 10., 11., 12., 13.]);
        let g = gather_cols(&a, &[3, 1]);
        assert_eq!(g.shape(), &[2, 2]);
        assert_eq!(g.data(), &[3., 1., 13., 11.]);
    }

    #[test]
    fn gather_rows_selects() {
        let a = Tensor::from_vec(&[3, 2], vec![0., 1., 10., 11., 20., 21.]);
        let g = gather_rows(&a, &[2, 0]);
        assert_eq!(g.data(), &[20., 21., 0., 1.]);
    }

    #[test]
    fn bias_and_stats() {
        let mut a = Tensor::from_vec(&[2, 2], vec![1., 2., 3., 4.]);
        add_bias(&mut a, &[10., 20.]);
        assert_eq!(a.data(), &[11., 22., 13., 24.]);
        let mu = col_mean(&a);
        assert_eq!(mu, vec![12., 23.]);
        let l2 = col_l2(&Tensor::from_vec(&[2, 1], vec![3., 4.]));
        assert!((l2[0] - 5.0).abs() < 1e-6);
    }

    #[test]
    fn gemm_zero_times_nonfinite_propagates() {
        // 0·NaN and 0·∞ must be NaN — every kernel path computes every
        // product (no data-dependent skip exists to get this wrong).
        let a = Tensor::from_vec(&[1, 2], vec![0.0, 1.0]);
        let b = Tensor::from_vec(&[2, 2], vec![f32::NAN, 1.0, 2.0, 3.0]);
        let c = matmul(&a, &b);
        assert!(c.at2(0, 0).is_nan(), "0·NaN + 1·2 must be NaN");
        assert_eq!(c.at2(0, 1), 3.0); // 0·1 + 1·3: finite column unaffected
        let b_inf = Tensor::from_vec(&[2, 2], vec![f32::INFINITY, 1.0, 2.0, 3.0]);
        let c = matmul(&a, &b_inf);
        assert!(c.at2(0, 0).is_nan(), "0·∞ + 1·2 must be NaN");
    }

    #[test]
    fn gemm_zero_entries_match_reference() {
        let mut r = Pcg64::seed(40);
        let mut a = randn(&mut r, &[5, 7]);
        // Exact zeros in A must behave like any other value.
        for i in 0..5 {
            a.set2(i, i % 7, 0.0);
        }
        let b = randn(&mut r, &[7, 4]);
        let c = matmul(&a, &b);
        let cr = matmul_ref(&a, &b);
        assert!(c.max_abs_diff(&cr) < 1e-4);
    }

    #[test]
    fn dispatching_entries_agree_with_refs_above_threshold() {
        // A shape comfortably above `gemm::PACKED_MIN_FLOPS`: the
        // dispatching entries take the packed engine and must agree
        // with the scalar oracles to rounding.
        let mut r = Pcg64::seed(41);
        let (m, k, n) = (96usize, 80usize, 72usize);
        let a = randn(&mut r, &[m, k]);
        let b = randn(&mut r, &[k, n]);
        let mut c1 = vec![0.0f32; m * n];
        let mut c2 = vec![0.0f32; m * n];
        gemm_acc(a.data(), b.data(), &mut c1, m, k, n, 1.0);
        gemm_acc_ref(a.data(), b.data(), &mut c2, m, k, n, 1.0);
        for (x, y) in c1.iter().zip(&c2) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }
        let bt = randn(&mut r, &[n, k]);
        let mut c1 = vec![0.0f32; m * n];
        let mut c2 = vec![0.0f32; m * n];
        gemm_nt_acc(a.data(), bt.data(), &mut c1, m, k, n);
        gemm_nt_acc_ref(a.data(), bt.data(), &mut c2, m, k, n);
        for (x, y) in c1.iter().zip(&c2) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }
        let x = randn(&mut r, &[512, 64]);
        let mut g1 = Tensor::zeros(&[64, 64]);
        let mut g2 = Tensor::zeros(&[64, 64]);
        syrk_upper_acc(&x, &mut g1);
        syrk_upper_acc_ref(&x, &mut g2);
        assert!(g1.max_abs_diff(&g2) < 1e-2);
    }

    #[test]
    fn syrk_zero_times_nonfinite_propagates() {
        let x = Tensor::from_vec(&[1, 2], vec![0.0, f32::NAN]);
        let mut g = Tensor::zeros(&[2, 2]);
        syrk_upper_acc(&x, &mut g);
        assert!(g.at2(0, 1).is_nan(), "0·NaN cross term must be NaN");
        assert!(g.at2(1, 1).is_nan());
        assert_eq!(g.at2(0, 0), 0.0); // 0·0 stays 0
    }

    #[test]
    fn split_rows_partitions() {
        let x = Tensor::from_vec(&[5, 2], (0..10).map(|i| i as f32).collect());
        let parts = split_rows(&x, 3);
        assert_eq!(parts.len(), 3);
        assert_eq!(parts[0].shape(), &[2, 2]);
        assert_eq!(parts[1].shape(), &[2, 2]);
        assert_eq!(parts[2].shape(), &[1, 2]);
        let rejoined: Vec<f32> =
            parts.iter().flat_map(|p| p.data().iter().copied()).collect();
        assert_eq!(rejoined, x.data());
        // More shards than rows clamps to one row each.
        assert_eq!(split_rows(&x, 99).len(), 5);
        assert_eq!(split_rows(&x, 1).len(), 1);
    }

    /// Naive strided f64 reference: C += alpha·op(A)·op(B).
    fn gemm_ref_f64(
        a: &[f64],
        lda: usize,
        b: &[f64],
        ldb: usize,
        c: &mut [f64],
        ldc: usize,
        m: usize,
        k: usize,
        n: usize,
        alpha: f64,
        ta: bool,
        tb: bool,
    ) {
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for p in 0..k {
                    let av = if ta { a[p * lda + i] } else { a[i * lda + p] };
                    let bv = if tb { b[j * ldb + p] } else { b[p * ldb + j] };
                    s += av * bv;
                }
                c[i * ldc + j] += alpha * s;
            }
        }
    }

    #[test]
    fn f64_kernels_match_reference_strided() {
        let mut r = Pcg64::seed(50);
        // Deliberately over-wide leading dimensions to exercise strides.
        let (m, k, n) = (7usize, 5usize, 6usize);
        let (lda, ldb, ldc) = (k + 3, n + 2, n + 4);
        let mk: Vec<f64> = (0..m * lda).map(|_| r.normal() as f64).collect();
        let kn: Vec<f64> = (0..k * ldb).map(|_| r.normal() as f64).collect();
        let mut c1 = vec![0.1f64; m * ldc];
        let mut c2 = c1.clone();
        gemm_acc_f64(&mk, lda, &kn, ldb, &mut c1, ldc, m, k, n, 0.7);
        gemm_ref_f64(&mk, lda, &kn, ldb, &mut c2, ldc, m, k, n, 0.7, false, false);
        for (x, y) in c1.iter().zip(&c2) {
            assert!((x - y).abs() < 1e-12, "{x} vs {y}");
        }

        // nt: B is [n, k] with stride ldb >= k.
        let ldb_nt = k + 1;
        let nk: Vec<f64> = (0..n * ldb_nt).map(|_| r.normal() as f64).collect();
        let mut c1 = vec![-0.3f64; m * ldc];
        let mut c2 = c1.clone();
        gemm_nt_acc_f64(&mk, lda, &nk, ldb_nt, &mut c1, ldc, m, k, n, -1.0);
        gemm_ref_f64(&mk, lda, &nk, ldb_nt, &mut c2, ldc, m, k, n, -1.0, false, true);
        for (x, y) in c1.iter().zip(&c2) {
            assert!((x - y).abs() < 1e-12, "{x} vs {y}");
        }

        // tn: A is [k, m] with stride lda >= m.
        let lda_tn = m + 2;
        let km: Vec<f64> = (0..k * lda_tn).map(|_| r.normal() as f64).collect();
        let mut c1 = vec![0.0f64; m * ldc];
        let mut c2 = c1.clone();
        gemm_tn_acc_f64(&km, lda_tn, &kn, ldb, &mut c1, ldc, m, k, n, 2.5);
        gemm_ref_f64(&km, lda_tn, &kn, ldb, &mut c2, ldc, m, k, n, 2.5, true, false);
        for (x, y) in c1.iter().zip(&c2) {
            assert!((x - y).abs() < 1e-12, "{x} vs {y}");
        }
    }

    #[test]
    fn f64_kernels_degenerate_dims() {
        // Zero-sized m/k/n must be no-ops, not panics.
        let a = [1.0f64; 4];
        let b = [2.0f64; 4];
        let mut c = [3.0f64; 4];
        gemm_acc_f64(&a, 2, &b, 2, &mut c, 2, 0, 2, 2, 1.0);
        gemm_acc_f64(&a, 2, &b, 2, &mut c, 2, 2, 0, 2, 1.0);
        gemm_nt_acc_f64(&a, 2, &b, 2, &mut c, 2, 2, 2, 0, 1.0);
        gemm_tn_acc_f64(&a, 2, &b, 2, &mut c, 2, 2, 0, 2, 1.0);
        assert_eq!(c, [3.0; 4]);
        // k=1 single-column panels (the K=1 solver edge).
        let mut c = [0.0f64; 1];
        gemm_nt_acc_f64(&[2.0], 1, &[3.0], 1, &mut c, 1, 1, 1, 1, 1.0);
        assert_eq!(c, [6.0]);
    }

    #[test]
    fn dot_f64_matches_scalar() {
        for n in 0..9 {
            let x: Vec<f64> = (0..n).map(|i| i as f64).collect();
            let y: Vec<f64> = (0..n).map(|i| (i * 2) as f64).collect();
            let want: f64 = (0..n).map(|i| (i * i * 2) as f64).sum();
            assert_eq!(dot_f64(&x, &y), want, "n={n}");
        }
    }

    #[test]
    fn dot_handles_remainders() {
        for n in 0..9 {
            let x: Vec<f32> = (0..n).map(|i| i as f32).collect();
            let y: Vec<f32> = (0..n).map(|i| (i * 2) as f32).collect();
            let want: f32 = (0..n).map(|i| (i * i * 2) as f32).sum();
            assert_eq!(dot(&x, &y), want, "n={n}");
        }
    }
}
