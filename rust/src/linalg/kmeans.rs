//! k-means clustering for model folding.
//!
//! Folding (paper §3.1) groups channels into K clusters over their
//! *weight rows* (or activation profiles) and replaces each cluster by
//! its centroid; the merge map `M_fold(h,k) = 1/|C_k|` for `h ∈ C_k`.
//! This is Lloyd's algorithm with k-means++ seeding and empty-cluster
//! re-seeding — deterministic given the RNG seed.

use crate::rng::Pcg64;
use crate::tensor::Tensor;

/// Output of [`kmeans`]: cluster assignment per point plus centroids.
pub struct KmeansResult {
    /// `assign[i]` = cluster index of point `i` (in `0..k`).
    pub assign: Vec<usize>,
    /// Centroids, `[k, d]`.
    pub centroids: Tensor,
    /// Final within-cluster sum of squares.
    pub inertia: f64,
    /// Iterations executed.
    pub iters: usize,
}

fn dist2(a: &[f32], b: &[f32]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(x, y)| {
            let d = (*x - *y) as f64;
            d * d
        })
        .sum()
}

/// Cluster the rows of `x: [n, d]` into `k` groups.
///
/// Panics if `k == 0` or `k > n`.
pub fn kmeans(x: &Tensor, k: usize, rng: &mut Pcg64, max_iters: usize) -> KmeansResult {
    let (n, d) = (x.dim(0), x.dim(1));
    assert!(k >= 1 && k <= n, "kmeans: k={k} out of range for n={n}");

    // --- k-means++ seeding ---
    let mut centroids = Tensor::zeros(&[k, d]);
    let first = rng.below(n);
    centroids.row_mut(0).copy_from_slice(x.row(first));
    let mut d2: Vec<f64> = (0..n).map(|i| dist2(x.row(i), centroids.row(0))).collect();
    for c in 1..k {
        let total: f64 = d2.iter().sum();
        let pick = if total <= 0.0 {
            rng.below(n)
        } else {
            let mut t = rng.next_f64() * total;
            let mut idx = n - 1;
            for (i, &w) in d2.iter().enumerate() {
                t -= w;
                if t <= 0.0 {
                    idx = i;
                    break;
                }
            }
            idx
        };
        centroids.row_mut(c).copy_from_slice(x.row(pick));
        for i in 0..n {
            d2[i] = d2[i].min(dist2(x.row(i), centroids.row(c)));
        }
    }

    // --- Lloyd iterations ---
    let mut assign = vec![0usize; n];
    let mut inertia = f64::INFINITY;
    let mut iters = 0;
    for it in 0..max_iters {
        iters = it + 1;
        // Assignment step.
        let mut new_inertia = 0.0f64;
        let mut changed = false;
        for i in 0..n {
            let (mut best, mut best_d) = (0usize, f64::INFINITY);
            for c in 0..k {
                let dd = dist2(x.row(i), centroids.row(c));
                if dd < best_d {
                    best_d = dd;
                    best = c;
                }
            }
            if assign[i] != best {
                assign[i] = best;
                changed = true;
            }
            new_inertia += best_d;
        }
        // Update step.
        let mut counts = vec![0usize; k];
        let mut sums = Tensor::zeros(&[k, d]);
        for i in 0..n {
            counts[assign[i]] += 1;
            for (s, &v) in sums.row_mut(assign[i]).iter_mut().zip(x.row(i)) {
                *s += v;
            }
        }
        for c in 0..k {
            if counts[c] == 0 {
                // Re-seed an empty cluster at the point farthest from
                // its centroid.
                let far = (0..n)
                    .max_by(|&a, &b| {
                        dist2(x.row(a), centroids.row(assign[a]))
                            .total_cmp(&dist2(x.row(b), centroids.row(assign[b])))
                    })
                    .unwrap_or_else(|| rng.below(n));
                centroids.row_mut(c).copy_from_slice(x.row(far));
                changed = true;
            } else {
                let inv = 1.0 / counts[c] as f32;
                for (cd, &s) in centroids.row_mut(c).iter_mut().zip(sums.row(c)) {
                    *cd = s * inv;
                }
            }
        }
        inertia = new_inertia;
        if !changed && it > 0 {
            break;
        }
    }
    KmeansResult { assign, centroids, inertia, iters }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blob_data() -> Tensor {
        // Three well-separated 2-D blobs, 5 points each.
        let mut pts = Vec::new();
        for (cx, cy) in [(0.0f32, 0.0f32), (10.0, 10.0), (-10.0, 10.0)] {
            for i in 0..5 {
                pts.push(cx + 0.1 * i as f32);
                pts.push(cy - 0.1 * i as f32);
            }
        }
        Tensor::from_vec(&[15, 2], pts)
    }

    #[test]
    fn separates_blobs() {
        let x = blob_data();
        let mut rng = Pcg64::seed(1);
        let r = kmeans(&x, 3, &mut rng, 50);
        // Points within a blob share a label; across blobs differ.
        for b in 0..3 {
            let l0 = r.assign[b * 5];
            for i in 0..5 {
                assert_eq!(r.assign[b * 5 + i], l0, "blob {b}");
            }
        }
        let labels: std::collections::HashSet<_> = r.assign.iter().collect();
        assert_eq!(labels.len(), 3);
        assert!(r.inertia < 1.0);
    }

    #[test]
    fn k_equals_n_zero_inertia() {
        let x = blob_data();
        let mut rng = Pcg64::seed(2);
        let r = kmeans(&x, 15, &mut rng, 50);
        assert!(r.inertia < 1e-9);
    }

    #[test]
    fn k_one_centroid_is_mean() {
        let x = blob_data();
        let mut rng = Pcg64::seed(3);
        let r = kmeans(&x, 1, &mut rng, 50);
        let mu = crate::tensor::ops::col_mean(&x);
        for j in 0..2 {
            assert!((r.centroids.at2(0, j) - mu[j]).abs() < 1e-4);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let x = blob_data();
        let a = kmeans(&x, 3, &mut Pcg64::seed(7), 50);
        let b = kmeans(&x, 3, &mut Pcg64::seed(7), 50);
        assert_eq!(a.assign, b.assign);
    }

    #[test]
    #[should_panic]
    fn k_zero_panics() {
        let x = blob_data();
        kmeans(&x, 0, &mut Pcg64::seed(1), 10);
    }
}
